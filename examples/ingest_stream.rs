//! Streaming-ingestion example: the L3 data-pipeline coordinator.
//!
//! A producer thread streams data facts (entities first, then links)
//! through a bounded channel into sharded table builders with
//! backpressure; single-relationship positive ct-tables and entity
//! marginals are maintained *incrementally* during ingestion.  After the
//! stream ends, the assembled database immediately serves complete
//! ct-tables through HYBRID, and we verify the incremental counters
//! against fresh batch queries.
//!
//! Run: `cargo run --release --example ingest_stream -- [preset] [scale]`

use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::query::{groupby_entity, positive_chain_ct, JoinStats};
use relcount::meta::extract::{vars_for_chain, vars_for_entity};
use relcount::pipeline::ingest::{ingest, IngestorConfig};
use relcount::pipeline::source::db_to_facts;
use relcount::strategies::traits::StrategyConfig;
use relcount::strategies::StrategyKind;

fn main() -> relcount::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("financial");
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.1);

    let cfg = preset(name, scale, 21)?;
    let source_db = generate(&cfg)?;
    let facts = db_to_facts(&source_db);
    println!(
        "streaming {} facts of {name} @ scale {scale} through the pipeline...",
        facts.len()
    );

    let icfg = IngestorConfig { batch_size: 512, channel_batches: 4, incremental_counts: true };
    let rep = ingest(source_db.schema.clone(), facts, icfg)?;
    println!(
        "ingested {} facts in {} batches, {:.3}s \
         (producer blocked {:.3}s on backpressure)",
        rep.facts,
        rep.batches,
        rep.elapsed.as_secs_f64(),
        rep.producer_blocked.as_secs_f64()
    );

    // Verify the incremental counters against batch queries.
    let db = &rep.db;
    let inc = rep.incremental.as_ref().expect("incremental counts on");
    for et in 0..db.schema.entities.len() {
        let vars = vars_for_entity(&db.schema, et);
        let batch = groupby_entity(db, et, &vars)?;
        assert_eq!(inc.entity_cts[et].n_rows(), batch.n_rows());
    }
    let mut stats = JoinStats::default();
    for rel in 0..db.schema.relationships.len() {
        let vars = vars_for_chain(&db.schema, &[rel]);
        let batch = positive_chain_ct(db, &[rel], &vars, &mut stats)?;
        assert_eq!(inc.rel_cts[rel].n_rows(), batch.n_rows(), "rel {rel}");
    }
    println!(
        "incremental counters match batch queries ✓ \
         ({} single-rel tables, {} entity marginals)",
        db.schema.relationships.len(),
        db.schema.entities.len()
    );

    // The assembled database serves counting queries right away.
    let mut strategy = StrategyKind::Hybrid.build(db, StrategyConfig::default())?;
    strategy.prepare()?;
    let lattice = relcount::lattice::Lattice::build(&db.schema, 2)?;
    let mut served = 0usize;
    for p in &lattice.points {
        let ct = strategy.ct_for_family(&p.all_vars(), &p.pops)?;
        served += 1;
        println!(
            "  ct({:?}): {} rows, total mass {} (= product of populations {:?})",
            p.rels,
            ct.n_rows(),
            ct.total()?,
            p.pops
        );
        assert_eq!(ct.total()? as u64, db.population_product(&p.pops));
    }
    println!("served {served} complete ct-tables from the ingested database ✓");
    Ok(())
}
