//! Structure-learning example: learn a first-order Bayesian network on a
//! benchmark preset with the learn-and-join lattice search, and print the
//! model, its families, the MP/N statistic (paper Table 4) and the
//! counting workload it generated.
//!
//! Run: `cargo run --release --example learn_structure -- [preset] [scale]`
//! (defaults: movielens at scale 0.1)

use relcount::datagen::{generator::generate, presets::preset};
use relcount::learn::search::{learn, SearchConfig};
use relcount::strategies::traits::StrategyConfig;
use relcount::strategies::StrategyKind;

fn main() -> relcount::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("movielens");
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.1);

    let cfg = preset(name, scale, 7)?;
    let db = generate(&cfg)?;
    println!(
        "{name} @ scale {scale}: {} rows, {} relationships, {} entity types\n",
        db.total_rows(),
        db.n_relationships(),
        db.schema.entities.len()
    );

    let mut strategy = StrategyKind::Hybrid.build(&db, StrategyConfig::default())?;
    let t0 = std::time::Instant::now();
    let model = learn(&db, strategy.as_mut(), SearchConfig::default())?;
    let elapsed = t0.elapsed();

    println!("learned first-order Bayesian network:");
    print!("{}", model.bn.display(&db.schema));
    println!();
    println!("nodes:              {}", model.bn.nodes.len());
    println!("edges:              {}", model.bn.n_edges());
    println!("MP/N (Table 4):     {:.2}", model.bn.mean_parents_per_node());
    println!("total BDeu score:   {:.3}", model.total_score);
    println!("families counted:   {}", model.families_scored);
    println!("score cache hits:   {}", model.score_cache_hits);
    println!("wall time:          {:.3}s", elapsed.as_secs_f64());

    let rep = strategy.report();
    println!(
        "\ncounting workload ({}): {} JOIN queries, {} rows enumerated, \
         {} ct rows generated, {:.1} KiB peak ct memory",
        rep.name,
        rep.join_stats.chain_queries,
        rep.join_stats.rows_enumerated,
        rep.ct_rows_generated,
        rep.peak_ct_bytes as f64 / 1024.0
    );
    println!(
        "timing: metadata {:.3}s, positive ct {:.3}s, negative ct {:.3}s",
        rep.timing.metadata.as_secs_f64(),
        rep.timing.positive.as_secs_f64(),
        rep.timing.negative.as_secs_f64()
    );
    Ok(())
}
