//! Quickstart: the paper's running example, end to end.
//!
//! Builds the University database (12 professors x 19 students, RA
//! tuples exactly matching the paper's Table 3), computes the complete
//! ct-table for the `Capa(P,S), RA(P,S), Salary(P,S)` pattern with the
//! HYBRID strategy, prints it next to the paper's numbers, and scores
//! the paper's example family `RA, Capa -> Salary` with BDeu.
//!
//! Run: `cargo run --release --example quickstart`

use relcount::db::fixtures::{university_db, TABLE3_NEGATIVE, TABLE3_POSITIVE};
use relcount::learn::score::bdeu_from_ct;
use relcount::meta::rvar::RVar;
use relcount::strategies::traits::StrategyConfig;
use relcount::strategies::StrategyKind;

fn main() -> relcount::Result<()> {
    let db = university_db();
    println!(
        "University database: {} professors, {} students, {} courses, {} RA tuples\n",
        db.population(0),
        db.population(1),
        db.population(2),
        db.rels[0].len()
    );

    // The pattern of the paper's Table 3.
    let vars = vec![
        RVar::RelAttr { rel: 0, attr: 0 }, // Capa(P,S)
        RVar::RelInd { rel: 0 },           // RA(P,S)
        RVar::RelAttr { rel: 0, attr: 1 }, // Salary(P,S)
    ];

    let mut hybrid = StrategyKind::Hybrid.build(&db, StrategyConfig::default())?;
    hybrid.prepare()?; // Algorithm 3 lines 1-3: positive pre-count
    let ct = hybrid.ct_for_family(&vars, &[0, 1])?; // lines 5-6: Möbius

    println!("complete ct-table (cf. paper Table 3):");
    println!("{}", ct.render(&db.schema));

    // Verify against the published counts.
    assert_eq!(ct.get(&[0, 0, 0])?, TABLE3_NEGATIVE as i128);
    for &(capa, sal, count) in TABLE3_POSITIVE {
        assert_eq!(ct.get(&[capa, 1, sal + 1])?, count as i128);
    }
    println!("all 10 rows match the paper's Table 3 ✓\n");

    // The paper's example family: RA(P,S), Capa(P,S) -> Salary(P,S).
    let salary = RVar::RelAttr { rel: 0, attr: 1 };
    let score = bdeu_from_ct(&ct, &salary, 1.0)?;
    println!("BDeu(salary(P,S) <- RA(P,S), capability(P,S)) = {score:.4}");

    let report = hybrid.report();
    println!(
        "\nstrategy report: {} chain JOINs, {} ct rows generated, \
         {:.1} KiB peak ct memory",
        report.join_stats.chain_queries,
        report.ct_rows_generated,
        report.peak_ct_bytes as f64 / 1024.0
    );
    Ok(())
}
