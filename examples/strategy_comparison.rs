//! End-to-end validation driver (DESIGN.md §5, deliverable (b)/E2E).
//!
//! Regenerates the paper's whole evaluation on scaled presets: for each
//! benchmark database and each counting strategy it runs full structure
//! learning, collects the Figure-3 timing breakdown, the Figure-4 memory
//! peaks and the Table-5 ct-size columns, verifies that all strategies
//! produced identical models (the Table-2 interchangeability), and prints
//! the headline comparison.
//!
//! Run: `cargo run --release --example strategy_comparison`
//! Env: RELCOUNT_SCALE (default 0.1), RELCOUNT_BUDGET_S (default 120),
//!      RELCOUNT_PRESETS (default: the five small/medium presets; pass
//!      `all` for the full 8 including imdb and visual_genome).

use std::time::Duration;

use relcount::bench::driver::{run_strategy, Workload};
use relcount::bench::experiments::paper_rows;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::learn::search::SearchConfig;
use relcount::metrics::report::{
    render_fig3, render_fig4, render_table5, RunRow, Table5Row,
};
use relcount::strategies::StrategyKind;

fn main() -> relcount::Result<()> {
    let scale: f64 = std::env::var("RELCOUNT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let budget = Duration::from_secs(
        std::env::var("RELCOUNT_BUDGET_S")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(120),
    );
    let presets: Vec<String> = match std::env::var("RELCOUNT_PRESETS").as_deref() {
        Ok("all") => relcount::datagen::presets::PRESET_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect(),
        Ok(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        Err(_) => ["uw", "mondial", "hepatitis", "mutagenesis", "movielens"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    println!(
        "strategy comparison: scale={scale}, budget={budget:?}, presets={presets:?}\n"
    );

    let search = SearchConfig::default();
    let mut fig_rows: Vec<RunRow> = Vec::new();
    let mut t5_rows: Vec<Table5Row> = Vec::new();

    for name in &presets {
        let cfg = preset(name, scale, 0)?;
        let db = generate(&cfg)?;
        println!(
            "-- {name}: {} rows ({} at paper scale), {} relationships",
            db.total_rows(),
            paper_rows(name).unwrap_or(0),
            db.n_relationships()
        );

        let mut models = Vec::new();
        let mut hybrid_family_rows = 0;
        for kind in StrategyKind::ALL {
            let out = run_strategy(&db, name, kind, Workload::Learn(search), Some(budget))?;
            println!(
                "   {:<9} total {:>8.3}s  (meta {:.3} + ct+ {:.3} + ct- {:.3})  \
                 joins {:>6}  peak {:>8.1} KiB{}",
                kind.name(),
                out.row.total().as_secs_f64(),
                out.row.metadata.as_secs_f64(),
                out.row.positive.as_secs_f64(),
                out.row.negative.as_secs_f64(),
                out.report.join_stats.chain_queries,
                out.row.peak_ct_bytes as f64 / 1024.0,
                if out.row.timed_out { "  ** TIMEOUT **" } else { "" }
            );
            if kind == StrategyKind::Hybrid {
                hybrid_family_rows = out.report.ct_rows_generated;
            }
            if let Some(m) = out.model {
                models.push((kind, m));
            }
            fig_rows.push(out.row);
        }

        // Table-2 interchangeability: identical learned models.
        if models.len() >= 2 {
            let (_, first) = &models[0];
            for (kind, m) in &models[1..] {
                assert_eq!(
                    m.bn.parents, first.bn.parents,
                    "{name}: {} disagrees with {}",
                    kind.name(),
                    models[0].0.name()
                );
            }
            println!(
                "   models identical across strategies ✓ (MP/N {:.2}, score {:.1})",
                first.bn.mean_parents_per_node(),
                first.total_score
            );
        }

        // Table 5 columns.
        let pre = run_strategy(
            &db,
            name,
            StrategyKind::Precount,
            Workload::PrepareOnly,
            Some(budget),
        )?;
        t5_rows.push(Table5Row {
            database: name.clone(),
            ct_family_rows: hybrid_family_rows,
            ct_database_rows: pre.report.ct_rows_generated,
        });
        println!();
    }

    println!("\n== Figure 3 (time breakdown) ==");
    print!("{}", render_fig3(&fig_rows));
    println!("\n== Figure 4 (peak ct memory) ==");
    print!("{}", render_fig4(&fig_rows));
    println!("\n== Table 5 (ct rows) ==");
    print!("{}", render_table5(&t5_rows));

    // Headline: HYBRID vs the others, on totals over all presets.
    let total_of = |s: &str| -> f64 {
        fig_rows
            .iter()
            .filter(|r| r.strategy == s && !r.timed_out)
            .map(|r| r.total().as_secs_f64())
            .sum()
    };
    println!("\n== headline ==");
    for kind in StrategyKind::ALL {
        let timeouts = fig_rows
            .iter()
            .filter(|r| r.strategy == kind.name() && r.timed_out)
            .count();
        println!(
            "{:<9} total {:>9.3}s over completed cells, {timeouts} timeouts",
            kind.name(),
            total_of(kind.name())
        );
    }
    Ok(())
}
