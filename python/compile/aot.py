"""AOT lowering: JAX -> HLO *text* artifacts + manifest for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/load_hlo and its README.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per entry point plus ``manifest.json``
describing input/output shapes+dtypes, which ``rust/src/runtime`` reads at
startup.  Python runs ONLY here; the Rust binary is self-contained after
``make artifacts``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import bdeu as bdeu_k  # noqa: E402
from .kernels import mobius as mobius_k  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _desc(shape, dtype):
    return {"shape": list(shape), "dtype": str(jnp.dtype(dtype).name)}


def build_artifacts():
    """Returns {name: (lowered, inputs_desc, outputs_desc, meta)}."""
    d, k, e = mobius_k.D_PAD, mobius_k.K_REL, mobius_k.E_PAD
    b, q, r = bdeu_k.B_PAD, bdeu_k.Q_PAD, bdeu_k.R_PAD
    f64, i32 = jnp.float64, jnp.int32
    g_shape = (d,) * k + (e,)
    cells = d**k * e

    arts = {}

    # 1. Mobius Join over the dense family tensor.
    low = jax.jit(model.complete_ct).lower(_spec(g_shape, f64))
    arts["mobius"] = (
        low,
        [("g", _desc(g_shape, f64))],
        [("complete", _desc(g_shape, f64))],
        {"d_pad": d, "k_rel": k, "e_pad": e},
    )

    # 2. Batched BDeu (the structure-search hot path).
    low = jax.jit(model.bdeu_scores).lower(
        _spec((b, q, r), f64), _spec((b,), f64), _spec((b,), f64)
    )
    arts["bdeu_batch"] = (
        low,
        [
            ("counts", _desc((b, q, r), f64)),
            ("alpha_row", _desc((b,), f64)),
            ("alpha_cell", _desc((b,), f64)),
        ],
        [("scores", _desc((b,), f64))],
        {"b_pad": b, "q_pad": q, "r_pad": r},
    )

    # 3. Single-family BDeu (no batching latency for interactive paths).
    low = jax.jit(model.bdeu_scores).lower(
        _spec((1, q, r), f64), _spec((1,), f64), _spec((1,), f64)
    )
    arts["bdeu_one"] = (
        low,
        [
            ("counts", _desc((1, q, r), f64)),
            ("alpha_row", _desc((1,), f64)),
            ("alpha_cell", _desc((1,), f64)),
        ],
        [("scores", _desc((1,), f64))],
        {"b_pad": 1, "q_pad": q, "r_pad": r},
    )

    # 4. Fused Mobius + projection + BDeu for one family.
    low = jax.jit(model.family_score).lower(
        _spec(g_shape, f64),
        _spec((cells,), i32),
        _spec((1,), f64),
        _spec((1,), f64),
    )
    arts["family_score"] = (
        low,
        [
            ("g", _desc(g_shape, f64)),
            ("seg", _desc((cells,), i32)),
            ("alpha_row", _desc((1,), f64)),
            ("alpha_cell", _desc((1,), f64)),
        ],
        [("score", _desc((1,), f64)), ("complete", _desc(g_shape, f64))],
        {"d_pad": d, "k_rel": k, "e_pad": e, "q_pad": q, "r_pad": r},
    )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": {}}
    for name, (lowered, ins, outs, meta) in build_artifacts().items():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [{"name": n, **d} for n, d in ins],
            "outputs": [{"name": n, **d} for n, d in outs],
            "meta": meta,
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
