# L1 Pallas kernels for relcount: the fast-Mobius butterfly and the
# batched BDeu lgamma reduction.  Each kernel has a pure-jnp oracle in
# ref.py; pytest/hypothesis compares them (the core correctness signal).
from . import bdeu, mobius, ref  # noqa: F401
