"""L1 Pallas kernel: batched BDeu family scoring.

BDeu (Equation 1 of the paper) per family i:

    sum_j [ lgamma(N'/q_i) - lgamma(N_ij + N'/q_i) ]
  + sum_jk [ lgamma(N_ijk + N'/(r_i q_i)) - lgamma(N'/(r_i q_i)) ]

We stream a batch of B families, each a padded ``[Q, R]`` count matrix
plus two scalars (alpha_row = N'/q_i, alpha_cell = N'/(r_i q_i)), and emit
one score per family.  Zero-count rows/cells contribute exactly 0 in the
difference form above, so padding Q and R is exact — the true q_i, r_i
enter only through the alpha scalars computed by the Rust coordinator.

Hardware adaptation: lgamma is a transcendental VPU op; the kernel is a
map-reduce with no matmuls.  The grid runs one program per family, so the
VMEM tile is a single [Q, R] matrix (default 256x16 f64 = 32 KiB).  The
Rust coordinator's micro-batcher fills B slots per call to amortize the
PJRT dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Default padded dims for AOT artifacts.
B_PAD = 64  # families per batch
Q_PAD = 256  # parent configurations
R_PAD = 16  # child values


def _bdeu_kernel(counts_ref, ar_ref, ac_ref, o_ref):
    c = counts_ref[0]  # [Q, R]
    ar = ar_ref[0]
    ac = ac_ref[0]
    nij = jnp.sum(c, axis=1)  # [Q]
    row_term = jnp.where(
        nij > 0.0, jax.lax.lgamma(ar) - jax.lax.lgamma(nij + ar), 0.0
    )
    cell_term = jnp.where(
        c > 0.0, jax.lax.lgamma(c + ac) - jax.lax.lgamma(ac), 0.0
    )
    o_ref[0] = jnp.sum(row_term) + jnp.sum(cell_term)


@jax.jit
def bdeu_pallas(
    counts: jnp.ndarray, alpha_row: jnp.ndarray, alpha_cell: jnp.ndarray
) -> jnp.ndarray:
    """Batched BDeu scores.

    counts     : [B, Q, R] float64 (padded with zeros)
    alpha_row  : [B] float64, N' / q_i
    alpha_cell : [B] float64, N' / (q_i r_i)
    returns    : [B] float64 log-scores (structure prior excluded)
    """
    b, q, r = counts.shape
    return pl.pallas_call(
        _bdeu_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, q, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), counts.dtype),
        interpret=True,
    )(counts, alpha_row, alpha_cell)


@functools.partial(jax.jit, static_argnames=("n_prime",))
def alphas_for(q: jnp.ndarray, r: jnp.ndarray, n_prime: float = 1.0):
    """Convenience: (alpha_row, alpha_cell) from true q_i, r_i vectors."""
    q = jnp.asarray(q, dtype=jnp.float64)
    r = jnp.asarray(r, dtype=jnp.float64)
    return n_prime / q, n_prime / (q * r)
