"""L1 Pallas kernel: the fast-Mobius butterfly over a dense family tensor.

The Mobius Join (Qian, Schulte & Sun 2014) extends a *positive* ct-table
(counts for existing relationships only) to a *complete* ct-table (counts
for both existing and non-existing relationships) by inclusion–exclusion,
with no further access to the original database.  Over the dense padded
layout described in ``ref.py`` this is a butterfly: for each relationship
axis, subtract the sum of the true-slices from the ⊥ slice.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the transform is a
pure VPU workload — three axis-wise reductions + one update per axis, no
matmuls.  We tile over the trailing entity-attribute axis with a
``BlockSpec`` so each grid program holds a ``[D1, D2, D3, E_BLK]`` tile in
VMEM (for the default D=8, E_BLK=256 at f64 that is 8^3*256*8 B = 1 MiB,
comfortably inside a TPU core's ~16 MiB VMEM with double buffering).  The
axes are independent along E, so the grid is embarrassingly parallel.

``interpret=True`` is mandatory on this image: CPU PJRT cannot execute
Mosaic custom-calls.  Numerics are identical either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Default padded dims for AOT artifacts (rust falls back to its sparse
# exact path for families that exceed them).
D_PAD = 8  # per-relationship combined (indicator, attr) axis
K_REL = 3  # number of relationship axes in the artifact layout
E_PAD = 1024  # flattened entity-attribute axis
E_BLK = 256  # VMEM tile along E


def _mobius_kernel(g_ref, o_ref):
    """One grid program: full butterfly on a [D1,...,Dk,E_BLK] tile."""
    t = g_ref[...]
    k = t.ndim - 1
    for axis in range(k):
        # true_sum over slots >= 1 of this axis.
        true_sum = jnp.sum(
            jax.lax.slice_in_dim(t, 1, t.shape[axis], axis=axis), axis=axis
        )
        bot = jax.lax.index_in_dim(t, 0, axis=axis, keepdims=False)
        t = jax.lax.dynamic_update_index_in_dim(t, bot - true_sum, 0, axis)
    o_ref[...] = t


@functools.partial(jax.jit, static_argnames=("e_blk",))
def mobius_pallas(g: jnp.ndarray, e_blk: int = E_BLK) -> jnp.ndarray:
    """Complete ct-tensor from a positive/unconstrained ct-tensor.

    g : [D_1, ..., D_k, E] float64, E divisible by ``e_blk``.
    """
    dims = g.shape[:-1]
    e = g.shape[-1]
    if e % e_blk != 0:
        raise ValueError(f"E={e} not divisible by e_blk={e_blk}")
    grid = (e // e_blk,)
    nlead = len(dims)
    block = (*dims, e_blk)

    def index_map(i):
        return (*([0] * nlead), i)

    return pl.pallas_call(
        _mobius_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(block, index_map)],
        out_specs=pl.BlockSpec(block, index_map),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(g)


def mobius_inverse_ref(f: jnp.ndarray) -> jnp.ndarray:
    """Zeta transform (inverse of the butterfly): ⊥ slice becomes the sum
    over all slots.  Used in tests to prove the kernel is a bijection."""
    t = jnp.asarray(f)
    k = t.ndim - 1
    for axis in range(k):
        true_sum = jnp.sum(
            jax.lax.slice_in_dim(t, 1, t.shape[axis], axis=axis), axis=axis
        )
        bot = jax.lax.index_in_dim(t, 0, axis=axis, keepdims=False)
        t = jax.lax.dynamic_update_index_in_dim(t, bot + true_sum, 0, axis)
    return t
