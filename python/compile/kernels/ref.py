"""Pure-jnp reference oracles for the L1 kernels.

These are the ground truth the Pallas kernels are validated against in
pytest.  They are deliberately written in the most transparent way
possible (even when slower), because the whole counting pipeline's
correctness rests on them:

- ``mobius_ref``      : axis-by-axis fast Mobius transform.
- ``mobius_ie_ref``   : direct inclusion-exclusion over subsets (an
                        *independent* derivation, used to check
                        ``mobius_ref`` itself).
- ``bdeu_ref``        : vectorized BDeu family score (Equation 1 of the
                        paper, without the structure-prior term which the
                        Rust coordinator adds).
- ``bdeu_scalar_ref`` : python-loop BDeu using ``math.lgamma`` — an
                        independent derivation to check ``bdeu_ref``.

Conventions for the dense family tensor (see DESIGN.md §2):

The tensor ``g`` has shape ``[D_1, ..., D_k, E]``.  Axis ``i < k`` is the
combined (indicator, rel-attribute) axis of relationship ``i``; coordinate
0 is the ⊥ slot and coordinates ``1..`` are (true, attr-value) slots.  The
trailing axis flattens all entity-attribute configurations.  On input,
``g[d_1, ..., d_k, e]`` is the count of groundings where, for each ``i``
with ``d_i != 0``, relationship ``i`` holds with its attribute equal to
slot ``d_i``, and relationships with ``d_i == 0`` are *unconstrained*.
On output, ``d_i == 0`` means relationship ``i`` is *false* (rel attrs
N/A).  Zero-padding in unused slots/axes is provably neutral.
"""

from __future__ import annotations

import itertools
import math

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Mobius transform references
# ---------------------------------------------------------------------------


def mobius_ref(g: jnp.ndarray) -> jnp.ndarray:
    """Fast Mobius transform: for each rel axis, subtract the sum of the
    true-slices from the ⊥ slice.  O(k * prod(dims)) work."""
    t = jnp.asarray(g)
    k = t.ndim - 1  # trailing axis is the entity-attribute axis
    for axis in range(k):
        true_sum = jnp.sum(
            jax.lax.slice_in_dim(t, 1, t.shape[axis], axis=axis), axis=axis
        )
        bot = jax.lax.index_in_dim(t, 0, axis=axis, keepdims=False)
        t = jax.lax.dynamic_update_index_in_dim(t, bot - true_sum, 0, axis)
    return t


def mobius_ie_ref(g) -> jnp.ndarray:
    """Direct inclusion-exclusion.  For an output cell with bottom-set
    ``B = {i : d_i = 0}``, the exact count is

        f(d) = sum_{S subseteq B} (-1)^{|S|} g(d with axes in S summed
                                               over their true slots)

    which is the textbook superset Mobius inversion.  Exponential in k —
    test-only."""
    import numpy as np

    g = np.asarray(g)
    k = g.ndim - 1
    out = np.array(g, copy=True)
    # Per subset of axes S: g with axes in S summed over their true slots
    # (slots >= 1), dims kept for easy indexing.
    true_sums = {}
    for r in range(0, k + 1):
        for S in itertools.combinations(range(k), r):
            t = g
            for axis in S:
                sl = [slice(None)] * g.ndim
                sl[axis] = slice(1, None)
                t = t[tuple(sl)].sum(axis=axis, keepdims=True)
            true_sums[frozenset(S)] = t
    for idx in itertools.product(*[range(d) for d in g.shape[:-1]]):
        bottom = [i for i in range(k) if idx[i] == 0]
        total = np.zeros(g.shape[-1], dtype=g.dtype)
        for r in range(0, len(bottom) + 1):
            for S in itertools.combinations(bottom, r):
                t = true_sums[frozenset(S)]
                sel = tuple(0 if i in S else idx[i] for i in range(k))
                total = total + ((-1) ** r) * t[sel]
        out[idx] = total
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# BDeu references
# ---------------------------------------------------------------------------


def bdeu_ref(
    counts: jnp.ndarray, alpha_row: jnp.ndarray, alpha_cell: jnp.ndarray
) -> jnp.ndarray:
    """Vectorized BDeu family scores.

    counts     : [B, Q, R] nonneg float64 — N_ijk per (family b, parent
                 config j, child value k).  Zero rows are padding and
                 contribute exactly 0.
    alpha_row  : [B] — N' / q_i   (true q, not the padded Q)
    alpha_cell : [B] — N' / (q_i r_i)
    returns    : [B] log score (without the log P(B) structure prior).
    """
    counts = jnp.asarray(counts, dtype=jnp.float64)
    ar = jnp.asarray(alpha_row, dtype=jnp.float64)[:, None]
    ac = jnp.asarray(alpha_cell, dtype=jnp.float64)[:, None, None]
    nij = jnp.sum(counts, axis=2)  # [B, Q]
    row_term = jnp.where(
        nij > 0, jax.lax.lgamma(ar) - jax.lax.lgamma(nij + ar), 0.0
    )
    cell_term = jnp.where(
        counts > 0,
        jax.lax.lgamma(counts + ac) - jax.lax.lgamma(ac),
        0.0,
    )
    return jnp.sum(row_term, axis=1) + jnp.sum(cell_term, axis=(1, 2))


def bdeu_scalar_ref(counts, alpha_row: float, alpha_cell: float) -> float:
    """Independent scalar derivation with math.lgamma (one family)."""
    total = 0.0
    for row in counts:
        nij = float(sum(row))
        if nij <= 0:
            continue
        total += math.lgamma(alpha_row) - math.lgamma(nij + alpha_row)
        for c in row:
            c = float(c)
            if c > 0:
                total += math.lgamma(c + alpha_cell) - math.lgamma(alpha_cell)
    return total
