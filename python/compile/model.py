"""L2: the build-time JAX compute graphs, composing the L1 kernels.

Three entry points, each AOT-lowered to an HLO-text artifact by
``aot.py`` and executed from the Rust coordinator via PJRT:

- ``complete_ct``  : positive/unconstrained family tensor -> complete
                     ct-tensor (the Mobius Join, L1 butterfly kernel).
- ``bdeu_scores``  : batched family count matrices -> BDeu scores
                     (L1 lgamma kernel).  This is the structure-search
                     hot path; the Rust micro-batcher fills the B axis.
- ``family_score`` : the fused path — Mobius Join, then projection onto
                     a (parent-config, child-value) contingency matrix
                     expressed as a segment-sum with a Rust-precomputed
                     cell->segment map, then BDeu.  One PJRT round trip
                     per family instead of two.

Everything is float64: counts are exact integers up to 2^53, which covers
the cross-product totals of the largest preset (Visual Genome) with many
orders of magnitude to spare.  Python never runs at request time; these
functions exist only to be lowered.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import bdeu as bdeu_k
from .kernels import mobius as mobius_k

jax.config.update("jax_enable_x64", True)


def complete_ct(g: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Mobius Join over the dense padded family tensor (see kernels.ref)."""
    return (mobius_k.mobius_pallas(g),)


def bdeu_scores(
    counts: jnp.ndarray, alpha_row: jnp.ndarray, alpha_cell: jnp.ndarray
) -> tuple[jnp.ndarray]:
    """Batched BDeu family scores (structure prior added in Rust)."""
    return (bdeu_k.bdeu_pallas(counts, alpha_row, alpha_cell),)


def family_score(
    g: jnp.ndarray,
    seg: jnp.ndarray,
    alpha_row: jnp.ndarray,
    alpha_cell: jnp.ndarray,
    *,
    q_pad: int = bdeu_k.Q_PAD,
    r_pad: int = bdeu_k.R_PAD,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Mobius Join + projection + BDeu for one family.

    g         : [D,..,D,E] float64 positive/unconstrained tensor
    seg       : [prod(g.shape)] int32 — for each cell of the *complete*
                tensor, its flattened (j*r_pad + k) contingency slot, or
                q_pad*r_pad for cells outside the family (padding).
    alpha_row : [1] float64, alpha_cell : [1] float64
    returns   : (score [1], complete ct-tensor [D,..,D,E])
    """
    complete = mobius_k.mobius_pallas(g)
    flat = complete.reshape(-1)
    qr = jax.ops.segment_sum(flat, seg, num_segments=q_pad * r_pad + 1)
    counts = qr[: q_pad * r_pad].reshape(1, q_pad, r_pad)
    score = bdeu_k.bdeu_pallas(counts, alpha_row, alpha_cell)
    return (score, complete)
