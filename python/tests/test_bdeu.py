"""BDeu Pallas kernel vs references (Equation 1 of the paper)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import bdeu, ref


def rand_family(rng, q, r, max_count=60, sparsity=0.3):
    c = rng.integers(0, max_count, size=(q, r)).astype(np.float64)
    mask = rng.random(size=(q, r)) < sparsity
    c[mask] = 0.0
    return c


# ---------------------------------------------------------------------------
# kernel vs references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,q,r", [(1, 4, 2), (3, 8, 3), (8, 16, 5), (64, 256, 16)])
def test_pallas_matches_refs(b, q, r):
    rng = np.random.default_rng(0)
    counts = np.stack([rand_family(rng, q, r) for _ in range(b)])
    ar = rng.uniform(0.05, 3.0, b)
    ac = ar / r
    got = np.asarray(bdeu.bdeu_pallas(jnp.asarray(counts), jnp.asarray(ar), jnp.asarray(ac)))
    want = np.asarray(ref.bdeu_ref(counts, ar, ac))
    scalar = np.array(
        [ref.bdeu_scalar_ref(counts[i], ar[i], ac[i]) for i in range(b)]
    )
    np.testing.assert_allclose(got, want, rtol=1e-12)
    np.testing.assert_allclose(got, scalar, rtol=1e-9)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    b=st.integers(1, 6),
    q=st.integers(1, 20),
    r=st.integers(2, 8),
    n_prime=st.floats(0.5, 10.0),
)
def test_hypothesis_sweep(seed, b, q, r, n_prime):
    rng = np.random.default_rng(seed)
    counts = np.stack([rand_family(rng, q, r) for _ in range(b)])
    ar = np.full(b, n_prime / q)
    ac = np.full(b, n_prime / (q * r))
    got = np.asarray(bdeu.bdeu_pallas(jnp.asarray(counts), jnp.asarray(ar), jnp.asarray(ac)))
    scalar = np.array(
        [ref.bdeu_scalar_ref(counts[i], ar[i], ac[i]) for i in range(b)]
    )
    np.testing.assert_allclose(got, scalar, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# padding exactness — what lets Rust use fixed [Q_PAD, R_PAD] artifacts
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31), q=st.integers(1, 10), r=st.integers(2, 6))
def test_padding_is_exact(seed, q, r):
    """Zero-padding Q and R must not change the score: the true q_i, r_i
    enter only through the alpha scalars."""
    rng = np.random.default_rng(seed)
    c = rand_family(rng, q, r)
    ar = np.array([1.0 / q])
    ac = np.array([1.0 / (q * r)])
    base = np.asarray(
        bdeu.bdeu_pallas(jnp.asarray(c[None]), jnp.asarray(ar), jnp.asarray(ac))
    )[0]
    qp, rp = q + 7, r + 5
    cp = np.zeros((1, qp, rp))
    cp[0, :q, :r] = c
    padded = np.asarray(
        bdeu.bdeu_pallas(jnp.asarray(cp), jnp.asarray(ar), jnp.asarray(ac))
    )[0]
    assert padded == pytest.approx(base, rel=1e-12)


def test_known_value_uniform():
    """Hand-derivable case: one parent config (q=1), r=2, N' = 1,
    counts [a, b].  Score = lgamma(1) - lgamma(a+b+1)
    + lgamma(a+0.5) - lgamma(0.5) + lgamma(b+0.5) - lgamma(0.5)."""
    a, b_ = 3.0, 2.0
    want = (
        math.lgamma(1.0)
        - math.lgamma(a + b_ + 1.0)
        + math.lgamma(a + 0.5)
        - math.lgamma(0.5)
        + math.lgamma(b_ + 0.5)
        - math.lgamma(0.5)
    )
    got = np.asarray(
        bdeu.bdeu_pallas(
            jnp.asarray([[[a, b_]]]), jnp.asarray([1.0]), jnp.asarray([0.5])
        )
    )[0]
    assert got == pytest.approx(want, rel=1e-12)


def test_empty_family_scores_zero():
    got = np.asarray(
        bdeu.bdeu_pallas(
            jnp.zeros((1, 8, 4)), jnp.asarray([0.25]), jnp.asarray([0.0625])
        )
    )[0]
    assert got == 0.0


def test_score_decreases_with_data():
    """More data -> lower (more negative) raw log marginal likelihood."""
    c1 = jnp.asarray([[[5.0, 5.0]]])
    c2 = jnp.asarray([[[50.0, 50.0]]])
    ar = jnp.asarray([1.0])
    ac = jnp.asarray([0.5])
    s1 = float(bdeu.bdeu_pallas(c1, ar, ac)[0])
    s2 = float(bdeu.bdeu_pallas(c2, ar, ac)[0])
    assert s2 < s1 < 0.0


def test_alphas_for():
    ar, ac = bdeu.alphas_for(jnp.asarray([4.0]), jnp.asarray([2.0]), n_prime=8.0)
    assert float(ar[0]) == pytest.approx(2.0)
    assert float(ac[0]) == pytest.approx(1.0)
