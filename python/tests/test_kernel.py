"""Model-level (L2) and artifact-level (AOT) tests.

test_mobius.py / test_bdeu.py validate the L1 kernels against oracles;
here we validate the composed graphs that actually get lowered, and the
manifest contract the Rust runtime depends on.
"""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import bdeu as bdeu_k
from compile.kernels import mobius as mobius_k
from compile.kernels import ref


def small_family():
    """A tiny family in the full artifact layout: 1 real rel axis with 2
    attr slots, 2 entity-attr configs; rest padding."""
    d, k, e = mobius_k.D_PAD, mobius_k.K_REL, mobius_k.E_PAD
    g = np.zeros((d,) * k + (e,))
    rng = np.random.default_rng(0)
    g[0, 0, 0, :2] = rng.integers(20, 40, 2)  # unconstrained totals
    g[1:3, 0, 0, :2] = rng.integers(0, 10, (2, 2))  # true counts
    return jnp.asarray(g)


def test_complete_ct_composition():
    g = small_family()
    (got,) = model.complete_ct(g)
    want = ref.mobius_ref(g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


def test_family_score_fused_matches_pieces():
    """family_score == mobius -> segment projection -> bdeu, done by hand."""
    g = small_family()
    d, k, e = mobius_k.D_PAD, mobius_k.K_REL, mobius_k.E_PAD
    q_pad, r_pad = bdeu_k.Q_PAD, bdeu_k.R_PAD
    cells = d**k * e

    # family: parent = rel indicator (F/T -> j in {0,1}), child = entity
    # attr (2 values -> k in {0,1}); everything else -> dump slot.
    seg = np.full(cells, q_pad * r_pad, dtype=np.int32)
    gshape = (d,) * k + (e,)
    for d0 in range(3):  # slot 0 = false, slots 1,2 = true
        j = 0 if d0 == 0 else 1
        for ev in range(2):
            flat_idx = np.ravel_multi_index((d0, 0, 0, ev), gshape)
            seg[flat_idx] = j * r_pad + ev

    ar = jnp.asarray([0.5])  # N'=1, q=2
    ac = jnp.asarray([0.25])
    score, complete = model.family_score(g, jnp.asarray(seg), ar, ac)

    # by hand
    comp = np.asarray(ref.mobius_ref(g))
    counts = np.zeros((2, 2))
    for d0 in range(3):
        j = 0 if d0 == 0 else 1
        for ev in range(2):
            counts[j, ev] += comp[d0, 0, 0, ev]
    want = ref.bdeu_scalar_ref(counts, 0.5, 0.25)
    assert float(score[0]) == pytest.approx(want, rel=1e-12)
    np.testing.assert_allclose(np.asarray(complete), comp, rtol=0)


def test_family_score_dump_slot_discards_padding():
    """Cells mapped to the dump segment must not affect the score."""
    g = small_family()
    d, k, e = mobius_k.D_PAD, mobius_k.K_REL, mobius_k.E_PAD
    cells = d**k * e
    seg = np.full(cells, bdeu_k.Q_PAD * bdeu_k.R_PAD, dtype=np.int32)
    score, _ = model.family_score(
        g, jnp.asarray(seg), jnp.asarray([1.0]), jnp.asarray([0.5])
    )
    assert float(score[0]) == 0.0


# ---------------------------------------------------------------------------
# AOT artifacts
# ---------------------------------------------------------------------------


def test_hlo_text_lowering_roundtrip():
    """Every artifact lowers to parseable-looking HLO text with an ENTRY."""
    arts = aot.build_artifacts()
    assert set(arts) == {"mobius", "bdeu_batch", "bdeu_one", "family_score"}
    for name, (lowered, ins, outs, meta) in arts.items():
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        assert len(ins) >= 1 and len(outs) >= 1


def test_lowering_is_deterministic():
    a1 = aot.build_artifacts()
    a2 = aot.build_artifacts()
    for name in a1:
        t1 = aot.to_hlo_text(a1[name][0])
        t2 = aot.to_hlo_text(a2[name][0])
        assert t1 == t2, f"{name} lowering not deterministic"


def test_manifest_matches_checked_in_artifacts():
    """If `make artifacts` has run, the manifest must describe the files."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(art_dir, entry["file"])
        assert os.path.exists(path), name
        for io in entry["inputs"] + entry["outputs"]:
            assert io["dtype"] in ("float64", "int32")
            assert all(s > 0 for s in io["shape"])


def test_x64_enabled():
    """Counts must be f64: f32 loses exactness beyond 2^24 groundings."""
    assert jax.config.jax_enable_x64
    assert jnp.asarray(1.0).dtype == jnp.float64
