"""Mobius butterfly kernel vs references — the core L1 correctness signal.

Three independent derivations are cross-checked:
  1. the Pallas kernel (mobius_pallas, interpret mode),
  2. the jnp axis-wise reference (ref.mobius_ref),
  3. direct subset inclusion-exclusion (ref.mobius_ie_ref),
plus a from-first-principles check against brute-force grounding
enumeration over small random synthetic relational databases, which ties
the tensor convention to the actual counting semantics used by Rust.
"""

import itertools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import mobius, ref


def rand_tensor(rng, dims, e):
    return rng.integers(0, 25, size=(*dims, e)).astype(np.float64)


# ---------------------------------------------------------------------------
# kernel vs references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dims,e,e_blk",
    [
        ((2,), 4, 4),
        ((3, 2), 8, 4),
        ((4, 3, 5), 16, 8),
        ((8, 8, 8), 64, 32),
        ((2, 2, 2, 2), 8, 8),
    ],
)
def test_pallas_matches_refs(dims, e, e_blk):
    rng = np.random.default_rng(42)
    g = rand_tensor(rng, dims, e)
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=e_blk))
    want = np.asarray(ref.mobius_ref(jnp.asarray(g)))
    ie = np.asarray(ref.mobius_ie_ref(g))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    np.testing.assert_allclose(got, ie, rtol=0, atol=0)


@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.integers(2, 5), min_size=1, max_size=3),
    e_pow=st.integers(0, 4),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_shapes(dims, e_pow, seed):
    e = 2**e_pow
    rng = np.random.default_rng(seed)
    g = rand_tensor(rng, tuple(dims), e)
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=e))
    ie = np.asarray(ref.mobius_ie_ref(g))
    np.testing.assert_allclose(got, ie, rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_bijection(seed):
    """zeta(mobius(g)) == g — the transform loses no information."""
    rng = np.random.default_rng(seed)
    g = rand_tensor(rng, (3, 4), 8)
    f = mobius.mobius_pallas(jnp.asarray(g), e_blk=8)
    back = np.asarray(mobius.mobius_inverse_ref(f))
    np.testing.assert_allclose(back, g, rtol=0, atol=0)


def test_dtype_float32_supported():
    """f32 path exists (used by ablation benches), though artifacts are f64."""
    rng = np.random.default_rng(7)
    g = rand_tensor(rng, (3, 3), 8).astype(np.float32)
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=8))
    want = np.asarray(ref.mobius_ref(jnp.asarray(g)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# padding neutrality — the property the Rust dense packer relies on
# ---------------------------------------------------------------------------


def test_padding_axes_neutral():
    """Embedding a k=2 tensor into a k=3 artifact layout (extra axis with
    all mass at the ⊥ slot) yields the same completed counts."""
    rng = np.random.default_rng(3)
    g2 = rand_tensor(rng, (4, 3), 8)
    want = np.asarray(ref.mobius_ref(jnp.asarray(g2)))
    g3 = np.zeros((4, 3, 6, 8))
    g3[:, :, 0, :] = g2  # unused rel axis parks everything at ⊥
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(g3), e_blk=8))
    np.testing.assert_allclose(got[:, :, 0, :], want, rtol=0, atol=0)
    # all other slots of the unused axis stay identically zero
    assert np.all(got[:, :, 1:, :] == 0)


def test_padding_slots_neutral():
    """Zero-padding unused value slots of a rel axis changes nothing."""
    rng = np.random.default_rng(4)
    g = rand_tensor(rng, (3, 4), 8)
    want = np.asarray(ref.mobius_ref(jnp.asarray(g)))
    gp = np.zeros((5, 4, 8))
    gp[:3] = g
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(gp), e_blk=8))
    np.testing.assert_allclose(got[:3], want, rtol=0, atol=0)
    assert np.all(got[3:] == 0)


def test_e_padding_neutral():
    rng = np.random.default_rng(5)
    g = rand_tensor(rng, (3, 3), 6)
    gp = np.zeros((3, 3, 8))
    gp[..., :6] = g
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(gp), e_blk=8))
    want = np.asarray(ref.mobius_ref(jnp.asarray(g)))
    np.testing.assert_allclose(got[..., :6], want, rtol=0, atol=0)
    assert np.all(got[..., 6:] == 0)


def test_e_blk_invariance():
    """The grid split along E must not change results."""
    rng = np.random.default_rng(6)
    g = rand_tensor(rng, (4, 4), 32)
    a = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=32))
    b = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=8))
    c = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=4))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


def test_e_not_divisible_raises():
    g = jnp.zeros((2, 2, 10))
    with pytest.raises(ValueError):
        mobius.mobius_pallas(g, e_blk=4)


# ---------------------------------------------------------------------------
# semantics: tensor convention == grounding enumeration over a database
# ---------------------------------------------------------------------------


def synth_db(rng, n_a, n_b, card_a, card_rel, density):
    """Tiny two-population database: entity attr on A, one relationship
    A-B with one rel attribute."""
    attr_a = rng.integers(0, card_a, size=n_a)
    links = {}
    for i in range(n_a):
        for j in range(n_b):
            if rng.random() < density:
                links[(i, j)] = int(rng.integers(0, card_rel))
    return attr_a, links


def build_g(attr_a, links, n_a, n_b, card_a, card_rel):
    """Positive/unconstrained tensor: axis 0 = rel slots (0=⊥ i.e.
    unconstrained, 1+v = true with attr v), axis 1 = attr_a value."""
    g = np.zeros((1 + card_rel, card_a))
    for i in range(n_a):
        g[0, attr_a[i]] += n_b  # unconstrained: all B partners
    for (i, j), v in links.items():
        g[1 + v, attr_a[i]] += 1
    return g


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    n_a=st.integers(1, 6),
    n_b=st.integers(1, 6),
    density=st.floats(0.0, 1.0),
)
def test_matches_grounding_enumeration(seed, n_a, n_b, density):
    card_a, card_rel = 3, 2
    rng = np.random.default_rng(seed)
    attr_a, links = synth_db(rng, n_a, n_b, card_a, card_rel, density)
    g = build_g(attr_a, links, n_a, n_b, card_a, card_rel)
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=card_a))

    # brute force: enumerate all (i, j) groundings
    want = np.zeros_like(g)
    for i, j in itertools.product(range(n_a), range(n_b)):
        if (i, j) in links:
            want[1 + links[(i, j)], attr_a[i]] += 1
        else:
            want[0, attr_a[i]] += 1  # rel false -> ⊥/N/A slot
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_total_mass_conserved():
    """Sum of the complete ct-table == number of groundings == the
    unconstrained total (⊥ row mass of the input)."""
    rng = np.random.default_rng(11)
    attr_a, links = synth_db(rng, 5, 4, 3, 2, 0.4)
    g = build_g(attr_a, links, 5, 4, 3, 2)
    got = np.asarray(mobius.mobius_pallas(jnp.asarray(g), e_blk=3))
    assert got.sum() == pytest.approx(5 * 4)
    assert np.all(got >= 0)
