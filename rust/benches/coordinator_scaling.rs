//! The coordinator worker-scaling sweep (EXPERIMENTS.md §E8).
//!
//! Runs the full learn workload for every strategy on Table-4 presets
//! through the L3 [`relcount::coordinator::ParallelCoordinator`], once
//! per worker count, and reports wall clock, speedup over the 1-worker
//! baseline, and pool efficiency.  Counts and learned models are
//! bit-identical across worker counts (asserted by
//! `rust/tests/coordinator_parallel.rs`); this bench only measures time.
//!
//! Run: `cargo bench --bench coordinator_scaling`
//! Env: RELCOUNT_SCALE (default 0.05), RELCOUNT_PRESETS (default
//!      "uw,hepatitis"), RELCOUNT_WORKERS (default "1,2,4,auto"),
//!      RELCOUNT_BUDGET_S (default 300).

use std::time::Duration;

use relcount::bench::experiments::{coordinator_scaling_rows, ExpConfig};
use relcount::metrics::report::render_scaling;

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> relcount::Result<()> {
    let scale: f64 = env_or("RELCOUNT_SCALE", "0.05").parse().unwrap_or(0.05);
    let budget_s: u64 = env_or("RELCOUNT_BUDGET_S", "300").parse().unwrap_or(300);
    let presets: Vec<&'static str> = env_or("RELCOUNT_PRESETS", "uw,hepatitis")
        .split(',')
        .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
        .collect();
    let workers: Vec<usize> = env_or("RELCOUNT_WORKERS", "1,2,4,auto")
        .split(',')
        .map(|t| match t.trim() {
            "auto" => 0,
            t => t.parse().expect("RELCOUNT_WORKERS: integer or `auto`"),
        })
        .collect();

    let cfg = ExpConfig {
        scale,
        budget: Some(Duration::from_secs(budget_s)),
        presets: Box::leak(presets.into_boxed_slice()),
        ..Default::default()
    };
    println!(
        "== coordinator scaling: scale={scale}, presets={:?}, workers={workers:?} ==",
        cfg.presets
    );

    let rows = coordinator_scaling_rows(&cfg, &workers)?;
    print!("{}", render_scaling(&rows));

    // Headline: best speedup per strategy across presets.
    for strat in ["PRECOUNT", "ONDEMAND", "HYBRID"] {
        let best = rows
            .iter()
            .filter(|r| r.strategy == strat && !r.timed_out)
            .map(|r| (r.speedup, r.workers))
            .fold((1.0f64, 1usize), |a, b| if b.0 > a.0 { b } else { a });
        println!("# {strat}: best {:.2}x at {} workers", best.0, best.1);
    }
    println!("# pre-count phases parallelize per lattice point, post-count per family");
    Ok(())
}
