//! The streaming-churn sweep (EXPERIMENTS.md §E10).
//!
//! Builds fully resident maintained caches (complete tables included)
//! on Table-4 presets, then streams seeded churn batches through two
//! clones of the same state — delta maintenance vs
//! invalidate-and-recount — and reports wall clock, speedup, and the
//! digest check that both paths produced identical caches.  The
//! headline claim: delta application beats recount at >= 1% churn on
//! every preset.
//!
//! Run: `cargo bench --bench delta_churn`
//! Env: RELCOUNT_SCALE (default 0.05), RELCOUNT_PRESETS (default
//!      "uw,mondial,hepatitis"), RELCOUNT_CHURN (default "0.01,0.05"),
//!      RELCOUNT_WORKERS (default 1), RELCOUNT_JSON (optional output
//!      path for machine-readable rows).

use relcount::bench::experiments::{churn_rows, ExpConfig};
use relcount::metrics::report::{churn_rows_to_json, render_churn};

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> relcount::Result<()> {
    let scale: f64 = env_or("RELCOUNT_SCALE", "0.05").parse().unwrap_or(0.05);
    let workers: usize = env_or("RELCOUNT_WORKERS", "1").parse().unwrap_or(1);
    let fracs: Vec<f64> = env_or("RELCOUNT_CHURN", "0.01,0.05")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let presets: Vec<&'static str> = env_or("RELCOUNT_PRESETS", "uw,mondial,hepatitis")
        .split(',')
        .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
        .collect();

    let cfg = ExpConfig {
        scale,
        presets: Box::leak(presets.into_boxed_slice()),
        ..Default::default()
    };
    println!(
        "== delta churn: scale={scale}, presets={:?}, fracs={fracs:?}, \
         workers={workers} ==",
        cfg.presets
    );

    let rows = churn_rows(&cfg, &fracs, workers)?;
    print!("{}", render_churn(&rows));

    if let Ok(path) = std::env::var("RELCOUNT_JSON") {
        std::fs::write(&path, churn_rows_to_json(&rows).dump() + "\n")?;
        println!("# wrote {path}");
    }

    // Headline: does delta maintenance beat invalidate-and-recount?
    let mut all_consistent = true;
    for preset in cfg.presets {
        for r in rows.iter().filter(|r| r.database == *preset) {
            all_consistent &= r.consistent;
            println!(
                "# {preset} @ {:.1}% churn: delta {:.1}x {} recount ({} ops, {} \
                 cells vs {} points re-joined)",
                100.0 * r.churn_frac,
                r.speedup,
                if r.speedup >= 1.0 { "faster than" } else { "SLOWER than" },
                r.batch_ops,
                r.cells_touched,
                r.points_recounted
            );
        }
    }
    if !all_consistent {
        return Err(relcount::Error::Data(
            "churn: delta and recount caches diverged".into(),
        ));
    }
    println!("# all rows: delta caches bit-identical to recount caches");
    Ok(())
}
