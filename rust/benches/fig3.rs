//! Figure 3: ct-table construction time for PRECOUNT / ONDEMAND / HYBRID
//! on all 8 benchmark presets, broken into the MetaData / positive ct /
//! negative ct components, under a wall-clock budget per cell (the
//! paper's 100-minute Slurm limit, scaled).
//!
//! Environment knobs: RELCOUNT_SCALE (default 0.1), RELCOUNT_BUDGET_S
//! (default 120), RELCOUNT_PRESETS (comma list, default all 8),
//! RELCOUNT_SEED.

use relcount::bench::experiments::{fig3_fig4_rows, ExpConfig};
use relcount::datagen::presets::PRESET_NAMES;
use relcount::learn::search::SearchConfig;
use relcount::metrics::report::render_fig3;
use std::time::Duration;

pub fn config_from_env() -> ExpConfig {
    let scale = std::env::var("RELCOUNT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let budget = std::env::var("RELCOUNT_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120u64);
    let seed = std::env::var("RELCOUNT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0u64);
    let presets: &'static [&'static str] = match std::env::var("RELCOUNT_PRESETS") {
        Ok(list) => Box::leak(
            list.split(',')
                .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
        ),
        Err(_) => &PRESET_NAMES,
    };
    ExpConfig {
        scale,
        budget: Some(Duration::from_secs(budget)),
        seed,
        search: SearchConfig::default(),
        presets,
    }
}

#[allow(dead_code)]
fn main() {
    let cfg = config_from_env();
    eprintln!(
        "fig3: scale={} budget={:?} presets={:?}",
        cfg.scale, cfg.budget, cfg.presets
    );
    let rows = fig3_fig4_rows(&cfg).expect("fig3 rows");
    println!("== Figure 3: ct-table construction time breakdown ==");
    print!("{}", render_fig3(&rows));
    // the paper's qualitative claims, as machine-checked notes
    let slowest_per_db = |db: &str| {
        rows.iter()
            .filter(|r| r.database == db && !r.timed_out)
            .max_by_key(|r| r.total())
            .map(|r| r.strategy.clone())
    };
    for p in cfg.presets {
        if let Some(s) = slowest_per_db(p) {
            println!("# slowest on {p}: {s}");
        }
        for r in rows.iter().filter(|r| r.database == *p && r.timed_out) {
            println!(
                "# {} timed out on {p} (the paper's ONDEMAND failure mode)",
                r.strategy
            );
        }
    }
}
