//! Figure 4: peak ct-table memory for the three strategies on all
//! presets (exact byte accounting of live ct-tables/caches, plus VmHWM
//! for an end-to-end number).  Same env knobs as fig3.

#[path = "fig3.rs"]
mod fig3_cfg;

use relcount::bench::experiments::fig3_fig4_rows;
use relcount::metrics::memory::vm_hwm_kb;
use relcount::metrics::report::render_fig4;

fn main() {
    let cfg = fig3_cfg::config_from_env();
    eprintln!(
        "fig4: scale={} budget={:?} presets={:?}",
        cfg.scale, cfg.budget, cfg.presets
    );
    let rows = fig3_fig4_rows(&cfg).expect("fig4 rows");
    println!("== Figure 4: peak ct-table memory ==");
    print!("{}", render_fig4(&rows));
    // paper claim: PRECOUNT is generally the most memory-intensive
    for p in cfg.presets {
        let max = rows
            .iter()
            .filter(|r| r.database == *p && !r.timed_out)
            .max_by_key(|r| r.peak_ct_bytes);
        if let Some(r) = max {
            println!("# most memory on {p}: {}", r.strategy);
        }
    }
    if let Some(kb) = vm_hwm_kb() {
        println!("# process VmHWM: {:.1} MiB", kb as f64 / 1024.0);
    }
}
