//! Equations 3 & 4: ct-table growth rates.
//!
//! Eq. 3 (PRECOUNT): the global ct-table grows as O(V^C) in the number
//! of columns C.  Eq. 4 (ONDEMAND/HYBRID): the family tables grow only
//! with the family size k.  We sweep the number of entity attributes and
//! report the complete-lattice rows (PRECOUNT side) vs the sum of family
//! table rows for a fixed max-parents search workload (HYBRID side).

use relcount::bench::driver::{run_strategy, Workload};
use relcount::datagen::config::{EntitySpec, GenConfig, RelSpec};
use relcount::datagen::generator::generate;
use relcount::learn::search::SearchConfig;
use relcount::strategies::StrategyKind;

fn db_with_columns(n_attrs: usize, seed: u64) -> relcount::db::Database {
    let attrs = |prefix: &str| {
        (0..n_attrs)
            .map(|i| (format!("{prefix}{i}"), 3u32))
            .collect::<Vec<_>>()
    };
    let cfg = GenConfig {
        name: format!("cols{n_attrs}"),
        entities: vec![
            EntitySpec { name: "A".into(), n: 300, attrs: attrs("a") },
            EntitySpec { name: "B".into(), n: 300, attrs: attrs("b") },
        ],
        rels: vec![RelSpec {
            name: "R".into(),
            from: 0,
            to: 1,
            attrs: vec![("u".into(), 3)],
            n_links: 1200,
        }],
        seed,
        correlated: false,
    };
    generate(&cfg).unwrap()
}

fn main() {
    println!("== Eq. 3/4 ablation: ct rows vs number of columns ==");
    println!(
        "{:<8} {:>20} {:>22} {:>10}",
        "attrs", "precount_ct_rows", "hybrid_family_rows", "ratio"
    );
    for n_attrs in [1usize, 2, 3, 4, 5] {
        let db = db_with_columns(n_attrs, n_attrs as u64);
        let pre = run_strategy(
            &db,
            "ablation",
            StrategyKind::Precount,
            Workload::PrepareOnly,
            None,
        )
        .unwrap();
        let hyb = run_strategy(
            &db,
            "ablation",
            StrategyKind::Hybrid,
            Workload::Learn(SearchConfig {
                max_parents: 3,
                max_ops_per_point: 60,
                ..Default::default()
            }),
            None,
        )
        .unwrap();
        let p = pre.report.ct_rows_generated.max(1);
        let h = hyb.report.ct_rows_generated.max(1);
        println!(
            "{:<8} {:>20} {:>22} {:>10.2}",
            2 * n_attrs,
            p,
            h,
            p as f64 / h as f64
        );
    }
    println!("# Eq. 3: the PRECOUNT column grows exponentially with attrs;");
    println!("# Eq. 4: the family-table column grows with family size only.");
}
