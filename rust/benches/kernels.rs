//! Hot-path kernel ablation: the storage-engine join kernels (hash vs
//! CSR backend, plus the raw intersection primitives), then the
//! Pallas/XLA artifacts vs the pure-Rust twins and the value of
//! micro-batching BDeu dispatches.
//!
//! - joins:  `positive_chain_ct` on the identical database under
//!           `--backend hash` vs `--backend csr` (results asserted
//!           equal), and merge vs gallop vs hash-set intersection
//! - mobius: dense butterfly, Rust loop vs `mobius` XLA artifact
//! - bdeu:   per-family dispatch (`bdeu_one`-shaped) vs batched
//!           (`bdeu_batch` with B families per PJRT call) vs pure Rust
//!
//! The join section always runs; the XLA section requires
//! `make artifacts` (skips with a notice otherwise).

use relcount::ct::dense::mobius_dense;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::index::Backend;
use relcount::db::query::{intersect_count, positive_chain_ct, JoinStats};
use relcount::learn::score::ln_gamma;
use relcount::lattice::Lattice;
use relcount::runtime::batcher::{FamilyCounts, ScoreBatcher};
use relcount::runtime::client::Runtime;
use relcount::util::bench::{bench, render};
use relcount::util::fxhash::FxHashSet;
use relcount::util::rng::Rng;

fn join_kernels() {
    let mut ms = Vec::new();
    let csr = generate(&preset("uw", 0.3, 7).unwrap()).unwrap();
    let mut hash = csr.clone();
    hash.set_backend(Backend::Hash).unwrap();
    let lattice = Lattice::build(&csr.schema, 3).unwrap();
    let point = lattice
        .points
        .iter()
        .max_by_key(|p| (p.rels.len(), p.attr_vars.len()))
        .expect("non-empty lattice");

    // full grouped chain join (index probes + key assembly)
    let mut totals = Vec::new();
    for (name, db) in [("hash", &hash), ("csr", &csr)] {
        ms.push(bench(&format!("chain_join_grouped_{name}"), 1, 8, || {
            let mut stats = JoinStats::default();
            let t =
                positive_chain_ct(db, &point.rels, &point.attr_vars, &mut stats)
                    .unwrap();
            t.total().unwrap()
        }));
        let mut stats = JoinStats::default();
        totals.push(
            positive_chain_ct(db, &point.rels, &point.attr_vars, &mut stats)
                .unwrap()
                .total()
                .unwrap(),
        );
    }
    assert_eq!(totals[0], totals[1], "backends must agree");

    // count-only chain join (the Möbius subset shape: kernels collapse
    // unused tails)
    for (name, db) in [("hash", &hash), ("csr", &csr)] {
        ms.push(bench(&format!("chain_join_count_only_{name}"), 1, 8, || {
            let mut stats = JoinStats::default();
            positive_chain_ct(db, &point.rels, &[], &mut stats)
                .unwrap()
                .total()
                .unwrap()
        }));
    }

    // raw intersection primitives: balanced merge, skewed gallop, and
    // the hash-probe baseline the CSR kernels replace
    let a: Vec<u32> = (0..60_000u32).map(|i| i * 3).collect();
    let b: Vec<u32> = (0..90_000u32).map(|i| i * 2).collect();
    let small: Vec<u32> = (0..4_000u32).map(|i| i * 45).collect();
    ms.push(bench("intersect_merge_60k_90k", 2, 20, || {
        intersect_count(&a, &b)
    }));
    ms.push(bench("intersect_gallop_4k_90k", 2, 20, || {
        intersect_count(&small, &b)
    }));
    let b_set: FxHashSet<u32> = b.iter().copied().collect();
    ms.push(bench("intersect_hashset_60k_90k", 2, 20, || {
        a.iter().filter(|&&v| b_set.contains(&v)).count() as u64
    }));
    ms.push(bench("intersect_hashset_4k_90k", 2, 20, || {
        small.iter().filter(|&&v| b_set.contains(&v)).count() as u64
    }));

    print!("{}", render("join_kernels", &ms));
}

fn main() {
    join_kernels();

    let dir = relcount::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("kernels bench: XLA section skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir).unwrap();
    let mut ms = Vec::new();

    // ---- mobius ---------------------------------------------------------
    let spec = rt.manifest.artifact("mobius").unwrap();
    let d = spec.meta_dim("d_pad").unwrap();
    let k = spec.meta_dim("k_rel").unwrap();
    let e = spec.meta_dim("e_pad").unwrap();
    let len = d.pow(k as u32) * e;
    let mut rng = Rng::new(1);
    let g: Vec<f64> = (0..len).map(|_| rng.gen_range(1000) as f64).collect();

    ms.push(bench("mobius_rust_dense", 2, 20, || {
        let mut t = g.clone();
        mobius_dense(&mut t, d, k, e);
        t
    }));
    ms.push(bench("mobius_xla_artifact", 2, 20, || rt.mobius(&g).unwrap()));

    // ---- bdeu -----------------------------------------------------------
    let mut batcher = ScoreBatcher::new(&rt).unwrap();
    let b = batcher.batch_size();
    let reqs: Vec<FamilyCounts> = (0..b)
        .map(|i| {
            let q = 24;
            let r = 6;
            let mut rng = Rng::new(i as u64);
            FamilyCounts {
                counts: (0..q * r).map(|_| rng.gen_range(60) as f64).collect(),
                q,
                r,
                n_prime: 1.0,
            }
        })
        .collect();

    ms.push(bench(&format!("bdeu_rust_scalar_x{b}"), 2, 30, || {
        let mut total = 0.0;
        for req in &reqs {
            let ar = req.alpha_row().unwrap();
            let ac = req.alpha_cell().unwrap();
            for j in 0..req.q {
                let row = &req.counts[j * req.r..(j + 1) * req.r];
                let nij: f64 = row.iter().sum();
                if nij > 0.0 {
                    total += ln_gamma(ar) - ln_gamma(nij + ar);
                    for &c in row {
                        if c > 0.0 {
                            total += ln_gamma(c + ac) - ln_gamma(ac);
                        }
                    }
                }
            }
        }
        total
    }));
    ms.push(bench(&format!("bdeu_xla_batched_x{b}"), 2, 30, || {
        batcher.score_all(&reqs).unwrap()
    }));
    // one-at-a-time dispatches (what a naive integration would do)
    let one = &reqs[..1];
    ms.push(bench("bdeu_xla_one_dispatch", 2, 30, || {
        batcher.score_all(one).unwrap()
    }));

    print!("{}", render("kernels", &ms));
    let batched = ms.iter().find(|m| m.name.starts_with("bdeu_xla_batched")).unwrap();
    let single = ms.iter().find(|m| m.name == "bdeu_xla_one_dispatch").unwrap();
    println!(
        "# batching amortization: {b} families cost {:.1}x one dispatch \
         (ideal {b}x smaller means perfect amortization)",
        batched.mean_s() / single.mean_s()
    );
}
