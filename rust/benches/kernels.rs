//! Hot-path kernel ablation: the Pallas/XLA artifacts vs the pure-Rust
//! twins, and the value of micro-batching BDeu dispatches.
//!
//! - mobius: dense butterfly, Rust loop vs `mobius` XLA artifact
//! - bdeu:   per-family dispatch (`bdeu_one`-shaped) vs batched
//!           (`bdeu_batch` with B families per PJRT call) vs pure Rust
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use relcount::ct::dense::mobius_dense;
use relcount::learn::score::ln_gamma;
use relcount::runtime::batcher::{FamilyCounts, ScoreBatcher};
use relcount::runtime::client::Runtime;
use relcount::util::bench::{bench, render};
use relcount::util::rng::Rng;

fn main() {
    let dir = relcount::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("kernels bench skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir).unwrap();
    let mut ms = Vec::new();

    // ---- mobius ---------------------------------------------------------
    let spec = rt.manifest.artifact("mobius").unwrap();
    let d = spec.meta_dim("d_pad").unwrap();
    let k = spec.meta_dim("k_rel").unwrap();
    let e = spec.meta_dim("e_pad").unwrap();
    let len = d.pow(k as u32) * e;
    let mut rng = Rng::new(1);
    let g: Vec<f64> = (0..len).map(|_| rng.gen_range(1000) as f64).collect();

    ms.push(bench("mobius_rust_dense", 2, 20, || {
        let mut t = g.clone();
        mobius_dense(&mut t, d, k, e);
        t
    }));
    ms.push(bench("mobius_xla_artifact", 2, 20, || rt.mobius(&g).unwrap()));

    // ---- bdeu -----------------------------------------------------------
    let mut batcher = ScoreBatcher::new(&rt).unwrap();
    let b = batcher.batch_size();
    let reqs: Vec<FamilyCounts> = (0..b)
        .map(|i| {
            let q = 24;
            let r = 6;
            let mut rng = Rng::new(i as u64);
            FamilyCounts {
                counts: (0..q * r).map(|_| rng.gen_range(60) as f64).collect(),
                q,
                r,
                n_prime: 1.0,
            }
        })
        .collect();

    ms.push(bench(&format!("bdeu_rust_scalar_x{b}"), 2, 30, || {
        let mut total = 0.0;
        for req in &reqs {
            let ar = req.alpha_row().unwrap();
            let ac = req.alpha_cell().unwrap();
            for j in 0..req.q {
                let row = &req.counts[j * req.r..(j + 1) * req.r];
                let nij: f64 = row.iter().sum();
                if nij > 0.0 {
                    total += ln_gamma(ar) - ln_gamma(nij + ar);
                    for &c in row {
                        if c > 0.0 {
                            total += ln_gamma(c + ac) - ln_gamma(ac);
                        }
                    }
                }
            }
        }
        total
    }));
    ms.push(bench(&format!("bdeu_xla_batched_x{b}"), 2, 30, || {
        batcher.score_all(&reqs).unwrap()
    }));
    // one-at-a-time dispatches (what a naive integration would do)
    let one = &reqs[..1];
    ms.push(bench("bdeu_xla_one_dispatch", 2, 30, || {
        batcher.score_all(one).unwrap()
    }));

    print!("{}", render("kernels", &ms));
    let batched = ms.iter().find(|m| m.name.starts_with("bdeu_xla_batched")).unwrap();
    let single = ms.iter().find(|m| m.name == "bdeu_xla_one_dispatch").unwrap();
    println!(
        "# batching amortization: {b} families cost {:.1}x one dispatch \
         (ideal {b}x smaller means perfect amortization)",
        batched.mean_s() / single.mean_s()
    );
}
