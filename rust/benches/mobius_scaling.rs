//! Equation 2: the Möbius Join costs O(r log r) in the output rows r.
//! We sweep the family attribute space (and data size) and report
//! time-per-row, which should stay near-constant (hash-based butterfly:
//! O(r) per axis, slightly better than the paper's sort-based bound).

use relcount::ct::mobius::mobius_complete;
use relcount::datagen::config::{EntitySpec, GenConfig, RelSpec};
use relcount::datagen::generator::generate;
use relcount::db::query::DirectSource;
use relcount::meta::rvar::RVar;
use relcount::util::bench::{bench, render, Measurement};

fn db_for(card: u32, n: u64, seed: u64) -> relcount::db::Database {
    let cfg = GenConfig {
        name: format!("sweep_c{card}"),
        entities: vec![
            EntitySpec {
                name: "A".into(),
                n,
                attrs: vec![("x".into(), card), ("y".into(), card)],
            },
            EntitySpec {
                name: "B".into(),
                n,
                attrs: vec![("z".into(), card), ("w".into(), card)],
            },
        ],
        rels: vec![RelSpec {
            name: "R".into(),
            from: 0,
            to: 1,
            attrs: vec![("u".into(), card)],
            n_links: n * 4,
        }],
        seed,
        correlated: false, // uniform -> dense ct-tables -> max rows
    };
    generate(&cfg).unwrap()
}

fn main() {
    let mut ms: Vec<Measurement> = Vec::new();
    println!("== Eq. 2 sweep: Möbius Join time vs output rows ==");
    println!(
        "{:<10} {:>12} {:>12} {:>16}",
        "card", "out_rows", "mean_s", "ns_per_row"
    );
    for card in [2u32, 3, 4, 6, 8, 12] {
        let db = db_for(card, 400, card as u64);
        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 0 },
            RVar::EntityAttr { et: 0, attr: 0 },
            RVar::EntityAttr { et: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::EntityAttr { et: 1, attr: 1 },
        ];
        let mut rows = 0usize;
        let m = bench(&format!("mobius_card{card}"), 1, 5, || {
            let mut src = DirectSource::new(&db);
            let ct = mobius_complete(&mut src, &vars, &[0, 1]).unwrap();
            rows = ct.n_rows();
            ct
        });
        println!(
            "{:<10} {:>12} {:>12.6} {:>16.1}",
            card,
            rows,
            m.mean.as_secs_f64(),
            m.mean.as_secs_f64() * 1e9 / rows as f64
        );
        ms.push(m);
    }
    print!("{}", render("mobius_scaling", &ms));
    println!("# near-constant ns/row = O(r) scaling (paper bound: O(r log r))");
}
