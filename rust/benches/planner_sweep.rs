//! The ADAPTIVE planner sweep (EXPERIMENTS.md §E9).
//!
//! Runs the full learn workload through the ADAPTIVE strategy at a
//! ladder of memory budgets, tracing the pre-count fraction from 0
//! (pure ONDEMAND) through HYBRID's operating point to 1 (pure
//! PRECOUNT) on Table-4 presets.  Counts and learned models are
//! bit-identical at every rung (`rust/tests/strategy_equivalence.rs`);
//! the sweep measures where the time goes and what stays resident.
//!
//! Run: `cargo bench --bench planner_sweep`
//! Env: RELCOUNT_SCALE (default 0.05), RELCOUNT_PRESETS (default
//!      "uw,hepatitis"), RELCOUNT_WORKERS (default 1),
//!      RELCOUNT_BUDGET_S (default 300), RELCOUNT_JSON (optional output
//!      path for machine-readable rows).

use std::time::Duration;

use relcount::bench::experiments::{planner_sweep_rows, ExpConfig};
use relcount::metrics::report::{planner_rows_to_json, render_planner};

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> relcount::Result<()> {
    let scale: f64 = env_or("RELCOUNT_SCALE", "0.05").parse().unwrap_or(0.05);
    let budget_s: u64 = env_or("RELCOUNT_BUDGET_S", "300").parse().unwrap_or(300);
    let workers: usize = env_or("RELCOUNT_WORKERS", "1").parse().unwrap_or(1);
    let presets: Vec<&'static str> = env_or("RELCOUNT_PRESETS", "uw,hepatitis")
        .split(',')
        .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
        .collect();

    let cfg = ExpConfig {
        scale,
        budget: Some(Duration::from_secs(budget_s)),
        presets: Box::leak(presets.into_boxed_slice()),
        ..Default::default()
    };
    println!(
        "== planner sweep: scale={scale}, presets={:?}, workers={workers} ==",
        cfg.presets
    );

    let rows = planner_sweep_rows(&cfg, workers)?;
    print!("{}", render_planner(&rows));

    if let Ok(path) = std::env::var("RELCOUNT_JSON") {
        std::fs::write(&path, planner_rows_to_json(&rows).dump() + "\n")?;
        println!("# wrote {path}");
    }

    // Headline: where along the spectrum does each preset run fastest?
    for preset in cfg.presets {
        let best = rows
            .iter()
            .filter(|r| r.database == *preset && !r.timed_out)
            .min_by(|a, b| a.total().cmp(&b.total()));
        if let Some(b) = best {
            println!(
                "# {preset}: fastest at pre-fraction {:.3} ({:.3}s, {} joins)",
                b.pre_fraction,
                b.total().as_secs_f64(),
                b.chain_queries
            );
        }
    }
    println!("# budget 0 = pure post-counting; inf = complete tables resident");
    Ok(())
}
