//! The serving-throughput sweep (EXPERIMENTS.md §E12).
//!
//! Builds the snapshot-isolated serving engine on Table-4 presets,
//! replays the deterministic singleton/pair request workload through a
//! full `relcount serve` session while a seeded churn stream publishes
//! new generations concurrently, and reports per-generation latency,
//! throughput and queue depth for each worker count.  The headline
//! claim: requests are answered from every generation the stream
//! publishes with zero in-protocol errors — reads never block on, nor
//! fail through, the delta writer.
//!
//! Run: `cargo bench --bench serve_throughput`
//! Env: RELCOUNT_SCALE (default 0.05), RELCOUNT_PRESETS (default
//!      "uw,mondial,hepatitis"), RELCOUNT_WORKERS_LIST (default "1,4"),
//!      RELCOUNT_CHURN (default 0.05), RELCOUNT_CHURN_STEPS (default 3),
//!      RELCOUNT_REPEAT (default 4), RELCOUNT_JSON (optional output
//!      path for machine-readable rows).

use relcount::bench::experiments::{serve_rows, ExpConfig};
use relcount::metrics::report::{render_serve, serve_rows_to_json, ServeRow};

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> relcount::Result<()> {
    let scale: f64 = env_or("RELCOUNT_SCALE", "0.05").parse().unwrap_or(0.05);
    let frac: f64 = env_or("RELCOUNT_CHURN", "0.05").parse().unwrap_or(0.05);
    let steps: usize = env_or("RELCOUNT_CHURN_STEPS", "3").parse().unwrap_or(3);
    let repeat: usize = env_or("RELCOUNT_REPEAT", "4").parse().unwrap_or(4);
    let workers_list: Vec<usize> = env_or("RELCOUNT_WORKERS_LIST", "1,4")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let presets: Vec<&'static str> = env_or("RELCOUNT_PRESETS", "uw,mondial,hepatitis")
        .split(',')
        .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
        .collect();

    let cfg = ExpConfig {
        scale,
        presets: Box::leak(presets.into_boxed_slice()),
        ..Default::default()
    };
    println!(
        "== serve throughput: scale={scale}, presets={:?}, churn={frac} x{steps}, \
         repeat={repeat}, workers={workers_list:?} ==",
        cfg.presets
    );

    let mut all: Vec<ServeRow> = Vec::new();
    for &workers in &workers_list {
        // serve_rows errors out on any in-protocol error or publish
        // failure, so a passing run IS the consistency claim
        let rows = serve_rows(&cfg, workers, frac, steps, repeat, 0, 1)?;
        print!("{}", render_serve(&rows));
        for preset in cfg.presets {
            let mine: Vec<&ServeRow> =
                rows.iter().filter(|r| r.database == *preset).collect();
            let requests: u64 = mine.iter().map(|r| r.requests).sum();
            let peak = mine
                .iter()
                .map(|r| r.throughput_rps)
                .fold(0.0f64, f64::max);
            println!(
                "# {preset} @ {workers} workers: {requests} requests over {} \
                 generations, peak {peak:.0} req/s, zero errors",
                mine.len()
            );
        }
        all.extend(rows);
    }

    if let Ok(path) = std::env::var("RELCOUNT_JSON") {
        std::fs::write(&path, serve_rows_to_json(&all).dump() + "\n")?;
        println!("# wrote {path}");
    }
    println!("# all sessions: served counts snapshot-consistent under churn");
    Ok(())
}
