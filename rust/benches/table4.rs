//! Table 4: database sizes, relationship counts, and the MP/N (mean
//! parents per node) of the learned first-order BN, side-by-side with
//! the paper's published values.

#[path = "fig3.rs"]
mod fig3_cfg;

use relcount::bench::experiments::{paper_rows, table4_rows};
use relcount::metrics::report::render_table4;

fn main() {
    let cfg = fig3_cfg::config_from_env();
    eprintln!("table4: scale={} presets={:?}", cfg.scale, cfg.presets);
    let rows = table4_rows(&cfg).expect("table4 rows");
    println!("== Table 4: databases and learned-model MP/N ==");
    print!("{}", render_table4(&rows));
    println!("# paper row counts at scale 1.0 (ours scale with RELCOUNT_SCALE):");
    for r in &rows {
        if let Some(paper) = paper_rows(&r.database) {
            println!(
                "#   {:<16} paper {:>10}   ours {:>10}  (x{:.3})",
                r.database,
                paper,
                r.row_count,
                r.row_count as f64 / paper as f64
            );
        }
    }
    println!("# paper MP/N range: 0.5 (visual genome) .. 3.4 (imdb)");
}
