//! Table 5: total ct-table rows generated per database — the family
//! ct-tables of HYBRID/ONDEMAND vs the complete lattice ("database")
//! ct-tables of PRECOUNT.  The paper's explanation of Figure 3's
//! exceptions rests on which column is larger per database.

#[path = "fig3.rs"]
mod fig3_cfg;

use relcount::bench::experiments::table5_rows;
use relcount::metrics::report::render_table5;

fn main() {
    let cfg = fig3_cfg::config_from_env();
    eprintln!("table5: scale={} presets={:?}", cfg.scale, cfg.presets);
    let rows = table5_rows(&cfg).expect("table5 rows");
    println!("== Table 5: ct(family) vs ct(database) total rows ==");
    print!("{}", render_table5(&rows));
    for r in &rows {
        let winner = if r.ct_family_rows < r.ct_database_rows {
            "family tables smaller -> HYBRID favoured"
        } else {
            "global tables smaller -> PRECOUNT favoured (paper's exception case)"
        };
        println!("# {:<16} {}", r.database, winner);
    }
}
