//! One experimental cell: run one strategy on one database under a
//! wall-clock budget, measuring the paper's three runtime components and
//! peak ct-memory.  A blown budget is recorded as a timeout row, exactly
//! like the paper's "ONDEMAND failed to complete" entries.

use std::time::Duration;

use crate::coordinator::{CoordinatorConfig, CoordinatorReport, ParallelCoordinator};
use crate::db::catalog::Database;
use crate::error::Result;
use crate::learn::search::{learn, LearnedModel, SearchConfig};
use crate::metrics::report::RunRow;
use crate::strategies::traits::{CountingStrategy, StrategyConfig, StrategyReport};
use crate::strategies::StrategyKind;

/// The counting workload driven through a strategy.
#[derive(Clone, Copy, Debug)]
pub enum Workload {
    /// Prepare only (the pre-count phases; ONDEMAND does nothing).
    PrepareOnly,
    /// Full structure learning — the workload of Figures 3 and 4.
    Learn(SearchConfig),
}

/// Result of one cell.
pub struct RunOutcome {
    pub row: RunRow,
    pub report: StrategyReport,
    pub model: Option<LearnedModel>,
    /// Deterministic digest of the strategy's resident caches
    /// ([`CountingStrategy::cache_digest`]) — the backend-equivalence
    /// witness the CI gate diffs across `--backend hash` / `csr`.
    pub cache_digest: u64,
}

/// Build the strategy configuration for a workload cell.
fn cell_config(workload: Workload, budget: Option<Duration>) -> StrategyConfig {
    StrategyConfig {
        budget,
        max_chain_length: match workload {
            Workload::Learn(s) => s.max_chain_length,
            Workload::PrepareOnly => StrategyConfig::default().max_chain_length,
        },
        ..Default::default()
    }
}

/// Run `kind` on `db` with the given budget.
pub fn run_strategy(
    db: &Database,
    db_name: &str,
    kind: StrategyKind,
    workload: Workload,
    budget: Option<Duration>,
) -> Result<RunOutcome> {
    run_strategy_with(db, db_name, kind, workload, cell_config(workload, budget))
}

/// Run `kind` on `db` with a fully explicit [`StrategyConfig`] (the
/// ADAPTIVE planner sweep sets `mem_budget`/`estimator` here).
pub fn run_strategy_with(
    db: &Database,
    db_name: &str,
    kind: StrategyKind,
    workload: Workload,
    scfg: StrategyConfig,
) -> Result<RunOutcome> {
    let mut strategy = kind.build(db, scfg)?;

    let (timed_out, model) = match workload {
        Workload::PrepareOnly => match strategy.prepare() {
            Ok(()) => (false, None),
            Err(e) if e.is_timeout() => (true, None),
            Err(e) => return Err(e),
        },
        Workload::Learn(search_cfg) => match learn(db, strategy.as_mut(), search_cfg) {
            Ok(m) => (false, Some(m)),
            Err(e) if e.is_timeout() => (true, None),
            Err(e) => return Err(e),
        },
    };

    let cache_digest = strategy.cache_digest();
    let report = strategy.report();
    let row = row_from_report(db_name, kind, &report, timed_out);
    Ok(RunOutcome { row, report, model, cache_digest })
}

fn row_from_report(
    db_name: &str,
    kind: StrategyKind,
    report: &StrategyReport,
    timed_out: bool,
) -> RunRow {
    RunRow {
        database: db_name.to_string(),
        strategy: kind.name().to_string(),
        metadata: report.timing.metadata,
        positive: report.timing.positive,
        negative: report.timing.negative,
        peak_ct_bytes: report.peak_ct_bytes,
        ct_rows_generated: report.ct_rows_generated,
        families_scored: report.families_served,
        chain_queries: report.join_stats.chain_queries,
        timed_out,
    }
}

/// Result of one coordinated (parallel) cell.
pub struct CoordinatedOutcome {
    pub row: RunRow,
    pub report: StrategyReport,
    /// Per-worker breakdown of the run.
    pub coordinator: CoordinatorReport,
    pub model: Option<LearnedModel>,
    /// Worker-count-invariant digest of the coordinator's caches (see
    /// [`RunOutcome::cache_digest`]).
    pub cache_digest: u64,
}

/// Run `kind` on `db` through the [`ParallelCoordinator`] with `workers`
/// workers (0 = all cores).  The counts, the learned model and the row's
/// count metrics are bit-identical to [`run_strategy`]; only the wall
/// clock (and its per-worker decomposition) differs.
pub fn run_coordinated(
    db: &Database,
    db_name: &str,
    kind: StrategyKind,
    workload: Workload,
    budget: Option<Duration>,
    workers: usize,
) -> Result<CoordinatedOutcome> {
    run_coordinated_with(db, db_name, kind, workload, cell_config(workload, budget), workers)
}

/// [`run_coordinated`] with a fully explicit [`StrategyConfig`].
pub fn run_coordinated_with(
    db: &Database,
    db_name: &str,
    kind: StrategyKind,
    workload: Workload,
    scfg: StrategyConfig,
    workers: usize,
) -> Result<CoordinatedOutcome> {
    let mut coord = ParallelCoordinator::new(
        db,
        kind,
        CoordinatorConfig { workers, strategy: scfg },
    )?;

    let (timed_out, model) = match workload {
        Workload::PrepareOnly => match coord.prepare() {
            Ok(()) => (false, None),
            Err(e) if e.is_timeout() => (true, None),
            Err(e) => return Err(e),
        },
        Workload::Learn(search_cfg) => match learn(db, &mut coord, search_cfg) {
            Ok(m) => (false, Some(m)),
            Err(e) if e.is_timeout() => (true, None),
            Err(e) => return Err(e),
        },
    };

    let cache_digest = coord.cache_digest();
    let report = coord.report();
    let row = row_from_report(db_name, kind, &report, timed_out);
    Ok(CoordinatedOutcome {
        row,
        report,
        coordinator: coord.coordinator_report(),
        model,
        cache_digest,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;

    #[test]
    fn all_strategies_run_the_learn_workload() {
        let db = university_db();
        for kind in StrategyKind::ALL {
            let out = run_strategy(
                &db,
                "university",
                kind,
                Workload::Learn(SearchConfig::default()),
                None,
            )
            .unwrap();
            assert!(!out.row.timed_out, "{kind:?}");
            assert!(out.model.is_some());
            assert!(out.row.families_scored > 0);
            assert!(out.row.total() > Duration::ZERO);
        }
    }

    #[test]
    fn budget_zero_times_out_precount() {
        let db = university_db();
        let out = run_strategy(
            &db,
            "university",
            StrategyKind::Precount,
            Workload::PrepareOnly,
            Some(Duration::ZERO),
        )
        .unwrap();
        assert!(out.row.timed_out);
    }

    #[test]
    fn coordinated_matches_sequential_models() {
        let db = university_db();
        let cfg = SearchConfig::default();
        for kind in StrategyKind::ALL {
            let seq = run_strategy(&db, "u", kind, Workload::Learn(cfg), None)
                .unwrap()
                .model
                .unwrap();
            let par =
                run_coordinated(&db, "u", kind, Workload::Learn(cfg), None, 3)
                    .unwrap();
            assert_eq!(par.coordinator.workers, 3);
            let m = par.model.unwrap();
            assert_eq!(m.bn.nodes, seq.bn.nodes, "{kind:?}");
            assert_eq!(m.bn.parents, seq.bn.parents, "{kind:?}");
            assert!((m.total_score - seq.total_score).abs() < 1e-9, "{kind:?}");
        }
    }

    #[test]
    fn identical_models_across_strategies() {
        let db = university_db();
        let cfg = SearchConfig::default();
        let models: Vec<_> = StrategyKind::ALL
            .iter()
            .map(|&k| {
                run_strategy(&db, "u", k, Workload::Learn(cfg), None)
                    .unwrap()
                    .model
                    .unwrap()
            })
            .collect();
        for m in &models[1..] {
            assert_eq!(m.bn.nodes, models[0].bn.nodes);
            assert_eq!(m.bn.parents, models[0].bn.parents);
        }
    }
}
