//! Row generators for every table and figure of the paper's evaluation
//! (the per-experiment index is DESIGN.md §4).  Shared by the `cargo
//! bench` targets, the CLI `exp` subcommand and the end-to-end example.

use std::time::{Duration, Instant};

use crate::bench::driver::{
    run_coordinated, run_coordinated_with, run_strategy, run_strategy_with,
    RunOutcome, Workload,
};
use crate::datagen::churn::churn_batch;
use crate::datagen::generator::generate;
use crate::datagen::presets::{preset, paper_row_count, PRESET_NAMES};
use crate::datagen::synth::{skewed_star_db, skewed_triangle_db};
use crate::db::index::Backend;
use crate::db::query::{positive_chain_ct, JoinStats};
use crate::db::wcoj::JoinKernel;
use crate::delta::maintain::{MaintainConfig, MaintainedCounts};
use crate::delta::policy::MaintenanceMode;
use crate::error::{Error, Result};
use crate::estimate::quality::{self, QualityMode};
use crate::estimate::sampler::EstimatorConfig;
use crate::lattice::Lattice;
use crate::learn::search::SearchConfig;
use crate::metrics::report::{
    ChurnRow, CompressRow, EstimatorRow, PersistRow, PlannerRow, RunRow,
    ScalingRow, ServeRow, Table4Row, Table5Row, WcojRow,
};
use crate::serve::replicate::{follow, ReplRecord};
use crate::serve::{
    enumerate_requests, run_router, run_serve, serve_listener, DeltaFeed,
    ReplHandle, ReplLog, Replicator, ServeEngine, ServeOptions, ShardConfig,
};
use crate::strategies::adaptive::Adaptive;
use crate::strategies::traits::StrategyConfig;
use crate::strategies::StrategyKind;

/// Experiment-wide options.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    /// Dataset scale factor in (0, 1] (the paper runs at 1.0; scaled
    /// runs preserve who-wins ordering at laptop budgets).
    pub scale: f64,
    /// Per-cell wall-clock budget (the paper's Slurm limit was 100 min).
    pub budget: Option<Duration>,
    pub seed: u64,
    pub search: SearchConfig,
    /// Presets to include (defaults to all 8).
    pub presets: &'static [&'static str],
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scale: 0.05,
            budget: Some(Duration::from_secs(120)),
            seed: 0,
            search: SearchConfig::default(),
            presets: &PRESET_NAMES,
        }
    }
}

/// Figures 3 & 4 share the same runs: every strategy on every preset,
/// full learn workload, timing breakdown + peak memory per cell.
pub fn fig3_fig4_rows(cfg: &ExpConfig) -> Result<Vec<RunRow>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        for kind in StrategyKind::ALL {
            let out = run_strategy(
                &db,
                name,
                kind,
                Workload::Learn(cfg.search),
                cfg.budget,
            )?;
            rows.push(out.row);
        }
    }
    Ok(rows)
}

/// Table 5: Σ rows over family ct-tables (HYBRID) vs the complete lattice
/// ct-tables (PRECOUNT), per database.
pub fn table5_rows(cfg: &ExpConfig) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let hybrid = run_strategy(
            &db,
            name,
            StrategyKind::Hybrid,
            Workload::Learn(cfg.search),
            cfg.budget,
        )?;
        let pre = run_strategy(
            &db,
            name,
            StrategyKind::Precount,
            Workload::PrepareOnly,
            cfg.budget,
        )?;
        rows.push(Table5Row {
            database: name.to_string(),
            ct_family_rows: hybrid.report.ct_rows_generated,
            ct_database_rows: pre.report.ct_rows_generated,
        });
    }
    Ok(rows)
}

/// Table 4: row count, #relationships, and the MP/N of the learned BN.
pub fn table4_rows(cfg: &ExpConfig) -> Result<Vec<Table4Row>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let out: RunOutcome = run_strategy(
            &db,
            name,
            StrategyKind::Hybrid,
            Workload::Learn(cfg.search),
            cfg.budget,
        )?;
        let mpn = out
            .model
            .as_ref()
            .map(|m| m.bn.mean_parents_per_node())
            .unwrap_or(f64::NAN);
        rows.push(Table4Row {
            database: name.to_string(),
            row_count: db.total_rows(),
            n_relationships: db.n_relationships(),
            mean_parents_per_node: mpn,
        });
    }
    Ok(rows)
}

/// The paper's Table 4 row counts for side-by-side reporting.
pub fn paper_rows(name: &str) -> Option<u64> {
    paper_row_count(name)
}

/// The coordinator worker-scaling sweep: every strategy on every preset
/// of `cfg`, full learn workload through the
/// [`crate::coordinator::ParallelCoordinator`], once per worker count.
///
/// A 1-worker cell always runs first as the speedup baseline (whether or
/// not `1` appears in `worker_counts`).  The learned models and count
/// metrics are identical across cells by construction — the sweep
/// measures wall clock only.
pub fn coordinator_scaling_rows(
    cfg: &ExpConfig,
    worker_counts: &[usize],
) -> Result<Vec<ScalingRow>> {
    let mut counts: Vec<usize> = worker_counts
        .iter()
        .copied()
        .map(|w| crate::coordinator::resolve_workers(w))
        .filter(|&w| w != 1)
        .collect();
    counts.sort_unstable();
    counts.dedup();

    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        for kind in StrategyKind::ALL {
            let mut baseline = Duration::ZERO;
            for (i, &w) in std::iter::once(&1usize).chain(&counts).enumerate() {
                let t0 = Instant::now();
                let out = run_coordinated(
                    &db,
                    name,
                    kind,
                    Workload::Learn(cfg.search),
                    cfg.budget,
                    w,
                )?;
                let wall = t0.elapsed();
                if i == 0 {
                    baseline = wall;
                }
                let cpu_timer = out.coordinator.cpu_view().timing;
                rows.push(ScalingRow {
                    database: name.to_string(),
                    strategy: kind.name().to_string(),
                    workers: w,
                    wall,
                    speedup: if wall.is_zero() {
                        1.0
                    } else {
                        baseline.as_secs_f64() / wall.as_secs_f64()
                    },
                    cpu: cpu_timer.total(),
                    timed_out: out.row.timed_out,
                });
            }
        }
    }
    Ok(rows)
}

/// The ADAPTIVE planner sweep: on every preset of `cfg`, run the full
/// learn workload at a ladder of memory budgets tracing the pre-count
/// fraction from 0 (pure ONDEMAND) through HYBRID's operating point
/// (marginals + all positives) to 1 (pure PRECOUNT, complete tables
/// resident).  Counts and learned models are bit-identical at every rung
/// (`rust/tests/strategy_equivalence.rs`); the sweep measures where the
/// time goes and what stays resident.
///
/// `workers > 1` routes every cell through the parallel coordinator
/// (`0` = all cores).
pub fn planner_sweep_rows(cfg: &ExpConfig, workers: usize) -> Result<Vec<PlannerRow>> {
    let workers = crate::coordinator::resolve_workers(workers);
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let base = StrategyConfig {
            budget: cfg.budget,
            max_chain_length: cfg.search.max_chain_length,
            ..Default::default()
        };
        // Probe plan (unlimited budget): yields the budget ladder's
        // anchor points.  Estimation is seeded, so the sweep cells see
        // the identical estimates.
        let (hybrid_budget, full_bytes, lattice_points) = {
            let probe = Adaptive::new(&db, base)?;
            (
                probe.plan().hybrid_budget(),
                probe.plan().est_all_complete_bytes,
                probe.plan().levels.len() as u64,
            )
        };
        let budgets: Vec<Option<u64>> = vec![
            Some(0),
            Some(hybrid_budget / 2),
            Some(hybrid_budget),
            Some(hybrid_budget + (full_bytes - hybrid_budget) / 2),
            None,
        ];
        for budget in budgets {
            let scfg = StrategyConfig { mem_budget: budget, ..base };
            let (row, report) = if workers <= 1 {
                let o = run_strategy_with(
                    &db,
                    name,
                    StrategyKind::Adaptive,
                    Workload::Learn(cfg.search),
                    scfg,
                )?;
                (o.row, o.report)
            } else {
                let o = run_coordinated_with(
                    &db,
                    name,
                    StrategyKind::Adaptive,
                    Workload::Learn(cfg.search),
                    scfg,
                    workers,
                )?;
                (o.row, o.report)
            };
            rows.push(PlannerRow {
                database: name.to_string(),
                budget_bytes: budget,
                pre_fraction: if full_bytes == 0 {
                    1.0
                } else {
                    report.plan_est_bytes as f64 / full_bytes as f64
                },
                planned_positive: report.planned_positive,
                planned_complete: report.planned_complete,
                lattice_points,
                metadata: row.metadata,
                positive: row.positive,
                negative: row.negative,
                peak_ct_bytes: row.peak_ct_bytes,
                chain_queries: row.chain_queries,
                ct_rows_generated: row.ct_rows_generated,
                estimator_walks: report.estimator_walks,
                workers,
                timed_out: row.timed_out,
            });
        }
    }
    Ok(rows)
}

/// The streaming-churn experiment (E10): on every preset of `cfg`,
/// build a fully resident maintained cache state (`mem_budget: None` —
/// complete tables included, the warm-serving regime), then stream one
/// seeded churn batch per fraction in `fracs`, measuring the delta path
/// against the invalidate-and-recount baseline on **identical** inputs
/// (two clones of the same state, same batch).  Batches accumulate:
/// fraction `k+1` churns the database fraction `k` produced, like a live
/// deployment.  Digest equality between the two paths is asserted into
/// the row (`consistent`), so every measurement doubles as a
/// differential check.
pub fn churn_rows(
    cfg: &ExpConfig,
    fracs: &[f64],
    workers: usize,
) -> Result<Vec<ChurnRow>> {
    let workers = crate::coordinator::resolve_workers(workers);
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let base = MaintainConfig {
            mem_budget: None,
            workers,
            max_chain_length: cfg.search.max_chain_length,
            ..Default::default()
        };
        let mut state = MaintainedCounts::build(db, base)?;
        for (step, &frac) in fracs.iter().enumerate() {
            let batch = churn_batch(state.db(), frac, cfg.seed ^ (step as u64 + 1));

            let mut delta_state = state.clone();
            delta_state.set_mode(MaintenanceMode::DeltaOnly);
            let t0 = Instant::now();
            let delta_rep = delta_state.apply(&batch)?;
            let delta_wall = t0.elapsed();

            let mut recount_state = state.clone();
            // separate clone, forced to the invalidate-and-recount mode
            recount_state.set_mode(MaintenanceMode::RecountOnly);
            let t1 = Instant::now();
            let recount_rep = recount_state.apply(&batch)?;
            let recount_wall = t1.elapsed();

            let consistent = delta_state.digest() == recount_state.digest();
            rows.push(ChurnRow {
                database: name.to_string(),
                churn_frac: frac,
                batch_ops: batch.len() as u64,
                link_inserts: delta_rep.link_inserts,
                link_deletes: delta_rep.link_deletes,
                entity_inserts: delta_rep.entity_inserts,
                delta: delta_wall,
                recount: recount_wall,
                speedup: if delta_wall.is_zero() {
                    1.0
                } else {
                    recount_wall.as_secs_f64() / delta_wall.as_secs_f64()
                },
                points_delta_maintained: delta_rep.points_delta_maintained,
                points_recounted: recount_rep.points_recounted,
                cells_touched: delta_rep.cells_touched,
                resident_bytes: delta_state.resident_bytes(),
                digest: format!("{:016x}", delta_state.digest()),
                consistent,
                workers,
            });
            state = delta_state; // next fraction churns the mutated state
        }
    }
    Ok(rows)
}

/// The serving-throughput experiment (`relcount exp serve`,
/// `benches/serve_throughput.rs`, EXPERIMENTS.md §E12): build the
/// serving engine per preset, synthesize the deterministic
/// singleton/pair request workload (repeated `repeat` times so the
/// micro-batcher has a queue to drain), and run a full serve session
/// while a seeded churn stream publishes `churn_steps` generations
/// concurrently.  Rows are per generation; any in-protocol error fails
/// the experiment (served counts must never fail under churn).
///
/// With `shards > 0` a scale-out scenario runs per preset on top of the
/// single-process rows: `shards` in-process shard listeners, one
/// router, and `sessions` concurrent clients replaying the same
/// workload through the router.  The routed responses are hard-checked
/// byte-identical to the single-process reference, and the scenario
/// rows carry the router-side columns (`shards`, `sessions`, p50/p99
/// latency, `merge_overhead_s`) plus the peak `follower_lag` of a
/// leader/follower replication replay (EXPERIMENTS.md §E18).
pub fn serve_rows(
    cfg: &ExpConfig,
    workers: usize,
    churn_frac: f64,
    churn_steps: usize,
    repeat: usize,
    shards: usize,
    sessions: usize,
) -> Result<Vec<ServeRow>> {
    let workers = crate::coordinator::resolve_workers(workers);
    let mut rows = Vec::new();
    for name in cfg.presets {
        let db = generate(&preset(name, cfg.scale, cfg.seed)?)?;
        let base = MaintainConfig {
            mem_budget: None,
            workers,
            max_chain_length: cfg.search.max_chain_length,
            ..Default::default()
        };
        let engine = ServeEngine::build(db, base)?;
        let reqs =
            enumerate_requests(engine.db(), cfg.search.max_chain_length, usize::MAX)?;
        let one_pass: String = reqs.iter().map(|r| r.to_json().dump() + "\n").collect();
        let input = one_pass.repeat(repeat.max(1));

        let opts = ServeOptions {
            database: name.to_string(),
            workers,
            feed: if churn_steps == 0 {
                DeltaFeed::None
            } else {
                DeltaFeed::Churn {
                    frac: churn_frac,
                    steps: churn_steps,
                    seed: cfg.seed ^ 0x5E47E,
                }
            },
            // spread publishes across the serving window so requests
            // actually span several generations
            delta_pause: Duration::from_millis(2),
            ..Default::default()
        };
        let summary = run_serve(
            engine,
            std::io::Cursor::new(input),
            std::io::sink(),
            &opts,
        )?;
        if summary.errors > 0 || !summary.publish_failures.is_empty() {
            return Err(crate::error::Error::Data(format!(
                "exp serve: {} request errors, {} publish failures on {name}",
                summary.errors,
                summary.publish_failures.len()
            )));
        }
        rows.extend(summary.rows);
        if shards > 0 {
            rows.extend(sharded_scenario_rows(
                cfg,
                name,
                workers,
                shards,
                sessions.max(1),
            )?);
        }
    }
    Ok(rows)
}

/// The scale-out half of `exp serve` (see [`serve_rows`]): a full
/// shard/router/replica topology on loopback, equivalence-gated against
/// single-process serving.
fn sharded_scenario_rows(
    cfg: &ExpConfig,
    name: &str,
    workers: usize,
    shards: usize,
    sessions: usize,
) -> Result<Vec<ServeRow>> {
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let base = MaintainConfig {
        mem_budget: None,
        workers,
        max_chain_length: cfg.search.max_chain_length,
        ..Default::default()
    };
    let fresh_db = || generate(&preset(name, cfg.scale, cfg.seed)?);
    let shutdown_server = |addr: &str| -> Result<()> {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(b"{\"op\": \"shutdown\", \"id\": 0}\n")?;
        let mut ack = Vec::new();
        std::io::BufReader::new(s).read_to_end(&mut ack)?;
        Ok(())
    };

    // every shard loads the full database; the slice is per query
    let mut addrs: Vec<String> = Vec::new();
    let mut shard_threads = Vec::new();
    for index in 0..shards {
        let engine = ServeEngine::build(fresh_db()?, base)?;
        let listener = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        let opts = ServeOptions {
            database: name.to_string(),
            workers,
            shard: Some(ShardConfig { index, of: shards }),
            ..Default::default()
        };
        shard_threads.push(std::thread::spawn(move || {
            serve_listener(engine, listener, &opts)
        }));
    }

    let router_listener = TcpListener::bind("127.0.0.1:0")?;
    let router_addr = router_listener.local_addr()?.to_string();
    let router_db = fresh_db()?;
    let router_opts =
        ServeOptions { database: name.to_string(), ..Default::default() };
    let router_shards = addrs.clone();
    let router = std::thread::spawn(move || {
        run_router(router_db, &router_shards, router_listener, &router_opts)
    });

    // single-process reference over the identical workload
    let reqs = enumerate_requests(
        &fresh_db()?,
        cfg.search.max_chain_length,
        usize::MAX,
    )?;
    let one_pass: String = reqs.iter().map(|r| r.to_json().dump() + "\n").collect();
    let mut reference = Vec::new();
    let ref_opts = ServeOptions {
        database: name.to_string(),
        workers,
        ..Default::default()
    };
    run_serve(
        ServeEngine::build(fresh_db()?, base)?,
        std::io::Cursor::new(one_pass.clone()),
        &mut reference,
        &ref_opts,
    )?;

    let mut clients = Vec::new();
    for _ in 0..sessions {
        let input = one_pass.clone();
        let addr = router_addr.clone();
        clients.push(std::thread::spawn(move || -> std::io::Result<Vec<u8>> {
            let mut s = TcpStream::connect(&addr)?;
            s.write_all(input.as_bytes())?;
            s.shutdown(std::net::Shutdown::Write)?;
            let mut buf = Vec::new();
            std::io::BufReader::new(s).read_to_end(&mut buf)?;
            Ok(buf)
        }));
    }
    for c in clients {
        let got = c.join().expect("router client panicked")?;
        if got != reference {
            return Err(Error::Data(format!(
                "exp serve: routed responses diverged from single-process \
                 serving on {name} ({shards} shards)"
            )));
        }
    }
    shutdown_server(&router_addr)?;
    let router_summary = router.join().expect("router thread panicked")?;
    for a in &addrs {
        shutdown_server(a)?;
    }
    for t in shard_threads {
        let s = t.join().expect("shard thread panicked")?;
        if s.errors > 0 {
            return Err(Error::Data(format!(
                "exp serve: {} partial-request errors on a {name} shard",
                s.errors
            )));
        }
    }
    if router_summary.errors > 0 {
        return Err(Error::Data(format!(
            "exp serve: {} routed request errors on {name}",
            router_summary.errors
        )));
    }

    // replication replay: leader log -> follower, peak lag observed
    let mut leader = ServeEngine::build(fresh_db()?, base)?;
    let log = Arc::new(ReplLog::new());
    for i in 0..3u64 {
        let b = churn_batch(leader.db(), 0.05, cfg.seed ^ (i + 1));
        leader.apply_publish(&b)?;
        log.append(ReplRecord {
            epoch: leader.epoch(),
            digest: leader.digest(),
            batch: b,
        });
    }
    log.close();
    let leader_listener = TcpListener::bind("127.0.0.1:0")?;
    let leader_addr = leader_listener.local_addr()?.to_string();
    let acceptor = Replicator::spawn(leader_listener, log)?;
    let handle = Arc::new(ReplHandle::new());
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let handle = handle.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut peak = 0u64;
            while !stop.load(Ordering::Relaxed) {
                peak = peak.max(handle.lag());
                std::thread::sleep(Duration::from_micros(200));
            }
            peak
        })
    };
    let mut follower = ServeEngine::build(fresh_db()?, base)?;
    let (applied, fails) = follow(
        &leader_addr,
        &mut follower,
        Some(&handle),
        Duration::from_millis(1),
    );
    stop.store(true, Ordering::Relaxed);
    let peak_lag = monitor.join().expect("lag monitor panicked");
    acceptor.shutdown();
    if !fails.is_empty() || applied != 3 || follower.digest() != leader.digest() {
        return Err(Error::Data(format!(
            "exp serve: follower diverged from leader on {name}: applied \
             {applied}/3, failures {fails:?}"
        )));
    }

    let mut rows = router_summary.rows;
    for r in &mut rows {
        r.follower_lag = peak_lag as f64;
    }
    Ok(rows)
}

/// The estimator quality lab (`relcount exp estimator`,
/// EXPERIMENTS.md §E15): per preset, sweep every lattice point the
/// planner estimates under each [`QualityMode`] and report the q-error
/// distribution (p50/p95/max against oracle counts) plus plan-regret —
/// see [`crate::estimate::quality`] for the metric definitions.  The
/// sweep is seeded and byte-deterministic, so `estimator-smoke` in CI
/// gates the JSON against `scripts/estimator_gates.json`.
pub fn estimator_rows(cfg: &ExpConfig) -> Result<Vec<EstimatorRow>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let lattice = Lattice::build(&db.schema, cfg.search.max_chain_length)?;
        for mode in QualityMode::ALL {
            let r =
                quality::evaluate(&db, &lattice, EstimatorConfig::default(), mode)?;
            rows.push(EstimatorRow {
                database: name.to_string(),
                mode: r.mode.to_string(),
                points: r.points,
                q_p50: r.q_p50,
                q_p95: r.q_p95,
                q_max: r.q_max,
                exact_frac: r.exact_frac,
                summary_hits: r.summary_hits,
                walks: r.walks,
                regret_saved_frac: r.regret_saved_frac,
                bytes_overrun_frac: r.bytes_overrun_frac,
            });
        }
    }
    Ok(rows)
}

/// The join-kernel differential experiment (`relcount exp wcoj`,
/// EXPERIMENTS.md §E16): every lattice point with at least two
/// relationships is counted by the binary chain kernel and by the
/// worst-case optimal kernel ([`crate::db::wcoj`]), on the hub-skewed
/// triangle/star constructions ([`crate::datagen::synth`]) and on the
/// Table-4 presets; a hash-backend WCOJ run is the third oracle.
/// Digests and [`JoinStats`] must be bit-identical across all three —
/// any divergence is a hard error, never a reported row — so only the
/// timings (and hence `speedup`) are machine-dependent.  The headline
/// is the `tri_skew` triangle row: binary plans enumerate Θ(n²) hub
/// pairs there while the WCOJ kernel touches Θ(n log n).
pub fn wcoj_rows(cfg: &ExpConfig) -> Result<Vec<WcojRow>> {
    let n = ((4000.0 * cfg.scale) as u32).max(16);
    let mut dbs = vec![
        ("tri_skew".to_string(), skewed_triangle_db(n)?),
        ("star_skew".to_string(), skewed_star_db(n)?),
    ];
    for name in cfg.presets {
        let db = generate(&preset(name, cfg.scale, cfg.seed)?)?;
        dbs.push((name.to_string(), db));
    }

    let mut rows = Vec::new();
    for (name, chain_db) in &dbs {
        let mut wcoj_db = chain_db.clone();
        wcoj_db.set_kernel(JoinKernel::Wcoj);
        let mut hash_db = chain_db.clone();
        hash_db.set_backend(Backend::Hash)?;
        hash_db.set_kernel(JoinKernel::Wcoj);

        let lattice = Lattice::build(&chain_db.schema, cfg.search.max_chain_length)?;
        for p in &lattice.points {
            if p.rels.len() < 2 {
                continue;
            }
            let point = p
                .rels
                .iter()
                .map(|&r| chain_db.schema.relationships[r].name.as_str())
                .collect::<Vec<_>>()
                .join("+");

            let mut sc = JoinStats::default();
            let start = Instant::now();
            let a = positive_chain_ct(chain_db, &p.rels, &p.attr_vars, &mut sc)?;
            let chain = start.elapsed();

            let mut sw = JoinStats::default();
            let start = Instant::now();
            let b = positive_chain_ct(&wcoj_db, &p.rels, &p.attr_vars, &mut sw)?;
            let wcoj = start.elapsed();

            let mut sh = JoinStats::default();
            let c = positive_chain_ct(&hash_db, &p.rels, &p.attr_vars, &mut sh)?;

            let digests_ok = a.digest() == b.digest() && b.digest() == c.digest();
            if !digests_ok || sc != sw || sw != sh {
                return Err(Error::Data(format!(
                    "wcoj kernel diverged from chain on {name} point {point}"
                )));
            }
            rows.push(WcojRow {
                database: name.clone(),
                point,
                pattern: p.pattern.name().to_string(),
                rels: p.rels.len(),
                rows_enumerated: sw.rows_enumerated,
                chain,
                wcoj,
                speedup: if wcoj.as_secs_f64() > 0.0 {
                    chain.as_secs_f64() / wcoj.as_secs_f64()
                } else {
                    f64::INFINITY
                },
                identical: true,
            });
        }
    }
    Ok(rows)
}

/// The index-compression experiment (`relcount exp compress`,
/// EXPERIMENTS.md §E17): per database (hub-skewed synthetics plus the
/// Table-4 presets), every lattice point with at least two
/// relationships is counted on all three index backends — plain CSR,
/// compressed block-CSR and the hash oracle — under **both** join
/// kernels, and the full cache build is digest-compared across backends
/// at 1 and 4 workers.  Any count-digest or [`JoinStats`] divergence is
/// a hard error, never a reported row, so only the timings (and hence
/// `throughput_vs_csr`) are machine-dependent.  The headline is
/// `bytes_per_pair_ccsr`: delta-encoded bit-packed blocks against CSR's
/// flat 16 bytes/pair, with intersection throughput required to stay
/// within 0.8x of plain CSR on at least one preset (gated by
/// `compress-smoke` in CI against `bench/baselines/BENCH_compress.json`).
pub fn compress_rows(cfg: &ExpConfig) -> Result<Vec<CompressRow>> {
    let n = ((4000.0 * cfg.scale) as u32).max(16);
    let mut dbs = vec![
        ("tri_skew".to_string(), skewed_triangle_db(n)?),
        ("star_skew".to_string(), skewed_star_db(n)?),
    ];
    for name in cfg.presets {
        let db = generate(&preset(name, cfg.scale, cfg.seed)?)?;
        dbs.push((name.to_string(), db));
    }

    let mut rows = Vec::new();
    for (name, base) in &dbs {
        let mut csr_db = base.clone();
        csr_db.set_backend(Backend::Csr)?;
        let mut ccsr_db = base.clone();
        ccsr_db.set_backend(Backend::Ccsr)?;
        let mut hash_db = base.clone();
        hash_db.set_backend(Backend::Hash)?;

        let pairs: u64 = base.rels.iter().map(|t| t.len() as u64).sum();
        let csr_bytes: u64 =
            csr_db.index_bytes_per_rel().iter().map(|&b| b as u64).sum();
        let ccsr_bytes: u64 =
            ccsr_db.index_bytes_per_rel().iter().map(|&b| b as u64).sum();

        // per-point differential across backends, under both kernels
        let lattice = Lattice::build(&base.schema, cfg.search.max_chain_length)?;
        let mut points = 0u64;
        let mut csr_time = Duration::ZERO;
        let mut ccsr_time = Duration::ZERO;
        for kernel in [JoinKernel::Chain, JoinKernel::Wcoj] {
            csr_db.set_kernel(kernel);
            ccsr_db.set_kernel(kernel);
            hash_db.set_kernel(kernel);
            for p in &lattice.points {
                if p.rels.len() < 2 {
                    continue;
                }
                let mut sa = JoinStats::default();
                let start = Instant::now();
                let a = positive_chain_ct(&csr_db, &p.rels, &p.attr_vars, &mut sa)?;
                csr_time += start.elapsed();

                let mut sb = JoinStats::default();
                let start = Instant::now();
                let b = positive_chain_ct(&ccsr_db, &p.rels, &p.attr_vars, &mut sb)?;
                ccsr_time += start.elapsed();

                let mut sc = JoinStats::default();
                let c = positive_chain_ct(&hash_db, &p.rels, &p.attr_vars, &mut sc)?;

                let digests_ok = a.digest() == b.digest() && b.digest() == c.digest();
                if !digests_ok || sa != sb || sb != sc {
                    return Err(Error::Data(format!(
                        "compress: backends diverged on {name} kernel {} point {:?}",
                        kernel.name(),
                        p.rels
                    )));
                }
                points += 1;
            }
        }

        // full cache build digest equality across backends x worker counts
        let scfg = StrategyConfig { budget: cfg.budget, ..Default::default() };
        let mut witness: Option<(u64, JoinStats)> = None;
        for workers in [1usize, 4] {
            for db in [&csr_db, &ccsr_db, &hash_db] {
                let (digest, stats) = if workers == 1 {
                    let o = run_strategy_with(
                        db,
                        name,
                        StrategyKind::Hybrid,
                        Workload::PrepareOnly,
                        scfg,
                    )?;
                    (o.cache_digest, o.report.join_stats)
                } else {
                    let o = run_coordinated_with(
                        db,
                        name,
                        StrategyKind::Hybrid,
                        Workload::PrepareOnly,
                        scfg,
                        workers,
                    )?;
                    (o.cache_digest, o.report.join_stats)
                };
                match &witness {
                    None => witness = Some((digest, stats)),
                    Some((d, s)) => {
                        if *d != digest || *s != stats {
                            return Err(Error::Data(format!(
                                "compress: cache digest diverged on {name} \
                                 backend {} at {workers} workers",
                                db.backend().name()
                            )));
                        }
                    }
                }
            }
        }

        rows.push(CompressRow {
            database: name.clone(),
            pairs,
            csr_bytes,
            ccsr_bytes,
            bytes_per_pair_csr: if pairs == 0 {
                0.0
            } else {
                csr_bytes as f64 / pairs as f64
            },
            bytes_per_pair_ccsr: if pairs == 0 {
                0.0
            } else {
                ccsr_bytes as f64 / pairs as f64
            },
            bytes_ratio: if ccsr_bytes == 0 {
                1.0
            } else {
                csr_bytes as f64 / ccsr_bytes as f64
            },
            points,
            csr_time,
            ccsr_time,
            throughput_vs_csr: if ccsr_time.as_secs_f64() > 0.0 {
                csr_time.as_secs_f64() / ccsr_time.as_secs_f64()
            } else {
                f64::INFINITY
            },
            identical: true,
            workers: 4,
        });
    }
    Ok(rows)
}

/// The restart-latency experiment (`relcount exp persist`,
/// EXPERIMENTS.md §E14): per preset, build the maintained-count state,
/// churn it so the snapshot is not the trivial initial generation, then
/// compare a cold rebuild from the mutated base tables against saving
/// a durable snapshot and loading it back.  `digest_match` must hold on
/// every row — the snapshot round-trip and the cold recount are both
/// required to be bit-identical to the live state; only the timings
/// (and hence `speedup`) are machine-dependent.
pub fn persist_rows(cfg: &ExpConfig, workers: usize) -> Result<Vec<PersistRow>> {
    let workers = crate::coordinator::resolve_workers(workers);
    let mut rows = Vec::new();
    for name in cfg.presets {
        let db = generate(&preset(name, cfg.scale, cfg.seed)?)?;
        let base = MaintainConfig {
            mem_budget: None,
            workers,
            max_chain_length: cfg.search.max_chain_length,
            ..Default::default()
        };
        let mut m = MaintainedCounts::build(db, base)?;
        for i in 0..2u64 {
            let batch = churn_batch(m.db(), 0.02, cfg.seed ^ 0x9E14 ^ (i + 1));
            m.apply(&batch)?;
        }
        m.compact_indexes();

        // cold restart: recount everything from the mutated base tables
        let rebuilt = crate::db::catalog::Database::new(
            m.db().schema.clone(),
            m.db().entities.clone(),
            m.db().rels.clone(),
        )?;
        let start = Instant::now();
        let cold = MaintainedCounts::build(rebuilt, base)?;
        let cold_build = start.elapsed();

        // durable restart: save a snapshot, load it back
        let dir = std::env::temp_dir().join(format!(
            "relcount-exp-persist-{}-{}",
            name,
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        let start = Instant::now();
        crate::persist::write_snapshot(&dir, &m, 2)?;
        let save = start.elapsed();
        let mut snapshot_bytes = 0u64;
        for entry in std::fs::read_dir(&dir)? {
            snapshot_bytes += entry?.metadata()?.len();
        }
        let start = Instant::now();
        let loaded =
            crate::persist::load_snapshot(&dir)?.into_maintained(workers)?;
        let load = start.elapsed();
        let _ = std::fs::remove_dir_all(&dir);

        let digest_match =
            loaded.digest() == m.digest() && cold.digest() == m.digest();
        rows.push(PersistRow {
            database: name.to_string(),
            rows: m.db().total_rows(),
            resident_bytes: m.resident_bytes(),
            snapshot_bytes,
            cold_build,
            save,
            load,
            speedup: if load.as_secs_f64() > 0.0 {
                cold_build.as_secs_f64() / load.as_secs_f64()
            } else {
                f64::INFINITY
            },
            digest_match,
            workers,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig {
            scale: 0.02,
            budget: Some(Duration::from_secs(60)),
            seed: 1,
            search: SearchConfig { max_ops_per_point: 20, ..Default::default() },
            presets: &["uw", "mondial"],
        }
    }

    #[test]
    fn fig3_rows_cover_grid() {
        let rows = fig3_fig4_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2 * 3);
        assert!(rows.iter().all(|r| r.total() > Duration::ZERO));
        let dbs: Vec<_> = rows.iter().map(|r| r.database.as_str()).collect();
        assert!(dbs.contains(&"uw") && dbs.contains(&"mondial"));
    }

    #[test]
    fn table5_shapes() {
        let rows = table5_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.ct_family_rows > 0);
            assert!(r.ct_database_rows > 0);
        }
    }

    #[test]
    fn scaling_rows_cover_grid() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = coordinator_scaling_rows(&cfg, &[1, 2]).unwrap();
        // 1 preset x 3 strategies x {1, 2} workers
        assert_eq!(rows.len(), 3 * 2);
        for r in &rows {
            assert!(!r.timed_out, "{r:?}");
            assert!(r.wall > Duration::ZERO);
            assert!(r.speedup > 0.0);
        }
        // baseline rows report exactly 1.0
        assert!(rows.iter().filter(|r| r.workers == 1).all(|r| r.speedup == 1.0));
    }

    #[test]
    fn planner_sweep_traces_the_spectrum() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = planner_sweep_rows(&cfg, 1).unwrap();
        assert_eq!(rows.len(), 5);
        // endpoint budgets: nothing planned vs everything planned
        assert_eq!(rows[0].budget_bytes, Some(0));
        assert_eq!(rows[0].planned_positive, 0);
        assert_eq!(rows[0].pre_fraction, 0.0);
        let last = rows.last().unwrap();
        assert_eq!(last.budget_bytes, None);
        assert_eq!(last.planned_complete, last.lattice_points);
        assert!((last.pre_fraction - 1.0).abs() < 1e-9);
        // the HYBRID rung plans all positives, no completes
        let hybrid = &rows[2];
        assert_eq!(hybrid.planned_positive, hybrid.lattice_points);
        assert_eq!(hybrid.planned_complete, 0);
        // pre_fraction is monotone along the ladder
        for w in rows.windows(2) {
            assert!(w[0].pre_fraction <= w[1].pre_fraction + 1e-12);
        }
        // post-counting joins disappear as the plan grows
        assert!(rows[0].chain_queries >= last.chain_queries);
        for r in &rows {
            assert!(!r.timed_out, "{r:?}");
        }
    }

    #[test]
    fn planner_sweep_through_coordinator() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let seq = planner_sweep_rows(&cfg, 1).unwrap();
        let par = planner_sweep_rows(&cfg, 2).unwrap();
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(&par) {
            // identical plans and count metrics; only wall clock differs
            assert_eq!(s.planned_positive, p.planned_positive);
            assert_eq!(s.planned_complete, p.planned_complete);
            assert_eq!(s.chain_queries, p.chain_queries);
            assert_eq!(s.ct_rows_generated, p.ct_rows_generated);
            assert_eq!(p.workers, 2);
        }
    }

    #[test]
    fn churn_rows_shapes_and_consistency() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = churn_rows(&cfg, &[0.02, 0.05], 1).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.batch_ops > 0, "{r:?}");
            assert!(r.consistent, "delta and recount paths diverged: {r:?}");
            assert_eq!(r.digest.len(), 16);
            assert!(r.resident_bytes > 0);
            assert!(r.speedup > 0.0);
        }
        // seeded determinism of the non-timing fields
        let again = churn_rows(&cfg, &[0.02, 0.05], 1).unwrap();
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.batch_ops, b.batch_ops);
            assert_eq!(a.digest, b.digest);
            assert_eq!(a.cells_touched, b.cells_touched);
        }
    }

    #[test]
    fn serve_rows_shapes() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = serve_rows(&cfg, 2, 0.05, 1, 2, 0, 1).unwrap();
        assert!(!rows.is_empty());
        let total: u64 = rows.iter().map(|r| r.requests).sum();
        assert!(total > 0);
        for r in &rows {
            assert_eq!(r.errors, 0, "{r:?}");
            assert_eq!(r.workers, 2);
            assert!(r.epoch <= 1);
            assert_eq!(r.shards, 0, "unsharded rows carry shards = 0");
        }
        // static serving lands every request on generation 0
        let quiet = serve_rows(&cfg, 1, 0.0, 0, 1, 0, 1).unwrap();
        assert_eq!(quiet.len(), 1);
        assert_eq!(quiet[0].epoch, 0);
    }

    #[test]
    fn sharded_serve_scenario_rows_carry_scaleout_columns() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = serve_rows(&cfg, 1, 0.0, 0, 1, 2, 2).unwrap();
        // unsharded rows first, then the router scenario rows
        let scenario: Vec<_> = rows.iter().filter(|r| r.shards == 2).collect();
        assert!(!scenario.is_empty(), "{rows:?}");
        for r in scenario {
            assert_eq!(r.errors, 0, "{r:?}");
            assert_eq!(r.epoch, 0, "static shards serve generation 0");
            assert!(r.sessions >= 2, "{r:?}");
            assert!(r.merge_overhead_s >= 0.0);
        }
    }

    #[test]
    fn persist_rows_round_trip_bit_identically() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = persist_rows(&cfg, 1).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert!(r.digest_match, "{r:?}");
        assert!(r.rows > 0);
        assert!(r.resident_bytes > 0);
        assert!(r.snapshot_bytes > 0);
        assert!(r.cold_build > Duration::ZERO);
        assert!(r.speedup > 0.0);
        assert_eq!(r.workers, 1);
    }

    #[test]
    fn estimator_rows_cover_modes_deterministically() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = estimator_rows(&cfg).unwrap();
        // 1 preset x 3 quality modes, in QualityMode::ALL order
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].mode, "default");
        assert_eq!(rows[1].mode, "sampled");
        assert_eq!(rows[2].mode, "summary");
        for r in &rows {
            assert!(r.points > 0, "{r:?}");
            assert!(r.q_max >= r.q_p95 && r.q_p95 >= r.q_p50 && r.q_p50 >= 1.0);
            assert!((0.0..=1.0).contains(&r.regret_saved_frac));
            assert!(r.bytes_overrun_frac.unwrap_or(0.0) >= 0.0);
        }
        assert_eq!(rows[2].walks, 0, "summary mode must not sample");
        let again = estimator_rows(&cfg).unwrap();
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.q_p50, b.q_p50);
            assert_eq!(a.q_max, b.q_max);
            assert_eq!(a.regret_saved_frac, b.regret_saved_frac);
        }
    }

    #[test]
    fn wcoj_rows_cover_synthetics_and_presets() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = wcoj_rows(&cfg).unwrap();
        // the generator hard-errors on any kernel divergence, so every
        // surviving row is a witnessed agreement
        assert!(rows.iter().all(|r| r.identical));
        assert!(rows.iter().all(|r| r.rels >= 2));
        let tri = rows
            .iter()
            .find(|r| r.database == "tri_skew" && r.pattern == "triangle")
            .expect("triangle point present");
        assert_eq!(tri.rels, 3);
        assert!(tri.rows_enumerated > 0);
        assert!(rows
            .iter()
            .any(|r| r.database == "star_skew" && r.pattern == "star"));
        assert!(rows.iter().any(|r| r.database == "uw"));
    }

    #[test]
    fn compress_rows_witness_identity_and_compression() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = compress_rows(&cfg).unwrap();
        // the generator hard-errors on any backend divergence, so every
        // surviving row is a witnessed three-way agreement at 1 and 4
        // workers
        assert_eq!(rows.len(), 3); // tri_skew, star_skew, uw
        for r in &rows {
            assert!(r.identical, "{r:?}");
            assert_eq!(r.workers, 4);
            assert!(r.pairs > 0);
            assert!(r.points > 0, "no multi-rel lattice points on {}", r.database);
            assert!(r.csr_bytes > 0 && r.ccsr_bytes > 0);
            assert!(r.throughput_vs_csr > 0.0);
        }
        // the hub-skewed synthetics have dense sorted runs: the
        // delta-encoded blocks must beat CSR's flat 16 bytes/pair
        let tri = rows.iter().find(|r| r.database == "tri_skew").unwrap();
        assert!(
            tri.bytes_ratio > 1.0,
            "ccsr should compress tri_skew: {tri:?}"
        );
        assert!(tri.bytes_per_pair_ccsr < tri.bytes_per_pair_csr);
    }

    #[test]
    fn table4_shapes() {
        let rows = table4_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.row_count > 0);
            assert!(r.mean_parents_per_node.is_finite());
        }
        assert_eq!(paper_rows("uw"), Some(712));
    }
}
