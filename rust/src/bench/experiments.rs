//! Row generators for every table and figure of the paper's evaluation
//! (the per-experiment index is DESIGN.md §4).  Shared by the `cargo
//! bench` targets, the CLI `exp` subcommand and the end-to-end example.

use std::time::Duration;

use crate::bench::driver::{run_strategy, RunOutcome, Workload};
use crate::datagen::generator::generate;
use crate::datagen::presets::{preset, paper_row_count, PRESET_NAMES};
use crate::error::Result;
use crate::learn::search::SearchConfig;
use crate::metrics::report::{RunRow, Table4Row, Table5Row};
use crate::strategies::StrategyKind;

/// Experiment-wide options.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    /// Dataset scale factor in (0, 1] (the paper runs at 1.0; scaled
    /// runs preserve who-wins ordering at laptop budgets).
    pub scale: f64,
    /// Per-cell wall-clock budget (the paper's Slurm limit was 100 min).
    pub budget: Option<Duration>,
    pub seed: u64,
    pub search: SearchConfig,
    /// Presets to include (defaults to all 8).
    pub presets: &'static [&'static str],
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scale: 0.05,
            budget: Some(Duration::from_secs(120)),
            seed: 0,
            search: SearchConfig::default(),
            presets: &PRESET_NAMES,
        }
    }
}

/// Figures 3 & 4 share the same runs: every strategy on every preset,
/// full learn workload, timing breakdown + peak memory per cell.
pub fn fig3_fig4_rows(cfg: &ExpConfig) -> Result<Vec<RunRow>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        for kind in StrategyKind::ALL {
            let out = run_strategy(
                &db,
                name,
                kind,
                Workload::Learn(cfg.search),
                cfg.budget,
            )?;
            rows.push(out.row);
        }
    }
    Ok(rows)
}

/// Table 5: Σ rows over family ct-tables (HYBRID) vs the complete lattice
/// ct-tables (PRECOUNT), per database.
pub fn table5_rows(cfg: &ExpConfig) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let hybrid = run_strategy(
            &db,
            name,
            StrategyKind::Hybrid,
            Workload::Learn(cfg.search),
            cfg.budget,
        )?;
        let pre = run_strategy(
            &db,
            name,
            StrategyKind::Precount,
            Workload::PrepareOnly,
            cfg.budget,
        )?;
        rows.push(Table5Row {
            database: name.to_string(),
            ct_family_rows: hybrid.report.ct_rows_generated,
            ct_database_rows: pre.report.ct_rows_generated,
        });
    }
    Ok(rows)
}

/// Table 4: row count, #relationships, and the MP/N of the learned BN.
pub fn table4_rows(cfg: &ExpConfig) -> Result<Vec<Table4Row>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let out: RunOutcome = run_strategy(
            &db,
            name,
            StrategyKind::Hybrid,
            Workload::Learn(cfg.search),
            cfg.budget,
        )?;
        let mpn = out
            .model
            .as_ref()
            .map(|m| m.bn.mean_parents_per_node())
            .unwrap_or(f64::NAN);
        rows.push(Table4Row {
            database: name.to_string(),
            row_count: db.total_rows(),
            n_relationships: db.n_relationships(),
            mean_parents_per_node: mpn,
        });
    }
    Ok(rows)
}

/// The paper's Table 4 row counts for side-by-side reporting.
pub fn paper_rows(name: &str) -> Option<u64> {
    paper_row_count(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig {
            scale: 0.02,
            budget: Some(Duration::from_secs(60)),
            seed: 1,
            search: SearchConfig { max_ops_per_point: 20, ..Default::default() },
            presets: &["uw", "mondial"],
        }
    }

    #[test]
    fn fig3_rows_cover_grid() {
        let rows = fig3_fig4_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2 * 3);
        assert!(rows.iter().all(|r| r.total() > Duration::ZERO));
        let dbs: Vec<_> = rows.iter().map(|r| r.database.as_str()).collect();
        assert!(dbs.contains(&"uw") && dbs.contains(&"mondial"));
    }

    #[test]
    fn table5_shapes() {
        let rows = table5_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.ct_family_rows > 0);
            assert!(r.ct_database_rows > 0);
        }
    }

    #[test]
    fn table4_shapes() {
        let rows = table4_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.row_count > 0);
            assert!(r.mean_parents_per_node.is_finite());
        }
        assert_eq!(paper_rows("uw"), Some(712));
    }
}
