//! Row generators for every table and figure of the paper's evaluation
//! (the per-experiment index is DESIGN.md §4).  Shared by the `cargo
//! bench` targets, the CLI `exp` subcommand and the end-to-end example.

use std::time::{Duration, Instant};

use crate::bench::driver::{run_coordinated, run_strategy, RunOutcome, Workload};
use crate::datagen::generator::generate;
use crate::datagen::presets::{preset, paper_row_count, PRESET_NAMES};
use crate::error::Result;
use crate::learn::search::SearchConfig;
use crate::metrics::report::{RunRow, ScalingRow, Table4Row, Table5Row};
use crate::strategies::StrategyKind;

/// Experiment-wide options.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    /// Dataset scale factor in (0, 1] (the paper runs at 1.0; scaled
    /// runs preserve who-wins ordering at laptop budgets).
    pub scale: f64,
    /// Per-cell wall-clock budget (the paper's Slurm limit was 100 min).
    pub budget: Option<Duration>,
    pub seed: u64,
    pub search: SearchConfig,
    /// Presets to include (defaults to all 8).
    pub presets: &'static [&'static str],
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scale: 0.05,
            budget: Some(Duration::from_secs(120)),
            seed: 0,
            search: SearchConfig::default(),
            presets: &PRESET_NAMES,
        }
    }
}

/// Figures 3 & 4 share the same runs: every strategy on every preset,
/// full learn workload, timing breakdown + peak memory per cell.
pub fn fig3_fig4_rows(cfg: &ExpConfig) -> Result<Vec<RunRow>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        for kind in StrategyKind::ALL {
            let out = run_strategy(
                &db,
                name,
                kind,
                Workload::Learn(cfg.search),
                cfg.budget,
            )?;
            rows.push(out.row);
        }
    }
    Ok(rows)
}

/// Table 5: Σ rows over family ct-tables (HYBRID) vs the complete lattice
/// ct-tables (PRECOUNT), per database.
pub fn table5_rows(cfg: &ExpConfig) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let hybrid = run_strategy(
            &db,
            name,
            StrategyKind::Hybrid,
            Workload::Learn(cfg.search),
            cfg.budget,
        )?;
        let pre = run_strategy(
            &db,
            name,
            StrategyKind::Precount,
            Workload::PrepareOnly,
            cfg.budget,
        )?;
        rows.push(Table5Row {
            database: name.to_string(),
            ct_family_rows: hybrid.report.ct_rows_generated,
            ct_database_rows: pre.report.ct_rows_generated,
        });
    }
    Ok(rows)
}

/// Table 4: row count, #relationships, and the MP/N of the learned BN.
pub fn table4_rows(cfg: &ExpConfig) -> Result<Vec<Table4Row>> {
    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        let out: RunOutcome = run_strategy(
            &db,
            name,
            StrategyKind::Hybrid,
            Workload::Learn(cfg.search),
            cfg.budget,
        )?;
        let mpn = out
            .model
            .as_ref()
            .map(|m| m.bn.mean_parents_per_node())
            .unwrap_or(f64::NAN);
        rows.push(Table4Row {
            database: name.to_string(),
            row_count: db.total_rows(),
            n_relationships: db.n_relationships(),
            mean_parents_per_node: mpn,
        });
    }
    Ok(rows)
}

/// The paper's Table 4 row counts for side-by-side reporting.
pub fn paper_rows(name: &str) -> Option<u64> {
    paper_row_count(name)
}

/// The coordinator worker-scaling sweep: every strategy on every preset
/// of `cfg`, full learn workload through the
/// [`crate::coordinator::ParallelCoordinator`], once per worker count.
///
/// A 1-worker cell always runs first as the speedup baseline (whether or
/// not `1` appears in `worker_counts`).  The learned models and count
/// metrics are identical across cells by construction — the sweep
/// measures wall clock only.
pub fn coordinator_scaling_rows(
    cfg: &ExpConfig,
    worker_counts: &[usize],
) -> Result<Vec<ScalingRow>> {
    let mut counts: Vec<usize> = worker_counts
        .iter()
        .copied()
        .map(|w| crate::coordinator::resolve_workers(w))
        .filter(|&w| w != 1)
        .collect();
    counts.sort_unstable();
    counts.dedup();

    let mut rows = Vec::new();
    for name in cfg.presets {
        let gen_cfg = preset(name, cfg.scale, cfg.seed)?;
        let db = generate(&gen_cfg)?;
        for kind in StrategyKind::ALL {
            let mut baseline = Duration::ZERO;
            for (i, &w) in std::iter::once(&1usize).chain(&counts).enumerate() {
                let t0 = Instant::now();
                let out = run_coordinated(
                    &db,
                    name,
                    kind,
                    Workload::Learn(cfg.search),
                    cfg.budget,
                    w,
                )?;
                let wall = t0.elapsed();
                if i == 0 {
                    baseline = wall;
                }
                let cpu_timer = out.coordinator.cpu_view().timing;
                rows.push(ScalingRow {
                    database: name.to_string(),
                    strategy: kind.name().to_string(),
                    workers: w,
                    wall,
                    speedup: if wall.is_zero() {
                        1.0
                    } else {
                        baseline.as_secs_f64() / wall.as_secs_f64()
                    },
                    cpu: cpu_timer.total(),
                    timed_out: out.row.timed_out,
                });
            }
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig {
            scale: 0.02,
            budget: Some(Duration::from_secs(60)),
            seed: 1,
            search: SearchConfig { max_ops_per_point: 20, ..Default::default() },
            presets: &["uw", "mondial"],
        }
    }

    #[test]
    fn fig3_rows_cover_grid() {
        let rows = fig3_fig4_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2 * 3);
        assert!(rows.iter().all(|r| r.total() > Duration::ZERO));
        let dbs: Vec<_> = rows.iter().map(|r| r.database.as_str()).collect();
        assert!(dbs.contains(&"uw") && dbs.contains(&"mondial"));
    }

    #[test]
    fn table5_shapes() {
        let rows = table5_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.ct_family_rows > 0);
            assert!(r.ct_database_rows > 0);
        }
    }

    #[test]
    fn scaling_rows_cover_grid() {
        let cfg = ExpConfig { presets: &["uw"], ..tiny() };
        let rows = coordinator_scaling_rows(&cfg, &[1, 2]).unwrap();
        // 1 preset x 3 strategies x {1, 2} workers
        assert_eq!(rows.len(), 3 * 2);
        for r in &rows {
            assert!(!r.timed_out, "{r:?}");
            assert!(r.wall > Duration::ZERO);
            assert!(r.speedup > 0.0);
        }
        // baseline rows report exactly 1.0
        assert!(rows.iter().filter(|r| r.workers == 1).all(|r| r.speedup == 1.0));
    }

    #[test]
    fn table4_shapes() {
        let rows = table4_rows(&tiny()).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.row_count > 0);
            assert!(r.mean_parents_per_node.is_finite());
        }
        assert_eq!(paper_rows("uw"), Some(712));
    }
}
