//! The experiment harness regenerating the paper's evaluation
//! (DESIGN.md §4): end-to-end strategy runs with the 3-component timing
//! breakdown and memory accounting, plus per-table/figure row generators.

pub mod driver;
pub mod experiments;

pub use driver::{run_strategy, run_strategy_with, RunOutcome, Workload};
pub use experiments::{fig3_fig4_rows, planner_sweep_rows, table4_rows, table5_rows};
