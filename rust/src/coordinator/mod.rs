//! The L3 counting coordinator: work-sharded parallel execution of the
//! counting strategies.
//!
//! The paper's bottleneck is computing instantiation counts over the
//! relationship lattice, and its workload is embarrassingly parallel:
//! the positive pre-count is independent per lattice point, the PRECOUNT
//! negative phase is independent per lattice point, and the HYBRID /
//! ONDEMAND post-count is independent per family.  This module exploits
//! that without touching the learner or the strategies' algorithms:
//!
//! - [`shard`] assigns work to workers deterministically (LPT by cost
//!   for pre-count tasks, stable hash routing for family cache keys);
//! - [`pool`] runs a shard assignment on scoped threads and hands the
//!   results back **in task order**;
//! - [`parallel::ParallelCoordinator`] wraps a
//!   [`crate::strategies::StrategyKind`] behind the standard
//!   [`crate::strategies::CountingStrategy`] interface, owning the
//!   shared positive/complete caches and one family-cache shard per
//!   worker, and merging per-worker metrics into a single deterministic
//!   [`crate::strategies::StrategyReport`].
//!
//! Counts are exact integer arithmetic over content-addressed tables, so
//! ct-tables, learned structures and BDeu scores are bit-identical for
//! every worker count — `rust/tests/coordinator_parallel.rs` holds that
//! invariant, and `rust/benches/coordinator_scaling.rs` measures the
//! wall-clock speedup (see `EXPERIMENTS.md`).
//!
//! Select it from the CLI with `--workers N` (`N = 0` or `auto` uses all
//! cores): `relcount learn --preset uw --strategy hybrid --workers 4`.

pub mod parallel;
pub mod pool;
pub mod shard;

pub use parallel::{
    resolve_workers, CoordinatorConfig, CoordinatorReport, ParallelCoordinator,
};
