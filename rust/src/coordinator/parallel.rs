//! The [`ParallelCoordinator`]: a [`CountingStrategy`] whose count
//! phases run on a worker pool.
//!
//! The coordinator wraps one of the three strategy *modes* (PRECOUNT /
//! ONDEMAND / HYBRID) and re-executes their algorithms with the lattice
//! sharded across workers:
//!
//! - **positive pre-count** (PRECOUNT, HYBRID, ADAPTIVE's planned
//!   subset): one task per entity marginal and per lattice point
//!   ([`PositiveTask`]), LPT-balanced by estimated join cost;
//! - **negative pre-count** (PRECOUNT, ADAPTIVE's complete-planned
//!   subset): one Möbius Join task per listed lattice point,
//!   LPT-balanced by the lattice's per-point cost estimate
//!   ([`crate::lattice::Lattice::point_costs`]) over the frozen
//!   positive cache;
//! - **post-count** ([`CountingStrategy::ct_for_families`]): one task per
//!   family, routed by cache-key hash so each worker owns a disjoint
//!   shard of the family cache.
//!
//! ADAPTIVE's [`CountPlan`] is built inline in [`ParallelCoordinator::new`]
//! — a pure function of the database, lattice, estimator seed and
//! budget — so every worker count executes the identical plan.
//!
//! Results are merged in task order, so ct-tables, learned structures and
//! BDeu scores are **bit-identical** to the sequential strategies for
//! every worker count (`rust/tests/coordinator_parallel.rs` asserts
//! this).  Only the wall clock and the per-worker timer breakdown change.

use std::time::{Duration, Instant};

use crate::ct::cttable::CtTable;
use crate::ct::mobius::{g_subset, mobius_complete};
use crate::ct::project::project;
use crate::db::catalog::Database;
use crate::db::query::{DirectSource, JoinStats};
use crate::error::{Error, Result};
use crate::estimate::plan::CountPlan;
use crate::lattice::Lattice;
use crate::meta::rvar::RVar;
use crate::metrics::memory::MemTracker;
use crate::metrics::timing::{Deadline, Phase, PhaseTimer, WorkerTimers};
use crate::strategies::adaptive::{Adaptive, PlannedSource};
use crate::strategies::cache::{digest_caches, CacheKey, CtCache};
use crate::strategies::common::{
    narrow_to_ctx, positive_tasks, run_positive_task, var_pops, var_rels,
    LatticeCtx, PositiveTask, SharedLatticeSource, TimedSource,
};
use crate::strategies::precount::Precount;
use crate::strategies::traits::{
    CountingStrategy, FamilyRequest, StrategyConfig, StrategyReport,
};
use crate::strategies::StrategyKind;

use super::pool;
use super::shard::{lpt_partition, shard_of};

/// Configuration of a [`ParallelCoordinator`].
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Worker count; `0` resolves to [`std::thread::available_parallelism`].
    pub workers: usize,
    /// The wrapped strategy's configuration (chain length, budget, family
    /// caching), interpreted exactly as the sequential strategies do.
    pub strategy: StrategyConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 0, strategy: StrategyConfig::default() }
    }
}

/// Resolve a `--workers` value: `0` means "all available cores".
pub fn resolve_workers(n: usize) -> usize {
    if n == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        n
    }
}

/// Metrics of a coordinated run, beyond the merged [`StrategyReport`].
#[derive(Clone, Debug)]
pub struct CoordinatorReport {
    /// Worker count the run executed with.
    pub workers: usize,
    /// The merged, deterministic-order report ([`CountingStrategy::report`]
    /// returns the same object).  Its timings are wall clock, so parallel
    /// speedup is visible in Figure-3-shaped tables.
    pub merged: StrategyReport,
    /// One report per worker shard (CPU view): that worker's phase
    /// timers, query counters, fresh ct rows, serves executed, and its
    /// family-cache shard's bytes/hit statistics.
    pub per_worker: Vec<StrategyReport>,
    /// Tasks executed per worker across all phases.
    pub tasks_per_worker: Vec<u64>,
}

impl CoordinatorReport {
    /// Fold the per-worker shard reports into one CPU-time view via
    /// [`StrategyReport::merge`]: busy time sums per phase (compare with
    /// `merged.timing`, the wall clock, for parallel efficiency), and
    /// cache bytes/peaks sum because the shards are disjoint.
    pub fn cpu_view(&self) -> StrategyReport {
        let mut out = StrategyReport::default();
        for r in &self.per_worker {
            out.merge(r);
        }
        out
    }
}

/// One family count served by a worker (or inline), with its timing and
/// cost attribution — the unit merged back into the coordinator's state.
/// Shared with the delta maintenance subsystem ([`crate::delta`]), whose
/// maintained caches serve families through the same code path.
pub(crate) struct ServedFamily {
    pub(crate) ct: CtTable,
    /// Wall time inside positive-count calls (projection / joins).
    pub(crate) positive: Duration,
    /// Remaining wall time (inclusion–exclusion).
    pub(crate) negative: Duration,
    pub(crate) stats: JoinStats,
    /// Rows to add to the Table-5 `ct_rows_generated` counter (zero for
    /// PRECOUNT projections, matching the sequential strategy).
    pub(crate) fresh_rows: u64,
    /// True when served by projection from a complete lattice table
    /// (PRECOUNT's cache-hit path).
    pub(crate) projected: bool,
}

/// A work-sharded execution layer serving complete ct-tables with the
/// same interface — and bit-identical results — as the sequential
/// [`StrategyKind`] it wraps.
///
/// ```no_run
/// use relcount::coordinator::{CoordinatorConfig, ParallelCoordinator};
/// use relcount::db::fixtures::university_db;
/// use relcount::strategies::{CountingStrategy, StrategyKind};
///
/// let db = university_db();
/// let cfg = CoordinatorConfig { workers: 4, ..Default::default() };
/// let mut c = ParallelCoordinator::new(&db, StrategyKind::Hybrid, cfg).unwrap();
/// c.prepare().unwrap(); // positive pre-count on 4 workers
/// ```
pub struct ParallelCoordinator<'a> {
    db: &'a Database,
    kind: StrategyKind,
    workers: usize,
    cfg: StrategyConfig,
    ctx: LatticeCtx,
    /// ADAPTIVE's pre-counting plan (None for the fixed modes).  Built
    /// inline in `new` from seeded estimates, so it is identical across
    /// worker counts.
    plan: Option<CountPlan>,
    /// Positive lattice ct-tables + entity marginals, frozen after the
    /// positive phase; workers read it concurrently via
    /// [`SharedLatticeSource`].
    positive: CtCache,
    /// Complete per-lattice-point tables (PRECOUNT mode only).
    complete: CtCache,
    /// Per-shard family caches; a family's key routes to one shard via
    /// [`shard_of`], so shards hold disjoint key sets.
    shards: Vec<CtCache>,
    /// Wall-clock phase timer (the merged report's view).
    timer: PhaseTimer,
    /// Per-worker CPU phase timers (inline serves count toward worker 0).
    worker_timers: WorkerTimers,
    /// Per-worker query counters.
    worker_stats: Vec<JoinStats>,
    /// Per-worker fresh ct rows (Table-5 metric, attributed).
    worker_rows: Vec<u64>,
    /// Families computed per worker (cache hits are not attributed).
    worker_families: Vec<u64>,
    tasks_per_worker: Vec<u64>,
    deadline: Deadline,
    join_stats: JoinStats,
    mem: MemTracker,
    families_served: u64,
    rows_generated: u64,
    complete_hits: u64,
    prepared: bool,
}

impl<'a> ParallelCoordinator<'a> {
    /// Build the coordinator; the metadata phase (schema extraction,
    /// lattice, query plans) runs here, exactly as in the sequential
    /// strategies.
    pub fn new(
        db: &'a Database,
        kind: StrategyKind,
        cfg: CoordinatorConfig,
    ) -> Result<Self> {
        let workers = resolve_workers(cfg.workers);
        let deadline = Deadline::new(cfg.strategy.budget);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(db, cfg.strategy.max_chain_length, &mut timer)?;
        let plan = match kind {
            StrategyKind::Adaptive => Some(timer.time(Phase::Metadata, || {
                CountPlan::build(
                    db,
                    &ctx.lattice,
                    cfg.strategy.estimator,
                    cfg.strategy.mem_budget,
                )
            })?),
            _ => None,
        };
        Ok(ParallelCoordinator {
            db,
            kind,
            workers,
            cfg: cfg.strategy,
            ctx,
            plan,
            positive: CtCache::new(),
            complete: CtCache::new(),
            shards: (0..workers).map(|_| CtCache::new()).collect(),
            timer,
            worker_timers: WorkerTimers::new(workers),
            worker_stats: vec![JoinStats::default(); workers],
            worker_rows: vec![0; workers],
            worker_families: vec![0; workers],
            tasks_per_worker: vec![0; workers],
            deadline,
            join_stats: JoinStats::default(),
            mem: MemTracker::default(),
            families_served: 0,
            rows_generated: 0,
            complete_hits: 0,
            prepared: false,
        })
    }

    /// Resolved worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The wrapped strategy mode.
    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    /// Full coordinated-run metrics (the merged report plus the
    /// per-worker breakdown).
    pub fn coordinator_report(&self) -> CoordinatorReport {
        CoordinatorReport {
            workers: self.workers,
            merged: self.report(),
            per_worker: self.per_worker_reports(),
            tasks_per_worker: self.tasks_per_worker.clone(),
        }
    }

    /// One [`StrategyReport`] per worker shard: the worker's CPU phase
    /// timers and attributed counters, plus its family-cache shard's
    /// bytes and hit statistics.  Fold with
    /// [`CoordinatorReport::cpu_view`] / [`StrategyReport::merge`].
    pub fn per_worker_reports(&self) -> Vec<StrategyReport> {
        (0..self.workers)
            .map(|w| StrategyReport {
                name: format!("{}/w{w}", self.kind.name()),
                timing: self
                    .worker_timers
                    .workers
                    .get(w)
                    .copied()
                    .unwrap_or_default(),
                join_stats: self.worker_stats[w],
                cache_bytes: self.shards[w].bytes(),
                peak_ct_bytes: self.shards[w].mem.peak_bytes,
                ct_rows_generated: self.worker_rows[w],
                families_served: self.worker_families[w],
                cache_hits: self.shards[w].hits,
                cache_misses: self.shards[w].misses,
                ..Default::default()
            })
            .collect()
    }

    /// Positive pre-count, sharded: one task per entity marginal and per
    /// lattice point, LPT-balanced by estimated query cost (entity rows,
    /// or the product of the chain's relationship table sizes).  The
    /// task list is the full lattice for PRECOUNT/HYBRID and the planned
    /// subset for ADAPTIVE.
    fn fill_positive_parallel(&mut self, tasks: Vec<PositiveTask>) -> Result<()> {
        let costs: Vec<u64> = tasks
            .iter()
            .map(|t| match *t {
                PositiveTask::Entity(et) => self.db.entities[et].len() as u64,
                PositiveTask::Point(id) => self.ctx.lattice.points[id]
                    .rels
                    .iter()
                    .map(|&r| self.db.rels[r].len() as u64)
                    .fold(1u64, |a, b| a.saturating_mul(b.max(1))),
            })
            .collect();
        let assignment = lpt_partition(&costs, self.workers);

        let db = self.db;
        let ctx = &self.ctx;
        let deadline = self.deadline;
        let run = pool::run_shards(&tasks, &assignment, |_, &task| {
            deadline.check(match task {
                PositiveTask::Entity(_) => "positive ct (entity)",
                PositiveTask::Point(_) => "positive ct (lattice)",
            })?;
            let mut stats = JoinStats::default();
            let (key, table) = run_positive_task(db, ctx, task, &mut stats)?;
            Ok((key, table, stats))
        });

        self.timer.add(Phase::Positive, run.wall);
        let worker_of = worker_of_task(tasks.len(), &assignment);
        for (w, d) in run.busy.iter().enumerate() {
            self.worker_timers.add(w, Phase::Positive, *d);
            self.tasks_per_worker[w] += run.tasks_run[w];
        }
        // Merge in task order: identical cache content (and byte/row
        // accounting) to the sequential fill_positive_cache.
        for (i, r) in run.results.into_iter().enumerate() {
            let (key, table, stats) = r?;
            self.worker_stats[worker_of[i]].merge(&stats);
            self.join_stats.merge(&stats);
            self.positive.insert(key, table);
        }
        Ok(())
    }

    /// Negative pre-count (PRECOUNT and ADAPTIVE's complete-planned
    /// subset), cost-sharded: one Möbius Join per listed lattice point
    /// over the frozen positive cache.  ADAPTIVE workers read through a
    /// [`PlannedSource`], so subsets missing from the plan fall back to
    /// fresh joins (whose stats are attributed per worker).
    fn fill_complete_parallel(&mut self, ids: Vec<usize>) -> Result<()> {
        let costs: Vec<u64> = {
            let all = self.ctx.lattice.point_costs();
            ids.iter().map(|&id| all[id]).collect()
        };
        let assignment = lpt_partition(&costs, self.workers);

        let db = self.db;
        let lattice = &self.ctx.lattice;
        let positive = &self.positive;
        let plan = self.plan.as_ref();
        let deadline = self.deadline;
        let run = pool::run_shards(&ids, &assignment, |_, &id| {
            deadline.check("negative ct (lattice)")?;
            let p = &lattice.points[id];
            let vars = p.all_vars();
            let mut stats = JoinStats::default();
            let ct = match plan {
                None => {
                    let mut src = SharedLatticeSource { db, lattice, cache: positive };
                    mobius_complete(&mut src, &vars, &p.pops)?
                }
                Some(plan) => {
                    let mut src = PlannedSource {
                        db,
                        lattice,
                        plan,
                        cache: positive,
                        stats: JoinStats::default(),
                    };
                    let ct = mobius_complete(&mut src, &vars, &p.pops)?;
                    stats = src.stats;
                    ct
                }
            };
            Ok((Precount::complete_key(p), ct, stats))
        });

        self.timer.add(Phase::Negative, run.wall);
        let worker_of = worker_of_task(ids.len(), &assignment);
        for (w, d) in run.busy.iter().enumerate() {
            self.worker_timers.add(w, Phase::Negative, *d);
            self.tasks_per_worker[w] += run.tasks_run[w];
        }
        for (i, r) in run.results.into_iter().enumerate() {
            let (key, table, stats) = r?;
            self.worker_stats[worker_of[i]].merge(&stats);
            self.join_stats.merge(&stats);
            self.worker_rows[worker_of[i]] += table.n_rows() as u64;
            self.rows_generated += table.n_rows() as u64;
            self.complete.insert(key, table);
        }
        Ok(())
    }

    /// Serve one family inline on the calling thread (the sequential
    /// path of `ct_for_family`); attributed to worker 0.
    fn serve_inline(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        let served = serve_one(
            self.db,
            &self.ctx.lattice,
            &self.positive,
            &self.complete,
            self.kind,
            self.plan.as_ref(),
            vars,
            ctx_pops,
        )?;
        self.merge_served(&served, 0, true);
        self.tasks_per_worker[0] += 1;
        Ok(served.ct)
    }

    /// True when ADAPTIVE serves this family by projection from a
    /// complete-planned lattice table — the sequential strategy bypasses
    /// the family cache on that path, so the coordinator must too.
    ///
    /// Must mirror the routing in `serve_one`'s `Adaptive` arm exactly:
    /// this gate decides cache bypass, that arm decides the serve path,
    /// and a divergence would cache projected serves (or vice versa).
    fn adaptive_complete_shortcut(&self, vars: &[RVar]) -> bool {
        let Some(plan) = self.plan.as_ref() else {
            return false;
        };
        let rels = var_rels(vars);
        if rels.is_empty() {
            return false;
        }
        let vpops = var_pops(&self.db.schema, vars);
        self.ctx
            .lattice
            .covering_point(&rels, &vpops)
            .map(|p| plan.complete_planned(p.id))
            .unwrap_or(false)
    }

    /// Fold one served family's metrics into the coordinator state,
    /// attributing its CPU to `worker`.  `count_wall` is set for inline
    /// serves, whose durations are also the wall clock; the batch path
    /// attributes wall time from the pool run instead.
    fn merge_served(&mut self, s: &ServedFamily, worker: usize, count_wall: bool) {
        if count_wall {
            self.timer.add(Phase::Positive, s.positive);
            self.timer.add(Phase::Negative, s.negative);
        }
        self.worker_timers.add(worker, Phase::Positive, s.positive);
        self.worker_timers.add(worker, Phase::Negative, s.negative);
        self.worker_stats[worker].merge(&s.stats);
        self.worker_rows[worker] += s.fresh_rows;
        self.worker_families[worker] += 1;
        self.join_stats.merge(&s.stats);
        self.rows_generated += s.fresh_rows;
        self.complete_hits += s.projected as u64;
        self.mem.observe_transient(s.ct.bytes());
    }

    /// Whether serve results are memoized in the per-shard family caches
    /// (PRECOUNT projects from its complete tables instead, matching the
    /// sequential strategy).
    fn uses_family_cache(&self) -> bool {
        self.cfg.family_cache && self.kind != StrategyKind::Precount
    }
}

/// Invert a shard assignment: for each task index, the worker that ran it.
fn worker_of_task(n_tasks: usize, assignment: &[Vec<usize>]) -> Vec<usize> {
    let mut of = vec![0usize; n_tasks];
    for (w, list) in assignment.iter().enumerate() {
        for &i in list {
            of[i] = w;
        }
    }
    of
}

/// Compute one family's complete ct-table in `kind`'s serving mode, from
/// shared read-only state.  This is the worker-side function: it is the
/// single code path for both the inline (sequential) and the sharded
/// (parallel) serve, which is what makes worker counts interchangeable.
/// `plan` is `Some` exactly for ADAPTIVE.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_one(
    db: &Database,
    lattice: &Lattice,
    positive: &CtCache,
    complete: &CtCache,
    kind: StrategyKind,
    plan: Option<&CountPlan>,
    vars: &[RVar],
    ctx_pops: &[usize],
) -> Result<ServedFamily> {
    let t0 = Instant::now();
    match kind {
        // Fresh joins + family Möbius (Algorithm 2).
        StrategyKind::OnDemand => {
            let mut direct = DirectSource::new(db);
            let (ct, positive) = {
                let mut timed = TimedSource::new(&mut direct);
                let ct = mobius_complete(&mut timed, vars, ctx_pops)?;
                (ct, timed.positive_elapsed)
            };
            Ok(ServedFamily {
                fresh_rows: ct.n_rows() as u64,
                negative: t0.elapsed().saturating_sub(positive),
                positive,
                stats: direct.stats,
                projected: false,
                ct,
            })
        }
        // Planned projections with fresh-join fallback + family Möbius,
        // or a complete-table projection when the covering point is
        // complete-planned (the ADAPTIVE spectrum).
        StrategyKind::Adaptive => {
            let plan = plan.expect("adaptive serve needs its plan");
            let rels = var_rels(vars);
            if !rels.is_empty() {
                let vpops = var_pops(&db.schema, vars);
                if let Some(p) = lattice.covering_point(&rels, &vpops) {
                    if plan.complete_planned(p.id) {
                        let full =
                            complete.peek(&Precount::complete_key(p)).ok_or_else(|| {
                                Error::Strategy("complete ct missing (prepare?)".into())
                            })?;
                        let mut ct = project(full, vars)?;
                        narrow_to_ctx(db, &mut ct, &p.pops, ctx_pops, vars)?;
                        return Ok(ServedFamily {
                            positive: t0.elapsed(),
                            negative: Duration::ZERO,
                            stats: JoinStats::default(),
                            fresh_rows: 0,
                            projected: true,
                            ct,
                        });
                    }
                }
            }
            let mut src = PlannedSource {
                db,
                lattice,
                plan,
                cache: positive,
                stats: JoinStats::default(),
            };
            let (ct, positive) = {
                let mut timed = TimedSource::new(&mut src);
                let ct = mobius_complete(&mut timed, vars, ctx_pops)?;
                (ct, timed.positive_elapsed)
            };
            Ok(ServedFamily {
                fresh_rows: ct.n_rows() as u64,
                negative: t0.elapsed().saturating_sub(positive),
                positive,
                stats: src.stats,
                projected: false,
                ct,
            })
        }
        // Projections from the positive cache + family Möbius (Alg. 3).
        StrategyKind::Hybrid => {
            let mut src = SharedLatticeSource { db, lattice, cache: positive };
            let (ct, positive) = {
                let mut timed = TimedSource::new(&mut src);
                let ct = mobius_complete(&mut timed, vars, ctx_pops)?;
                (ct, timed.positive_elapsed)
            };
            Ok(ServedFamily {
                fresh_rows: ct.n_rows() as u64,
                negative: t0.elapsed().saturating_sub(positive),
                positive,
                stats: JoinStats::default(),
                projected: false,
                ct,
            })
        }
        // Projection from the complete tables (Algorithm 1), with
        // PRECOUNT's two special cases kept intact.
        StrategyKind::Precount => {
            let rels = var_rels(vars);
            let vpops = var_pops(&db.schema, vars);
            if rels.is_empty() {
                // Attribute-only family: cross product of marginals.
                let mut src = SharedLatticeSource { db, lattice, cache: positive };
                let raw = g_subset(&mut src, &[], vars, ctx_pops)?;
                let ct = project(&raw, vars)?;
                return Ok(ServedFamily {
                    positive: t0.elapsed(),
                    negative: Duration::ZERO,
                    stats: JoinStats::default(),
                    fresh_rows: 0,
                    projected: false,
                    ct,
                });
            }
            let Some(p) = lattice.covering_point(&rels, &vpops) else {
                // Disconnected relationship set: family-level Möbius over
                // the positive cache (the HYBRID move; see precount.rs).
                let mut src = SharedLatticeSource { db, lattice, cache: positive };
                let ct = mobius_complete(&mut src, vars, ctx_pops)?;
                return Ok(ServedFamily {
                    positive: Duration::ZERO,
                    negative: t0.elapsed(),
                    stats: JoinStats::default(),
                    fresh_rows: ct.n_rows() as u64,
                    projected: false,
                    ct,
                });
            };
            let full = complete
                .peek(&Precount::complete_key(p))
                .ok_or_else(|| Error::Strategy("complete ct missing (prepare?)".into()))?;
            let mut ct = project(full, vars)?;
            narrow_to_ctx(db, &mut ct, &p.pops, ctx_pops, vars)?;
            Ok(ServedFamily {
                positive: t0.elapsed(),
                negative: Duration::ZERO,
                stats: JoinStats::default(),
                fresh_rows: 0,
                projected: true,
                ct,
            })
        }
    }
}

impl CountingStrategy for ParallelCoordinator<'_> {
    fn name(&self) -> &'static str {
        self.kind.name()
    }

    /// Run the wrapped mode's pre-count phases on the worker pool:
    /// positive fill for PRECOUNT/HYBRID (and ADAPTIVE's planned
    /// subset), plus the per-point Möbius for PRECOUNT (and ADAPTIVE's
    /// complete-planned subset).  ONDEMAND has no pre-phase.
    fn prepare(&mut self) -> Result<()> {
        if self.prepared {
            return Ok(());
        }
        match self.kind {
            StrategyKind::Precount | StrategyKind::Hybrid => {
                self.fill_positive_parallel(positive_tasks(self.db, &self.ctx))?;
            }
            StrategyKind::Adaptive => {
                let plan = self.plan.as_ref().expect("adaptive has a plan");
                let tasks = Adaptive::planned_positive_tasks(self.db, plan);
                if !tasks.is_empty() {
                    self.fill_positive_parallel(tasks)?;
                }
            }
            StrategyKind::OnDemand => {}
        }
        match self.kind {
            StrategyKind::Precount => {
                let ids: Vec<usize> = (0..self.ctx.lattice.points.len()).collect();
                self.fill_complete_parallel(ids)?;
            }
            StrategyKind::Adaptive => {
                let plan = self.plan.as_ref().expect("adaptive has a plan");
                let ids = Adaptive::planned_complete_points(plan);
                if !ids.is_empty() {
                    self.fill_complete_parallel(ids)?;
                }
            }
            _ => {}
        }
        self.prepared = true;
        Ok(())
    }

    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        if !self.prepared {
            self.prepare()?;
        }
        self.deadline.check("family count (coordinator)")?;
        self.families_served += 1;
        if !self.uses_family_cache() || self.adaptive_complete_shortcut(vars) {
            return self.serve_inline(vars, ctx_pops);
        }
        let key = CtCache::key(vars, ctx_pops);
        let shard = shard_of(&key, self.workers);
        if let Some(hit) = self.shards[shard].get(&key) {
            return Ok(hit.clone());
        }
        let ct = self.serve_inline(vars, ctx_pops)?;
        self.shards[shard].insert(key, ct.clone());
        Ok(ct)
    }

    /// The parallel post-count: cache hits are served inline, then the
    /// distinct misses fan out across workers (routed by cache-key hash,
    /// so each worker fills its own shard of the family cache) and merge
    /// back in request order.
    fn ct_for_families(&mut self, reqs: &[FamilyRequest]) -> Result<Vec<CtTable>> {
        if self.workers <= 1 || reqs.len() <= 1 {
            // Sequential fallback — identical to the default trait body.
            return reqs.iter().map(|r| self.ct_for_family(&r.vars, &r.ctx_pops)).collect();
        }
        if !self.prepared {
            self.prepare()?;
        }
        self.deadline.check("family batch (coordinator)")?;

        let use_cache = self.uses_family_cache();
        let mut out: Vec<Option<CtTable>> = vec![None; reqs.len()];
        // Distinct misses, preserving first-seen order; duplicates within
        // the batch reuse the first computation.
        let mut miss_keys: Vec<CacheKey> = Vec::new();
        let mut miss_req: Vec<usize> = Vec::new();
        let mut dups: Vec<(usize, usize)> = Vec::new(); // (req idx, miss idx)
        for (i, r) in reqs.iter().enumerate() {
            self.families_served += 1;
            self.deadline.check("family count (coordinator)")?;
            let key = CtCache::key(&r.vars, &r.ctx_pops);
            // Complete-planned ADAPTIVE families bypass the family cache
            // (served by projection), mirroring the sequential strategy.
            let cached = use_cache && !self.adaptive_complete_shortcut(&r.vars);
            if cached {
                let shard = shard_of(&key, self.workers);
                if let Some(hit) = self.shards[shard].get(&key) {
                    out[i] = Some(hit.clone());
                    continue;
                }
            }
            match miss_keys.iter().position(|k| *k == key) {
                Some(j) => {
                    if cached {
                        // Sequentially this lookup would land after the
                        // first copy's insert and hit; reclassify the
                        // miss just recorded so hit/miss statistics stay
                        // identical across worker counts.
                        let shard = shard_of(&key, self.workers);
                        self.shards[shard].misses -= 1;
                        self.shards[shard].hits += 1;
                    }
                    dups.push((i, j));
                }
                None => {
                    miss_keys.push(key);
                    miss_req.push(i);
                }
            }
        }

        if !miss_req.is_empty() {
            // Shard assignment: each miss goes to the worker owning its
            // cache key, so shards stay disjoint.
            let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); self.workers];
            let mut worker_of = vec![0usize; miss_keys.len()];
            for (j, key) in miss_keys.iter().enumerate() {
                let w = shard_of(key, self.workers);
                worker_of[j] = w;
                assignment[w].push(j);
            }
            let tasks: Vec<&FamilyRequest> =
                miss_req.iter().map(|&i| &reqs[i]).collect();

            let db = self.db;
            let lattice = &self.ctx.lattice;
            let positive = &self.positive;
            let complete = &self.complete;
            let kind = self.kind;
            let plan = self.plan.as_ref();
            let deadline = self.deadline;
            let run = pool::run_shards(&tasks, &assignment, |_, r| {
                deadline.check("family count (coordinator)")?;
                serve_one(db, lattice, positive, complete, kind, plan, &r.vars, &r.ctx_pops)
            });

            // Wall-clock attribution: the pool's wall time, split across
            // phases proportionally to the served families' CPU mix.
            let mut served: Vec<ServedFamily> = Vec::with_capacity(run.results.len());
            for r in run.results {
                served.push(r?);
            }
            for (w, &n) in run.tasks_run.iter().enumerate() {
                self.tasks_per_worker[w] += n;
            }
            let cpu_pos: Duration = served.iter().map(|s| s.positive).sum();
            let cpu_neg: Duration = served.iter().map(|s| s.negative).sum();
            let cpu = cpu_pos + cpu_neg;
            let wall_pos = if cpu.is_zero() {
                Duration::ZERO
            } else {
                run.wall.mul_f64(cpu_pos.as_secs_f64() / cpu.as_secs_f64())
            };
            self.timer.add(Phase::Positive, wall_pos);
            self.timer.add(Phase::Negative, run.wall.saturating_sub(wall_pos));

            // Merge in miss order (deterministic across worker counts).
            for (j, s) in served.into_iter().enumerate() {
                self.merge_served(&s, worker_of[j], false);
                if use_cache && !s.projected {
                    let key = miss_keys[j].clone();
                    self.shards[worker_of[j]].insert(key, s.ct.clone());
                }
                out[miss_req[j]] = Some(s.ct);
            }
        }

        for (i, j) in dups {
            out[i] = Some(
                out[miss_req[j]].clone().expect("duplicate resolved before its source"),
            );
        }
        Ok(out
            .into_iter()
            .map(|t| t.expect("every request served or failed loudly"))
            .collect())
    }

    /// The merged, deterministic-order report.  Timings are wall clock
    /// (speedup shows up here); the CPU view per worker is in
    /// [`ParallelCoordinator::coordinator_report`].
    fn report(&self) -> StrategyReport {
        let mut peak = self.mem;
        peak.merge_peak(&self.positive.mem);
        let shard_bytes: usize = self.shards.iter().map(|s| s.bytes()).sum();
        let shard_peak: usize = self.shards.iter().map(|s| s.mem.peak_bytes).sum();
        peak.peak_bytes = peak.peak_bytes.max(
            self.positive.mem.current_bytes + self.complete.mem.peak_bytes + shard_peak,
        );
        let (hits, misses) = match self.kind {
            StrategyKind::Precount => {
                (self.complete_hits, self.complete.misses)
            }
            // ADAPTIVE counts both family-cache hits and complete-table
            // projections, mirroring the sequential strategy's report.
            StrategyKind::Adaptive => (
                self.shards.iter().map(|s| s.hits).sum::<u64>() + self.complete_hits,
                self.shards.iter().map(|s| s.misses).sum(),
            ),
            _ => (
                self.shards.iter().map(|s| s.hits).sum(),
                self.shards.iter().map(|s| s.misses).sum(),
            ),
        };
        StrategyReport {
            name: self.kind.name().into(),
            timing: self.timer,
            join_stats: self.join_stats,
            cache_bytes: self.positive.bytes() + self.complete.bytes() + shard_bytes,
            peak_ct_bytes: peak.peak_bytes,
            ct_rows_generated: self.rows_generated,
            families_served: self.families_served,
            cache_hits: hits,
            cache_misses: misses,
            planned_positive: self
                .plan
                .as_ref()
                .map(|p| p.planned_positive_count())
                .unwrap_or(0),
            planned_complete: self
                .plan
                .as_ref()
                .map(|p| p.planned_complete_count())
                .unwrap_or(0),
            plan_est_bytes: self.plan.as_ref().map(|p| p.est_spent_bytes).unwrap_or(0),
            estimator_walks: self.plan.as_ref().map(|p| p.walks).unwrap_or(0),
        }
    }

    /// Digest over the shared lattice caches plus the union of the
    /// per-worker family shards — `digest_caches` sorts entries
    /// globally by (tag, key), so the result is independent of the
    /// worker count (shards hold disjoint keys) and equal to the
    /// sequential strategy's digest over the same content.
    fn cache_digest(&self) -> u64 {
        let mut tagged: Vec<(u8, &CtCache)> =
            vec![(0, &self.positive), (1, &self.complete)];
        tagged.extend(self.shards.iter().map(|s| (2u8, s)));
        digest_caches(&tagged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    fn coordinator(
        db: &Database,
        kind: StrategyKind,
        workers: usize,
    ) -> ParallelCoordinator<'_> {
        let cfg = CoordinatorConfig { workers, ..Default::default() };
        ParallelCoordinator::new(db, kind, cfg).unwrap()
    }

    #[test]
    fn matches_brute_force_for_all_modes() {
        let db = university_db();
        for kind in StrategyKind::ALL {
            for workers in [1usize, 3] {
                let mut c = coordinator(&db, kind, workers);
                c.prepare().unwrap();
                let ct = c.ct_for_family(&family(), &[0, 1]).unwrap();
                let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
                assert_eq!(ct.n_rows(), brute.n_rows(), "{kind:?} w={workers}");
                for (v, n) in brute.iter_rows() {
                    assert_eq!(ct.get(&v).unwrap(), n, "{kind:?} w={workers} {v:?}");
                }
            }
        }
    }

    #[test]
    fn batch_equals_singles() {
        let db = university_db();
        let reqs = vec![
            FamilyRequest::new(&family(), &[0, 1]),
            FamilyRequest::new(
                &[RVar::RelInd { rel: 1 }, RVar::EntityAttr { et: 2, attr: 0 }],
                &[1, 2],
            ),
            FamilyRequest::new(&family(), &[0, 1]), // duplicate in-batch
        ];
        let mut par = coordinator(&db, StrategyKind::Hybrid, 4);
        let batch = par.ct_for_families(&reqs).unwrap();
        let mut seq = coordinator(&db, StrategyKind::Hybrid, 1);
        for (r, b) in reqs.iter().zip(&batch) {
            let one = seq.ct_for_family(&r.vars, &r.ctx_pops).unwrap();
            assert_eq!(one.n_rows(), b.n_rows());
            for (v, n) in one.iter_rows() {
                assert_eq!(b.get(&v).unwrap(), n);
            }
        }
        assert_eq!(par.report().families_served, 3);
    }

    #[test]
    fn hybrid_family_cache_hits_on_revisit() {
        let db = university_db();
        let mut c = coordinator(&db, StrategyKind::Hybrid, 2);
        c.ct_for_family(&family(), &[0, 1]).unwrap();
        c.ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(c.report().cache_hits, 1);
        assert_eq!(c.report().families_served, 2);
    }

    #[test]
    fn no_joins_during_hybrid_serving() {
        let db = university_db();
        let mut c = coordinator(&db, StrategyKind::Hybrid, 2);
        c.prepare().unwrap();
        let joins = c.report().join_stats.chain_queries;
        assert!(joins > 0, "positive phase JOINs");
        c.ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(c.report().join_stats.chain_queries, joins);
    }

    #[test]
    fn adaptive_budgets_match_brute_force_across_workers() {
        let db = university_db();
        let hb = Adaptive::new(&db, StrategyConfig::default())
            .unwrap()
            .plan()
            .hybrid_budget();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        for budget in [Some(0u64), Some(hb), None] {
            for workers in [1usize, 3] {
                let cfg = CoordinatorConfig {
                    workers,
                    strategy: StrategyConfig { mem_budget: budget, ..Default::default() },
                };
                let mut c =
                    ParallelCoordinator::new(&db, StrategyKind::Adaptive, cfg).unwrap();
                c.prepare().unwrap();
                let ct = c.ct_for_family(&family(), &[0, 1]).unwrap();
                assert_eq!(ct.n_rows(), brute.n_rows(), "{budget:?} w={workers}");
                for (v, n) in brute.iter_rows() {
                    assert_eq!(ct.get(&v).unwrap(), n, "{budget:?} w={workers} {v:?}");
                }
                // the shared plan surfaces in the merged report
                let rep = c.report();
                match budget {
                    Some(0) => assert_eq!(rep.planned_positive, 0),
                    Some(_) => {
                        assert!(rep.planned_positive > 0);
                        assert_eq!(rep.planned_complete, 0);
                    }
                    None => assert!(rep.planned_complete > 0),
                }
            }
        }
    }

    #[test]
    fn budget_zero_times_out() {
        let db = university_db();
        let cfg = CoordinatorConfig {
            workers: 2,
            strategy: StrategyConfig {
                budget: Some(Duration::ZERO),
                ..Default::default()
            },
        };
        let mut c =
            ParallelCoordinator::new(&db, StrategyKind::Precount, cfg).unwrap();
        assert!(c.prepare().unwrap_err().is_timeout());
    }

    #[test]
    fn coordinator_report_shapes() {
        let db = university_db();
        let mut c = coordinator(&db, StrategyKind::Precount, 3);
        c.prepare().unwrap();
        c.ct_for_family(&family(), &[0, 1]).unwrap();
        let rep = c.coordinator_report();
        assert_eq!(rep.workers, 3);
        assert_eq!(rep.per_worker.len(), 3);
        assert_eq!(rep.tasks_per_worker.len(), 3);
        assert!(rep.tasks_per_worker.iter().sum::<u64>() > 0);
        assert!(rep.merged.timing.total() > Duration::ZERO);
        assert_eq!(rep.merged.cache_hits, 1); // served by projection
        let cpu = rep.cpu_view();
        assert!(cpu.timing.positive + cpu.timing.negative > Duration::ZERO);
        // the inline serve is attributed to worker 0
        assert_eq!(rep.per_worker[0].families_served, 1);
        // attributed counters fold to the merged totals
        assert_eq!(cpu.ct_rows_generated, rep.merged.ct_rows_generated);
        assert_eq!(
            cpu.join_stats.chain_queries,
            rep.merged.join_stats.chain_queries
        );
    }
}
