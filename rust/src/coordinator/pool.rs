//! The worker pool: chunked static sharding over scoped threads.
//!
//! A pool run takes an indexed task list plus a *shard assignment* (which
//! task indices each worker owns, produced by the deterministic
//! partitioners in [`crate::coordinator::shard`] and
//! [`crate::lattice::Lattice::partition_by_length`]), spawns one scoped
//! thread per non-empty shard, and streams `(task, result)` pairs back
//! over an mpsc channel.  Results are returned **in task order**, so a
//! caller that folds them left-to-right observes the same merge order no
//! matter how many workers ran or how their execution interleaved — the
//! cornerstone of the coordinator's determinism guarantee.
//!
//! With one live shard (or one task) the pool degenerates to a plain
//! sequential loop on the calling thread: a 1-worker coordinator run has
//! no threading overhead and exactly mirrors the sequential strategies.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::Result;

/// Outcome of one [`run_shards`] call.
#[derive(Debug)]
pub struct PoolRun<R> {
    /// Per-task results, indexed exactly like the caller's task list
    /// (i.e. independent of shard assignment and scheduling).
    pub results: Vec<Result<R>>,
    /// Per-worker busy time: the sum of task durations each shard ran.
    pub busy: Vec<Duration>,
    /// Per-worker executed-task counts.
    pub tasks_run: Vec<u64>,
    /// Wall-clock time of the whole parallel section.
    pub wall: Duration,
}

/// Execute `tasks` under the shard assignment `shards` (worker `w` runs
/// the task indices in `shards[w]`, in order) and gather the results in
/// task order.
///
/// `f` is called as `f(task_index, &tasks[task_index])` and must be safe
/// to call concurrently from several threads (it only gets shared
/// references).  Worker panics propagate to the caller via
/// [`std::thread::scope`].
///
/// # Invariant
///
/// Every task index in `0..tasks.len()` must appear in exactly one shard;
/// the function panics (never silently drops work) if the assignment
/// leaves a task uncovered.
pub fn run_shards<T, R, F>(tasks: &[T], shards: &[Vec<usize>], f: F) -> PoolRun<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
{
    let t0 = Instant::now();
    let n = shards.len().max(1);
    let mut busy = vec![Duration::ZERO; n];
    let mut tasks_run = vec![0u64; n];
    let mut slots: Vec<Option<Result<R>>> = Vec::with_capacity(tasks.len());
    slots.resize_with(tasks.len(), || None);

    let live = shards.iter().filter(|s| !s.is_empty()).count();
    if live <= 1 || tasks.len() <= 1 {
        // Sequential fast path: no threads, no channel.
        for (w, shard) in shards.iter().enumerate() {
            for &i in shard {
                let task_t0 = Instant::now();
                slots[i] = Some(f(i, &tasks[i]));
                busy[w] += task_t0.elapsed();
                tasks_run[w] += 1;
            }
        }
    } else {
        let (tx, rx) = mpsc::channel::<(usize, usize, Duration, Result<R>)>();
        std::thread::scope(|scope| {
            for (w, shard) in shards.iter().enumerate() {
                if shard.is_empty() {
                    continue;
                }
                let tx = tx.clone();
                let f = &f;
                scope.spawn(move || {
                    for &i in shard {
                        let task_t0 = Instant::now();
                        let r = f(i, &tasks[i]);
                        if tx.send((i, w, task_t0.elapsed(), r)).is_err() {
                            return; // receiver gone: abandon quietly
                        }
                    }
                });
            }
            drop(tx);
            for (i, w, d, r) in rx {
                slots[i] = Some(r);
                busy[w] += d;
                tasks_run[w] += 1;
            }
        });
    }

    PoolRun {
        results: slots
            .into_iter()
            .map(|s| s.expect("pool: shard assignment left a task uncovered"))
            .collect(),
        busy,
        tasks_run,
        wall: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    fn even_shards(n_tasks: usize, n_shards: usize) -> Vec<Vec<usize>> {
        let mut shards = vec![Vec::new(); n_shards];
        for i in 0..n_tasks {
            shards[i % n_shards].push(i);
        }
        shards
    }

    #[test]
    fn results_in_task_order() {
        let tasks: Vec<u64> = (0..40).collect();
        for n in [1usize, 2, 4] {
            let run = run_shards(&tasks, &even_shards(tasks.len(), n), |i, &t| {
                assert_eq!(i as u64, t);
                Ok(t * t)
            });
            let vals: Vec<u64> = run.results.into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(vals, tasks.iter().map(|t| t * t).collect::<Vec<_>>());
            assert_eq!(run.tasks_run.iter().sum::<u64>(), 40);
            assert!(run.wall > Duration::ZERO);
        }
    }

    #[test]
    fn errors_stay_on_their_task() {
        let tasks: Vec<u64> = (0..10).collect();
        let run = run_shards(&tasks, &even_shards(10, 3), |_, &t| {
            if t == 7 {
                Err(Error::Strategy("boom".into()))
            } else {
                Ok(t)
            }
        });
        for (i, r) in run.results.iter().enumerate() {
            assert_eq!(r.is_err(), i == 7, "slot {i}");
        }
    }

    #[test]
    fn empty_shards_and_empty_tasks() {
        let run = run_shards::<u64, u64, _>(&[], &[Vec::new(), Vec::new()], |_, &t| Ok(t));
        assert!(run.results.is_empty());
        let tasks = [5u64];
        let run = run_shards(&tasks, &[vec![0], Vec::new()], |_, &t| Ok(t + 1));
        assert_eq!(run.results.len(), 1);
        assert_eq!(*run.results[0].as_ref().unwrap(), 6);
        assert_eq!(run.tasks_run[0], 1);
        assert_eq!(run.tasks_run[1], 0);
    }

    #[test]
    #[should_panic(expected = "uncovered")]
    fn uncovered_task_panics() {
        let tasks = [1u64, 2];
        run_shards(&tasks, &[vec![0]], |_, &t| Ok(t));
    }
}
