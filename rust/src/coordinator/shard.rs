//! Deterministic shard assignment.
//!
//! Two flavors, matching the coordinator's two parallel workloads:
//!
//! - [`lpt_partition`] — cost-balanced chunked sharding for *known* task
//!   lists (the positive pre-count phase, where per-task cost is
//!   estimated from table sizes).  Longest-processing-time greedy:
//!   costliest task first, each to the currently lightest shard.
//! - [`shard_of`] — stable hash routing for *cache-affine* work (the
//!   per-family post-count phase): a family's cache key always routes to
//!   the same shard, so each worker owns a disjoint slice of the family
//!   cache and lookups never cross shards.
//!
//! Both are pure functions of their inputs — no randomness, no timing —
//! so a re-run with the same worker count shards identically, and the
//! merged results are bit-identical across *any* worker count (results
//! are merged in task order, see [`crate::coordinator::pool`]).

use std::hash::{Hash, Hasher};

use crate::util::fxhash::FxHasher;

/// Owning shard of a cache key: stable FxHash routing into `n_shards`
/// buckets.  FxHash is unseeded, so the route is reproducible across
/// processes and runs.
pub fn shard_of<K: Hash>(key: &K, n_shards: usize) -> usize {
    let n = n_shards.max(1);
    let mut h = FxHasher::default();
    key.hash(&mut h);
    (h.finish() % n as u64) as usize
}

/// Longest-processing-time partition of task indices `0..costs.len()`
/// into `n_shards` disjoint lists, balanced by `costs`.
///
/// Ties break toward the lower task id (for ordering) and the lower
/// shard id (for placement), making the assignment fully deterministic.
/// Within each shard, indices are returned ascending so a sequential
/// fallback walks them in task order.
pub fn lpt_partition(costs: &[u64], n_shards: usize) -> Vec<Vec<usize>> {
    let n = n_shards.max(1);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then(a.cmp(&b)));
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut loads = vec![0u64; n];
    for id in order {
        let s = (0..n).min_by_key(|&s| (loads[s], s)).unwrap();
        loads[s] = loads[s].saturating_add(costs[id].max(1));
        shards[s].push(id);
    }
    for shard in &mut shards {
        shard.sort_unstable();
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable_and_in_range() {
        let key = (vec![1usize, 2, 3], vec![0usize, 1]);
        for n in [1usize, 2, 4, 16] {
            let s = shard_of(&key, n);
            assert!(s < n);
            assert_eq!(s, shard_of(&key, n), "same key, same shard");
        }
        assert_eq!(shard_of(&key, 0), 0); // degenerate count clamps to 1
    }

    #[test]
    fn lpt_covers_and_balances() {
        let costs = vec![8u64, 1, 1, 1, 1, 8, 1, 1];
        let shards = lpt_partition(&costs, 2);
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        // each heavy task (ids 0 and 5) lands on a different shard
        let heavy_home =
            |id: usize| shards.iter().position(|s| s.contains(&id)).unwrap();
        assert_ne!(heavy_home(0), heavy_home(5));
        // deterministic
        assert_eq!(shards, lpt_partition(&costs, 2));
    }

    #[test]
    fn lpt_degenerate_shapes() {
        assert_eq!(lpt_partition(&[], 3), vec![Vec::<usize>::new(); 3]);
        let one = lpt_partition(&[5, 2, 9], 1);
        assert_eq!(one, vec![vec![0, 1, 2]]);
        // more shards than tasks: extras stay empty
        let wide = lpt_partition(&[3, 3], 4);
        assert_eq!(wide.iter().filter(|s| !s.is_empty()).count(), 2);
    }

    #[test]
    fn zero_cost_tasks_still_spread() {
        // all-zero costs must not pile every task onto shard 0
        let shards = lpt_partition(&[0, 0, 0, 0], 2);
        assert!(!shards[0].is_empty() && !shards[1].is_empty(), "{shards:?}");
    }
}
