//! Cross-product extension of ct-tables.
//!
//! When a sub-chain's positive counts must be interpreted over a larger
//! population context (a lattice point with more entity types, or a
//! disconnected relationship subset), the missing populations enter as a
//! cross product: every grounding of the sub-chain pairs with every
//! member of each unconstrained population.  With entity attributes in
//! play this is the outer product with the entity *marginal* ct-tables;
//! without them it is multiplication by the population size.

use crate::ct::cttable::CtTable;
use crate::error::{Error, Result};

/// Outer product of two ct-tables over disjoint variable lists.
pub fn outer(a: &CtTable, b: &CtTable) -> Result<CtTable> {
    for v in &b.vars {
        if a.vars.contains(v) {
            return Err(Error::Ct(format!("outer(): shared variable {v:?}")));
        }
    }
    let mut vars = a.vars.clone();
    vars.extend(b.vars.iter().copied());
    let mut dims = a.dims.clone();
    dims.extend(b.dims.iter().copied());
    let mut out = CtTable::with_dims(vars, dims)?;
    // With a's columns first, the combined key is a_key + a_cells * b_key.
    let a_cells = a.cells();
    for (bk, bc) in b.iter_keys() {
        for (ak, ac) in a.iter_keys() {
            let c = ac
                .checked_mul(bc)
                .ok_or_else(|| Error::Ct("outer() count overflow".into()))?;
            out.add_key(ak + a_cells * bk, c)?;
        }
    }
    Ok(out)
}

/// Outer-extend `t` by a scalar population factor.
pub fn extend_scalar(t: &CtTable, factor: i128) -> Result<CtTable> {
    let mut out = t.clone();
    out.scale(factor)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;
    use crate::meta::rvar::RVar;

    #[test]
    fn outer_product_counts() {
        let s = university_schema();
        let x = RVar::EntityAttr { et: 0, attr: 0 };
        let y = RVar::EntityAttr { et: 1, attr: 0 };
        let mut a = CtTable::new(&s, vec![x]).unwrap();
        a.add(&[0], 2).unwrap();
        a.add(&[2], 3).unwrap();
        let mut b = CtTable::new(&s, vec![y]).unwrap();
        b.add(&[1], 5).unwrap();
        b.add(&[2], 7).unwrap();
        let o = outer(&a, &b).unwrap();
        assert_eq!(o.vars, vec![x, y]);
        assert_eq!(o.get(&[0, 1]).unwrap(), 10);
        assert_eq!(o.get(&[2, 2]).unwrap(), 21);
        assert_eq!(
            o.total().unwrap(),
            a.total().unwrap() * b.total().unwrap()
        );
    }

    #[test]
    fn outer_with_scalar_is_scale() {
        let s = university_schema();
        let x = RVar::EntityAttr { et: 0, attr: 0 };
        let mut a = CtTable::new(&s, vec![x]).unwrap();
        a.add(&[1], 4).unwrap();
        let o = outer(&a, &CtTable::scalar(6)).unwrap();
        assert_eq!(o.get(&[1]).unwrap(), 24);
        let e = extend_scalar(&a, 6).unwrap();
        assert_eq!(e.get(&[1]).unwrap(), 24);
    }

    #[test]
    fn outer_rejects_shared_vars() {
        let s = university_schema();
        let x = RVar::EntityAttr { et: 0, attr: 0 };
        let a = CtTable::new(&s, vec![x]).unwrap();
        let b = CtTable::new(&s, vec![x]).unwrap();
        assert!(outer(&a, &b).is_err());
    }
}
