//! The sparse contingency table.
//!
//! Rows are stored in a hash map from a mixed-radix flat key (u128) to an
//! i64 count.  u128 keys keep hashing fast (no per-row allocation) while
//! supporting value spaces up to 2^127 cells — ample for lattice points
//! with dozens of attribute columns; construction fails loudly if the
//! value space would overflow.
//!
//! Counts are i128: cross products over several large populations
//! exceed i64 (e.g. 4-population contexts at IMDb scale); intermediate
//! Möbius values are differences of counts
//! and the arithmetic is checked, so overflow surfaces as an error rather
//! than silent wraparound.

use crate::util::fxhash::FxHashMap;

use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;

/// A sparse contingency table over an ordered list of variables.
#[derive(Clone, Debug)]
pub struct CtTable {
    /// Column variables, in key order.
    pub vars: Vec<RVar>,
    /// Dimension (number of value codes) per column.
    pub dims: Vec<u32>,
    /// Mixed-radix strides: `key = sum(v[i] * strides[i])`.
    strides: Vec<u128>,
    /// Flat key -> count.  Zero-count rows are not stored.
    counts: FxHashMap<u128, i128>,
}

impl CtTable {
    /// Empty table over `vars` (dims from the schema conventions).
    pub fn new(schema: &Schema, vars: Vec<RVar>) -> Result<Self> {
        let dims: Vec<u32> = vars.iter().map(|v| v.dim(schema)).collect();
        Self::with_dims(vars, dims)
    }

    /// Empty table with explicit dims (used by tests and dense packing).
    pub fn with_dims(vars: Vec<RVar>, dims: Vec<u32>) -> Result<Self> {
        if vars.len() != dims.len() {
            return Err(Error::Ct("vars/dims length mismatch".into()));
        }
        let mut strides = Vec::with_capacity(dims.len());
        let mut acc: u128 = 1;
        for (i, &d) in dims.iter().enumerate() {
            if d == 0 {
                return Err(Error::Ct(format!("column {i} has dimension 0")));
            }
            strides.push(acc);
            acc = acc.checked_mul(d as u128).ok_or_else(|| {
                Error::Ct("value space overflows u128 flat keys".into())
            })?;
        }
        Ok(CtTable { vars, dims, strides, counts: FxHashMap::default() })
    }

    /// A 0-column table holding a single scalar count (the ct-table of an
    /// empty variable list — used for cross-product seeds).
    pub fn scalar(count: i128) -> Self {
        let mut t = CtTable {
            vars: Vec::new(),
            dims: Vec::new(),
            strides: Vec::new(),
            counts: FxHashMap::default(),
        };
        if count != 0 {
            t.counts.insert(0, count);
        }
        t
    }

    /// Total number of cells in the (dense) value space.
    pub fn cells(&self) -> u128 {
        self.dims.iter().map(|&d| d as u128).product()
    }

    /// Number of stored (nonzero) rows — the paper's ct-table size metric.
    pub fn n_rows(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Sum of all counts (checked).
    pub fn total(&self) -> Result<i128> {
        let mut acc: i128 = 0;
        for &c in self.counts.values() {
            acc = acc
                .checked_add(c)
                .ok_or_else(|| Error::Ct("total() overflow".into()))?;
        }
        Ok(acc)
    }

    /// Encode a value tuple into a flat key.
    #[inline]
    pub fn encode(&self, values: &[u32]) -> Result<u128> {
        if values.len() != self.dims.len() {
            return Err(Error::Ct(format!(
                "key arity {} != {}",
                values.len(),
                self.dims.len()
            )));
        }
        let mut key: u128 = 0;
        for ((&v, &d), &s) in values.iter().zip(&self.dims).zip(&self.strides) {
            if v >= d {
                return Err(Error::Ct(format!("value {v} out of range 0..{d}")));
            }
            key += v as u128 * s;
        }
        Ok(key)
    }

    /// Decode a flat key into a value tuple.
    pub fn decode(&self, key: u128) -> Vec<u32> {
        self.dims
            .iter()
            .zip(&self.strides)
            .map(|(&d, &s)| ((key / s) % d as u128) as u32)
            .collect()
    }

    /// Add `count` to a row (removing it if it reaches zero).
    pub fn add(&mut self, values: &[u32], count: i128) -> Result<()> {
        let key = self.encode(values)?;
        self.add_key(key, count)
    }

    /// Add by pre-encoded key (hot path).
    #[inline]
    pub fn add_key(&mut self, key: u128, count: i128) -> Result<()> {
        if count == 0 {
            return Ok(());
        }
        let slot = self.counts.entry(key).or_insert(0);
        *slot = slot
            .checked_add(count)
            .ok_or_else(|| Error::Ct("count overflow".into()))?;
        if *slot == 0 {
            self.counts.remove(&key);
        }
        Ok(())
    }

    /// Merge a delta table cell-wise (checked arithmetic; rows reaching
    /// zero are dropped, so repeated insert/delete churn never leaves
    /// tombstones).  Both tables must be over identical columns.
    pub fn add_table(&mut self, delta: &CtTable) -> Result<()> {
        if self.vars != delta.vars || self.dims != delta.dims {
            return Err(Error::Ct(format!(
                "add_table: column mismatch ({:?} vs {:?})",
                self.vars, delta.vars
            )));
        }
        for (k, c) in delta.iter_keys() {
            self.add_key(k, c)?;
        }
        Ok(())
    }

    /// Count for a value tuple (0 if absent).
    pub fn get(&self, values: &[u32]) -> Result<i128> {
        Ok(self.counts.get(&self.encode(values)?).copied().unwrap_or(0))
    }

    #[inline]
    pub fn get_key(&self, key: u128) -> i128 {
        self.counts.get(&key).copied().unwrap_or(0)
    }

    /// Iterate rows as (flat key, count).
    pub fn iter_keys(&self) -> impl Iterator<Item = (u128, i128)> + '_ {
        self.counts.iter().map(|(&k, &c)| (k, c))
    }

    /// Iterate rows as (decoded values, count).
    pub fn iter_rows(&self) -> impl Iterator<Item = (Vec<u32>, i128)> + '_ {
        self.counts.iter().map(|(&k, &c)| (self.decode(k), c))
    }

    /// Position of a variable in the column list.
    pub fn var_pos(&self, var: &RVar) -> Result<usize> {
        self.vars
            .iter()
            .position(|v| v == var)
            .ok_or_else(|| Error::Ct(format!("variable {var:?} not in table")))
    }

    /// Stride of column `i` (used by projection / dense packing).
    #[inline]
    pub fn stride(&self, i: usize) -> u128 {
        self.strides[i]
    }

    /// Multiply every count by a scalar (checked).
    pub fn scale(&mut self, factor: i128) -> Result<()> {
        if factor == 0 {
            self.counts.clear();
            return Ok(());
        }
        for c in self.counts.values_mut() {
            *c = c
                .checked_mul(factor)
                .ok_or_else(|| Error::Ct("scale overflow".into()))?;
        }
        Ok(())
    }

    /// Divide every count exactly by `factor` (used to narrow a wider
    /// population context; counts are exact multiples by construction).
    pub fn divide_exact(&mut self, factor: i128) -> Result<()> {
        if factor <= 0 {
            return Err(Error::Ct(format!("divide_exact by {factor}")));
        }
        if factor == 1 {
            return Ok(());
        }
        for (k, c) in self.counts.iter_mut() {
            if *c % factor != 0 {
                return Err(Error::Ct(format!(
                    "count {c} at key {k} not divisible by {factor}"
                )));
            }
            *c /= factor;
        }
        Ok(())
    }

    /// Verify all counts are strictly positive (complete ct-tables of
    /// real databases must be — a negative count means a Möbius bug).
    pub fn assert_counts_nonnegative(&self) -> Result<()> {
        for (&k, &c) in &self.counts {
            if c < 0 {
                return Err(Error::Ct(format!(
                    "negative count {c} at {:?}",
                    self.decode(k)
                )));
            }
        }
        Ok(())
    }

    /// Approximate heap footprint in bytes (the Figure 4 metric).
    pub fn bytes(&self) -> usize {
        // key (16) + count (16) + hashbrown ctrl/overhead
        48 + self.vars.capacity() * std::mem::size_of::<RVar>()
            + self.dims.capacity() * 4
            + self.strides.capacity() * 16
            + self.counts.capacity() * 40
    }

    /// Deterministic content digest: variables, dims, and rows hashed in
    /// sorted key order — identical tables hash identically regardless of
    /// insertion order or hash-map layout.  The serving protocol stamps
    /// this onto every count response so clients (and the CI smoke) can
    /// compare answers across runs and worker counts without shipping
    /// full tables.
    pub fn digest(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = crate::util::fxhash::FxHasher::default();
        self.vars.hash(&mut h);
        self.dims.hash(&mut h);
        let mut rows: Vec<(u128, i128)> = self.iter_keys().collect();
        rows.sort_unstable();
        for (k, c) in rows {
            k.hash(&mut h);
            c.hash(&mut h);
        }
        h.finish()
    }

    /// Render as an aligned text table (quickstart / debugging).
    pub fn render(&self, schema: &Schema) -> String {
        let mut out = String::new();
        let names: Vec<String> = self.vars.iter().map(|v| v.name(schema)).collect();
        out.push_str(&format!("count\t{}\n", names.join("\t")));
        let mut rows: Vec<(Vec<u32>, i128)> = self.iter_rows().collect();
        rows.sort();
        for (vals, c) in rows {
            let vs: Vec<String> = vals.iter().map(|v| v.to_string()).collect();
            out.push_str(&format!("{c}\t{}\n", vs.join("\t")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;

    fn table() -> CtTable {
        let s = university_schema();
        CtTable::new(
            &s,
            vec![
                RVar::RelInd { rel: 0 },
                RVar::RelAttr { rel: 0, attr: 1 },
                RVar::EntityAttr { et: 1, attr: 0 },
            ],
        )
        .unwrap()
    }

    #[test]
    fn dims_follow_schema() {
        let t = table();
        assert_eq!(t.dims, vec![2, 4, 3]);
        assert_eq!(t.cells(), 24);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = table();
        for ind in 0..2 {
            for sal in 0..4 {
                for intel in 0..3 {
                    let vals = vec![ind, sal, intel];
                    let k = t.encode(&vals).unwrap();
                    assert_eq!(t.decode(k), vals);
                }
            }
        }
    }

    #[test]
    fn add_get_remove() {
        let mut t = table();
        t.add(&[1, 2, 0], 5).unwrap();
        t.add(&[1, 2, 0], 3).unwrap();
        assert_eq!(t.get(&[1, 2, 0]).unwrap(), 8);
        assert_eq!(t.get(&[0, 0, 0]).unwrap(), 0);
        t.add(&[1, 2, 0], -8).unwrap();
        assert_eq!(t.n_rows(), 0); // zero rows dropped
    }

    #[test]
    fn out_of_range_rejected() {
        let mut t = table();
        assert!(t.add(&[2, 0, 0], 1).is_err());
        assert!(t.add(&[0, 0], 1).is_err());
    }

    #[test]
    fn totals_and_scale() {
        let mut t = table();
        t.add(&[0, 0, 0], 10).unwrap();
        t.add(&[1, 3, 2], 7).unwrap();
        assert_eq!(t.total().unwrap(), 17);
        t.scale(3).unwrap();
        assert_eq!(t.total().unwrap(), 51);
        t.scale(0).unwrap();
        assert_eq!(t.n_rows(), 0);
    }

    #[test]
    fn add_table_merges_and_compacts() {
        let mut a = table();
        a.add(&[0, 0, 0], 5).unwrap();
        a.add(&[1, 1, 1], 2).unwrap();
        let mut d = table();
        d.add(&[0, 0, 0], -5).unwrap(); // cancels to zero -> row dropped
        d.add(&[1, 1, 1], 3).unwrap();
        d.add(&[1, 2, 2], 7).unwrap();
        a.add_table(&d).unwrap();
        assert_eq!(a.n_rows(), 2);
        assert_eq!(a.get(&[1, 1, 1]).unwrap(), 5);
        assert_eq!(a.get(&[1, 2, 2]).unwrap(), 7);
        // column mismatch rejected
        let other = CtTable::with_dims(vec![RVar::RelInd { rel: 1 }], vec![2]).unwrap();
        assert!(a.add_table(&other).is_err());
    }

    #[test]
    fn scalar_table() {
        let t = CtTable::scalar(42);
        assert_eq!(t.n_rows(), 1);
        assert_eq!(t.total().unwrap(), 42);
        assert_eq!(t.cells(), 1);
    }

    #[test]
    fn negative_detection() {
        let mut t = table();
        t.add(&[0, 0, 0], -1).unwrap();
        assert!(t.assert_counts_nonnegative().is_err());
    }

    #[test]
    fn digest_is_insertion_order_independent() {
        let mut a = table();
        a.add(&[0, 0, 0], 5).unwrap();
        a.add(&[1, 1, 1], 2).unwrap();
        let mut b = table();
        b.add(&[1, 1, 1], 2).unwrap();
        b.add(&[0, 0, 0], 5).unwrap();
        assert_eq!(a.digest(), b.digest());
        b.add(&[1, 2, 2], 1).unwrap();
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn overflow_value_space_rejected() {
        // 40 columns of dim 2^32-1 overflows u128
        let vars = vec![RVar::RelInd { rel: 0 }; 40];
        let dims = vec![u32::MAX; 40];
        assert!(CtTable::with_dims(vars, dims).is_err());
    }
}
