//! Dense padded family tensors — the interchange layout shared with the
//! Pallas kernels (see `python/compile/kernels/ref.py` for the full
//! convention).
//!
//! A family's relationship axes are packed into `k_rel` padded axes of
//! size `d_pad`; coordinate 0 is the ⊥ slot (indicator F / attrs N/A) and
//! coordinates `1..` enumerate the *true* states (the cartesian product
//! of the rel's attribute values present in the family).  All entity
//! attributes flatten into a trailing axis padded to `e_pad`.  Zero
//! padding is neutral for the Möbius butterfly (proved in
//! `python/tests/test_mobius.py` and re-checked here).
//!
//! Families whose axes exceed the padded dims simply don't get a layout
//! ([`DenseLayout::fits`] returns `None`) and take the exact sparse path.

use crate::ct::cttable::CtTable;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;

/// Default padded dims — must match `python/compile/kernels/{mobius,bdeu}.py`
/// (the runtime re-reads the authoritative values from the manifest).
pub const D_PAD: usize = 8;
pub const K_REL: usize = 3;
pub const E_PAD: usize = 1024;
pub const Q_PAD: usize = 256;
pub const R_PAD: usize = 16;
pub const B_PAD: usize = 64;

/// How a family's variables map onto the dense tensor.
#[derive(Clone, Debug)]
pub struct DenseLayout {
    /// The variable order this layout was built for.
    pub vars: Vec<RVar>,
    /// Relationship ids, one per used rel axis (sorted).
    pub rels: Vec<usize>,
    /// Per rel axis: positions (into `vars`) of the indicator column, if
    /// present.
    pub ind_col: Vec<Option<usize>>,
    /// Per rel axis: positions of the rel-attr columns (with their dims).
    pub attr_cols: Vec<Vec<(usize, u32)>>,
    /// Positions of entity-attr columns (with their dims).
    pub ent_cols: Vec<(usize, u32)>,
    /// Padded dims.
    pub d_pad: usize,
    pub k_rel: usize,
    pub e_pad: usize,
}

impl DenseLayout {
    /// Build a layout for `vars` if the family fits the padded dims.
    pub fn fits(
        schema: &Schema,
        vars: &[RVar],
        d_pad: usize,
        k_rel: usize,
        e_pad: usize,
    ) -> Option<DenseLayout> {
        let mut rels: Vec<usize> = vars.iter().filter_map(|v| v.rel()).collect();
        rels.sort_unstable();
        rels.dedup();
        if rels.len() > k_rel {
            return None;
        }
        let mut ind_col = Vec::new();
        let mut attr_cols = Vec::new();
        for &rel in &rels {
            let ind =
                vars.iter().position(|v| matches!(v, RVar::RelInd { rel: r } if *r == rel));
            let attrs: Vec<(usize, u32)> = vars
                .iter()
                .enumerate()
                .filter(|(_, v)| {
                    matches!(v, RVar::RelAttr { rel: r, .. } if *r == rel)
                })
                .map(|(i, v)| (i, v.dim(schema) - 1)) // true-value count
                .collect();
            // slot dim = 1 (⊥) + product of true-value counts
            let truth_states: u64 =
                attrs.iter().map(|&(_, c)| c as u64).product::<u64>().max(1);
            if 1 + truth_states > d_pad as u64 {
                return None;
            }
            ind_col.push(ind);
            attr_cols.push(attrs);
        }
        let ent_cols: Vec<(usize, u32)> = vars
            .iter()
            .enumerate()
            .filter(|(_, v)| matches!(v, RVar::EntityAttr { .. }))
            .map(|(i, v)| (i, v.dim(schema)))
            .collect();
        let e_size: u64 = ent_cols.iter().map(|&(_, d)| d as u64).product();
        if e_size > e_pad as u64 {
            return None;
        }
        Some(DenseLayout {
            vars: vars.to_vec(),
            rels,
            ind_col,
            attr_cols,
            ent_cols,
            d_pad,
            k_rel,
            e_pad,
        })
    }

    /// Total dense tensor length.
    pub fn len(&self) -> usize {
        self.d_pad.pow(self.k_rel as u32) * self.e_pad
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slot coordinate of rel axis `i` for a sparse row, or `None` if the
    /// row is in an invalid mixed state (e.g. indicator F with a real
    /// attribute value), which cannot occur in well-formed tables.
    fn slot_of(&self, i: usize, row: &[u32]) -> Option<usize> {
        let ind_true = self.ind_col[i].map(|c| row[c] == 1);
        let attrs = &self.attr_cols[i];
        let any_attr_real = attrs.iter().any(|&(c, _)| row[c] != 0);
        let all_attr_real = attrs.iter().all(|&(c, _)| row[c] != 0);
        match (ind_true, any_attr_real, all_attr_real) {
            // ⊥: indicator F (or absent) and all attrs N/A
            (Some(false) | None, false, _) => Some(0),
            // true state: indicator T (or absent) and all attrs real
            (Some(true), _, true) => Some(1 + self.flat_attrs(i, row)),
            (None, true, true) => Some(1 + self.flat_attrs(i, row)),
            _ => None,
        }
    }

    fn flat_attrs(&self, i: usize, row: &[u32]) -> usize {
        let mut flat = 0usize;
        let mut stride = 1usize;
        for &(c, card) in &self.attr_cols[i] {
            flat += (row[c] as usize - 1) * stride;
            stride *= card as usize;
        }
        flat
    }

    /// Inverse of `slot_of`: write the rel-axis state into a row.
    fn write_slot(&self, i: usize, slot: usize, row: &mut [u32]) {
        if slot == 0 {
            if let Some(c) = self.ind_col[i] {
                row[c] = 0;
            }
            for &(c, _) in &self.attr_cols[i] {
                row[c] = 0;
            }
        } else {
            if let Some(c) = self.ind_col[i] {
                row[c] = 1;
            }
            let mut rest = slot - 1;
            for &(c, card) in &self.attr_cols[i] {
                row[c] = (rest % card as usize) as u32 + 1;
                rest /= card as usize;
            }
        }
    }

    /// Number of valid slots on rel axis `i` (1 + true states).
    fn slot_dim(&self, i: usize) -> usize {
        1 + self
            .attr_cols[i]
            .iter()
            .map(|&(_, c)| c as usize)
            .product::<usize>()
            .max(1)
    }

    /// Entity flat coordinate of a sparse row.
    fn e_of(&self, row: &[u32]) -> usize {
        let mut flat = 0usize;
        let mut stride = 1usize;
        for &(c, d) in &self.ent_cols {
            flat += row[c] as usize * stride;
            stride *= d as usize;
        }
        flat
    }

    fn write_e(&self, e: usize, row: &mut [u32]) {
        let mut rest = e;
        for &(c, d) in &self.ent_cols {
            row[c] = (rest % d as usize) as u32;
            rest /= d as usize;
        }
    }

    /// Dense linear index from rel slots + entity coordinate.  The layout
    /// is row-major `[D_1, ..., D_k, E]` (C order), matching jnp.
    fn dense_index(&self, slots: &[usize], e: usize) -> usize {
        let mut idx = 0usize;
        for i in 0..self.k_rel {
            let s = if i < slots.len() { slots[i] } else { 0 };
            idx = idx * self.d_pad + s;
        }
        idx * self.e_pad + e
    }

    /// Pack a sparse table (in this layout's variable order) into a dense
    /// f64 tensor.
    pub fn pack(&self, t: &CtTable) -> Result<Vec<f64>> {
        if t.vars != self.vars {
            return Err(Error::Ct("pack(): variable order mismatch".into()));
        }
        let mut dense = vec![0f64; self.len()];
        let k = self.rels.len();
        let mut slots = vec![0usize; k];
        for (key, count) in t.iter_keys() {
            let row = t.decode(key);
            for i in 0..k {
                slots[i] = self.slot_of(i, &row).ok_or_else(|| {
                    Error::Ct(format!("invalid mixed state in row {row:?}"))
                })?;
            }
            let e = self.e_of(&row);
            dense[self.dense_index(&slots, e)] += count as f64;
        }
        Ok(dense)
    }

    /// Unpack a dense tensor into a sparse table over this layout's vars.
    /// Cells outside the valid (unpadded) region must be zero.
    pub fn unpack(&self, schema: &Schema, dense: &[f64]) -> Result<CtTable> {
        if dense.len() != self.len() {
            return Err(Error::Ct("unpack(): length mismatch".into()));
        }
        let mut out = CtTable::new(schema, self.vars.clone())?;
        let k = self.rels.len();
        let e_size: usize =
            self.ent_cols.iter().map(|&(_, d)| d as usize).product::<usize>().max(1);
        let mut row = vec![0u32; self.vars.len()];
        let mut slots = vec![0usize; k.max(1)];
        // iterate only the valid region
        let mut total_valid = e_size;
        for i in 0..k {
            total_valid *= self.slot_dim(i);
        }
        for flat in 0..total_valid {
            let mut rest = flat;
            let e = rest % e_size;
            rest /= e_size;
            for i in 0..k {
                slots[i] = rest % self.slot_dim(i);
                rest /= self.slot_dim(i);
            }
            let v = dense[self.dense_index(&slots[..k], e)];
            if v == 0.0 {
                continue;
            }
            if v.fract() != 0.0 || v.abs() > 9.007_199_254_740_992e15 {
                return Err(Error::Ct(format!("non-integral dense count {v}")));
            }
            for i in 0..k {
                self.write_slot(i, slots[i], &mut row);
            }
            self.write_e(e, &mut row);
            out.add(&row, v as i128)?;
        }
        Ok(out)
    }

    /// Segment map for the fused `family_score` artifact: dense cell ->
    /// `q * r_pad + r` slot of the (parent-config, child-value) matrix,
    /// or `q_pad * r_pad` (the dump slot) for padding cells.
    ///
    /// `parent_cols`/`child_col` index into `self.vars`; q is the mixed-
    /// radix index over the parents' *full ct dims* (N/A included), as
    /// used by the Rust scorer.
    pub fn seg_map(
        &self,
        schema: &Schema,
        parent_cols: &[usize],
        child_col: usize,
        q_pad: usize,
        r_pad: usize,
    ) -> Result<Vec<i32>> {
        let dims: Vec<u32> = self.vars.iter().map(|v| v.dim(schema)).collect();
        let q: usize = parent_cols.iter().map(|&c| dims[c] as usize).product();
        let r = dims[child_col] as usize;
        if q > q_pad || r > r_pad {
            return Err(Error::Ct(format!(
                "family q={q} r={r} exceeds padded ({q_pad},{r_pad})"
            )));
        }
        let dump = (q_pad * r_pad) as i32;
        let mut seg = vec![dump; self.len()];
        let k = self.rels.len();
        let e_size: usize =
            self.ent_cols.iter().map(|&(_, d)| d as usize).product::<usize>().max(1);
        let mut row = vec![0u32; self.vars.len()];
        let mut slots = vec![0usize; k.max(1)];
        let mut total_valid = e_size;
        for i in 0..k {
            total_valid *= self.slot_dim(i);
        }
        for flat in 0..total_valid {
            let mut rest = flat;
            let e = rest % e_size;
            rest /= e_size;
            for i in 0..k {
                slots[i] = rest % self.slot_dim(i);
                rest /= self.slot_dim(i);
            }
            for i in 0..k {
                self.write_slot(i, slots[i], &mut row);
            }
            self.write_e(e, &mut row);
            let mut qi = 0usize;
            for &c in parent_cols {
                qi = qi * dims[c] as usize + row[c] as usize;
            }
            let ri = row[child_col] as usize;
            seg[self.dense_index(&slots[..k], e)] = (qi * r_pad + ri) as i32;
        }
        Ok(seg)
    }
}

/// Pure-Rust dense Möbius butterfly over `[d; k] + [e]` (row-major) —
/// the fallback/ablation twin of the Pallas kernel.
pub fn mobius_dense(t: &mut [f64], d: usize, k: usize, e: usize) {
    assert_eq!(t.len(), d.pow(k as u32) * e);
    for axis in 0..k {
        // outer = product of dims before `axis`; inner = after (incl. e)
        let outer = d.pow(axis as u32);
        let inner = d.pow((k - axis - 1) as u32) * e;
        for o in 0..outer {
            let base = o * d * inner;
            for v in 1..d {
                let (bot, rest) = t.split_at_mut(base + v * inner);
                let bot = &mut bot[base..base + inner];
                let tru = &rest[..inner];
                for j in 0..inner {
                    bot[j] -= tru[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::{brute_force_complete, mobius_complete};
    use crate::db::fixtures::university_db;
    use crate::db::query::DirectSource;

    fn family_vars() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    #[test]
    fn layout_fits_and_sizes() {
        let db = university_db();
        let l = DenseLayout::fits(&db.schema, &family_vars(), D_PAD, K_REL, E_PAD)
            .expect("fits");
        assert_eq!(l.rels, vec![0]);
        assert_eq!(l.slot_dim(0), 4); // ⊥ + 3 salary values
        assert_eq!(l.len(), D_PAD.pow(3) * E_PAD);
    }

    #[test]
    fn too_big_family_rejected() {
        let db = university_db();
        // capability (5) x salary (3) -> 15 true states + ⊥ > 8
        let vars = vec![
            RVar::RelAttr { rel: 0, attr: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
        ];
        assert!(DenseLayout::fits(&db.schema, &vars, 8, 3, 64).is_none());
        assert!(DenseLayout::fits(&db.schema, &vars, 32, 3, 64).is_some());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let db = university_db();
        let ct = brute_force_complete(&db, &family_vars(), &[0, 1]).unwrap();
        let l = DenseLayout::fits(&db.schema, &family_vars(), D_PAD, K_REL, E_PAD)
            .unwrap();
        let dense = l.pack(&ct).unwrap();
        let back = l.unpack(&db.schema, &dense).unwrap();
        assert_eq!(back.n_rows(), ct.n_rows());
        for (vals, c) in ct.iter_rows() {
            assert_eq!(back.get(&vals).unwrap(), c);
        }
    }

    #[test]
    fn dense_butterfly_matches_sparse_mobius() {
        let db = university_db();
        let vars = family_vars();
        let l = DenseLayout::fits(&db.schema, &vars, D_PAD, K_REL, 64).unwrap();

        // Build the *unconstrained* g table sparsely via the same scatter
        // the sparse Möbius uses, then compare butterfly outputs.
        let mut src = DirectSource::new(&db);
        let complete_sparse = mobius_complete(&mut src, &vars, &[0, 1]).unwrap();

        // dense path: pack g by inverting the butterfly on the complete
        // table (zeta transform), then re-apply the dense butterfly.
        let mut dense = l.pack(&complete_sparse).unwrap();
        // zeta transform: bot += sum(true)
        let k = l.rels.len();
        for axis in 0..k {
            let outer = l.d_pad.pow(axis as u32);
            let inner = l.d_pad.pow((K_REL - axis - 1) as u32) * l.e_pad;
            for o in 0..outer {
                let base = o * l.d_pad * inner;
                for v in 1..l.d_pad {
                    for j in 0..inner {
                        let add = dense[base + v * inner + j];
                        dense[base + j] += add;
                    }
                }
            }
        }
        mobius_dense(&mut dense, l.d_pad, K_REL, l.e_pad);
        let back = l.unpack(&db.schema, &dense).unwrap();
        for (vals, c) in complete_sparse.iter_rows() {
            assert_eq!(back.get(&vals).unwrap(), c, "at {vals:?}");
        }
        assert_eq!(back.n_rows(), complete_sparse.n_rows());
    }

    #[test]
    fn seg_map_covers_family_cells() {
        let db = university_db();
        let vars = family_vars();
        let l = DenseLayout::fits(&db.schema, &vars, D_PAD, K_REL, E_PAD).unwrap();
        // parents = [RA, salary], child = intelligence
        let seg = l.seg_map(&db.schema, &[0, 1], 2, Q_PAD, R_PAD).unwrap();
        assert_eq!(seg.len(), l.len());
        let dump = (Q_PAD * R_PAD) as i32;
        let n_valid = seg.iter().filter(|&&s| s != dump).count();
        // valid cells = slot_dim(rel0) * e_size = 4 * 3
        assert_eq!(n_valid, 12);
        for &s in &seg {
            assert!(s <= dump);
        }
    }
}
