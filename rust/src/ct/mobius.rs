//! The Möbius Join: extending positive ct-tables to complete ct-tables.
//!
//! Given positive counts (existing relationships only) for every subset
//! of a pattern's relationship set, inclusion–exclusion yields exact
//! counts for every true/false indicator combination — *without touching
//! the original data again* (Qian, Schulte & Sun 2014).  This solves the
//! paper's negation problem.
//!
//! The implementation operates on sparse [`CtTable`]s in *combined-axis*
//! coordinates: for relationship `i`, its "axis" is the group of columns
//! belonging to it (its indicator, its attributes, or both).  The ⊥ state
//! of an axis is (indicator = F, attributes = N/A); every other occupied
//! state is a "true" state.  The transform per axis subtracts each true
//! state's count from its ⊥ projection — the same butterfly the Pallas
//! kernel `python/compile/kernels/mobius.py` performs on the dense padded
//! layout ([`crate::ct::dense`] converts between the two).
//!
//! Positive counts are obtained through the [`ChainSource`] trait, which
//! is where the three strategies differ: ONDEMAND joins tables afresh on
//! every call, while PRECOUNT/HYBRID project from the lattice cache.

use crate::ct::cross::outer;
use crate::ct::cttable::CtTable;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;

/// Provider of positive counts — the strategy-dependent half of the
/// Möbius Join.
pub trait ChainSource {
    /// Positive ct-table for a **connected, non-empty** relationship
    /// chain, over exactly `vars` (entity attributes of the chain's
    /// populations and/or rel attributes of the chain's rels, in ct-table
    /// coordinates).  Counts range over the chain's own populations.
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable>;

    /// Marginal ct-table of one entity type over `vars` (its attribute
    /// variables); counts range over that entity's population.
    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable>;

    fn schema(&self) -> &Schema;

    /// Population size of an entity type.
    fn population(&self, et: usize) -> i128;
}

/// Positive counts for an arbitrary (possibly disconnected, possibly
/// empty) relationship subset `t_rels`, over the attribute variables
/// `attr_vars` (no indicators), extended to the population context
/// `ctx_pops` by cross products.
pub fn g_subset(
    source: &mut dyn ChainSource,
    t_rels: &[usize],
    attr_vars: &[RVar],
    ctx_pops: &[usize],
) -> Result<CtTable> {
    g_subset_inner(source, |_, _| None, t_rels, attr_vars, ctx_pops)
}

/// [`g_subset`] with a per-component override: `delta_for` may supply a
/// component's positive table (the delta-Möbius feeds the *delta* of the
/// one component touched by a tuple change; every other factor of the
/// cross product is a current value read from `source`).
fn g_subset_inner(
    source: &mut dyn ChainSource,
    mut delta_for: impl FnMut(&[usize], &[RVar]) -> Option<Result<CtTable>>,
    t_rels: &[usize],
    attr_vars: &[RVar],
    ctx_pops: &[usize],
) -> Result<CtTable> {
    let schema = source.schema().clone();
    // Split into connected components; each is a joinable chain.
    let comps = schema.connected_components(t_rels);
    let mut covered_pops: Vec<usize> = Vec::new();
    let mut acc = CtTable::scalar(1);
    for comp in &comps {
        let comp_pops = schema.populations_of(comp);
        let vars_c: Vec<RVar> = attr_vars
            .iter()
            .copied()
            .filter(|v| match v {
                RVar::EntityAttr { et, .. } => comp_pops.contains(et),
                RVar::RelAttr { rel, .. } => comp.contains(rel),
                RVar::RelInd { .. } => false,
            })
            .collect();
        let ct_c = match delta_for(comp, &vars_c) {
            Some(delta) => delta?,
            None => source.positive_chain_ct(comp, &vars_c)?,
        };
        acc = outer(&acc, &ct_c)?;
        covered_pops.extend(comp_pops);
    }
    covered_pops.sort_unstable();
    covered_pops.dedup();
    for &et in &covered_pops {
        if !ctx_pops.contains(&et) {
            return Err(Error::Ct(format!(
                "subset populations {covered_pops:?} exceed context {ctx_pops:?}"
            )));
        }
    }
    // Unconstrained populations: outer with marginals (if attrs requested)
    // or scalar population factors.
    for &et in ctx_pops {
        if covered_pops.contains(&et) {
            continue;
        }
        let vars_e: Vec<RVar> = attr_vars
            .iter()
            .copied()
            .filter(|v| matches!(v, RVar::EntityAttr { et: e, .. } if *e == et))
            .collect();
        if vars_e.is_empty() {
            acc.scale(source.population(et))?;
        } else {
            let marg = source.entity_marginal(et, &vars_e)?;
            acc = outer(&acc, &marg)?;
        }
    }
    Ok(acc)
}

/// The Möbius Join: complete ct-table over `vars` (any mix of entity
/// attributes, rel attributes and rel indicators) with grounding
/// population `ctx_pops`.
///
/// `ctx_pops` must contain every population touched by `vars`.
pub fn mobius_complete(
    source: &mut dyn ChainSource,
    vars: &[RVar],
    ctx_pops: &[usize],
) -> Result<CtTable> {
    let schema = source.schema().clone();
    for v in vars {
        for p in v.populations(&schema) {
            if !ctx_pops.contains(&p) {
                return Err(Error::Ct(format!(
                    "variable {v:?} population {p} outside context {ctx_pops:?}"
                )));
            }
        }
    }
    // Relationship axes.
    let mut rels: Vec<usize> = vars.iter().filter_map(|v| v.rel()).collect();
    rels.sort_unstable();
    rels.dedup();
    let k = rels.len();
    if k > 30 {
        return Err(Error::Ct(format!("{k} relationship axes is unsupported")));
    }

    let attr_vars: Vec<RVar> =
        vars.iter().copied().filter(|v| !v.is_indicator()).collect();

    let mut g = CtTable::new(&schema, vars.to_vec())?;

    // --- Stage 1: scatter every subset's positive counts into g. -------
    for mask in 0..(1u32 << k) {
        let t_rels: Vec<usize> = (0..k)
            .filter(|&i| mask & (1 << i) != 0)
            .map(|i| rels[i])
            .collect();
        let sub_attr_vars = subset_attr_vars(&attr_vars, &t_rels);
        let gt = g_subset(source, &t_rels, &sub_attr_vars, ctx_pops)?;
        scatter_subset(&mut g, &gt, &t_rels, vars)?;
    }

    // --- Stage 2: the butterfly, one pass per relationship axis. -------
    butterfly(&mut g, vars, &rels)?;

    g.assert_counts_nonnegative()?;
    Ok(g)
}

/// Attribute variables visible on subset `t_rels`: entity attributes
/// always, rel attributes only for rels in the subset (absent rels are
/// pinned to N/A by the scatter).
fn subset_attr_vars(attr_vars: &[RVar], t_rels: &[usize]) -> Vec<RVar> {
    attr_vars
        .iter()
        .copied()
        .filter(|v| match v.rel() {
            Some(r) => t_rels.contains(&r),
            None => true,
        })
        .collect()
}

/// Scatter a subset's positive table `gt` into `g`'s key space
/// arithmetically: a constant offset for the fixed columns (indicators =
/// T for rels in the subset, F otherwise; N/A for absent rel attrs) plus
/// one (src stride, src dim, dst stride) digit move per copied column.
fn scatter_subset(
    g: &mut CtTable,
    gt: &CtTable,
    t_rels: &[usize],
    vars: &[RVar],
) -> Result<()> {
    let mut base: u128 = 0;
    let mut maps: Vec<(u128, u128, u128)> = Vec::new();
    for (j, v) in vars.iter().enumerate() {
        let dst = g.stride(j);
        match v {
            RVar::RelInd { rel } => {
                if t_rels.contains(rel) {
                    base += dst;
                }
            }
            RVar::RelAttr { rel, .. } if !t_rels.contains(rel) => {} // N/A = 0
            _ => {
                let sp = gt
                    .vars
                    .iter()
                    .position(|w| w == v)
                    .expect("attr present in subset table");
                maps.push((gt.stride(sp), gt.dims[sp] as u128, dst));
            }
        }
    }
    for (gk, count) in gt.iter_keys() {
        let mut key = base;
        for &(ss, sd, ds) in &maps {
            key += ((gk / ss) % sd) * ds;
        }
        g.add_key(key, count)?;
    }
    Ok(())
}

/// The inclusion–exclusion butterfly: for each relationship axis, every
/// row in a true state of the axis (any of the rel's columns nonzero)
/// subtracts its count from its ⊥ projection.  The ⊥ key is computed
/// arithmetically by zeroing the axis digits — no per-row decode or
/// allocation (this is the ct- hot loop).  The transform is linear in
/// the stored rows, so it applies unchanged to sparse *delta* tables
/// ([`mobius_delta`]), where it touches only the delta's rows.
fn butterfly(g: &mut CtTable, vars: &[RVar], rels: &[usize]) -> Result<()> {
    for &rel in rels {
        let axis: Vec<(u128, u128)> = vars
            .iter()
            .enumerate()
            .filter(|(_, v)| v.rel() == Some(rel))
            .map(|(i, _)| (g.stride(i), g.dims[i] as u128))
            .collect();
        let mut updates: Vec<(u128, i128)> = Vec::new();
        for (key, count) in g.iter_keys() {
            let mut bot = key;
            for &(s, d) in &axis {
                let v = (key / s) % d;
                bot -= v * s;
            }
            if bot != key {
                updates.push((bot, -count));
            }
        }
        for (k, delta) in updates {
            g.add_key(k, delta)?;
        }
    }
    Ok(())
}

/// Delta-Möbius: the change of [`mobius_complete`]`(source, vars,
/// ctx_pops)` caused by a single-tuple change of relationship
/// `touched_rel`, given `delta_positive(chain, chain_vars)` = the
/// positive-count delta of each chain containing the changed tuple (the
/// join rows through that one tuple, signed by the caller).
///
/// Only subsets containing `touched_rel` contribute — every other
/// subset's positives are unchanged — and within such a subset the
/// cross product is `Δ(A × B) = ΔA × B`: the component containing the
/// touched relationship comes from `delta_positive`, every other factor
/// (components, marginals, population scalars) is a *current* value read
/// from `source`.  The scatter and butterfly then run over the sparse
/// delta rows only, which is what makes per-tuple cache maintenance
/// cheap (re-deriving only the affected cells instead of re-running the
/// full butterfly).
///
/// Populations must be unchanged by the tuple change (link churn only;
/// entity inserts are handled separately — see
/// [`crate::delta`]).  The result is a signed delta table: add it to the
/// cached complete table with [`CtTable::add_table`].  Negative interim
/// counts are expected and NOT rejected here; the maintained table is
/// verified non-negative after application.
pub fn mobius_delta(
    source: &mut dyn ChainSource,
    delta_positive: &mut dyn FnMut(&[usize], &[RVar]) -> Result<CtTable>,
    touched_rel: usize,
    vars: &[RVar],
    ctx_pops: &[usize],
) -> Result<CtTable> {
    let schema = source.schema().clone();
    for v in vars {
        for p in v.populations(&schema) {
            if !ctx_pops.contains(&p) {
                return Err(Error::Ct(format!(
                    "variable {v:?} population {p} outside context {ctx_pops:?}"
                )));
            }
        }
    }
    let mut rels: Vec<usize> = vars.iter().filter_map(|v| v.rel()).collect();
    rels.sort_unstable();
    rels.dedup();
    let k = rels.len();
    if k > 30 {
        return Err(Error::Ct(format!("{k} relationship axes is unsupported")));
    }
    let attr_vars: Vec<RVar> =
        vars.iter().copied().filter(|v| !v.is_indicator()).collect();

    let mut g = CtTable::new(&schema, vars.to_vec())?;
    if !rels.contains(&touched_rel) {
        return Ok(g); // the family does not involve the touched rel
    }

    for mask in 0..(1u32 << k) {
        let t_rels: Vec<usize> = (0..k)
            .filter(|&i| mask & (1 << i) != 0)
            .map(|i| rels[i])
            .collect();
        if !t_rels.contains(&touched_rel) {
            continue; // subset positives unchanged -> zero delta
        }
        let sub_attr_vars = subset_attr_vars(&attr_vars, &t_rels);
        let gt = g_subset_inner(
            source,
            |comp, vars_c| {
                comp.contains(&touched_rel).then(|| delta_positive(comp, vars_c))
            },
            &t_rels,
            &sub_attr_vars,
            ctx_pops,
        )?;
        scatter_subset(&mut g, &gt, &t_rels, vars)?;
    }

    butterfly(&mut g, vars, &rels)?;
    Ok(g)
}

/// Ground-truth oracle: enumerate every grounding of `ctx_pops` and
/// evaluate all variables directly against the database.  Exponential in
/// the number of populations — for tests on small databases only.
pub fn brute_force_complete(
    db: &crate::db::catalog::Database,
    vars: &[RVar],
    ctx_pops: &[usize],
) -> Result<CtTable> {
    let schema = &db.schema;
    for v in vars {
        for p in v.populations(schema) {
            if !ctx_pops.contains(&p) {
                return Err(Error::Ct(format!(
                    "variable {v:?} population {p} outside context {ctx_pops:?}"
                )));
            }
        }
    }
    let mut out = CtTable::new(schema, vars.to_vec())?;
    // binding[i] = entity id for ctx_pops[i]
    let sizes: Vec<u32> = ctx_pops.iter().map(|&et| db.entities[et].len()).collect();
    if sizes.iter().any(|&n| n == 0) {
        return Ok(out);
    }
    let pos_of = |et: usize| ctx_pops.iter().position(|&p| p == et).unwrap();
    let mut binding = vec![0u32; ctx_pops.len()];
    loop {
        // evaluate row
        let mut vals = Vec::with_capacity(vars.len());
        for v in vars {
            let val = match *v {
                RVar::EntityAttr { et, attr } => {
                    db.entities[et].value(attr, binding[pos_of(et)])
                }
                RVar::RelInd { rel } => {
                    let (a, b) = schema.rel_endpoints(rel);
                    let ix = db.index(rel)?;
                    ix.lookup(binding[pos_of(a)], binding[pos_of(b)])
                        .map(|_| 1)
                        .unwrap_or(0)
                }
                RVar::RelAttr { rel, attr } => {
                    let (a, b) = schema.rel_endpoints(rel);
                    let ix = db.index(rel)?;
                    match ix.lookup(binding[pos_of(a)], binding[pos_of(b)]) {
                        Some(t) => db.rels[rel].value(attr, t) + 1, // ct coords
                        None => 0,                                  // N/A
                    }
                }
            };
            vals.push(val);
        }
        out.add(&vals, 1)?;
        // next binding
        let mut i = 0;
        loop {
            if i == binding.len() {
                return Ok(out);
            }
            binding[i] += 1;
            if binding[i] < sizes[i] {
                break;
            }
            binding[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::catalog::Database;
    use crate::db::fixtures::university_db;
    use crate::db::query::DirectSource;

    fn family_vars() -> Vec<RVar> {
        vec![
            RVar::RelAttr { rel: 0, attr: 0 }, // capability
            RVar::RelInd { rel: 0 },           // RA
            RVar::RelAttr { rel: 0, attr: 1 }, // salary
        ]
    }

    #[test]
    fn reproduces_paper_table3() {
        let db = university_db();
        let mut src = DirectSource::new(&db);
        let ct = mobius_complete(&mut src, &family_vars(), &[0, 1]).unwrap();
        // The N/A row: 203 pairs with RA = F.
        assert_eq!(ct.get(&[0, 0, 0]).unwrap(), 203);
        // Spot checks against Table 3 (capability raw v -> code v+1... the
        // fixture stores paper capability value c as raw c-1, ct code c).
        assert_eq!(ct.get(&[4, 1, 3]).unwrap(), 5); // Capa=4, T, HIGH
        assert_eq!(ct.get(&[5, 1, 3]).unwrap(), 4); // Capa=5, T, HIGH
        assert_eq!(ct.get(&[1, 1, 2]).unwrap(), 3); // Capa=1, T, MED
        assert_eq!(ct.total().unwrap(), 228);
    }

    #[test]
    fn matches_brute_force_university() {
        let db = university_db();
        let mut src = DirectSource::new(&db);
        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelInd { rel: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelAttr { rel: 1, attr: 0 },
        ];
        let ctx = vec![0, 1, 2];
        let fast = mobius_complete(&mut src, &vars, &ctx).unwrap();
        let brute = brute_force_complete(&db, &vars, &ctx).unwrap();
        assert_eq!(fast.n_rows(), brute.n_rows());
        for (vals, c) in brute.iter_rows() {
            assert_eq!(fast.get(&vals).unwrap(), c, "at {vals:?}");
        }
    }

    #[test]
    fn total_is_population_product() {
        let db = university_db();
        let mut src = DirectSource::new(&db);
        let ct = mobius_complete(
            &mut src,
            &[RVar::RelInd { rel: 1 }, RVar::EntityAttr { et: 2, attr: 0 }],
            &[1, 2],
        )
        .unwrap();
        assert_eq!(ct.total().unwrap() as u64, db.population_product(&[1, 2]));
    }

    #[test]
    fn context_extension_multiplies() {
        // Same family, larger context: counts scale by |extra population|.
        let db = university_db();
        let mut src = DirectSource::new(&db);
        let vars = vec![RVar::RelInd { rel: 0 }];
        let small = mobius_complete(&mut src, &vars, &[0, 1]).unwrap();
        let big = mobius_complete(&mut src, &vars, &[0, 1, 2]).unwrap();
        let c = db.population(2) as i128;
        assert_eq!(big.get(&[0]).unwrap(), small.get(&[0]).unwrap() * c);
        assert_eq!(big.get(&[1]).unwrap(), small.get(&[1]).unwrap() * c);
    }

    #[test]
    fn mobius_delta_matches_recompute_difference() {
        use crate::db::query::positive_chain_delta_ct;
        // ΔG from mobius_delta for one inserted tuple must equal
        // G(after) - G(before) from two full Möbius runs.
        let db = university_db();
        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::RelInd { rel: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ];
        let ctx = vec![0usize, 1, 2];
        let before = {
            let mut src = DirectSource::new(&db);
            mobius_complete(&mut src, &vars, &ctx).unwrap()
        };
        let mut db2 = db.clone();
        // (11, 0) is not an RA pair in the fixture (i % 12 == 11 -> i in
        // {11, 23}, whose i % 19 are 11 and 4)
        let tid = db2.insert_link(0, 11, 0, &[2, 1]).unwrap();
        let after = {
            let mut src = DirectSource::new(&db2);
            mobius_complete(&mut src, &vars, &ctx).unwrap()
        };
        let mut src = DirectSource::new(&db2);
        let mut stats = crate::db::query::JoinStats::default();
        let delta = mobius_delta(
            &mut src,
            &mut |chain, cvars| {
                positive_chain_delta_ct(&db2, chain, cvars, 0, tid, &mut stats)
            },
            0,
            &vars,
            &ctx,
        )
        .unwrap();
        let mut patched = before.clone();
        patched.add_table(&delta).unwrap();
        assert_eq!(patched.n_rows(), after.n_rows());
        for (v, c) in after.iter_rows() {
            assert_eq!(patched.get(&v).unwrap(), c, "{v:?}");
        }
        // a family not involving the touched rel sees a zero delta
        let other = vec![RVar::RelInd { rel: 1 }];
        let mut src2 = DirectSource::new(&db2);
        let z = mobius_delta(
            &mut src2,
            &mut |_, _| unreachable!("no subset contains rel 0"),
            0,
            &other,
            &[1, 2],
        )
        .unwrap();
        assert_eq!(z.n_rows(), 0);
    }

    #[test]
    fn rejects_var_outside_context() {
        let db = university_db();
        let mut src = DirectSource::new(&db);
        let r = mobius_complete(&mut src, &[RVar::RelInd { rel: 0 }], &[0]);
        assert!(r.is_err());
    }

    #[test]
    fn empty_database_all_bottom() {
        let schema = crate::db::fixtures::university_schema();
        let mut db = Database::empty(schema);
        for p in 0..3u32 {
            db.entities[0].push(&[p % 3]).unwrap();
        }
        for s in 0..2u32 {
            db.entities[1].push(&[s % 3]).unwrap();
        }
        db.build_indexes().unwrap();
        let mut src = DirectSource::new(&db);
        let ct =
            mobius_complete(&mut src, &[RVar::RelInd { rel: 0 }], &[0, 1]).unwrap();
        assert_eq!(ct.get(&[0]).unwrap(), 6);
        assert_eq!(ct.get(&[1]).unwrap(), 0);
    }
}
