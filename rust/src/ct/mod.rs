//! Contingency tables (ct-tables) and their algebra.
//!
//! A ct-table records, for a list of first-order variables, how many
//! groundings take each value combination (paper Table 3).  The module
//! provides:
//!
//! - [`cttable::CtTable`] — the sparse representation (flat u128 keys),
//! - [`project`] — summing out columns (the PRECOUNT/HYBRID projection),
//! - [`cross`] — cross-product extension by entity marginals (needed to
//!   extend sub-chain counts to a lattice point's full population),
//! - [`mobius`] — the Möbius Join: extending positive ct-tables to
//!   complete ones (positive *and negative* relationships) with no
//!   further data access, and
//! - [`dense`] — packing families into the padded dense tensor layout
//!   shared with the Pallas kernels (see `python/compile/kernels/ref.py`).

pub mod cross;
pub mod cttable;
pub mod dense;
pub mod mobius;
pub mod project;

pub use cttable::CtTable;
