//! Projection: summing out unwanted columns of a ct-table.
//!
//! This is the cheap operation PRECOUNT and HYBRID substitute for table
//! JOINs during structure search (Algorithm 1 line 6, Algorithm 3 line 5):
//! given a large cached ct-table, the ct-table for any subset of its
//! columns is obtained by summation, with no data access.

use crate::ct::cttable::CtTable;
use crate::error::Result;
use crate::meta::rvar::RVar;

/// Project onto `keep` (in the given order), summing out all other
/// columns.  Every kept variable must be a column of `t`.
pub fn project(t: &CtTable, keep: &[RVar]) -> Result<CtTable> {
    let mut out = CtTable::with_dims(
        keep.to_vec(),
        keep.iter()
            .map(|v| t.var_pos(v).map(|p| t.dims[p]))
            .collect::<Result<Vec<u32>>>()?,
    )?;
    // Precompute (old stride, old dim, new stride) per kept column.
    let mut maps = Vec::with_capacity(keep.len());
    for (new_pos, v) in keep.iter().enumerate() {
        let old_pos = t.var_pos(v)?;
        maps.push((t.stride(old_pos), t.dims[old_pos] as u128, out.stride(new_pos)));
    }
    for (key, count) in t.iter_keys() {
        let mut new_key: u128 = 0;
        for &(os, od, ns) in &maps {
            new_key += ((key / os) % od) * ns;
        }
        out.add_key(new_key, count)?;
    }
    Ok(out)
}

/// Condition: keep only rows where `var == value`, then drop the column.
/// Used to slice positive ct-tables out of complete ones in tests.
pub fn condition(t: &CtTable, var: &RVar, value: u32) -> Result<CtTable> {
    let pos = t.var_pos(var)?;
    let keep: Vec<RVar> =
        t.vars.iter().copied().filter(|v| v != var).collect();
    let mut out = CtTable::with_dims(
        keep.clone(),
        keep.iter()
            .map(|v| t.var_pos(v).map(|p| t.dims[p]))
            .collect::<Result<Vec<u32>>>()?,
    )?;
    let vs = t.stride(pos);
    let vd = t.dims[pos] as u128;
    let mut maps = Vec::with_capacity(keep.len());
    for (new_pos, v) in keep.iter().enumerate() {
        let old_pos = t.var_pos(v)?;
        maps.push((t.stride(old_pos), t.dims[old_pos] as u128, out.stride(new_pos)));
    }
    for (key, count) in t.iter_keys() {
        if ((key / vs) % vd) as u32 != value {
            continue;
        }
        let mut new_key: u128 = 0;
        for &(os, od, ns) in &maps {
            new_key += ((key / os) % od) * ns;
        }
        out.add_key(new_key, count)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;

    fn filled() -> (CtTable, RVar, RVar, RVar) {
        let s = university_schema();
        let a = RVar::RelInd { rel: 0 };
        let b = RVar::RelAttr { rel: 0, attr: 1 };
        let c = RVar::EntityAttr { et: 1, attr: 0 };
        let mut t = CtTable::new(&s, vec![a, b, c]).unwrap();
        t.add(&[0, 0, 0], 10).unwrap();
        t.add(&[0, 0, 1], 20).unwrap();
        t.add(&[1, 2, 0], 5).unwrap();
        t.add(&[1, 3, 1], 7).unwrap();
        (t, a, b, c)
    }

    #[test]
    fn project_sums_out() {
        let (t, a, _b, c) = filled();
        let p = project(&t, &[a]).unwrap();
        assert_eq!(p.get(&[0]).unwrap(), 30);
        assert_eq!(p.get(&[1]).unwrap(), 12);
        let p2 = project(&t, &[c, a]).unwrap(); // order respected
        assert_eq!(p2.vars, vec![c, a]);
        assert_eq!(p2.get(&[0, 1]).unwrap(), 5);
    }

    #[test]
    fn project_preserves_total() {
        let (t, a, b, c) = filled();
        for keep in [vec![a], vec![b], vec![c], vec![a, b], vec![b, c]] {
            let p = project(&t, &keep).unwrap();
            assert_eq!(p.total().unwrap(), t.total().unwrap());
        }
    }

    #[test]
    fn project_identity() {
        let (t, a, b, c) = filled();
        let p = project(&t, &[a, b, c]).unwrap();
        assert_eq!(p.n_rows(), t.n_rows());
        for (vals, c_) in t.iter_rows() {
            assert_eq!(p.get(&vals).unwrap(), c_);
        }
    }

    #[test]
    fn project_unknown_var_errors() {
        let (t, _, _, _) = filled();
        let ghost = RVar::EntityAttr { et: 0, attr: 0 };
        assert!(project(&t, &[ghost]).is_err());
    }

    #[test]
    fn condition_slices() {
        let (t, a, b, c) = filled();
        let pos = condition(&t, &a, 1).unwrap();
        assert_eq!(pos.vars, vec![b, c]);
        assert_eq!(pos.total().unwrap(), 12);
        assert_eq!(pos.get(&[2, 0]).unwrap(), 5);
    }
}
