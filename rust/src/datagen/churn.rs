//! Seeded churn workloads: random, valid insert/delete sequences over a
//! generated database, for the delta maintenance experiments
//! (`relcount exp churn`, `benches/delta_churn.rs`) and the differential
//! tests.
//!
//! A churn batch of fraction `f` holds `max(1, round(f * total links))`
//! ops: alternating deletes of existing tuples and inserts of fresh
//! pairs (set semantics respected against a simulated mirror of the
//! tables, so the batch always applies cleanly in order), with an
//! occasional entity insert to exercise population growth.  Everything
//! is drawn from the in-tree seeded [`Rng`], so `(db, frac, seed)`
//! always yields the identical batch.

use crate::db::catalog::Database;
use crate::db::index::pair_key;
use crate::delta::batch::{DeltaBatch, DeltaOp};
use crate::util::fxhash::FxHashSet;
use crate::util::rng::Rng;

/// Generate one seeded churn batch over the current state of `db`.
/// `frac` is the op count as a fraction of the database's link rows.
pub fn churn_batch(db: &Database, frac: f64, seed: u64) -> DeltaBatch {
    let mut rng = Rng::new(seed ^ 0xC0DE_D017);
    let schema = &db.schema;
    let n_rels = schema.relationships.len();

    // Mirror of the live pairs per relationship, kept in sync with the
    // ops we emit so every op is valid when applied in order.
    let mut pairs: Vec<Vec<(u32, u32)>> = Vec::with_capacity(n_rels);
    let mut present: Vec<FxHashSet<u64>> = Vec::with_capacity(n_rels);
    for rel in 0..n_rels {
        let t = &db.rels[rel];
        let mut list = Vec::with_capacity(t.len() as usize);
        let mut set = FxHashSet::default();
        for i in 0..t.len() {
            let (f, o) = (t.from[i as usize], t.to[i as usize]);
            list.push((f, o));
            set.insert(pair_key(f, o));
        }
        pairs.push(list);
        present.push(set);
    }
    let mut pops: Vec<u32> = (0..schema.entities.len())
        .map(|et| db.entities[et].len())
        .collect();

    let total_links: u64 = db.rels.iter().map(|t| t.len() as u64).sum();
    let n_ops = ((total_links as f64 * frac).round() as u64).max(1);

    let mut ops = Vec::with_capacity(n_ops as usize);
    for i in 0..n_ops {
        // occasional entity insert (population growth; fresh entities
        // become link targets for later inserts)
        if n_ops >= 8 && i % 16 == 7 {
            let et = rng.gen_range(schema.entities.len() as u64) as usize;
            let values: Vec<u32> = schema.entities[et]
                .attrs
                .iter()
                .map(|a| rng.gen_u32(a.card))
                .collect();
            pops[et] += 1;
            ops.push(DeltaOp::InsertEntity { et, values });
            continue;
        }
        let rel = rng.gen_range(n_rels as u64) as usize;
        let delete = i % 2 == 1 && !pairs[rel].is_empty();
        if delete {
            let j = rng.gen_range(pairs[rel].len() as u64) as usize;
            let (from, to) = pairs[rel].swap_remove(j);
            present[rel].remove(&pair_key(from, to));
            ops.push(DeltaOp::DeleteLink { rel, from, to });
        } else {
            let (fe, te) = schema.rel_endpoints(rel);
            let (nf, nt) = (pops[fe] as u64, pops[te] as u64);
            if nf == 0 || nt == 0 {
                continue;
            }
            // rejection-sample a fresh pair (bounded tries; dense
            // relations may occasionally yield a shorter batch)
            let mut found = None;
            for _ in 0..64 {
                let f = rng.gen_range(nf) as u32;
                let t = rng.gen_range(nt) as u32;
                if !present[rel].contains(&pair_key(f, t)) {
                    found = Some((f, t));
                    break;
                }
            }
            let Some((from, to)) = found else { continue };
            let values: Vec<u32> = schema.relationships[rel]
                .attrs
                .iter()
                .map(|a| rng.gen_u32(a.card))
                .collect();
            pairs[rel].push((from, to));
            present[rel].insert(pair_key(from, to));
            ops.push(DeltaOp::InsertLink { rel, from, to, values });
        }
    }
    DeltaBatch::new(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::delta::maintain::{MaintainConfig, MaintainedCounts};

    #[test]
    fn batches_are_seeded_and_sized() {
        let db = university_db();
        let a = churn_batch(&db, 0.2, 7);
        let b = churn_batch(&db, 0.2, 7);
        assert_eq!(a, b);
        let c = churn_batch(&db, 0.2, 8);
        assert_ne!(a, c);
        let total: u64 = db.rels.iter().map(|t| t.len() as u64).sum();
        assert!(a.len() as u64 <= (total as f64 * 0.2).round() as u64 + 1);
        assert!(!a.is_empty());
    }

    #[test]
    fn batches_apply_cleanly() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        for step in 0..3u64 {
            let batch = churn_batch(m.db(), 0.15, 100 + step);
            let rep = m.apply(&batch).unwrap();
            assert_eq!(rep.ops_applied, batch.len() as u64);
        }
    }
}
