//! Generator configuration.

use crate::error::{Error, Result};

/// One entity type to generate.
#[derive(Clone, Debug)]
pub struct EntitySpec {
    pub name: String,
    /// Population size (before scaling).
    pub n: u64,
    /// (attribute name, cardinality).
    pub attrs: Vec<(String, u32)>,
}

/// One relationship type to generate.
#[derive(Clone, Debug)]
pub struct RelSpec {
    pub name: String,
    /// Endpoint indexes into [`GenConfig::entities`].
    pub from: usize,
    pub to: usize,
    pub attrs: Vec<(String, u32)>,
    /// Number of links (before scaling); must not exceed half the pair
    /// space after scaling (duplicate-free sampling stays cheap).
    pub n_links: u64,
}

/// A full generation job.
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub name: String,
    pub entities: Vec<EntitySpec>,
    pub rels: Vec<RelSpec>,
    pub seed: u64,
    /// Inject cross-attribute dependencies (on by default; off yields
    /// fully independent noise, used by ablation benches).
    pub correlated: bool,
}

impl GenConfig {
    /// Scale all population sizes and link counts by `scale`
    /// (entity floors at 2 so every endpoint keeps a real population).
    pub fn scaled(mut self, scale: f64) -> Result<GenConfig> {
        if !(scale > 0.0 && scale <= 1.0) {
            return Err(Error::Data(format!("scale must be in (0, 1], got {scale}")));
        }
        if (scale - 1.0).abs() < 1e-12 {
            return Ok(self);
        }
        for e in &mut self.entities {
            e.n = ((e.n as f64 * scale).round() as u64).max(3);
        }
        for r in &mut self.rels {
            let scaled = ((r.n_links as f64 * scale).round() as u64).max(1);
            // entity floors can make the scaled pair space smaller than a
            // linear link scale expects; clamp to keep sampling feasible
            let pairs = self.entities[r.from].n * self.entities[r.to].n;
            r.n_links = scaled.min(pairs / 2);
        }
        self.name = format!("{}@{scale}", self.name);
        Ok(self)
    }

    /// Expected total data rows (entity rows + link rows) — compare with
    /// the paper's Table 4 "Row Count".
    pub fn total_rows(&self) -> u64 {
        self.entities.iter().map(|e| e.n).sum::<u64>()
            + self.rels.iter().map(|r| r.n_links).sum::<u64>()
    }

    pub fn validate(&self) -> Result<()> {
        for r in &self.rels {
            if r.from >= self.entities.len() || r.to >= self.entities.len() {
                return Err(Error::Data(format!("{}: bad endpoints", r.name)));
            }
            if r.from == r.to {
                return Err(Error::Data(format!(
                    "{}: self-relationships need role-split entities",
                    r.name
                )));
            }
            let pairs = self.entities[r.from].n.saturating_mul(self.entities[r.to].n);
            if r.n_links > pairs / 2 {
                return Err(Error::Data(format!(
                    "{}: {} links > half the pair space {}",
                    r.name, r.n_links, pairs
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GenConfig {
        GenConfig {
            name: "t".into(),
            entities: vec![
                EntitySpec { name: "A".into(), n: 100, attrs: vec![("x".into(), 3)] },
                EntitySpec { name: "B".into(), n: 50, attrs: vec![] },
            ],
            rels: vec![RelSpec {
                name: "R".into(),
                from: 0,
                to: 1,
                attrs: vec![("w".into(), 2)],
                n_links: 200,
            }],
            seed: 1,
            correlated: true,
        }
    }

    #[test]
    fn totals_and_scaling() {
        let c = cfg();
        assert_eq!(c.total_rows(), 350);
        let s = c.scaled(0.1).unwrap();
        assert_eq!(s.entities[0].n, 10);
        assert_eq!(s.entities[1].n, 5);
        assert_eq!(s.rels[0].n_links, 20);
        assert!(s.name.contains("@0.1"));
    }

    #[test]
    fn validation() {
        assert!(cfg().validate().is_ok());
        let mut c = cfg();
        c.rels[0].n_links = 10_000; // > half of 100*50
        assert!(c.validate().is_err());
        assert!(cfg().scaled(0.0).is_err());
        assert!(cfg().scaled(2.0).is_err());
    }
}
