//! Seeded database synthesis from a [`GenConfig`].
//!
//! Dependency injection (when `correlated`): the first attribute of each
//! entity is skewed noise; later attributes depend on the previous one;
//! relationship attributes depend on both endpoints' first attributes;
//! link formation is biased toward entities with low first-attribute
//! codes (preferential attachment-ish), so indicators correlate with
//! entity attributes.  This gives BDeu real structure to find without
//! hand-coding a ground-truth BN per preset.

use crate::util::fxhash::FxHashSet;

use crate::datagen::config::GenConfig;
use crate::db::catalog::Database;
use crate::db::index::pair_key;
use crate::db::schema::{Attribute, EntityType, RelationshipType, Schema};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Generate a database.
pub fn generate(cfg: &GenConfig) -> Result<Database> {
    cfg.validate()?;
    let schema = Schema::new(
        cfg.entities
            .iter()
            .map(|e| EntityType {
                name: e.name.clone(),
                attrs: e
                    .attrs
                    .iter()
                    .map(|(n, c)| Attribute::new(n.clone(), *c))
                    .collect(),
            })
            .collect(),
        cfg.rels
            .iter()
            .map(|r| RelationshipType {
                name: r.name.clone(),
                from: r.from,
                to: r.to,
                attrs: r
                    .attrs
                    .iter()
                    .map(|(n, c)| Attribute::new(n.clone(), *c))
                    .collect(),
            })
            .collect(),
    )?;
    // Fail before any table or dedup set grows: entity ids and
    // relationship tuple ids are u32-addressed, and `seen.reserve`
    // below sizes off n_links — an over-capacity preset must surface as
    // a typed error up front, not an OOM or a mid-build wrap.
    for spec in &cfg.entities {
        Error::check_u32_capacity(&format!("{} entity ids", spec.name), spec.n)?;
    }
    for spec in &cfg.rels {
        Error::check_u32_capacity(
            &format!("{} link pairs", spec.name),
            spec.n_links,
        )?;
    }
    let mut db = Database::empty(schema);
    let mut rng = Rng::new(cfg.seed);

    // --- entities -------------------------------------------------------
    for (et, spec) in cfg.entities.iter().enumerate() {
        let table = &mut db.entities[et];
        let mut row = vec![0u32; spec.attrs.len()];
        for _ in 0..spec.n {
            for (a, &(_, card)) in spec.attrs.iter().enumerate() {
                row[a] = if a == 0 || !cfg.correlated {
                    rng.gen_skewed(card)
                } else if rng.gen_bool(0.7) {
                    // depend on the previous attribute
                    (row[a - 1] + rng.gen_u32(2)) % card
                } else {
                    rng.gen_u32(card)
                };
            }
            table.push(&row)?;
        }
    }

    // --- relationships ----------------------------------------------------
    for (rt, spec) in cfg.rels.iter().enumerate() {
        let nf = db.entities[spec.from].len() as u64;
        let nt = db.entities[spec.to].len() as u64;
        let n_links = spec.n_links.min(nf * nt / 2).max(0);
        let mut seen: FxHashSet<u64> = FxHashSet::default();
        seen.reserve(n_links as usize);
        let mut row = vec![0u32; spec.attrs.len()];
        let mut emitted = 0u64;
        while emitted < n_links {
            // biased endpoint choice: half the draws concentrate on a
            // prefix of the population, correlating links w/ attributes
            let f = biased_pick(&mut rng, nf, cfg.correlated);
            let t = biased_pick(&mut rng, nt, cfg.correlated);
            let key = pair_key(f, t);
            if !seen.insert(key) {
                continue;
            }
            let fa = first_attr(&db, spec.from, f);
            let ta = first_attr(&db, spec.to, t);
            for (a, &(_, card)) in spec.attrs.iter().enumerate() {
                row[a] = if cfg.correlated && rng.gen_bool(0.7) {
                    (fa + ta + a as u32 + rng.gen_u32(2)) % card
                } else {
                    rng.gen_skewed(card)
                };
            }
            db.rels[rt].push(f as u32, t as u32, &row)?;
            emitted += 1;
        }
        if emitted < spec.n_links.min(nf * nt / 2) {
            return Err(Error::Data(format!("{}: could not place links", spec.name)));
        }
    }

    db.validate()?;
    db.build_indexes()?;
    Ok(db)
}

#[inline]
// (n + 3) / 4 is div_ceil spelled out to hold the MSRV-1.70 line
// (u64::div_ceil stabilized in 1.73; the CI msrv lane enforces this).
#[allow(clippy::manual_div_ceil)]
fn biased_pick(rng: &mut Rng, n: u64, correlated: bool) -> u32 {
    debug_assert!(n > 0);
    if correlated && rng.gen_bool(0.5) {
        // concentrate on the first ~quarter of the population
        rng.gen_range((n + 3) / 4) as u32
    } else {
        rng.gen_range(n) as u32
    }
}

#[inline]
fn first_attr(db: &Database, et: usize, id: u32) -> u32 {
    if db.entities[et].cols.is_empty() {
        0
    } else {
        db.entities[et].value(0, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::config::{EntitySpec, RelSpec};

    fn cfg(seed: u64) -> GenConfig {
        GenConfig {
            name: "t".into(),
            entities: vec![
                EntitySpec {
                    name: "A".into(),
                    n: 40,
                    attrs: vec![("x".into(), 3), ("y".into(), 4)],
                },
                EntitySpec { name: "B".into(), n: 30, attrs: vec![("z".into(), 2)] },
            ],
            rels: vec![RelSpec {
                name: "R".into(),
                from: 0,
                to: 1,
                attrs: vec![("w".into(), 3)],
                n_links: 150,
            }],
            seed,
            correlated: true,
        }
    }

    #[test]
    fn generates_exact_counts() {
        let db = generate(&cfg(5)).unwrap();
        assert_eq!(db.population(0), 40);
        assert_eq!(db.population(1), 30);
        assert_eq!(db.rels[0].len(), 150);
        assert_eq!(db.total_rows(), 40 + 30 + 150);
        assert!(db.has_indexes());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&cfg(9)).unwrap();
        let b = generate(&cfg(9)).unwrap();
        assert_eq!(a.rels[0].from, b.rels[0].from);
        assert_eq!(a.rels[0].cols, b.rels[0].cols);
        assert_eq!(a.entities[0].cols, b.entities[0].cols);
        let c = generate(&cfg(10)).unwrap();
        assert_ne!(a.rels[0].from, c.rels[0].from);
    }

    #[test]
    fn no_duplicate_pairs() {
        let db = generate(&cfg(11)).unwrap();
        // index build enforces uniqueness; verify count survived it
        assert_eq!(db.index(0).unwrap().len(), 150);
    }

    #[test]
    fn over_capacity_specs_error_before_building() {
        let mut c = cfg(5);
        c.rels[0].n_links = u32::MAX as u64 + 1;
        let e = generate(&c).unwrap_err();
        assert!(matches!(e, Error::Capacity { .. }), "{e}");
        let mut c = cfg(5);
        c.entities[0].n = u32::MAX as u64 + 1;
        let e = generate(&c).unwrap_err();
        assert!(matches!(e, Error::Capacity { .. }), "{e}");
    }

    #[test]
    fn correlation_signal_exists() {
        // rel attr should correlate with endpoint attrs when enabled
        let db = generate(&cfg(13)).unwrap();
        let mut match_count = 0u32;
        let t = &db.rels[0];
        for i in 0..t.len() {
            let fa = db.entities[0].value(0, t.from[i as usize]);
            let ta = db.entities[1].value(0, t.to[i as usize]);
            if t.value(0, i) == (fa + ta) % 3 || t.value(0, i) == (fa + ta + 1) % 3 {
                match_count += 1;
            }
        }
        // ~70% of links follow the dependency (plus chance matches)
        assert!(match_count > t.len() * 6 / 10, "{match_count}/{}", t.len());
    }
}
