//! Synthetic relational database generation.
//!
//! The paper evaluates on 8 real databases (Table 4) that are not
//! redistributable here, so — per DESIGN.md §1 — each benchmark gets a
//! seeded synthetic *preset* pinning the evaluation's independent
//! variables to the published values: total row count, number of
//! relationship tables, attribute counts/cardinalities and link
//! densities.  Attribute values carry injected dependencies so structure
//! learning has real signal (Table 4's MP/N column).

pub mod churn;
pub mod config;
pub mod generator;
pub mod presets;
pub mod synth;

pub use churn::churn_batch;
pub use config::{EntitySpec, GenConfig, RelSpec};
pub use generator::generate;
pub use presets::{preset, PRESET_NAMES};
pub use synth::{skewed_star_db, skewed_triangle_count, skewed_triangle_db};
