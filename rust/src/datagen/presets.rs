//! One preset per benchmark database of the paper's Table 4.
//!
//! Each preset pins: the total data-row count (entity rows + link rows,
//! exactly the paper's "Row Count" at scale 1.0), the number of
//! relationship tables, and a schema shaped like the original database.
//! Visual Genome mirrors the paper's star-schema conversion: the ternary
//! (subject, predicate, object) relation becomes a RelNode entity with
//! binary links.  Mondial's country-borders-country self-relationship is
//! role-split (the same technique the language bias requires).

use crate::datagen::config::{EntitySpec, GenConfig, RelSpec};
use crate::error::{Error, Result};

/// The 8 benchmark names, in the paper's Table 4 order.
pub const PRESET_NAMES: [&str; 8] = [
    "uw",
    "mondial",
    "hepatitis",
    "mutagenesis",
    "movielens",
    "financial",
    "imdb",
    "visual_genome",
];

fn e(name: &str, n: u64, attrs: &[(&str, u32)]) -> EntitySpec {
    EntitySpec {
        name: name.into(),
        n,
        attrs: attrs.iter().map(|&(a, c)| (a.into(), c)).collect(),
    }
}

fn r(
    name: &str,
    from: usize,
    to: usize,
    attrs: &[(&str, u32)],
    n_links: u64,
) -> RelSpec {
    RelSpec {
        name: name.into(),
        from,
        to,
        attrs: attrs.iter().map(|&(a, c)| (a.into(), c)).collect(),
        n_links,
    }
}

/// Build a preset by name, scaled by `scale` in (0, 1].
pub fn preset(name: &str, scale: f64, seed: u64) -> Result<GenConfig> {
    let cfg = match name {
        // 712 rows, 2 relationships (UW-CSE)
        "uw" => GenConfig {
            name: "uw".into(),
            entities: vec![
                e("Professor", 60, &[("position", 3), ("popularity", 3)]),
                e("Student", 150, &[("intelligence", 3), ("phase", 3)]),
                e("Course", 100, &[("level", 2), ("difficulty", 3)]),
            ],
            rels: vec![
                r("RA", 0, 1, &[("capability", 4), ("salary", 3)], 120),
                r("Registered", 1, 2, &[("grade", 4)], 282),
            ],
            seed,
            correlated: true,
        },
        // 870 rows, 2 relationships
        "mondial" => GenConfig {
            name: "mondial".into(),
            entities: vec![
                e("Country", 180, &[("continent", 5), ("govform", 4), ("gdp", 3)]),
                e("Org", 120, &[("kind", 3), ("established", 3)]),
                e("City", 170, &[("size", 3), ("coastal", 2)]),
            ],
            rels: vec![
                r("Member", 0, 1, &[("mtype", 3)], 250),
                r("Located", 1, 2, &[], 150),
            ],
            seed,
            correlated: true,
        },
        // 12,927 rows, 3 relationships
        "hepatitis" => GenConfig {
            name: "hepatitis".into(),
            entities: vec![
                e("Patient", 500, &[("sex", 2), ("age", 4), ("type", 3)]),
                e("Exam", 700, &[("fibros", 4), ("activity", 4)]),
                e("Bio", 300, &[("got", 3), ("gpt", 3)]),
            ],
            rels: vec![
                r("Took", 0, 1, &[("dur", 3)], 6000),
                r("BioOf", 0, 2, &[], 2427),
                r("ExamBio", 1, 2, &[("rel", 2)], 3000),
            ],
            seed,
            correlated: true,
        },
        // 14,540 rows, 2 relationships
        "mutagenesis" => GenConfig {
            name: "mutagenesis".into(),
            entities: vec![
                e(
                    "Molecule",
                    230,
                    &[("mutagenic", 2), ("logp", 4), ("lumo", 4), ("ind1", 2)],
                ),
                e("Atom", 4500, &[("element", 7), ("charge", 4)]),
            ],
            rels: vec![
                r("Contains", 0, 1, &[("atype", 5)], 4500),
                r("Functional", 0, 1, &[("group", 4)], 5310),
            ],
            seed,
            correlated: true,
        },
        // 74,402 rows, 1 relationship
        "movielens" => GenConfig {
            name: "movielens".into(),
            entities: vec![
                e("User", 941, &[("age", 4), ("gender", 2), ("occupation", 5)]),
                e("Movie", 1500, &[("genre", 6), ("year", 4)]),
            ],
            rels: vec![r("Rated", 0, 1, &[("rating", 5)], 71_961)],
            seed,
            correlated: true,
        },
        // 225,887 rows, 3 relationships
        "financial" => GenConfig {
            name: "financial".into(),
            entities: vec![
                e("Client", 5369, &[("sex", 2), ("agegrp", 4)]),
                e("Account", 4500, &[("frequency", 3), ("avgbal", 4)]),
                e("District", 77, &[("region", 4), ("urban", 3), ("crime", 3)]),
            ],
            rels: vec![
                r("Disp", 0, 1, &[("dtype", 2)], 6471),
                r("TransAt", 1, 2, &[("ttype", 4)], 150_000),
                r("ClientIn", 0, 2, &[], 59_470),
            ],
            seed,
            correlated: true,
        },
        // 1,063,559 rows, 3 relationships
        "imdb" => GenConfig {
            name: "imdb".into(),
            entities: vec![
                e("Movie", 30_000, &[("genre", 6), ("decade", 4), ("runtime", 3)]),
                e("Actor", 60_000, &[("gender", 2), ("quality", 4)]),
                e("Director", 8_000, &[("quality", 4)]),
                e("User", 10_000, &[("age", 4), ("gender", 2)]),
            ],
            rels: vec![
                r("ActsIn", 1, 0, &[("role", 3)], 650_000),
                r("Directs", 2, 0, &[], 65_559),
                r("Rates", 3, 0, &[("rating", 5)], 240_000),
            ],
            seed,
            correlated: true,
        },
        // 15,833,273 rows, 8 relationships (ternary -> star schema)
        "visual_genome" => GenConfig {
            name: "visual_genome".into(),
            entities: vec![
                e("Image", 100_000, &[("setting", 3), ("quality", 3)]),
                e("Object", 1_000_000, &[("category", 8), ("size", 3)]),
                e("RelNode", 1_500_000, &[("predicate", 8)]),
                e("Region", 800_000, &[("area", 3)]),
            ],
            rels: vec![
                r("ObjInImg", 1, 0, &[], 1_000_000),
                r("RelSubj", 2, 1, &[], 1_500_000),
                r("RelObj", 2, 1, &[("order", 2)], 1_500_000),
                r("RelInImg", 2, 0, &[], 1_500_000),
                r("RegionInImg", 3, 0, &[], 800_000),
                r("ObjInRegion", 1, 3, &[], 2_000_000),
                r("RegionRel", 3, 2, &[], 1_600_000),
                r("AttrIn", 1, 0, &[("attr", 6)], 2_533_273),
            ],
            seed,
            correlated: true,
        },
        other => {
            return Err(Error::Data(format!(
                "unknown preset {other:?} (expected one of {PRESET_NAMES:?})"
            )))
        }
    };
    cfg.scaled(scale)
}

/// The paper's Table 4 row counts, for validation and reporting.
pub fn paper_row_count(name: &str) -> Option<u64> {
    Some(match name {
        "uw" => 712,
        "mondial" => 870,
        "hepatitis" => 12_927,
        "mutagenesis" => 14_540,
        "movielens" => 74_402,
        "financial" => 225_887,
        "imdb" => 1_063_559,
        "visual_genome" => 15_833_273,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::generator::generate;

    #[test]
    fn all_presets_match_paper_row_counts() {
        for name in PRESET_NAMES {
            let cfg = preset(name, 1.0, 0).unwrap();
            assert_eq!(
                cfg.total_rows(),
                paper_row_count(name).unwrap(),
                "preset {name}"
            );
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn relationship_counts_match_table4() {
        let expected = [2usize, 2, 3, 2, 1, 3, 3, 8];
        for (name, want) in PRESET_NAMES.iter().zip(expected) {
            let cfg = preset(name, 1.0, 0).unwrap();
            assert_eq!(cfg.rels.len(), want, "preset {name}");
        }
    }

    #[test]
    fn small_scale_generates() {
        for name in PRESET_NAMES {
            let cfg = preset(name, 0.01, 7).unwrap();
            let db = generate(&cfg).unwrap();
            assert!(db.total_rows() > 0, "preset {name}");
            assert_eq!(db.n_relationships(), cfg.rels.len());
        }
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(preset("nope", 1.0, 0).is_err());
    }
}
