//! Deterministic hub-skewed pattern databases for the WCOJ experiment.
//!
//! The Table-4 presets ([`crate::datagen::presets`]) pin the paper's
//! benchmark shapes, which are chain-dominated; the AGM gap the WCOJ
//! kernel closes only opens on *cyclic* patterns with degree skew.
//! These constructions are the textbook worst case, built directly (no
//! sampling) so runs are exactly reproducible:
//!
//! - [`skewed_triangle_db`] — three populations of size `n` where node
//!   0 of each is a hub linked to everything.  Any binary two-relation
//!   join materializes Θ(n²) intermediate pairs through the hubs, while
//!   the full triangle count is only `3n - 2` rows; a worst-case
//!   optimal plan touches Θ(n log n).
//! - [`skewed_star_db`] — a hub population with three spoke
//!   relationships, hub node 0 again of degree n.  The pattern is
//!   acyclic, so this is the control: both kernels are near-linear and
//!   the experiment should show parity rather than a gap.

use crate::db::catalog::Database;
use crate::db::schema::{Attribute, EntityType, RelationshipType, Schema};
use crate::error::{Error, Result};

/// Triangle pattern A—B—C (R0: A→B, R1: B→C, R2: A→C), each population
/// of size `n`, hub node 0 everywhere: R0 = {(0,b)} ∪ {(a,0)},
/// R1 = {(0,c)} ∪ {(b,0)}, R2 = {(0,c)} ∪ {(a,0)}.  Attributes
/// `A.x` and `C.y` (cardinality 3) give the group-by something to do.
pub fn skewed_triangle_db(n: u32) -> Result<Database> {
    if n < 2 {
        return Err(Error::Data(format!(
            "skewed_triangle_db needs n >= 2, got {n}"
        )));
    }
    // fail before any table grows: each population holds n entities and
    // each relationship 2n-1 pairs, all addressed by u32 ids
    Error::check_u32_capacity("skewed_triangle_db entities", n as u64)?;
    Error::check_u32_capacity("skewed_triangle_db pairs", 2 * n as u64 - 1)?;
    let schema = Schema::new(
        vec![
            EntityType { name: "A".into(), attrs: vec![Attribute::new("x", 3)] },
            EntityType { name: "B".into(), attrs: vec![] },
            EntityType { name: "C".into(), attrs: vec![Attribute::new("y", 3)] },
        ],
        vec![
            RelationshipType { name: "R0".into(), from: 0, to: 1, attrs: vec![] },
            RelationshipType { name: "R1".into(), from: 1, to: 2, attrs: vec![] },
            RelationshipType { name: "R2".into(), from: 0, to: 2, attrs: vec![] },
        ],
    )?;
    let mut db = Database::empty(schema);
    for i in 0..n {
        db.entities[0].push(&[i % 3])?;
        db.entities[1].push(&[])?;
        db.entities[2].push(&[i % 3])?;
    }
    for rel in 0..3usize {
        for v in 0..n {
            db.rels[rel].push(0, v, &[])?;
        }
        for v in 1..n {
            db.rels[rel].push(v, 0, &[])?;
        }
    }
    db.build_indexes()?;
    Ok(db)
}

/// Number of triangles in [`skewed_triangle_db`]`(n)`: the hub rows
/// `(0,0,*)`, `(0,b,0)` for `b >= 1` and `(a,0,0)` for `a >= 1`.
pub fn skewed_triangle_count(n: u32) -> u64 {
    3 * n as u64 - 2
}

/// Star pattern around a hub population H: E0: P→H, E1: H→Q, E2: H→S,
/// all populations of size `n`.  Hub node 0 receives an edge from every
/// P; every hub keeps constant-degree links into Q and S, so the full
/// star join stays linear in `n` (the acyclic control case).
pub fn skewed_star_db(n: u32) -> Result<Database> {
    if n < 8 {
        return Err(Error::Data(format!(
            "skewed_star_db needs n >= 8, got {n}"
        )));
    }
    // fail before any table grows: the widest relationship (E1) holds
    // 3n pairs, all addressed by u32 tuple ids
    Error::check_u32_capacity("skewed_star_db entities", n as u64)?;
    Error::check_u32_capacity("skewed_star_db pairs", 3 * n as u64)?;
    let schema = Schema::new(
        vec![
            EntityType { name: "H".into(), attrs: vec![] },
            EntityType { name: "P".into(), attrs: vec![Attribute::new("x", 2)] },
            EntityType { name: "Q".into(), attrs: vec![] },
            EntityType { name: "S".into(), attrs: vec![Attribute::new("z", 2)] },
        ],
        vec![
            RelationshipType { name: "E0".into(), from: 1, to: 0, attrs: vec![] },
            RelationshipType { name: "E1".into(), from: 0, to: 2, attrs: vec![] },
            RelationshipType { name: "E2".into(), from: 0, to: 3, attrs: vec![] },
        ],
    )?;
    let mut db = Database::empty(schema);
    for i in 0..n {
        db.entities[0].push(&[])?;
        db.entities[1].push(&[i % 2])?;
        db.entities[2].push(&[])?;
        db.entities[3].push(&[i % 2])?;
    }
    for p in 0..n {
        db.rels[0].push(p, 0, &[])?;
        if p % (n - 1) != 0 {
            db.rels[0].push(p, p % (n - 1), &[])?;
        }
    }
    for h in 0..n {
        db.rels[1].push(h, h, &[])?;
        db.rels[1].push(h, (h + 1) % n, &[])?;
        db.rels[1].push(h, (h + 7) % n, &[])?;
        db.rels[2].push(h, (2 * h) % n, &[])?;
        db.rels[2].push(h, (2 * h + 3) % n, &[])?;
    }
    db.build_indexes()?;
    Ok(db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::query::{positive_chain_ct, JoinStats};
    use crate::db::wcoj::JoinKernel;
    use crate::lattice::pattern::{classify, PatternClass};

    #[test]
    fn triangle_construction_has_the_predicted_count() {
        let db = skewed_triangle_db(24).unwrap();
        let mut stats = JoinStats::default();
        let ct = positive_chain_ct(&db, &[0, 1, 2], &[], &mut stats).unwrap();
        assert_eq!(ct.total().unwrap(), skewed_triangle_count(24) as i128);
        assert_eq!(classify(&db.schema, &[0, 1, 2]), PatternClass::Triangle);
    }

    #[test]
    fn triangle_kernels_agree_on_the_skewed_hub() {
        let db = skewed_triangle_db(17).unwrap();
        let mut wcoj_db = db.clone();
        wcoj_db.set_kernel(JoinKernel::Wcoj);
        let mut s1 = JoinStats::default();
        let mut s2 = JoinStats::default();
        let a = positive_chain_ct(&db, &[0, 1, 2], &[], &mut s1).unwrap();
        let b = positive_chain_ct(&wcoj_db, &[0, 1, 2], &[], &mut s2).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.total().unwrap(), skewed_triangle_count(17) as i128);
    }

    #[test]
    fn star_is_linear_sized_and_classified() {
        let db = skewed_star_db(16).unwrap();
        assert_eq!(classify(&db.schema, &[0, 1, 2]), PatternClass::Star);
        let mut stats = JoinStats::default();
        let ct = positive_chain_ct(&db, &[0, 1, 2], &[], &mut stats).unwrap();
        // hub 0 carries n-ish P edges x 6 H-side pairs; other hubs O(1)
        let total = ct.total().unwrap();
        assert!(total > 0);
        assert!(total < 16 * 16, "star join must stay linear, got {total}");
        let mut wcoj_db = db.clone();
        wcoj_db.set_kernel(JoinKernel::Wcoj);
        let mut s2 = JoinStats::default();
        let b = positive_chain_ct(&wcoj_db, &[0, 1, 2], &[], &mut s2).unwrap();
        assert_eq!(b.total().unwrap(), total);
    }

    #[test]
    fn constructions_reject_degenerate_sizes() {
        assert!(skewed_triangle_db(1).is_err());
        assert!(skewed_star_db(4).is_err());
    }

    #[test]
    fn constructions_reject_u32_overflow_before_building() {
        // 2n-1 pairs would exceed the u32 tuple-id space: the guard must
        // fire immediately (this returns in microseconds, not after
        // growing gigabyte tables)
        let e = skewed_triangle_db(0x8000_0001).unwrap_err();
        assert!(matches!(e, Error::Capacity { .. }), "{e}");
        let e = skewed_star_db(0x6000_0000).unwrap_err();
        assert!(matches!(e, Error::Capacity { .. }), "{e}");
    }
}
