//! The database: schema + tables + indexes.

use crate::db::index::{Backend, RelIx};
use crate::db::schema::Schema;
use crate::db::table::{EntityTable, RelTable};
use crate::db::value::Code;
use crate::db::wcoj::JoinKernel;
use crate::error::{Error, Result};

/// An in-memory relational database.  Indexes are built explicitly with
/// [`Database::build_indexes`] on the selected storage [`Backend`]
/// (columnar CSR by default, CLI `--backend`); mutation through anything
/// but the incremental mutators invalidates them.  Positive-count joins
/// dispatch through the selected [`JoinKernel`] (binary chain by
/// default, CLI `--kernel`).
#[derive(Clone, Debug)]
pub struct Database {
    pub schema: Schema,
    pub entities: Vec<EntityTable>,
    pub rels: Vec<RelTable>,
    indexes: Option<Vec<RelIx>>,
    backend: Backend,
    kernel: JoinKernel,
}

impl Database {
    /// Empty database over a schema (default backend: CSR).
    pub fn empty(schema: Schema) -> Self {
        let entities =
            schema.entities.iter().map(|e| EntityTable::new(e.attrs.len())).collect();
        let rels =
            schema.relationships.iter().map(|r| RelTable::new(r.attrs.len())).collect();
        Database {
            schema,
            entities,
            rels,
            indexes: None,
            backend: Backend::default(),
            kernel: JoinKernel::default(),
        }
    }

    /// Construct from parts, validate, and build indexes.
    pub fn new(
        schema: Schema,
        entities: Vec<EntityTable>,
        rels: Vec<RelTable>,
    ) -> Result<Self> {
        let mut db = Database {
            schema,
            entities,
            rels,
            indexes: None,
            backend: Backend::default(),
            kernel: JoinKernel::default(),
        };
        db.validate()?;
        db.build_indexes()?;
        Ok(db)
    }

    /// The relationship-index storage engine in use.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The positive-count join kernel in use.
    pub fn kernel(&self) -> JoinKernel {
        self.kernel
    }

    /// Select the positive-count join kernel.  Pure dispatch — no index
    /// rebuild; clones (per-worker shards, strategy snapshots) inherit
    /// the selection, which is how the CLI flag reaches every consumer.
    pub fn set_kernel(&mut self, kernel: JoinKernel) {
        self.kernel = kernel;
    }

    /// Switch the index storage engine, rebuilding the indexes when they
    /// exist and the backend actually changes.  Counts are bit-identical
    /// on either engine; only the layout (and the join kernels it
    /// enables) differ.
    pub fn set_backend(&mut self, backend: Backend) -> Result<()> {
        if self.backend == backend {
            return Ok(());
        }
        self.backend = backend;
        if self.indexes.is_some() {
            self.build_indexes()?;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        self.schema.validate()?;
        if self.entities.len() != self.schema.entities.len()
            || self.rels.len() != self.schema.relationships.len()
        {
            return Err(Error::Data("table count != schema type count".into()));
        }
        for (et, t) in self.entities.iter().enumerate() {
            t.validate(&self.schema, et)?;
        }
        for (rt, t) in self.rels.iter().enumerate() {
            t.validate(&self.schema, rt)?;
        }
        Ok(())
    }

    /// (Re)build all relationship indexes on the current backend.
    pub fn build_indexes(&mut self) -> Result<()> {
        let mut ixs = Vec::with_capacity(self.rels.len());
        for (rt, t) in self.rels.iter().enumerate() {
            let (f, o) = self.schema.rel_endpoints(rt);
            ixs.push(RelIx::build(
                self.backend,
                t,
                self.entities[f].len(),
                self.entities[o].len(),
            )?);
        }
        self.indexes = Some(ixs);
        Ok(())
    }

    /// Install pre-built relationship indexes — the snapshot-restore
    /// path, which deserializes compacted CSR base arrays instead of
    /// re-sorting every table.  The index set must match the schema's
    /// relationship count, the current backend, and each table's live
    /// pair count; anything else means the persisted artifact does not
    /// describe this database.
    pub(crate) fn install_indexes(&mut self, ixs: Vec<RelIx>) -> Result<()> {
        if ixs.len() != self.rels.len() {
            return Err(Error::Data(format!(
                "index count {} != relationship count {}",
                ixs.len(),
                self.rels.len()
            )));
        }
        for (rt, ix) in ixs.iter().enumerate() {
            if ix.backend() != self.backend {
                return Err(Error::Data(format!(
                    "index {rt} backend {} != database backend {}",
                    ix.backend().name(),
                    self.backend.name()
                )));
            }
            if ix.len() != self.rels[rt].len() as usize {
                return Err(Error::Data(format!(
                    "index {rt} pair count {} != table rows {}",
                    ix.len(),
                    self.rels[rt].len()
                )));
            }
        }
        self.indexes = Some(ixs);
        Ok(())
    }

    /// Index for a relationship; requires [`Database::build_indexes`].
    pub fn index(&self, rel: usize) -> Result<&RelIx> {
        self.indexes
            .as_ref()
            .and_then(|v| v.get(rel))
            .ok_or_else(|| Error::Data("indexes not built (call build_indexes)".into()))
    }

    /// Merge pending CSR overlay entries into the base runs across all
    /// indexes (no-op on the hash backend or when indexes are absent).
    /// [`crate::delta::MaintainedCounts`] calls this at end-of-batch so
    /// recounts and post-batch serving read clean contiguous runs.
    pub fn compact_indexes(&mut self) {
        if let Some(ixs) = self.indexes.as_mut() {
            for ix in ixs {
                ix.compact();
            }
        }
    }

    /// Total pending overlay entries across all CSR indexes (0 on the
    /// hash backend; mutations self-compact past a size threshold, and
    /// the delta subsystem compacts at end-of-batch).
    pub fn index_overlay_len(&self) -> usize {
        self.indexes
            .as_ref()
            .map(|v| v.iter().map(|ix| ix.overlay_len()).sum())
            .unwrap_or(0)
    }

    pub fn has_indexes(&self) -> bool {
        self.indexes.is_some()
    }

    /// Invalidate indexes (call after mutating tables).
    pub fn invalidate_indexes(&mut self) {
        self.indexes = None;
    }

    /// Append an entity of type `et`, maintaining the indexes of its
    /// incident relationships (their adjacency lists grow by one empty
    /// slot).  Returns the new entity id.
    pub fn insert_entity(&mut self, et: usize, values: &[Code]) -> Result<u32> {
        let ety = self
            .schema
            .entities
            .get(et)
            .ok_or_else(|| Error::Data(format!("bad entity type {et}")))?;
        if values.len() != ety.attrs.len() {
            return Err(Error::Data(format!(
                "entity row arity {} != {}",
                values.len(),
                ety.attrs.len()
            )));
        }
        for (a, &v) in values.iter().enumerate() {
            if v >= ety.attrs[a].card {
                return Err(Error::Data(format!(
                    "{}.{} value {v} out of range 0..{}",
                    ety.name, ety.attrs[a].name, ety.attrs[a].card
                )));
            }
        }
        let id = self.entities[et].push(values)?;
        if let Some(ixs) = self.indexes.as_mut() {
            for (rel, ix) in ixs.iter_mut().enumerate() {
                let (f, o) = self.schema.rel_endpoints(rel);
                if f == et || o == et {
                    ix.grow(self.entities[f].len(), self.entities[o].len());
                }
            }
        }
        Ok(id)
    }

    /// Append a relationship tuple, maintaining `rel`'s index.  Rejects
    /// out-of-range endpoints/values and duplicate pairs (set semantics).
    /// Returns the new tuple id.
    pub fn insert_link(
        &mut self,
        rel: usize,
        from: u32,
        to: u32,
        values: &[Code],
    ) -> Result<u32> {
        let rty = self
            .schema
            .relationships
            .get(rel)
            .ok_or_else(|| Error::Data(format!("bad relationship {rel}")))?;
        let (fe, te) = (rty.from, rty.to);
        if from >= self.entities[fe].len() || to >= self.entities[te].len() {
            return Err(Error::Data(format!(
                "rel tuple ({from},{to}) out of population range ({},{})",
                self.entities[fe].len(),
                self.entities[te].len()
            )));
        }
        if values.len() != rty.attrs.len() {
            return Err(Error::Data(format!(
                "rel row arity {} != {}",
                values.len(),
                rty.attrs.len()
            )));
        }
        for (a, &v) in values.iter().enumerate() {
            if v >= rty.attrs[a].card {
                return Err(Error::Data(format!(
                    "{}.{} value {v} out of range 0..{}",
                    rty.name, rty.attrs[a].name, rty.attrs[a].card
                )));
            }
        }
        let duplicate = match self.indexes.as_ref() {
            Some(ixs) => ixs[rel].lookup(from, to).is_some(),
            None => {
                let t = &self.rels[rel];
                (0..t.len()).any(|i| {
                    t.from[i as usize] == from && t.to[i as usize] == to
                })
            }
        };
        if duplicate {
            return Err(Error::Data(format!(
                "duplicate relationship pair ({from},{to})"
            )));
        }
        let id = self.rels[rel].push(from, to, values)?;
        if let Some(ixs) = self.indexes.as_mut() {
            ixs[rel].insert(from, to, id)?;
        }
        Ok(id)
    }

    /// Remove the relationship tuple `(from, to)` of `rel` (swap-remove:
    /// the last tuple takes its id), maintaining `rel`'s index.  Returns
    /// the removed tuple's attribute values.
    pub fn delete_link(&mut self, rel: usize, from: u32, to: u32) -> Result<Vec<Code>> {
        if rel >= self.rels.len() {
            return Err(Error::Data(format!("bad relationship {rel}")));
        }
        let t = match self.indexes.as_ref() {
            Some(ixs) => ixs[rel].lookup(from, to),
            None => {
                let tab = &self.rels[rel];
                (0..tab.len()).find(|&i| {
                    tab.from[i as usize] == from && tab.to[i as usize] == to
                })
            }
        }
        .ok_or_else(|| {
            Error::Data(format!("no relationship tuple ({from},{to}) to delete"))
        })?;
        let last = self.rels[rel].len() - 1;
        let (last_from, last_to) =
            (self.rels[rel].from[last as usize], self.rels[rel].to[last as usize]);
        let values = self.rels[rel].swap_remove(t)?;
        if let Some(ixs) = self.indexes.as_mut() {
            ixs[rel].remove_swap(from, to, t, last, last_from, last_to)?;
        }
        Ok(values)
    }

    /// Population size of an entity type.
    pub fn population(&self, et: usize) -> u64 {
        self.entities[et].len() as u64
    }

    /// Product of population sizes over a set of entity types.
    pub fn population_product(&self, ets: &[usize]) -> u64 {
        ets.iter().map(|&e| self.population(e).max(0)).product()
    }

    /// Total data rows (entity rows + relationship rows) — the paper's
    /// Table 4 "Row Count".
    pub fn total_rows(&self) -> u64 {
        self.entities.iter().map(|t| t.len() as u64).sum::<u64>()
            + self.rels.iter().map(|t| t.len() as u64).sum::<u64>()
    }

    /// Number of relationship tables (Table 4 "# Relationships").
    pub fn n_relationships(&self) -> usize {
        self.rels.len()
    }

    /// Approximate heap footprint in bytes (tables + indexes).
    pub fn bytes(&self) -> usize {
        self.entities.iter().map(|t| t.bytes()).sum::<usize>()
            + self.rels.iter().map(|t| t.bytes()).sum::<usize>()
            + self
                .indexes
                .as_ref()
                .map(|v| v.iter().map(|i| i.bytes()).sum())
                .unwrap_or(0)
    }

    /// Bytes resident per relationship index, in relationship order
    /// (empty when indexes are not built).  This is what makes storage
    /// wins attributable per relationship in `relcount count` / `exp`
    /// output instead of one lumped index number.
    pub fn index_bytes_per_rel(&self) -> Vec<usize> {
        self.indexes
            .as_ref()
            .map(|v| v.iter().map(|i| i.bytes()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures;

    #[test]
    fn university_fixture_valid() {
        let db = fixtures::university_db();
        assert!(db.has_indexes());
        assert_eq!(db.n_relationships(), 2);
        assert!(db.total_rows() > 0);
        assert_eq!(
            db.population_product(&[0, 1]),
            db.population(0) * db.population(1)
        );
    }

    #[test]
    fn index_lookup_matches_data() {
        let db = fixtures::university_db();
        let ix = db.index(0).unwrap();
        let t = &db.rels[0];
        for i in 0..t.len() {
            assert_eq!(ix.lookup(t.from[i as usize], t.to[i as usize]), Some(i));
        }
    }

    #[test]
    fn incremental_mutation_matches_rebuild() {
        let mut db = fixtures::university_db();
        // insert a fresh link ((0, 4) is not a Registered pair in the
        // fixture: (0 + 2*4) % 3 != 0), delete an existing one, add an
        // entity
        let id = db.insert_link(1, 0, 4, &[1]).unwrap();
        assert_eq!(db.index(1).unwrap().lookup(0, 4), Some(id));
        let removed = db.delete_link(0, 0, 0).unwrap();
        assert_eq!(removed.len(), 2);
        assert!(db.delete_link(0, 0, 0).is_err());
        let pid = db.insert_entity(0, &[2]).unwrap();
        assert_eq!(pid, 12);
        assert!(db.insert_link(0, pid, 0, &[0, 0]).is_ok());

        // the incrementally maintained db validates, and its indexes
        // agree with a from-scratch rebuild
        db.validate().unwrap();
        let fresh =
            Database::new(db.schema.clone(), db.entities.clone(), db.rels.clone())
                .unwrap();
        for rel in 0..db.rels.len() {
            let t = &db.rels[rel];
            assert_eq!(db.index(rel).unwrap().len(), t.len() as usize);
            assert_eq!(fresh.index(rel).unwrap().len(), t.len() as usize);
            for i in 0..t.len() {
                let (f, o) = (t.from[i as usize], t.to[i as usize]);
                assert_eq!(db.index(rel).unwrap().lookup(f, o), Some(i));
                assert_eq!(fresh.index(rel).unwrap().lookup(f, o), Some(i));
            }
        }
    }

    #[test]
    fn backend_switch_rebuilds_equivalent_indexes() {
        use crate::db::index::Backend;
        let mut db = fixtures::university_db();
        assert_eq!(db.backend(), Backend::Csr);
        let csr_pairs: Vec<Vec<Option<u32>>> = (0..db.rels.len())
            .map(|rel| {
                let t = &db.rels[rel];
                (0..t.len())
                    .map(|i| {
                        db.index(rel)
                            .unwrap()
                            .lookup(t.from[i as usize], t.to[i as usize])
                    })
                    .collect()
            })
            .collect();
        db.set_backend(Backend::Hash).unwrap();
        assert_eq!(db.backend(), Backend::Hash);
        for rel in 0..db.rels.len() {
            let t = &db.rels[rel];
            for i in 0..t.len() {
                assert_eq!(
                    db.index(rel)
                        .unwrap()
                        .lookup(t.from[i as usize], t.to[i as usize]),
                    csr_pairs[rel][i as usize]
                );
            }
        }
        // switching to the same backend is a no-op
        db.set_backend(Backend::Hash).unwrap();
        assert!(db.has_indexes());
        // and on to the compressed engine: same pair->tid mapping
        db.set_backend(Backend::Ccsr).unwrap();
        assert_eq!(db.backend(), Backend::Ccsr);
        for rel in 0..db.rels.len() {
            let t = &db.rels[rel];
            for i in 0..t.len() {
                assert_eq!(
                    db.index(rel)
                        .unwrap()
                        .lookup(t.from[i as usize], t.to[i as usize]),
                    csr_pairs[rel][i as usize]
                );
            }
        }
    }

    #[test]
    fn per_rel_index_bytes_track_backend() {
        use crate::db::index::Backend;
        let mut db = fixtures::university_db();
        let csr_bytes = db.index_bytes_per_rel();
        assert_eq!(csr_bytes.len(), db.n_relationships());
        assert!(csr_bytes.iter().all(|&b| b > 0));
        db.set_backend(Backend::Ccsr).unwrap();
        let ccsr_bytes = db.index_bytes_per_rel();
        assert_eq!(ccsr_bytes.len(), db.n_relationships());
        assert!(ccsr_bytes.iter().all(|&b| b > 0));
        db.invalidate_indexes();
        assert!(db.index_bytes_per_rel().is_empty());
    }

    #[test]
    fn mutation_overlay_compacts_on_demand() {
        let mut db = fixtures::university_db();
        assert_eq!(db.index_overlay_len(), 0);
        db.insert_link(1, 0, 4, &[1]).unwrap();
        db.delete_link(0, 0, 0).unwrap();
        assert!(db.index_overlay_len() > 0);
        db.compact_indexes();
        assert_eq!(db.index_overlay_len(), 0);
        assert_eq!(db.index(1).unwrap().lookup(0, 4), Some(db.rels[1].len() - 1));
        assert_eq!(db.index(0).unwrap().lookup(0, 0), None);
    }

    #[test]
    fn mutators_reject_bad_input() {
        let mut db = fixtures::university_db();
        assert!(db.insert_entity(9, &[0]).is_err());
        assert!(db.insert_entity(0, &[9]).is_err()); // card
        assert!(db.insert_link(0, 0, 999, &[0, 0]).is_err());
        assert!(db.insert_link(0, 0, 0, &[0, 0]).is_err()); // duplicate pair
        assert!(db.insert_link(0, 1, 0, &[9, 0]).is_err()); // bad value
        assert!(db.delete_link(0, 11, 18).is_err()); // absent pair
    }

    #[test]
    fn invalidate_then_error() {
        let mut db = fixtures::university_db();
        db.invalidate_indexes();
        assert!(db.index(0).is_err());
    }
}
