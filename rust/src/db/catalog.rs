//! The database: schema + tables + indexes.

use crate::db::index::RelIndex;
use crate::db::schema::Schema;
use crate::db::table::{EntityTable, RelTable};
use crate::error::{Error, Result};

/// An in-memory relational database.  Indexes are built explicitly with
/// [`Database::build_indexes`]; mutation invalidates them.
#[derive(Clone, Debug)]
pub struct Database {
    pub schema: Schema,
    pub entities: Vec<EntityTable>,
    pub rels: Vec<RelTable>,
    indexes: Option<Vec<RelIndex>>,
}

impl Database {
    /// Empty database over a schema.
    pub fn empty(schema: Schema) -> Self {
        let entities =
            schema.entities.iter().map(|e| EntityTable::new(e.attrs.len())).collect();
        let rels =
            schema.relationships.iter().map(|r| RelTable::new(r.attrs.len())).collect();
        Database { schema, entities, rels, indexes: None }
    }

    /// Construct from parts, validate, and build indexes.
    pub fn new(
        schema: Schema,
        entities: Vec<EntityTable>,
        rels: Vec<RelTable>,
    ) -> Result<Self> {
        let mut db = Database { schema, entities, rels, indexes: None };
        db.validate()?;
        db.build_indexes()?;
        Ok(db)
    }

    pub fn validate(&self) -> Result<()> {
        self.schema.validate()?;
        if self.entities.len() != self.schema.entities.len()
            || self.rels.len() != self.schema.relationships.len()
        {
            return Err(Error::Data("table count != schema type count".into()));
        }
        for (et, t) in self.entities.iter().enumerate() {
            t.validate(&self.schema, et)?;
        }
        for (rt, t) in self.rels.iter().enumerate() {
            t.validate(&self.schema, rt)?;
        }
        Ok(())
    }

    /// (Re)build all relationship indexes.
    pub fn build_indexes(&mut self) -> Result<()> {
        let mut ixs = Vec::with_capacity(self.rels.len());
        for (rt, t) in self.rels.iter().enumerate() {
            let (f, o) = self.schema.rel_endpoints(rt);
            ixs.push(RelIndex::build(t, self.entities[f].len(), self.entities[o].len())?);
        }
        self.indexes = Some(ixs);
        Ok(())
    }

    /// Index for a relationship; requires [`Database::build_indexes`].
    pub fn index(&self, rel: usize) -> Result<&RelIndex> {
        self.indexes
            .as_ref()
            .and_then(|v| v.get(rel))
            .ok_or_else(|| Error::Data("indexes not built (call build_indexes)".into()))
    }

    pub fn has_indexes(&self) -> bool {
        self.indexes.is_some()
    }

    /// Invalidate indexes (call after mutating tables).
    pub fn invalidate_indexes(&mut self) {
        self.indexes = None;
    }

    /// Population size of an entity type.
    pub fn population(&self, et: usize) -> u64 {
        self.entities[et].len() as u64
    }

    /// Product of population sizes over a set of entity types.
    pub fn population_product(&self, ets: &[usize]) -> u64 {
        ets.iter().map(|&e| self.population(e).max(0)).product()
    }

    /// Total data rows (entity rows + relationship rows) — the paper's
    /// Table 4 "Row Count".
    pub fn total_rows(&self) -> u64 {
        self.entities.iter().map(|t| t.len() as u64).sum::<u64>()
            + self.rels.iter().map(|t| t.len() as u64).sum::<u64>()
    }

    /// Number of relationship tables (Table 4 "# Relationships").
    pub fn n_relationships(&self) -> usize {
        self.rels.len()
    }

    /// Approximate heap footprint in bytes (tables + indexes).
    pub fn bytes(&self) -> usize {
        self.entities.iter().map(|t| t.bytes()).sum::<usize>()
            + self.rels.iter().map(|t| t.bytes()).sum::<usize>()
            + self
                .indexes
                .as_ref()
                .map(|v| v.iter().map(|i| i.bytes()).sum())
                .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures;

    #[test]
    fn university_fixture_valid() {
        let db = fixtures::university_db();
        assert!(db.has_indexes());
        assert_eq!(db.n_relationships(), 2);
        assert!(db.total_rows() > 0);
        assert_eq!(
            db.population_product(&[0, 1]),
            db.population(0) * db.population(1)
        );
    }

    #[test]
    fn index_lookup_matches_data() {
        let db = fixtures::university_db();
        let ix = db.index(0).unwrap();
        let t = &db.rels[0];
        for i in 0..t.len() {
            assert_eq!(ix.lookup(t.from[i as usize], t.to[i as usize]), Some(i));
        }
    }

    #[test]
    fn invalidate_then_error() {
        let mut db = fixtures::university_db();
        db.invalidate_indexes();
        assert!(db.index(0).is_err());
    }
}
