//! Compressed block-CSR adjacency: the third `--backend` (`ccsr`),
//! storing each sorted neighbor run as fixed-size delta-encoded,
//! bit-packed blocks with per-block min/max headers.
//!
//! Layout, per orientation ([`CcsrHalf`]): the entry-offset column is
//! identical to the plain CSR ([`crate::db::csr::CsrHalf::offsets`]),
//! but instead of two parallel `u32` columns the entries live in
//! [`BLOCK`]-sized blocks that never span rows.  Each block stores
//!
//! * `nbr_min` — the first neighbor, raw (also the skip header's lower
//!   bound);
//! * `nbr_max` — the last neighbor (the skip header's upper bound);
//! * the remaining `blen-1` neighbors as `delta - 1` values bit-packed
//!   at the block's `nbr_width` (consecutive hub runs pack at width 0);
//! * the `blen` tuple ids as offsets from the block's `tid_min`,
//!   bit-packed at `tid_width`.
//!
//! Intersections skip whole blocks by comparing the probe value against
//! the `nbr_min`/`nbr_max` headers before paying for a decode (see
//! [`crate::db::index::NeighborRun`]), and decode itself is chunked —
//! deltas unpack into a stack buffer in one plain loop, then a prefix
//! sum rebuilds the run — so the compiler can vectorize the hot parts.
//!
//! Churn reuses the plain CSR's sorted overlay verbatim
//! ([`crate::db::csr::Overlay`]): mutations never rewrite packed
//! blocks, reads merge the overlay exactly like the CSR engine, and
//! [`CcsrIndex::compact`] decodes + merges + re-encodes each
//! orientation.  The one structural difference: relabeling a
//! base-resident tuple id after a swap-remove cannot patch the packed
//! bytes in place, so it tombstones the pair and re-adds it with the
//! fresh tid (the overlay merge and compaction already handle
//! tombstone-with-readd for the delete-then-reinsert case).
//!
//! Equivalence with the `csr` and `hash` backends at all times — counts,
//! `JoinStats`, cache digests, snapshot round-trips — is held by
//! `rust/tests/proptest_invariants.rs` and the `compress-smoke` CI lane.

use crate::db::csr::{isqrt, Overlay, NBR_MASK, OVERLAY_SLACK};
use crate::db::index::pair_key;
use crate::db::table::RelTable;
use crate::error::{Error, Result};

/// Entries per packed block.  64 keeps the decode buffers on the stack,
/// the per-block header cost under half a bit per entry, and one block's
/// deltas inside a couple of cache lines at typical widths.
pub const BLOCK: usize = 64;

/// Bits needed to represent `v` (0 for 0 — width-0 fields occupy no
/// payload bits at all).
#[inline]
fn bits_for(v: u32) -> u8 {
    (32 - v.leading_zeros()) as u8
}

/// Append `width` low bits of `v` to the packed stream.  Widths are at
/// most 32 (values are `u32`), so a write spills into at most one
/// following word; the spill shift `64 - off` is only taken when
/// `off + width > 64`, i.e. `off >= 33`, keeping it in `1..=31`.
fn push_bits(packed: &mut Vec<u64>, bit_len: &mut u64, width: u8, v: u64) {
    if width == 0 {
        return;
    }
    debug_assert!(width <= 32 && v < (1u64 << width));
    let word = (*bit_len / 64) as usize;
    let off = (*bit_len % 64) as u32;
    while packed.len() < word + 2 {
        packed.push(0);
    }
    packed[word] |= v << off;
    if off + width as u32 > 64 {
        packed[word + 1] |= v >> (64 - off);
    }
    *bit_len += width as u64;
}

/// Read `width` bits at `bit_pos` (the inverse of [`push_bits`]).
#[inline]
fn get_bits(packed: &[u64], bit_pos: u64, width: u8) -> u64 {
    if width == 0 {
        return 0;
    }
    let word = (bit_pos / 64) as usize;
    let off = (bit_pos % 64) as u32;
    let mut v = packed[word] >> off;
    if off + width as u32 > 64 {
        v |= packed[word + 1] << (64 - off);
    }
    v & ((1u64 << width) - 1)
}

/// One orientation of the compressed adjacency.  `offsets` has the
/// same semantics as the plain CSR entry bounds; `blk_offsets` bounds
/// each row's blocks; the five header columns and `data_off` are
/// indexed by global block number; `packed` holds every block's payload
/// bits back to back (trimmed to exactly `ceil(bits / 64)` words so the
/// encoding — and hence snapshot bytes and checksums — is a pure
/// function of the content).
#[derive(Clone, Debug, Default)]
pub struct CcsrHalf {
    /// Entry bounds per row; `len() == rows + 1`.
    pub offsets: Vec<u32>,
    /// Block-index bounds per row; `len() == rows + 1`.
    pub blk_offsets: Vec<u32>,
    /// First neighbor of each block, stored raw.
    pub nbr_min: Vec<u32>,
    /// Last neighbor of each block (skip header).
    pub nbr_max: Vec<u32>,
    /// Smallest tuple id in each block.
    pub tid_min: Vec<u32>,
    /// Bits per `delta - 1` neighbor gap in each block.
    pub nbr_width: Vec<u8>,
    /// Bits per `tid - tid_min` offset in each block.
    pub tid_width: Vec<u8>,
    /// Bit offset of each block's payload; `len() == blocks + 1`.
    pub data_off: Vec<u64>,
    /// Bit-packed payload words.
    pub packed: Vec<u64>,
}

impl CcsrHalf {
    fn rows(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    fn run(&self, r: u32) -> (usize, usize) {
        (self.offsets[r as usize] as usize, self.offsets[r as usize + 1] as usize)
    }

    /// Total base entries (live pairs before overlay adjustments).
    fn base_len(&self) -> usize {
        *self.offsets.last().expect("offsets non-empty") as usize
    }

    /// Build from `(row, nbr, tid)` triples (sorted in place), mirroring
    /// [`crate::db::csr::CsrHalf`]'s capacity guard on the offset column.
    fn build(mut triples: Vec<(u32, u32, u32)>, rows: usize) -> Result<CcsrHalf> {
        Error::check_u32_capacity("ccsr offset column", triples.len() as u64)?;
        triples.sort_unstable();
        let mut offsets = vec![0u32; rows + 1];
        for &(r, _, _) in &triples {
            offsets[r as usize + 1] += 1;
        }
        for i in 0..rows {
            offsets[i + 1] += offsets[i];
        }
        let nbr: Vec<u32> = triples.iter().map(|t| t.1).collect();
        let tid: Vec<u32> = triples.iter().map(|t| t.2).collect();
        Ok(Self::encode(offsets, &nbr, &tid))
    }

    /// Encode flat sorted columns (CSR shape) into packed blocks.
    fn encode(offsets: Vec<u32>, nbr: &[u32], tid: &[u32]) -> CcsrHalf {
        let rows = offsets.len() - 1;
        let mut h = CcsrHalf {
            blk_offsets: Vec::with_capacity(rows + 1),
            ..CcsrHalf::default()
        };
        h.blk_offsets.push(0);
        h.data_off.push(0);
        let mut bit_len = 0u64;
        for r in 0..rows {
            let (lo, hi) = (offsets[r] as usize, offsets[r + 1] as usize);
            let mut pos = lo;
            while pos < hi {
                let blen = (hi - pos).min(BLOCK);
                let bn = &nbr[pos..pos + blen];
                let bt = &tid[pos..pos + blen];
                let tmn = *bt.iter().min().expect("non-empty block");
                let nw = bn
                    .windows(2)
                    .map(|w| w[1] - w[0] - 1)
                    .max()
                    .map_or(0, bits_for);
                let tw = bt.iter().map(|&t| t - tmn).max().map_or(0, bits_for);
                h.nbr_min.push(bn[0]);
                h.nbr_max.push(bn[blen - 1]);
                h.tid_min.push(tmn);
                h.nbr_width.push(nw);
                h.tid_width.push(tw);
                for w in bn.windows(2) {
                    push_bits(&mut h.packed, &mut bit_len, nw, (w[1] - w[0] - 1) as u64);
                }
                for &t in bt {
                    push_bits(&mut h.packed, &mut bit_len, tw, (t - tmn) as u64);
                }
                h.data_off.push(bit_len);
                pos += blen;
            }
            h.blk_offsets.push(h.nbr_min.len() as u32);
        }
        // trim the spare spill word so the byte image is canonical
        h.packed.truncate(((bit_len + 63) / 64) as usize);
        h.offsets = offsets;
        h
    }

    /// Decode global block `g` (holding `blen` entries) into the output
    /// buffers: deltas unpack into a stack buffer in one plain loop,
    /// then a prefix sum rebuilds the neighbors (wrapping so corrupt
    /// persisted widths surface as validation errors, not panics).
    fn decode_block(&self, g: usize, blen: usize, nbr: &mut [u32; BLOCK], tid: &mut [u32; BLOCK]) {
        let nw = self.nbr_width[g];
        let tw = self.tid_width[g];
        let mut pos = self.data_off[g];
        let mut dbuf = [0u32; BLOCK];
        for d in dbuf[1..blen].iter_mut() {
            *d = get_bits(&self.packed, pos, nw) as u32;
            pos += nw as u64;
        }
        let mut acc = self.nbr_min[g];
        nbr[0] = acc;
        for i in 1..blen {
            acc = acc.wrapping_add(dbuf[i]).wrapping_add(1);
            nbr[i] = acc;
        }
        let tmn = self.tid_min[g];
        for t in tid[..blen].iter_mut() {
            *t = tmn.wrapping_add(get_bits(&self.packed, pos, tw) as u32);
            pos += tw as u64;
        }
    }

    /// Borrow row `r` as a block run.
    fn block_run(&self, r: u32) -> BlockRun<'_> {
        let (lo, hi) = self.run(r);
        BlockRun {
            half: self,
            len: hi - lo,
            blk0: self.blk_offsets[r as usize] as usize,
        }
    }

    /// Tuple id of `(r, x)` if present in the base blocks: skip to the
    /// candidate block by header, then decode and binary-search it.
    fn find(&self, r: u32, x: u32) -> Option<u32> {
        let blo = self.blk_offsets[r as usize] as usize;
        let bhi = self.blk_offsets[r as usize + 1] as usize;
        let b = blo + self.nbr_max[blo..bhi].partition_point(|&m| m < x);
        if b == bhi || self.nbr_min[b] > x {
            return None;
        }
        let (lo, hi) = self.run(r);
        let blen = (hi - lo - (b - blo) * BLOCK).min(BLOCK);
        let mut nb = [0u32; BLOCK];
        let mut tb = [0u32; BLOCK];
        self.decode_block(b, blen, &mut nb, &mut tb);
        nb[..blen].binary_search(&x).ok().map(|p| tb[p])
    }

    fn grow(&mut self, rows: usize) {
        let last = *self.offsets.last().expect("offsets non-empty");
        let blast = *self.blk_offsets.last().expect("blk_offsets non-empty");
        while self.offsets.len() < rows + 1 {
            self.offsets.push(last);
            self.blk_offsets.push(blast);
        }
    }

    fn bytes(&self) -> usize {
        (self.offsets.capacity()
            + self.blk_offsets.capacity()
            + self.nbr_min.capacity()
            + self.nbr_max.capacity()
            + self.tid_min.capacity())
            * 4
            + self.nbr_width.capacity()
            + self.tid_width.capacity()
            + (self.data_off.capacity() + self.packed.capacity()) * 8
    }
}

/// A borrowed clean row of packed blocks.  The skip headers
/// ([`BlockRun::seek_block`]) let intersections reject whole blocks
/// before decoding; [`BlockRun::decode_block`] materializes one block
/// into caller-provided stack buffers.
#[derive(Clone, Copy)]
pub struct BlockRun<'a> {
    half: &'a CcsrHalf,
    len: usize,
    blk0: usize,
}

impl<'a> BlockRun<'a> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocks in this run.
    pub fn n_blocks(&self) -> usize {
        (self.len + BLOCK - 1) / BLOCK
    }

    /// Entries in row-local block `b`.
    pub fn block_len(&self, b: usize) -> usize {
        (self.len - b * BLOCK).min(BLOCK)
    }

    /// Smallest neighbor in row-local block `b` (header read, no decode).
    pub fn block_min(&self, b: usize) -> u32 {
        self.half.nbr_min[self.blk0 + b]
    }

    /// Largest neighbor in row-local block `b` (header read, no decode).
    pub fn block_max(&self, b: usize) -> u32 {
        self.half.nbr_max[self.blk0 + b]
    }

    /// First row-local block at or after `b_from` whose `nbr_max` is
    /// `>= x` ([`Self::n_blocks`] if none) — the skip-intersection
    /// primitive: every earlier block provably holds only values `< x`.
    pub fn seek_block(&self, b_from: usize, x: u32) -> usize {
        let s = &self.half.nbr_max[self.blk0 + b_from..self.blk0 + self.n_blocks()];
        b_from + s.partition_point(|&m| m < x)
    }

    /// Decode row-local block `b` into the buffers; returns its length.
    pub fn decode_block(&self, b: usize, nbr: &mut [u32; BLOCK], tid: &mut [u32; BLOCK]) -> usize {
        let blen = self.block_len(b);
        self.half.decode_block(self.blk0 + b, blen, nbr, tid);
        blen
    }

    /// Entry `k` of the run (decodes `k`'s block; for one-off draws like
    /// the sampler's canonical-order walk, not for iteration).
    pub fn get(&self, k: usize) -> (u32, u32) {
        debug_assert!(k < self.len);
        let mut nb = [0u32; BLOCK];
        let mut tb = [0u32; BLOCK];
        self.decode_block(k / BLOCK, &mut nb, &mut tb);
        (nb[k % BLOCK], tb[k % BLOCK])
    }

    /// Materialize the whole run as sorted `(neighbor, tid)` pairs.
    pub fn to_pairs(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.len);
        let mut nb = [0u32; BLOCK];
        let mut tb = [0u32; BLOCK];
        for b in 0..self.n_blocks() {
            let blen = self.decode_block(b, &mut nb, &mut tb);
            out.extend(nb[..blen].iter().copied().zip(tb[..blen].iter().copied()));
        }
        out
    }
}

/// One row of a compressed orientation, merged with any overlay
/// entries.  Unlike [`crate::db::csr::CsrRow`] the clean arm cannot
/// lend column slices — entries live in packed blocks — so it lends the
/// block run itself.
pub enum CcsrRow<'a> {
    /// No overlay entries touch this row: borrow the packed blocks.
    Clean(BlockRun<'a>),
    /// Overlay entries touch this row: a materialized `(nbr, tid)` run,
    /// still strictly ascending by neighbor.
    Dirty(Vec<(u32, u32)>),
}

/// Compressed block-CSR index over one relationship table: both
/// orientations plus their overlays.  The API mirrors
/// [`crate::db::csr::CsrIndex`] so [`crate::db::index::RelIx`] can
/// dispatch on the backend.
#[derive(Clone, Debug, Default)]
pub struct CcsrIndex {
    /// from -> packed sorted (to, tid) runs.
    fwd: CcsrHalf,
    /// to -> packed sorted (from, tid) runs.
    rev: CcsrHalf,
    ov_fwd: Overlay,
    ov_rev: Overlay,
}

impl CcsrIndex {
    /// Build from a table (same contract as
    /// [`crate::db::csr::CsrIndex::build`]: rejects out-of-range
    /// endpoints and duplicate pairs).
    pub fn build(table: &RelTable, n_from: u32, n_to: u32) -> Result<CcsrIndex> {
        let n = table.len() as usize;
        let mut f_triples = Vec::with_capacity(n);
        let mut r_triples = Vec::with_capacity(n);
        for t in 0..table.len() {
            let f = table.from[t as usize];
            let o = table.to[t as usize];
            if f >= n_from || o >= n_to {
                return Err(Error::Data(format!(
                    "rel tuple ({f},{o}) out of population range ({n_from},{n_to})"
                )));
            }
            f_triples.push((f, o, t));
            r_triples.push((o, f, t));
        }
        f_triples.sort_unstable();
        for w in f_triples.windows(2) {
            if (w[0].0, w[0].1) == (w[1].0, w[1].1) {
                return Err(Error::Data(format!(
                    "duplicate relationship pair ({},{})",
                    w[0].0, w[0].1
                )));
            }
        }
        let fwd = CcsrHalf::build(f_triples, n_from as usize)?;
        let rev = CcsrHalf::build(r_triples, n_to as usize)?;
        Ok(CcsrIndex {
            fwd,
            rev,
            ov_fwd: Overlay::default(),
            ov_rev: Overlay::default(),
        })
    }

    /// Tuple id for a fully-bound pair, if the relationship holds
    /// (overlay-aware: pending inserts win, tombstones hide base
    /// entries).
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        if from as usize >= self.fwd.rows() || to as usize >= self.rev.rows() {
            return None;
        }
        if !self.ov_fwd.is_empty() {
            let k = pair_key(from, to);
            if let Ok(p) = self.ov_fwd.add.binary_search_by_key(&k, |e| e.0) {
                return Some(self.ov_fwd.add[p].1);
            }
            if self.ov_fwd.del.binary_search(&k).is_ok() {
                return None;
            }
        }
        self.fwd.find(from, to)
    }

    /// Live adjacency degree of `from`.
    pub fn degree_from(&self, f: u32) -> usize {
        let (lo, hi) = self.fwd.run(f);
        hi - lo - self.ov_fwd.del_range(f).len() + self.ov_fwd.add_range(f).len()
    }

    /// Live adjacency degree of `to`.
    pub fn degree_to(&self, t: u32) -> usize {
        let (lo, hi) = self.rev.run(t);
        hi - lo - self.ov_rev.del_range(t).len() + self.ov_rev.add_range(t).len()
    }

    /// The from-oriented row, merged with the overlay when necessary.
    pub fn row_from(&self, f: u32) -> CcsrRow<'_> {
        Self::row(&self.fwd, &self.ov_fwd, f)
    }

    /// The to-oriented row, merged with the overlay when necessary.
    pub fn row_to(&self, t: u32) -> CcsrRow<'_> {
        Self::row(&self.rev, &self.ov_rev, t)
    }

    /// The packed block run of `from`, available only when no overlay
    /// entry touches the row (same cleanliness contract as
    /// [`crate::db::csr::CsrIndex::sorted_run_from`]; dirty rows fall
    /// back to generic enumeration).
    pub fn block_run_from(&self, f: u32) -> Option<BlockRun<'_>> {
        if self.ov_fwd.is_empty() || !self.ov_fwd.touches(f) {
            Some(self.fwd.block_run(f))
        } else {
            None
        }
    }

    /// The packed block run of `to` (see [`CcsrIndex::block_run_from`]).
    pub fn block_run_to(&self, t: u32) -> Option<BlockRun<'_>> {
        if self.ov_rev.is_empty() || !self.ov_rev.touches(t) {
            Some(self.rev.block_run(t))
        } else {
            None
        }
    }

    fn row<'a>(half: &'a CcsrHalf, ov: &'a Overlay, r: u32) -> CcsrRow<'a> {
        if ov.is_empty() || !ov.touches(r) {
            return CcsrRow::Clean(half.block_run(r));
        }
        CcsrRow::Dirty(Self::merge_row(half, ov, r))
    }

    /// Decode row `r` and merge the overlay into a sorted `(nbr, tid)`
    /// run — the same merge as [`crate::db::csr::CsrIndex`]'s dirty-row
    /// path (adds interleave by key, tombstones drop base entries, a
    /// tombstone-with-readd carries the fresh tid).
    fn merge_row(half: &CcsrHalf, ov: &Overlay, r: u32) -> Vec<(u32, u32)> {
        let base = half.block_run(r).to_pairs();
        let adds = ov.add_range(r);
        let dels = ov.del_range(r);
        let mut out = Vec::with_capacity(base.len() + adds.len());
        let (mut ai, mut di) = (0, 0);
        for &(n, t) in &base {
            while ai < adds.len() && ((adds[ai].0 & NBR_MASK) as u32) < n {
                out.push(((adds[ai].0 & NBR_MASK) as u32, adds[ai].1));
                ai += 1;
            }
            if di < dels.len() && (dels[di] & NBR_MASK) as u32 == n {
                di += 1;
                if ai < adds.len() && (adds[ai].0 & NBR_MASK) as u32 == n {
                    out.push((n, adds[ai].1));
                    ai += 1;
                }
                continue;
            }
            out.push((n, t));
        }
        for &(k, t) in &adds[ai..] {
            out.push(((k & NBR_MASK) as u32, t));
        }
        out
    }

    /// Extend both orientations to cover grown endpoint populations.
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        if self.fwd.rows() < n_from as usize {
            self.fwd.grow(n_from as usize);
        }
        if self.rev.rows() < n_to as usize {
            self.rev.grow(n_to as usize);
        }
    }

    /// Register a freshly appended tuple `t = (from, to)` in the
    /// overlay.
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        if from as usize >= self.fwd.rows() || to as usize >= self.rev.rows() {
            return Err(Error::Data(format!(
                "rel tuple ({from},{to}) out of population range ({},{})",
                self.fwd.rows(),
                self.rev.rows()
            )));
        }
        if self.lookup(from, to).is_some() {
            return Err(Error::Data(format!(
                "duplicate relationship pair ({from},{to})"
            )));
        }
        Error::check_u32_capacity("ccsr live pairs", self.len() as u64 + 1)?;
        self.ov_fwd.insert_add(pair_key(from, to), t);
        self.ov_rev.insert_add(pair_key(to, from), t);
        self.maybe_compact();
        Ok(())
    }

    /// Unregister tuple `t = (from, to)` after a
    /// [`RelTable::swap_remove`], relabeling the moved tuple
    /// `last -> t`.  Packed blocks are immutable, so a base-resident
    /// relabel goes through the overlay as tombstone + re-add with the
    /// fresh tid instead of patching the tid column in place.
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self.lookup(from, to) {
            Some(id) if id == t => {}
            _ => {
                return Err(Error::Data(format!(
                    "index out of sync removing ({from},{to}) id {t}"
                )))
            }
        }
        let fk = pair_key(from, to);
        if let Ok(p) = self.ov_fwd.add.binary_search_by_key(&fk, |e| e.0) {
            self.ov_fwd.add.remove(p);
            let rk = pair_key(to, from);
            let q = self
                .ov_rev
                .add
                .binary_search_by_key(&rk, |e| e.0)
                .expect("overlay orientations in sync");
            self.ov_rev.add.remove(q);
        } else {
            self.ov_fwd.insert_del(fk);
            self.ov_rev.insert_del(pair_key(to, from));
        }
        if t != last {
            let lk = pair_key(last_from, last_to);
            if let Ok(p) = self.ov_fwd.add.binary_search_by_key(&lk, |e| e.0) {
                self.ov_fwd.add[p].1 = t;
                let rk = pair_key(last_to, last_from);
                let q = self
                    .ov_rev
                    .add
                    .binary_search_by_key(&rk, |e| e.0)
                    .expect("overlay orientations in sync");
                self.ov_rev.add[q].1 = t;
            } else {
                // base-resident: tombstone + re-add with the fresh tid
                debug_assert!(self.fwd.find(last_from, last_to).is_some());
                self.ov_fwd.insert_del(lk);
                self.ov_fwd.insert_add(lk, t);
                let rk = pair_key(last_to, last_from);
                self.ov_rev.insert_del(rk);
                self.ov_rev.insert_add(rk, t);
            }
        }
        self.maybe_compact();
        Ok(())
    }

    /// Live pair count.
    pub fn len(&self) -> usize {
        self.fwd.base_len() - self.ov_fwd.del.len() + self.ov_fwd.add.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pending overlay entries across both orientations.
    pub fn overlay_len(&self) -> usize {
        self.ov_fwd.len() + self.ov_rev.len()
    }

    /// Largest live degree in either orientation.
    pub fn max_degree(&self) -> usize {
        if self.ov_fwd.is_empty() && self.ov_rev.is_empty() {
            let f = self.fwd.offsets.windows(2).map(|w| (w[1] - w[0]) as usize);
            let t = self.rev.offsets.windows(2).map(|w| (w[1] - w[0]) as usize);
            f.max().unwrap_or(0).max(t.max().unwrap_or(0))
        } else {
            let f = (0..self.fwd.rows()).map(|r| self.degree_from(r as u32));
            let t = (0..self.rev.rows()).map(|r| self.degree_to(r as u32));
            f.max().unwrap_or(0).max(t.max().unwrap_or(0))
        }
    }

    /// Merge the overlay into freshly re-encoded blocks (decode + merge
    /// + re-encode per orientation); afterwards every row is clean and
    /// [`CcsrIndex::overlay_len`] is zero.
    pub fn compact(&mut self) {
        if !self.ov_fwd.is_empty() {
            self.fwd = Self::compact_half(&self.fwd, &mut self.ov_fwd);
        }
        if !self.ov_rev.is_empty() {
            self.rev = Self::compact_half(&self.rev, &mut self.ov_rev);
        }
    }

    fn maybe_compact(&mut self) {
        let threshold = OVERLAY_SLACK + isqrt(self.fwd.base_len());
        if self.ov_fwd.len() > threshold || self.ov_rev.len() > threshold {
            self.compact();
        }
    }

    fn compact_half(half: &CcsrHalf, ov: &mut Overlay) -> CcsrHalf {
        let rows = half.rows();
        let new_len = half.base_len() - ov.del.len() + ov.add.len();
        let mut offsets = Vec::with_capacity(rows + 1);
        let mut nbr = Vec::with_capacity(new_len);
        let mut tid = Vec::with_capacity(new_len);
        offsets.push(0u32);
        let mut nb = [0u32; BLOCK];
        let mut tb = [0u32; BLOCK];
        for r in 0..rows as u32 {
            if ov.touches(r) {
                for (n, t) in Self::merge_row(half, ov, r) {
                    nbr.push(n);
                    tid.push(t);
                }
            } else {
                let run = half.block_run(r);
                for b in 0..run.n_blocks() {
                    let blen = run.decode_block(b, &mut nb, &mut tb);
                    nbr.extend_from_slice(&nb[..blen]);
                    tid.extend_from_slice(&tb[..blen]);
                }
            }
            offsets.push(nbr.len() as u32);
        }
        debug_assert_eq!(nbr.len(), new_len);
        ov.add.clear();
        ov.del.clear();
        CcsrHalf::encode(offsets, &nbr, &tid)
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.fwd.bytes() + self.rev.bytes() + self.ov_fwd.bytes() + self.ov_rev.bytes()
    }

    /// The packed halves of both orientations, for snapshot
    /// serialization.  Only a clean index can be serialized — callers
    /// must [`CcsrIndex::compact`] first.
    pub fn halves(&self) -> Result<(&CcsrHalf, &CcsrHalf)> {
        if self.overlay_len() != 0 {
            return Err(Error::Data(
                "cannot serialize a ccsr index with a pending overlay; compact first"
                    .into(),
            ));
        }
        Ok((&self.fwd, &self.rev))
    }

    /// Rebuild an index from persisted halves (the snapshot-restore
    /// path), validating the block structure so corrupt-but-checksummed
    /// inputs can never produce out-of-bounds reads or silent count
    /// divergence: header/offset arithmetic first (so every subsequent
    /// payload read is provably in bounds), then a full decode checking
    /// strict ascent, population/tuple ranges, and header consistency.
    pub fn from_halves(fwd: CcsrHalf, rev: CcsrHalf) -> Result<CcsrIndex> {
        Self::validate_half(&fwd, rev.offsets.len().saturating_sub(1), "fwd")?;
        Self::validate_half(&rev, fwd.offsets.len().saturating_sub(1), "rev")?;
        if fwd.base_len() != rev.base_len() {
            return Err(Error::Data(format!(
                "ccsr orientations disagree on pair count ({} vs {})",
                fwd.base_len(),
                rev.base_len()
            )));
        }
        Ok(CcsrIndex {
            fwd,
            rev,
            ov_fwd: Overlay::default(),
            ov_rev: Overlay::default(),
        })
    }

    fn validate_half(h: &CcsrHalf, n_opposite: usize, side: &str) -> Result<()> {
        let err = |m: String| Error::Data(format!("ccsr {side} half: {m}"));
        if h.offsets.is_empty() || h.offsets[0] != 0 {
            return Err(err("offsets must start at 0".into()));
        }
        if h.offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err(err("offsets not monotone".into()));
        }
        if h.blk_offsets.len() != h.offsets.len() || h.blk_offsets[0] != 0 {
            return Err(err("block offsets inconsistent with offsets".into()));
        }
        for r in 0..h.rows() {
            let run = (h.offsets[r + 1] - h.offsets[r]) as usize;
            let blks = h
                .blk_offsets[r + 1]
                .checked_sub(h.blk_offsets[r])
                .ok_or_else(|| err("block offsets not monotone".into()))?
                as usize;
            if blks != (run + BLOCK - 1) / BLOCK {
                return Err(err(format!("row {r}: {run} entries but {blks} blocks")));
            }
        }
        let n_blocks = *h.blk_offsets.last().unwrap() as usize;
        if h.nbr_min.len() != n_blocks
            || h.nbr_max.len() != n_blocks
            || h.tid_min.len() != n_blocks
            || h.nbr_width.len() != n_blocks
            || h.tid_width.len() != n_blocks
            || h.data_off.len() != n_blocks + 1
        {
            return Err(err("header column lengths inconsistent".into()));
        }
        if h.data_off[0] != 0 {
            return Err(err("payload must start at bit 0".into()));
        }
        if h.nbr_width.iter().chain(h.tid_width.iter()).any(|&w| w > 32) {
            return Err(err("field width exceeds 32 bits".into()));
        }
        // bit-offset contiguity: each block's payload is exactly its
        // (blen-1) deltas plus blen tid offsets, back to back
        let total = h.base_len();
        let mut g = 0usize;
        for r in 0..h.rows() {
            let mut left = (h.offsets[r + 1] - h.offsets[r]) as usize;
            while left > 0 {
                let blen = left.min(BLOCK) as u64;
                let want = h.data_off[g]
                    + (blen - 1) * h.nbr_width[g] as u64
                    + blen * h.tid_width[g] as u64;
                if h.data_off[g + 1] != want {
                    return Err(err(format!("block {g}: payload bits not contiguous")));
                }
                left -= blen as usize;
                g += 1;
            }
        }
        let final_bits = h.data_off[n_blocks];
        if h.packed.len() as u64 != (final_bits + 63) / 64 {
            return Err(err(format!(
                "packed length {} words inconsistent with {final_bits} payload bits",
                h.packed.len()
            )));
        }
        // full decode: strict ascent within rows (across block seams
        // too), ids in range, headers matching the decoded content
        let mut nb = [0u32; BLOCK];
        let mut tb = [0u32; BLOCK];
        for r in 0..h.rows() {
            let run = h.block_run(r as u32);
            let mut prev: Option<u32> = None;
            for b in 0..run.n_blocks() {
                let blen = run.decode_block(b, &mut nb, &mut tb);
                if nb[0] != run.block_min(b) || nb[blen - 1] != run.block_max(b) {
                    return Err(err(format!("row {r}: block header/content mismatch")));
                }
                for i in 0..blen {
                    if prev.map_or(false, |p| p >= nb[i]) {
                        return Err(err(format!("row {r}: neighbor run not strictly ascending")));
                    }
                    prev = Some(nb[i]);
                    if nb[i] as usize >= n_opposite {
                        return Err(err("neighbor id out of population range".into()));
                    }
                    if tb[i] as usize >= total {
                        return Err(err("tuple id out of range".into()));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::csr::CsrIndex;

    fn table() -> RelTable {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        t
    }

    fn nbrs(ix: &CcsrIndex, f: u32) -> Vec<(u32, u32)> {
        match ix.row_from(f) {
            CcsrRow::Clean(run) => run.to_pairs(),
            CcsrRow::Dirty(v) => v,
        }
    }

    #[test]
    fn builds_packed_runs_and_lookup() {
        let t = table();
        let ix = CcsrIndex::build(&t, 2, 3).unwrap();
        assert_eq!(nbrs(&ix, 0), vec![(1, 0), (2, 1)]);
        assert_eq!(ix.lookup(0, 2), Some(1));
        assert_eq!(ix.lookup(1, 2), None);
        assert_eq!(ix.degree_from(0), 2);
        assert_eq!(ix.degree_to(1), 2);
        assert_eq!(ix.len(), 3);
        assert_eq!(ix.max_degree(), 2);
        let run = ix.block_run_from(0).unwrap();
        assert_eq!(run.get(0), (1, 0));
        assert_eq!(run.get(1), (2, 1));
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        assert!(CcsrIndex::build(&t, 2, 2).is_err());

        let mut t2 = RelTable::new(0);
        t2.push(5, 0, &[]).unwrap();
        assert!(CcsrIndex::build(&t2, 2, 2).is_err());
    }

    #[test]
    fn multi_block_rows_decode_exactly_and_skip_by_header() {
        // a 200-entry hub row with irregular gaps spanning 4 blocks
        let mut t = RelTable::new(0);
        let mut expect = Vec::new();
        let mut v = 0u32;
        for i in 0..200u32 {
            v += 1 + (i * 7) % 5; // gaps 1..=5, deterministic
            t.push(0, v, &[]).unwrap();
            expect.push((v, i));
        }
        let ix = CcsrIndex::build(&t, 1, v + 1).unwrap();
        let run = ix.block_run_from(0).unwrap();
        assert_eq!(run.len(), 200);
        assert_eq!(run.n_blocks(), 4);
        assert_eq!(run.to_pairs(), expect);
        for (k, &(n, id)) in expect.iter().enumerate() {
            assert_eq!(run.get(k), (n, id), "entry {k}");
            assert_eq!(ix.lookup(0, n), Some(id));
        }
        // headers bound each block exactly
        for b in 0..run.n_blocks() {
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(200);
            assert_eq!(run.block_min(b), expect[lo].0);
            assert_eq!(run.block_max(b), expect[hi - 1].0);
        }
        // seek_block lands on the first block that can hold the probe
        let probe = expect[130].0;
        let b = run.seek_block(0, probe);
        assert_eq!(b, 130 / BLOCK);
        assert_eq!(run.seek_block(0, v + 1), run.n_blocks());
        // a value between runs is absent but findable-block-wise
        assert_eq!(ix.lookup(0, 0), None);
    }

    #[test]
    fn hub_rows_compress_well_below_plain_csr() {
        // consecutive neighbors (delta 1 -> width 0) with in-order tids:
        // the shape the skewed synth generators produce at scale
        let mut t = RelTable::new(0);
        for v in 0..4096u32 {
            t.push(0, v, &[]).unwrap();
        }
        let ccsr = CcsrIndex::build(&t, 1, 4096).unwrap();
        let csr = CsrIndex::build(&t, 1, 4096).unwrap();
        assert!(
            ccsr.bytes() * 2 < csr.bytes(),
            "ccsr {} bytes vs csr {} bytes",
            ccsr.bytes(),
            csr.bytes()
        );
    }

    #[test]
    fn overlay_insert_delete_reads_like_rebuild() {
        let mut t = table();
        let mut ix = CcsrIndex::build(&t, 2, 3).unwrap();

        let id = t.push(1, 2, &[]).unwrap();
        ix.insert(1, 2, id).unwrap();
        assert!(ix.insert(1, 2, 9).is_err()); // duplicate
        assert_eq!(ix.lookup(1, 2), Some(3));
        assert_eq!(ix.degree_from(1), 2);
        assert!(ix.block_run_from(1).is_none(), "dirty row must not lend a run");
        assert!(ix.block_run_from(0).is_some(), "untouched row stays clean");
        assert_eq!(nbrs(&ix, 1), vec![(1, 2), (2, 3)]);
        assert!(ix.overlay_len() > 0);

        // delete (0, 2): the last tuple (1,2) takes id 1
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(1).unwrap();
        ix.remove_swap(0, 2, 1, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 2), None);
        assert_eq!(ix.lookup(1, 2), Some(1));
        assert_eq!(ix.degree_from(0), 1);
        assert_eq!(ix.len(), t.len() as usize);

        let fresh = CcsrIndex::build(&t, 2, 3).unwrap();
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f}");
        }
        ix.compact();
        assert_eq!(ix.overlay_len(), 0);
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f} after compact");
        }
        assert_eq!(ix.lookup(1, 2), fresh.lookup(1, 2));
    }

    #[test]
    fn base_resident_relabel_goes_through_tombstone_readd() {
        // delete tuple 0 while the moved last tuple lives in the packed
        // base: its relabel must tombstone + re-add with the fresh tid
        let mut t = table();
        let mut ix = CcsrIndex::build(&t, 2, 3).unwrap();
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(0).unwrap();
        ix.remove_swap(0, 1, 0, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 1), None);
        assert_eq!(ix.lookup(1, 1), Some(0), "relabeled tid must win over base");
        assert_eq!(ix.degree_from(1), 1);
        let fresh = CcsrIndex::build(&t, 2, 3).unwrap();
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f}");
        }
        ix.compact();
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f} after compact");
        }
    }

    #[test]
    fn delete_then_reinsert_same_pair() {
        let mut t = table();
        let mut ix = CcsrIndex::build(&t, 2, 3).unwrap();
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(0).unwrap();
        ix.remove_swap(0, 1, 0, last, lf, lt).unwrap();
        let id = t.push(0, 1, &[]).unwrap();
        ix.insert(0, 1, id).unwrap();
        assert_eq!(ix.lookup(0, 1), Some(id));
        assert_eq!(nbrs(&ix, 0), vec![(1, id), (2, 1)]);
        ix.compact();
        let fresh = CcsrIndex::build(&t, 2, 3).unwrap();
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f}");
        }
    }

    #[test]
    fn halves_roundtrip_and_validation() {
        let mut t = table();
        for i in 0..150u32 {
            t.push(1, i + 3, &[]).unwrap(); // multi-block row
        }
        let mut ix = CcsrIndex::build(&t, 2, 160).unwrap();
        let (f, r) = ix.halves().unwrap();
        let (f, r) = (f.clone(), r.clone());
        let back = CcsrIndex::from_halves(f.clone(), r.clone()).unwrap();
        assert_eq!(back.lookup(0, 2), ix.lookup(0, 2));
        assert_eq!(back.lookup(1, 100), ix.lookup(1, 100));
        assert_eq!(back.len(), ix.len());
        assert_eq!(nbrs(&back, 1), nbrs(&ix, 1));

        // a dirty index refuses to expose its halves
        let id = t.push(0, 5, &[]).unwrap();
        ix.insert(0, 5, id).unwrap();
        assert!(ix.halves().is_err());
        ix.compact();
        assert!(ix.halves().is_ok());

        // structural corruption is rejected
        let mut bad = f.clone();
        bad.nbr_max[0] = 0; // header no longer matches content
        assert!(CcsrIndex::from_halves(bad, r.clone()).is_err());
        let mut bad = f.clone();
        bad.data_off[1] += 1; // payload bits not contiguous
        assert!(CcsrIndex::from_halves(bad, r.clone()).is_err());
        let mut bad = f.clone();
        bad.packed.pop(); // payload truncated
        assert!(CcsrIndex::from_halves(bad, r.clone()).is_err());
        let mut bad = f.clone();
        bad.blk_offsets[1] = 0; // block bounds inconsistent with entries
        assert!(CcsrIndex::from_halves(bad, r.clone()).is_err());
        let mut bad = f.clone();
        bad.nbr_min[0] = 9999; // decoded neighbor out of population range
        assert!(CcsrIndex::from_halves(bad, r).is_err());
    }

    #[test]
    fn grow_extends_runs() {
        let t = RelTable::new(0);
        let mut ix = CcsrIndex::build(&t, 1, 1).unwrap();
        ix.grow(3, 2);
        assert_eq!(ix.degree_from(2), 0);
        ix.insert(2, 1, 0).unwrap();
        assert_eq!(ix.lookup(2, 1), Some(0));
        assert!(ix.insert(5, 0, 1).is_err()); // out of range
    }

    #[test]
    fn self_compaction_keeps_overlay_bounded() {
        let mut t = RelTable::new(0);
        let mut ix = CcsrIndex::build(&t, 1, 4096).unwrap();
        for i in 0..2000u32 {
            let id = t.push(0, i, &[]).unwrap();
            ix.insert(0, i, id).unwrap();
        }
        assert!(ix.overlay_len() <= 2 * (OVERLAY_SLACK + isqrt(ix.len())));
        assert_eq!(ix.len(), 2000);
        assert_eq!(ix.degree_from(0), 2000);
        ix.compact();
        let run = ix.block_run_from(0).unwrap();
        let pairs = run.to_pairs();
        assert_eq!(pairs.len(), 2000);
        assert!(pairs.windows(2).all(|w| w[0].0 < w[1].0));
    }
}
