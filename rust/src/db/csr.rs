//! Columnar CSR (compressed sparse row) adjacency for relationship
//! tables: one sorted run of `(neighbor, tuple id)` per endpoint value,
//! in both orientations, backed by three contiguous arrays per
//! orientation (`offsets` / `nbr` / `tid`).
//!
//! Compared with the seed-era hash index ([`crate::db::index::RelIndex`]
//! — `Vec<Vec<u32>>` adjacency plus an `FxHashMap` pair map), the CSR
//! layout trades pointer-chasing hash probes for cache-friendly scans:
//! membership is a binary search inside one contiguous run, degree is an
//! offset subtraction, and two runs over the same population intersect
//! with a linear merge (or galloping search when the degree distribution
//! is skewed — see [`crate::db::query::intersect_count`]).
//!
//! Churn support: mutations do **not** rewrite the base arrays.  They go
//! to a small sorted *overlay* — pending inserts plus tombstones over
//! base entries — consulted by every read.  [`CsrIndex::compact`] merges
//! the overlay back into fresh base runs in one linear pass;
//! [`crate::delta::MaintainedCounts`] calls it at end-of-batch so the
//! stale-point recounts (whose costs the `DeltaPolicy` estimates assume
//! clean-run join speed) and all post-batch serving read contiguous
//! runs.  The mutators also self-compact once the overlay outgrows
//! `64 + √base` entries: sorted-insert memmoves cost O(overlay) and a
//! compaction costs O(base) amortized over the overlay's lifetime, so
//! the √base threshold bounds streaming mutation at O(√base) amortized
//! per op (vs the hash backend's O(1); the batched delta path compacts
//! at end-of-batch regardless).
//!
//! Reads are equivalent to the hash backend *at all times* (overlay
//! pending or not): `rust/tests/proptest_invariants.rs` asserts
//! build-vs-overlay-then-compact equivalence, run sortedness, and
//! hash/CSR count equality under random churn.

use crate::db::index::pair_key;
use crate::db::table::RelTable;
use crate::error::{Error, Result};

/// Mask extracting the neighbor id from an orientation pair key
/// (shared with the compressed block engine, [`crate::db::ccsr`]).
pub(crate) const NBR_MASK: u64 = 0xFFFF_FFFF;

/// Self-compaction slack: compact when one orientation's overlay holds
/// more than `OVERLAY_SLACK + √base` entries.  Sorted inserts cost
/// O(overlay) and compaction O(base)/overlay-lifetime, so the √base
/// threshold balances them at O(√base) amortized per streaming op.
pub(crate) const OVERLAY_SLACK: usize = 64;

/// Integer square root (`usize::isqrt` needs Rust 1.84; MSRV is 1.70).
/// f64 has 52 mantissa bits, exact for every table size we index.
pub(crate) fn isqrt(n: usize) -> usize {
    (n as f64).sqrt() as usize
}

/// One orientation of the adjacency: `row` is an endpoint value, its run
/// `nbr[offsets[row]..offsets[row+1]]` lists the opposite endpoints in
/// strictly ascending order, with the owning tuple ids alongside.
#[derive(Clone, Debug, Default)]
pub struct CsrHalf {
    /// Run bounds; `len() == rows + 1`.
    pub offsets: Vec<u32>,
    /// Neighbor entity ids, strictly ascending within each run.
    pub nbr: Vec<u32>,
    /// Tuple id of each `(row, nbr)` entry, parallel to `nbr`.
    pub tid: Vec<u32>,
}

impl CsrHalf {
    fn rows(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    fn run(&self, r: u32) -> (usize, usize) {
        (self.offsets[r as usize] as usize, self.offsets[r as usize + 1] as usize)
    }

    /// Build from `(row, nbr, tid)` triples (sorted in place).  The
    /// offsets column is `u32`, so the cumulative count would wrap
    /// silently past `u32::MAX` triples — guard before accumulating.
    fn build(mut triples: Vec<(u32, u32, u32)>, rows: usize) -> Result<CsrHalf> {
        Error::check_u32_capacity("csr offset column", triples.len() as u64)?;
        triples.sort_unstable();
        let mut offsets = vec![0u32; rows + 1];
        for &(r, _, _) in &triples {
            offsets[r as usize + 1] += 1;
        }
        for i in 0..rows {
            offsets[i + 1] += offsets[i];
        }
        Ok(CsrHalf {
            offsets,
            nbr: triples.iter().map(|t| t.1).collect(),
            tid: triples.iter().map(|t| t.2).collect(),
        })
    }

    /// Position of `nbr` inside row `r`'s run.
    fn find(&self, r: u32, nbr: u32) -> Option<usize> {
        let (lo, hi) = self.run(r);
        self.nbr[lo..hi].binary_search(&nbr).ok().map(|p| lo + p)
    }

    fn grow(&mut self, rows: usize) {
        let last = *self.offsets.last().expect("offsets non-empty");
        while self.offsets.len() < rows + 1 {
            self.offsets.push(last);
        }
    }

    fn bytes(&self) -> usize {
        (self.offsets.capacity() + self.nbr.capacity() + self.tid.capacity()) * 4
    }
}

/// Pending mutations of one orientation, keyed by that orientation's
/// `(row << 32) | nbr` pair key (so one row's entries are contiguous).
/// Shared with the compressed block engine ([`crate::db::ccsr`]), whose
/// churn path is this overlay verbatim over bit-packed base blocks.
#[derive(Clone, Debug, Default)]
pub(crate) struct Overlay {
    /// `(key, tid)` of inserted pairs absent from the live base.
    pub(crate) add: Vec<(u64, u32)>,
    /// Keys of base entries deleted (tombstones).
    pub(crate) del: Vec<u64>,
}

impl Overlay {
    pub(crate) fn is_empty(&self) -> bool {
        self.add.is_empty() && self.del.is_empty()
    }

    pub(crate) fn len(&self) -> usize {
        self.add.len() + self.del.len()
    }

    /// Pending inserts within row `r`.
    pub(crate) fn add_range(&self, r: u32) -> &[(u64, u32)] {
        let lo = self.add.partition_point(|&(k, _)| k < pair_key(r, 0));
        let hi = self.add.partition_point(|&(k, _)| k <= pair_key(r, u32::MAX));
        &self.add[lo..hi]
    }

    /// Tombstones within row `r`.
    pub(crate) fn del_range(&self, r: u32) -> &[u64] {
        let lo = self.del.partition_point(|&k| k < pair_key(r, 0));
        let hi = self.del.partition_point(|&k| k <= pair_key(r, u32::MAX));
        &self.del[lo..hi]
    }

    pub(crate) fn touches(&self, r: u32) -> bool {
        !self.add_range(r).is_empty() || !self.del_range(r).is_empty()
    }

    pub(crate) fn insert_add(&mut self, key: u64, tid: u32) {
        let pos = self.add.partition_point(|&(k, _)| k < key);
        self.add.insert(pos, (key, tid));
    }

    pub(crate) fn insert_del(&mut self, key: u64) {
        let pos = self.del.partition_point(|&k| k < key);
        self.del.insert(pos, key);
    }

    pub(crate) fn bytes(&self) -> usize {
        self.add.capacity() * 12 + self.del.capacity() * 8
    }
}

/// One row of a CSR orientation, merged with any overlay entries.
pub enum CsrRow<'a> {
    /// No overlay entries touch this row: borrow the base run directly.
    Clean { nbr: &'a [u32], tid: &'a [u32] },
    /// Overlay entries touch this row: a materialized `(nbr, tid)` run,
    /// still strictly ascending by neighbor.
    Dirty(Vec<(u32, u32)>),
}

/// CSR index over one relationship table: both orientations plus their
/// overlays.  The mutation API mirrors [`crate::db::index::RelIndex`]
/// so [`crate::db::index::RelIx`] can dispatch on the backend.
#[derive(Clone, Debug, Default)]
pub struct CsrIndex {
    /// from -> sorted (to, tid) runs.
    fwd: CsrHalf,
    /// to -> sorted (from, tid) runs.
    rev: CsrHalf,
    ov_fwd: Overlay,
    ov_rev: Overlay,
}

impl CsrIndex {
    /// Build from a table given the endpoint population sizes (same
    /// contract as [`crate::db::index::RelIndex::build`]: rejects
    /// out-of-range endpoints and duplicate pairs).
    pub fn build(table: &RelTable, n_from: u32, n_to: u32) -> Result<CsrIndex> {
        let n = table.len() as usize;
        let mut f_triples = Vec::with_capacity(n);
        let mut r_triples = Vec::with_capacity(n);
        for t in 0..table.len() {
            let f = table.from[t as usize];
            let o = table.to[t as usize];
            if f >= n_from || o >= n_to {
                return Err(Error::Data(format!(
                    "rel tuple ({f},{o}) out of population range ({n_from},{n_to})"
                )));
            }
            f_triples.push((f, o, t));
            r_triples.push((o, f, t));
        }
        f_triples.sort_unstable();
        for w in f_triples.windows(2) {
            if (w[0].0, w[0].1) == (w[1].0, w[1].1) {
                return Err(Error::Data(format!(
                    "duplicate relationship pair ({},{})",
                    w[0].0, w[0].1
                )));
            }
        }
        let fwd = CsrHalf::build(f_triples, n_from as usize)?;
        let rev = CsrHalf::build(r_triples, n_to as usize)?;
        Ok(CsrIndex {
            fwd,
            rev,
            ov_fwd: Overlay::default(),
            ov_rev: Overlay::default(),
        })
    }

    /// Tuple id for a fully-bound pair, if the relationship holds
    /// (overlay-aware: pending inserts win, tombstones hide base
    /// entries).
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        if from as usize >= self.fwd.rows() || to as usize >= self.rev.rows() {
            return None;
        }
        if !self.ov_fwd.is_empty() {
            let k = pair_key(from, to);
            if let Ok(p) = self.ov_fwd.add.binary_search_by_key(&k, |e| e.0) {
                return Some(self.ov_fwd.add[p].1);
            }
            if self.ov_fwd.del.binary_search(&k).is_ok() {
                return None;
            }
        }
        self.fwd.find(from, to).map(|p| self.fwd.tid[p])
    }

    /// Live adjacency degree of `from` (base minus tombstones plus
    /// pending inserts).
    pub fn degree_from(&self, f: u32) -> usize {
        let (lo, hi) = self.fwd.run(f);
        hi - lo - self.ov_fwd.del_range(f).len() + self.ov_fwd.add_range(f).len()
    }

    /// Live adjacency degree of `to`.
    pub fn degree_to(&self, t: u32) -> usize {
        let (lo, hi) = self.rev.run(t);
        hi - lo - self.ov_rev.del_range(t).len() + self.ov_rev.add_range(t).len()
    }

    /// The from-oriented row, merged with the overlay when necessary.
    pub fn row_from(&self, f: u32) -> CsrRow<'_> {
        Self::row(&self.fwd, &self.ov_fwd, f)
    }

    /// The to-oriented row, merged with the overlay when necessary.
    pub fn row_to(&self, t: u32) -> CsrRow<'_> {
        Self::row(&self.rev, &self.ov_rev, t)
    }

    /// The contiguous sorted neighbor run of `from`, available only when
    /// no overlay entry touches the row (the merge-intersection kernel's
    /// fast path; dirty rows fall back to generic enumeration).
    pub fn sorted_nbrs_from(&self, f: u32) -> Option<&[u32]> {
        if self.ov_fwd.is_empty() || !self.ov_fwd.touches(f) {
            let (lo, hi) = self.fwd.run(f);
            Some(&self.fwd.nbr[lo..hi])
        } else {
            None
        }
    }

    /// The contiguous sorted neighbor run of `to` (see
    /// [`CsrIndex::sorted_nbrs_from`]).
    pub fn sorted_nbrs_to(&self, t: u32) -> Option<&[u32]> {
        if self.ov_rev.is_empty() || !self.ov_rev.touches(t) {
            let (lo, hi) = self.rev.run(t);
            Some(&self.rev.nbr[lo..hi])
        } else {
            None
        }
    }

    /// The clean sorted `(neighbor, tid)` run of `from` as parallel
    /// column slices, under the same no-overlay condition as
    /// [`CsrIndex::sorted_nbrs_from`] — unlike [`CsrIndex::row_from`]
    /// this never allocates, so it doubles as a cheap cleanliness probe.
    pub fn sorted_run_from(&self, f: u32) -> Option<(&[u32], &[u32])> {
        if self.ov_fwd.is_empty() || !self.ov_fwd.touches(f) {
            let (lo, hi) = self.fwd.run(f);
            Some((&self.fwd.nbr[lo..hi], &self.fwd.tid[lo..hi]))
        } else {
            None
        }
    }

    /// The clean sorted `(neighbor, tid)` run of `to` (see
    /// [`CsrIndex::sorted_run_from`]).
    pub fn sorted_run_to(&self, t: u32) -> Option<(&[u32], &[u32])> {
        if self.ov_rev.is_empty() || !self.ov_rev.touches(t) {
            let (lo, hi) = self.rev.run(t);
            Some((&self.rev.nbr[lo..hi], &self.rev.tid[lo..hi]))
        } else {
            None
        }
    }

    fn row<'a>(half: &'a CsrHalf, ov: &'a Overlay, r: u32) -> CsrRow<'a> {
        let (lo, hi) = half.run(r);
        if ov.is_empty() || !ov.touches(r) {
            return CsrRow::Clean { nbr: &half.nbr[lo..hi], tid: &half.tid[lo..hi] };
        }
        let adds = ov.add_range(r);
        let dels = ov.del_range(r);
        let mut out = Vec::with_capacity(hi - lo + adds.len());
        let mut ai = 0;
        let mut di = 0;
        for p in lo..hi {
            let n = half.nbr[p];
            while ai < adds.len() && ((adds[ai].0 & NBR_MASK) as u32) < n {
                out.push(((adds[ai].0 & NBR_MASK) as u32, adds[ai].1));
                ai += 1;
            }
            if di < dels.len() && (dels[di] & NBR_MASK) as u32 == n {
                // tombstoned; a re-added pair carries the fresh tid
                di += 1;
                if ai < adds.len() && (adds[ai].0 & NBR_MASK) as u32 == n {
                    out.push((n, adds[ai].1));
                    ai += 1;
                }
                continue;
            }
            out.push((n, half.tid[p]));
        }
        for &(k, t) in &adds[ai..] {
            out.push(((k & NBR_MASK) as u32, t));
        }
        CsrRow::Dirty(out)
    }

    /// Extend both orientations to cover grown endpoint populations.
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        if self.fwd.rows() < n_from as usize {
            self.fwd.grow(n_from as usize);
        }
        if self.rev.rows() < n_to as usize {
            self.rev.grow(n_to as usize);
        }
    }

    /// Register a freshly appended tuple `t = (from, to)` in the
    /// overlay (duplicate pairs are rejected before any structure is
    /// touched).
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        if from as usize >= self.fwd.rows() || to as usize >= self.rev.rows() {
            return Err(Error::Data(format!(
                "rel tuple ({from},{to}) out of population range ({},{})",
                self.fwd.rows(),
                self.rev.rows()
            )));
        }
        if self.lookup(from, to).is_some() {
            return Err(Error::Data(format!(
                "duplicate relationship pair ({from},{to})"
            )));
        }
        // compaction folds the overlay back into u32 offsets; keep the
        // live pair count inside the space they can address
        Error::check_u32_capacity("csr live pairs", self.len() as u64 + 1)?;
        self.ov_fwd.insert_add(pair_key(from, to), t);
        self.ov_rev.insert_add(pair_key(to, from), t);
        self.maybe_compact();
        Ok(())
    }

    /// Unregister tuple `t = (from, to)` after a
    /// [`RelTable::swap_remove`]: tombstone (or drop the pending insert
    /// of) the pair, then relabel the moved tuple `last -> t` wherever
    /// its entries live.  Mirrors
    /// [`crate::db::index::RelIndex::remove_swap`].
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self.lookup(from, to) {
            Some(id) if id == t => {}
            _ => {
                return Err(Error::Data(format!(
                    "index out of sync removing ({from},{to}) id {t}"
                )))
            }
        }
        let fk = pair_key(from, to);
        if let Ok(p) = self.ov_fwd.add.binary_search_by_key(&fk, |e| e.0) {
            self.ov_fwd.add.remove(p);
            let rk = pair_key(to, from);
            let q = self
                .ov_rev
                .add
                .binary_search_by_key(&rk, |e| e.0)
                .expect("overlay orientations in sync");
            self.ov_rev.add.remove(q);
        } else {
            self.ov_fwd.insert_del(fk);
            self.ov_rev.insert_del(pair_key(to, from));
        }
        if t != last {
            // relabel the moved tuple: last -> t
            let lk = pair_key(last_from, last_to);
            if let Ok(p) = self.ov_fwd.add.binary_search_by_key(&lk, |e| e.0) {
                self.ov_fwd.add[p].1 = t;
                let rk = pair_key(last_to, last_from);
                let q = self
                    .ov_rev
                    .add
                    .binary_search_by_key(&rk, |e| e.0)
                    .expect("overlay orientations in sync");
                self.ov_rev.add[q].1 = t;
            } else {
                if let Some(p) = self.fwd.find(last_from, last_to) {
                    self.fwd.tid[p] = t;
                }
                if let Some(p) = self.rev.find(last_to, last_from) {
                    self.rev.tid[p] = t;
                }
            }
        }
        self.maybe_compact();
        Ok(())
    }

    /// Live pair count.
    pub fn len(&self) -> usize {
        self.fwd.nbr.len() - self.ov_fwd.del.len() + self.ov_fwd.add.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pending overlay entries across both orientations.
    pub fn overlay_len(&self) -> usize {
        self.ov_fwd.len() + self.ov_rev.len()
    }

    /// Largest live degree in either orientation.
    pub fn max_degree(&self) -> usize {
        if self.ov_fwd.is_empty() && self.ov_rev.is_empty() {
            let f = self.fwd.offsets.windows(2).map(|w| (w[1] - w[0]) as usize);
            let t = self.rev.offsets.windows(2).map(|w| (w[1] - w[0]) as usize);
            f.max().unwrap_or(0).max(t.max().unwrap_or(0))
        } else {
            let f = (0..self.fwd.rows()).map(|r| self.degree_from(r as u32));
            let t = (0..self.rev.rows()).map(|r| self.degree_to(r as u32));
            f.max().unwrap_or(0).max(t.max().unwrap_or(0))
        }
    }

    /// Merge the overlay into fresh base runs (one linear pass per
    /// orientation); afterwards every row is clean and
    /// [`CsrIndex::overlay_len`] is zero.
    pub fn compact(&mut self) {
        if !self.ov_fwd.is_empty() {
            Self::compact_half(&mut self.fwd, &mut self.ov_fwd);
        }
        if !self.ov_rev.is_empty() {
            Self::compact_half(&mut self.rev, &mut self.ov_rev);
        }
    }

    fn maybe_compact(&mut self) {
        let threshold = OVERLAY_SLACK + isqrt(self.fwd.nbr.len());
        if self.ov_fwd.len() > threshold || self.ov_rev.len() > threshold {
            self.compact();
        }
    }

    fn compact_half(half: &mut CsrHalf, ov: &mut Overlay) {
        let rows = half.rows();
        let new_len = half.nbr.len() + ov.add.len() - ov.del.len();
        let mut offsets = Vec::with_capacity(rows + 1);
        let mut nbr = Vec::with_capacity(new_len);
        let mut tid = Vec::with_capacity(new_len);
        offsets.push(0u32);
        let (mut ai, mut di) = (0, 0);
        for r in 0..rows as u32 {
            let (lo, hi) = half.run(r);
            let mut bi = lo;
            let row_end = pair_key(r, u32::MAX);
            loop {
                let bkey = if bi < hi { Some(pair_key(r, half.nbr[bi])) } else { None };
                let akey = match ov.add.get(ai) {
                    Some(&(k, _)) if k <= row_end => Some(k),
                    _ => None,
                };
                match (bkey, akey) {
                    (None, None) => break,
                    (Some(bk), Some(ak)) if bk == ak => {
                        // tombstoned base entry shadowed by a re-insert
                        debug_assert_eq!(ov.del.get(di), Some(&bk));
                        di += 1;
                        bi += 1;
                        nbr.push((ak & NBR_MASK) as u32);
                        tid.push(ov.add[ai].1);
                        ai += 1;
                    }
                    (Some(bk), _) if bkey < akey || akey.is_none() => {
                        if ov.del.get(di) == Some(&bk) {
                            di += 1; // tombstoned: drop
                        } else {
                            nbr.push(half.nbr[bi]);
                            tid.push(half.tid[bi]);
                        }
                        bi += 1;
                    }
                    (_, Some(ak)) => {
                        nbr.push((ak & NBR_MASK) as u32);
                        tid.push(ov.add[ai].1);
                        ai += 1;
                    }
                    (Some(_), None) => unreachable!("covered above"),
                }
            }
            offsets.push(nbr.len() as u32);
        }
        debug_assert_eq!(ai, ov.add.len());
        debug_assert_eq!(di, ov.del.len());
        half.offsets = offsets;
        half.nbr = nbr;
        half.tid = tid;
        ov.add.clear();
        ov.del.clear();
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.fwd.bytes() + self.rev.bytes() + self.ov_fwd.bytes() + self.ov_rev.bytes()
    }

    /// The base arrays of both orientations, for snapshot serialization.
    /// Only a clean index can be serialized — callers must
    /// [`CsrIndex::compact`] first so the base runs hold every live pair.
    pub fn halves(&self) -> Result<(&CsrHalf, &CsrHalf)> {
        if self.overlay_len() != 0 {
            return Err(Error::Data(
                "cannot serialize a CSR index with a pending overlay; compact first"
                    .into(),
            ));
        }
        Ok((&self.fwd, &self.rev))
    }

    /// Rebuild an index from persisted base arrays (the snapshot-restore
    /// path), validating structure so corrupt-but-checksummed inputs can
    /// never produce out-of-bounds reads: offsets monotone and
    /// bounds-consistent, neighbor runs strictly ascending, neighbor and
    /// tuple ids inside the opposite orientation's ranges, and both
    /// orientations holding the same pair count.
    pub fn from_halves(fwd: CsrHalf, rev: CsrHalf) -> Result<CsrIndex> {
        Self::validate_half(&fwd, rev.offsets.len().saturating_sub(1), "fwd")?;
        Self::validate_half(&rev, fwd.offsets.len().saturating_sub(1), "rev")?;
        if fwd.nbr.len() != rev.nbr.len() {
            return Err(Error::Data(format!(
                "CSR orientations disagree on pair count ({} vs {})",
                fwd.nbr.len(),
                rev.nbr.len()
            )));
        }
        Ok(CsrIndex {
            fwd,
            rev,
            ov_fwd: Overlay::default(),
            ov_rev: Overlay::default(),
        })
    }

    fn validate_half(h: &CsrHalf, n_opposite: usize, side: &str) -> Result<()> {
        let err = |m: String| Error::Data(format!("CSR {side} half: {m}"));
        if h.offsets.is_empty() || h.offsets[0] != 0 {
            return Err(err("offsets must start at 0".into()));
        }
        if h.offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err(err("offsets not monotone".into()));
        }
        let total = *h.offsets.last().unwrap() as usize;
        if total != h.nbr.len() || h.nbr.len() != h.tid.len() {
            return Err(err(format!(
                "array lengths inconsistent (offsets end {total}, nbr {}, tid {})",
                h.nbr.len(),
                h.tid.len()
            )));
        }
        for w in h.offsets.windows(2) {
            let (lo, hi) = (w[0] as usize, w[1] as usize);
            if h.nbr[lo..hi].windows(2).any(|p| p[0] >= p[1]) {
                return Err(err("neighbor run not strictly ascending".into()));
            }
        }
        if h.nbr.iter().any(|&n| n as usize >= n_opposite) {
            return Err(err("neighbor id out of population range".into()));
        }
        if h.tid.iter().any(|&t| t as usize >= total) {
            return Err(err("tuple id out of range".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> RelTable {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        t
    }

    fn nbrs(ix: &CsrIndex, f: u32) -> Vec<(u32, u32)> {
        match ix.row_from(f) {
            CsrRow::Clean { nbr, tid } => {
                nbr.iter().copied().zip(tid.iter().copied()).collect()
            }
            CsrRow::Dirty(v) => v,
        }
    }

    #[test]
    fn sorted_runs_expose_parallel_columns_only_when_clean() {
        let t = table();
        let mut ix = CsrIndex::build(&t, 2, 3).unwrap();
        let (nbr, tid) = ix.sorted_run_from(0).unwrap();
        assert_eq!(nbr, &[1, 2]);
        assert_eq!(tid, &[0, 1]);
        ix.insert(1, 2, 3).unwrap();
        assert!(ix.sorted_run_from(1).is_none(), "dirty row must not lend a run");
        assert!(ix.sorted_run_from(0).is_some(), "untouched row stays clean");
        assert!(ix.sorted_run_to(2).is_none());
        ix.compact();
        let (nbr, tid) = ix.sorted_run_from(1).unwrap();
        assert_eq!(nbr, &[1, 2]);
        assert_eq!(tid, &[2, 3]);
    }

    #[test]
    fn builds_sorted_runs_and_lookup() {
        let t = table();
        let ix = CsrIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.sorted_nbrs_from(0).unwrap(), &[1, 2]);
        assert_eq!(ix.sorted_nbrs_to(1).unwrap(), &[0, 1]);
        assert_eq!(ix.lookup(0, 2), Some(1));
        assert_eq!(ix.lookup(1, 2), None);
        assert_eq!(ix.degree_from(0), 2);
        assert_eq!(ix.degree_to(1), 2);
        assert_eq!(ix.len(), 3);
        assert_eq!(ix.max_degree(), 2);
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        assert!(CsrIndex::build(&t, 2, 2).is_err());

        let mut t2 = RelTable::new(0);
        t2.push(5, 0, &[]).unwrap();
        assert!(CsrIndex::build(&t2, 2, 2).is_err());
    }

    #[test]
    fn overlay_insert_delete_reads_like_rebuild() {
        let mut t = table();
        let mut ix = CsrIndex::build(&t, 2, 3).unwrap();

        // insert (1, 2) through the overlay
        let id = t.push(1, 2, &[]).unwrap();
        ix.insert(1, 2, id).unwrap();
        assert!(ix.insert(1, 2, 9).is_err()); // duplicate
        assert_eq!(ix.lookup(1, 2), Some(3));
        assert_eq!(ix.degree_from(1), 2);
        assert_eq!(ix.sorted_nbrs_from(1), None); // dirty row
        assert_eq!(nbrs(&ix, 1), vec![(1, 2), (2, 3)]);
        assert!(ix.overlay_len() > 0);

        // delete (0, 2): the last tuple (1,2) takes id 1
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(1).unwrap();
        ix.remove_swap(0, 2, 1, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 2), None);
        assert_eq!(ix.lookup(1, 2), Some(1));
        assert_eq!(ix.degree_from(0), 1);
        assert_eq!(ix.len(), t.len() as usize);

        // overlay reads match a from-scratch rebuild...
        let fresh = CsrIndex::build(&t, 2, 3).unwrap();
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f}");
        }
        // ...and compaction reproduces its base arrays exactly
        ix.compact();
        assert_eq!(ix.overlay_len(), 0);
        for f in 0..2u32 {
            assert_eq!(
                ix.sorted_nbrs_from(f).unwrap(),
                fresh.sorted_nbrs_from(f).unwrap(),
                "row {f}"
            );
        }
        for o in 0..3u32 {
            assert_eq!(
                ix.sorted_nbrs_to(o).unwrap(),
                fresh.sorted_nbrs_to(o).unwrap(),
                "rev row {o}"
            );
        }
        assert_eq!(ix.lookup(1, 2), fresh.lookup(1, 2));
    }

    #[test]
    fn delete_then_reinsert_same_pair() {
        let mut t = table();
        let mut ix = CsrIndex::build(&t, 2, 3).unwrap();
        // delete (0, 1): last tuple (1,1) takes id 0
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(0).unwrap();
        ix.remove_swap(0, 1, 0, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 1), None);
        assert_eq!(ix.lookup(1, 1), Some(0));
        // re-insert the tombstoned pair with a fresh tid
        let id = t.push(0, 1, &[]).unwrap();
        ix.insert(0, 1, id).unwrap();
        assert_eq!(ix.lookup(0, 1), Some(id));
        assert_eq!(nbrs(&ix, 0), vec![(1, id), (2, 1)]);
        ix.compact();
        let fresh = CsrIndex::build(&t, 2, 3).unwrap();
        for f in 0..2u32 {
            assert_eq!(nbrs(&ix, f), nbrs(&fresh, f), "row {f}");
        }
    }

    #[test]
    fn halves_roundtrip_and_validation() {
        let t = table();
        let mut ix = CsrIndex::build(&t, 2, 3).unwrap();
        let (f, r) = ix.halves().unwrap();
        let (f, r) = (f.clone(), r.clone());
        let back = CsrIndex::from_halves(f.clone(), r.clone()).unwrap();
        assert_eq!(back.lookup(0, 2), ix.lookup(0, 2));
        assert_eq!(back.sorted_nbrs_from(0), ix.sorted_nbrs_from(0));
        assert_eq!(back.len(), ix.len());

        // a dirty index refuses to expose its halves
        ix.insert(1, 2, 3).unwrap();
        assert!(ix.halves().is_err());
        ix.compact();
        assert!(ix.halves().is_ok());

        // structural corruption is rejected
        let mut bad = f.clone();
        bad.nbr[0] = 99; // out of population range
        assert!(CsrIndex::from_halves(bad, r.clone()).is_err());
        let mut bad = f.clone();
        bad.offsets[1] = 0; // folds both rows into one non-ascending run
        assert!(CsrIndex::from_halves(bad, r.clone()).is_err());
        let mut bad = f.clone();
        bad.tid.pop(); // lengths inconsistent
        assert!(CsrIndex::from_halves(bad, r).is_err());
    }

    #[test]
    fn grow_extends_runs() {
        let t = RelTable::new(0);
        let mut ix = CsrIndex::build(&t, 1, 1).unwrap();
        ix.grow(3, 2);
        assert_eq!(ix.degree_from(2), 0);
        ix.insert(2, 1, 0).unwrap();
        assert_eq!(ix.lookup(2, 1), Some(0));
        assert!(ix.insert(5, 0, 1).is_err()); // out of range
    }

    #[test]
    fn self_compaction_keeps_overlay_bounded() {
        let mut t = RelTable::new(0);
        let mut ix = CsrIndex::build(&t, 1, 4096).unwrap();
        for i in 0..2000u32 {
            let id = t.push(0, i, &[]).unwrap();
            ix.insert(0, i, id).unwrap();
        }
        // the mutators self-compacted along the way (both orientations
        // count toward overlay_len, hence the factor of two)
        assert!(ix.overlay_len() <= 2 * (OVERLAY_SLACK + isqrt(ix.len())));
        assert_eq!(ix.len(), 2000);
        assert_eq!(ix.degree_from(0), 2000);
        ix.compact();
        let run = ix.sorted_nbrs_from(0).unwrap();
        assert!(run.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(run.len(), 2000);
    }
}
