//! Shared fixtures: the paper's running University example.
//!
//! The RA relationship data reproduces the paper's Table 3 exactly:
//! 228 professor-student pairs of which 25 are RA tuples, with the
//! (capability, salary) joint counts of Table 3; the remaining 203 pairs
//! are the `Capa = N/A, RA = F, Salary = N/A` row.
//!
//! This module is compiled unconditionally (not `#[cfg(test)]`) because
//! the quickstart example and the integration tests both build on it.

use crate::db::catalog::Database;
use crate::db::schema::{Attribute, EntityType, RelationshipType, Schema};

/// Salary codes (raw, before the ct-table N/A shift).
pub const SALARY_LOW: u32 = 0;
pub const SALARY_MED: u32 = 1;
pub const SALARY_HIGH: u32 = 2;

/// The University schema: Professor, Student, Course; RA(P,S) with
/// capability (5 values) and salary (3 values), Registered(S,C) with
/// grade (4 values).
pub fn university_schema() -> Schema {
    Schema::new(
        vec![
            EntityType {
                name: "Professor".into(),
                attrs: vec![Attribute::new("popularity", 3)],
            },
            EntityType {
                name: "Student".into(),
                attrs: vec![Attribute::new("intelligence", 3)],
            },
            EntityType {
                name: "Course".into(),
                attrs: vec![Attribute::new("difficulty", 2)],
            },
        ],
        vec![
            RelationshipType {
                name: "RA".into(),
                from: 0,
                to: 1,
                attrs: vec![
                    // paper capability values 1..=5 -> raw codes 0..=4
                    Attribute::new("capability", 5),
                    // LOW/MED/HIGH -> 0/1/2 (N/A appears only in ct-tables)
                    Attribute::new("salary", 3),
                ],
            },
            RelationshipType {
                name: "Registered".into(),
                from: 1,
                to: 2,
                attrs: vec![Attribute::new("grade", 4)],
            },
        ],
    )
    .expect("university schema is valid")
}

/// Table 3 of the paper as (capability 1..=5, salary code, count) rows.
pub const TABLE3_POSITIVE: &[(u32, u32, u32)] = &[
    (4, SALARY_HIGH, 5),
    (5, SALARY_HIGH, 4),
    (3, SALARY_HIGH, 2),
    (3, SALARY_LOW, 1),
    (2, SALARY_LOW, 2),
    (1, SALARY_LOW, 2),
    (2, SALARY_MED, 2),
    (3, SALARY_MED, 4),
    (1, SALARY_MED, 3),
];

/// Number of professor-student pairs with `RA = F` in Table 3.
pub const TABLE3_NEGATIVE: u32 = 203;

/// The University database: 12 professors x 19 students = 228 pairs,
/// 25 of them RA tuples with Table 3's joint counts, plus a small
/// Registered(S, C) relation over 5 courses.
pub fn university_db() -> Database {
    let schema = university_schema();
    let mut db = Database::empty(schema);

    // Entities with deterministic attribute values.
    for p in 0..12u32 {
        db.entities[0].push(&[p % 3]).unwrap();
    }
    for s in 0..19u32 {
        db.entities[1].push(&[(s / 2) % 3]).unwrap();
    }
    for c in 0..5u32 {
        db.entities[2].push(&[c % 2]).unwrap();
    }

    // RA tuples: 25 distinct (p, s) pairs; (i % 12, i % 19) are distinct
    // for i < lcm(12, 19) = 228.
    let mut i = 0u32;
    for &(capa, salary, count) in TABLE3_POSITIVE {
        for _ in 0..count {
            db.rels[0].push(i % 12, i % 19, &[capa - 1, salary]).unwrap();
            i += 1;
        }
    }
    debug_assert_eq!(i, 25);

    // Registered tuples: a modest deterministic pattern.
    for s in 0..19u32 {
        for c in 0..5u32 {
            if (s + 2 * c) % 3 == 0 {
                db.rels[1].push(s, c, &[(s + c) % 4]).unwrap();
            }
        }
    }

    db.validate().expect("fixture valid");
    db.build_indexes().expect("fixture indexes");
    db
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_accounting_matches_table3() {
        let db = university_db();
        let pairs = db.population(0) * db.population(1);
        let positive: u32 = TABLE3_POSITIVE.iter().map(|&(_, _, c)| c).sum();
        assert_eq!(positive, 25);
        assert_eq!(pairs, (25 + TABLE3_NEGATIVE) as u64);
        assert_eq!(db.rels[0].len(), 25);
    }

    #[test]
    fn ra_pairs_distinct() {
        let db = university_db();
        // index build would have failed on duplicates; double-check here
        let ix = db.index(0).unwrap();
        assert_eq!(ix.len(), 25);
    }
}
