//! FK indexes over relationship tables, behind a [`Backend`] selector:
//!
//! - [`RelIndex`] — the seed-era **hash** engine: per-endpoint adjacency
//!   `Vec`s plus a unique `(from, to) -> tuple` FxHash map used for
//!   indicator lookups and bound-bound join steps;
//! - [`crate::db::csr::CsrIndex`] — the columnar **CSR** engine (the
//!   default): contiguous sorted neighbor runs in both orientations,
//!   with a sorted overlay absorbing churn until compaction.
//!
//! [`RelIx`] is the enum the rest of the crate sees (returned by
//! [`crate::db::catalog::Database::index`]); every consumer goes
//! through its accessors, so the two engines are interchangeable and
//! produce bit-identical counts (asserted by the backend-equivalence
//! tests and the CI digest gate).

use crate::util::fxhash::FxHashMap;

use crate::db::csr::{CsrIndex, CsrRow};
use crate::db::table::RelTable;
use crate::error::{Error, Result};

/// Relationship-index storage engine selector (CLI `--backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Seed-era FxHash adjacency lists + pair map.
    Hash,
    /// Columnar CSR with sorted neighbor runs (the default).
    #[default]
    Csr,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(Backend::Hash),
            "csr" => Some(Backend::Csr),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Hash => "hash",
            Backend::Csr => "csr",
        }
    }
}

/// Index over one relationship table.
#[derive(Clone, Debug, Default)]
pub struct RelIndex {
    /// `by_from[f]` = tuple ids with `from == f`.
    pub by_from: Vec<Vec<u32>>,
    /// `by_to[t]` = tuple ids with `to == t`.
    pub by_to: Vec<Vec<u32>>,
    /// `(from << 32 | to)` -> tuple id.
    pub pair: FxHashMap<u64, u32>,
}

#[inline]
pub fn pair_key(from: u32, to: u32) -> u64 {
    ((from as u64) << 32) | to as u64
}

impl RelIndex {
    /// Build from a table given the endpoint population sizes.
    pub fn build(table: &RelTable, n_from: u32, n_to: u32) -> Result<Self> {
        let mut by_from = vec![Vec::new(); n_from as usize];
        let mut by_to = vec![Vec::new(); n_to as usize];
        let mut pair = FxHashMap::default();
        pair.reserve(table.len() as usize);
        for t in 0..table.len() {
            let f = table.from[t as usize];
            let o = table.to[t as usize];
            if f >= n_from || o >= n_to {
                return Err(Error::Data(format!(
                    "rel tuple ({f},{o}) out of population range ({n_from},{n_to})"
                )));
            }
            if pair.insert(pair_key(f, o), t).is_some() {
                return Err(Error::Data(format!(
                    "duplicate relationship pair ({f},{o})"
                )));
            }
            by_from[f as usize].push(t);
            by_to[o as usize].push(t);
        }
        Ok(RelIndex { by_from, by_to, pair })
    }

    /// Tuple id for a fully-bound pair, if the relationship holds.
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        self.pair.get(&pair_key(from, to)).copied()
    }

    /// Extend the adjacency lists to cover grown endpoint populations
    /// (entity inserts; existing entries are untouched).
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        if self.by_from.len() < n_from as usize {
            self.by_from.resize(n_from as usize, Vec::new());
        }
        if self.by_to.len() < n_to as usize {
            self.by_to.resize(n_to as usize, Vec::new());
        }
    }

    /// Register a freshly appended tuple `t = (from, to)` (incremental
    /// counterpart of [`RelIndex::build`]; duplicate pairs are rejected
    /// before any structure is touched).
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        if from as usize >= self.by_from.len() || to as usize >= self.by_to.len() {
            return Err(Error::Data(format!(
                "rel tuple ({from},{to}) out of population range ({},{})",
                self.by_from.len(),
                self.by_to.len()
            )));
        }
        if self.pair.contains_key(&pair_key(from, to)) {
            return Err(Error::Data(format!(
                "duplicate relationship pair ({from},{to})"
            )));
        }
        self.pair.insert(pair_key(from, to), t);
        self.by_from[from as usize].push(t);
        self.by_to[to as usize].push(t);
        Ok(())
    }

    /// Unregister tuple `t = (from, to)` after a
    /// [`crate::db::table::RelTable::swap_remove`]: the tuple formerly
    /// holding id `last` (endpoints `last_from`, `last_to`) has been
    /// relabeled to `t`, so its index entries move too.  When `t ==
    /// last` (the removed tuple was the last row) nothing is relabeled.
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self.pair.remove(&pair_key(from, to)) {
            Some(id) if id == t => {}
            _ => {
                return Err(Error::Data(format!(
                    "index out of sync removing ({from},{to}) id {t}"
                )))
            }
        }
        let drop_id = |list: &mut Vec<u32>, id: u32| {
            if let Some(p) = list.iter().position(|&x| x == id) {
                list.swap_remove(p);
            }
        };
        drop_id(&mut self.by_from[from as usize], t);
        drop_id(&mut self.by_to[to as usize], t);
        if t != last {
            // relabel the moved tuple: last -> t
            if let Some(id) = self.pair.get_mut(&pair_key(last_from, last_to)) {
                *id = t;
            }
            let relabel = |list: &mut Vec<u32>| {
                if let Some(p) = list.iter().position(|&x| x == last) {
                    list[p] = t;
                }
            };
            relabel(&mut self.by_from[last_from as usize]);
            relabel(&mut self.by_to[last_to as usize]);
        }
        Ok(())
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        let adj: usize = self
            .by_from
            .iter()
            .chain(self.by_to.iter())
            .map(|v| v.capacity() * 4 + 24)
            .sum();
        adj + self.pair.capacity() * 16
    }
}

/// Iterator over the tuple ids adjacent to one endpoint value, for
/// either backend (CSR dirty rows materialize their merged run).
pub enum Tids<'a> {
    Slice(std::slice::Iter<'a, u32>),
    Owned(std::vec::IntoIter<(u32, u32)>),
}

impl Iterator for Tids<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        match self {
            Tids::Slice(it) => it.next().copied(),
            Tids::Owned(it) => it.next().map(|(_, t)| t),
        }
    }
}

/// A relationship index of either backend.  All consumers (join
/// enumeration, the wander-join sampler, delta maintenance, the Möbius
/// indicator probes) go through these accessors, so hash and CSR are
/// interchangeable bit-for-bit.
#[derive(Clone, Debug)]
pub enum RelIx {
    Hash(RelIndex),
    Csr(CsrIndex),
}

impl RelIx {
    /// Build an index of the selected backend from a table.
    pub fn build(
        backend: Backend,
        table: &RelTable,
        n_from: u32,
        n_to: u32,
    ) -> Result<RelIx> {
        match backend {
            Backend::Hash => Ok(RelIx::Hash(RelIndex::build(table, n_from, n_to)?)),
            Backend::Csr => Ok(RelIx::Csr(CsrIndex::build(table, n_from, n_to)?)),
        }
    }

    pub fn backend(&self) -> Backend {
        match self {
            RelIx::Hash(_) => Backend::Hash,
            RelIx::Csr(_) => Backend::Csr,
        }
    }

    /// The underlying CSR index, if this is the CSR backend (snapshot
    /// serialization reads the compacted base arrays through this).
    pub fn as_csr(&self) -> Option<&CsrIndex> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => Some(ix),
        }
    }

    /// Tuple id for a fully-bound pair, if the relationship holds.
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        match self {
            RelIx::Hash(ix) => ix.lookup(from, to),
            RelIx::Csr(ix) => ix.lookup(from, to),
        }
    }

    /// Adjacency degree of `from`.
    #[inline]
    pub fn degree_from(&self, f: u32) -> usize {
        match self {
            RelIx::Hash(ix) => ix.by_from[f as usize].len(),
            RelIx::Csr(ix) => ix.degree_from(f),
        }
    }

    /// Adjacency degree of `to`.
    #[inline]
    pub fn degree_to(&self, t: u32) -> usize {
        match self {
            RelIx::Hash(ix) => ix.by_to[t as usize].len(),
            RelIx::Csr(ix) => ix.degree_to(t),
        }
    }

    /// Tuple ids with `from == f` (hash: insertion order; CSR: sorted by
    /// neighbor — counting consumers are order-independent).
    pub fn tids_from(&self, f: u32) -> Tids<'_> {
        match self {
            RelIx::Hash(ix) => Tids::Slice(ix.by_from[f as usize].iter()),
            RelIx::Csr(ix) => match ix.row_from(f) {
                CsrRow::Clean { tid, .. } => Tids::Slice(tid.iter()),
                CsrRow::Dirty(v) => Tids::Owned(v.into_iter()),
            },
        }
    }

    /// Tuple ids with `to == t`.
    pub fn tids_to(&self, t: u32) -> Tids<'_> {
        match self {
            RelIx::Hash(ix) => Tids::Slice(ix.by_to[t as usize].iter()),
            RelIx::Csr(ix) => match ix.row_to(t) {
                CsrRow::Clean { tid, .. } => Tids::Slice(tid.iter()),
                CsrRow::Dirty(v) => Tids::Owned(v.into_iter()),
            },
        }
    }

    /// The `k`-th `(neighbor, tuple id)` of `f` in **ascending neighbor
    /// order** — the canonical ordering both backends share, so seeded
    /// samplers (the ADAPTIVE wander-join estimator) draw identical
    /// walks on either engine.  CSR reads its sorted run directly; the
    /// hash backend sorts the row on demand (sampling-path only).
    pub fn nth_from(&self, table: &RelTable, f: u32, k: usize) -> Option<(u32, u32)> {
        match self {
            RelIx::Hash(ix) => {
                let list = ix.by_from.get(f as usize)?;
                let mut row: Vec<(u32, u32)> =
                    list.iter().map(|&t| (table.to[t as usize], t)).collect();
                row.sort_unstable();
                row.get(k).copied()
            }
            RelIx::Csr(ix) => match ix.row_from(f) {
                CsrRow::Clean { nbr, tid } => nbr.get(k).map(|&n| (n, tid[k])),
                CsrRow::Dirty(v) => v.get(k).copied(),
            },
        }
    }

    /// The `k`-th `(neighbor, tuple id)` of `t` in ascending neighbor
    /// order (see [`RelIx::nth_from`]).
    pub fn nth_to(&self, table: &RelTable, t: u32, k: usize) -> Option<(u32, u32)> {
        match self {
            RelIx::Hash(ix) => {
                let list = ix.by_to.get(t as usize)?;
                let mut row: Vec<(u32, u32)> =
                    list.iter().map(|&x| (table.from[x as usize], x)).collect();
                row.sort_unstable();
                row.get(k).copied()
            }
            RelIx::Csr(ix) => match ix.row_to(t) {
                CsrRow::Clean { nbr, tid } => nbr.get(k).map(|&n| (n, tid[k])),
                CsrRow::Dirty(v) => v.get(k).copied(),
            },
        }
    }

    /// The contiguous sorted neighbor run of `f` — `Some` only on the
    /// CSR backend with no pending overlay in the row (the merge
    /// intersection kernel's fast path).
    pub fn sorted_nbrs_from(&self, f: u32) -> Option<&[u32]> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => ix.sorted_nbrs_from(f),
        }
    }

    /// The contiguous sorted neighbor run of `t` (see
    /// [`RelIx::sorted_nbrs_from`]).
    pub fn sorted_nbrs_to(&self, t: u32) -> Option<&[u32]> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => ix.sorted_nbrs_to(t),
        }
    }

    /// The clean sorted `(neighbor, tid)` run of `f` — both parallel
    /// column slices, available under the same conditions as
    /// [`RelIx::sorted_nbrs_from`].  The WCOJ kernel intersects these in
    /// place; hash/dirty rows take its sorted-memo fallback instead.
    pub fn sorted_run_from(&self, f: u32) -> Option<(&[u32], &[u32])> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => ix.sorted_run_from(f),
        }
    }

    /// The clean sorted `(neighbor, tid)` run of `t` (see
    /// [`RelIx::sorted_run_from`]).
    pub fn sorted_run_to(&self, t: u32) -> Option<(&[u32], &[u32])> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => ix.sorted_run_to(t),
        }
    }

    /// Largest adjacency-list length in either direction.
    pub fn max_degree(&self) -> usize {
        match self {
            RelIx::Hash(ix) => {
                let f = ix.by_from.iter().map(|v| v.len()).max().unwrap_or(0);
                let t = ix.by_to.iter().map(|v| v.len()).max().unwrap_or(0);
                f.max(t)
            }
            RelIx::Csr(ix) => ix.max_degree(),
        }
    }

    /// Number of live relationship pairs.
    pub fn len(&self) -> usize {
        match self {
            RelIx::Hash(ix) => ix.pair.len(),
            RelIx::Csr(ix) => ix.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pending CSR overlay entries (0 on the hash backend).
    pub fn overlay_len(&self) -> usize {
        match self {
            RelIx::Hash(_) => 0,
            RelIx::Csr(ix) => ix.overlay_len(),
        }
    }

    /// Extend the adjacency to cover grown endpoint populations.
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        match self {
            RelIx::Hash(ix) => ix.grow(n_from, n_to),
            RelIx::Csr(ix) => ix.grow(n_from, n_to),
        }
    }

    /// Register a freshly appended tuple (see [`RelIndex::insert`]).
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        match self {
            RelIx::Hash(ix) => ix.insert(from, to, t),
            RelIx::Csr(ix) => ix.insert(from, to, t),
        }
    }

    /// Unregister a swap-removed tuple (see [`RelIndex::remove_swap`]).
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self {
            RelIx::Hash(ix) => ix.remove_swap(from, to, t, last, last_from, last_to),
            RelIx::Csr(ix) => ix.remove_swap(from, to, t, last, last_from, last_to),
        }
    }

    /// Merge any pending CSR overlay into the base runs (no-op on hash).
    pub fn compact(&mut self) {
        if let RelIx::Csr(ix) = self {
            ix.compact();
        }
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            RelIx::Hash(ix) => ix.bytes(),
            RelIx::Csr(ix) => ix.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_adjacency_and_pairs() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let ix = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.by_from[0], vec![0, 1]);
        assert_eq!(ix.by_to[1], vec![0, 2]);
        assert_eq!(ix.lookup(0, 2), Some(1));
        assert_eq!(ix.lookup(1, 2), None);
    }

    #[test]
    fn incremental_insert_and_remove_match_rebuild() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let mut ix = RelIndex::build(&t, 2, 3).unwrap();

        // insert a new tuple incrementally
        let id = t.push(1, 2, &[]).unwrap();
        ix.insert(1, 2, id).unwrap();
        assert_eq!(ix.lookup(1, 2), Some(3));
        assert!(ix.insert(1, 2, 9).is_err()); // duplicate pair

        // remove tuple 1 = (0,2); the last tuple (1,2) takes id 1
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(1).unwrap();
        ix.remove_swap(0, 2, 1, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 2), None);
        assert_eq!(ix.lookup(1, 2), Some(1));

        // the maintained index matches a from-scratch rebuild (as sets)
        let fresh = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.pair, fresh.pair);
        for f in 0..2usize {
            let mut a = ix.by_from[f].clone();
            let mut b = fresh.by_from[f].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "by_from[{f}]");
        }
        for o in 0..3usize {
            let mut a = ix.by_to[o].clone();
            let mut b = fresh.by_to[o].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "by_to[{o}]");
        }
    }

    #[test]
    fn grow_extends_adjacency() {
        let t = RelTable::new(0);
        let mut ix = RelIndex::build(&t, 1, 1).unwrap();
        ix.grow(3, 2);
        assert_eq!(ix.by_from.len(), 3);
        assert_eq!(ix.by_to.len(), 2);
        ix.insert(2, 1, 0).unwrap();
        assert_eq!(ix.lookup(2, 1), Some(0));
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        assert!(RelIndex::build(&t, 2, 2).is_err());

        let mut t2 = RelTable::new(0);
        t2.push(5, 0, &[]).unwrap();
        assert!(RelIndex::build(&t2, 2, 2).is_err());
    }

    #[test]
    fn backend_parse_and_default() {
        assert_eq!(Backend::parse("hash"), Some(Backend::Hash));
        assert_eq!(Backend::parse("CSR"), Some(Backend::Csr));
        assert_eq!(Backend::parse("btree"), None);
        assert_eq!(Backend::default(), Backend::Csr);
        assert_eq!(Backend::Csr.name(), "csr");
    }

    #[test]
    fn relix_backends_agree_on_all_accessors() {
        let mut t = RelTable::new(0);
        t.push(0, 2, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let mut h = RelIx::build(Backend::Hash, &t, 2, 3).unwrap();
        let mut c = RelIx::build(Backend::Csr, &t, 2, 3).unwrap();
        assert_eq!(h.backend(), Backend::Hash);
        assert_eq!(c.backend(), Backend::Csr);
        assert!(c.sorted_nbrs_from(0).is_some());
        assert!(h.sorted_nbrs_from(0).is_none());

        let check = |h: &RelIx, c: &RelIx, t: &RelTable| {
            assert_eq!(h.len(), c.len());
            assert_eq!(h.max_degree(), c.max_degree());
            for f in 0..2u32 {
                assert_eq!(h.degree_from(f), c.degree_from(f), "deg from {f}");
                let mut a: Vec<u32> = h.tids_from(f).collect();
                let mut b: Vec<u32> = c.tids_from(f).collect();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "tids from {f}");
                for k in 0..h.degree_from(f) {
                    assert_eq!(h.nth_from(t, f, k), c.nth_from(t, f, k));
                }
                assert_eq!(h.nth_from(t, f, h.degree_from(f)), None);
            }
            for o in 0..3u32 {
                assert_eq!(h.degree_to(o), c.degree_to(o), "deg to {o}");
                let mut a: Vec<u32> = h.tids_to(o).collect();
                let mut b: Vec<u32> = c.tids_to(o).collect();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "tids to {o}");
                for k in 0..h.degree_to(o) {
                    assert_eq!(h.nth_to(t, o, k), c.nth_to(t, o, k));
                }
                for f in 0..2u32 {
                    assert_eq!(h.lookup(f, o), c.lookup(f, o), "lookup {f},{o}");
                }
            }
        };
        check(&h, &c, &t);

        // churn both through the shared mutation API
        let id = t.push(1, 2, &[]).unwrap();
        h.insert(1, 2, id).unwrap();
        c.insert(1, 2, id).unwrap();
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(0).unwrap();
        h.remove_swap(0, 2, 0, last, lf, lt).unwrap();
        c.remove_swap(0, 2, 0, last, lf, lt).unwrap();
        check(&h, &c, &t);
        c.compact();
        h.compact(); // no-op
        assert_eq!(c.overlay_len(), 0);
        check(&h, &c, &t);
    }
}
