//! FK indexes over relationship tables, behind a [`Backend`] selector:
//!
//! - [`RelIndex`] — the seed-era **hash** engine: per-endpoint adjacency
//!   `Vec`s plus a unique `(from, to) -> tuple` FxHash map used for
//!   indicator lookups and bound-bound join steps;
//! - [`crate::db::csr::CsrIndex`] — the columnar **CSR** engine (the
//!   default): contiguous sorted neighbor runs in both orientations,
//!   with a sorted overlay absorbing churn until compaction;
//! - [`crate::db::ccsr::CcsrIndex`] — the compressed block-**CSR**
//!   engine: the same sorted runs as delta-encoded bit-packed blocks
//!   with per-block skip headers, behind the same overlay.
//!
//! [`RelIx`] is the enum the rest of the crate sees (returned by
//! [`crate::db::catalog::Database::index`]); every consumer goes
//! through its accessors, so the three engines are interchangeable and
//! produce bit-identical counts (asserted by the backend-equivalence
//! tests and the CI digest gate).  Consumers that intersect or walk
//! sorted runs go through one further abstraction, [`NeighborRun`]
//! (and its stateful [`RunCursor`]): a clean run borrowed from
//! whichever representation the backend keeps, so the join kernels and
//! the sampler are written once against runs rather than once per
//! storage engine.

use crate::util::fxhash::FxHashMap;

use crate::db::ccsr::{BlockRun, CcsrIndex, CcsrRow, BLOCK};
use crate::db::csr::{CsrIndex, CsrRow};
use crate::db::table::RelTable;
use crate::error::{Error, Result};

/// Relationship-index storage engine selector (CLI `--backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Seed-era FxHash adjacency lists + pair map.
    Hash,
    /// Columnar CSR with sorted neighbor runs (the default).
    #[default]
    Csr,
    /// Compressed block-CSR: delta-encoded bit-packed runs.
    Ccsr,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(Backend::Hash),
            "csr" => Some(Backend::Csr),
            "ccsr" => Some(Backend::Ccsr),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Hash => "hash",
            Backend::Csr => "csr",
            Backend::Ccsr => "ccsr",
        }
    }
}

/// Index over one relationship table.
#[derive(Clone, Debug, Default)]
pub struct RelIndex {
    /// `by_from[f]` = tuple ids with `from == f`.
    pub by_from: Vec<Vec<u32>>,
    /// `by_to[t]` = tuple ids with `to == t`.
    pub by_to: Vec<Vec<u32>>,
    /// `(from << 32 | to)` -> tuple id.
    pub pair: FxHashMap<u64, u32>,
}

#[inline]
pub fn pair_key(from: u32, to: u32) -> u64 {
    ((from as u64) << 32) | to as u64
}

impl RelIndex {
    /// Build from a table given the endpoint population sizes.
    pub fn build(table: &RelTable, n_from: u32, n_to: u32) -> Result<Self> {
        let mut by_from = vec![Vec::new(); n_from as usize];
        let mut by_to = vec![Vec::new(); n_to as usize];
        let mut pair = FxHashMap::default();
        pair.reserve(table.len() as usize);
        for t in 0..table.len() {
            let f = table.from[t as usize];
            let o = table.to[t as usize];
            if f >= n_from || o >= n_to {
                return Err(Error::Data(format!(
                    "rel tuple ({f},{o}) out of population range ({n_from},{n_to})"
                )));
            }
            if pair.insert(pair_key(f, o), t).is_some() {
                return Err(Error::Data(format!(
                    "duplicate relationship pair ({f},{o})"
                )));
            }
            by_from[f as usize].push(t);
            by_to[o as usize].push(t);
        }
        Ok(RelIndex { by_from, by_to, pair })
    }

    /// Tuple id for a fully-bound pair, if the relationship holds.
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        self.pair.get(&pair_key(from, to)).copied()
    }

    /// Extend the adjacency lists to cover grown endpoint populations
    /// (entity inserts; existing entries are untouched).
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        if self.by_from.len() < n_from as usize {
            self.by_from.resize(n_from as usize, Vec::new());
        }
        if self.by_to.len() < n_to as usize {
            self.by_to.resize(n_to as usize, Vec::new());
        }
    }

    /// Register a freshly appended tuple `t = (from, to)` (incremental
    /// counterpart of [`RelIndex::build`]; duplicate pairs are rejected
    /// before any structure is touched).
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        if from as usize >= self.by_from.len() || to as usize >= self.by_to.len() {
            return Err(Error::Data(format!(
                "rel tuple ({from},{to}) out of population range ({},{})",
                self.by_from.len(),
                self.by_to.len()
            )));
        }
        if self.pair.contains_key(&pair_key(from, to)) {
            return Err(Error::Data(format!(
                "duplicate relationship pair ({from},{to})"
            )));
        }
        self.pair.insert(pair_key(from, to), t);
        self.by_from[from as usize].push(t);
        self.by_to[to as usize].push(t);
        Ok(())
    }

    /// Unregister tuple `t = (from, to)` after a
    /// [`crate::db::table::RelTable::swap_remove`]: the tuple formerly
    /// holding id `last` (endpoints `last_from`, `last_to`) has been
    /// relabeled to `t`, so its index entries move too.  When `t ==
    /// last` (the removed tuple was the last row) nothing is relabeled.
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self.pair.remove(&pair_key(from, to)) {
            Some(id) if id == t => {}
            _ => {
                return Err(Error::Data(format!(
                    "index out of sync removing ({from},{to}) id {t}"
                )))
            }
        }
        let drop_id = |list: &mut Vec<u32>, id: u32| {
            if let Some(p) = list.iter().position(|&x| x == id) {
                list.swap_remove(p);
            }
        };
        drop_id(&mut self.by_from[from as usize], t);
        drop_id(&mut self.by_to[to as usize], t);
        if t != last {
            // relabel the moved tuple: last -> t
            if let Some(id) = self.pair.get_mut(&pair_key(last_from, last_to)) {
                *id = t;
            }
            let relabel = |list: &mut Vec<u32>| {
                if let Some(p) = list.iter().position(|&x| x == last) {
                    list[p] = t;
                }
            };
            relabel(&mut self.by_from[last_from as usize]);
            relabel(&mut self.by_to[last_to as usize]);
        }
        Ok(())
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        let adj: usize = self
            .by_from
            .iter()
            .chain(self.by_to.iter())
            .map(|v| v.capacity() * 4 + 24)
            .sum();
        adj + self.pair.capacity() * 16
    }
}

/// Iterator over the tuple ids adjacent to one endpoint value, for
/// either backend (CSR dirty rows materialize their merged run).
pub enum Tids<'a> {
    Slice(std::slice::Iter<'a, u32>),
    Owned(std::vec::IntoIter<(u32, u32)>),
}

impl Iterator for Tids<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        match self {
            Tids::Slice(it) => it.next().copied(),
            Tids::Owned(it) => it.next().map(|(_, t)| t),
        }
    }
}

/// Skew threshold: gallop instead of merging when one run is this many
/// times longer than the other.
const GALLOP_RATIO: usize = 8;

/// Size of the intersection of two strictly ascending `u32` runs.
///
/// Balanced runs use a linear merge; skewed runs (degree distributions
/// with heavy hitters) gallop the short run's elements through the long
/// one, bounding the work at `O(short · log(long/short))` — the
/// adaptive scheme of Karan et al., "Fast Counting in Machine Learning
/// Applications" (2018).  This is the slice fast path of
/// [`NeighborRun::intersect_count`]; it stays public because plain
/// sorted slices arise outside the run abstraction too.
pub fn intersect_count(mut a: &[u32], mut b: &[u32]) -> u64 {
    if a.len() > b.len() {
        std::mem::swap(&mut a, &mut b);
    }
    if a.is_empty() {
        return 0;
    }
    let mut n = 0u64;
    if b.len() / a.len() >= GALLOP_RATIO {
        let mut lo = 0usize;
        for &x in a {
            lo += gallop_lower_bound(&b[lo..], x);
            if lo >= b.len() {
                break;
            }
            if b[lo] == x {
                n += 1;
                lo += 1;
            }
        }
    } else {
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
    }
    n
}

/// First position in a strictly ascending run whose value is `>= x`,
/// found by doubling probes then a bounded binary search (shared with
/// the WCOJ kernel's leapfrog seeks).
pub(crate) fn gallop_lower_bound(s: &[u32], x: u32) -> usize {
    let mut hi = 1usize;
    while hi < s.len() && s[hi] < x {
        hi <<= 1;
    }
    let lo = hi >> 1;
    let hi = hi.min(s.len());
    lo + s[lo..hi].partition_point(|&v| v < x)
}

/// [`gallop_lower_bound`] over the neighbor component of a pair run.
pub(crate) fn gallop_pairs_lower_bound(s: &[(u32, u32)], x: u32) -> usize {
    let mut hi = 1usize;
    while hi < s.len() && s[hi].0 < x {
        hi <<= 1;
    }
    let lo = hi >> 1;
    let hi = hi.min(s.len());
    lo + s[lo..hi].partition_point(|&(v, _)| v < x)
}

/// A borrowed sorted `(neighbor, tid)` run, in whichever representation
/// its owner keeps: plain CSR column slices, compressed ccsr blocks, or
/// a caller-memoized pair vector (the hash backend and dirty rows).
/// Every consumer of sorted runs — the chain kernel's intersection fast
/// path, the WCOJ leapfrog, the sampler's canonical-order draws — is
/// written against this enum, so adding a storage engine means adding a
/// variant here rather than a fourth copy of each kernel.
#[derive(Clone, Copy)]
pub enum NeighborRun<'a> {
    /// Clean plain-CSR row: parallel column slices.
    Slice { nbr: &'a [u32], tid: &'a [u32] },
    /// Clean compressed block-CSR row (decode on access).
    Blocks(BlockRun<'a>),
    /// Memoized sorted row borrowed from caller-owned storage.
    Pairs(&'a [(u32, u32)]),
}

impl<'a> NeighborRun<'a> {
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            NeighborRun::Slice { nbr, .. } => nbr.len(),
            NeighborRun::Blocks(r) => r.len(),
            NeighborRun::Pairs(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Neighbor at position `k` (ascending order).  On `Blocks` this
    /// decodes `k`'s block — O(BLOCK) per call, intended for one-off
    /// draws like the sampler's; iteration should use [`RunCursor`].
    #[inline]
    pub fn value_at(&self, k: usize) -> u32 {
        match self {
            NeighborRun::Slice { nbr, .. } => nbr[k],
            NeighborRun::Blocks(r) => r.get(k).0,
            NeighborRun::Pairs(p) => p[k].0,
        }
    }

    /// `(neighbor, tid)` at position `k` (see [`NeighborRun::value_at`]).
    #[inline]
    pub fn pair_at(&self, k: usize) -> (u32, u32) {
        match self {
            NeighborRun::Slice { nbr, tid } => (nbr[k], tid[k]),
            NeighborRun::Blocks(r) => r.get(k),
            NeighborRun::Pairs(p) => p[k],
        }
    }

    /// Size of the intersection with `other`.  Two plain slices take
    /// the adaptive merge/gallop kernel unchanged; any combination
    /// involving blocks or pairs runs a cursor-gallop loop whose seeks
    /// skip whole ccsr blocks by their min/max headers before paying
    /// for a decode.  Exact for every variant combination — the
    /// backends stay bit-identical through this call.
    pub fn intersect_count(&self, other: &NeighborRun<'_>) -> u64 {
        if let (
            NeighborRun::Slice { nbr: a, .. },
            NeighborRun::Slice { nbr: b, .. },
        ) = (self, other)
        {
            return intersect_count(a, b);
        }
        let mut ca = RunCursor::new(*self);
        let mut cb = RunCursor::new(*other);
        let (la, lb) = (ca.len(), cb.len());
        let (mut i, mut j) = (0usize, 0usize);
        let mut n = 0u64;
        while i < la && j < lb {
            let va = ca.val(i);
            let vb = cb.val(j);
            match va.cmp(&vb) {
                std::cmp::Ordering::Less => i = ca.seek(i + 1, vb),
                std::cmp::Ordering::Greater => j = cb.seek(j + 1, va),
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }
}

/// Forward cursor over a [`NeighborRun`] with a one-block decode cache:
/// `Slice`/`Pairs` index their borrows directly, `Blocks` decodes a
/// block into the inline buffers on first touch and reuses it until the
/// cursor crosses a block boundary.  The WCOJ leapfrog and the generic
/// intersection loop drive these; positions only move forward, so each
/// block decodes at most once per pass.
pub struct RunCursor<'a> {
    run: NeighborRun<'a>,
    /// Row-local index of the cached decoded block (`usize::MAX` none).
    blk: usize,
    buf_nbr: [u32; BLOCK],
    buf_tid: [u32; BLOCK],
}

impl<'a> RunCursor<'a> {
    pub fn new(run: NeighborRun<'a>) -> RunCursor<'a> {
        RunCursor {
            run,
            blk: usize::MAX,
            buf_nbr: [0; BLOCK],
            buf_tid: [0; BLOCK],
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.run.len()
    }

    #[inline]
    fn load(&mut self, r: BlockRun<'a>, b: usize) {
        if self.blk != b {
            r.decode_block(b, &mut self.buf_nbr, &mut self.buf_tid);
            self.blk = b;
        }
    }

    /// Neighbor at position `i`.
    #[inline]
    pub fn val(&mut self, i: usize) -> u32 {
        match self.run {
            NeighborRun::Slice { nbr, .. } => nbr[i],
            NeighborRun::Pairs(p) => p[i].0,
            NeighborRun::Blocks(r) => {
                self.load(r, i / BLOCK);
                self.buf_nbr[i % BLOCK]
            }
        }
    }

    /// Tuple id at position `i`.
    #[inline]
    pub fn tid(&mut self, i: usize) -> u32 {
        match self.run {
            NeighborRun::Slice { tid, .. } => tid[i],
            NeighborRun::Pairs(p) => p[i].1,
            NeighborRun::Blocks(r) => {
                self.load(r, i / BLOCK);
                self.buf_tid[i % BLOCK]
            }
        }
    }

    /// First position `>= lo` whose neighbor is `>= x` (`len()` if
    /// none).  Slices and pairs gallop; blocks first skip by the
    /// `nbr_max` headers and only decode the one block that can hold
    /// the target.
    pub fn seek(&mut self, lo: usize, x: u32) -> usize {
        match self.run {
            NeighborRun::Slice { nbr, .. } => lo + gallop_lower_bound(&nbr[lo..], x),
            NeighborRun::Pairs(p) => lo + gallop_pairs_lower_bound(&p[lo..], x),
            NeighborRun::Blocks(r) => {
                if lo >= r.len() {
                    return r.len();
                }
                let b0 = lo / BLOCK;
                let b = r.seek_block(b0, x);
                if b == r.n_blocks() {
                    return r.len();
                }
                self.load(r, b);
                // the target block's max is >= x, so the partition
                // point lands strictly inside it
                let start = if b == b0 { lo % BLOCK } else { 0 };
                let blen = r.block_len(b);
                b * BLOCK
                    + start
                    + self.buf_nbr[start..blen].partition_point(|&v| v < x)
            }
        }
    }
}

/// A relationship index of any backend.  All consumers (join
/// enumeration, the wander-join sampler, delta maintenance, the Möbius
/// indicator probes) go through these accessors, so hash, CSR and ccsr
/// are interchangeable bit-for-bit.
#[derive(Clone, Debug)]
pub enum RelIx {
    Hash(RelIndex),
    Csr(CsrIndex),
    Ccsr(CcsrIndex),
}

impl RelIx {
    /// Build an index of the selected backend from a table.
    pub fn build(
        backend: Backend,
        table: &RelTable,
        n_from: u32,
        n_to: u32,
    ) -> Result<RelIx> {
        match backend {
            Backend::Hash => Ok(RelIx::Hash(RelIndex::build(table, n_from, n_to)?)),
            Backend::Csr => Ok(RelIx::Csr(CsrIndex::build(table, n_from, n_to)?)),
            Backend::Ccsr => Ok(RelIx::Ccsr(CcsrIndex::build(table, n_from, n_to)?)),
        }
    }

    pub fn backend(&self) -> Backend {
        match self {
            RelIx::Hash(_) => Backend::Hash,
            RelIx::Csr(_) => Backend::Csr,
            RelIx::Ccsr(_) => Backend::Ccsr,
        }
    }

    /// The underlying CSR index, if this is the CSR backend (snapshot
    /// serialization reads the compacted base arrays through this).
    pub fn as_csr(&self) -> Option<&CsrIndex> {
        match self {
            RelIx::Csr(ix) => Some(ix),
            _ => None,
        }
    }

    /// The underlying compressed index, if this is the ccsr backend
    /// (snapshot serialization reads the compacted blocks through this).
    pub fn as_ccsr(&self) -> Option<&CcsrIndex> {
        match self {
            RelIx::Ccsr(ix) => Some(ix),
            _ => None,
        }
    }

    /// Tuple id for a fully-bound pair, if the relationship holds.
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        match self {
            RelIx::Hash(ix) => ix.lookup(from, to),
            RelIx::Csr(ix) => ix.lookup(from, to),
            RelIx::Ccsr(ix) => ix.lookup(from, to),
        }
    }

    /// Adjacency degree of `from`.
    #[inline]
    pub fn degree_from(&self, f: u32) -> usize {
        match self {
            RelIx::Hash(ix) => ix.by_from[f as usize].len(),
            RelIx::Csr(ix) => ix.degree_from(f),
            RelIx::Ccsr(ix) => ix.degree_from(f),
        }
    }

    /// Adjacency degree of `to`.
    #[inline]
    pub fn degree_to(&self, t: u32) -> usize {
        match self {
            RelIx::Hash(ix) => ix.by_to[t as usize].len(),
            RelIx::Csr(ix) => ix.degree_to(t),
            RelIx::Ccsr(ix) => ix.degree_to(t),
        }
    }

    /// Tuple ids with `from == f` (hash: insertion order; CSR: sorted by
    /// neighbor — counting consumers are order-independent).
    pub fn tids_from(&self, f: u32) -> Tids<'_> {
        match self {
            RelIx::Hash(ix) => Tids::Slice(ix.by_from[f as usize].iter()),
            RelIx::Csr(ix) => match ix.row_from(f) {
                CsrRow::Clean { tid, .. } => Tids::Slice(tid.iter()),
                CsrRow::Dirty(v) => Tids::Owned(v.into_iter()),
            },
            RelIx::Ccsr(ix) => match ix.row_from(f) {
                CcsrRow::Clean(run) => Tids::Owned(run.to_pairs().into_iter()),
                CcsrRow::Dirty(v) => Tids::Owned(v.into_iter()),
            },
        }
    }

    /// Tuple ids with `to == t`.
    pub fn tids_to(&self, t: u32) -> Tids<'_> {
        match self {
            RelIx::Hash(ix) => Tids::Slice(ix.by_to[t as usize].iter()),
            RelIx::Csr(ix) => match ix.row_to(t) {
                CsrRow::Clean { tid, .. } => Tids::Slice(tid.iter()),
                CsrRow::Dirty(v) => Tids::Owned(v.into_iter()),
            },
            RelIx::Ccsr(ix) => match ix.row_to(t) {
                CcsrRow::Clean(run) => Tids::Owned(run.to_pairs().into_iter()),
                CcsrRow::Dirty(v) => Tids::Owned(v.into_iter()),
            },
        }
    }

    /// The `k`-th `(neighbor, tuple id)` of `f` in **ascending neighbor
    /// order** — the canonical ordering both backends share, so seeded
    /// samplers (the ADAPTIVE wander-join estimator) draw identical
    /// walks on either engine.  CSR reads its sorted run directly; the
    /// hash backend sorts the row on demand (sampling-path only).
    pub fn nth_from(&self, table: &RelTable, f: u32, k: usize) -> Option<(u32, u32)> {
        match self {
            RelIx::Hash(ix) => {
                let list = ix.by_from.get(f as usize)?;
                let mut row: Vec<(u32, u32)> =
                    list.iter().map(|&t| (table.to[t as usize], t)).collect();
                row.sort_unstable();
                row.get(k).copied()
            }
            RelIx::Csr(ix) => match ix.row_from(f) {
                CsrRow::Clean { nbr, tid } => nbr.get(k).map(|&n| (n, tid[k])),
                CsrRow::Dirty(v) => v.get(k).copied(),
            },
            RelIx::Ccsr(ix) => match ix.row_from(f) {
                CcsrRow::Clean(run) => (k < run.len()).then(|| run.get(k)),
                CcsrRow::Dirty(v) => v.get(k).copied(),
            },
        }
    }

    /// The `k`-th `(neighbor, tuple id)` of `t` in ascending neighbor
    /// order (see [`RelIx::nth_from`]).
    pub fn nth_to(&self, table: &RelTable, t: u32, k: usize) -> Option<(u32, u32)> {
        match self {
            RelIx::Hash(ix) => {
                let list = ix.by_to.get(t as usize)?;
                let mut row: Vec<(u32, u32)> =
                    list.iter().map(|&x| (table.from[x as usize], x)).collect();
                row.sort_unstable();
                row.get(k).copied()
            }
            RelIx::Csr(ix) => match ix.row_to(t) {
                CsrRow::Clean { nbr, tid } => nbr.get(k).map(|&n| (n, tid[k])),
                CsrRow::Dirty(v) => v.get(k).copied(),
            },
            RelIx::Ccsr(ix) => match ix.row_to(t) {
                CcsrRow::Clean(run) => (k < run.len()).then(|| run.get(k)),
                CcsrRow::Dirty(v) => v.get(k).copied(),
            },
        }
    }

    /// The contiguous sorted neighbor run of `f` — `Some` only on the
    /// plain CSR backend with no pending overlay in the row (ccsr rows
    /// are packed, not contiguous; use [`RelIx::neighbor_run_from`] for
    /// the backend-generic borrow).
    pub fn sorted_nbrs_from(&self, f: u32) -> Option<&[u32]> {
        match self {
            RelIx::Csr(ix) => ix.sorted_nbrs_from(f),
            _ => None,
        }
    }

    /// The contiguous sorted neighbor run of `t` (see
    /// [`RelIx::sorted_nbrs_from`]).
    pub fn sorted_nbrs_to(&self, t: u32) -> Option<&[u32]> {
        match self {
            RelIx::Csr(ix) => ix.sorted_nbrs_to(t),
            _ => None,
        }
    }

    /// The clean sorted `(neighbor, tid)` run of `f` — both parallel
    /// column slices, available under the same conditions as
    /// [`RelIx::sorted_nbrs_from`] (plain CSR only).
    pub fn sorted_run_from(&self, f: u32) -> Option<(&[u32], &[u32])> {
        match self {
            RelIx::Csr(ix) => ix.sorted_run_from(f),
            _ => None,
        }
    }

    /// The clean sorted `(neighbor, tid)` run of `t` (see
    /// [`RelIx::sorted_run_from`]).
    pub fn sorted_run_to(&self, t: u32) -> Option<(&[u32], &[u32])> {
        match self {
            RelIx::Csr(ix) => ix.sorted_run_to(t),
            _ => None,
        }
    }

    /// The clean sorted run of `f` as a backend-generic [`NeighborRun`]
    /// borrow — `Some` exactly when the row can be read without
    /// materialization: a clean CSR row lends its column slices, a
    /// clean ccsr row lends its packed blocks.  Hash rows and rows with
    /// pending overlay entries return `None`; consumers (the chain
    /// kernel's intersection fast path, the WCOJ leapfrog, the sampler)
    /// fall back to memoized enumeration there, identically on every
    /// backend.
    pub fn neighbor_run_from(&self, f: u32) -> Option<NeighborRun<'_>> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => ix
                .sorted_run_from(f)
                .map(|(nbr, tid)| NeighborRun::Slice { nbr, tid }),
            RelIx::Ccsr(ix) => ix.block_run_from(f).map(NeighborRun::Blocks),
        }
    }

    /// The clean sorted run of `t` as a [`NeighborRun`] borrow (see
    /// [`RelIx::neighbor_run_from`]).
    pub fn neighbor_run_to(&self, t: u32) -> Option<NeighborRun<'_>> {
        match self {
            RelIx::Hash(_) => None,
            RelIx::Csr(ix) => ix
                .sorted_run_to(t)
                .map(|(nbr, tid)| NeighborRun::Slice { nbr, tid }),
            RelIx::Ccsr(ix) => ix.block_run_to(t).map(NeighborRun::Blocks),
        }
    }

    /// Largest adjacency-list length in either direction.
    pub fn max_degree(&self) -> usize {
        match self {
            RelIx::Hash(ix) => {
                let f = ix.by_from.iter().map(|v| v.len()).max().unwrap_or(0);
                let t = ix.by_to.iter().map(|v| v.len()).max().unwrap_or(0);
                f.max(t)
            }
            RelIx::Csr(ix) => ix.max_degree(),
            RelIx::Ccsr(ix) => ix.max_degree(),
        }
    }

    /// Number of live relationship pairs.
    pub fn len(&self) -> usize {
        match self {
            RelIx::Hash(ix) => ix.pair.len(),
            RelIx::Csr(ix) => ix.len(),
            RelIx::Ccsr(ix) => ix.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pending overlay entries (0 on the hash backend).
    pub fn overlay_len(&self) -> usize {
        match self {
            RelIx::Hash(_) => 0,
            RelIx::Csr(ix) => ix.overlay_len(),
            RelIx::Ccsr(ix) => ix.overlay_len(),
        }
    }

    /// Extend the adjacency to cover grown endpoint populations.
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        match self {
            RelIx::Hash(ix) => ix.grow(n_from, n_to),
            RelIx::Csr(ix) => ix.grow(n_from, n_to),
            RelIx::Ccsr(ix) => ix.grow(n_from, n_to),
        }
    }

    /// Register a freshly appended tuple (see [`RelIndex::insert`]).
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        match self {
            RelIx::Hash(ix) => ix.insert(from, to, t),
            RelIx::Csr(ix) => ix.insert(from, to, t),
            RelIx::Ccsr(ix) => ix.insert(from, to, t),
        }
    }

    /// Unregister a swap-removed tuple (see [`RelIndex::remove_swap`]).
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self {
            RelIx::Hash(ix) => ix.remove_swap(from, to, t, last, last_from, last_to),
            RelIx::Csr(ix) => ix.remove_swap(from, to, t, last, last_from, last_to),
            RelIx::Ccsr(ix) => ix.remove_swap(from, to, t, last, last_from, last_to),
        }
    }

    /// Merge any pending overlay into the base runs (no-op on hash).
    pub fn compact(&mut self) {
        match self {
            RelIx::Hash(_) => {}
            RelIx::Csr(ix) => ix.compact(),
            RelIx::Ccsr(ix) => ix.compact(),
        }
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            RelIx::Hash(ix) => ix.bytes(),
            RelIx::Csr(ix) => ix.bytes(),
            RelIx::Ccsr(ix) => ix.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_adjacency_and_pairs() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let ix = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.by_from[0], vec![0, 1]);
        assert_eq!(ix.by_to[1], vec![0, 2]);
        assert_eq!(ix.lookup(0, 2), Some(1));
        assert_eq!(ix.lookup(1, 2), None);
    }

    #[test]
    fn incremental_insert_and_remove_match_rebuild() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let mut ix = RelIndex::build(&t, 2, 3).unwrap();

        // insert a new tuple incrementally
        let id = t.push(1, 2, &[]).unwrap();
        ix.insert(1, 2, id).unwrap();
        assert_eq!(ix.lookup(1, 2), Some(3));
        assert!(ix.insert(1, 2, 9).is_err()); // duplicate pair

        // remove tuple 1 = (0,2); the last tuple (1,2) takes id 1
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(1).unwrap();
        ix.remove_swap(0, 2, 1, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 2), None);
        assert_eq!(ix.lookup(1, 2), Some(1));

        // the maintained index matches a from-scratch rebuild (as sets)
        let fresh = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.pair, fresh.pair);
        for f in 0..2usize {
            let mut a = ix.by_from[f].clone();
            let mut b = fresh.by_from[f].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "by_from[{f}]");
        }
        for o in 0..3usize {
            let mut a = ix.by_to[o].clone();
            let mut b = fresh.by_to[o].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "by_to[{o}]");
        }
    }

    #[test]
    fn grow_extends_adjacency() {
        let t = RelTable::new(0);
        let mut ix = RelIndex::build(&t, 1, 1).unwrap();
        ix.grow(3, 2);
        assert_eq!(ix.by_from.len(), 3);
        assert_eq!(ix.by_to.len(), 2);
        ix.insert(2, 1, 0).unwrap();
        assert_eq!(ix.lookup(2, 1), Some(0));
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        assert!(RelIndex::build(&t, 2, 2).is_err());

        let mut t2 = RelTable::new(0);
        t2.push(5, 0, &[]).unwrap();
        assert!(RelIndex::build(&t2, 2, 2).is_err());
    }

    #[test]
    fn backend_parse_and_default() {
        assert_eq!(Backend::parse("hash"), Some(Backend::Hash));
        assert_eq!(Backend::parse("CSR"), Some(Backend::Csr));
        assert_eq!(Backend::parse("ccsr"), Some(Backend::Ccsr));
        assert_eq!(Backend::parse("CCSR"), Some(Backend::Ccsr));
        assert_eq!(Backend::parse("btree"), None);
        assert_eq!(Backend::default(), Backend::Csr);
        assert_eq!(Backend::Csr.name(), "csr");
        assert_eq!(Backend::Ccsr.name(), "ccsr");
    }

    #[test]
    fn relix_backends_agree_on_all_accessors() {
        let mut t = RelTable::new(0);
        t.push(0, 2, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let mut h = RelIx::build(Backend::Hash, &t, 2, 3).unwrap();
        let mut c = RelIx::build(Backend::Csr, &t, 2, 3).unwrap();
        let mut z = RelIx::build(Backend::Ccsr, &t, 2, 3).unwrap();
        assert_eq!(h.backend(), Backend::Hash);
        assert_eq!(c.backend(), Backend::Csr);
        assert_eq!(z.backend(), Backend::Ccsr);
        assert!(c.sorted_nbrs_from(0).is_some());
        assert!(h.sorted_nbrs_from(0).is_none());
        assert!(z.sorted_nbrs_from(0).is_none(), "ccsr runs are packed");
        assert!(c.neighbor_run_from(0).is_some());
        assert!(z.neighbor_run_from(0).is_some());
        assert!(h.neighbor_run_from(0).is_none());

        let check = |h: &RelIx, c: &RelIx, t: &RelTable| {
            assert_eq!(h.len(), c.len());
            assert_eq!(h.max_degree(), c.max_degree());
            for f in 0..2u32 {
                assert_eq!(h.degree_from(f), c.degree_from(f), "deg from {f}");
                let mut a: Vec<u32> = h.tids_from(f).collect();
                let mut b: Vec<u32> = c.tids_from(f).collect();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "tids from {f}");
                for k in 0..h.degree_from(f) {
                    assert_eq!(h.nth_from(t, f, k), c.nth_from(t, f, k));
                }
                assert_eq!(h.nth_from(t, f, h.degree_from(f)), None);
            }
            for o in 0..3u32 {
                assert_eq!(h.degree_to(o), c.degree_to(o), "deg to {o}");
                let mut a: Vec<u32> = h.tids_to(o).collect();
                let mut b: Vec<u32> = c.tids_to(o).collect();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "tids to {o}");
                for k in 0..h.degree_to(o) {
                    assert_eq!(h.nth_to(t, o, k), c.nth_to(t, o, k));
                }
                for f in 0..2u32 {
                    assert_eq!(h.lookup(f, o), c.lookup(f, o), "lookup {f},{o}");
                }
            }
        };
        check(&h, &c, &t);
        check(&h, &z, &t);

        // churn all three through the shared mutation API
        let id = t.push(1, 2, &[]).unwrap();
        h.insert(1, 2, id).unwrap();
        c.insert(1, 2, id).unwrap();
        z.insert(1, 2, id).unwrap();
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(0).unwrap();
        h.remove_swap(0, 2, 0, last, lf, lt).unwrap();
        c.remove_swap(0, 2, 0, last, lf, lt).unwrap();
        z.remove_swap(0, 2, 0, last, lf, lt).unwrap();
        check(&h, &c, &t);
        check(&h, &z, &t);
        c.compact();
        z.compact();
        h.compact(); // no-op
        assert_eq!(c.overlay_len(), 0);
        assert_eq!(z.overlay_len(), 0);
        check(&h, &c, &t);
        check(&h, &z, &t);
    }

    /// Two multi-block relationship rows sharing a population, indexed
    /// by every backend — the fixture for run-abstraction tests.
    fn run_fixture() -> (RelTable, Vec<u32>, Vec<u32>) {
        let mut t = RelTable::new(0);
        let mut r0 = Vec::new();
        let mut r1 = Vec::new();
        for v in 0..600u32 {
            if v % 3 != 1 {
                t.push(0, v, &[]).unwrap();
                r0.push(v);
            }
        }
        for v in 0..600u32 {
            if v % 7 < 5 {
                t.push(1, v, &[]).unwrap();
                r1.push(v);
            }
        }
        (t, r0, r1)
    }

    #[test]
    fn neighbor_run_variants_read_identically() {
        let (t, r0, _) = run_fixture();
        let c = RelIx::build(Backend::Csr, &t, 2, 600).unwrap();
        let z = RelIx::build(Backend::Ccsr, &t, 2, 600).unwrap();
        let rc = c.neighbor_run_from(0).unwrap();
        let rz = z.neighbor_run_from(0).unwrap();
        assert_eq!(rc.len(), r0.len());
        assert_eq!(rz.len(), r0.len());
        for k in 0..r0.len() {
            assert_eq!(rc.value_at(k), r0[k]);
            assert_eq!(rz.value_at(k), r0[k], "ccsr value_at {k}");
            assert_eq!(rc.pair_at(k), rz.pair_at(k), "pair_at {k}");
        }
        // cursor seek matches partition_point on every variant
        let mut cc = RunCursor::new(rc);
        let mut cz = RunCursor::new(rz);
        for x in [0u32, 1, 2, 63, 64, 299, 300, 301, 598, 599, 1000] {
            let want = r0.partition_point(|&v| v < x);
            assert_eq!(cc.seek(0, x), want, "slice seek {x}");
            assert_eq!(cz.seek(0, x), want, "blocks seek {x}");
        }
        // forward-only seeks from interior positions
        let mut cz = RunCursor::new(rz);
        let mut pos = 0;
        for x in [5u32, 70, 71, 200, 450, 599] {
            let want = r0.partition_point(|&v| v < x).max(pos);
            pos = cz.seek(pos, x);
            assert_eq!(pos, want, "interior seek {x}");
        }
    }

    #[test]
    fn intersect_count_agrees_across_run_variants() {
        let (t, r0, r1) = run_fixture();
        let c = RelIx::build(Backend::Csr, &t, 2, 600).unwrap();
        let z = RelIx::build(Backend::Ccsr, &t, 2, 600).unwrap();
        let brute = r0.iter().filter(|v| r1.binary_search(v).is_ok()).count() as u64;
        let (c0, c1) = (
            c.neighbor_run_from(0).unwrap(),
            c.neighbor_run_from(1).unwrap(),
        );
        let (z0, z1) = (
            z.neighbor_run_from(0).unwrap(),
            z.neighbor_run_from(1).unwrap(),
        );
        let pairs0: Vec<(u32, u32)> = (0..c0.len()).map(|k| c0.pair_at(k)).collect();
        let p0 = NeighborRun::Pairs(&pairs0);
        // every variant pairing lands on the brute-force size
        assert_eq!(c0.intersect_count(&c1), brute, "slice x slice");
        assert_eq!(z0.intersect_count(&z1), brute, "blocks x blocks");
        assert_eq!(c0.intersect_count(&z1), brute, "slice x blocks");
        assert_eq!(z0.intersect_count(&c1), brute, "blocks x slice");
        assert_eq!(p0.intersect_count(&z1), brute, "pairs x blocks");
        assert_eq!(p0.intersect_count(&c1), brute, "pairs x slice");
        // degenerate: empty row intersects to zero on both engines
        let e = RelIx::build(Backend::Ccsr, &RelTable::new(0), 1, 1).unwrap();
        let ez = e.neighbor_run_from(0).unwrap();
        assert_eq!(ez.len(), 0);
        assert_eq!(ez.intersect_count(&z0), 0);
        assert_eq!(z0.intersect_count(&ez), 0);
    }
}
