//! FK hash indexes over relationship tables: adjacency lists in both
//! directions plus a unique `(from, to) -> tuple` map used for indicator
//! lookups and bound-bound join steps.

use crate::util::fxhash::FxHashMap;

use crate::db::table::RelTable;
use crate::error::{Error, Result};

/// Index over one relationship table.
#[derive(Clone, Debug, Default)]
pub struct RelIndex {
    /// `by_from[f]` = tuple ids with `from == f`.
    pub by_from: Vec<Vec<u32>>,
    /// `by_to[t]` = tuple ids with `to == t`.
    pub by_to: Vec<Vec<u32>>,
    /// `(from << 32 | to)` -> tuple id.
    pub pair: FxHashMap<u64, u32>,
}

#[inline]
pub fn pair_key(from: u32, to: u32) -> u64 {
    ((from as u64) << 32) | to as u64
}

impl RelIndex {
    /// Build from a table given the endpoint population sizes.
    pub fn build(table: &RelTable, n_from: u32, n_to: u32) -> Result<Self> {
        let mut by_from = vec![Vec::new(); n_from as usize];
        let mut by_to = vec![Vec::new(); n_to as usize];
        let mut pair = FxHashMap::default();
        pair.reserve(table.len() as usize);
        for t in 0..table.len() {
            let f = table.from[t as usize];
            let o = table.to[t as usize];
            if f >= n_from || o >= n_to {
                return Err(Error::Data(format!(
                    "rel tuple ({f},{o}) out of population range ({n_from},{n_to})"
                )));
            }
            if pair.insert(pair_key(f, o), t).is_some() {
                return Err(Error::Data(format!(
                    "duplicate relationship pair ({f},{o})"
                )));
            }
            by_from[f as usize].push(t);
            by_to[o as usize].push(t);
        }
        Ok(RelIndex { by_from, by_to, pair })
    }

    /// Tuple id for a fully-bound pair, if the relationship holds.
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        self.pair.get(&pair_key(from, to)).copied()
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        let adj: usize = self
            .by_from
            .iter()
            .chain(self.by_to.iter())
            .map(|v| v.capacity() * 4 + 24)
            .sum();
        adj + self.pair.capacity() * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_adjacency_and_pairs() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let ix = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.by_from[0], vec![0, 1]);
        assert_eq!(ix.by_to[1], vec![0, 2]);
        assert_eq!(ix.lookup(0, 2), Some(1));
        assert_eq!(ix.lookup(1, 2), None);
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        assert!(RelIndex::build(&t, 2, 2).is_err());

        let mut t2 = RelTable::new(0);
        t2.push(5, 0, &[]).unwrap();
        assert!(RelIndex::build(&t2, 2, 2).is_err());
    }
}
