//! FK hash indexes over relationship tables: adjacency lists in both
//! directions plus a unique `(from, to) -> tuple` map used for indicator
//! lookups and bound-bound join steps.

use crate::util::fxhash::FxHashMap;

use crate::db::table::RelTable;
use crate::error::{Error, Result};

/// Index over one relationship table.
#[derive(Clone, Debug, Default)]
pub struct RelIndex {
    /// `by_from[f]` = tuple ids with `from == f`.
    pub by_from: Vec<Vec<u32>>,
    /// `by_to[t]` = tuple ids with `to == t`.
    pub by_to: Vec<Vec<u32>>,
    /// `(from << 32 | to)` -> tuple id.
    pub pair: FxHashMap<u64, u32>,
}

#[inline]
pub fn pair_key(from: u32, to: u32) -> u64 {
    ((from as u64) << 32) | to as u64
}

impl RelIndex {
    /// Build from a table given the endpoint population sizes.
    pub fn build(table: &RelTable, n_from: u32, n_to: u32) -> Result<Self> {
        let mut by_from = vec![Vec::new(); n_from as usize];
        let mut by_to = vec![Vec::new(); n_to as usize];
        let mut pair = FxHashMap::default();
        pair.reserve(table.len() as usize);
        for t in 0..table.len() {
            let f = table.from[t as usize];
            let o = table.to[t as usize];
            if f >= n_from || o >= n_to {
                return Err(Error::Data(format!(
                    "rel tuple ({f},{o}) out of population range ({n_from},{n_to})"
                )));
            }
            if pair.insert(pair_key(f, o), t).is_some() {
                return Err(Error::Data(format!(
                    "duplicate relationship pair ({f},{o})"
                )));
            }
            by_from[f as usize].push(t);
            by_to[o as usize].push(t);
        }
        Ok(RelIndex { by_from, by_to, pair })
    }

    /// Tuple id for a fully-bound pair, if the relationship holds.
    #[inline]
    pub fn lookup(&self, from: u32, to: u32) -> Option<u32> {
        self.pair.get(&pair_key(from, to)).copied()
    }

    /// Extend the adjacency lists to cover grown endpoint populations
    /// (entity inserts; existing entries are untouched).
    pub fn grow(&mut self, n_from: u32, n_to: u32) {
        if self.by_from.len() < n_from as usize {
            self.by_from.resize(n_from as usize, Vec::new());
        }
        if self.by_to.len() < n_to as usize {
            self.by_to.resize(n_to as usize, Vec::new());
        }
    }

    /// Register a freshly appended tuple `t = (from, to)` (incremental
    /// counterpart of [`RelIndex::build`]; duplicate pairs are rejected
    /// before any structure is touched).
    pub fn insert(&mut self, from: u32, to: u32, t: u32) -> Result<()> {
        if from as usize >= self.by_from.len() || to as usize >= self.by_to.len() {
            return Err(Error::Data(format!(
                "rel tuple ({from},{to}) out of population range ({},{})",
                self.by_from.len(),
                self.by_to.len()
            )));
        }
        if self.pair.contains_key(&pair_key(from, to)) {
            return Err(Error::Data(format!(
                "duplicate relationship pair ({from},{to})"
            )));
        }
        self.pair.insert(pair_key(from, to), t);
        self.by_from[from as usize].push(t);
        self.by_to[to as usize].push(t);
        Ok(())
    }

    /// Unregister tuple `t = (from, to)` after a
    /// [`crate::db::table::RelTable::swap_remove`]: the tuple formerly
    /// holding id `last` (endpoints `last_from`, `last_to`) has been
    /// relabeled to `t`, so its index entries move too.  When `t ==
    /// last` (the removed tuple was the last row) nothing is relabeled.
    pub fn remove_swap(
        &mut self,
        from: u32,
        to: u32,
        t: u32,
        last: u32,
        last_from: u32,
        last_to: u32,
    ) -> Result<()> {
        match self.pair.remove(&pair_key(from, to)) {
            Some(id) if id == t => {}
            _ => {
                return Err(Error::Data(format!(
                    "index out of sync removing ({from},{to}) id {t}"
                )))
            }
        }
        let drop_id = |list: &mut Vec<u32>, id: u32| {
            if let Some(p) = list.iter().position(|&x| x == id) {
                list.swap_remove(p);
            }
        };
        drop_id(&mut self.by_from[from as usize], t);
        drop_id(&mut self.by_to[to as usize], t);
        if t != last {
            // relabel the moved tuple: last -> t
            if let Some(id) = self.pair.get_mut(&pair_key(last_from, last_to)) {
                *id = t;
            }
            let relabel = |list: &mut Vec<u32>| {
                if let Some(p) = list.iter().position(|&x| x == last) {
                    list[p] = t;
                }
            };
            relabel(&mut self.by_from[last_from as usize]);
            relabel(&mut self.by_to[last_to as usize]);
        }
        Ok(())
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        let adj: usize = self
            .by_from
            .iter()
            .chain(self.by_to.iter())
            .map(|v| v.capacity() * 4 + 24)
            .sum();
        adj + self.pair.capacity() * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_adjacency_and_pairs() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let ix = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.by_from[0], vec![0, 1]);
        assert_eq!(ix.by_to[1], vec![0, 2]);
        assert_eq!(ix.lookup(0, 2), Some(1));
        assert_eq!(ix.lookup(1, 2), None);
    }

    #[test]
    fn incremental_insert_and_remove_match_rebuild() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 2, &[]).unwrap();
        t.push(1, 1, &[]).unwrap();
        let mut ix = RelIndex::build(&t, 2, 3).unwrap();

        // insert a new tuple incrementally
        let id = t.push(1, 2, &[]).unwrap();
        ix.insert(1, 2, id).unwrap();
        assert_eq!(ix.lookup(1, 2), Some(3));
        assert!(ix.insert(1, 2, 9).is_err()); // duplicate pair

        // remove tuple 1 = (0,2); the last tuple (1,2) takes id 1
        let last = t.len() - 1;
        let (lf, lt) = (t.from[last as usize], t.to[last as usize]);
        t.swap_remove(1).unwrap();
        ix.remove_swap(0, 2, 1, last, lf, lt).unwrap();
        assert_eq!(ix.lookup(0, 2), None);
        assert_eq!(ix.lookup(1, 2), Some(1));

        // the maintained index matches a from-scratch rebuild (as sets)
        let fresh = RelIndex::build(&t, 2, 3).unwrap();
        assert_eq!(ix.pair, fresh.pair);
        for f in 0..2usize {
            let mut a = ix.by_from[f].clone();
            let mut b = fresh.by_from[f].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "by_from[{f}]");
        }
        for o in 0..3usize {
            let mut a = ix.by_to[o].clone();
            let mut b = fresh.by_to[o].clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "by_to[{o}]");
        }
    }

    #[test]
    fn grow_extends_adjacency() {
        let t = RelTable::new(0);
        let mut ix = RelIndex::build(&t, 1, 1).unwrap();
        ix.grow(3, 2);
        assert_eq!(ix.by_from.len(), 3);
        assert_eq!(ix.by_to.len(), 2);
        ix.insert(2, 1, 0).unwrap();
        assert_eq!(ix.lookup(2, 1), Some(0));
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        let mut t = RelTable::new(0);
        t.push(0, 1, &[]).unwrap();
        t.push(0, 1, &[]).unwrap();
        assert!(RelIndex::build(&t, 2, 2).is_err());

        let mut t2 = RelTable::new(0);
        t2.push(5, 0, &[]).unwrap();
        assert!(RelIndex::build(&t2, 2, 2).is_err());
    }
}
