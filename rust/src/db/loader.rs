//! Load/save databases as a directory of `schema.json` + CSV files.
//!
//! Format:
//! - `schema.json`  — serde-serialized [`Schema`]
//! - `entity_<Name>.csv` — one row per entity, columns = attribute codes
//! - `rel_<Name>.csv`    — columns `from,to,<attr codes...>`
//!
//! Values are the raw u32 codes; a header line names the columns.

use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Save a database to a directory (created if absent).
pub fn save(db: &Database, dir: &Path) -> Result<()> {
    fs::create_dir_all(dir)?;
    fs::write(dir.join("schema.json"), db.schema.to_json().dump())?;

    for (et, t) in db.entities.iter().enumerate() {
        let ety = &db.schema.entities[et];
        let mut f = fs::File::create(dir.join(format!("entity_{}.csv", ety.name)))?;
        // explicit id column so attribute-less entity tables still have rows
        let mut header = vec!["id".to_string()];
        header.extend(ety.attrs.iter().map(|a| a.name.clone()));
        writeln!(f, "{}", header.join(","))?;
        for i in 0..t.len() {
            let mut row = vec![i.to_string()];
            row.extend((0..t.cols.len()).map(|a| t.value(a, i).to_string()));
            writeln!(f, "{}", row.join(","))?;
        }
    }
    for (rt, t) in db.rels.iter().enumerate() {
        let rty = &db.schema.relationships[rt];
        let mut f = fs::File::create(dir.join(format!("rel_{}.csv", rty.name)))?;
        let mut header = vec!["from".to_string(), "to".to_string()];
        header.extend(rty.attrs.iter().map(|a| a.name.clone()));
        writeln!(f, "{}", header.join(","))?;
        for i in 0..t.len() {
            let mut row =
                vec![t.from[i as usize].to_string(), t.to[i as usize].to_string()];
            row.extend((0..t.cols.len()).map(|a| t.value(a, i).to_string()));
            writeln!(f, "{}", row.join(","))?;
        }
    }
    Ok(())
}

fn parse_codes(line: &str, path: &Path, lineno: usize) -> Result<Vec<u32>> {
    line.split(',')
        .map(|s| {
            s.trim().parse::<u32>().map_err(|_| {
                Error::Data(format!("{}:{}: bad code {s:?}", path.display(), lineno))
            })
        })
        .collect()
}

/// Load a database from a directory written by [`save`].
pub fn load(dir: &Path) -> Result<Database> {
    let schema_json = fs::read_to_string(dir.join("schema.json"))?;
    let schema = Schema::from_json(&Json::parse(&schema_json)?)?;
    schema.validate()?;
    let mut db = Database::empty(schema.clone());

    for (et, ety) in schema.entities.iter().enumerate() {
        let path = dir.join(format!("entity_{}.csv", ety.name));
        let f = fs::File::open(&path)?;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line?;
            if lineno == 0 || line.trim().is_empty() {
                continue; // header
            }
            let codes = parse_codes(&line, &path, lineno)?;
            if codes.len() != 1 + ety.attrs.len() {
                return Err(Error::Data(format!(
                    "{}:{}: expected {} fields",
                    path.display(),
                    lineno,
                    1 + ety.attrs.len()
                )));
            }
            if codes[0] as u32 != db.entities[et].len() {
                return Err(Error::Data(format!(
                    "{}:{}: non-contiguous entity id {}",
                    path.display(),
                    lineno,
                    codes[0]
                )));
            }
            db.entities[et].push(&codes[1..])?;
        }
    }
    for (rt, rty) in schema.relationships.iter().enumerate() {
        let path = dir.join(format!("rel_{}.csv", rty.name));
        let f = fs::File::open(&path)?;
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line?;
            if lineno == 0 || line.trim().is_empty() {
                continue;
            }
            let codes = parse_codes(&line, &path, lineno)?;
            if codes.len() != 2 + rty.attrs.len() {
                return Err(Error::Data(format!(
                    "{}:{}: expected {} fields",
                    path.display(),
                    lineno,
                    2 + rty.attrs.len()
                )));
            }
            db.rels[rt].push(codes[0], codes[1], &codes[2..])?;
        }
    }
    db.validate()?;
    db.build_indexes()?;
    Ok(db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures;

    #[test]
    fn roundtrip_university() {
        let db = fixtures::university_db();
        let dir = std::env::temp_dir().join("relcount_loader_test");
        let _ = fs::remove_dir_all(&dir);
        save(&db, &dir).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.schema, db.schema);
        assert_eq!(back.total_rows(), db.total_rows());
        for (a, b) in db.rels.iter().zip(back.rels.iter()) {
            assert_eq!(a.from, b.from);
            assert_eq!(a.to, b.to);
            assert_eq!(a.cols, b.cols);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_errors() {
        assert!(load(Path::new("/nonexistent/relcount")).is_err());
    }
}
