//! The in-memory columnar relational database engine.
//!
//! This is the substrate that replaces MariaDB in the paper's setup (see
//! DESIGN.md §1): typed entity/relationship schemas, columnar tables with
//! interned u32-coded categorical values, FK indexes behind a selectable
//! storage engine ([`Backend`]: seed-era hash maps, the default columnar
//! CSR with merge-join kernels, or compressed block-CSR — DESIGN.md
//! §3d/§3h) and the two
//! counting queries FACTORBASE issues — GROUP-BY counts over entity tables and
//! GROUP-BY counts over INNER-JOIN chains of relationship tables (the
//! paper's *JOIN problem*).

pub mod catalog;
pub mod ccsr;
pub mod csr;
pub mod fixtures;
pub mod index;
pub mod loader;
pub mod query;
pub mod schema;
pub mod table;
pub mod value;
pub mod wcoj;

pub use catalog::Database;
pub use ccsr::CcsrIndex;
pub use csr::CsrIndex;
pub use index::{Backend, NeighborRun, RelIndex, RelIx};
pub use schema::{Attribute, EntityType, RelationshipType, Schema};
pub use table::{EntityTable, RelTable};
pub use value::Code;
pub use wcoj::JoinKernel;
