//! Counting queries: GROUP-BY over entity tables and GROUP-BY COUNT(*)
//! over INNER-JOIN relationship chains — the paper's *JOIN problem*.
//!
//! `positive_chain_ct` is the expensive operation whose frequency
//! distinguishes the three strategies: PRECOUNT/HYBRID execute it once
//! per lattice point, ONDEMAND once per subset per family scored.

use crate::ct::cttable::CtTable;
use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::extract::plan_chain;
use crate::meta::rvar::RVar;

/// Cumulative cost counters for the positive-count queries a source has
/// executed (reported in EXPERIMENTS.md alongside Figure 3).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JoinStats {
    /// Number of chain-join queries executed (INNER JOIN GROUP BY).
    pub chain_queries: u64,
    /// Total join steps (relationship tables visited across queries).
    pub join_steps: u64,
    /// Join result rows enumerated (groundings satisfying all rels).
    pub rows_enumerated: u64,
    /// Entity GROUP BY queries executed.
    pub entity_queries: u64,
}

impl JoinStats {
    /// Fold another counter set into this one (per-worker shard merge).
    pub fn merge(&mut self, other: &JoinStats) {
        self.chain_queries += other.chain_queries;
        self.join_steps += other.join_steps;
        self.rows_enumerated += other.rows_enumerated;
        self.entity_queries += other.entity_queries;
    }
}

/// GROUP-BY counts over one entity table.  `vars` must all be
/// `EntityAttr` of `et`.
pub fn groupby_entity(db: &Database, et: usize, vars: &[RVar]) -> Result<CtTable> {
    for v in vars {
        match v {
            RVar::EntityAttr { et: e, .. } if *e == et => {}
            _ => {
                return Err(Error::Ct(format!(
                    "groupby_entity({et}): bad variable {v:?}"
                )))
            }
        }
    }
    let mut out = CtTable::new(&db.schema, vars.to_vec())?;
    let t = &db.entities[et];
    let attrs: Vec<usize> = vars
        .iter()
        .map(|v| match v {
            RVar::EntityAttr { attr, .. } => *attr,
            _ => unreachable!(),
        })
        .collect();
    let mut vals = vec![0u32; attrs.len()];
    for i in 0..t.len() {
        for (j, &a) in attrs.iter().enumerate() {
            vals[j] = t.value(a, i);
        }
        out.add(&vals, 1)?;
    }
    Ok(out)
}

/// Positive ct-table for a connected relationship chain over `vars`
/// (entity attrs of the chain's populations and/or rel attrs of the
/// chain's rels).  Relationship-attribute codes are emitted in ct-table
/// coordinates (raw + 1; 0 is reserved for N/A).
///
/// The join is an index-nested-loop over the plan's join order: each step
/// extends the current binding through an FK index (or a pair lookup when
/// both endpoints are already bound).
pub fn positive_chain_ct(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    stats: &mut JoinStats,
) -> Result<CtTable> {
    chain_ct_bound(db, chain, vars, None, stats)
}

/// The positive-count **delta** of one tuple: GROUP-BY counts over
/// exactly the join rows of `chain` that use tuple `tuple` of `rel`.
/// Equals `positive_chain_ct(after) - positive_chain_ct(before)` for an
/// insert of that tuple (all other tables fixed), and the negation of
/// the same for a delete evaluated while the tuple still exists.  The
/// delta maintenance subsystem ([`crate::delta`]) applies these, signed,
/// to the resident lattice caches instead of re-joining.
pub fn positive_chain_delta_ct(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    rel: usize,
    tuple: u32,
    stats: &mut JoinStats,
) -> Result<CtTable> {
    if !chain.contains(&rel) {
        return Err(Error::Ct(format!(
            "delta rel {rel} not in chain {chain:?}"
        )));
    }
    chain_ct_bound(db, chain, vars, Some((rel, tuple)), stats)
}

/// Shared core of [`positive_chain_ct`] / [`positive_chain_delta_ct`]:
/// when `bound` is set, the enumeration starts with that relationship's
/// endpoints pinned to the given tuple, so only join rows through it are
/// visited (the join reaches the pinned rel fully bound and the pair
/// lookup confirms the single tuple).
fn chain_ct_bound(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    bound: Option<(usize, u32)>,
    stats: &mut JoinStats,
) -> Result<CtTable> {
    let plan = plan_chain(db, chain)?;
    for v in vars {
        let ok = match v {
            RVar::EntityAttr { et, .. } => plan.pops.contains(et),
            RVar::RelAttr { rel, .. } => plan.chain.contains(rel),
            RVar::RelInd { .. } => false,
        };
        if !ok {
            return Err(Error::Ct(format!(
                "variable {v:?} not available on chain {chain:?}"
            )));
        }
    }
    let mut out = CtTable::new(&db.schema, vars.to_vec())?;
    stats.chain_queries += 1;
    stats.join_steps += plan.join_order.len() as u64;

    // Hot path: precompiled per-column accessors assembling the flat key
    // directly (no per-leaf value vector, no re-validation — table values
    // were range-checked at load).  The N/A shift of rel-attr codes is
    // folded into a constant key offset.
    enum Access {
        Ent { et: usize, attr: usize, stride: u128 },
        Rel { rel: usize, jp: usize, attr: usize, stride: u128 },
    }
    let mut base: u128 = 0;
    let mut accesses = Vec::with_capacity(vars.len());
    for (j, v) in vars.iter().enumerate() {
        let stride = out.stride(j);
        match *v {
            RVar::EntityAttr { et, attr } => {
                accesses.push(Access::Ent { et, attr, stride })
            }
            RVar::RelAttr { rel, attr } => {
                let jp = plan
                    .join_order
                    .iter()
                    .position(|&r| r == rel)
                    .expect("rel in chain");
                base += stride; // ct coords = raw + 1
                accesses.push(Access::Rel { rel, jp, attr, stride });
            }
            RVar::RelInd { .. } => unreachable!("validated above"),
        }
    }

    let n_ets = db.schema.entities.len();
    let mut binding: Vec<Option<u32>> = vec![None; n_ets];
    if let Some((rel, tuple)) = bound {
        let t = &db.rels[rel];
        if tuple >= t.len() {
            return Err(Error::Ct(format!(
                "delta tuple {tuple} out of range 0..{}",
                t.len()
            )));
        }
        let (a, b) = db.schema.rel_endpoints(rel);
        binding[a] = Some(t.from[tuple as usize]);
        binding[b] = Some(t.to[tuple as usize]);
    }
    // tuple id bound for each rel of the chain (indexed by join position)
    let mut tuples: Vec<u32> = vec![0; plan.join_order.len()];
    let mut rows = 0u64;
    enumerate_join(
        db,
        &plan.join_order,
        0,
        &mut binding,
        &mut tuples,
        &mut |binding, tuples| {
            let mut key = base;
            for a in &accesses {
                key += match *a {
                    Access::Ent { et, attr, stride } => {
                        db.entities[et].value(attr, binding[et].expect("bound"))
                            as u128
                            * stride
                    }
                    Access::Rel { rel, jp, attr, stride } => {
                        db.rels[rel].value(attr, tuples[jp]) as u128 * stride
                    }
                };
            }
            rows += 1;
            out.add_key(key, 1)
        },
    )?;
    stats.rows_enumerated += rows;
    Ok(out)
}

/// Recursive index-nested-loop join enumeration.
fn enumerate_join(
    db: &Database,
    order: &[usize],
    depth: usize,
    binding: &mut Vec<Option<u32>>,
    tuples: &mut Vec<u32>,
    emit: &mut dyn FnMut(&[Option<u32>], &[u32]) -> Result<()>,
) -> Result<()> {
    if depth == order.len() {
        return emit(binding, tuples);
    }
    let rel = order[depth];
    let (a, b) = db.schema.rel_endpoints(rel);
    let ix = db.index(rel)?;
    match (binding[a], binding[b]) {
        (Some(fa), Some(fb)) => {
            if let Some(t) = ix.lookup(fa, fb) {
                tuples[depth] = t;
                enumerate_join(db, order, depth + 1, binding, tuples, emit)?;
            }
        }
        (Some(fa), None) => {
            // clone the tuple list to release the borrow on ix
            for &t in &ix.by_from[fa as usize] {
                tuples[depth] = t;
                binding[b] = Some(db.rels[rel].to[t as usize]);
                enumerate_join(db, order, depth + 1, binding, tuples, emit)?;
            }
            binding[b] = None;
        }
        (None, Some(fb)) => {
            for &t in &ix.by_to[fb as usize] {
                tuples[depth] = t;
                binding[a] = Some(db.rels[rel].from[t as usize]);
                enumerate_join(db, order, depth + 1, binding, tuples, emit)?;
            }
            binding[a] = None;
        }
        (None, None) => {
            let table = &db.rels[rel];
            for t in 0..table.len() {
                tuples[depth] = t;
                binding[a] = Some(table.from[t as usize]);
                binding[b] = Some(table.to[t as usize]);
                enumerate_join(db, order, depth + 1, binding, tuples, emit)?;
            }
            binding[a] = None;
            binding[b] = None;
        }
    }
    Ok(())
}

/// A [`ChainSource`](crate::ct::mobius::ChainSource) that executes fresh
/// joins against the database on every request — the post-counting data
/// access pattern (ONDEMAND), and the ground-truth source for tests.
pub struct DirectSource<'a> {
    pub db: &'a Database,
    pub stats: JoinStats,
}

impl<'a> DirectSource<'a> {
    pub fn new(db: &'a Database) -> Self {
        DirectSource { db, stats: JoinStats::default() }
    }
}

impl crate::ct::mobius::ChainSource for DirectSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        positive_chain_ct(self.db, chain, vars, &mut self.stats)
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        self.stats.entity_queries += 1;
        groupby_entity(self.db, et, vars)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::{university_db, TABLE3_POSITIVE};

    #[test]
    fn entity_groupby_counts() {
        let db = university_db();
        let v = RVar::EntityAttr { et: 0, attr: 0 };
        let ct = groupby_entity(&db, 0, &[v]).unwrap();
        assert_eq!(ct.total().unwrap() as u64, db.population(0));
        assert_eq!(ct.get(&[0]).unwrap(), 4); // 12 professors, popularity p%3
        assert_eq!(ct.get(&[1]).unwrap(), 4);
        assert_eq!(ct.get(&[2]).unwrap(), 4);
    }

    #[test]
    fn entity_groupby_rejects_foreign_vars() {
        let db = university_db();
        let v = RVar::EntityAttr { et: 1, attr: 0 };
        assert!(groupby_entity(&db, 0, &[v]).is_err());
    }

    #[test]
    fn single_rel_positive_matches_table3() {
        let db = university_db();
        let mut stats = JoinStats::default();
        let vars = vec![
            RVar::RelAttr { rel: 0, attr: 0 }, // capability (ct coords)
            RVar::RelAttr { rel: 0, attr: 1 }, // salary (ct coords)
        ];
        let ct = positive_chain_ct(&db, &[0], &vars, &mut stats).unwrap();
        assert_eq!(ct.total().unwrap(), 25);
        for &(capa, sal, count) in TABLE3_POSITIVE {
            // paper capability c stored raw c-1 -> ct code c; salary raw s -> s+1
            assert_eq!(ct.get(&[capa, sal + 1]).unwrap(), count as i128);
        }
        assert_eq!(stats.chain_queries, 1);
        assert_eq!(stats.rows_enumerated, 25);
    }

    #[test]
    fn two_rel_chain_counts() {
        let db = university_db();
        let mut stats = JoinStats::default();
        // chain RA(P,S) - Registered(S,C): count pairs sharing the student
        let ct = positive_chain_ct(&db, &[0, 1], &[], &mut stats).unwrap();
        // brute force the expected join size
        let mut expected = 0i128;
        for i in 0..db.rels[0].len() {
            let s = db.rels[0].to[i as usize];
            for j in 0..db.rels[1].len() {
                if db.rels[1].from[j as usize] == s {
                    expected += 1;
                }
            }
        }
        assert_eq!(ct.total().unwrap(), expected);
        assert_eq!(stats.join_steps, 2);
    }

    #[test]
    fn chain_with_entity_attrs() {
        let db = university_db();
        let mut stats = JoinStats::default();
        let vars = vec![
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelAttr { rel: 1, attr: 0 },
        ];
        let ct = positive_chain_ct(&db, &[1], &vars, &mut stats).unwrap();
        assert_eq!(ct.total().unwrap() as u32, db.rels[1].len());
        // every rel-attr code is in ct coordinates (>= 1)
        for (vals, _) in ct.iter_rows() {
            assert!(vals[1] >= 1);
        }
    }

    #[test]
    fn tuple_deltas_sum_to_full_positive_ct() {
        // summing the per-tuple deltas over every tuple of a rel must
        // reproduce the full chain count (each join row uses exactly one
        // tuple of each rel in the chain)
        let db = university_db();
        let vars = vec![
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
        ];
        for chain in [vec![0usize], vec![0, 1]] {
            let mut stats = JoinStats::default();
            let full = positive_chain_ct(&db, &chain, &vars, &mut stats).unwrap();
            let mut acc =
                crate::ct::cttable::CtTable::new(&db.schema, vars.clone()).unwrap();
            for t in 0..db.rels[0].len() {
                let d = positive_chain_delta_ct(&db, &chain, &vars, 0, t, &mut stats)
                    .unwrap();
                acc.add_table(&d).unwrap();
            }
            assert_eq!(acc.n_rows(), full.n_rows(), "chain {chain:?}");
            for (v, c) in full.iter_rows() {
                assert_eq!(acc.get(&v).unwrap(), c, "chain {chain:?} {v:?}");
            }
        }
    }

    #[test]
    fn delta_rejects_rel_outside_chain() {
        let db = university_db();
        let mut stats = JoinStats::default();
        assert!(positive_chain_delta_ct(&db, &[1], &[], 0, 0, &mut stats).is_err());
        assert!(
            positive_chain_delta_ct(&db, &[0], &[], 0, 999, &mut stats).is_err()
        );
    }

    #[test]
    fn rejects_vars_off_chain() {
        let db = university_db();
        let mut stats = JoinStats::default();
        let vars = vec![RVar::RelAttr { rel: 1, attr: 0 }];
        assert!(positive_chain_ct(&db, &[0], &vars, &mut stats).is_err());
        let vars2 = vec![RVar::EntityAttr { et: 2, attr: 0 }];
        assert!(positive_chain_ct(&db, &[0], &vars2, &mut stats).is_err());
    }
}
