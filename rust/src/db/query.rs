//! Counting queries: GROUP-BY over entity tables and GROUP-BY COUNT(*)
//! over INNER-JOIN relationship chains — the paper's *JOIN problem*.
//!
//! `positive_chain_ct` is the expensive operation whose frequency
//! distinguishes the three strategies: PRECOUNT/HYBRID execute it once
//! per lattice point, ONDEMAND once per subset per family scored.
//!
//! The enumeration core carries **count-only kernels** that collapse
//! join tails which feed no group-by column (common for the Möbius
//! subset queries, whose variable sets shrink toward the empty set):
//!
//! - a *degree multiply* when a step's freshly bound entity is never
//!   read again — the subtree contribution is the adjacency degree;
//! - a *sorted-run intersection* ([`NeighborRun::intersect_count`],
//!   linear merge with galloping on skewed degree distributions, block
//!   skipping on the compressed backend) when a step binds an entity
//!   only so the next relationship can probe membership against its
//!   other, already-bound endpoint.  The intersection runs on the
//!   sorted neighbor runs both columnar backends expose
//!   ([`crate::db::index::RelIx::neighbor_run_from`]); the hash backend
//!   (and columnar rows with pending overlay) falls back to generic
//!   enumeration with pair lookups.
//!
//! Both kernels are exact — they emit the same group keys with the same
//! multiplicities as full enumeration, and `JoinStats::rows_enumerated`
//! still counts true join rows — so every backend/kernel combination is
//! bit-identical (`rust/tests/proptest_invariants.rs`).

use crate::ct::cttable::CtTable;
use crate::db::catalog::Database;
use crate::db::index::NeighborRun;
use crate::db::schema::Schema;
use crate::db::wcoj::JoinKernel;
use crate::error::{Error, Result};
use crate::meta::extract::plan_chain;
use crate::meta::rvar::RVar;

/// Cumulative cost counters for the positive-count queries a source has
/// executed (reported in EXPERIMENTS.md alongside Figure 3).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JoinStats {
    /// Number of chain-join queries executed (INNER JOIN GROUP BY).
    pub chain_queries: u64,
    /// Total join steps (relationship tables visited across queries).
    pub join_steps: u64,
    /// Join result rows enumerated (groundings satisfying all rels).
    pub rows_enumerated: u64,
    /// Entity GROUP BY queries executed.
    pub entity_queries: u64,
}

impl JoinStats {
    /// Fold another counter set into this one (per-worker shard merge).
    pub fn merge(&mut self, other: &JoinStats) {
        self.chain_queries += other.chain_queries;
        self.join_steps += other.join_steps;
        self.rows_enumerated += other.rows_enumerated;
        self.entity_queries += other.entity_queries;
    }
}

/// GROUP-BY counts over one entity table.  `vars` must all be
/// `EntityAttr` of `et`.
pub fn groupby_entity(db: &Database, et: usize, vars: &[RVar]) -> Result<CtTable> {
    groupby_entity_filtered(db, et, vars, None)
}

/// Entity-hash shard assignment: which of `of` shards owns entity `id`
/// of type `et`.  Deterministic and seed-free ([`shard_of`] over the
/// unseeded `FxHasher`), so every process of a scale-out topology on the
/// same platform computes the same ownership map without coordination.
///
/// [`shard_of`]: crate::coordinator::shard::shard_of
pub fn entity_shard(et: usize, id: u32, of: usize) -> usize {
    crate::coordinator::shard::shard_of(&(et, id), of)
}

/// [`groupby_entity`] restricted to the rows a shard owns: with
/// `slice = Some((index, of))` only entities whose [`entity_shard`] is
/// `index` contribute.  Summing the `of` partial tables reproduces the
/// full GROUP-BY integer-exactly.
pub fn groupby_entity_filtered(
    db: &Database,
    et: usize,
    vars: &[RVar],
    slice: Option<(usize, usize)>,
) -> Result<CtTable> {
    for v in vars {
        match v {
            RVar::EntityAttr { et: e, .. } if *e == et => {}
            _ => {
                return Err(Error::Ct(format!(
                    "groupby_entity({et}): bad variable {v:?}"
                )))
            }
        }
    }
    if let Some((index, of)) = slice {
        check_slice(index, of)?;
    }
    let mut out = CtTable::new(&db.schema, vars.to_vec())?;
    let t = &db.entities[et];
    let attrs: Vec<usize> = vars
        .iter()
        .map(|v| match v {
            RVar::EntityAttr { attr, .. } => *attr,
            _ => unreachable!(),
        })
        .collect();
    let mut vals = vec![0u32; attrs.len()];
    for i in 0..t.len() {
        if let Some((index, of)) = slice {
            if entity_shard(et, i, of) != index {
                continue;
            }
        }
        for (j, &a) in attrs.iter().enumerate() {
            vals[j] = t.value(a, i);
        }
        out.add(&vals, 1)?;
    }
    Ok(out)
}

fn check_slice(index: usize, of: usize) -> Result<()> {
    if of == 0 || index >= of {
        return Err(Error::Ct(format!("bad shard slice {index}/{of}")));
    }
    Ok(())
}

/// Positive ct-table for a connected relationship chain over `vars`
/// (entity attrs of the chain's populations and/or rel attrs of the
/// chain's rels).  Relationship-attribute codes are emitted in ct-table
/// coordinates (raw + 1; 0 is reserved for N/A).
///
/// The join is an index-nested-loop over the plan's join order: each step
/// extends the current binding through an FK index (or a pair lookup when
/// both endpoints are already bound).
pub fn positive_chain_ct(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    stats: &mut JoinStats,
) -> Result<CtTable> {
    match db.kernel() {
        JoinKernel::Chain => chain_ct_bound(db, chain, vars, Restrict::All, stats),
        // the WCOJ twin: bit-identical counts and JoinStats, different
        // enumeration order (variable-at-a-time, DESIGN.md §3g)
        JoinKernel::Wcoj => crate::db::wcoj::wcoj_chain_ct(db, chain, vars, stats),
    }
}

/// The shard-`index`-of-`of` **partial** positive ct-table of a chain:
/// GROUP-BY counts over exactly the join rows whose *anchor* entity —
/// the chain's lowest-numbered population — is owned by shard `index`
/// under [`entity_shard`].  Every join row of a connected chain grounds
/// every population exactly once, so the anchor partitions the row set
/// and summing the `of` partial tables reproduces
/// [`positive_chain_ct`] integer-exactly (the scale-out router's merge
/// invariant, DESIGN.md §3i).
///
/// Always runs the bound chain kernel regardless of `db.kernel()` (the
/// WCOJ kernel has no anchor-bound variant); counts are kernel-identical
/// by the project's bit-identity discipline, so merged results match
/// single-process runs under either kernel.
pub fn partial_chain_ct(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    index: usize,
    of: usize,
    stats: &mut JoinStats,
) -> Result<CtTable> {
    check_slice(index, of)?;
    chain_ct_bound(db, chain, vars, Restrict::Slice { index, of }, stats)
}

/// The positive-count **delta** of one tuple: GROUP-BY counts over
/// exactly the join rows of `chain` that use tuple `tuple` of `rel`.
/// Equals `positive_chain_ct(after) - positive_chain_ct(before)` for an
/// insert of that tuple (all other tables fixed), and the negation of
/// the same for a delete evaluated while the tuple still exists.  The
/// delta maintenance subsystem ([`crate::delta`]) applies these, signed,
/// to the resident lattice caches instead of re-joining.
pub fn positive_chain_delta_ct(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    rel: usize,
    tuple: u32,
    stats: &mut JoinStats,
) -> Result<CtTable> {
    if !chain.contains(&rel) {
        return Err(Error::Ct(format!(
            "delta rel {rel} not in chain {chain:?}"
        )));
    }
    chain_ct_bound(db, chain, vars, Restrict::Tuple { rel, tuple }, stats)
}

/// Which join rows one [`chain_ct_bound`] run visits.
#[derive(Clone, Copy)]
enum Restrict {
    /// Every join row (the full positive table).
    All,
    /// Only rows through one pinned relationship tuple (delta counting).
    Tuple { rel: usize, tuple: u32 },
    /// Only rows whose anchor entity hashes to shard `index` of `of`
    /// (partial counting; see [`partial_chain_ct`]).
    Slice { index: usize, of: usize },
}

/// Shared core of [`positive_chain_ct`] / [`positive_chain_delta_ct`] /
/// [`partial_chain_ct`]: a `Tuple` restriction starts the enumeration
/// with that relationship's endpoints pinned to the given tuple, so only
/// join rows through it are visited (the join reaches the pinned rel
/// fully bound and the pair lookup confirms the single tuple); a `Slice`
/// restriction loops the shard's owned anchor-entity ids through the
/// same pinned-binding path.  Pinned bindings are exact: the count-only
/// kernels never collapse an already-bound entity, and `enumerate_join`
/// unsets only the bindings it set itself.
fn chain_ct_bound(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    restrict: Restrict,
    stats: &mut JoinStats,
) -> Result<CtTable> {
    let plan = plan_chain(db, chain)?;
    for v in vars {
        let ok = match v {
            RVar::EntityAttr { et, .. } => plan.pops.contains(et),
            RVar::RelAttr { rel, .. } => plan.chain.contains(rel),
            RVar::RelInd { .. } => false,
        };
        if !ok {
            return Err(Error::Ct(format!(
                "variable {v:?} not available on chain {chain:?}"
            )));
        }
    }
    let mut out = CtTable::new(&db.schema, vars.to_vec())?;
    stats.chain_queries += 1;
    stats.join_steps += plan.join_order.len() as u64;

    // Hot path: precompiled per-column accessors assembling the flat key
    // directly (no per-leaf value vector, no re-validation — table values
    // were range-checked at load).  The N/A shift of rel-attr codes is
    // folded into a constant key offset.
    enum Access {
        Ent { et: usize, attr: usize, stride: u128 },
        Rel { rel: usize, jp: usize, attr: usize, stride: u128 },
    }
    let mut base: u128 = 0;
    let mut accesses = Vec::with_capacity(vars.len());
    for (j, v) in vars.iter().enumerate() {
        let stride = out.stride(j);
        match *v {
            RVar::EntityAttr { et, attr } => {
                accesses.push(Access::Ent { et, attr, stride })
            }
            RVar::RelAttr { rel, attr } => {
                let jp = plan
                    .join_order
                    .iter()
                    .position(|&r| r == rel)
                    .expect("rel in chain");
                base += stride; // ct coords = raw + 1
                accesses.push(Access::Rel { rel, jp, attr, stride });
            }
            RVar::RelInd { .. } => unreachable!("validated above"),
        }
    }

    let n_ets = db.schema.entities.len();
    let mut shape = JoinShape {
        last_use: vec![usize::MAX; n_ets],
        needed_ets: vec![false; n_ets],
        needed_jps: vec![false; plan.join_order.len()],
    };
    for (d, &rel) in plan.join_order.iter().enumerate() {
        let (a, b) = db.schema.rel_endpoints(rel);
        shape.last_use[a] = d;
        shape.last_use[b] = d;
    }
    for acc in &accesses {
        match *acc {
            Access::Ent { et, .. } => shape.needed_ets[et] = true,
            Access::Rel { jp, .. } => shape.needed_jps[jp] = true,
        }
    }
    let mut binding: Vec<Option<u32>> = vec![None; n_ets];
    if let Restrict::Tuple { rel, tuple } = restrict {
        let t = &db.rels[rel];
        if tuple >= t.len() {
            return Err(Error::Ct(format!(
                "delta tuple {tuple} out of range 0..{}",
                t.len()
            )));
        }
        let (a, b) = db.schema.rel_endpoints(rel);
        binding[a] = Some(t.from[tuple as usize]);
        binding[b] = Some(t.to[tuple as usize]);
    }
    // tuple id bound for each rel of the chain (indexed by join position)
    let mut tuples: Vec<u32> = vec![0; plan.join_order.len()];
    let mut rows = 0u64;
    let cx = JoinCx { db, order: &plan.join_order, shape };
    let mut emit = |binding: &[Option<u32>], tuples: &[u32], mult: i128| {
        let mut key = base;
        for a in &accesses {
            key += match *a {
                Access::Ent { et, attr, stride } => {
                    db.entities[et].value(attr, binding[et].expect("bound"))
                        as u128
                        * stride
                }
                Access::Rel { rel, jp, attr, stride } => {
                    db.rels[rel].value(attr, tuples[jp]) as u128 * stride
                }
            };
        }
        rows += mult as u64;
        out.add_key(key, mult)
    };
    if let Restrict::Slice { index, of } = restrict {
        // anchor = the chain's lowest-numbered population; every join
        // row grounds it exactly once, so slicing by its owner shard
        // partitions the row set (partial_chain_ct's merge invariant)
        let anchor = plan.pops[0];
        for id in 0..db.entities[anchor].len() {
            if entity_shard(anchor, id, of) != index {
                continue;
            }
            binding[anchor] = Some(id);
            enumerate_join(&cx, 0, 1, &mut binding, &mut tuples, &mut emit)?;
        }
    } else {
        enumerate_join(&cx, 0, 1, &mut binding, &mut tuples, &mut emit)?;
    }
    stats.rows_enumerated += rows;
    Ok(out)
}

/// Precomputed shape of one chain enumeration: which entity types and
/// join positions feed the group-by key, and where each entity type is
/// last used — the legality conditions for the count-only kernels.
struct JoinShape {
    /// Deepest join-order position whose relationship touches each
    /// entity type (`usize::MAX` = not on the chain).
    last_use: Vec<usize>,
    /// Entity types whose attributes feed the group-by key.
    needed_ets: Vec<bool>,
    /// Join positions whose relationship attributes feed the key.
    needed_jps: Vec<bool>,
}

/// Borrowed context threaded through the recursive enumeration.
struct JoinCx<'a> {
    db: &'a Database,
    order: &'a [usize],
    shape: JoinShape,
}

/// Recursive index-nested-loop join enumeration with count-only
/// kernels.  `mult` is the multiplicity carried by collapsed steps
/// (degree multiplies and sorted-run intersections); the leaf emit
/// receives it so group counts and `rows_enumerated` stay exact.
fn enumerate_join(
    cx: &JoinCx<'_>,
    depth: usize,
    mult: i128,
    binding: &mut Vec<Option<u32>>,
    tuples: &mut Vec<u32>,
    emit: &mut dyn FnMut(&[Option<u32>], &[u32], i128) -> Result<()>,
) -> Result<()> {
    if depth == cx.order.len() {
        return emit(binding, tuples, mult);
    }
    let db = cx.db;
    let rel = cx.order[depth];
    let (a, b) = db.schema.rel_endpoints(rel);
    let ix = db.index(rel)?;
    match (binding[a], binding[b]) {
        (Some(fa), Some(fb)) => {
            if let Some(t) = ix.lookup(fa, fb) {
                tuples[depth] = t;
                enumerate_join(cx, depth + 1, mult, binding, tuples, emit)?;
            }
        }
        (Some(fa), None) => {
            if a != b && !cx.shape.needed_jps[depth] {
                if let Some(n) = try_intersect(cx, depth, b, fa, true, binding)? {
                    if n > 0 {
                        let m = mult * n as i128;
                        enumerate_join(cx, depth + 2, m, binding, tuples, emit)?;
                    }
                    return Ok(());
                }
                if cx.shape.last_use[b] == depth && !cx.shape.needed_ets[b] {
                    let deg = ix.degree_from(fa);
                    if deg > 0 {
                        let m = mult * deg as i128;
                        enumerate_join(cx, depth + 1, m, binding, tuples, emit)?;
                    }
                    return Ok(());
                }
            }
            for t in ix.tids_from(fa) {
                tuples[depth] = t;
                binding[b] = Some(db.rels[rel].to[t as usize]);
                enumerate_join(cx, depth + 1, mult, binding, tuples, emit)?;
            }
            binding[b] = None;
        }
        (None, Some(fb)) => {
            if a != b && !cx.shape.needed_jps[depth] {
                if let Some(n) = try_intersect(cx, depth, a, fb, false, binding)? {
                    if n > 0 {
                        let m = mult * n as i128;
                        enumerate_join(cx, depth + 2, m, binding, tuples, emit)?;
                    }
                    return Ok(());
                }
                if cx.shape.last_use[a] == depth && !cx.shape.needed_ets[a] {
                    let deg = ix.degree_to(fb);
                    if deg > 0 {
                        let m = mult * deg as i128;
                        enumerate_join(cx, depth + 1, m, binding, tuples, emit)?;
                    }
                    return Ok(());
                }
            }
            for t in ix.tids_to(fb) {
                tuples[depth] = t;
                binding[a] = Some(db.rels[rel].from[t as usize]);
                enumerate_join(cx, depth + 1, mult, binding, tuples, emit)?;
            }
            binding[a] = None;
        }
        (None, None) => {
            if a != b
                && !cx.shape.needed_jps[depth]
                && cx.shape.last_use[a] == depth
                && !cx.shape.needed_ets[a]
                && cx.shape.last_use[b] == depth
                && !cx.shape.needed_ets[b]
            {
                let n = db.rels[rel].len();
                if n > 0 {
                    let m = mult * n as i128;
                    enumerate_join(cx, depth + 1, m, binding, tuples, emit)?;
                }
                return Ok(());
            }
            let table = &db.rels[rel];
            for t in 0..table.len() {
                tuples[depth] = t;
                binding[a] = Some(table.from[t as usize]);
                binding[b] = Some(table.to[t as usize]);
                enumerate_join(cx, depth + 1, mult, binding, tuples, emit)?;
            }
            binding[a] = None;
            binding[b] = None;
        }
    }
    Ok(())
}

/// Attempt the sorted-run intersection kernel at `depth`: the current
/// relationship would bind `x` (from its bound endpoint `bound_val`)
/// only so the *next* relationship can probe membership against its
/// other, already-bound endpoint — and nothing downstream reads `x`.
/// The two steps' contribution then factors into the size of
/// `candidates(x via rel_d) ∩ candidates(x via rel_d+1)`, computed by
/// [`NeighborRun::intersect_count`] over the columnar backends' sorted
/// neighbor runs (contiguous slices for CSR, packed blocks for CCSR).
/// Returns `None` when the shape or backend does not admit the kernel
/// (generic enumeration handles those cases identically).
fn try_intersect(
    cx: &JoinCx<'_>,
    depth: usize,
    x: usize,
    bound_val: u32,
    x_is_to: bool,
    binding: &[Option<u32>],
) -> Result<Option<u64>> {
    let shape = &cx.shape;
    if depth + 1 >= cx.order.len()
        || shape.needed_jps[depth + 1]
        || shape.needed_ets[x]
        || shape.last_use[x] != depth + 1
    {
        return Ok(None);
    }
    let db = cx.db;
    let rel2 = cx.order[depth + 1];
    let (a2, b2) = db.schema.rel_endpoints(rel2);
    if a2 == b2 {
        return Ok(None);
    }
    let (y, x_is_from2) = if a2 == x {
        (b2, true)
    } else if b2 == x {
        (a2, false)
    } else {
        return Ok(None);
    };
    let vy = match binding[y] {
        Some(v) => v,
        None => return Ok(None),
    };
    let ix1 = db.index(cx.order[depth])?;
    let ix2 = db.index(rel2)?;
    let s1: Option<NeighborRun<'_>> = if x_is_to {
        ix1.neighbor_run_from(bound_val)
    } else {
        ix1.neighbor_run_to(bound_val)
    };
    let s2 = if x_is_from2 {
        ix2.neighbor_run_to(vy)
    } else {
        ix2.neighbor_run_from(vy)
    };
    match (s1, s2) {
        (Some(r1), Some(r2)) => Ok(Some(r1.intersect_count(&r2))),
        _ => Ok(None),
    }
}

// The adaptive merge/gallop intersection primitive lives next to the
// `NeighborRun` abstraction now; re-exported here because this module
// is its historical home and external callers import it from here.
pub use crate::db::index::intersect_count;
pub(crate) use crate::db::index::gallop_lower_bound;

/// A [`ChainSource`](crate::ct::mobius::ChainSource) that executes fresh
/// joins against the database on every request — the post-counting data
/// access pattern (ONDEMAND), and the ground-truth source for tests.
pub struct DirectSource<'a> {
    pub db: &'a Database,
    pub stats: JoinStats,
}

impl<'a> DirectSource<'a> {
    pub fn new(db: &'a Database) -> Self {
        DirectSource { db, stats: JoinStats::default() }
    }
}

impl crate::ct::mobius::ChainSource for DirectSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        positive_chain_ct(self.db, chain, vars, &mut self.stats)
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        self.stats.entity_queries += 1;
        groupby_entity(self.db, et, vars)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::{university_db, TABLE3_POSITIVE};

    #[test]
    fn entity_groupby_counts() {
        let db = university_db();
        let v = RVar::EntityAttr { et: 0, attr: 0 };
        let ct = groupby_entity(&db, 0, &[v]).unwrap();
        assert_eq!(ct.total().unwrap() as u64, db.population(0));
        assert_eq!(ct.get(&[0]).unwrap(), 4); // 12 professors, popularity p%3
        assert_eq!(ct.get(&[1]).unwrap(), 4);
        assert_eq!(ct.get(&[2]).unwrap(), 4);
    }

    #[test]
    fn entity_groupby_rejects_foreign_vars() {
        let db = university_db();
        let v = RVar::EntityAttr { et: 1, attr: 0 };
        assert!(groupby_entity(&db, 0, &[v]).is_err());
    }

    #[test]
    fn single_rel_positive_matches_table3() {
        let db = university_db();
        let mut stats = JoinStats::default();
        let vars = vec![
            RVar::RelAttr { rel: 0, attr: 0 }, // capability (ct coords)
            RVar::RelAttr { rel: 0, attr: 1 }, // salary (ct coords)
        ];
        let ct = positive_chain_ct(&db, &[0], &vars, &mut stats).unwrap();
        assert_eq!(ct.total().unwrap(), 25);
        for &(capa, sal, count) in TABLE3_POSITIVE {
            // paper capability c stored raw c-1 -> ct code c; salary raw s -> s+1
            assert_eq!(ct.get(&[capa, sal + 1]).unwrap(), count as i128);
        }
        assert_eq!(stats.chain_queries, 1);
        assert_eq!(stats.rows_enumerated, 25);
    }

    #[test]
    fn two_rel_chain_counts() {
        let db = university_db();
        let mut stats = JoinStats::default();
        // chain RA(P,S) - Registered(S,C): count pairs sharing the student
        let ct = positive_chain_ct(&db, &[0, 1], &[], &mut stats).unwrap();
        // brute force the expected join size
        let mut expected = 0i128;
        for i in 0..db.rels[0].len() {
            let s = db.rels[0].to[i as usize];
            for j in 0..db.rels[1].len() {
                if db.rels[1].from[j as usize] == s {
                    expected += 1;
                }
            }
        }
        assert_eq!(ct.total().unwrap(), expected);
        assert_eq!(stats.join_steps, 2);
    }

    #[test]
    fn chain_with_entity_attrs() {
        let db = university_db();
        let mut stats = JoinStats::default();
        let vars = vec![
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelAttr { rel: 1, attr: 0 },
        ];
        let ct = positive_chain_ct(&db, &[1], &vars, &mut stats).unwrap();
        assert_eq!(ct.total().unwrap() as u32, db.rels[1].len());
        // every rel-attr code is in ct coordinates (>= 1)
        for (vals, _) in ct.iter_rows() {
            assert!(vals[1] >= 1);
        }
    }

    #[test]
    fn tuple_deltas_sum_to_full_positive_ct() {
        // summing the per-tuple deltas over every tuple of a rel must
        // reproduce the full chain count (each join row uses exactly one
        // tuple of each rel in the chain)
        let db = university_db();
        let vars = vec![
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
        ];
        for chain in [vec![0usize], vec![0, 1]] {
            let mut stats = JoinStats::default();
            let full = positive_chain_ct(&db, &chain, &vars, &mut stats).unwrap();
            let mut acc =
                crate::ct::cttable::CtTable::new(&db.schema, vars.clone()).unwrap();
            for t in 0..db.rels[0].len() {
                let d = positive_chain_delta_ct(&db, &chain, &vars, 0, t, &mut stats)
                    .unwrap();
                acc.add_table(&d).unwrap();
            }
            assert_eq!(acc.n_rows(), full.n_rows(), "chain {chain:?}");
            for (v, c) in full.iter_rows() {
                assert_eq!(acc.get(&v).unwrap(), c, "chain {chain:?} {v:?}");
            }
        }
    }

    #[test]
    fn shard_partials_sum_to_full_positive_ct() {
        // summing the per-shard partial tables over every shard must
        // reproduce the full chain count (each join row grounds the
        // anchor population exactly once, so anchor ownership
        // partitions the row set) — the scale-out router's merge
        // invariant
        let db = university_db();
        let vars = vec![
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
        ];
        for chain in [vec![0usize], vec![0, 1]] {
            for of in [1usize, 2, 3] {
                let mut stats = JoinStats::default();
                let full =
                    positive_chain_ct(&db, &chain, &vars, &mut stats).unwrap();
                let mut acc =
                    crate::ct::cttable::CtTable::new(&db.schema, vars.clone())
                        .unwrap();
                for index in 0..of {
                    let p = partial_chain_ct(
                        &db, &chain, &vars, index, of, &mut stats,
                    )
                    .unwrap();
                    acc.add_table(&p).unwrap();
                }
                assert_eq!(acc.n_rows(), full.n_rows(), "chain {chain:?} of {of}");
                for (v, c) in full.iter_rows() {
                    assert_eq!(
                        acc.get(&v).unwrap(),
                        c,
                        "chain {chain:?} of {of} {v:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn shard_partial_marginals_sum_to_full_groupby() {
        let db = university_db();
        let vars = vec![RVar::EntityAttr { et: 0, attr: 0 }];
        let full = groupby_entity(&db, 0, &vars).unwrap();
        for of in [1usize, 2, 4] {
            let mut acc =
                crate::ct::cttable::CtTable::new(&db.schema, vars.clone()).unwrap();
            for index in 0..of {
                let p = groupby_entity_filtered(&db, 0, &vars, Some((index, of)))
                    .unwrap();
                acc.add_table(&p).unwrap();
            }
            for (v, c) in full.iter_rows() {
                assert_eq!(acc.get(&v).unwrap(), c, "of {of} {v:?}");
            }
        }
    }

    #[test]
    fn partial_rejects_bad_slices() {
        let db = university_db();
        let mut stats = JoinStats::default();
        assert!(partial_chain_ct(&db, &[0], &[], 0, 0, &mut stats).is_err());
        assert!(partial_chain_ct(&db, &[0], &[], 2, 2, &mut stats).is_err());
        assert!(groupby_entity_filtered(&db, 0, &[], Some((3, 2))).is_err());
    }

    #[test]
    fn entity_shard_is_stable_and_in_range() {
        for of in [1usize, 2, 5] {
            for et in 0..3usize {
                for id in 0..50u32 {
                    let s = entity_shard(et, id, of);
                    assert!(s < of);
                    assert_eq!(s, entity_shard(et, id, of), "deterministic");
                }
            }
        }
    }

    #[test]
    fn delta_rejects_rel_outside_chain() {
        let db = university_db();
        let mut stats = JoinStats::default();
        assert!(positive_chain_delta_ct(&db, &[1], &[], 0, 0, &mut stats).is_err());
        assert!(
            positive_chain_delta_ct(&db, &[0], &[], 0, 999, &mut stats).is_err()
        );
    }

    #[test]
    fn rejects_vars_off_chain() {
        let db = university_db();
        let mut stats = JoinStats::default();
        let vars = vec![RVar::RelAttr { rel: 1, attr: 0 }];
        assert!(positive_chain_ct(&db, &[0], &vars, &mut stats).is_err());
        let vars2 = vec![RVar::EntityAttr { et: 2, attr: 0 }];
        assert!(positive_chain_ct(&db, &[0], &vars2, &mut stats).is_err());
    }

    #[test]
    fn intersect_count_merge_and_gallop_agree() {
        let a: Vec<u32> = (0..200).map(|i| i * 3).collect();
        let b: Vec<u32> = (0..150).map(|i| i * 2).collect();
        let brute = |x: &[u32], y: &[u32]| {
            x.iter().filter(|v| y.binary_search(v).is_ok()).count() as u64
        };
        // balanced: linear merge path
        assert_eq!(intersect_count(&a, &b), brute(&a, &b));
        // skewed: galloping path (|big| / |small| >= GALLOP_RATIO)
        let small: Vec<u32> = vec![0, 7, 300, 301, 597, 9999];
        let big: Vec<u32> = (0..600).collect();
        assert_eq!(intersect_count(&small, &big), brute(&small, &big));
        assert_eq!(intersect_count(&big, &small), brute(&small, &big));
        // edges
        assert_eq!(intersect_count(&[], &b), 0);
        assert_eq!(intersect_count(&a, &a), a.len() as u64);
        assert_eq!(intersect_count(&[5], &big), 1);
        assert_eq!(intersect_count(&[600], &big), 0);
    }

    #[test]
    fn gallop_lower_bound_matches_partition_point() {
        let s: Vec<u32> = (0..97).map(|i| i * 5 + 2).collect();
        for x in [0u32, 1, 2, 3, 240, 481, 482, 483, 1000] {
            assert_eq!(
                gallop_lower_bound(&s, x),
                s.partition_point(|&v| v < x),
                "x = {x}"
            );
        }
        assert_eq!(gallop_lower_bound(&[], 7), 0);
    }

    /// A triangle schema R0(A,B) R1(B,C) R2(A,C): the chain over all
    /// three relationships exercises the intersection kernel (the C
    /// binding only feeds R2's membership probe when no C/R1/R2 column
    /// is requested).
    fn triangle_db() -> Database {
        use crate::db::schema::{Attribute, EntityType, RelationshipType, Schema};
        let schema = Schema::new(
            vec![
                EntityType { name: "A".into(), attrs: vec![Attribute::new("x", 2)] },
                EntityType { name: "B".into(), attrs: vec![] },
                EntityType { name: "C".into(), attrs: vec![] },
            ],
            vec![
                RelationshipType { name: "R0".into(), from: 0, to: 1, attrs: vec![] },
                RelationshipType { name: "R1".into(), from: 1, to: 2, attrs: vec![] },
                RelationshipType { name: "R2".into(), from: 0, to: 2, attrs: vec![] },
            ],
        )
        .unwrap();
        let mut db = Database::empty(schema);
        for i in 0..6u32 {
            db.entities[0].push(&[i % 2]).unwrap();
        }
        for _ in 0..5u32 {
            db.entities[1].push(&[]).unwrap();
        }
        for _ in 0..7u32 {
            db.entities[2].push(&[]).unwrap();
        }
        for a in 0..6u32 {
            for b in 0..5u32 {
                if (a + 2 * b) % 3 != 1 {
                    db.rels[0].push(a, b, &[]).unwrap();
                }
            }
        }
        for b in 0..5u32 {
            for c in 0..7u32 {
                if (b + c) % 2 == 0 {
                    db.rels[1].push(b, c, &[]).unwrap();
                }
            }
        }
        for a in 0..6u32 {
            for c in 0..7u32 {
                if (2 * a + c) % 3 != 0 {
                    db.rels[2].push(a, c, &[]).unwrap();
                }
            }
        }
        db.build_indexes().unwrap();
        db
    }

    /// Brute-force triangle count grouped by A.x.
    fn triangle_brute(db: &Database) -> Vec<i128> {
        let mut counts = vec![0i128; 2];
        for a in 0..db.entities[0].len() {
            for b in 0..db.entities[1].len() {
                if db.index(0).unwrap().lookup(a, b).is_none() {
                    continue;
                }
                for c in 0..db.entities[2].len() {
                    if db.index(1).unwrap().lookup(b, c).is_some()
                        && db.index(2).unwrap().lookup(a, c).is_some()
                    {
                        counts[db.entities[0].value(0, a) as usize] += 1;
                    }
                }
            }
        }
        counts
    }

    #[test]
    fn triangle_chain_counts_match_brute_force_on_both_backends() {
        let csr = triangle_db();
        let mut hash = csr.clone();
        hash.set_backend(crate::db::index::Backend::Hash).unwrap();
        let brute = triangle_brute(&csr);
        let vars = vec![RVar::EntityAttr { et: 0, attr: 0 }];
        let mut s_csr = JoinStats::default();
        let mut s_hash = JoinStats::default();
        let a = positive_chain_ct(&csr, &[0, 1, 2], &vars, &mut s_csr).unwrap();
        let b = positive_chain_ct(&hash, &[0, 1, 2], &vars, &mut s_hash).unwrap();
        for x in 0..2u32 {
            assert_eq!(a.get(&[x]).unwrap(), brute[x as usize], "csr x={x}");
            assert_eq!(b.get(&[x]).unwrap(), brute[x as usize], "hash x={x}");
        }
        // the kernels preserve the row accounting exactly
        assert_eq!(s_csr, s_hash);
        assert_eq!(s_csr.rows_enumerated, (brute[0] + brute[1]) as u64);
        // ungrouped count too (pure count-only tail)
        let mut s2 = JoinStats::default();
        let t = positive_chain_ct(&csr, &[0, 1, 2], &[], &mut s2).unwrap();
        assert_eq!(t.total().unwrap(), brute[0] + brute[1]);
    }

    #[test]
    fn degree_kernel_matches_enumeration_on_university() {
        // chain [0, 1] with vars only on the RA leg: the Registered leg
        // collapses to a degree multiply on both backends
        let csr = university_db();
        let mut hash = csr.clone();
        hash.set_backend(crate::db::index::Backend::Hash).unwrap();
        let vars = vec![RVar::RelAttr { rel: 0, attr: 1 }];
        let mut s1 = JoinStats::default();
        let mut s2 = JoinStats::default();
        let a = positive_chain_ct(&csr, &[0, 1], &vars, &mut s1).unwrap();
        let b = positive_chain_ct(&hash, &[0, 1], &vars, &mut s2).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(a.n_rows(), b.n_rows());
        for (v, c) in a.iter_rows() {
            assert_eq!(b.get(&v).unwrap(), c, "{v:?}");
        }
        // brute force the expected grouped join size
        let mut expect = std::collections::BTreeMap::new();
        for i in 0..csr.rels[0].len() {
            let s = csr.rels[0].to[i as usize];
            let sal = csr.rels[0].value(1, i) + 1; // ct coords
            let deg = csr.index(1).unwrap().degree_from(s) as i128;
            *expect.entry(sal).or_insert(0i128) += deg;
        }
        for (sal, c) in expect {
            if c > 0 {
                assert_eq!(a.get(&[sal]).unwrap(), c, "salary {sal}");
            }
        }
    }
}
