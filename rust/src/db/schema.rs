//! Relational schema: entity types, binary relationship types, and
//! categorical attributes.
//!
//! Following the paper's language bias, relationships are binary between
//! two *distinct* entity types (the Visual Genome preset mirrors the
//! paper's star-schema conversion of ternary relations into binary ones).

use crate::error::{Error, Result};
use crate::util::json::Json;

/// A categorical attribute with values `0..card`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Attribute {
    pub name: String,
    /// Number of distinct values; must be >= 1.
    pub card: u32,
}

impl Attribute {
    pub fn new(name: impl Into<String>, card: u32) -> Self {
        Attribute { name: name.into(), card }
    }
}

/// An entity type (a population), e.g. `Student`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EntityType {
    pub name: String,
    pub attrs: Vec<Attribute>,
}

/// A binary relationship type, e.g. `Registered(Student, Course)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RelationshipType {
    pub name: String,
    /// Index of the first endpoint entity type in [`Schema::entities`].
    pub from: usize,
    /// Index of the second endpoint entity type.
    pub to: usize,
    pub attrs: Vec<Attribute>,
}

/// A full relational schema.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    pub entities: Vec<EntityType>,
    pub relationships: Vec<RelationshipType>,
}

impl Schema {
    pub fn new(
        entities: Vec<EntityType>,
        relationships: Vec<RelationshipType>,
    ) -> Result<Self> {
        let s = Schema { entities, relationships };
        s.validate()?;
        Ok(s)
    }

    /// Structural validation: endpoint ids in range, distinct endpoints,
    /// unique names, nonzero cardinalities.
    pub fn validate(&self) -> Result<()> {
        let mut names: Vec<&str> = Vec::new();
        for e in &self.entities {
            names.push(&e.name);
            for a in &e.attrs {
                if a.card == 0 {
                    return Err(Error::Schema(format!(
                        "attribute {}.{} has cardinality 0",
                        e.name, a.name
                    )));
                }
            }
        }
        for r in &self.relationships {
            names.push(&r.name);
            if r.from >= self.entities.len() || r.to >= self.entities.len() {
                return Err(Error::Schema(format!(
                    "relationship {} references unknown entity type",
                    r.name
                )));
            }
            if r.from == r.to {
                return Err(Error::Schema(format!(
                    "relationship {} is a self-relationship; model it with \
                     a role-split star schema (see datagen::presets)",
                    r.name
                )));
            }
            for a in &r.attrs {
                if a.card == 0 {
                    return Err(Error::Schema(format!(
                        "attribute {}.{} has cardinality 0",
                        r.name, a.name
                    )));
                }
            }
        }
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != names.len() {
            return Err(Error::Schema("duplicate type names".into()));
        }
        Ok(())
    }

    pub fn entity_id(&self, name: &str) -> Result<usize> {
        self.entities
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| Error::Schema(format!("unknown entity type {name}")))
    }

    pub fn rel_id(&self, name: &str) -> Result<usize> {
        self.relationships
            .iter()
            .position(|r| r.name == name)
            .ok_or_else(|| Error::Schema(format!("unknown relationship {name}")))
    }

    /// Entity types touched by a relationship.
    pub fn rel_endpoints(&self, rel: usize) -> (usize, usize) {
        let r = &self.relationships[rel];
        (r.from, r.to)
    }

    /// Entity types touched by a set of relationships, sorted, deduped.
    pub fn populations_of(&self, rels: &[usize]) -> Vec<usize> {
        let mut pops: Vec<usize> = rels
            .iter()
            .flat_map(|&r| {
                let (a, b) = self.rel_endpoints(r);
                [a, b]
            })
            .collect();
        pops.sort_unstable();
        pops.dedup();
        pops
    }

    /// Is the relationship set connected in the entity-type graph?
    /// (Singleton and empty sets count as connected.)
    pub fn is_connected(&self, rels: &[usize]) -> bool {
        if rels.len() <= 1 {
            return true;
        }
        let mut joined: Vec<usize> = vec![rels[0]];
        let mut pops = {
            let (a, b) = self.rel_endpoints(rels[0]);
            vec![a, b]
        };
        let mut rest: Vec<usize> = rels[1..].to_vec();
        loop {
            let before = rest.len();
            rest.retain(|&r| {
                let (a, b) = self.rel_endpoints(r);
                if pops.contains(&a) || pops.contains(&b) {
                    pops.push(a);
                    pops.push(b);
                    joined.push(r);
                    false
                } else {
                    true
                }
            });
            if rest.is_empty() {
                return true;
            }
            if rest.len() == before {
                return false;
            }
        }
    }

    /// Serialize to JSON (for `db::loader`).
    pub fn to_json(&self) -> Json {
        let attrs = |xs: &Vec<Attribute>| {
            Json::Arr(
                xs.iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("name", Json::str(a.name.clone())),
                            ("card", Json::num(a.card as f64)),
                        ])
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            (
                "entities",
                Json::Arr(
                    self.entities
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("name", Json::str(e.name.clone())),
                                ("attrs", attrs(&e.attrs)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "relationships",
                Json::Arr(
                    self.relationships
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::str(r.name.clone())),
                                ("from", Json::num(r.from as f64)),
                                ("to", Json::num(r.to as f64)),
                                ("attrs", attrs(&r.attrs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse from JSON (inverse of [`Schema::to_json`]).
    pub fn from_json(j: &Json) -> Result<Schema> {
        let bad = |m: &str| Error::Schema(format!("schema json: {m}"));
        let attrs = |j: &Json| -> Result<Vec<Attribute>> {
            j.as_arr()
                .ok_or_else(|| bad("attrs not an array"))?
                .iter()
                .map(|a| {
                    Ok(Attribute {
                        name: a
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| bad("attr name"))?
                            .to_string(),
                        card: a
                            .get("card")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| bad("attr card"))?
                            as u32,
                    })
                })
                .collect()
        };
        let entities = j
            .get("entities")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("entities"))?
            .iter()
            .map(|e| {
                Ok(EntityType {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| bad("entity name"))?
                        .to_string(),
                    attrs: attrs(e.get("attrs").ok_or_else(|| bad("entity attrs"))?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let relationships = j
            .get("relationships")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("relationships"))?
            .iter()
            .map(|r| {
                Ok(RelationshipType {
                    name: r
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| bad("rel name"))?
                        .to_string(),
                    from: r.get("from").and_then(Json::as_usize).ok_or_else(|| bad("rel from"))?,
                    to: r.get("to").and_then(Json::as_usize).ok_or_else(|| bad("rel to"))?,
                    attrs: attrs(r.get("attrs").ok_or_else(|| bad("rel attrs"))?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Schema::new(entities, relationships)
    }

    /// Split a relationship set into connected components.
    pub fn connected_components(&self, rels: &[usize]) -> Vec<Vec<usize>> {
        let mut remaining: Vec<usize> = rels.to_vec();
        let mut comps = Vec::new();
        while let Some(seed) = remaining.pop() {
            let mut comp = vec![seed];
            let mut pops = {
                let (a, b) = self.rel_endpoints(seed);
                vec![a, b]
            };
            loop {
                let before = remaining.len();
                remaining.retain(|&r| {
                    let (a, b) = self.rel_endpoints(r);
                    if pops.contains(&a) || pops.contains(&b) {
                        pops.push(a);
                        pops.push(b);
                        comp.push(r);
                        false
                    } else {
                        true
                    }
                });
                if remaining.len() == before {
                    break;
                }
            }
            comp.sort_unstable();
            comps.push(comp);
        }
        comps.sort();
        comps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn university() -> Schema {
        Schema::new(
            vec![
                EntityType {
                    name: "Professor".into(),
                    attrs: vec![Attribute::new("popularity", 3)],
                },
                EntityType {
                    name: "Student".into(),
                    attrs: vec![Attribute::new("intelligence", 3)],
                },
                EntityType {
                    name: "Course".into(),
                    attrs: vec![Attribute::new("difficulty", 2)],
                },
            ],
            vec![
                RelationshipType {
                    name: "RA".into(),
                    from: 0,
                    to: 1,
                    attrs: vec![
                        Attribute::new("capability", 5),
                        Attribute::new("salary", 3),
                    ],
                },
                RelationshipType {
                    name: "Registered".into(),
                    from: 1,
                    to: 2,
                    attrs: vec![Attribute::new("grade", 4)],
                },
            ],
        )
        .unwrap()
    }

    #[test]
    fn builds_and_looks_up() {
        let s = university();
        assert_eq!(s.entity_id("Student").unwrap(), 1);
        assert_eq!(s.rel_id("Registered").unwrap(), 1);
        assert!(s.entity_id("Nope").is_err());
    }

    #[test]
    fn rejects_self_relationship() {
        let r = Schema::new(
            vec![EntityType { name: "U".into(), attrs: vec![] }],
            vec![RelationshipType {
                name: "Friend".into(),
                from: 0,
                to: 0,
                attrs: vec![],
            }],
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let r = Schema::new(
            vec![
                EntityType { name: "A".into(), attrs: vec![] },
                EntityType { name: "A".into(), attrs: vec![] },
            ],
            vec![],
        );
        assert!(r.is_err());
    }

    #[test]
    fn connectivity() {
        let s = university();
        assert!(s.is_connected(&[0]));
        assert!(s.is_connected(&[0, 1])); // share Student
        assert!(s.is_connected(&[]));
        let comps = s.connected_components(&[0, 1]);
        assert_eq!(comps, vec![vec![0, 1]]);
    }

    #[test]
    fn populations() {
        let s = university();
        assert_eq!(s.populations_of(&[0, 1]), vec![0, 1, 2]);
        assert_eq!(s.populations_of(&[1]), vec![1, 2]);
    }
}
