//! Columnar entity and relationship tables.

use crate::db::schema::Schema;
use crate::db::value::Code;
use crate::error::{Error, Result};

/// A columnar entity table: row id is the entity id (`0..n`), one value
/// column per schema attribute.
#[derive(Clone, Debug, Default)]
pub struct EntityTable {
    /// Number of entities.
    pub n: u32,
    /// `cols[a][i]` = value of attribute `a` for entity `i`.
    pub cols: Vec<Vec<Code>>,
}

impl EntityTable {
    pub fn new(n_attrs: usize) -> Self {
        EntityTable { n: 0, cols: vec![Vec::new(); n_attrs] }
    }

    /// Append one entity; returns its id.
    pub fn push(&mut self, values: &[Code]) -> Result<u32> {
        if values.len() != self.cols.len() {
            return Err(Error::Data(format!(
                "entity row arity {} != {}",
                values.len(),
                self.cols.len()
            )));
        }
        // entity ids are u32; `n += 1` at the boundary would wrap to 0
        Error::check_u32_capacity("entity ids", self.n as u64 + 1)?;
        for (c, &v) in self.cols.iter_mut().zip(values) {
            c.push(v);
        }
        let id = self.n;
        self.n += 1;
        Ok(id)
    }

    pub fn len(&self) -> u32 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Value of attribute `a` for entity `i`.
    #[inline]
    pub fn value(&self, a: usize, i: u32) -> Code {
        self.cols[a][i as usize]
    }

    pub fn validate(&self, schema: &Schema, et: usize) -> Result<()> {
        let ety = &schema.entities[et];
        if self.cols.len() != ety.attrs.len() {
            return Err(Error::Data(format!(
                "entity table {} has {} columns, schema says {}",
                ety.name,
                self.cols.len(),
                ety.attrs.len()
            )));
        }
        for (a, col) in self.cols.iter().enumerate() {
            if col.len() != self.n as usize {
                return Err(Error::Data(format!(
                    "{}.{} column length mismatch",
                    ety.name, ety.attrs[a].name
                )));
            }
            let card = ety.attrs[a].card;
            if let Some(&bad) = col.iter().find(|&&v| v >= card) {
                return Err(Error::Data(format!(
                    "{}.{} value {} out of range 0..{}",
                    ety.name, ety.attrs[a].name, bad, card
                )));
            }
        }
        Ok(())
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.cols.iter().map(|c| c.capacity() * 4).sum()
    }
}

/// A columnar relationship table: tuples `(from, to)` with attribute
/// columns.  At most one tuple per `(from, to)` pair (set semantics with
/// attributes), matching the paper's relational model.
#[derive(Clone, Debug, Default)]
pub struct RelTable {
    pub from: Vec<u32>,
    pub to: Vec<u32>,
    /// `cols[a][t]` = raw value (`0..card`) of rel attribute `a` for
    /// tuple `t`.
    pub cols: Vec<Vec<Code>>,
}

impl RelTable {
    pub fn new(n_attrs: usize) -> Self {
        RelTable { from: Vec::new(), to: Vec::new(), cols: vec![Vec::new(); n_attrs] }
    }

    /// Append one tuple; duplicate-pair checking happens at index build.
    pub fn push(&mut self, from: u32, to: u32, values: &[Code]) -> Result<u32> {
        if values.len() != self.cols.len() {
            return Err(Error::Data(format!(
                "rel row arity {} != {}",
                values.len(),
                self.cols.len()
            )));
        }
        // tuple ids are u32: an unchecked 2^32-th push would hand out a
        // wrapped id and silently alias tuple 0
        Error::check_u32_capacity("relationship tuple ids", self.from.len() as u64 + 1)?;
        self.from.push(from);
        self.to.push(to);
        for (c, &v) in self.cols.iter_mut().zip(values) {
            c.push(v);
        }
        Ok(self.from.len() as u32 - 1)
    }

    pub fn len(&self) -> u32 {
        self.from.len() as u32
    }

    pub fn is_empty(&self) -> bool {
        self.from.is_empty()
    }

    #[inline]
    pub fn value(&self, a: usize, t: u32) -> Code {
        self.cols[a][t as usize]
    }

    /// Remove tuple `t` by swapping the last tuple into its slot
    /// (tombstone-free: ids stay dense, so indexes relabel the moved
    /// tuple instead of tracking holes).  Returns the removed tuple's
    /// attribute values.  The caller owns index maintenance — see
    /// [`crate::db::catalog::Database::delete_link`].
    pub fn swap_remove(&mut self, t: u32) -> Result<Vec<Code>> {
        let i = t as usize;
        if i >= self.from.len() {
            return Err(Error::Data(format!(
                "swap_remove({t}) out of range 0..{}",
                self.from.len()
            )));
        }
        self.from.swap_remove(i);
        self.to.swap_remove(i);
        let values = self.cols.iter_mut().map(|c| c.swap_remove(i)).collect();
        Ok(values)
    }

    pub fn validate(&self, schema: &Schema, rt: usize) -> Result<()> {
        let rty = &schema.relationships[rt];
        if self.cols.len() != rty.attrs.len() {
            return Err(Error::Data(format!(
                "rel table {} has {} columns, schema says {}",
                rty.name,
                self.cols.len(),
                rty.attrs.len()
            )));
        }
        if self.to.len() != self.from.len() {
            return Err(Error::Data(format!("{} from/to length mismatch", rty.name)));
        }
        for (a, col) in self.cols.iter().enumerate() {
            if col.len() != self.from.len() {
                return Err(Error::Data(format!(
                    "{}.{} column length mismatch",
                    rty.name, rty.attrs[a].name
                )));
            }
            let card = rty.attrs[a].card;
            if let Some(&bad) = col.iter().find(|&&v| v >= card) {
                return Err(Error::Data(format!(
                    "{}.{} value {} out of range 0..{}",
                    rty.name, rty.attrs[a].name, bad, card
                )));
            }
        }
        Ok(())
    }

    pub fn bytes(&self) -> usize {
        (self.from.capacity() + self.to.capacity()) * 4
            + self.cols.iter().map(|c| c.capacity() * 4).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::schema::{Attribute, EntityType, RelationshipType};

    fn schema() -> Schema {
        Schema::new(
            vec![
                EntityType { name: "A".into(), attrs: vec![Attribute::new("x", 2)] },
                EntityType { name: "B".into(), attrs: vec![] },
            ],
            vec![RelationshipType {
                name: "R".into(),
                from: 0,
                to: 1,
                attrs: vec![Attribute::new("w", 3)],
            }],
        )
        .unwrap()
    }

    #[test]
    fn entity_push_and_validate() {
        let s = schema();
        let mut t = EntityTable::new(1);
        assert_eq!(t.push(&[0]).unwrap(), 0);
        assert_eq!(t.push(&[1]).unwrap(), 1);
        assert!(t.push(&[0, 1]).is_err()); // arity
        t.validate(&s, 0).unwrap();
        assert_eq!(t.value(0, 1), 1);
    }

    #[test]
    fn entity_rejects_out_of_range() {
        let s = schema();
        let mut t = EntityTable::new(1);
        t.push(&[5]).unwrap();
        assert!(t.validate(&s, 0).is_err());
    }

    #[test]
    fn rel_push_and_validate() {
        let s = schema();
        let mut t = RelTable::new(1);
        t.push(0, 0, &[2]).unwrap();
        t.push(1, 0, &[0]).unwrap();
        t.validate(&s, 0).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.value(0, 0), 2);
    }

    #[test]
    fn rel_swap_remove_moves_last() {
        let mut t = RelTable::new(1);
        t.push(0, 0, &[0]).unwrap();
        t.push(1, 0, &[1]).unwrap();
        t.push(1, 1, &[2]).unwrap();
        let removed = t.swap_remove(0).unwrap();
        assert_eq!(removed, vec![0]);
        assert_eq!(t.len(), 2);
        // the former last tuple (1,1) now owns id 0
        assert_eq!((t.from[0], t.to[0]), (1, 1));
        assert_eq!(t.value(0, 0), 2);
        // removing the last tuple moves nothing
        t.swap_remove(1).unwrap();
        assert_eq!(t.len(), 1);
        assert!(t.swap_remove(5).is_err());
    }

    #[test]
    fn rel_rejects_bad_value() {
        let s = schema();
        let mut t = RelTable::new(1);
        t.push(0, 0, &[3]).unwrap();
        assert!(t.validate(&s, 0).is_err());
    }
}
