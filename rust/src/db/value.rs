//! Categorical value coding conventions.
//!
//! All attribute values are dense u32 codes.  The conventions used across
//! the whole stack (Rust sparse ct-tables, the dense Pallas layout, and
//! the synthetic generators) are:
//!
//! - **Entity attributes**: raw codes `0..card`, ct-table dimension =
//!   `card`.
//! - **Relationship attributes**: ct-table dimension = `card + 1`; code
//!   `0` is the distinguished **N/A** value taken exactly when the
//!   relationship indicator is false (paper Table 3: `Capa(P,S) = N/A`
//!   whenever `RA(P,S) = F`), and codes `1..=card` are the real values
//!   shifted by one.  Raw table storage keeps unshifted `0..card`.
//! - **Relationship indicators**: dimension 2, `0 = F`, `1 = T`.

/// A dense categorical value code.
pub type Code = u32;

/// The N/A code for relationship attributes *in ct-table coordinates*.
pub const NA: Code = 0;

/// Shift a raw relationship-attribute value into ct-table coordinates.
#[inline]
pub fn rel_attr_to_ct(raw: Code) -> Code {
    raw + 1
}

/// Unshift a ct-table relationship-attribute code into a raw value.
/// Returns `None` for N/A.
#[inline]
pub fn rel_attr_from_ct(ct: Code) -> Option<Code> {
    ct.checked_sub(1)
}

/// Indicator codes.
pub const IND_FALSE: Code = 0;
pub const IND_TRUE: Code = 1;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifts_roundtrip() {
        for raw in 0..10 {
            assert_eq!(rel_attr_from_ct(rel_attr_to_ct(raw)), Some(raw));
        }
        assert_eq!(rel_attr_from_ct(NA), None);
    }
}
