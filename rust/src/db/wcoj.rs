//! Worst-case optimal multi-way count kernel (LeapFrog / Atreides style).
//!
//! The binary chain enumerator in [`crate::db::query`] extends a partial
//! binding one *relationship* at a time, so on skewed data a pairwise
//! plan can enumerate intermediate joins asymptotically larger than the
//! output (the AGM gap: for the triangle pattern every binary plan is
//! Θ(N²) on the hub construction while the output is Θ(N)).  This
//! module binds one *variable* (entity type) at a time instead: a new
//! variable's candidates are the intersection of the sorted neighbor
//! runs of every relationship connecting it to the bound prefix,
//! computed with the same merge/gallop primitives the chain kernel's
//! intersection fast path uses.  Runs are borrowed through the
//! [`NeighborRun`] abstraction, so clean CSR slices and clean
//! compressed block runs are intersected in place (the latter with
//! block-skipping seeks); dirty rows and the hash backend fall back to
//! a per-query sorted memo, so all storage engines produce identical
//! answers.
//!
//! The variable order is chosen greedily from cardinality estimates —
//! [`SummaryStats`] degree summaries when the caller maintains them
//! (the PR 7 estimator tier), raw index fan-outs otherwise.  The order
//! affects running time only, never counts: results are bit-identical
//! to the chain enumerator under the established discipline (same
//! `JoinStats` semantics, comparable `cache_digest`s), so the chain
//! kernel and the hash backend double as differential oracles on every
//! connected pattern — chains, stars, triangles and small cliques.

use crate::ct::cttable::CtTable;
use crate::db::catalog::Database;
use crate::db::index::{NeighborRun, RelIx, RunCursor};
use crate::db::query::JoinStats;
use crate::error::{Error, Result};
use crate::estimate::summary::SummaryStats;
use crate::meta::extract::plan_chain;
use crate::meta::rvar::RVar;
use crate::util::fxhash::FxHashMap;

/// Positive-count join kernel selector (CLI `--kernel`).  Carried by
/// [`Database`] so every consumer — all four strategies, the Möbius
/// completer and the `ParallelCoordinator`'s per-worker clones —
/// dispatches through the same switch in
/// [`crate::db::query::positive_chain_ct`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum JoinKernel {
    /// Binary chain enumeration, one relationship per step (the
    /// default; also the delta-maintenance path's only kernel).
    #[default]
    Chain,
    /// Worst-case optimal variable-at-a-time enumeration.
    Wcoj,
}

impl JoinKernel {
    pub fn parse(s: &str) -> Option<JoinKernel> {
        match s.to_ascii_lowercase().as_str() {
            "chain" => Some(JoinKernel::Chain),
            "wcoj" => Some(JoinKernel::Wcoj),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            JoinKernel::Chain => "chain",
            JoinKernel::Wcoj => "wcoj",
        }
    }
}

/// Greedy connectivity-preserving variable order: start from the
/// cheapest population, then repeatedly append the not-yet-bound entity
/// type with the smallest estimated candidate count given the bound
/// prefix (minimum average degree over its connecting relationships).
/// Estimates come from `summary` when provided, otherwise from index
/// cardinalities; ties break toward the smaller entity-type id, so the
/// order is deterministic for a given database state.
pub fn variable_order(
    db: &Database,
    chain: &[usize],
    pops: &[usize],
    summary: Option<&SummaryStats>,
) -> Vec<usize> {
    let mut order: Vec<usize> = Vec::with_capacity(pops.len());
    let mut bound = vec![false; db.schema.entities.len()];
    while order.len() < pops.len() {
        let mut best: Option<(f64, usize)> = None;
        for &v in pops {
            if bound[v] {
                continue;
            }
            let score = if order.is_empty() {
                db.entities[v].len() as f64
            } else {
                // min avg degree toward v over rels whose other endpoint
                // is already bound; unconnected vars wait their turn
                let mut s: Option<f64> = None;
                for &r in chain {
                    let (a, b) = db.schema.rel_endpoints(r);
                    let est = if b == v && bound[a] {
                        avg_degree(db, summary, r, true)
                    } else if a == v && bound[b] {
                        avg_degree(db, summary, r, false)
                    } else {
                        continue;
                    };
                    s = Some(s.map_or(est, |cur: f64| cur.min(est)));
                }
                match s {
                    Some(s) => s,
                    None => continue,
                }
            };
            // strict < keeps the first (smallest-id) minimum
            if best.map_or(true, |(bs, _)| score < bs) {
                best = Some((score, v));
            }
        }
        match best {
            Some((_, v)) => {
                bound[v] = true;
                order.push(v);
            }
            // disconnected pattern: plan_chain rejects these before we
            // run, but stay total — append remaining vars in id order
            None => {
                for &v in pops {
                    if !bound[v] {
                        bound[v] = true;
                        order.push(v);
                        break;
                    }
                }
            }
        }
    }
    order
}

/// Expected number of `to`-values reachable from one bound `from`
/// value of `rel` (or the reverse when `toward_to` is false).
fn avg_degree(
    db: &Database,
    summary: Option<&SummaryStats>,
    rel: usize,
    toward_to: bool,
) -> f64 {
    if let Some(s) = summary {
        let rs = &s.rels[rel];
        let active = if toward_to {
            rs.fan_out.active()
        } else {
            rs.fan_in.active()
        };
        return rs.rows as f64 / active.max(1) as f64;
    }
    let (a, b) = db.schema.rel_endpoints(rel);
    let other = if toward_to { a } else { b };
    let rows = db.index(rel).map(|ix| ix.len()).unwrap_or(0);
    rows as f64 / (db.entities[other].len() as f64).max(1.0)
}

/// One already-bound-side constraint on the variable being extended.
struct Cons {
    rel: usize,
    /// Position of `rel` in the canonical (sorted) chain — indexes the
    /// shared `tuples` scratch the group-by key reads rel attrs from.
    pos: usize,
    /// The already-bound endpoint entity type.
    other: usize,
    /// The new variable sits on the `to` side of `rel`.
    v_is_to: bool,
}

/// One variable of the enumeration, with the relationships that
/// constrain it against the bound prefix (empty for the first).
struct Step {
    var: usize,
    cons: Vec<Cons>,
}

/// Per-query sorted-run memo for rows the columnar engines cannot hand
/// out as clean runs: hash-backend adjacency (insertion order) and
/// CSR/CCSR rows with pending overlay entries.  Keyed by (rel,
/// orientation, value); each materialized row is sorted by neighbor,
/// mirroring the clean-run order, so intersection results cannot depend
/// on the backend.
type RunMemo = FxHashMap<(u32, bool, u32), Vec<(u32, u32)>>;

/// Candidates for one variable: the intersection members, plus the
/// tuple id each constraining relationship matched them with (`k` tids
/// per candidate, in constraint order).
struct Cands {
    k: usize,
    vals: Vec<u32>,
    tids: Vec<u32>,
}

/// Leapfrog intersection of `runs`: iterate the shortest run and seek
/// the rest.  Runs are strictly ascending in neighbor (pairs are unique
/// per relationship), so each cursor only moves forward — block runs
/// additionally skip whole packed blocks via their min/max headers and
/// decode at most one block per seek.
fn collect_candidates(runs: &[NeighborRun<'_>]) -> Cands {
    let k = runs.len();
    let pi = (0..k).min_by_key(|&i| runs[i].len()).expect("k >= 1");
    let mut cursors: Vec<RunCursor<'_>> =
        runs.iter().map(|&r| RunCursor::new(r)).collect();
    let mut cur = vec![0usize; k];
    let mut out = Cands { k, vals: Vec::new(), tids: Vec::new() };
    'probe: for i in 0..runs[pi].len() {
        let c = cursors[pi].val(i);
        for j in 0..k {
            if j == pi {
                continue;
            }
            let p = cursors[j].seek(cur[j], c);
            cur[j] = p;
            if p >= runs[j].len() {
                // this run is exhausted; later probes are larger still
                break 'probe;
            }
            if cursors[j].val(p) != c {
                continue 'probe;
            }
        }
        out.vals.push(c);
        for j in 0..k {
            let p = if j == pi { i } else { cur[j] };
            out.tids.push(cursors[j].tid(p));
        }
    }
    out
}

/// Size of the k-way intersection (count-only collapse at the last
/// variable).  Two runs reuse [`NeighborRun::intersect_count`], which
/// keeps the adaptive merge/gallop fast path for clean CSR slices.
fn intersect_size(runs: &[NeighborRun<'_>]) -> u64 {
    if runs.len() == 1 {
        return runs[0].len() as u64;
    }
    if runs.len() == 2 {
        return runs[0].intersect_count(&runs[1]);
    }
    let k = runs.len();
    let pi = (0..k).min_by_key(|&i| runs[i].len()).expect("k >= 2");
    let mut cursors: Vec<RunCursor<'_>> =
        runs.iter().map(|&r| RunCursor::new(r)).collect();
    let mut cur = vec![0usize; k];
    let mut n = 0u64;
    'probe: for i in 0..runs[pi].len() {
        let c = cursors[pi].val(i);
        for j in 0..k {
            if j == pi {
                continue;
            }
            let p = cursors[j].seek(cur[j], c);
            cur[j] = p;
            if p >= runs[j].len() {
                break 'probe;
            }
            if cursors[j].val(p) != c {
                continue 'probe;
            }
        }
        n += 1;
    }
    n
}

/// Context threaded through the recursive variable-at-a-time descent.
struct WcojCx<'a> {
    db: &'a Database,
    steps: Vec<Step>,
    /// Degree screens for the seed variable: (rel, seed-is-from).
    seed_filters: Vec<(usize, bool)>,
    /// The last variable admits the count-only collapse (its entity
    /// attrs and its completing rels' attrs are all outside the key).
    collapse_last: bool,
}

/// Worst-case optimal positive ct-table for a connected relationship
/// set — the WCOJ twin of the chain path inside
/// [`crate::db::query::positive_chain_ct`], which dispatches here when
/// the database's [`JoinKernel`] is `Wcoj`.  Counts, `JoinStats` and
/// ct-table contents are bit-identical to the chain enumerator's.
pub fn wcoj_chain_ct(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    stats: &mut JoinStats,
) -> Result<CtTable> {
    wcoj_chain_ct_with(db, chain, vars, None, stats)
}

/// [`wcoj_chain_ct`] with an optional summary-statistics tier steering
/// the variable order (`exp wcoj` and estimator-maintaining callers).
pub fn wcoj_chain_ct_with(
    db: &Database,
    chain: &[usize],
    vars: &[RVar],
    summary: Option<&SummaryStats>,
    stats: &mut JoinStats,
) -> Result<CtTable> {
    let plan = plan_chain(db, chain)?;
    for v in vars {
        let ok = match v {
            RVar::EntityAttr { et, .. } => plan.pops.contains(et),
            RVar::RelAttr { rel, .. } => plan.chain.contains(rel),
            RVar::RelInd { .. } => false,
        };
        if !ok {
            return Err(Error::Ct(format!(
                "variable {v:?} not available on chain {chain:?}"
            )));
        }
    }
    let mut out = CtTable::new(&db.schema, vars.to_vec())?;
    stats.chain_queries += 1;
    stats.join_steps += plan.join_order.len() as u64;

    // Precompiled key accessors, as in the chain kernel; rel attrs are
    // read through the canonical chain position.
    enum Access {
        Ent { et: usize, attr: usize, stride: u128 },
        Rel { rel: usize, pos: usize, attr: usize, stride: u128 },
    }
    let mut base: u128 = 0;
    let mut accesses = Vec::with_capacity(vars.len());
    for (j, v) in vars.iter().enumerate() {
        let stride = out.stride(j);
        match *v {
            RVar::EntityAttr { et, attr } => {
                accesses.push(Access::Ent { et, attr, stride })
            }
            RVar::RelAttr { rel, attr } => {
                let pos = plan
                    .chain
                    .iter()
                    .position(|&r| r == rel)
                    .expect("rel in chain");
                base += stride; // ct coords = raw + 1
                accesses.push(Access::Rel { rel, pos, attr, stride });
            }
            RVar::RelInd { .. } => unreachable!("validated above"),
        }
    }
    let n_ets = db.schema.entities.len();
    let mut needed_ets = vec![false; n_ets];
    let mut needed_pos = vec![false; plan.chain.len()];
    for acc in &accesses {
        match *acc {
            Access::Ent { et, .. } => needed_ets[et] = true,
            Access::Rel { pos, .. } => needed_pos[pos] = true,
        }
    }

    let order = variable_order(db, &plan.chain, &plan.pops, summary);
    let mut steps: Vec<Step> = order
        .iter()
        .map(|&v| Step { var: v, cons: Vec::new() })
        .collect();
    let depth_of = |et: usize| order.iter().position(|&v| v == et);
    for (pos, &rel) in plan.chain.iter().enumerate() {
        let (a, b) = db.schema.rel_endpoints(rel);
        let (da, db_) = match (depth_of(a), depth_of(b)) {
            (Some(x), Some(y)) => (x, y),
            _ => unreachable!("chain endpoints are in pops"),
        };
        // the rel constrains whichever endpoint binds later
        let (d, other, v_is_to) = if da < db_ {
            (db_, a, true)
        } else {
            (da, b, false)
        };
        steps[d].cons.push(Cons { rel, pos, other, v_is_to });
    }
    for step in steps.iter().skip(1) {
        if step.cons.is_empty() {
            return Err(Error::Ct(format!(
                "wcoj: disconnected variable order for chain {chain:?}"
            )));
        }
    }
    let seed_filters: Vec<(usize, bool)> = plan
        .chain
        .iter()
        .filter_map(|&rel| {
            let (a, b) = db.schema.rel_endpoints(rel);
            if a == order[0] {
                Some((rel, true))
            } else if b == order[0] {
                Some((rel, false))
            } else {
                None
            }
        })
        .collect();
    let collapse_last = {
        let last = steps.last().expect("pattern has >= 2 variables");
        !needed_ets[last.var] && last.cons.iter().all(|c| !needed_pos[c.pos])
    };

    let cx = WcojCx { db, steps, seed_filters, collapse_last };
    let mut binding: Vec<u32> = vec![0; n_ets];
    let mut tuples: Vec<u32> = vec![0; plan.chain.len()];
    let mut memo = RunMemo::default();
    let mut rows = 0u64;
    descend(
        &cx,
        0,
        1,
        &mut binding,
        &mut tuples,
        &mut memo,
        &mut |binding, tuples, mult| {
            let mut key = base;
            for a in &accesses {
                key += match *a {
                    Access::Ent { et, attr, stride } => {
                        db.entities[et].value(attr, binding[et]) as u128 * stride
                    }
                    Access::Rel { rel, pos, attr, stride } => {
                        db.rels[rel].value(attr, tuples[pos]) as u128 * stride
                    }
                };
            }
            rows += mult as u64;
            out.add_key(key, mult)
        },
    )?;
    stats.rows_enumerated += rows;
    Ok(out)
}

/// Borrow the sorted run for one constraint, memoizing rows the engine
/// cannot hand out as clean runs.  Phase 1 of each step fills the memo
/// (mutable); phase 2 takes the borrows.
fn ensure_memo(
    db: &Database,
    memo: &mut RunMemo,
    cons: &Cons,
    bound_val: u32,
) -> Result<()> {
    let ix = db.index(cons.rel)?;
    let clean = if cons.v_is_to {
        ix.neighbor_run_from(bound_val).is_some()
    } else {
        ix.neighbor_run_to(bound_val).is_some()
    };
    if clean {
        return Ok(());
    }
    let key = (cons.rel as u32, cons.v_is_to, bound_val);
    if !memo.contains_key(&key) {
        let table = &db.rels[cons.rel];
        let mut row: Vec<(u32, u32)> = if cons.v_is_to {
            ix.tids_from(bound_val)
                .map(|t| (table.to[t as usize], t))
                .collect()
        } else {
            ix.tids_to(bound_val)
                .map(|t| (table.from[t as usize], t))
                .collect()
        };
        row.sort_unstable();
        memo.insert(key, row);
    }
    Ok(())
}

fn run_for<'a>(
    ix: &'a RelIx,
    memo: &'a RunMemo,
    cons: &Cons,
    bound_val: u32,
) -> NeighborRun<'a> {
    let clean = if cons.v_is_to {
        ix.neighbor_run_from(bound_val)
    } else {
        ix.neighbor_run_to(bound_val)
    };
    match clean {
        Some(run) => run,
        None => NeighborRun::Pairs(
            memo.get(&(cons.rel as u32, cons.v_is_to, bound_val))
                .expect("memoized in ensure_memo"),
        ),
    }
}

/// Recursive variable-at-a-time descent.  `mult` carries collapsed
/// multiplicities exactly as the chain enumerator's kernels do, so the
/// leaf emit keeps group counts and `rows_enumerated` exact.
fn descend(
    cx: &WcojCx<'_>,
    depth: usize,
    mult: i128,
    binding: &mut [u32],
    tuples: &mut [u32],
    memo: &mut RunMemo,
    emit: &mut dyn FnMut(&[u32], &[u32], i128) -> Result<()>,
) -> Result<()> {
    if depth == cx.steps.len() {
        return emit(binding, tuples, mult);
    }
    let db = cx.db;
    let step = &cx.steps[depth];
    if depth == 0 {
        // seed variable: scan its population, screening out values that
        // cannot satisfy some incident relationship (degree 0)
        let pop = db.entities[step.var].len();
        'seed: for c in 0..pop {
            for &(rel, is_from) in &cx.seed_filters {
                let ix = db.index(rel)?;
                let deg = if is_from {
                    ix.degree_from(c)
                } else {
                    ix.degree_to(c)
                };
                if deg == 0 {
                    continue 'seed;
                }
            }
            binding[step.var] = c;
            descend(cx, depth + 1, mult, binding, tuples, memo, emit)?;
        }
        return Ok(());
    }
    for cons in &step.cons {
        ensure_memo(db, memo, cons, binding[cons.other])?;
    }
    if depth + 1 == cx.steps.len() && cx.collapse_last {
        // count-only collapse: nothing downstream reads this variable
        // or its completing rels, so the subtree contributes |∩ runs|
        let n = {
            let mut runs = Vec::with_capacity(step.cons.len());
            for cons in &step.cons {
                let ix = db.index(cons.rel)?;
                runs.push(run_for(ix, memo, cons, binding[cons.other]));
            }
            intersect_size(&runs)
        };
        if n > 0 {
            emit(binding, tuples, mult * n as i128)?;
        }
        return Ok(());
    }
    let cands = {
        let mut runs = Vec::with_capacity(step.cons.len());
        for cons in &step.cons {
            let ix = db.index(cons.rel)?;
            runs.push(run_for(ix, memo, cons, binding[cons.other]));
        }
        collect_candidates(&runs)
    };
    for (i, &c) in cands.vals.iter().enumerate() {
        binding[step.var] = c;
        for (j, cons) in step.cons.iter().enumerate() {
            tuples[cons.pos] = cands.tids[i * cands.k + j];
        }
        descend(cx, depth + 1, mult, binding, tuples, memo, emit)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::index::Backend;
    use crate::db::query::positive_chain_ct;
    use crate::db::schema::{Attribute, EntityType, RelationshipType, Schema};

    /// Triangle schema A-B-C with all three pairwise rels, deterministic
    /// membership predicates, and attrs on A, C and R2.
    fn triangle_db() -> Database {
        let schema = Schema::new(
            vec![
                EntityType { name: "A".into(), attrs: vec![Attribute::new("x", 2)] },
                EntityType { name: "B".into(), attrs: vec![] },
                EntityType { name: "C".into(), attrs: vec![Attribute::new("y", 3)] },
            ],
            vec![
                RelationshipType { name: "R0".into(), from: 0, to: 1, attrs: vec![] },
                RelationshipType { name: "R1".into(), from: 1, to: 2, attrs: vec![] },
                RelationshipType {
                    name: "R2".into(),
                    from: 0,
                    to: 2,
                    attrs: vec![Attribute::new("w", 2)],
                },
            ],
        )
        .unwrap();
        let mut db = Database::empty(schema);
        for a in 0..6u32 {
            db.entities[0].push(&[a % 2]).unwrap();
        }
        for _ in 0..5u32 {
            db.entities[1].push(&[]).unwrap();
        }
        for c in 0..7u32 {
            db.entities[2].push(&[c % 3]).unwrap();
        }
        for a in 0..6u32 {
            for b in 0..5u32 {
                if (a + 2 * b) % 3 != 1 {
                    db.rels[0].push(a, b, &[]).unwrap();
                }
            }
        }
        for b in 0..5u32 {
            for c in 0..7u32 {
                if (b + c) % 2 == 0 {
                    db.rels[1].push(b, c, &[]).unwrap();
                }
            }
        }
        for a in 0..6u32 {
            for c in 0..7u32 {
                if (2 * a + c) % 3 != 0 {
                    db.rels[2].push(a, c, &[(a + c) % 2]).unwrap();
                }
            }
        }
        db.build_indexes().unwrap();
        db
    }

    fn star_db() -> Database {
        let schema = Schema::new(
            vec![
                EntityType { name: "Hub".into(), attrs: vec![] },
                EntityType { name: "P".into(), attrs: vec![Attribute::new("x", 2)] },
                EntityType { name: "Q".into(), attrs: vec![] },
                EntityType { name: "S".into(), attrs: vec![Attribute::new("z", 2)] },
            ],
            vec![
                RelationshipType { name: "E0".into(), from: 1, to: 0, attrs: vec![] },
                RelationshipType { name: "E1".into(), from: 0, to: 2, attrs: vec![] },
                RelationshipType { name: "E2".into(), from: 0, to: 3, attrs: vec![] },
            ],
        )
        .unwrap();
        let mut db = Database::empty(schema);
        for _ in 0..4u32 {
            db.entities[0].push(&[]).unwrap();
        }
        for p in 0..5u32 {
            db.entities[1].push(&[p % 2]).unwrap();
        }
        for _ in 0..6u32 {
            db.entities[2].push(&[]).unwrap();
        }
        for s in 0..3u32 {
            db.entities[3].push(&[s % 2]).unwrap();
        }
        for p in 0..5u32 {
            for h in 0..4u32 {
                if (p + h) % 3 != 0 {
                    db.rels[0].push(p, h, &[]).unwrap();
                }
            }
        }
        for h in 0..4u32 {
            for q in 0..6u32 {
                if (h + 2 * q) % 4 != 1 {
                    db.rels[1].push(h, q, &[]).unwrap();
                }
            }
        }
        for h in 0..4u32 {
            for s in 0..3u32 {
                if (h + s) % 2 == 0 {
                    db.rels[2].push(h, s, &[]).unwrap();
                }
            }
        }
        db.build_indexes().unwrap();
        db
    }

    fn compare_kernels(db: &Database, chain: &[usize], vars: &[RVar]) {
        let mut chain_db = db.clone();
        chain_db.set_kernel(JoinKernel::Chain);
        let mut wcoj_db = db.clone();
        wcoj_db.set_kernel(JoinKernel::Wcoj);
        let mut s1 = JoinStats::default();
        let mut s2 = JoinStats::default();
        let a = positive_chain_ct(&chain_db, chain, vars, &mut s1).unwrap();
        let b = positive_chain_ct(&wcoj_db, chain, vars, &mut s2).unwrap();
        assert_eq!(s1, s2, "JoinStats diverge on {chain:?} {vars:?}");
        assert_eq!(a.digest(), b.digest(), "tables diverge on {chain:?} {vars:?}");
    }

    fn all_var_subsets(db: &Database, chain: &[usize]) -> Vec<Vec<RVar>> {
        let pops = db.schema.populations_of(chain);
        let mut pool: Vec<RVar> = Vec::new();
        for &et in &pops {
            for attr in 0..db.schema.entities[et].attrs.len() {
                pool.push(RVar::EntityAttr { et, attr });
            }
        }
        for &rel in chain {
            for attr in 0..db.schema.relationships[rel].attrs.len() {
                pool.push(RVar::RelAttr { rel, attr });
            }
        }
        let mut subsets = vec![Vec::new()];
        for v in pool {
            let mut more: Vec<Vec<RVar>> = subsets
                .iter()
                .map(|s| {
                    let mut s = s.clone();
                    s.push(v);
                    s
                })
                .collect();
            subsets.append(&mut more);
        }
        subsets
    }

    #[test]
    fn triangle_matches_chain_kernel_on_all_var_subsets() {
        let db = triangle_db();
        for chain in [
            vec![0usize],
            vec![1],
            vec![2],
            vec![0, 1],
            vec![1, 2],
            vec![0, 2],
            vec![0, 1, 2],
        ] {
            if !db.schema.is_connected(&chain) {
                continue;
            }
            for vars in all_var_subsets(&db, &chain) {
                compare_kernels(&db, &chain, &vars);
            }
        }
    }

    #[test]
    fn star_matches_chain_kernel_on_all_var_subsets() {
        let db = star_db();
        for chain in [vec![0usize, 1], vec![0, 2], vec![1, 2], vec![0, 1, 2]] {
            for vars in all_var_subsets(&db, &chain) {
                compare_kernels(&db, &chain, &vars);
            }
        }
    }

    #[test]
    fn hash_backend_memo_path_matches_csr() {
        let mut db = triangle_db();
        db.set_backend(Backend::Hash).unwrap();
        db.set_kernel(JoinKernel::Wcoj);
        let mut csr = triangle_db();
        csr.set_kernel(JoinKernel::Wcoj);
        let vars = vec![
            RVar::EntityAttr { et: 0, attr: 0 },
            RVar::RelAttr { rel: 2, attr: 0 },
        ];
        let mut s1 = JoinStats::default();
        let mut s2 = JoinStats::default();
        let a = positive_chain_ct(&db, &[0, 1, 2], &vars, &mut s1).unwrap();
        let b = positive_chain_ct(&csr, &[0, 1, 2], &vars, &mut s2).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn dirty_csr_rows_take_memo_fallback() {
        // churn the triangle db so CSR overlays are pending, then compare
        let mut db = triangle_db();
        db.set_kernel(JoinKernel::Wcoj);
        // delete + reinsert some R1 links without compacting
        db.delete_link(1, 0, 0).unwrap();
        db.delete_link(1, 2, 2).unwrap();
        db.insert_link(1, 0, 1, &[]).unwrap();
        let mut chain_db = db.clone();
        chain_db.set_kernel(JoinKernel::Chain);
        let vars = vec![RVar::EntityAttr { et: 2, attr: 0 }];
        let mut s1 = JoinStats::default();
        let mut s2 = JoinStats::default();
        let a = positive_chain_ct(&chain_db, &[0, 1, 2], &vars, &mut s1).unwrap();
        let b = positive_chain_ct(&db, &[0, 1, 2], &vars, &mut s2).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn triangle_count_matches_brute_force() {
        let db = triangle_db();
        let mut wcoj_db = db.clone();
        wcoj_db.set_kernel(JoinKernel::Wcoj);
        let mut stats = JoinStats::default();
        let ct = positive_chain_ct(&wcoj_db, &[0, 1, 2], &[], &mut stats).unwrap();
        // brute-force nested loop over all (a, b, c)
        let mut n = 0i128;
        for a in 0..6u32 {
            for b in 0..5u32 {
                for c in 0..7u32 {
                    if (a + 2 * b) % 3 != 1 && (b + c) % 2 == 0 && (2 * a + c) % 3 != 0 {
                        n += 1;
                    }
                }
            }
        }
        assert_eq!(ct.total().unwrap(), n);
        assert_eq!(stats.rows_enumerated, n as u64);
        assert_eq!(stats.chain_queries, 1);
        assert_eq!(stats.join_steps, 3);
    }

    #[test]
    fn variable_order_is_connected_and_deterministic() {
        let db = triangle_db();
        for chain in [vec![0usize, 1], vec![0, 1, 2]] {
            let pops = db.schema.populations_of(&chain);
            let order = variable_order(&db, &chain, &pops, None);
            assert_eq!(order.len(), pops.len());
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, pops);
            // every var after the first connects to the prefix
            for d in 1..order.len() {
                let prefix = &order[..d];
                assert!(
                    chain.iter().any(|&r| {
                        let (a, b) = db.schema.rel_endpoints(r);
                        (order[d] == a && prefix.contains(&b))
                            || (order[d] == b && prefix.contains(&a))
                    }),
                    "order {order:?} disconnected at depth {d}"
                );
            }
            assert_eq!(order, variable_order(&db, &chain, &pops, None));
        }
    }

    #[test]
    fn summary_steered_order_agrees_with_counts() {
        let db = triangle_db();
        let summary = SummaryStats::build(&db);
        let vars = vec![RVar::EntityAttr { et: 0, attr: 0 }];
        let mut s1 = JoinStats::default();
        let mut s2 = JoinStats::default();
        let a = wcoj_chain_ct_with(&db, &[0, 1, 2], &vars, Some(&summary), &mut s1);
        let b = wcoj_chain_ct(&db, &[0, 1, 2], &vars, &mut s2);
        assert_eq!(s1, s2);
        assert_eq!(a.unwrap().digest(), b.unwrap().digest());
    }

    #[test]
    fn kernel_parse_roundtrip() {
        assert_eq!(JoinKernel::parse("chain"), Some(JoinKernel::Chain));
        assert_eq!(JoinKernel::parse("WCOJ"), Some(JoinKernel::Wcoj));
        assert_eq!(JoinKernel::parse("nope"), None);
        assert_eq!(JoinKernel::default().name(), "chain");
        assert_eq!(JoinKernel::Wcoj.name(), "wcoj");
    }
}
