//! Delta batches: the unit of streaming mutation.
//!
//! A [`DeltaBatch`] is an ordered list of fact-level mutations — link
//! inserts, link deletes, entity inserts — applied to a
//! [`crate::delta::MaintainedCounts`].  Ops apply in list order; a batch
//! whose ops touch distinct `(rel, from, to)` pairs is order-independent
//! (asserted by `rust/tests/proptest_invariants.rs`).  A batch either
//! applies in full or **poisons** the maintained state: a mid-batch
//! error (e.g. deleting an absent pair) leaves earlier ops applied to
//! the database but pending cache work undone, so `MaintainedCounts`
//! refuses further use after an `apply` error — validate batches (or
//! rebuild on error) rather than relying on partial application.
//!
//! Entity *deletion* is intentionally outside the delta language:
//! removing an entity shrinks a population, which rescales every
//! complete count that ranges over it and cascades through incident
//! links — a rebuild, not a delta.  (Qian et al.'s cross-product
//! derivation, like ours, treats populations as stable dimensions.)
//!
//! The JSON wire format (for `relcount apply --deltas FILE`) is an array
//! of op objects:
//!
//! ```json
//! [
//!   {"op": "insert_link", "rel": 0, "from": 3, "to": 7, "values": [1, 0]},
//!   {"op": "delete_link", "rel": 0, "from": 2, "to": 5},
//!   {"op": "insert_entity", "et": 1, "values": [2]}
//! ]
//! ```

use crate::db::value::Code;
use crate::error::{Error, Result};
use crate::util::json::Json;

/// One fact-level mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DeltaOp {
    /// Insert a relationship tuple (pair must be absent: set semantics).
    InsertLink { rel: usize, from: u32, to: u32, values: Vec<Code> },
    /// Retract a relationship tuple (pair must be present).
    DeleteLink { rel: usize, from: u32, to: u32 },
    /// Append a new entity; the id is assigned on application.  The new
    /// entity starts with no incident links (link it with later ops).
    InsertEntity { et: usize, values: Vec<Code> },
}

impl DeltaOp {
    /// The relationship this op mutates, if any.
    pub fn rel(&self) -> Option<usize> {
        match self {
            DeltaOp::InsertLink { rel, .. } | DeltaOp::DeleteLink { rel, .. } => {
                Some(*rel)
            }
            DeltaOp::InsertEntity { .. } => None,
        }
    }
}

/// An ordered batch of mutations.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeltaBatch {
    pub ops: Vec<DeltaOp>,
}

impl DeltaBatch {
    pub fn new(ops: Vec<DeltaOp>) -> Self {
        DeltaBatch { ops }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Number of link ops (inserts + deletes) touching `rel`.
    pub fn link_ops_on(&self, rel: usize) -> u64 {
        self.ops.iter().filter(|op| op.rel() == Some(rel)).count() as u64
    }

    /// Parse the JSON wire format.
    pub fn parse_json(text: &str) -> Result<DeltaBatch> {
        let json = Json::parse(text)?;
        let arr = json
            .as_arr()
            .ok_or_else(|| Error::Data("delta file: expected a JSON array".into()))?;
        let mut ops = Vec::with_capacity(arr.len());
        for (i, item) in arr.iter().enumerate() {
            ops.push(parse_op(item).map_err(|e| {
                Error::Data(format!("delta file: op {i}: {e}"))
            })?);
        }
        Ok(DeltaBatch { ops })
    }

    /// Load a batch from a file in the JSON wire format.
    pub fn from_file(path: &std::path::Path) -> Result<DeltaBatch> {
        Self::parse_json(&std::fs::read_to_string(path)?)
    }

    /// Emit the JSON wire format.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.ops.iter().map(op_to_json).collect())
    }
}

fn values_of(j: &Json) -> Result<Vec<Code>> {
    match j.get("values") {
        None => Ok(Vec::new()),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| Error::Data("`values` must be an array".into()))?
            .iter()
            .map(|x| {
                x.as_usize()
                    .map(|n| n as Code)
                    .ok_or_else(|| Error::Data("`values` entries must be integers".into()))
            })
            .collect(),
    }
}

fn field_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Data(format!("`{key}` must be a non-negative integer")))
}

fn parse_op(j: &Json) -> Result<DeltaOp> {
    let op = j
        .req("op")?
        .as_str()
        .ok_or_else(|| Error::Data("`op` must be a string".into()))?;
    match op {
        "insert_link" => Ok(DeltaOp::InsertLink {
            rel: field_usize(j, "rel")?,
            from: field_usize(j, "from")? as u32,
            to: field_usize(j, "to")? as u32,
            values: values_of(j)?,
        }),
        "delete_link" => Ok(DeltaOp::DeleteLink {
            rel: field_usize(j, "rel")?,
            from: field_usize(j, "from")? as u32,
            to: field_usize(j, "to")? as u32,
        }),
        "insert_entity" => Ok(DeltaOp::InsertEntity {
            et: field_usize(j, "et")?,
            values: values_of(j)?,
        }),
        other => Err(Error::Data(format!(
            "unknown op {other:?} (insert_link | delete_link | insert_entity)"
        ))),
    }
}

fn op_to_json(op: &DeltaOp) -> Json {
    let vals = |values: &[Code]| {
        Json::Arr(values.iter().map(|&v| Json::num(v as f64)).collect())
    };
    match op {
        DeltaOp::InsertLink { rel, from, to, values } => Json::obj(vec![
            ("op", Json::str("insert_link")),
            ("rel", Json::num(*rel as f64)),
            ("from", Json::num(*from as f64)),
            ("to", Json::num(*to as f64)),
            ("values", vals(values)),
        ]),
        DeltaOp::DeleteLink { rel, from, to } => Json::obj(vec![
            ("op", Json::str("delete_link")),
            ("rel", Json::num(*rel as f64)),
            ("from", Json::num(*from as f64)),
            ("to", Json::num(*to as f64)),
        ]),
        DeltaOp::InsertEntity { et, values } => Json::obj(vec![
            ("op", Json::str("insert_entity")),
            ("et", Json::num(*et as f64)),
            ("values", vals(values)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> DeltaBatch {
        DeltaBatch::new(vec![
            DeltaOp::InsertLink { rel: 0, from: 3, to: 7, values: vec![1, 0] },
            DeltaOp::DeleteLink { rel: 1, from: 2, to: 5 },
            DeltaOp::InsertEntity { et: 1, values: vec![2] },
        ])
    }

    #[test]
    fn json_roundtrip() {
        let b = batch();
        let text = b.to_json().dump();
        let back = DeltaBatch::parse_json(&text).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn link_op_counting() {
        let b = batch();
        assert_eq!(b.len(), 3);
        assert_eq!(b.link_ops_on(0), 1);
        assert_eq!(b.link_ops_on(1), 1);
        assert_eq!(b.link_ops_on(2), 0);
    }

    #[test]
    fn malformed_rejected() {
        assert!(DeltaBatch::parse_json("{}").is_err());
        assert!(DeltaBatch::parse_json(r#"[{"op":"drop_table"}]"#).is_err());
        assert!(DeltaBatch::parse_json(r#"[{"op":"insert_link","rel":0}]"#).is_err());
        assert!(
            DeltaBatch::parse_json(r#"[{"op":"insert_link","rel":0,"from":1,"to":2,"values":["x"]}]"#)
                .is_err()
        );
        // values may be omitted for attribute-less relationships
        let ok = DeltaBatch::parse_json(r#"[{"op":"delete_link","rel":0,"from":1,"to":2}]"#)
            .unwrap();
        assert_eq!(ok.len(), 1);
    }
}
