//! The maintained counting state: a database plus resident lattice
//! caches that stay **exact** under streaming mutation.
//!
//! [`MaintainedCounts`] owns the [`Database`] and the same cache levels
//! the ADAPTIVE strategy plans ([`CountPlan`]): entity marginals,
//! positive ct-tables per lattice point, and complete ct-tables for the
//! complete-planned points.  [`MaintainedCounts::apply`] propagates a
//! [`DeltaBatch`] through every level:
//!
//! - **tables + indexes**: incremental push / swap-remove with in-place
//!   index maintenance ([`Database::insert_link`] & friends);
//! - **positive ct-tables**: one bound join enumeration per (op,
//!   touched point) — the rows through the changed tuple
//!   ([`crate::db::query::positive_chain_delta_ct`]) — applied signed;
//! - **entity marginals**: one row added per entity insert;
//! - **complete ct-tables**: delta-Möbius
//!   ([`crate::ct::mobius::mobius_delta`]) for link churn, and the
//!   population-slice projection for entity inserts (the new entity has
//!   no links yet, so its slice is the point's sub-complete table — the
//!   cached complete projected and divided by the old population — at
//!   the new entity's attribute values with incident axes at ⊥).
//!
//! Per batch, a [`DeltaPolicy`] decides per point whether deltas beat
//! invalidate-and-recount (using the ADAPTIVE sampling estimator);
//! recount-flagged points sit out the per-op loop as *stale* — no delta
//! computation may read them — and are re-joined once at the end.
//! Per-op point work and end-of-batch recounts are sharded across the
//! coordinator's worker pool exactly like counting tasks and merged in
//! task order, so the maintained caches are **bit-identical for every
//! worker count** and to a from-scratch rebuild
//! (`rust/tests/delta_equivalence.rs`).

use std::time::{Duration, Instant};

use crate::coordinator::parallel::serve_one;
use crate::coordinator::pool;
use crate::ct::cttable::CtTable;
use crate::ct::mobius::{mobius_complete, mobius_delta, ChainSource};
use crate::ct::project::project;
use crate::db::catalog::Database;
use crate::db::query::{
    groupby_entity, positive_chain_ct, positive_chain_delta_ct, JoinStats,
};
use crate::db::schema::Schema;
use crate::db::value::Code;
use crate::delta::batch::{DeltaBatch, DeltaOp};
use crate::delta::policy::{DeltaPolicy, MaintenanceDecision, MaintenanceMode};
use crate::error::{Error, Result};
use crate::estimate::plan::CountPlan;
use crate::estimate::sampler::EstimatorConfig;
use crate::estimate::summary::SummaryStats;
use crate::lattice::Lattice;
use crate::learn::search::{learn, LearnedModel, SearchConfig};
use crate::meta::extract::vars_for_entity;
use crate::meta::rvar::RVar;
use crate::metrics::timing::PhaseTimer;
use crate::strategies::adaptive::Adaptive;
use crate::strategies::cache::{digest_caches, CtCache};
use crate::strategies::common::{
    entity_key, lp_key, run_positive_task, LatticeCtx, PositiveTask,
};
use crate::strategies::precount::Precount;
use crate::strategies::traits::{CountingStrategy, StrategyReport};
use crate::strategies::StrategyKind;
use crate::util::fxhash::FxHashSet;

/// Configuration of a [`MaintainedCounts`].
#[derive(Clone, Copy, Debug)]
pub struct MaintainConfig {
    /// Maximum relationship-chain length of the maintained lattice.
    pub max_chain_length: usize,
    /// Which tables stay resident, via the ADAPTIVE planner: `None` =
    /// everything complete (PRECOUNT-level residency), the hybrid budget
    /// = positives only, `Some(0)` = nothing resident (pure
    /// post-counting; deltas are db-only).
    pub mem_budget: Option<u64>,
    /// The cardinality estimator config shared by the residency plan and
    /// the per-batch delta-vs-recount policy.
    pub estimator: EstimatorConfig,
    /// Worker count for per-op point deltas and end-of-batch recounts
    /// (sharded like counting tasks; 1 = sequential).
    pub workers: usize,
    /// Delta-vs-recount decision mode.
    pub mode: MaintenanceMode,
    /// Verify maintained tables after each batch (non-negative counts;
    /// complete totals equal the population product).  Cheap relative to
    /// churn workloads; disable for raw throughput measurement.
    pub verify: bool,
}

impl Default for MaintainConfig {
    fn default() -> Self {
        MaintainConfig {
            max_chain_length: 3,
            mem_budget: None,
            estimator: EstimatorConfig::default(),
            workers: 1,
            mode: MaintenanceMode::Auto,
            verify: true,
        }
    }
}

/// Counters of one [`MaintainedCounts::apply`] call (merge across
/// batches with [`DeltaReport::merge`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaReport {
    pub ops_applied: u64,
    pub link_inserts: u64,
    pub link_deletes: u64,
    pub entity_inserts: u64,
    /// Distinct resident points updated through the delta path.
    pub points_delta_maintained: u64,
    /// Distinct resident points invalidated and re-joined.
    pub points_recounted: u64,
    /// Delta-table rows applied across all resident caches.
    pub cells_touched: u64,
    pub join_stats: JoinStats,
    pub elapsed: Duration,
}

impl DeltaReport {
    pub fn merge(&mut self, other: &DeltaReport) {
        self.ops_applied += other.ops_applied;
        self.link_inserts += other.link_inserts;
        self.link_deletes += other.link_deletes;
        self.entity_inserts += other.entity_inserts;
        self.points_delta_maintained += other.points_delta_maintained;
        self.points_recounted += other.points_recounted;
        self.cells_touched += other.cells_touched;
        self.join_stats.merge(&other.join_stats);
        self.elapsed += other.elapsed;
    }
}

/// A [`ChainSource`] over the maintained caches that refuses to read
/// *stale* (recount-deferred) points: their cached positives lag the
/// database mid-batch, so reads fall back to fresh joins instead.
struct MaintSource<'a> {
    db: &'a Database,
    lattice: &'a Lattice,
    plan: &'a CountPlan,
    cache: &'a CtCache,
    stale: &'a [bool],
    stats: JoinStats,
}

impl ChainSource for MaintSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        if let Some(p) = self.lattice.point(chain) {
            if self.plan.positive_planned(p.id) && !self.stale[p.id] {
                if let Some(full) =
                    self.cache.peek(&lp_key(&p.rels, &p.attr_vars, &p.pops))
                {
                    return project(full, vars);
                }
            }
        }
        positive_chain_ct(self.db, chain, vars, &mut self.stats)
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        if self.plan.marginals {
            if let Some(full) = self.cache.peek(&entity_key(et)) {
                return project(full, vars);
            }
        }
        self.stats.entity_queries += 1;
        groupby_entity(self.db, et, vars)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

/// One point's signed cache deltas for a single link op.
struct PointDelta {
    id: usize,
    positive: CtTable,
    complete: Option<CtTable>,
    stats: JoinStats,
}

/// Compute one point's deltas for the tuple `tid` of `rel` (sign −1 for
/// a delete, evaluated while the tuple exists).  Read-only over shared
/// state, so it runs identically inline or on a pool worker.
#[allow(clippy::too_many_arguments)]
fn compute_link_delta(
    db: &Database,
    lattice: &Lattice,
    plan: &CountPlan,
    positive: &CtCache,
    stale: &[bool],
    rel: usize,
    tid: u32,
    sign: i128,
    id: usize,
) -> Result<PointDelta> {
    let p = &lattice.points[id];
    let mut stats = JoinStats::default();
    let mut dpos =
        positive_chain_delta_ct(db, &p.rels, &p.attr_vars, rel, tid, &mut stats)?;
    if sign < 0 {
        dpos.scale(-1)?;
    }
    let dcmp = if plan.complete_planned(id) {
        let vars = p.all_vars();
        let mut src = MaintSource {
            db,
            lattice,
            plan,
            cache: positive,
            stale,
            stats: JoinStats::default(),
        };
        let mut dg = mobius_delta(
            &mut src,
            &mut |chain, cvars| {
                positive_chain_delta_ct(db, chain, cvars, rel, tid, &mut stats)
            },
            rel,
            &vars,
            &p.pops,
        )?;
        stats.merge(&src.stats);
        if sign < 0 {
            dg.scale(-1)?;
        }
        Some(dg)
    } else {
        None
    };
    Ok(PointDelta { id, positive: dpos, complete: dcmp, stats })
}

/// Database + resident caches, kept exact under mutation.
#[derive(Clone)]
pub struct MaintainedCounts {
    db: Database,
    ctx: LatticeCtx,
    plan: CountPlan,
    cfg: MaintainConfig,
    /// Planned positive lattice ct-tables + entity marginals (same keys
    /// as the strategies': [`lp_key`] / [`entity_key`]).
    positive: CtCache,
    /// Planned complete lattice ct-tables ([`Precount::complete_key`]).
    complete: CtCache,
    /// Per-point cost estimates, computed once (per-op sharding reuses
    /// them instead of rebuilding the vector on every mutation).
    point_costs: Vec<u64>,
    /// First-tier estimator summaries (degree histograms + selectivity
    /// counts), maintained per-op alongside the tables so the
    /// [`DeltaPolicy`] cost model can answer in O(1).  Derived state:
    /// excluded from [`MaintainedCounts::digest`] and rebuilt from the
    /// tables on restore.
    summary: SummaryStats,
    /// Cumulative query counters (build + maintenance + serving).
    join_stats: JoinStats,
    /// Set when a batch failed mid-application: the database holds the
    /// batch's earlier ops but pending cache work never ran, so every
    /// entry point refuses further use (rebuild to recover).
    poisoned: bool,
}

impl MaintainedCounts {
    /// Take ownership of `db` (indexes are built if absent), plan the
    /// residency with the ADAPTIVE planner, and build the planned tables
    /// — sharded over [`MaintainConfig::workers`].
    pub fn build(mut db: Database, cfg: MaintainConfig) -> Result<MaintainedCounts> {
        if !db.has_indexes() {
            db.build_indexes()?;
        }
        let mut cfg = cfg;
        cfg.workers = crate::coordinator::resolve_workers(cfg.workers);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(&db, cfg.max_chain_length, &mut timer)?;
        let plan = CountPlan::build(&db, &ctx.lattice, cfg.estimator, cfg.mem_budget)?;
        let point_costs = ctx.lattice.point_costs();
        let summary = SummaryStats::build(&db);
        let mut m = MaintainedCounts {
            db,
            ctx,
            plan,
            cfg,
            positive: CtCache::new(),
            complete: CtCache::new(),
            point_costs,
            summary,
            join_stats: JoinStats::default(),
            poisoned: false,
        };
        let all_fresh = vec![false; m.ctx.lattice.len()];
        m.recount_positive(&[], true)?;
        let cmp_ids = Adaptive::planned_complete_points(&m.plan);
        m.recount_complete(&cmp_ids, &all_fresh)?;
        Ok(m)
    }

    /// Rebuild a maintained state from persisted parts (the
    /// snapshot-restore path).  The plan is taken verbatim — it was
    /// built from the *initial* database at [`MaintainedCounts::build`]
    /// time and is never re-planned on apply, so re-deriving it from the
    /// mutated tables would diverge from the pre-crash writer.  The
    /// lattice, by contrast, is a pure function of (schema,
    /// max_chain_length) and is rebuilt here.  `db` must already carry
    /// indexes (installed from the snapshot or rebuilt by the loader).
    pub fn restore(
        db: Database,
        cfg: MaintainConfig,
        plan: CountPlan,
        positive: CtCache,
        complete: CtCache,
    ) -> Result<MaintainedCounts> {
        if !db.has_indexes() {
            return Err(Error::Persist {
                section: "db".into(),
                msg: "restore requires a database with indexes installed".into(),
            });
        }
        let mut cfg = cfg;
        cfg.workers = crate::coordinator::resolve_workers(cfg.workers);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(&db, cfg.max_chain_length, &mut timer)?;
        if plan.levels.len() != ctx.lattice.len() {
            return Err(Error::Persist {
                section: "plan".into(),
                msg: format!(
                    "persisted plan covers {} lattice points, schema implies {}",
                    plan.levels.len(),
                    ctx.lattice.len()
                ),
            });
        }
        let point_costs = ctx.lattice.point_costs();
        let summary = SummaryStats::build(&db);
        Ok(MaintainedCounts {
            db,
            ctx,
            plan,
            cfg,
            positive,
            complete,
            point_costs,
            summary,
            join_stats: JoinStats::default(),
            poisoned: false,
        })
    }

    pub fn db(&self) -> &Database {
        &self.db
    }

    pub fn plan(&self) -> &CountPlan {
        &self.plan
    }

    /// The configuration this state was built with (workers resolved).
    pub fn config(&self) -> &MaintainConfig {
        &self.cfg
    }

    /// The resident caches `(positive, complete)` — read-only, for
    /// snapshot serialization.
    pub fn caches(&self) -> (&CtCache, &CtCache) {
        (&self.positive, &self.complete)
    }

    /// The incrementally-maintained first-tier estimator summaries.
    /// Invariant (proptested): always equal to
    /// [`SummaryStats::build`] on the current tables.
    pub fn summary(&self) -> &SummaryStats {
        &self.summary
    }

    /// Merge any pending CSR overlay into the base runs (no-op when
    /// clean — [`MaintainedCounts::apply`] compacts at end-of-batch).
    /// The snapshot writer persists base arrays only, so it compacts
    /// through this before serializing.
    pub fn compact_indexes(&mut self) {
        self.db.compact_indexes();
    }

    pub fn lattice(&self) -> &Lattice {
        &self.ctx.lattice
    }

    /// Exact bytes held in the maintained caches.
    pub fn resident_bytes(&self) -> usize {
        self.positive.bytes() + self.complete.bytes()
    }

    /// Override the delta-vs-recount decision mode (the churn experiment
    /// pits a `DeltaOnly` clone against a `RecountOnly` clone of the
    /// same state).
    pub fn set_mode(&mut self, mode: MaintenanceMode) {
        self.cfg.mode = mode;
    }

    /// Apply one batch: mutate the database and keep every resident
    /// table exact (see the module docs for the data flow).
    ///
    /// On error the state is **poisoned**: the database may hold the
    /// batch's earlier ops while deferred cache work (stale-point
    /// recounts) never ran, so all further use of this instance errors
    /// — rebuild from the tables to recover.  This keeps a failed batch
    /// from silently serving stale counts.
    ///
    /// The serving layer ([`crate::serve::ServeEngine`]) applies batches
    /// to a clone of the last-good state, so there a failure is reported
    /// on publish while the previous generation keeps serving — the
    /// poison never reaches readers.
    pub fn apply(&mut self, batch: &DeltaBatch) -> Result<DeltaReport> {
        self.check_poisoned()?;
        match self.apply_inner(batch) {
            Ok(report) => Ok(report),
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    fn check_poisoned(&self) -> Result<()> {
        if self.poisoned {
            return Err(Error::Strategy(
                "maintained counts poisoned by a failed delta batch; \
                 rebuild from the database tables"
                    .into(),
            ));
        }
        Ok(())
    }

    fn apply_inner(&mut self, batch: &DeltaBatch) -> Result<DeltaReport> {
        let t0 = Instant::now();
        let policy = DeltaPolicy::decide(
            &self.db,
            &self.ctx.lattice,
            &self.plan,
            self.cfg.estimator,
            Some(&self.summary),
            batch,
            self.cfg.mode,
        )?;
        let mut stale: Vec<bool> = policy
            .per_point
            .iter()
            .map(|d| *d == MaintenanceDecision::Recount)
            .collect();

        let mut report = DeltaReport::default();
        let mut delta_points: FxHashSet<usize> = FxHashSet::default();
        let cells_before = self.positive.cells_deltaed + self.complete.cells_deltaed;
        let stats_before = self.join_stats;

        for op in &batch.ops {
            match op {
                DeltaOp::InsertLink { rel, from, to, values } => {
                    let tid = self.db.insert_link(*rel, *from, *to, values)?;
                    self.summary.insert_link(*rel, *from, *to, values);
                    self.link_delta(*rel, tid, 1, &stale, &mut delta_points)?;
                    report.link_inserts += 1;
                }
                DeltaOp::DeleteLink { rel, from, to } => {
                    let tid = self
                        .db
                        .index(*rel)?
                        .lookup(*from, *to)
                        .ok_or_else(|| {
                            Error::Data(format!(
                                "no relationship tuple ({from},{to}) to delete"
                            ))
                        })?;
                    // deltas first, while the tuple still exists
                    self.link_delta(*rel, tid, -1, &stale, &mut delta_points)?;
                    let values = self.db.delete_link(*rel, *from, *to)?;
                    self.summary.delete_link(*rel, *from, *to, &values);
                    report.link_deletes += 1;
                }
                DeltaOp::InsertEntity { et, values } => {
                    self.entity_insert_delta(*et, values, &mut stale, &mut delta_points)?;
                    self.db.insert_entity(*et, values)?;
                    self.summary.insert_entity(*et, values);
                    report.entity_inserts += 1;
                }
            }
            report.ops_applied += 1;
        }

        // End-of-batch CSR compaction: the overlay absorbed this batch's
        // churn; merging it before the recounts means the stale-point
        // joins below — whose costs the DeltaPolicy estimated assuming
        // clean-run speed — and all post-batch serving read contiguous
        // base runs.  No-op on the hash backend.
        self.db.compact_indexes();

        // Invalidate-and-recount the stale points, positives first so
        // the complete Möbius reads fresh projections.
        let pos_ids: Vec<usize> = (0..stale.len())
            .filter(|&id| stale[id] && self.plan.positive_planned(id))
            .collect();
        self.recount_positive(&pos_ids, false)?;
        let all_fresh = vec![false; stale.len()];
        let cmp_ids: Vec<usize> = (0..stale.len())
            .filter(|&id| stale[id] && self.plan.complete_planned(id))
            .collect();
        self.recount_complete(&cmp_ids, &all_fresh)?;
        report.points_recounted = pos_ids.len() as u64;
        report.points_delta_maintained = delta_points.len() as u64;
        report.cells_touched =
            self.positive.cells_deltaed + self.complete.cells_deltaed - cells_before;

        if self.cfg.verify {
            let touched: Vec<usize> =
                delta_points.iter().copied().chain(pos_ids.iter().copied()).collect();
            self.verify_points(&touched)?;
        }
        report.join_stats = JoinStats {
            chain_queries: self.join_stats.chain_queries - stats_before.chain_queries,
            join_steps: self.join_stats.join_steps - stats_before.join_steps,
            rows_enumerated: self.join_stats.rows_enumerated
                - stats_before.rows_enumerated,
            entity_queries: self.join_stats.entity_queries - stats_before.entity_queries,
        };
        report.elapsed = t0.elapsed();
        Ok(report)
    }

    /// Per-op maintenance: compute the signed join-row delta (and
    /// delta-Möbius when a complete table is resident) for every
    /// delta-maintained point touching `rel`, then merge in point-id
    /// order.  With several workers *and* several touched points the
    /// computations shard like counting tasks; otherwise they run
    /// inline (no pool setup on the per-op hot path — thread scopes are
    /// far costlier than a small point's bound join).  The database
    /// must already hold the tuple (`tid` valid) — insert before,
    /// delete after.
    fn link_delta(
        &mut self,
        rel: usize,
        tid: u32,
        sign: i128,
        stale: &[bool],
        delta_points: &mut FxHashSet<usize>,
    ) -> Result<()> {
        let ids: Vec<usize> = self
            .ctx
            .lattice
            .points
            .iter()
            .filter(|p| {
                p.rels.contains(&rel) && self.plan.positive_planned(p.id) && !stale[p.id]
            })
            .map(|p| p.id)
            .collect();
        if ids.is_empty() {
            return Ok(());
        }

        let db = &self.db;
        let lattice = &self.ctx.lattice;
        let plan = &self.plan;
        let positive = &self.positive;
        let results: Vec<Result<PointDelta>> =
            if self.cfg.workers > 1 && ids.len() > 1 {
                let costs: Vec<u64> =
                    ids.iter().map(|&id| self.point_costs[id]).collect();
                let assignment = crate::coordinator::shard::lpt_partition(
                    &costs,
                    self.cfg.workers,
                );
                pool::run_shards(&ids, &assignment, |_, &id| {
                    compute_link_delta(db, lattice, plan, positive, stale, rel, tid, sign, id)
                })
                .results
            } else {
                ids.iter()
                    .map(|&id| {
                        compute_link_delta(
                            db, lattice, plan, positive, stale, rel, tid, sign, id,
                        )
                    })
                    .collect()
            };

        for r in results {
            let d = r?;
            let p = &self.ctx.lattice.points[d.id];
            self.positive.apply_delta(&lp_key(&p.rels, &p.attr_vars, &p.pops), &d.positive)?;
            if let Some(dg) = d.complete {
                self.complete.apply_delta(&Precount::complete_key(p), &dg)?;
            }
            self.join_stats.merge(&d.stats);
            delta_points.insert(d.id);
        }
        Ok(())
    }

    /// Entity-insert maintenance, applied *before* the database mutation
    /// (`n_old` is the pre-insert population).  Positive chain tables
    /// are untouched — a fresh entity has no links, so no join row
    /// involves it.  The marginal gains one row; each resident complete
    /// table over the population gains the new entity's slice, derived
    /// from the cached table itself: `project(G, other vars) / n_old`
    /// scattered at the new attribute values with incident axes at ⊥.
    /// An empty population has no table to project from — those points
    /// flip to recount.
    fn entity_insert_delta(
        &mut self,
        et: usize,
        values: &[Code],
        stale: &mut [bool],
        delta_points: &mut FxHashSet<usize>,
    ) -> Result<()> {
        let schema = self.db.schema.clone();
        if et >= schema.entities.len() {
            return Err(Error::Data(format!("bad entity type {et}")));
        }
        if self.plan.marginals {
            let vars = vars_for_entity(&schema, et);
            let mut row = CtTable::new(&schema, vars)?;
            row.add(values, 1)?;
            self.positive.apply_delta(&entity_key(et), &row)?;
        }
        let n_old = self.db.population(et);
        let incident = |rel: usize| {
            let (a, b) = schema.rel_endpoints(rel);
            a == et || b == et
        };
        for id in 0..self.ctx.lattice.len() {
            if !self.plan.complete_planned(id) || stale[id] {
                continue;
            }
            let p = self.ctx.lattice.points[id].clone();
            if !p.pops.contains(&et) {
                continue;
            }
            if n_old == 0 {
                stale[id] = true; // no slice to derive from; re-join later
                continue;
            }
            let vars = p.all_vars();
            let subvars: Vec<RVar> = vars
                .iter()
                .copied()
                .filter(|v| match v {
                    RVar::EntityAttr { et: e, .. } => *e != et,
                    RVar::RelInd { rel } | RVar::RelAttr { rel, .. } => !incident(*rel),
                })
                .collect();
            let key = Precount::complete_key(&p);
            let full = self.complete.peek(&key).ok_or_else(|| {
                Error::Strategy("complete ct missing from maintained cache".into())
            })?;
            let mut sub = project(full, &subvars)?;
            sub.divide_exact(n_old as i128)?;
            // scatter the slice into the full key space: new attribute
            // values fixed, incident indicators F / rel attrs N/A (= 0)
            let mut dg = CtTable::new(&schema, vars.clone())?;
            let mut base: u128 = 0;
            let mut maps: Vec<(u128, u128, u128)> = Vec::new();
            for (j, v) in vars.iter().enumerate() {
                let dst = dg.stride(j);
                match v {
                    RVar::EntityAttr { et: e, attr } if *e == et => {
                        let val = *values.get(*attr).ok_or_else(|| {
                            Error::Data(format!("entity row arity < attr {attr}"))
                        })?;
                        base += val as u128 * dst;
                    }
                    RVar::RelInd { rel } | RVar::RelAttr { rel, .. }
                        if incident(*rel) => {} // ⊥ / N/A = 0
                    _ => {
                        let sp = sub.var_pos(v)?;
                        maps.push((sub.stride(sp), sub.dims[sp] as u128, dst));
                    }
                }
            }
            for (k, c) in sub.iter_keys() {
                let mut keyv = base;
                for &(ss, sd, ds) in &maps {
                    keyv += ((k / ss) % sd) * ds;
                }
                dg.add_key(keyv, c)?;
            }
            self.complete.apply_delta(&key, &dg)?;
            delta_points.insert(id);
        }
        Ok(())
    }

    /// Re-join the positive tables of `ids` (sharded, merged in task
    /// order).  `initial` marks the build-time fill, which also fills
    /// the entity marginals.
    fn recount_positive(&mut self, ids: &[usize], initial: bool) -> Result<()> {
        let tasks: Vec<PositiveTask> = if initial {
            Adaptive::planned_positive_tasks(&self.db, &self.plan)
        } else {
            ids.iter().map(|&id| PositiveTask::Point(id)).collect()
        };
        if tasks.is_empty() {
            return Ok(());
        }
        let costs: Vec<u64> = tasks
            .iter()
            .map(|t| match *t {
                PositiveTask::Entity(et) => self.db.entities[et].len() as u64,
                PositiveTask::Point(id) => self.point_costs[id],
            })
            .collect();
        let assignment =
            crate::coordinator::shard::lpt_partition(&costs, self.cfg.workers.max(1));
        let db = &self.db;
        let ctx = &self.ctx;
        let run = pool::run_shards(&tasks, &assignment, |_, &task| {
            let mut stats = JoinStats::default();
            let out = run_positive_task(db, ctx, task, &mut stats)?;
            Ok((out, stats))
        });
        for r in run.results {
            let ((key, table), stats) = r?;
            self.join_stats.merge(&stats);
            self.positive.insert(key, table);
        }
        Ok(())
    }

    /// Re-run the per-point Möbius for `ids` over the (fresh) positive
    /// cache (sharded, merged in task order).
    fn recount_complete(&mut self, ids: &[usize], stale: &[bool]) -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        let costs: Vec<u64> = ids.iter().map(|&id| self.point_costs[id]).collect();
        let assignment =
            crate::coordinator::shard::lpt_partition(&costs, self.cfg.workers.max(1));
        let db = &self.db;
        let lattice = &self.ctx.lattice;
        let plan = &self.plan;
        let positive = &self.positive;
        let run = pool::run_shards(ids, &assignment, |_, &id| {
            let p = &lattice.points[id];
            let mut src = MaintSource {
                db,
                lattice,
                plan,
                cache: positive,
                stale,
                stats: JoinStats::default(),
            };
            let ct = mobius_complete(&mut src, &p.all_vars(), &p.pops)?;
            Ok((id, ct, src.stats))
        });
        for r in run.results {
            let (id, ct, stats) = r?;
            self.join_stats.merge(&stats);
            let p = &self.ctx.lattice.points[id];
            self.complete.insert(Precount::complete_key(p), ct);
        }
        Ok(())
    }

    /// Post-batch invariants on the touched points: counts stay
    /// non-negative everywhere, and complete totals equal the (current)
    /// population product — a delta bug fails loudly here, not in a
    /// downstream score.
    fn verify_points(&self, ids: &[usize]) -> Result<()> {
        for &id in ids {
            let p = &self.ctx.lattice.points[id];
            if self.plan.positive_planned(id) {
                if let Some(t) = self.positive.peek(&lp_key(&p.rels, &p.attr_vars, &p.pops))
                {
                    t.assert_counts_nonnegative()?;
                }
            }
            if self.plan.complete_planned(id) {
                if let Some(t) = self.complete.peek(&Precount::complete_key(p)) {
                    t.assert_counts_nonnegative()?;
                    let want: i128 =
                        p.pops.iter().map(|&e| self.db.population(e) as i128).product();
                    let got = t.total()?;
                    if got != want {
                        return Err(Error::Ct(format!(
                            "maintained complete ct for point {:?} totals {got}, \
                             population product is {want}",
                            p.rels
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Serve one family's complete ct-table from the maintained caches —
    /// the identical code path the parallel coordinator's ADAPTIVE mode
    /// uses, so maintained serving is bit-identical to a fresh strategy
    /// over the same data.
    pub fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        self.check_poisoned()?;
        let served = serve_one(
            &self.db,
            &self.ctx.lattice,
            &self.positive,
            &self.complete,
            StrategyKind::Adaptive,
            Some(&self.plan),
            vars,
            ctx_pops,
        )?;
        self.join_stats.merge(&served.stats);
        Ok(served.ct)
    }

    /// Structure learning over the maintained caches (counts come from
    /// [`MaintainedCounts::ct_for_family`]; identical counts give
    /// bit-identical models and BDeu scores to any fresh strategy).
    pub fn learn(&mut self, cfg: SearchConfig) -> Result<LearnedModel> {
        let db = self.db.clone();
        let mut view = MaintainedStrategy { inner: self };
        learn(&db, &mut view, cfg)
    }

    /// Deterministic digest of every resident table (keys and rows in
    /// sorted order) — the churn experiment's cross-run/bit-identity
    /// witness.  Shares its algorithm with
    /// [`crate::serve::Generation::digest`], so a snapshot taken from
    /// this state hashes identically to it.
    pub fn digest(&self) -> u64 {
        digest_caches(&[(0u8, &self.positive), (1u8, &self.complete)])
    }

    /// Freeze the current state into an immutable serving generation
    /// (deep copy of the database and every resident table).  Errors on
    /// a poisoned instance: a half-applied batch must never be
    /// published.  The serving layer ([`crate::serve`]) publishes these
    /// through an epoch-versioned [`crate::serve::SnapshotStore`].
    pub fn snapshot(&self, epoch: u64) -> Result<crate::serve::Generation> {
        self.check_poisoned()?;
        Ok(crate::serve::Generation::from_parts(
            epoch,
            self.db.clone(),
            self.ctx.lattice.clone(),
            self.plan.clone(),
            self.positive.clone(),
            self.complete.clone(),
        ))
    }
}

/// [`CountingStrategy`] view over a [`MaintainedCounts`], so the learner
/// and the differential tests drive maintained counts through the same
/// interface as the fresh strategies.
pub struct MaintainedStrategy<'a> {
    pub inner: &'a mut MaintainedCounts,
}

impl CountingStrategy for MaintainedStrategy<'_> {
    fn name(&self) -> &'static str {
        "MAINTAINED"
    }

    fn prepare(&mut self) -> Result<()> {
        Ok(()) // the maintained caches are always ready
    }

    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        self.inner.ct_for_family(vars, ctx_pops)
    }

    fn report(&self) -> StrategyReport {
        StrategyReport {
            name: "MAINTAINED".into(),
            join_stats: self.inner.join_stats,
            cache_bytes: self.inner.resident_bytes(),
            planned_positive: self.inner.plan.planned_positive_count(),
            planned_complete: self.inner.plan.planned_complete_count(),
            ..Default::default()
        }
    }

    fn cache_digest(&self) -> u64 {
        self.inner.digest()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    #[test]
    fn build_matches_fresh_counts() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db.clone(), MaintainConfig::default()).unwrap();
        let ct = m.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        assert_eq!(ct.n_rows(), brute.n_rows());
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
    }

    #[test]
    fn link_churn_stays_exact() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        let batch = DeltaBatch::new(vec![
            DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 },
            DeltaOp::InsertLink { rel: 0, from: 11, to: 0, values: vec![2, 1] },
            DeltaOp::InsertLink { rel: 1, from: 1, to: 0, values: vec![3] },
        ]);
        let rep = m.apply(&batch).unwrap();
        assert_eq!(rep.ops_applied, 3);
        assert_eq!(rep.link_inserts, 2);
        assert_eq!(rep.link_deletes, 1);
        assert!(rep.cells_touched > 0);
        // maintained serving equals brute force over the mutated data
        let brute = brute_force_complete(m.db(), &family(), &[0, 1]).unwrap();
        let ct = m.ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(ct.n_rows(), brute.n_rows());
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c, "{v:?}");
        }
    }

    #[test]
    fn entity_insert_slice_is_exact() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        let batch = DeltaBatch::new(vec![
            DeltaOp::InsertEntity { et: 1, values: vec![2] },
            DeltaOp::InsertLink { rel: 0, from: 3, to: 19, values: vec![0, 2] },
        ]);
        let rep = m.apply(&batch).unwrap();
        assert_eq!(rep.entity_inserts, 1);
        assert_eq!(m.db().population(1), 20);
        let brute = brute_force_complete(m.db(), &family(), &[0, 1]).unwrap();
        let ct = m.ct_for_family(&family(), &[0, 1]).unwrap();
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c, "{v:?}");
        }
    }

    #[test]
    fn delete_then_reinsert_roundtrips_digest() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        let d0 = m.digest();
        m.apply(&DeltaBatch::new(vec![DeltaOp::DeleteLink {
            rel: 0,
            from: 0,
            to: 0,
        }]))
        .unwrap();
        assert_ne!(m.digest(), d0);
        // the fixture's (0,0) RA tuple carries capability 4-1=3, salary HIGH
        m.apply(&DeltaBatch::new(vec![DeltaOp::InsertLink {
            rel: 0,
            from: 0,
            to: 0,
            values: vec![3, 2],
        }]))
        .unwrap();
        assert_eq!(m.digest(), d0);
    }

    #[test]
    fn workers_are_interchangeable() {
        let db = university_db();
        let mut a = MaintainedCounts::build(
            db.clone(),
            MaintainConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        let mut b = MaintainedCounts::build(
            db,
            MaintainConfig { workers: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(a.digest(), b.digest());
        let batch = DeltaBatch::new(vec![
            DeltaOp::DeleteLink { rel: 0, from: 1, to: 1 },
            DeltaOp::InsertLink { rel: 0, from: 1, to: 2, values: vec![4, 0] },
            DeltaOp::InsertEntity { et: 0, values: vec![1] },
        ]);
        a.apply(&batch).unwrap();
        b.apply(&batch).unwrap();
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn summary_tracks_tables_through_batches() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        assert_eq!(*m.summary(), SummaryStats::build(m.db()));
        let batch = DeltaBatch::new(vec![
            DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 },
            DeltaOp::InsertLink { rel: 0, from: 11, to: 0, values: vec![2, 1] },
            DeltaOp::InsertEntity { et: 1, values: vec![2] },
        ]);
        m.apply(&batch).unwrap();
        assert_eq!(*m.summary(), SummaryStats::build(m.db()));
    }

    #[test]
    fn recount_mode_matches_delta_mode() {
        let db = university_db();
        let batch = DeltaBatch::new(vec![
            DeltaOp::DeleteLink { rel: 1, from: 0, to: 0 },
            DeltaOp::InsertLink { rel: 1, from: 0, to: 1, values: vec![2] },
        ]);
        let mut d = MaintainedCounts::build(
            db.clone(),
            MaintainConfig { mode: MaintenanceMode::DeltaOnly, ..Default::default() },
        )
        .unwrap();
        let mut r = MaintainedCounts::build(
            db,
            MaintainConfig { mode: MaintenanceMode::RecountOnly, ..Default::default() },
        )
        .unwrap();
        let dr = d.apply(&batch).unwrap();
        let rr = r.apply(&batch).unwrap();
        assert_eq!(d.digest(), r.digest());
        assert_eq!(dr.points_recounted, 0);
        assert!(rr.points_recounted > 0);
        assert_eq!(rr.points_delta_maintained, 0);
    }

    #[test]
    fn bad_ops_fail_loudly_and_poison() {
        let db = university_db();
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        let dup = DeltaBatch::new(vec![DeltaOp::InsertLink {
            rel: 0,
            from: 0,
            to: 0,
            values: vec![0, 0],
        }]);
        assert!(m.apply(&dup).is_err());
        // a failed batch poisons the state: no further serving or
        // application (the db may hold earlier ops of the failed batch)
        assert!(m.ct_for_family(&family(), &[0, 1]).is_err());
        let fine = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        assert!(m.apply(&fine).is_err());
    }

    #[test]
    fn mid_batch_failure_poisons_instead_of_serving_stale() {
        // op 1 mutates the db; op 2 fails.  The maintained state must
        // refuse to serve rather than return counts missing op 1.
        let db = university_db();
        let mut m = MaintainedCounts::build(
            db,
            MaintainConfig { mode: MaintenanceMode::RecountOnly, ..Default::default() },
        )
        .unwrap();
        let batch = DeltaBatch::new(vec![
            DeltaOp::InsertLink { rel: 0, from: 11, to: 0, values: vec![2, 1] },
            DeltaOp::DeleteLink { rel: 0, from: 11, to: 18 }, // absent pair
        ]);
        assert!(m.apply(&batch).is_err());
        assert!(m.ct_for_family(&family(), &[0, 1]).is_err());
        assert!(m.learn(crate::learn::search::SearchConfig::default()).is_err());
    }
}
