//! Delta maintenance: resident count caches that stay exact under
//! streaming fact inserts and retractions.
//!
//! The paper's pre-vs-post counting trade-off assumes a static database;
//! a production deployment sees facts arrive and retract after the
//! caches are built.  This subsystem generalizes the ingestion-time
//! incremental counters ([`crate::pipeline::incremental`], chain length
//! 1, positive-only, append-only) into full cache maintenance:
//!
//! - [`batch`] — the [`DeltaBatch`] mutation language (link insert /
//!   link delete / entity insert) and its JSON wire format
//!   (`relcount apply --deltas FILE`);
//! - [`policy`] — the per-point delta-vs-recount decision, costed with
//!   the same sampling estimator that drives the ADAPTIVE strategy;
//! - [`maintain`] — [`MaintainedCounts`]: database + planned caches,
//!   kept bit-identical to a from-scratch rebuild through per-tuple
//!   join-row deltas, the delta-Möbius
//!   ([`crate::ct::mobius::mobius_delta`]) and entity-slice projection,
//!   with work sharded over the coordinator's pool (`--workers`).
//!
//! The correctness contract is differential: after arbitrary seeded
//! insert/delete sequences, maintained counts — and the models and BDeu
//! scores learned from them — are bit-identical to every fresh strategy
//! on the mutated data, sequentially and under 4 workers
//! (`rust/tests/delta_equivalence.rs`).  The churn workload this opens
//! is measured by `relcount exp churn` and `benches/delta_churn.rs`
//! (see EXPERIMENTS.md §E10).

pub mod batch;
pub mod maintain;
pub mod policy;

pub use batch::{DeltaBatch, DeltaOp};
pub use maintain::{DeltaReport, MaintainConfig, MaintainedCounts, MaintainedStrategy};
pub use policy::{DeltaPolicy, MaintenanceDecision, MaintenanceMode};
