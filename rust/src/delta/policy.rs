//! Per-point maintenance policy: delta or invalidate-and-recount.
//!
//! Applying a batch to a resident lattice point costs either
//!
//! - **delta**: one bound join enumeration per touching link op —
//!   roughly the chain's rows-per-tuple fan-out (estimated join
//!   cardinality over the mutated relationship's size), doubled per
//!   extra relationship axis for the delta-Möbius's subset scatter — or
//! - **recount**: one full chain join (the estimated join cardinality),
//!   plus the complete table's Möbius when one is resident.
//!
//! Both sides come from the same seeded sampling estimator that drives
//! the ADAPTIVE strategy ([`crate::estimate`]), so the decision is a
//! pure function of `(database, lattice, batch shape, estimator
//! config)` and identical across worker counts.  Low-churn batches pick
//! delta; a batch that rewrites most of a relationship flips its points
//! to recount.

use crate::db::catalog::Database;
use crate::delta::batch::DeltaBatch;
use crate::error::Result;
use crate::estimate::plan::CountPlan;
use crate::estimate::sampler::{EstimatorConfig, JoinSampler};
use crate::estimate::summary::SummaryStats;
use crate::lattice::Lattice;

/// How a batch maintains one resident lattice point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaintenanceDecision {
    /// Apply per-op join-row deltas (and delta-Möbius when a complete
    /// table is resident).
    Delta,
    /// Mark stale, apply mutations, re-run the point's JOIN (and Möbius)
    /// once at the end of the batch.
    Recount,
}

/// Forced or estimated decision mode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MaintenanceMode {
    /// Decide per point from estimated costs (the default).
    #[default]
    Auto,
    /// Always delta-maintain (except where a delta is undefined, e.g. an
    /// entity insert into an empty population).
    DeltaOnly,
    /// Always invalidate-and-recount — the baseline the churn experiment
    /// compares against.
    RecountOnly,
}

impl MaintenanceMode {
    pub fn parse(s: &str) -> Option<MaintenanceMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(MaintenanceMode::Auto),
            "delta" => Some(MaintenanceMode::DeltaOnly),
            "recount" => Some(MaintenanceMode::RecountOnly),
            _ => None,
        }
    }

    /// The CLI token [`MaintenanceMode::parse`] accepts back (also the
    /// snapshot manifest's serialization of the mode).
    pub fn name(&self) -> &'static str {
        match self {
            MaintenanceMode::Auto => "auto",
            MaintenanceMode::DeltaOnly => "delta",
            MaintenanceMode::RecountOnly => "recount",
        }
    }
}

/// The per-point decisions for one batch.
#[derive(Clone, Debug)]
pub struct DeltaPolicy {
    /// Decision per lattice point id (points untouched by the batch are
    /// `Delta` — there is no work either way).
    pub per_point: Vec<MaintenanceDecision>,
}

impl DeltaPolicy {
    /// Decide every point for `batch` under `mode`.
    ///
    /// `summary` is the incrementally-maintained first-tier estimator
    /// (see [`crate::estimate::summary`]); when present and
    /// `cfg.summary_bound > 0` the Auto cost model answers cheap chains
    /// from it in O(1) instead of sampling — estimation sits on the
    /// serve hot path, so every avoided walk is throughput.  At bound 0
    /// the decisions are bit-identical with or without a summary.
    pub fn decide(
        db: &Database,
        lattice: &Lattice,
        plan: &CountPlan,
        cfg: EstimatorConfig,
        summary: Option<&SummaryStats>,
        batch: &DeltaBatch,
        mode: MaintenanceMode,
    ) -> Result<DeltaPolicy> {
        let n = lattice.len();
        let mut per_point = vec![MaintenanceDecision::Delta; n];
        match mode {
            MaintenanceMode::DeltaOnly => {}
            MaintenanceMode::RecountOnly => {
                for (id, d) in per_point.iter_mut().enumerate() {
                    if plan.positive_planned(id) && touches(lattice, batch, id) {
                        *d = MaintenanceDecision::Recount;
                    }
                }
            }
            MaintenanceMode::Auto => {
                let sampler = JoinSampler::new(db, cfg);
                for (id, d) in per_point.iter_mut().enumerate() {
                    if !plan.positive_planned(id) || !touches(lattice, batch, id) {
                        continue;
                    }
                    let p = &lattice.points[id];
                    let est = sampler.chain_cardinality_with(&p.rels, summary)?;
                    let ops: u64 = p.rels.iter().map(|&r| batch.link_ops_on(r)).sum();
                    // rows visited per bound tuple ~ join rows / rel size
                    let rel_rows: f64 = p
                        .rels
                        .iter()
                        .map(|&r| db.rels[r].len().max(1) as f64)
                        .fold(f64::INFINITY, f64::min);
                    let per_op = (est.value / rel_rows).max(1.0)
                        * (1u64 << (p.rels.len() - 1)) as f64;
                    let delta_cost = ops as f64 * per_op;
                    let mut recount_cost = est.value.max(1.0);
                    if plan.complete_planned(id) {
                        recount_cost += plan.estimates[id].est_complete_rows;
                    }
                    if delta_cost > recount_cost {
                        *d = MaintenanceDecision::Recount;
                    }
                }
            }
        }
        Ok(DeltaPolicy { per_point })
    }

    pub fn recount_count(&self) -> u64 {
        self.per_point
            .iter()
            .filter(|d| **d == MaintenanceDecision::Recount)
            .count() as u64
    }
}

/// Whether any link op of `batch` touches lattice point `id`.
fn touches(lattice: &Lattice, batch: &DeltaBatch, id: usize) -> bool {
    lattice.points[id].rels.iter().any(|&r| batch.link_ops_on(r) > 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::delta::batch::DeltaOp;

    fn setup() -> (Database, Lattice, CountPlan) {
        let db = university_db();
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        let plan =
            CountPlan::build(&db, &lattice, EstimatorConfig::default(), None).unwrap();
        (db, lattice, plan)
    }

    #[test]
    fn small_batches_pick_delta_heavy_batches_recount() {
        let (db, lattice, plan) = setup();
        let one = DeltaBatch::new(vec![DeltaOp::DeleteLink {
            rel: 0,
            from: 0,
            to: 0,
        }]);
        let p = DeltaPolicy::decide(
            &db,
            &lattice,
            &plan,
            EstimatorConfig::default(),
            None,
            &one,
            MaintenanceMode::Auto,
        )
        .unwrap();
        assert_eq!(p.recount_count(), 0, "{:?}", p.per_point);

        // a batch rewriting rel 0 many times over should flip its points
        let ops: Vec<DeltaOp> = (0..2000)
            .map(|i| DeltaOp::DeleteLink { rel: 0, from: i % 12, to: i % 19 })
            .collect();
        let heavy = DeltaBatch::new(ops);
        let p = DeltaPolicy::decide(
            &db,
            &lattice,
            &plan,
            EstimatorConfig::default(),
            None,
            &heavy,
            MaintenanceMode::Auto,
        )
        .unwrap();
        assert!(p.recount_count() > 0, "{:?}", p.per_point);
    }

    #[test]
    fn forced_modes() {
        let (db, lattice, plan) = setup();
        let b = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let d = DeltaPolicy::decide(
            &db,
            &lattice,
            &plan,
            EstimatorConfig::default(),
            None,
            &b,
            MaintenanceMode::DeltaOnly,
        )
        .unwrap();
        assert_eq!(d.recount_count(), 0);
        let r = DeltaPolicy::decide(
            &db,
            &lattice,
            &plan,
            EstimatorConfig::default(),
            None,
            &b,
            MaintenanceMode::RecountOnly,
        )
        .unwrap();
        // rel 0 sits in points {0} and {0,1}
        assert_eq!(r.recount_count(), 2);
        assert_eq!(MaintenanceMode::parse("recount"), Some(MaintenanceMode::RecountOnly));
        assert_eq!(MaintenanceMode::parse("nope"), None);
    }
}
