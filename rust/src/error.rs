//! Library-wide error type (hand-rolled `Display`/`Error` impls — the
//! crate builds with zero external dependencies, so no derive macros).

use std::fmt;

/// Errors surfaced by the relcount library.
#[derive(Debug)]
pub enum Error {
    /// A schema reference (entity/relationship/attribute id) is invalid.
    Schema(String),

    /// Data violates the schema (bad code, out-of-range id, ...).
    Data(String),

    /// A columnar structure would outgrow its u32 address space (e.g. a
    /// CSR offset column asked to cover more than `u32::MAX` tuples).
    Capacity { what: String, needed: u64 },

    /// A contingency-table operation was applied to incompatible tables
    /// or the value space overflows the flat-key width.
    Ct(String),

    /// A counting strategy could not serve a family (e.g. no covering
    /// lattice point).
    Strategy(String),

    /// Structure-learning error.
    Learn(String),

    /// PJRT / XLA runtime error.
    Runtime(String),

    /// Artifact manifest problems.
    Manifest(String),

    /// The streaming pipeline failed (channel closed, shard mismatch...).
    Pipeline(String),

    /// Wall-clock budget exceeded (mirrors the paper's 100-minute Slurm
    /// limit that ONDEMAND blows on IMDb / Visual Genome).
    Timeout { phase: String, elapsed_ms: u64 },

    /// On-disk persistence failure (snapshot section or WAL record
    /// failed checksum/format verification), tagged with the section
    /// that failed so fault-injection tests and operators can pinpoint
    /// the corrupt artifact.
    Persist { section: String, msg: String },

    /// The sharded-count router could not assemble an exact answer: a
    /// shard connection died, a reconstructed partial table failed its
    /// digest check, or shards disagreed on epoch/state.
    Route(String),

    /// Generation replication failed (leader stream ended abnormally or
    /// a follower's published epoch digest diverged from the leader's).
    Replicate(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Schema(m) => write!(f, "schema error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Capacity { what, needed } => {
                write!(f, "capacity error: {what} needs {needed} entries, over the u32 limit")
            }
            Error::Ct(m) => write!(f, "ct-table error: {m}"),
            Error::Strategy(m) => write!(f, "strategy error: {m}"),
            Error::Learn(m) => write!(f, "learn error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Timeout { phase, elapsed_ms } => {
                write!(f, "timeout after {elapsed_ms} ms during {phase}")
            }
            Error::Persist { section, msg } => {
                write!(f, "persist error in section '{section}': {msg}")
            }
            Error::Route(m) => write!(f, "route error: {m}"),
            Error::Replicate(m) => write!(f, "replicate error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True if this error is the bench-harness timeout.
    pub fn is_timeout(&self) -> bool {
        matches!(self, Error::Timeout { .. })
    }

    /// Construct a [`Error::Persist`] naming the on-disk section.
    pub fn persist(section: impl Into<String>, msg: impl Into<String>) -> Error {
        Error::Persist { section: section.into(), msg: msg.into() }
    }

    /// Guard for u32-addressed structures (tuple ids, CSR offset
    /// columns): error when `needed` entries would overflow the u32 id
    /// space.  The columnar engine accumulates offsets in `u32`, so an
    /// unchecked build past `u32::MAX` triples would wrap silently and
    /// corrupt every run boundary; callers guard *before* growing.
    pub fn check_u32_capacity(what: &str, needed: u64) -> Result<()> {
        if needed > u32::MAX as u64 {
            return Err(Error::Capacity { what: what.into(), needed });
        }
        Ok(())
    }

    /// The section name of a persistence error, if this is one.
    pub fn persist_section(&self) -> Option<&str> {
        match self {
            Error::Persist { section, .. } => Some(section),
            _ => None,
        }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeout_detection() {
        let e = Error::Timeout { phase: "positive".into(), elapsed_ms: 12 };
        assert!(e.is_timeout());
        assert!(!Error::Schema("x".into()).is_timeout());
        assert!(e.to_string().contains("positive"));
    }

    #[test]
    fn persist_errors_name_their_section() {
        let e = Error::persist("caches", "checksum mismatch");
        assert_eq!(e.persist_section(), Some("caches"));
        assert!(e.to_string().contains("'caches'"));
        assert!(e.to_string().contains("checksum mismatch"));
        assert_eq!(Error::Schema("x".into()).persist_section(), None);
    }

    #[test]
    fn capacity_errors_report_the_demand() {
        let e = Error::Capacity { what: "csr fwd offsets".into(), needed: 1 << 33 };
        assert!(e.to_string().contains("csr fwd offsets"));
        assert!(e.to_string().contains(&(1u64 << 33).to_string()));
    }

    #[test]
    fn u32_capacity_boundary() {
        // exactly u32::MAX entries fit (ids 0..=u32::MAX-1, len
        // representable); one more would wrap — no allocation involved
        assert!(Error::check_u32_capacity("ids", u32::MAX as u64).is_ok());
        let e = Error::check_u32_capacity("ids", u32::MAX as u64 + 1).unwrap_err();
        assert!(matches!(e, Error::Capacity { needed, .. } if needed == u32::MAX as u64 + 1));
    }

    #[test]
    fn io_conversion() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone"));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }
}
