//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the relcount library.
#[derive(Error, Debug)]
pub enum Error {
    /// A schema reference (entity/relationship/attribute id) is invalid.
    #[error("schema error: {0}")]
    Schema(String),

    /// Data violates the schema (bad code, out-of-range id, ...).
    #[error("data error: {0}")]
    Data(String),

    /// A contingency-table operation was applied to incompatible tables
    /// or the value space overflows the flat-key width.
    #[error("ct-table error: {0}")]
    Ct(String),

    /// A counting strategy could not serve a family (e.g. no covering
    /// lattice point).
    #[error("strategy error: {0}")]
    Strategy(String),

    /// Structure-learning error.
    #[error("learn error: {0}")]
    Learn(String),

    /// PJRT / XLA runtime error.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact manifest problems.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// The streaming pipeline failed (channel closed, shard mismatch...).
    #[error("pipeline error: {0}")]
    Pipeline(String),

    /// Wall-clock budget exceeded (mirrors the paper's 100-minute Slurm
    /// limit that ONDEMAND blows on IMDb / Visual Genome).
    #[error("timeout after {elapsed_ms} ms during {phase}")]
    Timeout { phase: String, elapsed_ms: u64 },

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// True if this error is the bench-harness timeout.
    pub fn is_timeout(&self) -> bool {
        matches!(self, Error::Timeout { .. })
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeout_detection() {
        let e = Error::Timeout { phase: "positive".into(), elapsed_ms: 12 };
        assert!(e.is_timeout());
        assert!(!Error::Schema("x".into()).is_timeout());
        assert!(e.to_string().contains("positive"));
    }

    #[test]
    fn io_conversion() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
