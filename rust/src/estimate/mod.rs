//! Cheap, deterministic cardinality estimation for the ADAPTIVE counting
//! planner.
//!
//! The paper's HYBRID strategy hard-codes one global answer to the
//! pre-vs-post counting trade-off (pre-count positives, post-count
//! negatives).  Karan et al. (2018) observe that the optimal counting
//! method varies *per query* with data characteristics; acting on that
//! requires knowing — before any table is built — roughly how large each
//! lattice point's join result and ct-tables will be.  This module
//! supplies those numbers:
//!
//! - [`sampler`] — wander-join-style random walks over the relationship
//!   FK indexes ([`crate::db::index::RelIx`], either backend), giving unbiased
//!   join-chain cardinality estimates with declared error bounds, seeded
//!   via [`crate::util::rng::Rng`] for bit-reproducible plans.  Chains
//!   cheap enough to enumerate outright are counted exactly.
//! - [`plan`] — the [`plan::CountPlan`]: per-lattice-point estimates of
//!   join cost, ct-table rows and resident bytes, folded into a greedy
//!   knapsack fill of an explicit `--mem-budget`.  Each point is planned
//!   at one of three levels (on-demand / positive pre-count / complete
//!   pre-count), spanning the whole ONDEMAND → HYBRID → PRECOUNT
//!   spectrum from a single strategy.
//!
//! Estimation never touches counting correctness: the ADAPTIVE strategy
//! (`strategies::adaptive`) produces bit-identical ct-tables at every
//! plan — estimates only decide *where* counts are computed.

pub mod plan;
pub mod sampler;

pub use plan::{CountPlan, PlanLevel, PointEstimate};
pub use sampler::{Estimate, EstimatorConfig, JoinSampler};
