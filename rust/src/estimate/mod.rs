//! Cheap, deterministic cardinality estimation for the ADAPTIVE counting
//! planner.
//!
//! The paper's HYBRID strategy hard-codes one global answer to the
//! pre-vs-post counting trade-off (pre-count positives, post-count
//! negatives).  Karan et al. (2018) observe that the optimal counting
//! method varies *per query* with data characteristics; acting on that
//! requires knowing — before any table is built — roughly how large each
//! lattice point's join result and ct-tables will be.  This module
//! supplies those numbers:
//!
//! - [`sampler`] — wander-join-style random walks over the relationship
//!   FK indexes ([`crate::db::index::RelIx`], either backend), giving unbiased
//!   join-chain cardinality estimates with declared error bounds, seeded
//!   via [`crate::util::rng::Rng`] for bit-reproducible plans.  Chains
//!   cheap enough to enumerate outright are counted exactly.
//! - [`summary`] — the O(1) first tier: per-relationship degree
//!   histograms and per-attribute-value selectivity counts, maintained
//!   incrementally by the delta path.  The sampler consults it first and
//!   refines by walking only when the summary's declared band is wider
//!   than [`sampler::EstimatorConfig::summary_bound`] allows; at bound 0
//!   (the default) the tier is off and plans are bit-identical to the
//!   sampler-only path.
//! - [`plan`] — the [`plan::CountPlan`]: per-lattice-point estimates of
//!   join cost, ct-table rows and resident bytes, folded into a greedy
//!   knapsack fill of an explicit `--mem-budget`.  Each point is planned
//!   at one of three levels (on-demand / positive pre-count / complete
//!   pre-count), spanning the whole ONDEMAND → HYBRID → PRECOUNT
//!   spectrum from a single strategy.
//! - [`quality`] — the estimator lab: q-error distributions and
//!   plan-regret against oracle counts for every lattice point, per
//!   preset (`relcount exp estimator`, `BENCH_estimator.json`, gated by
//!   CI's `estimator-smoke`).
//!
//! Estimation never touches counting correctness: the ADAPTIVE strategy
//! (`strategies::adaptive`) produces bit-identical ct-tables at every
//! plan — estimates only decide *where* counts are computed.

pub mod plan;
pub mod quality;
pub mod sampler;
pub mod summary;

pub use plan::{CountPlan, PlanLevel, PointEstimate};
pub use quality::{QualityMode, QualityReport};
pub use sampler::{Estimate, EstimatorConfig, JoinSampler};
pub use summary::SummaryStats;
