//! The [`CountPlan`]: a budgeted, cost-ranked pre-counting plan over the
//! relationship lattice.
//!
//! For every lattice point the planner estimates
//!
//! - the **join cost** of building its positive ct-table (the estimated
//!   INNER-JOIN cardinality, from [`crate::estimate::sampler`]),
//! - the **rows and resident bytes** of its positive and complete
//!   ct-tables (value-space caps intersected with the join estimate),
//! - its **reuse frequency** — how many lattice points' Möbius Joins
//!   project from it (the number of superset chains, itself included;
//!   every family on a superset chain requests this point's positives).
//!
//! Points are then ranked by `reuse × join-cost / bytes` — the benefit
//! of never re-joining, per byte held resident — and a greedy knapsack
//! fill admits them into the plan until the `--mem-budget` is spent.
//! Two passes run over the same budget: first **positive** pre-counts
//! (the HYBRID axis), then **complete** pre-counts (the PRECOUNT axis,
//! only for points whose positives were admitted).  The resulting plan
//! spans the whole spectrum:
//!
//! | budget            | plan                            | behaves like |
//! |-------------------|---------------------------------|--------------|
//! | `0`               | nothing pre-counted             | ONDEMAND     |
//! | [`CountPlan::hybrid_budget`] | marginals + all positives | HYBRID  |
//! | unlimited         | everything, complete included   | PRECOUNT     |
//!
//! Plans are pure functions of `(database, lattice, estimator config,
//! budget)` — estimation is seeded — so sequential and parallel runs of
//! the ADAPTIVE strategy share the identical plan.

use crate::db::catalog::Database;
use crate::error::Result;
use crate::estimate::sampler::{EstimatorConfig, JoinSampler};
use crate::estimate::summary::SummaryStats;
use crate::lattice::Lattice;
use crate::meta::rvar::RVar;

/// Pre-count level assigned to one lattice point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanLevel {
    /// Nothing cached; positives come from fresh joins at serve time.
    OnDemand,
    /// Positive ct-table built before search (HYBRID-style).
    Positive,
    /// Positive and complete ct-tables built before search
    /// (PRECOUNT-style; families covered by this point are served by
    /// projection).
    Complete,
}

/// Estimates backing one lattice point's plan decision.
#[derive(Clone, Copy, Debug)]
pub struct PointEstimate {
    pub point: usize,
    /// Estimated INNER-JOIN cardinality of the point's chain.
    pub est_join_rows: f64,
    pub est_positive_rows: f64,
    pub est_positive_bytes: u64,
    pub est_complete_rows: f64,
    pub est_complete_bytes: u64,
    /// Superset chains (itself included) whose Möbius Joins project from
    /// this point.
    pub reuse: u64,
    /// Random walks the join estimate consumed (0 when exact).
    pub walks: u64,
}

/// A budgeted pre-counting plan over one lattice.
#[derive(Clone, Debug)]
pub struct CountPlan {
    /// Per-point level, indexed by lattice point id.
    pub levels: Vec<PlanLevel>,
    /// Whether entity marginals are pre-counted (first item admitted:
    /// they are tiny and every Möbius family serve wants them).
    pub marginals: bool,
    /// The estimates the fill ranked on, in point-id order.
    pub estimates: Vec<PointEstimate>,
    /// Estimated resident bytes of all entity marginals.
    pub marginal_bytes: u64,
    /// The budget the plan was filled against (`None` = unlimited).
    pub budget: Option<u64>,
    /// Estimated bytes the admitted items hold resident.
    pub est_spent_bytes: u64,
    /// Estimated bytes of the HYBRID-equivalent plan (marginals + every
    /// positive table) — see [`CountPlan::hybrid_budget`].
    pub est_all_positive_bytes: u64,
    /// Estimated bytes of the everything plan (PRECOUNT-equivalent).
    pub est_all_complete_bytes: u64,
    /// Total random walks consumed by the estimators.
    pub walks: u64,
}

/// Mirror of [`crate::ct::cttable::CtTable::bytes`] for a hypothetical
/// table: fixed header + per-var metadata + per-row map entry.
fn ct_bytes_estimate(n_vars: usize, rows: f64) -> u64 {
    let per_var = std::mem::size_of::<RVar>() + 4 + 16;
    48 + (n_vars * per_var) as u64 + (rows.max(0.0) * 40.0).round() as u64
}

impl CountPlan {
    /// Estimate every lattice point and greedily fill `budget`.
    pub fn build(
        db: &Database,
        lattice: &Lattice,
        cfg: EstimatorConfig,
        budget: Option<u64>,
    ) -> Result<CountPlan> {
        let sampler = JoinSampler::new(db, cfg);
        // First-tier summary statistics, consulted ahead of sampling
        // when a nonzero summary_bound enables the tier (at 0 the plan
        // is a pure function of the sampler, bit-identical to builds
        // that never constructed a summary).
        let summary =
            if cfg.summary_bound > 0.0 { Some(SummaryStats::build(db)) } else { None };
        let schema = &db.schema;

        // Entity marginals: one ct-table per entity type.
        let mut marginal_bytes = 0u64;
        for (et, e) in schema.entities.iter().enumerate() {
            let cells: f64 = e.attrs.iter().map(|a| a.card as f64).product();
            let rows = cells.min(db.population(et) as f64);
            marginal_bytes += ct_bytes_estimate(e.attrs.len(), rows);
        }

        let mut estimates = Vec::with_capacity(lattice.len());
        let mut walks = 0u64;
        for p in &lattice.points {
            let join = sampler.chain_cardinality_with(&p.rels, summary.as_ref())?;
            walks += join.walks;

            // Positive table: one row per distinct attribute combination
            // present in the join result.  Rel-attr dims include the N/A
            // slot, which positives never occupy.
            let pos_cells: f64 = p
                .attr_vars
                .iter()
                .map(|v| match v {
                    RVar::RelAttr { .. } => (v.dim(schema) - 1) as f64,
                    _ => v.dim(schema) as f64,
                })
                .product();
            let est_positive_rows = join.value.min(pos_cells);
            let est_positive_bytes =
                ct_bytes_estimate(p.attr_vars.len(), est_positive_rows);

            // Complete table: per relationship axis, every true attribute
            // combination plus the single ⊥ state (indicator F, attrs
            // N/A); entity attributes multiply in fully.
            let mut complete_rows = 1.0f64;
            for &rel in &p.rels {
                let true_states: f64 = p
                    .attr_vars
                    .iter()
                    .filter(|v| v.rel() == Some(rel))
                    .map(|v| (v.dim(schema) - 1) as f64)
                    .product();
                complete_rows *= true_states + 1.0;
            }
            for v in &p.attr_vars {
                if v.rel().is_none() {
                    complete_rows *= v.dim(schema) as f64;
                }
            }
            let est_complete_bytes = ct_bytes_estimate(
                p.rels.len() + p.attr_vars.len(),
                complete_rows,
            );

            let reuse = lattice
                .points
                .iter()
                .filter(|q| p.rels.iter().all(|r| q.rels.contains(r)))
                .count() as u64;

            estimates.push(PointEstimate {
                point: p.id,
                est_join_rows: join.value,
                est_positive_rows,
                est_positive_bytes,
                est_complete_rows: complete_rows,
                est_complete_bytes,
                reuse,
                walks: join.walks,
            });
        }

        let est_all_positive_bytes = marginal_bytes
            + estimates.iter().map(|e| e.est_positive_bytes).sum::<u64>();
        let est_all_complete_bytes = est_all_positive_bytes
            + estimates.iter().map(|e| e.est_complete_bytes).sum::<u64>();

        // Greedy knapsack fill.
        let fits = |spent: u64, add: u64| match budget {
            None => true,
            Some(b) => spent.saturating_add(add) <= b,
        };
        let mut levels = vec![PlanLevel::OnDemand; lattice.len()];
        let mut spent = 0u64;
        let mut marginals = false;
        if fits(spent, marginal_bytes.max(1)) {
            marginals = true;
            spent += marginal_bytes;
        }

        // Pass 1 — positives, ranked by reuse × join cost per byte (the
        // joins a resident positive table saves, per byte it holds).
        let mut order: Vec<usize> = (0..estimates.len()).collect();
        let score_pos = |e: &PointEstimate| {
            e.reuse as f64 * e.est_join_rows / e.est_positive_bytes.max(1) as f64
        };
        order.sort_by(|&a, &b| {
            score_pos(&estimates[b])
                .partial_cmp(&score_pos(&estimates[a]))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        if marginals {
            for &i in &order {
                let e = &estimates[i];
                if fits(spent, e.est_positive_bytes) {
                    levels[e.point] = PlanLevel::Positive;
                    spent += e.est_positive_bytes;
                }
            }
        }

        // Pass 2 — completes, ranked by the Möbius work a resident
        // complete table saves per byte (only points whose positives are
        // already in the plan; the Möbius re-runs per serve otherwise).
        let score_cmp = |e: &PointEstimate| {
            e.reuse as f64 * e.est_complete_rows / e.est_complete_bytes.max(1) as f64
        };
        order.sort_by(|&a, &b| {
            score_cmp(&estimates[b])
                .partial_cmp(&score_cmp(&estimates[a]))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &i in &order {
            let e = &estimates[i];
            if levels[e.point] == PlanLevel::Positive
                && fits(spent, e.est_complete_bytes.max(1))
            {
                levels[e.point] = PlanLevel::Complete;
                spent += e.est_complete_bytes;
            }
        }

        Ok(CountPlan {
            levels,
            marginals,
            estimates,
            marginal_bytes,
            budget,
            est_spent_bytes: spent,
            est_all_positive_bytes,
            est_all_complete_bytes,
            walks,
        })
    }

    /// The budget at which the plan is exactly HYBRID: marginals plus
    /// every positive table fit, and no complete table does (each costs
    /// at least one further byte).
    pub fn hybrid_budget(&self) -> u64 {
        self.est_all_positive_bytes
    }

    /// True when `point`'s positive ct-table is pre-counted.
    pub fn positive_planned(&self, point: usize) -> bool {
        matches!(self.levels[point], PlanLevel::Positive | PlanLevel::Complete)
    }

    /// True when `point`'s complete ct-table is pre-counted.
    pub fn complete_planned(&self, point: usize) -> bool {
        self.levels[point] == PlanLevel::Complete
    }

    /// Points planned at least to the positive level.
    pub fn planned_positive_count(&self) -> u64 {
        self.levels.iter().filter(|l| **l != PlanLevel::OnDemand).count() as u64
    }

    /// Points planned to the complete level.
    pub fn planned_complete_count(&self) -> u64 {
        self.levels.iter().filter(|l| **l == PlanLevel::Complete).count() as u64
    }

    /// Fraction of the full (PRECOUNT-equivalent) pre-count this plan
    /// holds resident, by estimated bytes — the planner sweep's x-axis.
    pub fn pre_fraction(&self) -> f64 {
        if self.est_all_complete_bytes == 0 {
            return 1.0;
        }
        self.est_spent_bytes as f64 / self.est_all_complete_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;

    fn plan_with(budget: Option<u64>) -> CountPlan {
        let db = university_db();
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        CountPlan::build(&db, &lattice, EstimatorConfig::default(), budget).unwrap()
    }

    #[test]
    fn zero_budget_plans_nothing() {
        let p = plan_with(Some(0));
        assert!(!p.marginals);
        assert!(p.levels.iter().all(|l| *l == PlanLevel::OnDemand));
        assert_eq!(p.est_spent_bytes, 0);
        assert_eq!(p.pre_fraction(), 0.0);
    }

    #[test]
    fn unlimited_budget_plans_everything() {
        let p = plan_with(None);
        assert!(p.marginals);
        assert!(p.levels.iter().all(|l| *l == PlanLevel::Complete));
        assert_eq!(p.est_spent_bytes, p.est_all_complete_bytes);
        assert!((p.pre_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hybrid_budget_plans_exactly_all_positives() {
        let unbounded = plan_with(None);
        let p = plan_with(Some(unbounded.hybrid_budget()));
        assert!(p.marginals);
        assert!(p.levels.iter().all(|l| *l == PlanLevel::Positive), "{:?}", p.levels);
        assert_eq!(p.est_spent_bytes, p.est_all_positive_bytes);
        assert_eq!(p.planned_complete_count(), 0);
    }

    #[test]
    fn intermediate_budget_is_monotone() {
        let full = plan_with(None);
        let half = plan_with(Some(full.est_all_complete_bytes / 2));
        assert!(half.est_spent_bytes <= full.est_all_complete_bytes / 2);
        assert!(half.pre_fraction() < 1.0);
        // a planned Complete point always implies Positive machinery
        for (i, l) in half.levels.iter().enumerate() {
            if *l == PlanLevel::Complete {
                assert!(half.positive_planned(i));
            }
        }
    }

    #[test]
    fn plans_are_deterministic() {
        let a = plan_with(Some(10_000));
        let b = plan_with(Some(10_000));
        assert_eq!(a.levels, b.levels);
        assert_eq!(a.est_spent_bytes, b.est_spent_bytes);
    }

    #[test]
    fn summary_tier_plans_are_deterministic_and_valid() {
        let db = university_db();
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        let cfg = EstimatorConfig {
            exhaustive_limit: 0,
            summary_bound: f64::INFINITY,
            ..Default::default()
        };
        let a = CountPlan::build(&db, &lattice, cfg, Some(10_000)).unwrap();
        let b = CountPlan::build(&db, &lattice, cfg, Some(10_000)).unwrap();
        assert_eq!(a.levels, b.levels);
        assert_eq!(a.est_spent_bytes, b.est_spent_bytes);
        // the summary answered everything: no walks consumed
        assert_eq!(a.walks, 0);
        // bound 0 never consults the summary: identical to the default
        // config's (sampler-only) plan
        let c = CountPlan::build(
            &db,
            &lattice,
            EstimatorConfig { summary_bound: 0.0, ..Default::default() },
            Some(10_000),
        )
        .unwrap();
        let d = plan_with(Some(10_000));
        assert_eq!(c.levels, d.levels);
        assert_eq!(c.est_spent_bytes, d.est_spent_bytes);
    }

    #[test]
    fn reuse_counts_supersets() {
        let p = plan_with(None);
        // university lattice: {0}, {1}, {0,1} -> the singletons are reused
        // by the 2-chain, the 2-chain only by itself
        let by_point: Vec<u64> = p.estimates.iter().map(|e| e.reuse).collect();
        assert_eq!(by_point, vec![2, 2, 1]);
    }
}
