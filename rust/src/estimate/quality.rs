//! Estimator quality lab: q-error distributions and plan-regret.
//!
//! The ADAPTIVE planner and the delta policy live or die on the
//! cardinality estimator, so this harness measures it directly against
//! ground truth.  For every lattice point the planner would ask about,
//! [`evaluate`] computes the **true** join cardinality (the sampler in
//! oracle mode: unlimited `exhaustive_limit`, so every chain is counted
//! by full enumeration) next to the estimate a given
//! [`QualityMode`] produces, and reports
//!
//! - the **q-error** distribution (p50 / p95 / max), where
//!   `q = max(est, truth) / max(1, min(est, truth))` — the standard
//!   symmetric multiplicative error, 1.0 for a perfect estimate;
//! - **plan-regret**: both the estimate-driven and the oracle-driven
//!   [`CountPlan`] are filled against the same budget (the oracle plan's
//!   HYBRID operating point, where admission decisions actually bite),
//!   and the plans are compared on the true benefit they admit
//!   (`reuse × true join rows` summed over pre-counted positives —
//!   `regret_saved_frac` is the fraction of oracle benefit the
//!   estimate-driven plan forfeits) and on the true bytes the
//!   estimate-driven admissions really cost versus the budget they were
//!   admitted under (`bytes_overrun_frac`).
//!
//! Both regret metrics are exactly 0 under perfect estimates, which the
//! unit tests assert for [`QualityMode::Default`] on the University
//! fixture (every chain is below the exhaustive limit there).
//!
//! The harness is surfaced per preset as `relcount exp estimator
//! --json BENCH_estimator.json` (see [`crate::bench::experiments`]) and
//! gated in CI by `scripts/estimator_gates.json`.

use crate::db::catalog::Database;
use crate::error::Result;
use crate::estimate::plan::{CountPlan, PlanLevel};
use crate::estimate::sampler::{EstimatorConfig, JoinSampler};
use crate::estimate::summary::{within_bound, SummaryStats};
use crate::lattice::Lattice;
use crate::meta::extract::plan_chain;

/// Which estimator configuration a quality sweep exercises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QualityMode {
    /// The configuration the planner actually runs with (small chains
    /// exhaustive, the rest sampled; summary tier off).
    Default,
    /// Wander-join sampling forced on every chain
    /// (`exhaustive_limit = 0`) — stresses the sampler itself.
    Sampled,
    /// Pure first-tier summary estimates (`summary_bound = ∞`, sampling
    /// never consulted) — stresses the O(1) tier's independence
    /// assumptions.
    Summary,
}

impl QualityMode {
    pub const ALL: [QualityMode; 3] =
        [QualityMode::Default, QualityMode::Sampled, QualityMode::Summary];

    pub fn name(self) -> &'static str {
        match self {
            QualityMode::Default => "default",
            QualityMode::Sampled => "sampled",
            QualityMode::Summary => "summary",
        }
    }

    /// The estimator configuration this mode derives from `base`.
    pub fn cfg(self, base: EstimatorConfig) -> EstimatorConfig {
        match self {
            QualityMode::Default => base,
            QualityMode::Sampled => EstimatorConfig { exhaustive_limit: 0, ..base },
            QualityMode::Summary => EstimatorConfig {
                exhaustive_limit: 0,
                summary_bound: f64::INFINITY,
                ..base
            },
        }
    }
}

/// One (database, mode) sweep's quality metrics.
#[derive(Clone, Debug)]
pub struct QualityReport {
    pub mode: &'static str,
    /// Lattice points evaluated (every point the planner estimates).
    pub points: u64,
    pub q_p50: f64,
    pub q_p95: f64,
    pub q_max: f64,
    /// Fraction of points the estimator answered exactly.
    pub exact_frac: f64,
    /// Points answered by the O(1) summary tier (its declared band was
    /// within `summary_bound`).
    pub summary_hits: u64,
    /// Random walks consumed across all points.
    pub walks: u64,
    /// Fraction of the oracle plan's true admitted benefit
    /// (`reuse × true join rows`) the estimate-driven plan forfeits.
    pub regret_saved_frac: f64,
    /// True bytes of the estimate-driven admissions beyond the budget
    /// they were admitted under, as a fraction of that budget.  `None`
    /// when the budget is zero: the fraction is undefined there, and
    /// the old 1-byte floor turned those rows into astronomically large
    /// (but meaningless) overruns.
    pub bytes_overrun_frac: Option<f64>,
}

/// Overrun as a fraction of `budget`, or `None` for a zero budget
/// (undefined — flooring the divisor would fabricate a huge fraction).
pub fn overrun_frac(spent: u64, budget: u64) -> Option<f64> {
    if budget == 0 {
        return None;
    }
    Some(spent.saturating_sub(budget) as f64 / budget as f64)
}

/// `max(est, truth) / max(1, min(est, truth))`; 1.0 when both are 0.
fn q_error(est: f64, truth: f64) -> f64 {
    let (lo, hi) = if est <= truth { (est, truth) } else { (truth, est) };
    hi.max(1.0) / lo.max(1.0)
}

/// Nearest-rank percentile of an ascending-sorted slice.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Sweep every lattice point under `mode` and compare against oracle
/// counts — see the module docs for the metrics.
pub fn evaluate(
    db: &Database,
    lattice: &Lattice,
    base: EstimatorConfig,
    mode: QualityMode,
) -> Result<QualityReport> {
    let cfg = mode.cfg(base);
    let oracle_cfg =
        EstimatorConfig { exhaustive_limit: u64::MAX, summary_bound: 0.0, ..base };

    let summary =
        if cfg.summary_bound > 0.0 { Some(SummaryStats::build(db)) } else { None };
    let sampler = JoinSampler::new(db, cfg);
    let oracle = JoinSampler::new(db, oracle_cfg);

    let mut qs = Vec::with_capacity(lattice.len());
    let mut truths = Vec::with_capacity(lattice.len());
    let mut exact = 0u64;
    let mut summary_hits = 0u64;
    let mut walks = 0u64;
    for p in &lattice.points {
        let truth = oracle.chain_cardinality(&p.rels)?;
        debug_assert!(truth.exact);
        let est = sampler.chain_cardinality_with(&p.rels, summary.as_ref())?;
        walks += est.walks;
        if est.exact {
            exact += 1;
        }
        if let Some(s) = summary.as_ref() {
            let order = plan_chain(db, &p.rels)?.join_order;
            if within_bound(&s.chain_estimate(&db.schema, &order), cfg.summary_bound) {
                summary_hits += 1;
            }
        }
        qs.push(q_error(est.value, truth.value));
        truths.push(truth.value);
    }
    qs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));

    // Plan-regret: fill both plans against the oracle plan's HYBRID
    // operating point — the budget where admission decisions bite.
    let probe = CountPlan::build(db, lattice, oracle_cfg, None)?;
    let budget = probe.hybrid_budget();
    let plan_est = CountPlan::build(db, lattice, cfg, Some(budget))?;
    let plan_orc = CountPlan::build(db, lattice, oracle_cfg, Some(budget))?;

    let saved_true = |plan: &CountPlan| -> f64 {
        plan.estimates
            .iter()
            .filter(|e| plan.positive_planned(e.point))
            .map(|e| e.reuse as f64 * truths[e.point])
            .sum()
    };
    let saved_orc = saved_true(&plan_orc);
    let saved_est = saved_true(&plan_est);
    let regret_saved_frac = if saved_orc > 0.0 {
        ((saved_orc - saved_est) / saved_orc).max(0.0)
    } else {
        0.0
    };

    // True bytes of the estimate-driven plan's admissions, priced by the
    // oracle's (exact-cardinality) byte estimates.
    let mut spent_true = if plan_est.marginals { plan_est.marginal_bytes } else { 0 };
    for oe in &plan_orc.estimates {
        match plan_est.levels[oe.point] {
            PlanLevel::OnDemand => {}
            PlanLevel::Positive => spent_true += oe.est_positive_bytes,
            PlanLevel::Complete => {
                spent_true += oe.est_positive_bytes + oe.est_complete_bytes
            }
        }
    }
    let bytes_overrun_frac = overrun_frac(spent_true, budget);

    let points = lattice.len() as u64;
    Ok(QualityReport {
        mode: mode.name(),
        points,
        q_p50: percentile(&qs, 0.50),
        q_p95: percentile(&qs, 0.95),
        q_max: qs.last().copied().unwrap_or(0.0),
        exact_frac: if points == 0 { 1.0 } else { exact as f64 / points as f64 },
        summary_hits,
        walks,
        regret_saved_frac,
        bytes_overrun_frac,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;

    fn lab(mode: QualityMode) -> QualityReport {
        let db = university_db();
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        evaluate(&db, &lattice, EstimatorConfig::default(), mode).unwrap()
    }

    #[test]
    fn default_mode_is_perfect_on_university() {
        // every university chain is below the exhaustive limit, so the
        // planner's estimates equal the oracle: q == 1 everywhere and
        // both regret metrics are exactly 0
        let r = lab(QualityMode::Default);
        assert!(r.points >= 3);
        assert_eq!(r.q_p50, 1.0);
        assert_eq!(r.q_p95, 1.0);
        assert_eq!(r.q_max, 1.0);
        assert_eq!(r.exact_frac, 1.0);
        assert_eq!(r.summary_hits, 0);
        assert_eq!(r.regret_saved_frac, 0.0);
        assert_eq!(r.bytes_overrun_frac, Some(0.0));
    }

    #[test]
    fn sampled_mode_stays_sane() {
        let r = lab(QualityMode::Sampled);
        assert!(r.q_p50 >= 1.0);
        assert!(r.q_max >= r.q_p95 && r.q_p95 >= r.q_p50);
        assert!(r.walks > 0);
        assert!((0.0..=1.0).contains(&r.regret_saved_frac));
        assert!(r.bytes_overrun_frac.unwrap_or(0.0) >= 0.0);
    }

    #[test]
    fn summary_mode_answers_without_walks() {
        let r = lab(QualityMode::Summary);
        assert_eq!(r.walks, 0);
        assert_eq!(r.summary_hits, r.points);
        assert!(r.q_p50 >= 1.0);
    }

    #[test]
    fn quality_is_deterministic() {
        let a = lab(QualityMode::Sampled);
        let b = lab(QualityMode::Sampled);
        assert_eq!(a.q_p50, b.q_p50);
        assert_eq!(a.q_max, b.q_max);
        assert_eq!(a.regret_saved_frac, b.regret_saved_frac);
    }

    #[test]
    fn overrun_frac_zero_budget_is_undefined_not_huge() {
        assert_eq!(overrun_frac(10, 0), None);
        assert_eq!(overrun_frac(0, 0), None);
        assert_eq!(overrun_frac(5, 10), Some(0.0));
        assert_eq!(overrun_frac(15, 10), Some(0.5));
    }

    #[test]
    fn percentiles_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.50), 2.0);
        assert_eq!(percentile(&v, 0.95), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(q_error(0.0, 0.0), 1.0);
        assert_eq!(q_error(0.0, 5.0), 5.0);
        assert_eq!(q_error(10.0, 5.0), 2.0);
    }
}
