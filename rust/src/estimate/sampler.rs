//! Wander-join cardinality estimation over the relationship indexes.
//!
//! A *walk* starts from a uniformly drawn tuple of the join order's first
//! relationship and extends it one relationship at a time through the FK
//! adjacency lists, picking one continuation uniformly at each step.  The
//! product of the choice-set sizes along a surviving walk is an unbiased
//! (Horvitz–Thompson) estimate of the join cardinality; dead ends
//! contribute zero.  Averaging `walks` such estimates gives the point
//! estimate, and the sample variance gives the declared error interval.
//!
//! Chains whose worst-case enumeration is small (see
//! [`EstimatorConfig::exhaustive_limit`]) are counted **exactly** by full
//! enumeration instead — on those the estimate carries zero error, which
//! the property tests assert.
//!
//! Everything is seeded through [`crate::util::rng::Rng`]: the same
//! database, chain, and config always produce the identical estimate, so
//! the ADAPTIVE plan is bit-reproducible across runs and worker counts.
//!
//! Walks draw continuations in **canonical neighbor order** (ascending
//! opposite-endpoint id) rather than adjacency-list position, so the
//! estimate — and therefore every plan and cache digest built on it —
//! is also identical across index storage backends (`--backend hash`
//! vs `--backend csr` vs `--backend ccsr`).  Clean columnar rows serve
//! a draw through [`crate::db::index::NeighborRun::value_at`] — O(1)
//! on CSR slices, one block decode on compressed runs; rows the index
//! cannot serve sorted (hash backend, rows with pending overlay) are
//! sorted **once per endpoint** into a sampler-local memo — walks
//! hammer the same hubs, so the sort amortizes across all of a chain's
//! draws.

use std::cell::RefCell;

use crate::db::catalog::Database;
use crate::db::index::RelIx;
use crate::error::Result;
use crate::estimate::summary::{within_bound, SummaryStats};
use crate::meta::extract::plan_chain;
use crate::util::fxhash::FxHashMap;
use crate::util::rng::Rng;

/// Configuration of the sampling estimators (carried inside
/// [`crate::strategies::StrategyConfig`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EstimatorConfig {
    /// Base seed; mixed with the chain id so distinct chains draw
    /// independent walk sequences.
    pub seed: u64,
    /// Random walks per sampled chain.
    pub walks: u32,
    /// Chains whose deterministic cardinality cap is at most this are
    /// enumerated exactly instead of sampled.
    pub exhaustive_limit: u64,
    /// Relative error band within which a first-tier
    /// [`crate::estimate::summary::SummaryStats`] estimate is accepted
    /// without sampling (see
    /// [`JoinSampler::chain_cardinality_with`]).  At the default `0.0`
    /// the summary tier is never consulted and every estimate — and
    /// therefore every plan and cache digest — is bit-identical to the
    /// sampler-only path.
    pub summary_bound: f64,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            seed: 0x9E3779B9,
            walks: 256,
            exhaustive_limit: 8192,
            summary_bound: 0.0,
        }
    }
}

/// One cardinality estimate with its declared error bounds.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    /// Point estimate (exact when [`Estimate::exact`]).
    pub value: f64,
    /// Declared lower bound (0 ≤ `lo` ≤ true value when the declared
    /// interval covers, which is guaranteed for exact estimates and holds
    /// with overwhelming probability for sampled ones: a 6-sigma CLT
    /// interval plus a deterministic cushion).
    pub lo: f64,
    /// Declared upper bound (see [`Estimate::lo`]).
    pub hi: f64,
    /// Deterministic cap: the true cardinality can never exceed this
    /// (first table size times the product of maximum fan-outs).
    pub cap: f64,
    /// True when the chain was enumerated exhaustively (`lo == hi`).
    pub exact: bool,
    /// Random walks consumed (0 for exact estimates).
    pub walks: u64,
}

/// Join-chain cardinality estimator over one database.
pub struct JoinSampler<'a> {
    db: &'a Database,
    cfg: EstimatorConfig,
    /// Sorted neighbor rows for endpoints the index cannot serve in
    /// O(1) canonical order (hash backend, CSR overlay-dirty rows),
    /// keyed by `(rel, from-orientation, endpoint)`.  The database is
    /// borrowed for the sampler's lifetime, so entries never go stale.
    sorted_rows: RefCell<FxHashMap<(usize, bool, u32), Vec<u32>>>,
}

impl<'a> JoinSampler<'a> {
    pub fn new(db: &'a Database, cfg: EstimatorConfig) -> Self {
        JoinSampler { db, cfg, sorted_rows: RefCell::new(FxHashMap::default()) }
    }

    /// The `k`-th neighbor of endpoint `v` through `rel`, in canonical
    /// (ascending) order — served from the clean neighbor run when the
    /// backend has one, one memoized sort per endpoint otherwise.
    fn nth_nbr(&self, rel: usize, ix: &RelIx, from_side: bool, v: u32, k: usize) -> u32 {
        let run =
            if from_side { ix.neighbor_run_from(v) } else { ix.neighbor_run_to(v) };
        if let Some(run) = run {
            return run.value_at(k);
        }
        let mut rows = self.sorted_rows.borrow_mut();
        let row = rows.entry((rel, from_side, v)).or_insert_with(|| {
            let table = &self.db.rels[rel];
            let mut r: Vec<u32> = if from_side {
                ix.tids_from(v).map(|t| table.to[t as usize]).collect()
            } else {
                ix.tids_to(v).map(|t| table.from[t as usize]).collect()
            };
            r.sort_unstable();
            r
        });
        row[k]
    }

    /// Tiered estimate: consult the O(1) summary tier first and fall
    /// through to [`JoinSampler::chain_cardinality`] whenever the
    /// summary's declared band is wider than
    /// [`EstimatorConfig::summary_bound`] allows.
    ///
    /// With `summary` absent or `summary_bound == 0.0` this is exactly
    /// `chain_cardinality` — the bound-0 bit-identity invariant the
    /// property tests assert.
    pub fn chain_cardinality_with(
        &self,
        chain: &[usize],
        summary: Option<&SummaryStats>,
    ) -> Result<Estimate> {
        if let Some(s) = summary {
            if self.cfg.summary_bound > 0.0 {
                let plan = plan_chain(self.db, chain)?;
                let est = s.chain_estimate(&self.db.schema, &plan.join_order);
                if within_bound(&est, self.cfg.summary_bound) {
                    return Ok(est);
                }
            }
        }
        self.chain_cardinality(chain)
    }

    /// Estimated number of groundings satisfying every relationship of
    /// `chain` (the size of the INNER-JOIN result that
    /// [`crate::db::query::positive_chain_ct`] enumerates).
    pub fn chain_cardinality(&self, chain: &[usize]) -> Result<Estimate> {
        let plan = plan_chain(self.db, chain)?;
        let order = &plan.join_order;
        let first = order[0];
        let n0 = self.db.rels[first].len() as u64;

        // Deterministic cap: |R_first| * prod(max fan-out of each later
        // step).  A bound-bound step has fan-out <= 1 <= max degree.
        let mut cap = n0 as f64;
        for &rel in &order[1..] {
            cap *= self.max_degree(rel)? as f64;
        }
        if n0 == 0 || cap == 0.0 {
            return Ok(Estimate { value: 0.0, lo: 0.0, hi: 0.0, cap: 0.0, exact: true, walks: 0 });
        }
        if order.len() == 1 {
            let v = n0 as f64;
            return Ok(Estimate { value: v, lo: v, hi: v, cap: v, exact: true, walks: 0 });
        }
        if cap <= self.cfg.exhaustive_limit as f64 {
            let v = self.enumerate_exact(order)? as f64;
            return Ok(Estimate { value: v, lo: v, hi: v, cap, exact: true, walks: 0 });
        }

        // Wander join: seeded per chain, so the estimate is a pure
        // function of (db, chain, cfg).
        let mut rng = Rng::new(chain_seed(self.cfg.seed, chain));
        let n = self.cfg.walks.max(1) as u64;
        let mut sum = 0.0f64;
        let mut sum_sq = 0.0f64;
        for _ in 0..n {
            let w = self.walk(order, &mut rng)?;
            sum += w;
            sum_sq += w * w;
        }
        let mean = sum / n as f64;
        let var = (sum_sq / n as f64 - mean * mean).max(0.0);
        let sigma = (var / n as f64).sqrt();
        // 6-sigma CLT interval, cushioned against degenerate samples
        // (e.g. every walk dying on a rare heavy path).
        let slack = 1.0 + 0.02 * cap;
        Ok(Estimate {
            value: mean,
            lo: (mean - 6.0 * sigma - slack).max(0.0),
            hi: (mean + 6.0 * sigma + slack).min(cap),
            cap,
            exact: false,
            walks: n,
        })
    }

    /// Largest adjacency-list length of `rel` in either direction.
    fn max_degree(&self, rel: usize) -> Result<usize> {
        Ok(self.db.index(rel)?.max_degree())
    }

    /// One random walk; returns its Horvitz–Thompson weight (0 on a dead
    /// end).
    fn walk(&self, order: &[usize], rng: &mut Rng) -> Result<f64> {
        let n_ets = self.db.schema.entities.len();
        let mut binding: Vec<Option<u32>> = vec![None; n_ets];
        let first = order[0];
        let table = &self.db.rels[first];
        let t0 = rng.gen_range(table.len() as u64) as u32;
        let (a, b) = self.db.schema.rel_endpoints(first);
        binding[a] = Some(table.from[t0 as usize]);
        binding[b] = Some(table.to[t0 as usize]);
        let mut weight = table.len() as f64;

        for &rel in &order[1..] {
            let ix = self.db.index(rel)?;
            let (a, b) = self.db.schema.rel_endpoints(rel);
            match (binding[a], binding[b]) {
                (Some(fa), Some(fb)) => {
                    if ix.lookup(fa, fb).is_none() {
                        return Ok(0.0);
                    }
                }
                (Some(fa), None) => {
                    let deg = ix.degree_from(fa);
                    if deg == 0 {
                        return Ok(0.0);
                    }
                    let k = rng.gen_range(deg as u64) as usize;
                    binding[b] = Some(self.nth_nbr(rel, ix, true, fa, k));
                    weight *= deg as f64;
                }
                (None, Some(fb)) => {
                    let deg = ix.degree_to(fb);
                    if deg == 0 {
                        return Ok(0.0);
                    }
                    let k = rng.gen_range(deg as u64) as usize;
                    binding[a] = Some(self.nth_nbr(rel, ix, false, fb, k));
                    weight *= deg as f64;
                }
                (None, None) => {
                    // plan_chain emits connected orders, but stay robust:
                    // sample the whole table uniformly.
                    let t = &self.db.rels[rel];
                    if t.is_empty() {
                        return Ok(0.0);
                    }
                    let i = rng.gen_range(t.len() as u64) as u32;
                    binding[a] = Some(t.from[i as usize]);
                    binding[b] = Some(t.to[i as usize]);
                    weight *= t.len() as f64;
                }
            }
        }
        Ok(weight)
    }

    /// Exact join cardinality by full index-nested-loop enumeration
    /// (used when the deterministic cap says it is cheap).
    fn enumerate_exact(&self, order: &[usize]) -> Result<u64> {
        let n_ets = self.db.schema.entities.len();
        let mut binding: Vec<Option<u32>> = vec![None; n_ets];
        self.count_rec(order, 0, &mut binding)
    }

    fn count_rec(
        &self,
        order: &[usize],
        depth: usize,
        binding: &mut Vec<Option<u32>>,
    ) -> Result<u64> {
        if depth == order.len() {
            return Ok(1);
        }
        let rel = order[depth];
        let (a, b) = self.db.schema.rel_endpoints(rel);
        let ix = self.db.index(rel)?;
        let mut total = 0u64;
        match (binding[a], binding[b]) {
            (Some(fa), Some(fb)) => {
                if ix.lookup(fa, fb).is_some() {
                    total += self.count_rec(order, depth + 1, binding)?;
                }
            }
            (Some(fa), None) => {
                for t in ix.tids_from(fa) {
                    binding[b] = Some(self.db.rels[rel].to[t as usize]);
                    total += self.count_rec(order, depth + 1, binding)?;
                }
                binding[b] = None;
            }
            (None, Some(fb)) => {
                for t in ix.tids_to(fb) {
                    binding[a] = Some(self.db.rels[rel].from[t as usize]);
                    total += self.count_rec(order, depth + 1, binding)?;
                }
                binding[a] = None;
            }
            (None, None) => {
                let table = &self.db.rels[rel];
                for t in 0..table.len() {
                    binding[a] = Some(table.from[t as usize]);
                    binding[b] = Some(table.to[t as usize]);
                    total += self.count_rec(order, depth + 1, binding)?;
                }
                binding[a] = None;
                binding[b] = None;
            }
        }
        Ok(total)
    }
}

/// Mix the base seed with a chain's relationship ids (FNV-style fold) so
/// each chain draws an independent, reproducible walk stream.
fn chain_seed(base: u64, chain: &[usize]) -> u64 {
    chain.iter().fold(base ^ 0xcbf2_9ce4_8422_2325, |s, &r| {
        s.wrapping_mul(0x0000_0100_0000_01b3).wrapping_add(r as u64 + 1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::db::query::positive_chain_ct;
    use crate::db::query::JoinStats;

    fn true_cardinality(db: &Database, chain: &[usize]) -> u64 {
        let mut stats = JoinStats::default();
        positive_chain_ct(db, chain, &[], &mut stats).unwrap().total().unwrap() as u64
    }

    #[test]
    fn single_rel_is_exact() {
        let db = university_db();
        let s = JoinSampler::new(&db, EstimatorConfig::default());
        let e = s.chain_cardinality(&[0]).unwrap();
        assert!(e.exact);
        assert_eq!(e.value as u64, db.rels[0].len() as u64);
        assert_eq!(e.lo, e.hi);
    }

    #[test]
    fn exhaustive_mode_matches_join() {
        let db = university_db();
        let s = JoinSampler::new(&db, EstimatorConfig::default());
        let e = s.chain_cardinality(&[0, 1]).unwrap();
        assert!(e.exact, "university 2-chain is tiny; cap {}", e.cap);
        assert_eq!(e.value as u64, true_cardinality(&db, &[0, 1]));
        assert_eq!(e.walks, 0);
    }

    #[test]
    fn sampled_mode_bounds_cover_truth() {
        let db = university_db();
        // force sampling by disabling exhaustive enumeration
        let cfg = EstimatorConfig { exhaustive_limit: 0, walks: 2048, ..Default::default() };
        let s = JoinSampler::new(&db, cfg);
        let e = s.chain_cardinality(&[0, 1]).unwrap();
        assert!(!e.exact);
        assert_eq!(e.walks, 2048);
        let truth = true_cardinality(&db, &[0, 1]) as f64;
        assert!(truth <= e.cap, "cap {} < truth {truth}", e.cap);
        assert!(
            e.lo <= truth && truth <= e.hi,
            "declared interval [{}, {}] misses truth {truth} (est {})",
            e.lo,
            e.hi,
            e.value
        );
    }

    #[test]
    fn estimates_are_deterministic() {
        let db = university_db();
        let cfg = EstimatorConfig { exhaustive_limit: 0, ..Default::default() };
        let a = JoinSampler::new(&db, cfg).chain_cardinality(&[0, 1]).unwrap();
        let b = JoinSampler::new(&db, cfg).chain_cardinality(&[0, 1]).unwrap();
        assert_eq!(a.value, b.value);
        assert_eq!(a.lo, b.lo);
        assert_eq!(a.hi, b.hi);
        // a different seed draws a different walk stream
        let c = JoinSampler::new(&db, EstimatorConfig { seed: 7, ..cfg })
            .chain_cardinality(&[0, 1])
            .unwrap();
        assert!(c.lo <= true_cardinality(&db, &[0, 1]) as f64);
        assert!(c.hi >= true_cardinality(&db, &[0, 1]) as f64);
    }

    #[test]
    fn summary_tier_gates_on_bound() {
        let db = university_db();
        let summary = SummaryStats::build(&db);
        // bound 0 (the default): the summary is never consulted — the
        // tiered call is bit-identical to the sampler-only path
        let cfg = EstimatorConfig { exhaustive_limit: 0, ..Default::default() };
        let s = JoinSampler::new(&db, cfg);
        let a = s.chain_cardinality(&[0, 1]).unwrap();
        let b = s.chain_cardinality_with(&[0, 1], Some(&summary)).unwrap();
        assert_eq!((a.value, a.lo, a.hi, a.walks), (b.value, b.lo, b.hi, b.walks));
        // bound infinity: the summary always answers — no walks
        let cfg = EstimatorConfig {
            exhaustive_limit: 0,
            summary_bound: f64::INFINITY,
            ..Default::default()
        };
        let s = JoinSampler::new(&db, cfg);
        let e = s.chain_cardinality_with(&[0, 1], Some(&summary)).unwrap();
        assert_eq!(e.walks, 0);
        assert!(!e.exact);
        // no summary handed in: falls through regardless of bound
        let f = s.chain_cardinality_with(&[0, 1], None).unwrap();
        assert!(f.walks > 0);
    }

    #[test]
    fn estimates_are_backend_invariant() {
        // canonical neighbor-order sampling: the hash, CSR, and
        // compressed engines draw the identical walk stream, so
        // estimates (and the plans built on them) match bit-for-bit
        let csr = university_db();
        let mut hash = csr.clone();
        hash.set_backend(crate::db::index::Backend::Hash).unwrap();
        let mut ccsr = csr.clone();
        ccsr.set_backend(crate::db::index::Backend::Ccsr).unwrap();
        let cfg = EstimatorConfig { exhaustive_limit: 0, ..Default::default() };
        for chain in [vec![0usize], vec![1], vec![0, 1]] {
            let a = JoinSampler::new(&csr, cfg).chain_cardinality(&chain).unwrap();
            for other in [&hash, &ccsr] {
                let b = JoinSampler::new(other, cfg).chain_cardinality(&chain).unwrap();
                assert_eq!(a.value, b.value, "{chain:?}");
                assert_eq!(a.lo, b.lo, "{chain:?}");
                assert_eq!(a.hi, b.hi, "{chain:?}");
                assert_eq!(a.walks, b.walks, "{chain:?}");
            }
        }
    }
}
