//! O(1) summary-statistics estimator: the cheap first tier in front of
//! the wander-join sampler.
//!
//! A [`SummaryStats`] holds, per relationship, the row count, a
//! log-bucketed degree histogram for each endpoint orientation and
//! per-attribute-value selectivity counts — and per entity type, the
//! population and per-attribute-value counts.  Everything is maintained
//! **incrementally** by the delta path ([`crate::delta::maintain`]): one
//! link insert/delete or entity insert touches O(values) map entries, so
//! the summary is always exact for the facts it tracks, no matter how
//! much churn has flowed through.  A from-scratch [`SummaryStats::build`]
//! over the same tables is always equal (asserted by
//! `rust/tests/proptest_invariants.rs`), because zeroed entries are
//! removed eagerly — the representation is canonical.
//!
//! [`SummaryStats::chain_estimate`] answers a join-chain cardinality
//! question in O(chain length) under an independence assumption
//! (uniform fan-out per step), with a **sound deterministic upper bound**
//! from the degree histograms: the top of the highest occupied bucket
//! can never be exceeded by any real degree.  The declared band is wide
//! — `lo = 0` for multi-relationship chains — which is exactly the
//! point: [`crate::estimate::sampler::JoinSampler::chain_cardinality_with`]
//! consults the summary first and falls through to sampling whenever the
//! band is wider than [`EstimatorConfig::summary_bound`] allows, so at
//! bound 0 the summary is never consulted and plans are bit-identical to
//! the sampler-only path.
//!
//! [`EstimatorConfig::summary_bound`]: crate::estimate::sampler::EstimatorConfig::summary_bound

use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::db::value::Code;
use crate::estimate::sampler::Estimate;
use crate::util::fxhash::FxHashMap;

/// Log-bucketed degree histogram over one endpoint orientation of a
/// relationship.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DegreeHist {
    /// Exact degree per endpoint id.  Entries that drop to degree 0 are
    /// removed, so two histograms over the same live edges compare equal
    /// regardless of churn history.
    degrees: FxHashMap<u32, u32>,
    /// `buckets[k]` = endpoints with degree in `[2^k, 2^(k+1))`.
    buckets: [u64; 32],
}

impl DegreeHist {
    #[inline]
    fn bucket(d: u32) -> usize {
        d.ilog2() as usize
    }

    /// Record one new edge incident to endpoint `v`.
    pub fn add(&mut self, v: u32) {
        let d = self.degrees.entry(v).or_insert(0);
        if *d > 0 {
            self.buckets[Self::bucket(*d)] -= 1;
        }
        *d += 1;
        self.buckets[Self::bucket(*d)] += 1;
    }

    /// Retract one edge incident to endpoint `v` (no-op if `v` has no
    /// recorded edge — the delta path only retracts live tuples).
    pub fn remove(&mut self, v: u32) {
        if let Some(d) = self.degrees.get_mut(&v) {
            self.buckets[Self::bucket(*d)] -= 1;
            *d -= 1;
            if *d == 0 {
                self.degrees.remove(&v);
            } else {
                self.buckets[Self::bucket(*d)] += 1;
            }
        }
    }

    /// Exact degree of endpoint `v`.
    pub fn degree(&self, v: u32) -> u32 {
        self.degrees.get(&v).copied().unwrap_or(0)
    }

    /// Endpoints with at least one edge.
    pub fn active(&self) -> u64 {
        self.degrees.len() as u64
    }

    /// Deterministic upper bound on the maximum degree: the top of the
    /// highest occupied bucket (`2^(k+1) - 1`), 0 when no endpoint has
    /// an edge.  Never below the true maximum.
    pub fn max_degree_bound(&self) -> u64 {
        match self.buckets.iter().rposition(|&c| c > 0) {
            Some(k) => (1u64 << (k as u32 + 1)) - 1,
            None => 0,
        }
    }
}

/// Summary statistics for one relationship table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RelSummary {
    /// Live tuple count.
    pub rows: u64,
    /// Degree histogram of the `from` endpoints (fan-out).
    pub fan_out: DegreeHist,
    /// Degree histogram of the `to` endpoints (fan-in).
    pub fan_in: DegreeHist,
    /// `attr_counts[a][value]` = live tuples carrying `value` in rel
    /// attribute `a` (zeroed entries removed).
    pub attr_counts: Vec<FxHashMap<Code, u64>>,
}

/// Summary statistics for one entity table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EntitySummary {
    pub population: u64,
    /// `attr_counts[a][value]` = entities carrying `value` in attribute
    /// `a`.
    pub attr_counts: Vec<FxHashMap<Code, u64>>,
}

/// Incrementally-maintained database summary: the first estimator tier.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SummaryStats {
    pub rels: Vec<RelSummary>,
    pub entities: Vec<EntitySummary>,
}

impl SummaryStats {
    /// Build from the base tables in one pass (O(data)).
    pub fn build(db: &Database) -> SummaryStats {
        let mut entities = Vec::with_capacity(db.entities.len());
        for t in &db.entities {
            let mut s = EntitySummary {
                population: t.len() as u64,
                attr_counts: vec![FxHashMap::default(); t.cols.len()],
            };
            for (a, col) in t.cols.iter().enumerate() {
                for &v in col {
                    *s.attr_counts[a].entry(v).or_insert(0) += 1;
                }
            }
            entities.push(s);
        }
        let mut rels = Vec::with_capacity(db.rels.len());
        for t in &db.rels {
            let mut s = RelSummary {
                rows: t.len() as u64,
                fan_out: DegreeHist::default(),
                fan_in: DegreeHist::default(),
                attr_counts: vec![FxHashMap::default(); t.cols.len()],
            };
            for &f in &t.from {
                s.fan_out.add(f);
            }
            for &v in &t.to {
                s.fan_in.add(v);
            }
            for (a, col) in t.cols.iter().enumerate() {
                for &v in col {
                    *s.attr_counts[a].entry(v).or_insert(0) += 1;
                }
            }
            rels.push(s);
        }
        SummaryStats { rels, entities }
    }

    /// Maintain through one link insert (O(values)).
    pub fn insert_link(&mut self, rel: usize, from: u32, to: u32, values: &[Code]) {
        let s = &mut self.rels[rel];
        s.rows += 1;
        s.fan_out.add(from);
        s.fan_in.add(to);
        for (a, &v) in values.iter().enumerate() {
            *s.attr_counts[a].entry(v).or_insert(0) += 1;
        }
    }

    /// Maintain through one link delete; `values` are the retracted
    /// tuple's attribute values (returned by
    /// [`crate::db::catalog::Database::delete_link`]).
    pub fn delete_link(&mut self, rel: usize, from: u32, to: u32, values: &[Code]) {
        let s = &mut self.rels[rel];
        s.rows = s.rows.saturating_sub(1);
        s.fan_out.remove(from);
        s.fan_in.remove(to);
        for (a, &v) in values.iter().enumerate() {
            if let Some(c) = s.attr_counts[a].get_mut(&v) {
                *c -= 1;
                if *c == 0 {
                    s.attr_counts[a].remove(&v);
                }
            }
        }
    }

    /// Maintain through one entity insert (O(values)).
    pub fn insert_entity(&mut self, et: usize, values: &[Code]) {
        let s = &mut self.entities[et];
        s.population += 1;
        for (a, &v) in values.iter().enumerate() {
            *s.attr_counts[a].entry(v).or_insert(0) += 1;
        }
    }

    /// Fraction of `rel`'s live tuples carrying `value` in attribute `a`
    /// (1.0 on an empty relationship: a vacuous filter).
    pub fn rel_selectivity(&self, rel: usize, a: usize, value: Code) -> f64 {
        let s = &self.rels[rel];
        if s.rows == 0 {
            return 1.0;
        }
        s.attr_counts[a].get(&value).copied().unwrap_or(0) as f64 / s.rows as f64
    }

    /// Fraction of entity type `et`'s population carrying `value` in
    /// attribute `a` (1.0 on an empty population).
    pub fn entity_selectivity(&self, et: usize, a: usize, value: Code) -> f64 {
        let s = &self.entities[et];
        if s.population == 0 {
            return 1.0;
        }
        s.attr_counts[a].get(&value).copied().unwrap_or(0) as f64
            / s.population as f64
    }

    /// O(chain) cardinality estimate over a connected join `order` (as
    /// produced by [`crate::meta::extract::plan_chain`]).
    ///
    /// The point value multiplies independence-assumption fan-out factors
    /// (`rows / population` per newly-bound endpoint); the declared `hi`
    /// multiplies the degree-histogram maximum bounds and is therefore a
    /// sound deterministic cap.  Empty relationships and single-rel
    /// chains are exact; everything else declares `lo = 0`.
    pub fn chain_estimate(&self, schema: &Schema, order: &[usize]) -> Estimate {
        if order.iter().any(|&r| self.rels[r].rows == 0) {
            return Estimate { value: 0.0, lo: 0.0, hi: 0.0, cap: 0.0, exact: true, walks: 0 };
        }
        let first = order[0];
        let n0 = self.rels[first].rows as f64;
        if order.len() == 1 {
            return Estimate { value: n0, lo: n0, hi: n0, cap: n0, exact: true, walks: 0 };
        }
        let mut bound = vec![false; schema.entities.len()];
        let (a0, b0) = schema.rel_endpoints(first);
        bound[a0] = true;
        bound[b0] = true;
        let mut value = n0;
        let mut hi = n0;
        for &rel in &order[1..] {
            let s = &self.rels[rel];
            let rows = s.rows as f64;
            let (a, b) = schema.rel_endpoints(rel);
            let pop = |et: usize| self.entities[et].population.max(1) as f64;
            let (factor, hi_factor) = match (bound[a], bound[b]) {
                // Both endpoints already bound: the step is a membership
                // probe — on average rows/(|A|·|B|) pairs survive, at
                // most 1 (set semantics).
                (true, true) => (rows / (pop(a) * pop(b)), 1.0),
                // One endpoint bound: average vs maximum fan-out.
                (true, false) => {
                    (rows / pop(a), s.fan_out.max_degree_bound() as f64)
                }
                (false, true) => (rows / pop(b), s.fan_in.max_degree_bound() as f64),
                // Disconnected step (plan_chain avoids these): full
                // cross-product with the table.
                (false, false) => (rows, rows),
            };
            bound[a] = true;
            bound[b] = true;
            value *= factor;
            hi *= hi_factor;
        }
        Estimate { value, lo: 0.0, hi, cap: hi, exact: false, walks: 0 }
    }
}

/// The tiering predicate shared by
/// [`crate::estimate::sampler::JoinSampler::chain_cardinality_with`] and
/// the quality harness: an estimate is usable as-is when it is exact or
/// its declared band is within `bound`, relative to its point value.
pub fn within_bound(est: &Estimate, bound: f64) -> bool {
    est.exact || (est.hi - est.lo) <= bound * est.value.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::db::query::{positive_chain_ct, JoinStats};

    fn truth(db: &Database, chain: &[usize]) -> u64 {
        let mut stats = JoinStats::default();
        positive_chain_ct(db, chain, &[], &mut stats).unwrap().total().unwrap() as u64
    }

    #[test]
    fn hist_add_remove_is_canonical() {
        let mut h = DegreeHist::default();
        h.add(3);
        h.add(3);
        h.add(7);
        assert_eq!(h.degree(3), 2);
        assert_eq!(h.active(), 2);
        assert_eq!(h.max_degree_bound(), 3); // bucket [2,4) -> bound 3
        h.remove(3);
        h.remove(3);
        h.remove(7);
        assert_eq!(h, DegreeHist::default());
        assert_eq!(h.max_degree_bound(), 0);
    }

    #[test]
    fn bound_never_below_true_max() {
        let mut h = DegreeHist::default();
        for _ in 0..9 {
            h.add(0);
        }
        assert_eq!(h.degree(0), 9);
        assert!(h.max_degree_bound() >= 9);
        assert_eq!(h.max_degree_bound(), 15); // bucket [8,16)
    }

    #[test]
    fn build_matches_incremental_after_churn() {
        let mut db = university_db();
        let mut s = SummaryStats::build(&db);
        // retract a live tuple, then re-insert the (now absent) pair
        let (from, to) = (db.rels[0].from[0], db.rels[0].to[0]);
        let values = db.delete_link(0, from, to).unwrap();
        s.delete_link(0, from, to, &values);
        assert_eq!(s, SummaryStats::build(&db));
        db.insert_link(0, from, to, &values).unwrap();
        s.insert_link(0, from, to, &values);
        assert_eq!(s, SummaryStats::build(&db));
        // grow a population
        let n_attrs = db.entities[0].cols.len();
        let ev = vec![0; n_attrs];
        db.insert_entity(0, &ev).unwrap();
        s.insert_entity(0, &ev);
        assert_eq!(s, SummaryStats::build(&db));
    }

    #[test]
    fn single_rel_chains_are_exact() {
        let db = university_db();
        let s = SummaryStats::build(&db);
        let e = s.chain_estimate(&db.schema, &[0]);
        assert!(e.exact);
        assert_eq!(e.value as u64, db.rels[0].len() as u64);
        assert_eq!(e.lo, e.hi);
    }

    #[test]
    fn multi_rel_hi_covers_truth() {
        let db = university_db();
        let s = SummaryStats::build(&db);
        let order = crate::meta::extract::plan_chain(&db, &[0, 1]).unwrap().join_order;
        let e = s.chain_estimate(&db.schema, &order);
        assert!(!e.exact);
        let t = truth(&db, &[0, 1]) as f64;
        assert!(e.hi >= t, "hi {} < truth {t}", e.hi);
        assert_eq!(e.lo, 0.0);
        assert!(e.value > 0.0);
    }

    #[test]
    fn empty_relationship_is_exact_zero() {
        let mut s = SummaryStats::default();
        s.rels.push(RelSummary::default());
        s.rels.push(RelSummary { rows: 5, ..Default::default() });
        let db = university_db();
        let e = s.chain_estimate(&db.schema, &[1, 0]);
        assert!(e.exact);
        assert_eq!(e.value, 0.0);
    }

    #[test]
    fn selectivities_sum_to_one() {
        let db = university_db();
        let s = SummaryStats::build(&db);
        for (rel, t) in db.rels.iter().enumerate() {
            for a in 0..t.cols.len() {
                let total: f64 = s.rels[rel].attr_counts[a]
                    .keys()
                    .map(|&v| s.rel_selectivity(rel, a, v))
                    .sum();
                assert!((total - 1.0).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn within_bound_predicate() {
        let exact = Estimate { value: 5.0, lo: 5.0, hi: 5.0, cap: 5.0, exact: true, walks: 0 };
        assert!(within_bound(&exact, 0.0));
        let wide = Estimate { value: 10.0, lo: 0.0, hi: 100.0, cap: 100.0, exact: false, walks: 0 };
        assert!(!within_bound(&wide, 1.0));
        assert!(within_bound(&wide, 10.0));
        assert!(within_bound(&wide, f64::INFINITY));
    }
}
