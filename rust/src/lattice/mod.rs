//! The relationship lattice (paper Figure 2).
//!
//! Chains of relationships form a lattice that structures both the
//! pre-counting phase (one positive ct-table per lattice point) and the
//! learn-and-join model search.  Points are *connected* relationship
//! subsets up to a maximum chain length (default 3, matching FACTORBASE).

pub mod pattern;

use crate::util::fxhash::FxHashMap;

use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::extract::vars_for_chain;
use crate::meta::rvar::RVar;

pub use pattern::PatternClass;

/// One lattice point: a connected relationship chain.
#[derive(Clone, Debug)]
pub struct LatticePoint {
    pub id: usize,
    /// Sorted relationship ids.
    pub rels: Vec<usize>,
    /// Sorted entity types touched by the chain.
    pub pops: Vec<usize>,
    /// Non-indicator variables of the chain (entity attrs of `pops` +
    /// rel attrs of `rels`).
    pub attr_vars: Vec<RVar>,
    /// Chain length = number of relationships.
    pub length: usize,
    /// Ids of the points directly below (one relationship removed).
    pub below: Vec<usize>,
    /// Shape of the point's entity-type multigraph (chain, star,
    /// triangle, …) — see [`pattern::classify`].
    pub pattern: PatternClass,
}

impl LatticePoint {
    /// All variables of the point's *complete* ct-table: indicators of
    /// its rels plus its attribute variables.
    pub fn all_vars(&self) -> Vec<RVar> {
        let mut vs: Vec<RVar> =
            self.rels.iter().map(|&rel| RVar::RelInd { rel }).collect();
        vs.extend(self.attr_vars.iter().copied());
        vs
    }
}

/// The relationship lattice.
#[derive(Clone, Debug)]
pub struct Lattice {
    /// Points sorted by (length, rels).
    pub points: Vec<LatticePoint>,
    by_rels: FxHashMap<Vec<usize>, usize>,
    pub max_length: usize,
}

impl Lattice {
    /// Build all connected chains up to `max_length` relationships.
    pub fn build(schema: &Schema, max_length: usize) -> Result<Self> {
        if max_length == 0 {
            return Err(Error::Schema("max_length must be >= 1".into()));
        }
        let n_rels = schema.relationships.len();
        let mut chains: Vec<Vec<usize>> = Vec::new();
        let mut seen: FxHashMap<Vec<usize>, ()> = FxHashMap::default();
        // length 1
        for r in 0..n_rels {
            chains.push(vec![r]);
            seen.insert(vec![r], ());
        }
        // extend
        let mut frontier: Vec<Vec<usize>> = chains.clone();
        for _len in 2..=max_length {
            let mut next = Vec::new();
            for chain in &frontier {
                let pops = schema.populations_of(chain);
                for r in 0..n_rels {
                    if chain.contains(&r) {
                        continue;
                    }
                    let (a, b) = schema.rel_endpoints(r);
                    if !pops.contains(&a) && !pops.contains(&b) {
                        continue; // stay connected
                    }
                    let mut ext = chain.clone();
                    ext.push(r);
                    ext.sort_unstable();
                    if seen.insert(ext.clone(), ()).is_none() {
                        chains.push(ext.clone());
                        next.push(ext);
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            frontier = next;
        }
        chains.sort_by_key(|c| (c.len(), c.clone()));

        let mut by_rels = FxHashMap::default();
        let mut points = Vec::with_capacity(chains.len());
        for (id, rels) in chains.into_iter().enumerate() {
            by_rels.insert(rels.clone(), id);
            points.push(LatticePoint {
                id,
                pops: schema.populations_of(&rels),
                attr_vars: vars_for_chain(schema, &rels),
                length: rels.len(),
                below: Vec::new(),
                pattern: pattern::classify(schema, &rels),
                rels,
            });
        }
        // subset links (one rel removed)
        let below_of = |rels: &[usize], by: &FxHashMap<Vec<usize>, usize>| {
            let mut out = Vec::new();
            if rels.len() > 1 {
                for skip in 0..rels.len() {
                    let sub: Vec<usize> = rels
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| i != skip)
                        .map(|(_, &r)| r)
                        .collect();
                    if let Some(&id) = by.get(&sub) {
                        out.push(id);
                    }
                }
            }
            out
        };
        for p in &mut points {
            p.below = below_of(&p.rels, &by_rels);
        }
        Ok(Lattice { points, by_rels, max_length })
    }

    /// Look up a point by its (sorted) relationship set.
    pub fn point(&self, rels: &[usize]) -> Option<&LatticePoint> {
        let mut key = rels.to_vec();
        key.sort_unstable();
        self.by_rels.get(&key).map(|&id| &self.points[id])
    }

    /// Smallest lattice point whose relationship set covers `rels` and
    /// whose populations cover `pops`.  Points are stored by ascending
    /// length, so the first hit is minimal.
    pub fn covering_point(&self, rels: &[usize], pops: &[usize]) -> Option<&LatticePoint> {
        self.points.iter().find(|p| {
            rels.iter().all(|r| p.rels.contains(r))
                && pops.iter().all(|e| p.pops.contains(e))
        })
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Per-point cost estimate for shard balancing.  Both the chain JOIN
    /// and the per-point Möbius Join grow exponentially with chain
    /// length (more subsets, wider tables), so length dominates any
    /// finer-grained estimate.
    pub fn point_costs(&self) -> Vec<u64> {
        self.points.iter().map(|p| 1u64 << (2 * p.length.min(30))).collect()
    }

    /// Deterministically partition the point ids into `n_shards` disjoint
    /// lists, balanced by chain length (longest-processing-time greedy,
    /// [`crate::coordinator::shard::lpt_partition`]).  Every point
    /// appears in exactly one shard; within a shard, ids are ascending.
    /// The partition depends only on the lattice shape and `n_shards`,
    /// never on timing or hashing, so parallel runs shard identically
    /// across executions.
    pub fn partition_by_length(&self, n_shards: usize) -> Vec<Vec<usize>> {
        crate::coordinator::shard::lpt_partition(&self.point_costs(), n_shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;
    use crate::db::schema::{Attribute, EntityType, RelationshipType};

    #[test]
    fn university_lattice() {
        let s = university_schema();
        let l = Lattice::build(&s, 3).unwrap();
        // chains: {RA}, {Registered}, {RA, Registered}
        assert_eq!(l.len(), 3);
        assert_eq!(l.points[0].length, 1);
        assert_eq!(l.points[2].rels, vec![0, 1]);
        assert_eq!(l.points[2].pops, vec![0, 1, 2]);
        assert_eq!(l.points[2].below.len(), 2);
        assert_eq!(l.points[0].pattern, PatternClass::Single);
        assert_eq!(l.points[2].pattern, PatternClass::Chain);
    }

    #[test]
    fn lattice_contains_cyclic_points_when_schema_has_them() {
        // triangle schema: three pairwise relationships over A, B, C
        let s = Schema::new(
            vec![
                EntityType { name: "A".into(), attrs: vec![] },
                EntityType { name: "B".into(), attrs: vec![] },
                EntityType { name: "C".into(), attrs: vec![] },
            ],
            vec![
                RelationshipType { name: "R0".into(), from: 0, to: 1, attrs: vec![] },
                RelationshipType { name: "R1".into(), from: 1, to: 2, attrs: vec![] },
                RelationshipType { name: "R2".into(), from: 0, to: 2, attrs: vec![] },
            ],
        )
        .unwrap();
        let l = Lattice::build(&s, 3).unwrap();
        let top = l.point(&[0, 1, 2]).unwrap();
        assert_eq!(top.pattern, PatternClass::Triangle);
        assert!(top.pattern.is_cyclic());
        assert_eq!(l.point(&[0, 1]).unwrap().pattern, PatternClass::Chain);
    }

    #[test]
    fn covering_point_minimal() {
        let s = university_schema();
        let l = Lattice::build(&s, 3).unwrap();
        let p = l.covering_point(&[0], &[0, 1]).unwrap();
        assert_eq!(p.rels, vec![0]);
        // needs Course population too -> the 2-chain
        let p2 = l.covering_point(&[0], &[0, 1, 2]).unwrap();
        assert_eq!(p2.rels, vec![0, 1]);
        assert!(l.covering_point(&[5], &[]).is_none());
    }

    #[test]
    fn max_length_respected() {
        let s = university_schema();
        let l = Lattice::build(&s, 1).unwrap();
        assert_eq!(l.len(), 2);
        assert!(l.point(&[0, 1]).is_none());
    }

    #[test]
    fn disconnected_rels_not_chained() {
        // two relationships with no shared entity type
        let s = Schema::new(
            vec![
                EntityType { name: "A".into(), attrs: vec![] },
                EntityType { name: "B".into(), attrs: vec![] },
                EntityType { name: "C".into(), attrs: vec![] },
                EntityType { name: "D".into(), attrs: vec![Attribute::new("x", 2)] },
            ],
            vec![
                RelationshipType { name: "R1".into(), from: 0, to: 1, attrs: vec![] },
                RelationshipType { name: "R2".into(), from: 2, to: 3, attrs: vec![] },
            ],
        )
        .unwrap();
        let l = Lattice::build(&s, 3).unwrap();
        assert_eq!(l.len(), 2); // no {R1, R2} point
        assert!(l.point(&[0, 1]).is_none());
    }

    #[test]
    fn partition_covers_points_exactly_once() {
        let s = university_schema();
        let l = Lattice::build(&s, 3).unwrap();
        for n in [1usize, 2, 4, 7] {
            let shards = l.partition_by_length(n);
            assert_eq!(shards.len(), n);
            let mut seen: Vec<usize> = shards.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..l.len()).collect::<Vec<_>>(), "n={n}");
            // deterministic: same call, same answer
            assert_eq!(shards, l.partition_by_length(n));
        }
    }

    #[test]
    fn partition_spreads_long_chains() {
        let s = university_schema();
        let l = Lattice::build(&s, 3).unwrap();
        // 3 points (two 1-chains + one 2-chain) over 2 shards: the costly
        // 2-chain must sit alone against the two cheap 1-chains.
        let shards = l.partition_by_length(2);
        let len_of = |ids: &Vec<usize>| -> usize {
            ids.iter().map(|&i| l.points[i].length).max().unwrap_or(0)
        };
        assert_eq!(len_of(&shards[0]).max(len_of(&shards[1])), 2);
        let two_chain_shard =
            shards.iter().position(|ids| ids.iter().any(|&i| l.points[i].length == 2));
        let solo = &shards[two_chain_shard.unwrap()];
        assert_eq!(solo.len(), 1, "the 2-chain should not share a shard: {shards:?}");
    }

    #[test]
    fn all_vars_include_indicators() {
        let s = university_schema();
        let l = Lattice::build(&s, 3).unwrap();
        let top = l.point(&[0, 1]).unwrap();
        let vars = top.all_vars();
        assert!(vars.contains(&RVar::RelInd { rel: 0 }));
        assert!(vars.contains(&RVar::RelInd { rel: 1 }));
        assert_eq!(vars.len(), 2 + top.attr_vars.len());
    }
}
