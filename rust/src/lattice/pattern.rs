//! Join-pattern classification for lattice points.
//!
//! The lattice builder enumerates every *connected* relationship subset
//! up to the length cap, so beyond simple paths the lattice contains
//! stars, triangles, longer cycles and small cliques.  This module
//! names those shapes: the class of a point is the shape of its
//! entity-type multigraph (nodes = populations, edges = relationships).
//! The WCOJ kernel's advantage is shape-dependent — cyclic classes are
//! exactly where binary chain plans hit the AGM gap — so `exp wcoj`
//! groups its measurements by [`PatternClass`].

use crate::db::schema::Schema;

/// Shape of a connected relationship subset's entity-type multigraph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PatternClass {
    /// One relationship (a lattice atom).
    Single,
    /// A simple path: acyclic, every node on at most two relationships.
    Chain,
    /// Acyclic with one center on every relationship, leaves elsewhere.
    Star,
    /// Acyclic but neither a path nor a star.
    Tree,
    /// The 3-cycle on three distinct entity types.
    Triangle,
    /// A single cycle that is not a triangle (including the 2-cycle of
    /// parallel relationships over the same endpoint pair).
    Cycle,
    /// Complete simple graph on four or more entity types.
    Clique,
    /// Anything denser or more irregular.
    General,
}

impl PatternClass {
    pub fn name(&self) -> &'static str {
        match self {
            PatternClass::Single => "single",
            PatternClass::Chain => "chain",
            PatternClass::Star => "star",
            PatternClass::Tree => "tree",
            PatternClass::Triangle => "triangle",
            PatternClass::Cycle => "cycle",
            PatternClass::Clique => "clique",
            PatternClass::General => "general",
        }
    }

    /// Classes where a binary join plan can enumerate intermediates
    /// asymptotically larger than the output (the WCOJ target set).
    pub fn is_cyclic(&self) -> bool {
        matches!(
            self,
            PatternClass::Triangle | PatternClass::Cycle | PatternClass::Clique
        )
    }
}

/// Classify a *connected* relationship subset (a lattice point's
/// `rels`).  Degree arguments over the entity-type multigraph decide
/// every class, so parallel relationships between the same endpoint
/// pair are handled uniformly: connected with `m` edges over `n` nodes,
/// `m == n - 1` means acyclic, `m == n` with all degrees 2 means a
/// single cycle, and anything denser falls through to clique/general.
pub fn classify(schema: &Schema, rels: &[usize]) -> PatternClass {
    let m = rels.len();
    if m <= 1 {
        return PatternClass::Single;
    }
    let pops = schema.populations_of(rels);
    let n = pops.len();
    let node = |et: usize| pops.binary_search(&et).expect("endpoint in pops");
    let mut deg = vec![0usize; n];
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(m);
    for &r in rels {
        let (a, b) = schema.rel_endpoints(r);
        let (a, b) = (node(a), node(b));
        deg[a] += 1;
        deg[b] += 1;
        pairs.push((a.min(b), a.max(b)));
    }
    pairs.sort_unstable();
    pairs.dedup();
    let simple = pairs.len() == m;
    if m + 1 == n {
        // acyclic (a tree); leaves have degree 1
        let leaves = deg.iter().filter(|&&d| d == 1).count();
        if deg.iter().all(|&d| d <= 2) {
            PatternClass::Chain
        } else if leaves == n - 1 {
            PatternClass::Star
        } else {
            PatternClass::Tree
        }
    } else if m == n && deg.iter().all(|&d| d == 2) {
        if n == 3 {
            PatternClass::Triangle
        } else {
            PatternClass::Cycle
        }
    } else if simple && 2 * m == n * (n - 1) && deg.iter().all(|&d| d == n - 1) {
        PatternClass::Clique
    } else {
        PatternClass::General
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::schema::{EntityType, RelationshipType};

    fn schema_with(n_ets: usize, edges: &[(usize, usize)]) -> Schema {
        let ets = (0..n_ets)
            .map(|i| EntityType { name: format!("E{i}"), attrs: vec![] })
            .collect();
        let rels = edges
            .iter()
            .enumerate()
            .map(|(i, &(a, b))| RelationshipType {
                name: format!("R{i}"),
                from: a,
                to: b,
                attrs: vec![],
            })
            .collect();
        Schema::new(ets, rels).unwrap()
    }

    #[test]
    fn classifies_acyclic_shapes() {
        let s = schema_with(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(classify(&s, &[0]), PatternClass::Single);
        assert_eq!(classify(&s, &[0, 1]), PatternClass::Chain);
        assert_eq!(classify(&s, &[0, 1, 2]), PatternClass::Chain);
        let star = schema_with(4, &[(1, 0), (0, 2), (0, 3)]);
        assert_eq!(classify(&star, &[0, 1, 2]), PatternClass::Star);
        // two edges from a 3-star still form a chain through the center
        assert_eq!(classify(&star, &[0, 1]), PatternClass::Chain);
        let tree = schema_with(5, &[(0, 1), (1, 2), (1, 3), (3, 4)]);
        assert_eq!(classify(&tree, &[0, 1, 2, 3]), PatternClass::Tree);
    }

    #[test]
    fn classifies_cyclic_shapes() {
        let tri = schema_with(3, &[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(classify(&tri, &[0, 1, 2]), PatternClass::Triangle);
        assert!(classify(&tri, &[0, 1, 2]).is_cyclic());
        let square = schema_with(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]);
        assert_eq!(classify(&square, &[0, 1, 2, 3]), PatternClass::Cycle);
        // parallel relationships over one endpoint pair: a 2-cycle
        let par = schema_with(2, &[(0, 1), (0, 1)]);
        assert_eq!(classify(&par, &[0, 1]), PatternClass::Cycle);
        let k4 = schema_with(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        assert_eq!(classify(&k4, &[0, 1, 2, 3, 4, 5]), PatternClass::Clique);
        // triangle plus a pendant edge: cyclic but no single class fits
        let lollipop = schema_with(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        assert_eq!(classify(&lollipop, &[0, 1, 2, 3]), PatternClass::General);
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(PatternClass::Triangle.name(), "triangle");
        assert_eq!(PatternClass::Chain.name(), "chain");
        assert!(!PatternClass::Chain.is_cyclic());
    }
}
