//! Scoring backends: where BDeu family scores are computed.
//!
//! The search evaluates whole hill-climb neighborhoods at once, so the
//! backend receives *batches* of family count matrices:
//!
//! - [`RustBackend`] — the in-process scalar scorer (`ln_gamma` loops).
//! - [`XlaBackend`]  — the AOT-compiled Pallas kernel via PJRT, dispatched
//!   through the micro-batcher (`bdeu_batch` artifact, 64 families per
//!   dispatch).  Families exceeding the artifact's padded (q, r) fall
//!   back to the Rust scalar path transparently.
//!
//! Both backends are cross-checked to 1e-9 in `rust/tests/
//! runtime_artifacts.rs`; the `kernels` bench measures the tradeoff
//! (on CPU-PJRT the dispatch overhead dominates; on a real accelerator
//! the batched path is the point — see DESIGN.md §Perf).

use crate::error::Result;
use crate::learn::score::ln_gamma;
use crate::runtime::batcher::{FamilyCounts, ScoreBatcher};
use crate::runtime::client::Runtime;

/// A batched BDeu scorer.
pub trait ScoreBackend {
    fn name(&self) -> &'static str;
    /// Scores for a batch of family count matrices (Eq. 1 without the
    /// structure prior).
    fn scores(&mut self, reqs: &[FamilyCounts]) -> Result<Vec<f64>>;
}

/// Scalar BDeu on a dense (q, r) matrix.  Errors on degenerate shapes
/// (q or r zero) instead of scoring with NaN/inf alphas.
pub fn bdeu_matrix(req: &FamilyCounts) -> Result<f64> {
    let ar = req.alpha_row()?;
    let ac = req.alpha_cell()?;
    let lg_ar = ln_gamma(ar);
    let lg_ac = ln_gamma(ac);
    let mut s = 0.0;
    for j in 0..req.q {
        let row = &req.counts[j * req.r..(j + 1) * req.r];
        let nij: f64 = row.iter().sum();
        if nij > 0.0 {
            s += lg_ar - ln_gamma(nij + ar);
            for &c in row {
                if c > 0.0 {
                    s += ln_gamma(c + ac) - lg_ac;
                }
            }
        }
    }
    Ok(s)
}

/// The in-process scorer.
#[derive(Default)]
pub struct RustBackend;

impl ScoreBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn scores(&mut self, reqs: &[FamilyCounts]) -> Result<Vec<f64>> {
        reqs.iter().map(bdeu_matrix).collect()
    }
}

/// The PJRT-backed scorer (owns its runtime; not `Send`).
pub struct XlaBackend {
    rt: Runtime,
    /// Families scored through the artifact vs via scalar fallback.
    pub xla_scored: u64,
    pub fallback_scored: u64,
    pub dispatches: u64,
}

impl XlaBackend {
    /// Load artifacts from the default directory (`RELCOUNT_ARTIFACTS`
    /// or `./artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load(&crate::runtime::default_artifact_dir())
    }

    pub fn load(dir: &std::path::Path) -> Result<Self> {
        Ok(XlaBackend {
            rt: Runtime::load(dir)?,
            xla_scored: 0,
            fallback_scored: 0,
            dispatches: 0,
        })
    }
}

impl ScoreBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn scores(&mut self, reqs: &[FamilyCounts]) -> Result<Vec<f64>> {
        let mut batcher = ScoreBatcher::new(&self.rt)?;
        let mut out = vec![0.0; reqs.len()];
        // split: artifact-sized families go through PJRT, the rest scalar
        let mut xla_idx = Vec::new();
        let mut xla_reqs = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            if batcher.fits(req.q, req.r) {
                xla_idx.push(i);
                xla_reqs.push(req.clone());
            } else {
                out[i] = bdeu_matrix(req)?;
                self.fallback_scored += 1;
            }
        }
        if !xla_reqs.is_empty() {
            let scores = batcher.score_all(&xla_reqs)?;
            for (i, s) in xla_idx.into_iter().zip(scores) {
                out[i] = s;
            }
            self.xla_scored += xla_reqs.len() as u64;
            self.dispatches += batcher.dispatches;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_backend_matches_scalar() {
        let req = FamilyCounts {
            counts: vec![3.0, 0.0, 5.0, 2.0, 1.0, 0.0],
            q: 3,
            r: 2,
            n_prime: 1.0,
        };
        let mut b = RustBackend;
        let got = b.scores(std::slice::from_ref(&req)).unwrap()[0];
        assert!((got - bdeu_matrix(&req).unwrap()).abs() < 1e-15);
        assert_eq!(b.name(), "rust");
    }

    #[test]
    fn bdeu_matrix_zero_counts() {
        let req = FamilyCounts { counts: vec![0.0; 8], q: 4, r: 2, n_prime: 2.0 };
        assert_eq!(bdeu_matrix(&req).unwrap(), 0.0);
    }

    #[test]
    fn degenerate_family_is_a_typed_error_not_nan() {
        let req = FamilyCounts { counts: vec![], q: 0, r: 2, n_prime: 1.0 };
        assert!(bdeu_matrix(&req).is_err());
        let mut b = RustBackend;
        assert!(b.scores(std::slice::from_ref(&req)).is_err());
    }
}
