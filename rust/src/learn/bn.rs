//! First-order Bayesian networks: a DAG over first-order random
//! variables, with the MP/N statistic of the paper's Table 4.

use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::family::Family;
use crate::meta::rvar::RVar;

/// A directed graphical model over first-order variables.
#[derive(Clone, Debug, Default)]
pub struct Bn {
    /// Node variables (stable order).
    pub nodes: Vec<RVar>,
    /// `parents[i]` = indexes into `nodes` (sorted).
    pub parents: Vec<Vec<usize>>,
}

impl Bn {
    pub fn new(nodes: Vec<RVar>) -> Self {
        let n = nodes.len();
        Bn { nodes, parents: vec![Vec::new(); n] }
    }

    pub fn node_pos(&self, v: &RVar) -> Option<usize> {
        self.nodes.iter().position(|n| n == v)
    }

    /// Add a node if not present; returns its index.
    pub fn ensure_node(&mut self, v: RVar) -> usize {
        if let Some(i) = self.node_pos(&v) {
            return i;
        }
        self.nodes.push(v);
        self.parents.push(Vec::new());
        self.nodes.len() - 1
    }

    pub fn has_edge(&self, parent: usize, child: usize) -> bool {
        self.parents[child].contains(&parent)
    }

    /// Add `parent -> child`; fails on self-loops, duplicates, cycles.
    pub fn add_edge(&mut self, parent: usize, child: usize) -> Result<()> {
        if parent == child {
            return Err(Error::Learn("self-loop".into()));
        }
        if self.has_edge(parent, child) {
            return Err(Error::Learn("duplicate edge".into()));
        }
        if self.reaches(child, parent) {
            return Err(Error::Learn("edge would create a cycle".into()));
        }
        self.parents[child].push(parent);
        self.parents[child].sort_unstable();
        Ok(())
    }

    pub fn remove_edge(&mut self, parent: usize, child: usize) -> Result<()> {
        let before = self.parents[child].len();
        self.parents[child].retain(|&p| p != parent);
        if self.parents[child].len() == before {
            return Err(Error::Learn("no such edge".into()));
        }
        Ok(())
    }

    /// Is `to` reachable from `from` along directed edges
    /// (parent -> child direction)?
    pub fn reaches(&self, from: usize, to: usize) -> bool {
        if from == to {
            return true;
        }
        // children adjacency on the fly (graphs here are small)
        let mut stack = vec![from];
        let mut seen = vec![false; self.nodes.len()];
        seen[from] = true;
        while let Some(x) = stack.pop() {
            for (c, ps) in self.parents.iter().enumerate() {
                if ps.contains(&x) && !seen[c] {
                    if c == to {
                        return true;
                    }
                    seen[c] = true;
                    stack.push(c);
                }
            }
        }
        false
    }

    pub fn n_edges(&self) -> usize {
        self.parents.iter().map(|p| p.len()).sum()
    }

    /// Mean number of parents per node — Table 4's MP/N.
    pub fn mean_parents_per_node(&self) -> f64 {
        if self.nodes.is_empty() {
            return 0.0;
        }
        self.n_edges() as f64 / self.nodes.len() as f64
    }

    /// The family of a node (child + its parents).
    pub fn family(&self, child: usize) -> Family {
        Family::new(
            self.nodes[child],
            self.parents[child].iter().map(|&p| self.nodes[p]).collect(),
        )
    }

    /// All families.
    pub fn families(&self) -> Vec<Family> {
        (0..self.nodes.len()).map(|i| self.family(i)).collect()
    }

    /// Human-readable listing.
    pub fn display(&self, schema: &Schema) -> String {
        let mut out = String::new();
        for i in 0..self.nodes.len() {
            out.push_str(&self.family(i).display(schema));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;

    fn nodes() -> Vec<RVar> {
        vec![
            RVar::EntityAttr { et: 0, attr: 0 },
            RVar::EntityAttr { et: 1, attr: 0 },
            RVar::RelInd { rel: 0 },
        ]
    }

    #[test]
    fn edges_and_cycles() {
        let mut bn = Bn::new(nodes());
        bn.add_edge(0, 1).unwrap();
        bn.add_edge(1, 2).unwrap();
        assert!(bn.add_edge(2, 0).is_err()); // cycle
        assert!(bn.add_edge(0, 0).is_err());
        assert!(bn.add_edge(0, 1).is_err()); // dup
        assert_eq!(bn.n_edges(), 2);
        bn.remove_edge(0, 1).unwrap();
        assert!(bn.remove_edge(0, 1).is_err());
        assert_eq!(bn.n_edges(), 1);
    }

    #[test]
    fn mpn() {
        let mut bn = Bn::new(nodes());
        assert_eq!(bn.mean_parents_per_node(), 0.0);
        bn.add_edge(0, 2).unwrap();
        bn.add_edge(1, 2).unwrap();
        assert!((bn.mean_parents_per_node() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn families_and_display() {
        let s = university_schema();
        let mut bn = Bn::new(nodes());
        bn.add_edge(0, 2).unwrap();
        let fam = bn.family(2);
        assert_eq!(fam.child, RVar::RelInd { rel: 0 });
        assert_eq!(fam.parents.len(), 1);
        let d = bn.display(&s);
        assert!(d.contains("RA(P,S) <- popularity(P)"));
    }

    #[test]
    fn ensure_node_idempotent() {
        let mut bn = Bn::new(vec![]);
        let a = bn.ensure_node(RVar::RelInd { rel: 0 });
        let b = bn.ensure_node(RVar::RelInd { rel: 0 });
        assert_eq!(a, b);
        assert_eq!(bn.nodes.len(), 1);
    }
}
