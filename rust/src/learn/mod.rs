//! First-order Bayesian-network structure learning: the BDeu score
//! (Equation 1 of the paper) computed from ct-tables, and the
//! learn-and-join lattice search (Schulte & Khosravi 2012) that generates
//! the family-counting workload the three strategies serve.

pub mod backend;
pub mod bn;
pub mod score;
pub mod search;

pub use backend::{RustBackend, ScoreBackend, XlaBackend};
pub use bn::Bn;
pub use score::{bdeu_from_ct, ln_gamma};
pub use search::{learn, learn_with_backend, LearnedModel, SearchConfig};
