//! BDeu scoring from ct-tables (paper Equation 1, Table 1), plus the
//! `ln Γ` implementation it rests on.
//!
//! The same score has two other implementations in this stack: the
//! pure-jnp reference (`python/compile/kernels/ref.py`) and the Pallas
//! kernel behind the `bdeu_batch` XLA artifact; `rust/tests/
//! runtime_artifacts.rs` cross-checks all three.

use crate::util::fxhash::FxHashMap;

use crate::ct::cttable::CtTable;
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;

/// `ln Γ(x)` for `x > 0` via the Lanczos approximation (g = 7, n = 9),
/// accurate to ~1e-13 relative — matching `jax.lax.lgamma` well within
/// the 1e-9 tolerance used by the cross-layer tests.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_59,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    debug_assert!(x > 0.0, "ln_gamma({x})");
    if x < 0.5 {
        // reflection: Γ(x) Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// BDeu family score from a complete family ct-table.
///
/// `child` must be a column of `ct`; all other columns are the parents.
/// `n_prime` is the equivalent sample size N'.  The structure prior
/// `log P(B)` is *not* included (the search adds it).
///
/// q_i is the full parent configuration space (product of parent dims,
/// N/A values included), exactly as in the paper's Table 1.
pub fn bdeu_from_ct(ct: &CtTable, child: &RVar, n_prime: f64) -> Result<f64> {
    let child_pos = ct.var_pos(child)?;
    let r = ct.dims[child_pos] as f64;
    let q: f64 = ct
        .dims
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != child_pos)
        .map(|(_, &d)| d as f64)
        .product();
    if n_prime <= 0.0 {
        return Err(Error::Learn(format!("n_prime must be positive, got {n_prime}")));
    }
    let alpha_row = n_prime / q;
    let alpha_cell = n_prime / (q * r);

    // Single pass: cell terms directly, parent-config sums N_ij for the
    // row terms.  Parent key: strip the child column out of the flat key.
    let child_stride = ct.stride(child_pos);
    let child_dim = ct.dims[child_pos] as u128;
    let mut nij: FxHashMap<u128, i128> = FxHashMap::default();
    let mut score = 0.0;
    let lg_ac = ln_gamma(alpha_cell);
    for (key, count) in ct.iter_keys() {
        if count < 0 {
            return Err(Error::Learn("negative count in family ct".into()));
        }
        if count == 0 {
            continue;
        }
        score += ln_gamma(count as f64 + alpha_cell) - lg_ac;
        // remove the child digit from the mixed-radix key
        let low = key % child_stride;
        let high = key / (child_stride * child_dim);
        *nij.entry(high * child_stride + low).or_insert(0) += count;
    }
    let lg_ar = ln_gamma(alpha_row);
    for (_, n) in nij {
        score += lg_ar - ln_gamma(n as f64 + alpha_row);
    }
    Ok(score)
}

/// Largest dense (q x r) matrix worth materializing for the batched
/// backends; families beyond this stay on the sparse scalar path.
pub const MAX_MATRIX_CELLS: usize = 1 << 20;

/// Densify a family ct-table into the (parent-config, child-value) count
/// matrix consumed by the batched score backends, or `None` when the
/// parent configuration space is too large to materialize.
pub fn family_matrix(
    ct: &CtTable,
    child: &RVar,
    n_prime: f64,
) -> Result<Option<crate::runtime::batcher::FamilyCounts>> {
    let child_pos = ct.var_pos(child)?;
    let r = ct.dims[child_pos] as usize;
    let mut q: usize = 1;
    for (i, &d) in ct.dims.iter().enumerate() {
        if i != child_pos {
            q = match q.checked_mul(d as usize) {
                Some(v) if v * r <= MAX_MATRIX_CELLS => v,
                _ => return Ok(None),
            };
        }
    }
    let mut counts = vec![0.0; q * r];
    for (vals, c) in ct.iter_rows() {
        let mut j = 0usize;
        for (i, v) in vals.iter().enumerate() {
            if i != child_pos {
                j = j * ct.dims[i] as usize + *v as usize;
            }
        }
        counts[j * r + vals[child_pos] as usize] += c as f64;
    }
    Ok(Some(crate::runtime::batcher::FamilyCounts { counts, q, r, n_prime }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    #[test]
    fn family_matrix_agrees_with_sparse_scorer() {
        let db = university_db();
        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ];
        let ct = brute_force_complete(&db, &vars, &[0, 1]).unwrap();
        let child = RVar::EntityAttr { et: 1, attr: 0 };
        let m = family_matrix(&ct, &child, 1.0).unwrap().unwrap();
        assert_eq!(m.q, 2 * 4);
        assert_eq!(m.r, 3);
        let via_matrix = crate::learn::backend::bdeu_matrix(&m).unwrap();
        let via_sparse = bdeu_from_ct(&ct, &child, 1.0).unwrap();
        assert!((via_matrix - via_sparse).abs() < 1e-9);
    }

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1) = Γ(2) = 1, Γ(5) = 24, Γ(0.5) = sqrt(π)
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-11);
        let sqrt_pi = std::f64::consts::PI.sqrt();
        assert!((ln_gamma(0.5) - sqrt_pi.ln()).abs() < 1e-12);
        // recurrence Γ(x+1) = x Γ(x) across magnitudes
        for &x in &[0.1, 0.7, 1.3, 4.5, 20.0, 123.456, 1e6] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = x.ln() + ln_gamma(x);
            assert!((lhs - rhs).abs() < 1e-9 * lhs.abs().max(1.0), "x={x}");
        }
    }

    /// Transparent scalar re-derivation (mirrors ref.bdeu_scalar_ref).
    fn bdeu_scalar(counts: &[Vec<i128>], ar: f64, ac: f64) -> f64 {
        let mut total = 0.0;
        for row in counts {
            let nij: i128 = row.iter().sum();
            if nij <= 0 {
                continue;
            }
            total += ln_gamma(ar) - ln_gamma(nij as f64 + ar);
            for &c in row {
                if c > 0 {
                    total += ln_gamma(c as f64 + ac) - ln_gamma(ac);
                }
            }
        }
        total
    }

    #[test]
    fn matches_scalar_reference_on_family() {
        let db = university_db();
        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 }, // salary = child
            RVar::EntityAttr { et: 1, attr: 0 },
        ];
        let ct = brute_force_complete(&db, &vars, &[0, 1]).unwrap();
        let child = RVar::RelAttr { rel: 0, attr: 1 };
        let n_prime = 1.0;
        let got = bdeu_from_ct(&ct, &child, n_prime).unwrap();

        // rebuild the (q, r) matrix by hand: parents = {RA, intelligence}
        let q = 2 * 3;
        let r = 4;
        let mut m = vec![vec![0i128; r]; q];
        for (v, c) in ct.iter_rows() {
            let j = (v[0] * 3 + v[2]) as usize;
            m[j][v[1] as usize] += c;
        }
        let want = bdeu_scalar(&m, n_prime / q as f64, n_prime / (q * r) as f64);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn empty_table_scores_zero() {
        let db = university_db();
        let ct = CtTable::new(
            &db.schema,
            vec![RVar::RelInd { rel: 0 }, RVar::EntityAttr { et: 0, attr: 0 }],
        )
        .unwrap();
        let s = bdeu_from_ct(&ct, &RVar::RelInd { rel: 0 }, 1.0).unwrap();
        assert_eq!(s, 0.0);
    }

    #[test]
    fn more_parents_can_lower_score() {
        // adding an independent random parent should not raise the score
        let db = university_db();
        let child = RVar::EntityAttr { et: 1, attr: 0 };
        let small = brute_force_complete(&db, &[child], &[1]).unwrap();
        let s_small = bdeu_from_ct(&small, &child, 1.0).unwrap();
        let big = brute_force_complete(
            &db,
            &[child, RVar::EntityAttr { et: 0, attr: 0 }],
            &[0, 1],
        )
        .unwrap();
        let s_big = bdeu_from_ct(&big, &child, 1.0).unwrap();
        // counts in `big` are over P x S (larger grounding), so compare
        // against the same child marginal recomputed in that context
        let small_ctx =
            brute_force_complete(&db, &[child], &[0, 1]).unwrap();
        let s_small_ctx = bdeu_from_ct(&small_ctx, &child, 1.0).unwrap();
        assert!(s_big <= s_small_ctx + 1e-9);
        let _ = s_small;
    }

    #[test]
    fn rejects_bad_inputs() {
        let db = university_db();
        let ct = CtTable::new(&db.schema, vec![RVar::RelInd { rel: 0 }]).unwrap();
        assert!(bdeu_from_ct(&ct, &RVar::RelInd { rel: 1 }, 1.0).is_err());
        assert!(bdeu_from_ct(&ct, &RVar::RelInd { rel: 0 }, 0.0).is_err());
    }
}
