//! Learn-and-join structure search (Schulte & Khosravi 2012), the model
//! discovery loop whose counting workload the paper's strategies serve.
//!
//! The search proceeds bottom-up over the relationship lattice: first a
//! BN per entity table, then per lattice point in ascending chain length,
//! inheriting (and freezing) the edges learned at sub-points.  At each
//! point a greedy hill climb adds/removes edges, scoring candidate
//! families with BDeu over ct-tables served by a [`CountingStrategy`] —
//! this is exactly where PRECOUNT / ONDEMAND / HYBRID differ.

use crate::util::fxhash::{FxHashMap, FxHashSet};

use crate::db::catalog::Database;
use crate::error::Result;
use crate::lattice::Lattice;
use crate::learn::backend::{RustBackend, ScoreBackend};
use crate::learn::bn::Bn;
use crate::learn::score::{bdeu_from_ct, family_matrix};
use crate::meta::family::Family;
use crate::meta::rvar::RVar;
use crate::strategies::traits::{CountingStrategy, FamilyRequest};

/// Structure-search configuration.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// BDeu equivalent sample size N'.
    pub n_prime: f64,
    /// Maximum parents per node (the literature's typical bound is 4;
    /// see the paper's ONDEMAND discussion).
    pub max_parents: usize,
    /// Log-prior penalty per parent (the `log P(B)` term).
    pub edge_penalty: f64,
    /// Safety bound on hill-climb operations per lattice point.
    pub max_ops_per_point: usize,
    /// Maximum relationship-chain length (must match the strategy's).
    pub max_chain_length: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            n_prime: 1.0,
            max_parents: 4,
            edge_penalty: 0.0,
            max_ops_per_point: 500,
            max_chain_length: 3,
        }
    }
}

/// The output of [`learn`].
#[derive(Clone, Debug)]
pub struct LearnedModel {
    pub bn: Bn,
    /// Sum of final family scores (each in its widest learned context).
    pub total_score: f64,
    /// Distinct families sent to the counting strategy.
    pub families_scored: u64,
    /// Score-cache hits (revisited candidates).
    pub score_cache_hits: u64,
}

struct Scorer<'a, 's> {
    strategy: &'s mut dyn CountingStrategy,
    backend: &'s mut dyn ScoreBackend,
    cfg: SearchConfig,
    cache: FxHashMap<(RVar, Vec<RVar>), f64>,
    families_scored: u64,
    hits: u64,
    db: &'a Database,
    lattice: &'a Lattice,
}

impl Scorer<'_, '_> {
    /// Score a family in its *canonical* context: the populations of its
    /// minimal covering lattice point (its own populations for attr-only
    /// families).  Using a family-intrinsic context keeps the three
    /// strategies exactly interchangeable and keeps scores well-defined
    /// when hill climbing rescores families inherited from other lattice
    /// points.  (Scores of the same child over different contexts are
    /// compared during search; the implied bias against cross-population
    /// parents acts as an extra complexity penalty — see Schulte &
    /// Gholami 2017 for score consistency across relational contexts.)
    /// Language bias: families whose relationship set exceeds the
    /// lattice's maximum chain length cannot be counted by the
    /// pre-counting strategies (the paper's "if the overall number of
    /// relationships is too large ... ONDEMAND must be used"); the
    /// search simply does not propose them, as in FACTORBASE where
    /// families live inside one lattice point.
    fn admissible(&self, family: &Family) -> bool {
        family.rels().len() <= self.cfg.max_chain_length
    }

    fn score(&mut self, family: &Family) -> Result<f64> {
        Ok(self.score_batch(std::slice::from_ref(family))?[0])
    }

    /// Score a batch of families.  Cache hits are served directly; the
    /// misses' ct-tables come from the counting strategy in one
    /// [`CountingStrategy::ct_for_families`] batch (which the parallel
    /// coordinator fans out across worker shards), and the BDeu
    /// evaluation goes through the batched score backend (one PJRT
    /// dispatch per 64 families on the XLA backend).  Families whose
    /// parent-configuration space is too large to densify use the sparse
    /// scalar path.
    fn score_batch(&mut self, families: &[Family]) -> Result<Vec<f64>> {
        let mut out = vec![0.0; families.len()];
        let mut ct_idx: Vec<(usize, (RVar, Vec<RVar>))> = Vec::new();
        let mut ct_reqs: Vec<FamilyRequest> = Vec::new();
        for (i, family) in families.iter().enumerate() {
            let key = (family.child, family.parents.clone());
            if let Some(&s) = self.cache.get(&key) {
                self.hits += 1;
                out[i] = s;
                continue;
            }
            self.families_scored += 1;
            let ctx = widest_ctx(self.db, self.lattice, family);
            ct_idx.push((i, key));
            ct_reqs.push(FamilyRequest { vars: family.vars(), ctx_pops: ctx });
        }
        // The whole miss batch is materialized at once so the coordinator
        // can fan it out; residency is bounded by the neighborhood size
        // times a *family* table (small by the paper's Eq. 4 — the
        // complete lattice tables never pass through here).  Strategies'
        // peak_ct_bytes keeps its per-serve meaning and does not include
        // this learner-held batch.
        let cts = self.strategy.ct_for_families(&ct_reqs)?;
        let mut miss_idx = Vec::new();
        let mut miss_reqs = Vec::new();
        for ((i, key), ct) in ct_idx.into_iter().zip(cts) {
            let family = &families[i];
            let penalty = self.cfg.edge_penalty * family.parents.len() as f64;
            match family_matrix(&ct, &family.child, self.cfg.n_prime)? {
                Some(req) => {
                    miss_idx.push((i, key, penalty));
                    miss_reqs.push(req);
                }
                None => {
                    // parent space too large to densify: sparse path
                    let raw = bdeu_from_ct(&ct, &family.child, self.cfg.n_prime)?;
                    let s = raw - penalty;
                    self.cache.insert(key, s);
                    out[i] = s;
                }
            }
        }
        if !miss_reqs.is_empty() {
            let scores = self.backend.scores(&miss_reqs)?;
            for ((i, key, penalty), raw) in miss_idx.into_iter().zip(scores) {
                let s = raw - penalty;
                self.cache.insert(key, s);
                out[i] = s;
            }
        }
        Ok(out)
    }
}

/// Greedy hill climb over `node_ids` of `bn` in population context `ctx`,
/// with `frozen` edges immutable.  Returns the number of ops applied.
fn hill_climb(
    bn: &mut Bn,
    node_ids: &[usize],
    frozen: &FxHashSet<(usize, usize)>,
    scorer: &mut Scorer,
) -> Result<usize> {
    let mut ops = 0usize;
    // current family scores for the local nodes (batched evaluation)
    let cur_fams: Vec<Family> = node_ids.iter().map(|&c| bn.family(c)).collect();
    let cur_scores = scorer.score_batch(&cur_fams)?;
    let mut cur: FxHashMap<usize, f64> = FxHashMap::default();
    for (&c, s) in node_ids.iter().zip(cur_scores) {
        cur.insert(c, s);
    }
    loop {
        if ops >= scorer.cfg.max_ops_per_point {
            break;
        }
        // Gather the whole neighborhood, then score it in one batch —
        // this is what lets the XLA backend amortize PJRT dispatches.
        let mut cand: Vec<(usize, usize, bool, Family)> = Vec::new();
        for &c in node_ids {
            for &p in node_ids {
                if p == c {
                    continue;
                }
                if bn.has_edge(p, c) {
                    if frozen.contains(&(p, c)) {
                        continue;
                    }
                    let mut fam = bn.family(c);
                    fam.parents.retain(|x| *x != bn.nodes[p]);
                    cand.push((p, c, false, fam));
                } else {
                    if bn.parents[c].len() >= scorer.cfg.max_parents {
                        continue;
                    }
                    if bn.reaches(c, p) {
                        continue; // would create a cycle
                    }
                    let mut fam = bn.family(c);
                    fam.parents.push(bn.nodes[p]);
                    fam.parents.sort_unstable();
                    if !scorer.admissible(&fam) {
                        continue;
                    }
                    cand.push((p, c, true, fam));
                }
            }
        }
        let fams: Vec<Family> = cand.iter().map(|(_, _, _, f)| f.clone()).collect();
        let scores = scorer.score_batch(&fams)?;
        let mut best: Option<(f64, usize, usize, bool)> = None;
        for ((p, c, add, _), s) in cand.into_iter().zip(scores) {
            let delta = s - cur[&c];
            if delta > 1e-9 && best.map(|b| delta > b.0).unwrap_or(true) {
                best = Some((delta, p, c, add));
            }
        }
        match best {
            None => break,
            Some((delta, p, c, add)) => {
                if add {
                    bn.add_edge(p, c)?;
                } else {
                    bn.remove_edge(p, c)?;
                }
                *cur.get_mut(&c).unwrap() += delta;
                ops += 1;
            }
        }
    }
    Ok(ops)
}

/// Learn a first-order BN with the learn-and-join lattice search and the
/// in-process Rust score backend.
pub fn learn(
    db: &Database,
    strategy: &mut dyn CountingStrategy,
    cfg: SearchConfig,
) -> Result<LearnedModel> {
    let mut backend = RustBackend;
    learn_with_backend(db, strategy, &mut backend, cfg)
}

/// Learn with an explicit score backend (e.g. the batched XLA kernel).
pub fn learn_with_backend(
    db: &Database,
    strategy: &mut dyn CountingStrategy,
    backend: &mut dyn ScoreBackend,
    cfg: SearchConfig,
) -> Result<LearnedModel> {
    let lattice = Lattice::build(&db.schema, cfg.max_chain_length)?;
    let mut bn = Bn::new(Vec::new());
    let mut scorer = Scorer {
        strategy,
        backend,
        cfg,
        cache: FxHashMap::default(),
        families_scored: 0,
        hits: 0,
        db,
        lattice: &lattice,
    };

    strategy_prepare(scorer.strategy)?;

    // Phase 0: per-entity-table BNs.
    for et in 0..db.schema.entities.len() {
        let node_ids: Vec<usize> = (0..db.schema.entities[et].attrs.len())
            .map(|attr| bn.ensure_node(RVar::EntityAttr { et, attr }))
            .collect();
        if node_ids.len() < 2 {
            continue; // nothing to connect
        }
        let frozen = FxHashSet::default();
        hill_climb(&mut bn, &node_ids, &frozen, &mut scorer)?;
    }

    // Lattice phases, ascending chain length.
    for p in &lattice.points {
        let mut node_ids: Vec<usize> = Vec::new();
        for v in p.all_vars() {
            node_ids.push(bn.ensure_node(v));
        }
        // freeze edges inherited from earlier phases
        let mut frozen: FxHashSet<(usize, usize)> = FxHashSet::default();
        for &c in &node_ids {
            for &par in &bn.parents[c] {
                frozen.insert((par, c));
            }
        }
        hill_climb(&mut bn, &node_ids, &frozen, &mut scorer)?;
    }

    // Final score: each node's family in its canonical context.
    let mut total = 0.0;
    for i in 0..bn.nodes.len() {
        let fam = bn.family(i);
        total += scorer.score(&fam)?;
    }

    Ok(LearnedModel {
        bn,
        total_score: total,
        families_scored: scorer.families_scored,
        score_cache_hits: scorer.hits,
    })
}

fn strategy_prepare(s: &mut dyn CountingStrategy) -> Result<()> {
    s.prepare()
}

/// Context used for a family's final score: the covering lattice point's
/// populations, or the family's own populations for attr-only families.
fn widest_ctx(db: &Database, lattice: &Lattice, fam: &Family) -> Vec<usize> {
    let rels = fam.rels();
    let pops = fam.populations(&db.schema);
    if rels.is_empty() {
        return pops;
    }
    lattice
        .covering_point(&rels, &pops)
        .map(|p| p.pops.clone())
        .unwrap_or(pops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::strategies::hybrid::Hybrid;
    use crate::strategies::ondemand::OnDemand;
    use crate::strategies::traits::StrategyConfig;

    #[test]
    fn learns_salary_dependency() {
        // In the fixture, salary and the RA indicator are deterministically
        // linked (salary = N/A iff RA = F), so the search must connect
        // salary(P,S) to the rest of the model (either orientation is
        // score-equivalent).
        let db = university_db();
        let mut strat = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        let model = learn(&db, &mut strat, SearchConfig::default()).unwrap();
        let salary = RVar::RelAttr { rel: 0, attr: 1 };
        let pos = model.bn.node_pos(&salary).unwrap();
        let as_child = !model.bn.parents[pos].is_empty();
        let as_parent = model.bn.parents.iter().any(|ps| ps.contains(&pos));
        assert!(
            as_child || as_parent,
            "salary should participate in an edge:\n{}",
            model.bn.display(&db.schema)
        );
        assert!(model.families_scored > 0);
        assert!(model.bn.mean_parents_per_node() > 0.0);
    }

    #[test]
    fn respects_max_parents() {
        let db = university_db();
        let mut strat = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        let cfg = SearchConfig { max_parents: 1, ..Default::default() };
        let model = learn(&db, &mut strat, cfg).unwrap();
        for ps in &model.bn.parents {
            assert!(ps.len() <= 1);
        }
    }

    #[test]
    fn strategies_learn_identical_models() {
        // same counts -> same scores -> same greedy decisions
        let db = university_db();
        let cfg = SearchConfig::default();
        let mut h = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        let mh = learn(&db, &mut h, cfg).unwrap();
        let mut o = OnDemand::new(&db, StrategyConfig::default()).unwrap();
        let mo = learn(&db, &mut o, cfg).unwrap();
        assert_eq!(mh.bn.nodes, mo.bn.nodes);
        assert_eq!(mh.bn.parents, mo.bn.parents);
        assert!((mh.total_score - mo.total_score).abs() < 1e-6);
    }
}
