//! # relcount
//!
//! A reproduction of *"Pre and Post Counting for Scalable
//! Statistical-Relational Model Discovery"* (Mar & Schulte, 2021) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The library provides, from scratch:
//!
//! - an in-memory columnar **relational database engine** ([`db`]) with
//!   GROUP-BY counting and k-way INNER-JOIN chain counting (the paper's
//!   *JOIN problem*),
//! - **first-order metadata** extraction ([`meta`]) and the
//!   **relationship lattice** ([`lattice`]) of FACTORBASE,
//! - **contingency tables** ([`ct`]) with projection, cross-product
//!   extension and the **Möbius Join** (the paper's *negation problem*),
//!   in both an exact sparse form and a dense padded form matching the
//!   Pallas kernel layout,
//! - the three **count-caching strategies** ([`strategies`]):
//!   `PRECOUNT` (Algorithm 1), `ONDEMAND` (Algorithm 2) and the paper's
//!   contribution `HYBRID` (Algorithm 3), plus `ADAPTIVE` — a
//!   generalization that *chooses* pre or post counting per lattice
//!   point from estimated costs under an explicit memory budget
//!   (`--mem-budget`),
//! - **sampling-based cardinality estimation** ([`estimate`]):
//!   wander-join random walks over the relationship indexes and the
//!   budgeted [`estimate::CountPlan`] that drives ADAPTIVE,
//! - the **parallel counting coordinator** ([`coordinator`]): a
//!   work-sharded execution layer that partitions the lattice across a
//!   worker pool and serves bit-identical counts through the same
//!   strategy interface (`--workers N`),
//! - **BDeu-scored structure learning** ([`learn`]) with the
//!   learn-and-join lattice search,
//! - a **PJRT runtime** ([`runtime`]) that loads the AOT-compiled XLA
//!   artifacts produced by `python/compile/aot.py` (Pallas kernels for
//!   the Möbius butterfly and batched BDeu) and a score micro-batcher,
//! - a **streaming ingestion pipeline** ([`pipeline`]) with sharded
//!   builders, backpressure, and incremental positive-count maintenance,
//! - **delta maintenance** ([`delta`]): resident caches kept exact under
//!   streaming fact inserts *and* retractions — per-tuple join-row
//!   deltas, the delta-Möbius, and a planner-driven
//!   delta-vs-recount policy (`relcount apply`, `relcount exp churn`),
//! - **snapshot-isolated serving** ([`serve`]): immutable epoch-stamped
//!   cache generations behind an atomic publish point, so any number of
//!   reader threads answer count/score requests lock-free while the
//!   delta writer builds the next generation (`relcount serve`, line-
//!   delimited JSON on stdin or TCP, micro-batched over the pool),
//! - **durable snapshots + write-ahead log** ([`persist`]): a
//!   manifest-addressed, checksummed snapshot format for the full
//!   maintained-count state and an fsync-on-append WAL of delta
//!   batches, so `relcount serve --data-dir` recovers bit-identically
//!   (same cache digests) after a crash (`relcount snapshot`),
//! - seeded **synthetic dataset generators** ([`datagen`]) with one
//!   preset per benchmark database of the paper's Table 4,
//! - **metrics** ([`metrics`]) reproducing the paper's runtime breakdown
//!   (MetaData / positive ct / negative ct) and memory profiling, and
//! - the **experiment harness** ([`bench`]) regenerating every table and
//!   figure of the paper's evaluation.
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! `EXPERIMENTS.md` for measured results.

pub mod bench;
pub mod coordinator;
pub mod ct;
pub mod datagen;
pub mod db;
pub mod delta;
pub mod error;
pub mod estimate;
pub mod lattice;
pub mod learn;
pub mod meta;
pub mod metrics;
pub mod persist;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod strategies;
pub mod util;

pub use error::{Error, Result};
