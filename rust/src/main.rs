//! `relcount` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   gen        --preset <name> --scale <f> --seed <n> --out <dir>
//!   count      --preset <name>|--db <dir> --strategy <pre|post|hybrid>
//!              [--workers N|auto]
//!   learn      --preset <name>|--db <dir> --strategy <...>
//!              [--workers N|auto] [--xla]
//!   apply      --preset <name>|--db <dir> --deltas <file>
//!              [--mode auto|delta|recount] [--workers N|auto] [--out <dir>]
//!   serve      --preset <name>|--db <dir>|--data-dir <dir> [--port N]
//!              [--data-dir <dir> --snapshot-every N --snapshot-retain N]
//!              [--replicate-port N | --follow ADDR]
//!   shard      --index I --of K + serve flags   (one partition slice)
//!   route      --shards host:port,... [--port N]  (merge shard partials)
//!   snapshot   save|verify|load                        (snapshot tooling)
//!   exp        fig3|fig4|table4|table5|scaling|churn|serve|persist|estimator
//!              |wcoj|compress --scale <f> --budget-s <n>
//!   artifacts  --dir <artifacts>        (smoke-test the XLA runtime)
//!
//! `--workers` routes the counting phases through the L3 parallel
//! coordinator (`relcount::coordinator`); counts stay bit-identical.
//!
//! Examples:
//!   relcount learn --preset uw --strategy hybrid --workers auto
//!   relcount exp fig3 --scale 0.05 --budget-s 120
//!   relcount exp scaling --workers-list 1,2,4 --presets uw
//!   relcount gen --preset imdb --scale 0.1 --out /tmp/imdb

use std::io::{BufRead, BufReader};
use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use relcount::bench::driver::{
    run_coordinated_with, run_strategy_with, Workload,
};
use relcount::bench::experiments::{
    churn_rows, compress_rows, coordinator_scaling_rows, estimator_rows,
    fig3_fig4_rows, persist_rows, planner_sweep_rows, serve_rows, table4_rows,
    table5_rows, wcoj_rows, ExpConfig,
};
use relcount::coordinator::{CoordinatorConfig, ParallelCoordinator};
use relcount::datagen::generator::generate;
use relcount::datagen::presets::{preset, PRESET_NAMES};
use relcount::db::catalog::Database;
use relcount::db::index::Backend;
use relcount::db::loader;
use relcount::db::wcoj::JoinKernel;
use relcount::delta::{DeltaBatch, MaintainConfig, MaintainedCounts, MaintenanceMode};
use relcount::error::{Error, Result};
use relcount::learn::search::{learn, SearchConfig};
use relcount::persist::{load_snapshot, verify_snapshot, write_snapshot, DataDir};
use relcount::metrics::report::{
    churn_rows_to_json, compress_rows_to_json, estimator_rows_to_json,
    persist_rows_to_json, planner_rows_to_json, render_churn, render_compress,
    render_estimator, render_fig3, render_fig4, render_persist, render_planner,
    render_scaling, render_serve, render_table4, render_table5, render_wcoj,
    scaling_rows_to_json, serve_rows_to_json, wcoj_rows_to_json,
};
use relcount::runtime::client::Runtime;
use relcount::serve::{
    enumerate_requests, parse_delta_stream, run_router, run_serve,
    serve_listener, DeltaFeed, ReplHandle, ReplLog, Replicator, ServeEngine,
    ServeOptions, ShardConfig,
};
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;
use relcount::util::cli::Args;
use relcount::util::json::Json;

const USAGE: &str = "\
relcount — pre/post/hybrid/adaptive count caching for SRL model discovery

USAGE:
  relcount gen       --preset <name> [--scale F] [--seed N] --out <dir>
  relcount count     (--preset <name> | --db <dir>) [--strategy S] [--scale F]
                     [--workers N|auto] [--mem-budget BYTES[k|m|g]|inf]
                     [--backend csr|ccsr|hash] [--kernel chain|wcoj]
  relcount learn     (--preset <name> | --db <dir>) [--strategy S] [--scale F]
                     [--workers N|auto] [--mem-budget ...] [--xla]
  relcount apply     (--preset <name> | --db <dir>) --deltas FILE
                     [--mode auto|delta|recount] [--mem-budget ...]
                     [--workers N|auto] [--out <dir>]
  relcount serve     (--preset <name> | --db <dir> | --data-dir <dir>)
                     [--requests FILE | --port N]
                     [--deltas FILE | --churn F --churn-steps K
                      | --follow ADDR] [--replicate-port N]
                     [--workers N|auto] [--mem-budget ...] [--batch-max N]
                     [--delta-pause-ms N] [--snapshot-every N]
                     [--snapshot-retain N] [--json FILE]
  relcount shard     --index I --of K + the same flags as serve
  relcount route     (--preset <name> | --db <dir>)
                     --shards host:port[,host:port...] [--port N]
                     [--batch-max N] [--json FILE]
  relcount snapshot  save (--preset <name> | --db <dir>) --out <dir>
                     | verify --dir <snapshot dir> | load --dir <snapshot dir>
  relcount gen-requests (--preset <name> | --db <dir>) [--limit N] [--out FILE]
  relcount exp <fig3|fig4|table4|table5|scaling|planner|churn|serve|persist
                     |estimator|wcoj|compress> [--scale F]
                     [--budget-s N] [--presets a,b] [--workers-list 1,2,4]
                     [--workers N] [--churn 0.01,0.05] [--json FILE]
  relcount artifacts [--dir <artifacts>]
  relcount presets

  strategies: precount | ondemand | hybrid | adaptive
  presets: uw mondial hepatitis mutagenesis movielens financial imdb
  visual_genome
  --backend selects the relationship-index storage engine for any
  subcommand that loads a database: `csr` (default; columnar sorted
  adjacency with merge-join kernels), `ccsr` (delta-encoded bit-packed
  block-CSR with block-skipping intersections — smallest resident
  footprint) or `hash` (seed-era hash maps).  Counts, plans, models and
  cache digests are bit-identical across backends — `count` prints the
  digest plus per-relationship index bytes so backends can be diffed.
  --kernel selects the positive-count join kernel for any subcommand
  that loads a database: `chain` (default; binary merge joins in chain
  order) or `wcoj` (worst-case optimal variable-at-a-time
  intersection).  Counts, digests and join statistics are bit-identical
  across kernels; the asymptotic gap on cyclic skewed patterns is
  measured by `exp wcoj`.
  --workers N shards the counting phases over N threads (auto = all cores)
  via the L3 parallel coordinator; counts stay bit-identical.
  --mem-budget caps ADAPTIVE's pre-count plan (0 = pure post-counting,
  inf = pre-count everything); `exp planner` sweeps the whole spectrum
  and --json writes machine-readable rows (BENCH_planner.json).
  `apply` streams a JSON delta batch (link inserts/deletes, entity
  inserts) through the maintained caches; `exp churn` measures delta
  maintenance against invalidate-and-recount at the given churn
  fractions (BENCH_churn.json).
  `serve` answers line-delimited JSON count/score requests (stdin,
  --requests FILE, or one TCP client at a time on --port) from
  snapshot-isolated cache generations, micro-batched over the reader
  pool, while --deltas (line-delimited batches) or --churn publish new
  generations concurrently; responses go to stdout, per-generation
  metrics to stderr (--json writes BENCH_serve.json rows).
  --data-dir makes `serve` durable: every published batch is fsync'd to
  a write-ahead log before readers can see it, a full checksummed
  snapshot is written every --snapshot-every batches (default 8) and on
  graceful shutdown, and restarting with the same --data-dir (no
  --preset/--db needed) recovers bit-identically — same epoch, same
  cache digest — from the last valid snapshot plus WAL replay.
  --snapshot-retain N (default 2, minimum 1) keeps the newest N
  snapshot epochs on disk; each save prunes older epochs and trims the
  WAL through the oldest retained epoch.
  `shard` is `serve` for one slice of the entity-hash partition: the
  process answers `pcount`/`pmarginal` partial-count requests for the
  anchor entities it owns (--index I --of K) and recovers its slice
  from its own --data-dir like any serve process.  `route` fans count
  and score requests out to the shard processes, digest-checks every
  partial table on the wire, sums the positive partials and runs the
  Möbius/negative completion once at the router, so routed responses
  are byte-identical to single-process `serve`; shards answering at
  diverging epochs or state digests are a hard `route error`.
  --replicate-port turns a serving leader into a replication source:
  every published generation is streamed (epoch, digest, batch) to
  followers.  `serve --follow ADDR` consumes that stream, independently
  apply-publishes every batch, hard-checks each published digest
  against the leader's, and reports lag/health through `stats` and a
  final `replica:` summary line.
  `exp serve --shards K --sessions S` additionally stands up a live
  K-shard + router topology on localhost, byte-compares S concurrent
  routed sessions against single-process serving, replays the publish
  log through a follower (hard-failing on digest divergence) and
  reports merge overhead and peak follower lag per row.
  `snapshot save/verify/load` manage standalone snapshot directories;
  `verify` proves a snapshot can reproduce its manifest digest and
  names the corrupt section otherwise.
  `exp persist` measures restart latency per preset — cold recount vs
  snapshot save + load — and fails unless all three states share one
  cache digest (--json writes BENCH_persist.json rows).
  `exp estimator` runs the estimator quality lab per preset: q-error
  distributions (p50/p95/max vs oracle counts) and plan-regret for the
  default, pure-sampled and pure-summary estimator tiers (--json writes
  BENCH_estimator.json rows, gated in CI by scripts/estimator_gates.json).
  `exp wcoj` differentially tests the chain and WCOJ kernels (plus the
  hash backend as a third oracle) on every multi-relationship lattice
  point of hub-skewed triangle/star constructions and the presets,
  hard-failing on any digest or JoinStats divergence, and times the AGM
  gap on the skewed triangle (--json writes BENCH_wcoj.json rows).
  `exp compress` differentially tests all three index backends (csr,
  ccsr, hash) across both kernels at 1 and 4 workers — hard-failing on
  any count-digest divergence — and measures ccsr's resident bytes and
  intersection throughput against plain csr (--json writes
  BENCH_compress.json rows).
  `gen-requests` emits a deterministic request workload for a database.
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn backend_of(args: &Args) -> Result<Backend> {
    match args.get("backend") {
        None => Ok(Backend::default()),
        Some(v) => Backend::parse(v).ok_or_else(|| {
            Error::Data(format!("--backend expects csr|ccsr|hash, got {v:?}"))
        }),
    }
}

fn kernel_of(args: &Args) -> Result<JoinKernel> {
    match args.get("kernel") {
        None => Ok(JoinKernel::default()),
        Some(v) => JoinKernel::parse(v)
            .ok_or_else(|| Error::Data(format!("--kernel expects chain|wcoj, got {v:?}"))),
    }
}

fn load_db(args: &Args) -> Result<(String, Database)> {
    let backend = backend_of(args)?;
    let kernel = kernel_of(args)?;
    if let Some(dir) = args.get("db") {
        let mut db = loader::load(Path::new(dir))?;
        db.set_backend(backend)?;
        db.set_kernel(kernel);
        return Ok((dir.to_string(), db));
    }
    let name = args
        .get("preset")
        .ok_or_else(|| Error::Data("need --preset <name> or --db <dir>".into()))?;
    let scale = args.get_f64("scale", 0.05)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let cfg = preset(name, scale, seed)?;
    eprintln!(
        "generating preset {} (scale {scale}, ~{} rows)...",
        cfg.name,
        cfg.total_rows()
    );
    let mut db = generate(&cfg)?;
    db.set_backend(backend)?;
    db.set_kernel(kernel);
    Ok((cfg.name.clone(), db))
}

fn strategy_kind(args: &Args) -> Result<StrategyKind> {
    let s = args.get_or("strategy", "hybrid");
    StrategyKind::parse(s)
        .ok_or_else(|| {
            Error::Data(format!("unknown strategy {s:?} (pre|post|hybrid|adaptive)"))
        })
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("gen") => {
            let name = args
                .get("preset")
                .ok_or_else(|| Error::Data("need --preset".into()))?;
            let out = args.get("out").ok_or_else(|| Error::Data("need --out".into()))?;
            let scale = args.get_f64("scale", 1.0)?;
            let seed = args.get_usize("seed", 0)? as u64;
            let cfg = preset(name, scale, seed)?;
            let db = generate(&cfg)?;
            loader::save(&db, Path::new(out))?;
            println!(
                "wrote {} ({} rows, {} relationships) to {out}",
                cfg.name,
                db.total_rows(),
                db.n_relationships()
            );
            Ok(())
        }
        Some("count") => {
            let (name, db) = load_db(&args)?;
            let kind = strategy_kind(&args)?;
            let scfg = StrategyConfig {
                budget: budget_of(&args)?,
                mem_budget: args.mem_budget()?,
                ..Default::default()
            };
            let workers = args.workers()?;
            let (row, report, digest) = if workers == 1 {
                let out =
                    run_strategy_with(&db, &name, kind, Workload::PrepareOnly, scfg)?;
                (out.row, out.report, out.cache_digest)
            } else {
                let out = run_coordinated_with(
                    &db,
                    &name,
                    kind,
                    Workload::PrepareOnly,
                    scfg,
                    workers,
                )?;
                let cpu = out.coordinator.cpu_view().timing;
                println!(
                    "coordinator: {} workers, cpu {:.3}s over wall {:.3}s \
                     (tasks/worker: {:?})",
                    out.coordinator.workers,
                    cpu.total().as_secs_f64(),
                    out.row.total().as_secs_f64(),
                    out.coordinator.tasks_per_worker
                );
                (out.row, out.report, out.cache_digest)
            };
            print!("{}", render_fig3(&[row.clone()]));
            print!("{}", render_fig4(&[row]));
            println!(
                "joins: {} chain queries, {} rows enumerated; ct rows generated: {}",
                report.join_stats.chain_queries,
                report.join_stats.rows_enumerated,
                report.ct_rows_generated
            );
            println!(
                "caches: digest {digest:016x} (backend {}, kernel {})",
                db.backend().name(),
                db.kernel().name()
            );
            let per_rel = db.index_bytes_per_rel();
            if !per_rel.is_empty() {
                println!(
                    "indexes: {} bytes resident (per relationship: {})",
                    per_rel.iter().sum::<usize>(),
                    per_rel
                        .iter()
                        .enumerate()
                        .map(|(rt, b)| format!("r{rt}={b}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                );
            }
            if kind == StrategyKind::Adaptive {
                println!(
                    "plan: {} points positive-planned, {} complete-planned, \
                     ~{} est bytes resident ({} estimator walks)",
                    report.planned_positive,
                    report.planned_complete,
                    report.plan_est_bytes,
                    report.estimator_walks
                );
            }
            Ok(())
        }
        Some("learn") => {
            let (name, db) = load_db(&args)?;
            let kind = strategy_kind(&args)?;
            let cfg = SearchConfig {
                max_parents: args.get_usize("max-parents", 4)?,
                n_prime: args.get_f64("n-prime", 1.0)?,
                ..Default::default()
            };
            let scfg = StrategyConfig {
                budget: budget_of(&args)?,
                mem_budget: args.mem_budget()?,
                ..Default::default()
            };
            let workers = args.workers()?;
            let mut strategy: Box<dyn CountingStrategy + '_> = if workers == 1 {
                kind.build(&db, scfg)?
            } else {
                Box::new(ParallelCoordinator::new(
                    &db,
                    kind,
                    CoordinatorConfig { workers, strategy: scfg },
                )?)
            };
            let model = if args.has("xla") {
                // score through the AOT-compiled Pallas kernel (batched)
                let mut backend = relcount::learn::backend::XlaBackend::load_default()?;
                let m = relcount::learn::search::learn_with_backend(
                    &db,
                    strategy.as_mut(),
                    &mut backend,
                    cfg,
                )?;
                println!(
                    "scored via XLA: {} families / {} PJRT dispatches \
                     ({} scalar fallbacks)",
                    backend.xla_scored, backend.dispatches, backend.fallback_scored
                );
                m
            } else {
                learn(&db, strategy.as_mut(), cfg)?
            };
            println!("learned first-order BN for {name} with {}:", kind.name());
            print!("{}", model.bn.display(&db.schema));
            println!(
                "score: {:.3}  MP/N: {:.2}  families scored: {} (cache hits {})",
                model.total_score,
                model.bn.mean_parents_per_node(),
                model.families_scored,
                model.score_cache_hits
            );
            Ok(())
        }
        Some("apply") => {
            let (name, db) = load_db(&args)?;
            let path = args
                .get("deltas")
                .ok_or_else(|| Error::Data("need --deltas FILE".into()))?
                .to_string();
            let batch = DeltaBatch::from_file(Path::new(&path))?;
            let mode = MaintenanceMode::parse(args.get_or("mode", "auto"))
                .ok_or_else(|| {
                    Error::Data("--mode expects auto|delta|recount".into())
                })?;
            let cfg = MaintainConfig {
                mem_budget: args.mem_budget()?,
                workers: args.workers()?,
                mode,
                ..Default::default()
            };
            eprintln!("building maintained caches for {name}...");
            let mut m = MaintainedCounts::build(db, cfg)?;
            let rep = m.apply(&batch)?;
            println!(
                "applied {} ops to {name} in {:.3}s: {} link inserts, {} link \
                 deletes, {} entity inserts",
                rep.ops_applied,
                rep.elapsed.as_secs_f64(),
                rep.link_inserts,
                rep.link_deletes,
                rep.entity_inserts
            );
            println!(
                "maintenance: {} points delta-maintained ({} cells), {} points \
                 recounted, {} fresh chain queries; resident {} bytes; digest \
                 {:016x}",
                rep.points_delta_maintained,
                rep.cells_touched,
                rep.points_recounted,
                rep.join_stats.chain_queries,
                m.resident_bytes(),
                m.digest()
            );
            if let Some(out) = args.get("out") {
                loader::save(m.db(), Path::new(out))?;
                println!("wrote mutated database to {out}");
            }
            Ok(())
        }
        Some("serve") | Some("shard") => {
            // `relcount shard` is `serve` plus a slice identity: the
            // engine answers `pcount`/`pmarginal` over the anchor
            // entities it owns and the router owns the merge.
            let shard_cfg = if args.command.as_deref() == Some("shard") {
                let of = args.get_usize("of", 0)?;
                let index = args.get_usize("index", 0)?;
                if of == 0 || index >= of {
                    return Err(Error::Data(
                        "shard needs --index I --of K with I < K".into(),
                    ));
                }
                Some(ShardConfig { index, of })
            } else {
                None
            };
            let follow = args.get("follow").map(str::to_string);
            let feed = if let Some(addr) = follow.clone() {
                if args.get("deltas").is_some() || args.get("churn").is_some() {
                    return Err(Error::Data(
                        "--follow consumes the leader's delta stream: drop \
                         --deltas/--churn"
                            .into(),
                    ));
                }
                DeltaFeed::Follow { addr }
            } else if let Some(path) = args.get("deltas") {
                let text = std::fs::read_to_string(path)?;
                DeltaFeed::Batches(parse_delta_stream(&text)?)
            } else if args.get("churn").is_some() {
                DeltaFeed::Churn {
                    frac: args.get_f64("churn", 0.05)?,
                    steps: args.get_usize("churn-steps", 3)?,
                    seed: args.get_usize("seed", 0)? as u64 ^ 0x5E47E,
                }
            } else {
                DeltaFeed::None
            };
            if args.get("port").is_some() && args.get("requests").is_some() {
                return Err(Error::Data(
                    "--port and --requests are mutually exclusive: TCP sessions \
                     read requests from the socket"
                        .into(),
                ));
            }
            // --data-dir makes the engine durable: a dir with snapshots
            // recovers the pre-crash state (no --preset/--db needed);
            // an empty one starts from the loaded database and writes
            // the initial snapshot
            let data_dir = args.get("data-dir").map(Path::new);
            let snapshot_every = args.get_usize("snapshot-every", 8)? as u64;
            let snapshot_retain = args.get_usize("snapshot-retain", 2)?;
            if snapshot_retain == 0 {
                return Err(Error::Data(
                    "--snapshot-retain expects an integer >= 1".into(),
                ));
            }
            let (name, mut engine) = match data_dir {
                Some(root) => {
                    let dd = DataDir::with_retain(root, snapshot_retain)?;
                    if dd.has_snapshots()? {
                        eprintln!("recovering state from {}...", root.display());
                        let (m, epoch) = dd.recover(args.workers()?)?;
                        let name = match args.get("preset").or_else(|| args.get("db")) {
                            Some(s) => s.to_string(),
                            None => root.display().to_string(),
                        };
                        eprintln!(
                            "recovered epoch {epoch} digest {:016x}",
                            m.digest()
                        );
                        (name, ServeEngine::from_maintained_at(m, epoch)?)
                    } else {
                        let (name, db) = load_db(&args)?;
                        let cfg = MaintainConfig {
                            mem_budget: args.mem_budget()?,
                            workers: args.workers()?,
                            ..Default::default()
                        };
                        eprintln!("building serving engine for {name}...");
                        (name, ServeEngine::build(db, cfg)?)
                    }
                }
                None => {
                    let (name, db) = load_db(&args)?;
                    let cfg = MaintainConfig {
                        mem_budget: args.mem_budget()?,
                        workers: args.workers()?,
                        ..Default::default()
                    };
                    eprintln!("building serving engine for {name}...");
                    (name, ServeEngine::build(db, cfg)?)
                }
            };
            if let Some(root) = data_dir {
                engine.attach_persistence(
                    DataDir::with_retain(root, snapshot_retain)?,
                    snapshot_every,
                )?;
                eprintln!(
                    "durable: WAL + snapshot every {snapshot_every} batches \
                     (retaining {snapshot_retain}) in {}",
                    root.display()
                );
            }
            // --replicate-port makes this process a replication
            // leader: every published batch lands in a shared log that
            // the acceptor thread streams to followers.
            let (publish_log, replicator) = match args.get("replicate-port") {
                Some(port) => {
                    let port: u16 = port.parse().map_err(|_| {
                        Error::Data(format!(
                            "--replicate-port expects a TCP port, got {port:?}"
                        ))
                    })?;
                    let listener =
                        std::net::TcpListener::bind(("127.0.0.1", port))?;
                    eprintln!(
                        "replicating on {} (follow with --follow ADDR)",
                        listener.local_addr()?
                    );
                    let log = Arc::new(ReplLog::new());
                    let acceptor = Replicator::spawn(listener, log.clone())?;
                    (Some(log), Some(acceptor))
                }
                None => (None, None),
            };
            let repl = follow.as_ref().map(|_| Arc::new(ReplHandle::new()));
            if let Some(sc) = &shard_cfg {
                eprintln!(
                    "shard {}/{} of the entity-hash partition",
                    sc.index, sc.of
                );
            }
            let opts = ServeOptions {
                database: name.clone(),
                workers: args.workers()?,
                batch_max: args.get_usize("batch-max", 64)?,
                feed,
                delta_pause: Duration::from_millis(
                    args.get_usize("delta-pause-ms", 0)? as u64,
                ),
                shard: shard_cfg,
                repl: repl.clone(),
                publish_log,
            };
            let summary = if let Some(port) = args.get("port") {
                let port: u16 = port.parse().map_err(|_| {
                    Error::Data(format!("--port expects a TCP port, got {port:?}"))
                })?;
                let listener =
                    std::net::TcpListener::bind(("127.0.0.1", port))?;
                eprintln!(
                    "serving {name} on {} (send {{\"op\":\"shutdown\"}} to stop)",
                    listener.local_addr()?
                );
                serve_listener(engine, listener, &opts)?
            } else {
                let input: Box<dyn BufRead + Send> = match args.get("requests") {
                    Some(path) => {
                        Box::new(BufReader::new(std::fs::File::open(path)?))
                    }
                    None => Box::new(BufReader::new(std::io::stdin())),
                };
                run_serve(engine, input, std::io::stdout(), &opts)?
            };
            eprint!("{}", render_serve(&summary.rows));
            for (i, e) in &summary.publish_failures {
                if *i == usize::MAX {
                    eprintln!("warning: {e} (WAL still holds every batch)");
                } else {
                    eprintln!("publish failure on batch {i}: {e} (previous generation kept serving)");
                }
            }
            eprintln!(
                "serve: {} requests ({} errors), {} generations published, \
                 final epoch {} digest {:016x}",
                summary.requests,
                summary.errors,
                summary.publishes,
                summary.final_epoch,
                summary.final_digest
            );
            if let Some(acceptor) = replicator {
                acceptor.shutdown();
            }
            if let Some(h) = &repl {
                eprintln!(
                    "replica: applied epoch {} of leader epoch {} (lag {}, {})",
                    h.applied_epoch(),
                    h.leader_epoch(),
                    h.lag(),
                    if h.healthy() { "healthy" } else { "DIVERGED" }
                );
            }
            write_json(&args, serve_rows_to_json(&summary.rows))?;
            Ok(())
        }
        Some("route") => {
            // The router never counts locally: it fans pcount/pmarginal
            // out to the shards, digest-checks each partial, sums the
            // positives and runs the Möbius completion once, so its
            // responses are byte-identical to single-process serving.
            let shards: Vec<String> = args
                .get("shards")
                .ok_or_else(|| {
                    Error::Data(
                        "route needs --shards host:port[,host:port...]".into(),
                    )
                })?
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let (name, db) = load_db(&args)?;
            let port: u16 = args.get_or("port", "0").parse().map_err(|_| {
                Error::Data("--port expects a TCP port".into())
            })?;
            let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
            eprintln!(
                "routing {name} over {} shards on {} (send \
                 {{\"op\":\"shutdown\"}} to stop)",
                shards.len(),
                listener.local_addr()?
            );
            let opts = ServeOptions {
                database: name.clone(),
                batch_max: args.get_usize("batch-max", 64)?,
                ..Default::default()
            };
            let summary = run_router(db, &shards, listener, &opts)?;
            eprint!("{}", render_serve(&summary.rows));
            eprintln!(
                "route: {} requests ({} errors) over {} sessions, merge \
                 overhead {:.3} ms, final epoch {}",
                summary.requests,
                summary.errors,
                summary.sessions,
                summary.merge_wall.as_secs_f64() * 1e3,
                summary.final_epoch
            );
            write_json(&args, serve_rows_to_json(&summary.rows))?;
            Ok(())
        }
        Some("snapshot") => {
            let action = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or_else(|| Error::Data("snapshot needs save|verify|load".into()))?;
            match action {
                "save" => {
                    let (name, db) = load_db(&args)?;
                    let cfg = MaintainConfig {
                        mem_budget: args.mem_budget()?,
                        workers: args.workers()?,
                        ..Default::default()
                    };
                    let out = args
                        .get("out")
                        .ok_or_else(|| Error::Data("need --out <dir>".into()))?;
                    eprintln!("building maintained caches for {name}...");
                    let mut m = MaintainedCounts::build(db, cfg)?;
                    m.compact_indexes();
                    std::fs::create_dir_all(out)?;
                    write_snapshot(Path::new(out), &m, 0)?;
                    println!(
                        "wrote snapshot of {name} at epoch 0 to {out} (digest {:016x})",
                        m.digest()
                    );
                }
                "verify" => {
                    let dir = args
                        .get("dir")
                        .ok_or_else(|| Error::Data("need --dir <snapshot dir>".into()))?;
                    let info = verify_snapshot(Path::new(dir))?;
                    println!(
                        "snapshot OK: epoch {}, backend {}, digest {:016x}",
                        info.epoch,
                        info.backend.name(),
                        info.cache_digest
                    );
                    for (section, bytes) in &info.sections {
                        println!("  {section}: {bytes} bytes");
                    }
                }
                "load" => {
                    let dir = args
                        .get("dir")
                        .ok_or_else(|| Error::Data("need --dir <snapshot dir>".into()))?;
                    let state = load_snapshot(Path::new(dir))?;
                    let (epoch, digest) = (state.epoch, state.cache_digest);
                    let m = state.into_maintained(args.workers()?)?;
                    println!(
                        "loaded snapshot epoch {epoch} digest {digest:016x}: \
                         resident {} bytes, serviceable",
                        m.resident_bytes()
                    );
                }
                other => {
                    return Err(Error::Data(format!(
                        "unknown snapshot action {other:?} (save|verify|load)"
                    )))
                }
            }
            Ok(())
        }
        Some("gen-requests") => {
            let (_, db) = load_db(&args)?;
            let limit = args.get_usize("limit", 200)?;
            let chain = args.get_usize("chain", 3)?;
            let reqs = enumerate_requests(&db, chain, limit)?;
            let lines: String =
                reqs.iter().map(|r| r.to_json().dump() + "\n").collect();
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &lines)?;
                    eprintln!("wrote {} requests to {path}", reqs.len());
                }
                None => print!("{lines}"),
            }
            Ok(())
        }
        Some("exp") => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or_else(|| {
                    Error::Data(
                        "exp needs fig3|fig4|table4|table5|scaling|planner|\
                         churn|serve|persist|estimator|wcoj|compress"
                            .into(),
                    )
                })?;
            let cfg = exp_config(&args)?;
            match which {
                "fig3" => print!("{}", render_fig3(&fig3_fig4_rows(&cfg)?)),
                "fig4" => print!("{}", render_fig4(&fig3_fig4_rows(&cfg)?)),
                "table4" => print!("{}", render_table4(&table4_rows(&cfg)?)),
                "table5" => print!("{}", render_table5(&table5_rows(&cfg)?)),
                "scaling" => {
                    let counts = workers_list(&args)?;
                    let rows = coordinator_scaling_rows(&cfg, &counts)?;
                    print!("{}", render_scaling(&rows));
                    write_json(&args, scaling_rows_to_json(&rows))?;
                }
                "planner" => {
                    let workers = args.workers()?;
                    let rows = planner_sweep_rows(&cfg, workers)?;
                    print!("{}", render_planner(&rows));
                    write_json(&args, planner_rows_to_json(&rows))?;
                }
                "churn" => {
                    let workers = args.workers()?;
                    let fracs = churn_fracs(&args)?;
                    let rows = churn_rows(&cfg, &fracs, workers)?;
                    print!("{}", render_churn(&rows));
                    if rows.iter().any(|r| !r.consistent) {
                        return Err(Error::Data(
                            "churn: delta and recount caches diverged".into(),
                        ));
                    }
                    write_json(&args, churn_rows_to_json(&rows))?;
                }
                "serve" => {
                    let workers = args.workers()?;
                    let frac = args.get_f64("churn-frac", 0.05)?;
                    let steps = args.get_usize("churn-steps", 3)?;
                    let repeat = args.get_usize("repeat", 4)?;
                    let shards = args.get_usize("shards", 0)?;
                    let sessions = args.get_usize("sessions", 2)?;
                    let rows =
                        serve_rows(&cfg, workers, frac, steps, repeat, shards, sessions)?;
                    print!("{}", render_serve(&rows));
                    write_json(&args, serve_rows_to_json(&rows))?;
                }
                "persist" => {
                    let workers = args.workers()?;
                    let rows = persist_rows(&cfg, workers)?;
                    print!("{}", render_persist(&rows));
                    if rows.iter().any(|r| !r.digest_match) {
                        return Err(Error::Data(
                            "persist: snapshot round-trip or cold recount \
                             diverged from the live state"
                                .into(),
                        ));
                    }
                    write_json(&args, persist_rows_to_json(&rows))?;
                }
                "estimator" => {
                    let rows = estimator_rows(&cfg)?;
                    print!("{}", render_estimator(&rows));
                    write_json(&args, estimator_rows_to_json(&rows))?;
                }
                "wcoj" => {
                    // wcoj_rows hard-errors on any kernel divergence, so
                    // reaching here means every row witnessed agreement
                    let rows = wcoj_rows(&cfg)?;
                    print!("{}", render_wcoj(&rows));
                    write_json(&args, wcoj_rows_to_json(&rows))?;
                }
                "compress" => {
                    // compress_rows hard-errors on any digest divergence
                    // across the three backends, so reaching here means
                    // every row witnessed bit-identity
                    let rows = compress_rows(&cfg)?;
                    print!("{}", render_compress(&rows));
                    write_json(&args, compress_rows_to_json(&rows))?;
                }
                other => return Err(Error::Data(format!("unknown experiment {other:?}"))),
            }
            Ok(())
        }
        Some("artifacts") => {
            let dir = args.get_or("dir", "artifacts").to_string();
            let rt = Runtime::load(Path::new(&dir))?;
            println!("loaded {} artifacts from {dir}:", rt.manifest.artifacts.len());
            for (name, spec) in &rt.manifest.artifacts {
                println!(
                    "  {name}: {} -> {} ({} inputs)",
                    spec.file,
                    spec.outputs[0].name,
                    spec.inputs.len()
                );
            }
            // smoke: empty batch scores zero
            let spec = rt.manifest.artifact("bdeu_batch")?;
            let b = spec.meta_dim("b_pad")?;
            let q = spec.meta_dim("q_pad")?;
            let r = spec.meta_dim("r_pad")?;
            let scores =
                rt.bdeu_batch(&vec![0.0; b * q * r], &vec![1.0; b], &vec![0.5; b])?;
            if scores.iter().any(|&s| s != 0.0) {
                return Err(Error::Runtime("smoke test failed: nonzero scores".into()));
            }
            println!("bdeu_batch smoke test ok ({} slots, all-zero batch -> 0.0)", b);
            Ok(())
        }
        Some("presets") => {
            for p in PRESET_NAMES {
                let cfg = preset(p, 1.0, 0)?;
                println!(
                    "{p:<16} rows {:>10}  relationships {}",
                    cfg.total_rows(),
                    cfg.rels.len()
                );
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Write experiment rows to `--json FILE` (no-op when absent).
fn write_json(args: &Args, rows: Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, rows.dump() + "\n")?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// Parse `--churn 0.01,0.05` (batch sizes as link-row fractions).
fn churn_fracs(args: &Args) -> Result<Vec<f64>> {
    let raw = args.get_or("churn", "0.01,0.05");
    raw.split(',')
        .map(|tok| {
            tok.trim().parse::<f64>().ok().filter(|f| *f > 0.0).ok_or_else(|| {
                Error::Data(format!(
                    "--churn expects positive fractions, got {tok:?}"
                ))
            })
        })
        .collect()
}

/// Parse `--workers-list 1,2,4` (`auto` entries resolve to all cores).
fn workers_list(args: &Args) -> Result<Vec<usize>> {
    let raw = args.get_or("workers-list", "1,2,4");
    raw.split(',')
        .map(|tok| match tok.trim() {
            "auto" => Ok(0),
            t => t.parse::<usize>().map_err(|_| {
                Error::Data(format!("--workers-list expects integers or `auto`, got {t:?}"))
            }),
        })
        .collect()
}

fn budget_of(args: &Args) -> Result<Option<Duration>> {
    Ok(match args.get_usize("budget-s", 0)? {
        0 => None,
        s => Some(Duration::from_secs(s as u64)),
    })
}

fn exp_config(args: &Args) -> Result<ExpConfig> {
    let mut cfg = ExpConfig {
        scale: args.get_f64("scale", 0.05)?,
        budget: budget_of(args)?.or(Some(Duration::from_secs(120))),
        seed: args.get_usize("seed", 0)? as u64,
        ..Default::default()
    };
    if let Some(list) = args.get("presets") {
        // leak: tiny, once-per-process, keeps ExpConfig Copy-friendly
        let names: Vec<&'static str> = list
            .split(',')
            .map(|s| &*Box::leak(s.trim().to_string().into_boxed_str()))
            .collect();
        cfg.presets = Box::leak(names.into_boxed_slice());
    }
    Ok(cfg)
}
