//! Metadata extraction — the paper's "MetaData" runtime component.
//!
//! FACTORBASE's first stage extracts the first-order-logic view of the
//! database (the 1rvs), generates the relationship lattice, and generates
//! the *metaqueries* that drive the dynamic SQL.  Our equivalent builds
//! [`Metadata`]: the variable universe, per-chain variable lists, and a
//! [`QueryPlan`] (join order) per lattice chain.  The wall-clock cost of
//! this stage is what Figure 3 reports as "MetaData".

use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;

/// A join plan for one relationship chain: the order in which the
/// backtracking join enumerates relationship tables, chosen greedily
/// smallest-table-first subject to connectivity (each step shares an
/// entity variable with the already-joined prefix, so every step can use
/// an FK index instead of a cross product).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueryPlan {
    /// The chain (sorted relationship ids).
    pub chain: Vec<usize>,
    /// Join order (a permutation of `chain`).
    pub join_order: Vec<usize>,
    /// All variables of the chain's ct-table (entity attrs of the chain's
    /// populations + rel attrs of the chain's rels), in canonical order.
    pub vars: Vec<RVar>,
    /// The chain's populations (sorted entity type ids).
    pub pops: Vec<usize>,
}

/// Extracted first-order metadata for a database.
#[derive(Clone, Debug, Default)]
pub struct Metadata {
    /// Every 1rv of the schema.
    pub all_vars: Vec<RVar>,
    /// Per entity type: its attribute variables.
    pub entity_vars: Vec<Vec<RVar>>,
    /// Per relationship: its attribute variables (not the indicator).
    pub rel_attr_vars: Vec<Vec<RVar>>,
}

/// All non-indicator variables associated with a chain: entity attributes
/// of the chain's populations plus rel attributes of the chain's rels.
pub fn vars_for_chain(schema: &Schema, rels: &[usize]) -> Vec<RVar> {
    let mut vars = Vec::new();
    for &et in &schema.populations_of(rels) {
        for attr in 0..schema.entities[et].attrs.len() {
            vars.push(RVar::EntityAttr { et, attr });
        }
    }
    for &rel in rels {
        for attr in 0..schema.relationships[rel].attrs.len() {
            vars.push(RVar::RelAttr { rel, attr });
        }
    }
    vars.sort_unstable();
    vars
}

/// Attribute variables of a single entity type.
pub fn vars_for_entity(schema: &Schema, et: usize) -> Vec<RVar> {
    (0..schema.entities[et].attrs.len())
        .map(|attr| RVar::EntityAttr { et, attr })
        .collect()
}

/// Greedy join order: repeatedly pick the smallest not-yet-joined rel
/// table that connects to the joined prefix (first pick = smallest).
pub fn plan_chain(db: &Database, chain: &[usize]) -> Result<QueryPlan> {
    if chain.is_empty() {
        return Err(Error::Schema("cannot plan an empty chain".into()));
    }
    if !db.schema.is_connected(chain) {
        return Err(Error::Schema(format!("chain {chain:?} is not connected")));
    }
    let mut remaining: Vec<usize> = chain.to_vec();
    let mut order = Vec::with_capacity(chain.len());
    let mut pops: Vec<usize> = Vec::new();
    while !remaining.is_empty() {
        let pick = remaining
            .iter()
            .copied()
            .filter(|&r| {
                if order.is_empty() {
                    true
                } else {
                    let (a, b) = db.schema.rel_endpoints(r);
                    pops.contains(&a) || pops.contains(&b)
                }
            })
            .min_by_key(|&r| db.rels[r].len())
            .expect("connected chain always has a connectable next rel");
        let (a, b) = db.schema.rel_endpoints(pick);
        if !pops.contains(&a) {
            pops.push(a);
        }
        if !pops.contains(&b) {
            pops.push(b);
        }
        order.push(pick);
        remaining.retain(|&r| r != pick);
    }
    pops.sort_unstable();
    let mut chain_sorted = chain.to_vec();
    chain_sorted.sort_unstable();
    Ok(QueryPlan {
        chain: chain_sorted,
        join_order: order,
        vars: vars_for_chain(&db.schema, chain),
        pops,
    })
}

impl Metadata {
    /// Extract all 1rvs from the schema.
    pub fn extract(db: &Database) -> Self {
        let schema = &db.schema;
        let mut all_vars = Vec::new();
        let mut entity_vars = Vec::new();
        for et in 0..schema.entities.len() {
            let vs = vars_for_entity(schema, et);
            all_vars.extend(vs.iter().copied());
            entity_vars.push(vs);
        }
        let mut rel_attr_vars = Vec::new();
        for rel in 0..schema.relationships.len() {
            let mut vs = Vec::new();
            for attr in 0..schema.relationships[rel].attrs.len() {
                vs.push(RVar::RelAttr { rel, attr });
            }
            all_vars.extend(vs.iter().copied());
            all_vars.push(RVar::RelInd { rel });
            rel_attr_vars.push(vs);
        }
        all_vars.sort_unstable();
        Metadata { all_vars, entity_vars, rel_attr_vars }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;

    #[test]
    fn extracts_all_vars() {
        let db = university_db();
        let md = Metadata::extract(&db);
        // 3 entity attrs + 3 rel attrs + 2 indicators
        assert_eq!(md.all_vars.len(), 8);
        assert_eq!(md.entity_vars.len(), 3);
        assert_eq!(md.rel_attr_vars[0].len(), 2);
    }

    #[test]
    fn chain_vars_cover_populations() {
        let db = university_db();
        let vars = vars_for_chain(&db.schema, &[0, 1]);
        // all 3 entity attrs + 3 rel attrs
        assert_eq!(vars.len(), 6);
    }

    #[test]
    fn plans_are_connected_orders() {
        let db = university_db();
        let plan = plan_chain(&db, &[0, 1]).unwrap();
        assert_eq!(plan.join_order.len(), 2);
        assert_eq!(plan.pops, vec![0, 1, 2]);
        // Registered (rel 1) has more tuples than RA? pick smallest first
        let first = plan.join_order[0];
        assert!(db.rels[first].len() <= db.rels[plan.join_order[1]].len());
    }

    #[test]
    fn empty_chain_rejected() {
        let db = university_db();
        assert!(plan_chain(&db, &[]).is_err());
    }
}
