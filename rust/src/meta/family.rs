//! Families: a child variable plus its parent set — the unit the model
//! search scores and therefore the unit of post-counting.

use crate::db::schema::Schema;
use crate::meta::rvar::RVar;

/// A model family (child + parents), the paper's "local pattern".
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Family {
    pub child: RVar,
    /// Parents in canonical (sorted) order.
    pub parents: Vec<RVar>,
}

/// Canonical cache key for a family (order-insensitive in the parents).
pub type FamilyKey = (RVar, Vec<RVar>);

impl Family {
    pub fn new(child: RVar, mut parents: Vec<RVar>) -> Self {
        parents.sort_unstable();
        parents.dedup();
        Family { child, parents }
    }

    /// All variables, parents first then child — the ct-table column
    /// order used throughout.
    pub fn vars(&self) -> Vec<RVar> {
        let mut v = self.parents.clone();
        v.push(self.child);
        v
    }

    /// Cache key.
    pub fn key(&self) -> FamilyKey {
        (self.child, self.parents.clone())
    }

    /// Relationships referenced by any variable (indicator or attribute),
    /// sorted and deduplicated.  These are the axes of the Möbius Join.
    pub fn rels(&self) -> Vec<usize> {
        let mut rels: Vec<usize> = self.vars().iter().filter_map(|v| v.rel()).collect();
        rels.sort_unstable();
        rels.dedup();
        rels
    }

    /// Entity types whose populations ground this family (before
    /// extension to a context lattice point).
    pub fn populations(&self, schema: &Schema) -> Vec<usize> {
        let mut pops: Vec<usize> =
            self.vars().iter().flat_map(|v| v.populations(schema)).collect();
        pops.sort_unstable();
        pops.dedup();
        pops
    }

    /// Number of parent configurations q_i = prod of parent dims.
    pub fn q(&self, schema: &Schema) -> u64 {
        self.parents.iter().map(|p| p.dim(schema) as u64).product()
    }

    /// Number of child values r_i.
    pub fn r(&self, schema: &Schema) -> u64 {
        self.child.dim(schema) as u64
    }

    /// Human-readable form, e.g. `salary(P,S) <- RA(P,S), capability(P,S)`.
    pub fn display(&self, schema: &Schema) -> String {
        if self.parents.is_empty() {
            format!("{} <- ()", self.child.name(schema))
        } else {
            let ps: Vec<String> =
                self.parents.iter().map(|p| p.name(schema)).collect();
            format!("{} <- {}", self.child.name(schema), ps.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;

    #[test]
    fn canonical_parent_order() {
        let a = Family::new(
            RVar::RelAttr { rel: 0, attr: 1 },
            vec![RVar::RelInd { rel: 0 }, RVar::RelAttr { rel: 0, attr: 0 }],
        );
        let b = Family::new(
            RVar::RelAttr { rel: 0, attr: 1 },
            vec![RVar::RelAttr { rel: 0, attr: 0 }, RVar::RelInd { rel: 0 }],
        );
        assert_eq!(a, b);
        assert_eq!(a.key(), b.key());
    }

    #[test]
    fn paper_example_family() {
        // Salary(P,S) <- RA(P,S), Capa(P,S): q = 2 * 6, r = 4 (3 + N/A)
        let s = university_schema();
        let f = Family::new(
            RVar::RelAttr { rel: 0, attr: 1 },
            vec![RVar::RelInd { rel: 0 }, RVar::RelAttr { rel: 0, attr: 0 }],
        );
        assert_eq!(f.q(&s), 12);
        assert_eq!(f.r(&s), 4);
        assert_eq!(f.rels(), vec![0]);
        assert_eq!(f.populations(&s), vec![0, 1]);
        assert!(f.display(&s).starts_with("salary(P,S) <- "));
    }

    #[test]
    fn cross_rel_family() {
        let s = university_schema();
        let f = Family::new(
            RVar::EntityAttr { et: 1, attr: 0 },
            vec![RVar::RelInd { rel: 0 }, RVar::RelInd { rel: 1 }],
        );
        assert_eq!(f.rels(), vec![0, 1]);
        assert_eq!(f.populations(&s), vec![0, 1, 2]);
        assert_eq!(f.q(&s), 4);
    }
}
