//! First-order metadata: random variables (1rvs), families, and the
//! metadata-extraction phase whose wall-clock time is the paper's
//! "MetaData" runtime component.

pub mod extract;
pub mod family;
pub mod rvar;

pub use extract::{Metadata, QueryPlan};
pub use family::{Family, FamilyKey};
pub use rvar::RVar;
