//! First-order random variables (functor nodes).
//!
//! Following the paper's language bias, variables range over *types* of
//! individuals, never specific individuals: `gender(U)`, `grade(S, C)`,
//! `Registered(S, C)`.  Three kinds exist:
//!
//! - [`RVar::EntityAttr`] — an attribute of an entity type,
//! - [`RVar::RelAttr`]    — an attribute of a relationship; its ct-table
//!   dimension includes the distinguished N/A value (code 0) taken when
//!   the relationship is false,
//! - [`RVar::RelInd`]     — a relationship indicator with values F/T.

use crate::db::schema::Schema;

/// A first-order random variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RVar {
    /// `attrs[attr]` of entity type `et`, e.g. `intelligence(S)`.
    EntityAttr { et: usize, attr: usize },
    /// `attrs[attr]` of relationship `rel`, e.g. `grade(S, C)`.
    RelAttr { rel: usize, attr: usize },
    /// The indicator of relationship `rel`, e.g. `Registered(S, C)`.
    RelInd { rel: usize },
}

impl RVar {
    /// ct-table dimension (number of value codes) of this variable.
    pub fn dim(&self, schema: &Schema) -> u32 {
        match *self {
            RVar::EntityAttr { et, attr } => schema.entities[et].attrs[attr].card,
            // +1 for the N/A code 0
            RVar::RelAttr { rel, attr } => schema.relationships[rel].attrs[attr].card + 1,
            RVar::RelInd { .. } => 2,
        }
    }

    /// Human-readable functor name, e.g. `grade(S,C)`.
    pub fn name(&self, schema: &Schema) -> String {
        match *self {
            RVar::EntityAttr { et, attr } => {
                let e = &schema.entities[et];
                format!("{}({})", e.attrs[attr].name, initial(&e.name))
            }
            RVar::RelAttr { rel, attr } => {
                let r = &schema.relationships[rel];
                format!(
                    "{}({},{})",
                    r.attrs[attr].name,
                    initial(&schema.entities[r.from].name),
                    initial(&schema.entities[r.to].name)
                )
            }
            RVar::RelInd { rel } => {
                let r = &schema.relationships[rel];
                format!(
                    "{}({},{})",
                    r.name,
                    initial(&schema.entities[r.from].name),
                    initial(&schema.entities[r.to].name)
                )
            }
        }
    }

    /// The relationship this variable belongs to, if any.
    pub fn rel(&self) -> Option<usize> {
        match *self {
            RVar::RelAttr { rel, .. } | RVar::RelInd { rel } => Some(rel),
            RVar::EntityAttr { .. } => None,
        }
    }

    /// Entity types whose populations this variable's groundings range
    /// over.
    pub fn populations(&self, schema: &Schema) -> Vec<usize> {
        match *self {
            RVar::EntityAttr { et, .. } => vec![et],
            RVar::RelAttr { rel, .. } | RVar::RelInd { rel } => {
                let (a, b) = schema.rel_endpoints(rel);
                vec![a, b]
            }
        }
    }

    /// True for indicator variables.
    pub fn is_indicator(&self) -> bool {
        matches!(self, RVar::RelInd { .. })
    }
}

fn initial(name: &str) -> String {
    name.chars().next().map(|c| c.to_string()).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;

    #[test]
    fn dims_follow_conventions() {
        let s = university_schema();
        assert_eq!(RVar::EntityAttr { et: 1, attr: 0 }.dim(&s), 3);
        // capability card 5 -> dim 6 with N/A
        assert_eq!(RVar::RelAttr { rel: 0, attr: 0 }.dim(&s), 6);
        assert_eq!(RVar::RelInd { rel: 0 }.dim(&s), 2);
    }

    #[test]
    fn names_are_readable() {
        let s = university_schema();
        assert_eq!(RVar::RelAttr { rel: 0, attr: 1 }.name(&s), "salary(P,S)");
        assert_eq!(RVar::RelInd { rel: 1 }.name(&s), "Registered(S,C)");
        assert_eq!(RVar::EntityAttr { et: 1, attr: 0 }.name(&s), "intelligence(S)");
    }

    #[test]
    fn populations_and_rel() {
        let s = university_schema();
        assert_eq!(RVar::RelInd { rel: 0 }.populations(&s), vec![0, 1]);
        assert_eq!(RVar::RelInd { rel: 0 }.rel(), Some(0));
        assert_eq!(RVar::EntityAttr { et: 2, attr: 0 }.rel(), None);
    }
}
