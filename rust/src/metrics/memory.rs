//! Memory accounting for Figure 4.
//!
//! The paper reports the peak resident set of the counting process.  The
//! quantity that actually differs between strategies is the bytes held in
//! ct-tables and caches, so we track those exactly (allocator- and
//! GC-independent), and additionally sample Linux `VmHWM` for an
//! end-to-end sanity number.

/// Exact byte accounting of live ct-table/cache memory.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemTracker {
    pub current_bytes: usize,
    pub peak_bytes: usize,
}

impl MemTracker {
    /// Saturating: a tracker fed absurd sizes pins at `usize::MAX`
    /// instead of wrapping (wrapped totals would *underreport* peaks,
    /// the one failure mode a memory profile must not have).
    pub fn add(&mut self, bytes: usize) {
        self.current_bytes = self.current_bytes.saturating_add(bytes);
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    /// Subtract released bytes.  Releasing more than is tracked means a
    /// caller's byte accounting drifted (e.g. a `CtCache::apply_delta`
    /// double-subtract) — that fails loudly in debug/test builds
    /// instead of being masked by saturation; release builds still
    /// saturate so a drifted profile cannot wrap into nonsense.
    pub fn sub(&mut self, bytes: usize) {
        debug_assert!(
            self.current_bytes >= bytes,
            "MemTracker underflow: sub({bytes}) from {} tracked bytes",
            self.current_bytes
        );
        self.current_bytes = self.current_bytes.saturating_sub(bytes);
    }

    /// Record a transient allocation that lives only within one
    /// operation (counts toward the peak, not the current level).
    /// Saturating, like [`MemTracker::add`].
    pub fn observe_transient(&mut self, bytes: usize) {
        self.peak_bytes = self.peak_bytes.max(self.current_bytes.saturating_add(bytes));
    }

    pub fn merge_peak(&mut self, other: &MemTracker) {
        self.peak_bytes = self.peak_bytes.max(other.peak_bytes);
    }
}

/// Linux `VmHWM` (peak RSS) in kilobytes, if available.
pub fn vm_hwm_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak() {
        let mut m = MemTracker::default();
        m.add(100);
        m.add(50);
        m.sub(120);
        assert_eq!(m.current_bytes, 30);
        assert_eq!(m.peak_bytes, 150);
        m.observe_transient(1000);
        assert_eq!(m.peak_bytes, 1030);
        assert_eq!(m.current_bytes, 30);
    }

    #[test]
    fn add_and_transient_saturate_instead_of_wrapping() {
        let mut m = MemTracker::default();
        m.add(usize::MAX - 10);
        m.add(100); // would wrap with unchecked +=
        assert_eq!(m.current_bytes, usize::MAX);
        assert_eq!(m.peak_bytes, usize::MAX);

        let mut t = MemTracker { current_bytes: usize::MAX - 5, peak_bytes: 0 };
        t.observe_transient(50); // would overflow current + bytes
        assert_eq!(t.peak_bytes, usize::MAX);
        assert_eq!(t.current_bytes, usize::MAX - 5);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "MemTracker underflow")]
    fn sub_underflow_fails_loudly_in_debug() {
        let mut m = MemTracker::default();
        m.add(10);
        m.sub(11);
    }

    #[test]
    fn vm_hwm_readable_on_linux() {
        // present on the CI image; tolerate absence elsewhere
        if cfg!(target_os = "linux") {
            assert!(vm_hwm_kb().unwrap_or(0) > 0);
        }
    }
}
