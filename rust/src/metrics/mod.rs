//! Measurement infrastructure for the paper's evaluation: the 3-component
//! runtime breakdown of Figure 3 (MetaData / positive ct / negative ct),
//! the memory profiling of Figure 4, and report rendering.

pub mod memory;
pub mod report;
pub mod timing;

pub use memory::MemTracker;
pub use timing::{Deadline, Phase, PhaseTimer};
