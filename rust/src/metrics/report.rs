//! Rendering of experiment rows in the shape of the paper's tables and
//! figures (consumed by the benches, the CLI `exp` subcommand and the
//! examples), plus machine-readable JSON emission for the bench
//! trajectory files (`BENCH_planner.json`, `BENCH_scaling.json`).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::json::Json;

/// One bar of Figure 3 / Figure 4: a (strategy, database) cell.
#[derive(Clone, Debug)]
pub struct RunRow {
    pub database: String,
    pub strategy: String,
    pub metadata: Duration,
    pub positive: Duration,
    pub negative: Duration,
    /// Exact ct-table/cache peak bytes (Figure 4).
    pub peak_ct_bytes: usize,
    /// Total rows over all ct-tables generated (Table 5).
    pub ct_rows_generated: u64,
    pub families_scored: u64,
    /// INNER-JOIN chain queries executed — the scale-free witness of the
    /// JOIN problem (ONDEMAND's is 10-100x the others').
    pub chain_queries: u64,
    pub timed_out: bool,
}

impl RunRow {
    pub fn total(&self) -> Duration {
        self.metadata + self.positive + self.negative
    }
}

fn fmt_dur(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

/// Figure-3-shaped table: stacked time components per (db, strategy).
pub fn render_fig3(rows: &[RunRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:<9} {:>10} {:>10} {:>10} {:>10} {:>8}  {}\n",
        "database", "strategy", "metadata_s", "ct+_s", "ct-_s", "total_s", "joins", "status"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:<9} {:>10} {:>10} {:>10} {:>10} {:>8}  {}\n",
            r.database,
            r.strategy,
            fmt_dur(r.metadata),
            fmt_dur(r.positive),
            fmt_dur(r.negative),
            fmt_dur(r.total()),
            r.chain_queries,
            if r.timed_out { "TIMEOUT" } else { "ok" }
        ));
    }
    out
}

/// Figure-4-shaped table: peak ct memory per (db, strategy).
pub fn render_fig4(rows: &[RunRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:<9} {:>14}  {}\n",
        "database", "strategy", "peak_ct_MiB", "status"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:<9} {:>14.3}  {}\n",
            r.database,
            r.strategy,
            r.peak_ct_bytes as f64 / (1024.0 * 1024.0),
            if r.timed_out { "TIMEOUT" } else { "ok" }
        ));
    }
    out
}

/// Table-5-shaped rows: ct(family) totals (ONDEMAND/HYBRID) vs
/// ct(database) totals (PRECOUNT).
#[derive(Clone, Debug)]
pub struct Table5Row {
    pub database: String,
    pub ct_family_rows: u64,
    pub ct_database_rows: u64,
}

pub fn render_table5(rows: &[Table5Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>22} {:>24}\n",
        "database", "ct(family)_total_rows", "ct(database)_total_rows"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>22} {:>24}\n",
            r.database, r.ct_family_rows, r.ct_database_rows
        ));
    }
    out
}

/// One cell of the coordinator worker-scaling sweep: a (database,
/// strategy, worker-count) run with its wall clock and the speedup
/// against the same cell at 1 worker.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub database: String,
    pub strategy: String,
    pub workers: usize,
    /// Wall-clock of the whole workload (prepare + serving).
    pub wall: Duration,
    /// `wall(1 worker) / wall(workers)`; 1.0 for the baseline row.
    pub speedup: f64,
    /// Summed per-worker CPU time, for an efficiency readout
    /// (`cpu / (workers * wall)`).
    pub cpu: Duration,
    pub timed_out: bool,
}

/// Render the worker-scaling sweep (the `coordinator_scaling` bench and
/// the CLI `exp scaling`).
pub fn render_scaling(rows: &[ScalingRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:<9} {:>8} {:>10} {:>9} {:>8} {:>11}  {}\n",
        "database", "strategy", "workers", "wall_s", "speedup", "cpu_s", "efficiency", "status"
    ));
    for r in rows {
        let eff = if r.workers > 0 && !r.wall.is_zero() {
            r.cpu.as_secs_f64() / (r.workers as f64 * r.wall.as_secs_f64())
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:<16} {:<9} {:>8} {:>10} {:>8.2}x {:>8} {:>10.0}%  {}\n",
            r.database,
            r.strategy,
            r.workers,
            fmt_dur(r.wall),
            r.speedup,
            fmt_dur(r.cpu),
            100.0 * eff,
            if r.timed_out { "TIMEOUT" } else { "ok" }
        ));
    }
    out
}

/// One cell of the ADAPTIVE planner sweep: a (database, memory-budget)
/// run tracing the pre-count fraction from 0 (pure ONDEMAND) through
/// HYBRID's operating point to 1 (pure PRECOUNT).
#[derive(Clone, Debug)]
pub struct PlannerRow {
    pub database: String,
    /// The `--mem-budget` the plan was filled against (`None` =
    /// unlimited).
    pub budget_bytes: Option<u64>,
    /// Estimated fraction of the full pre-count held resident — the
    /// sweep's x-axis.
    pub pre_fraction: f64,
    pub planned_positive: u64,
    pub planned_complete: u64,
    pub lattice_points: u64,
    pub metadata: Duration,
    pub positive: Duration,
    pub negative: Duration,
    pub peak_ct_bytes: usize,
    pub chain_queries: u64,
    pub ct_rows_generated: u64,
    pub estimator_walks: u64,
    pub workers: usize,
    pub timed_out: bool,
}

impl PlannerRow {
    pub fn total(&self) -> Duration {
        self.metadata + self.positive + self.negative
    }
}

/// Render the planner sweep (the `planner_sweep` bench and the CLI
/// `exp planner`).
pub fn render_planner(rows: &[PlannerRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>12} {:>8} {:>9} {:>10} {:>10} {:>10} {:>12} {:>8}  {}\n",
        "database",
        "budget",
        "pre_frac",
        "plan_p/c",
        "ct+_s",
        "ct-_s",
        "total_s",
        "peak_ct_MiB",
        "joins",
        "status"
    ));
    for r in rows {
        let budget = match r.budget_bytes {
            None => "inf".to_string(),
            Some(b) => b.to_string(),
        };
        out.push_str(&format!(
            "{:<16} {:>12} {:>8.3} {:>9} {:>10} {:>10} {:>10} {:>12.3} {:>8}  {}\n",
            r.database,
            budget,
            r.pre_fraction,
            format!("{}/{}", r.planned_positive, r.planned_complete),
            fmt_dur(r.positive),
            fmt_dur(r.negative),
            fmt_dur(r.total()),
            r.peak_ct_bytes as f64 / (1024.0 * 1024.0),
            r.chain_queries,
            if r.timed_out { "TIMEOUT" } else { "ok" }
        ));
    }
    out
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Machine-readable planner sweep (written to `BENCH_planner.json` by
/// `scripts/bench.sh`).
pub fn planner_rows_to_json(rows: &[PlannerRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    (
                        "budget_bytes",
                        r.budget_bytes.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
                    ),
                    ("pre_fraction", Json::Num(r.pre_fraction)),
                    ("planned_positive", Json::Num(r.planned_positive as f64)),
                    ("planned_complete", Json::Num(r.planned_complete as f64)),
                    ("lattice_points", Json::Num(r.lattice_points as f64)),
                    ("metadata_s", Json::Num(r.metadata.as_secs_f64())),
                    ("positive_s", Json::Num(r.positive.as_secs_f64())),
                    ("negative_s", Json::Num(r.negative.as_secs_f64())),
                    ("total_s", Json::Num(r.total().as_secs_f64())),
                    ("peak_ct_bytes", Json::Num(r.peak_ct_bytes as f64)),
                    ("chain_queries", Json::Num(r.chain_queries as f64)),
                    ("ct_rows_generated", Json::Num(r.ct_rows_generated as f64)),
                    ("estimator_walks", Json::Num(r.estimator_walks as f64)),
                    ("workers", Json::Num(r.workers as f64)),
                    ("timed_out", Json::Bool(r.timed_out)),
                ])
            })
            .collect(),
    )
}

/// Machine-readable scaling sweep (written to `BENCH_scaling.json` by
/// `scripts/bench.sh`).
pub fn scaling_rows_to_json(rows: &[ScalingRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("strategy", Json::Str(r.strategy.clone())),
                    ("workers", Json::Num(r.workers as f64)),
                    ("wall_s", Json::Num(r.wall.as_secs_f64())),
                    ("speedup", Json::Num(r.speedup)),
                    ("cpu_s", Json::Num(r.cpu.as_secs_f64())),
                    ("timed_out", Json::Bool(r.timed_out)),
                ])
            })
            .collect(),
    )
}

/// One cell of the streaming-churn experiment: a seeded delta batch
/// applied to a maintained cache state, delta path vs
/// invalidate-and-recount baseline (`relcount exp churn`,
/// `benches/delta_churn.rs`, EXPERIMENTS.md §E10).
#[derive(Clone, Debug)]
pub struct ChurnRow {
    pub database: String,
    /// Batch size as a fraction of the database's link rows.
    pub churn_frac: f64,
    /// Ops actually emitted for the batch.
    pub batch_ops: u64,
    pub link_inserts: u64,
    pub link_deletes: u64,
    pub entity_inserts: u64,
    /// Wall clock of the delta-maintained application.
    pub delta: Duration,
    /// Wall clock of the invalidate-and-recount application.
    pub recount: Duration,
    /// `recount / delta` (>1 means delta maintenance wins).
    pub speedup: f64,
    /// Points maintained through the delta path (delta run).
    pub points_delta_maintained: u64,
    /// Points the recount baseline re-joined.
    pub points_recounted: u64,
    /// Delta-table rows applied across resident caches (delta run).
    pub cells_touched: u64,
    /// Resident cache bytes after the batch (delta run).
    pub resident_bytes: usize,
    /// Deterministic digest of every resident table after the batch
    /// (hex) — identical across runs, worker counts, and both paths.
    pub digest: String,
    /// Delta and recount paths produced identical caches.
    pub consistent: bool,
    pub workers: usize,
}

/// Render the churn sweep (the `delta_churn` bench and `exp churn`).
pub fn render_churn(rows: &[ChurnRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>6} {:>6} {:>9} {:>10} {:>10} {:>8} {:>8} {:>9} {:>12}  {}\n",
        "database",
        "churn",
        "ops",
        "delta_s",
        "recount_s",
        "speedup",
        "pts_d",
        "pts_r",
        "cells",
        "resident_B",
        "check"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>6.3} {:>6} {:>9} {:>10} {:>9.2}x {:>8} {:>8} {:>9} {:>12}  {}\n",
            r.database,
            r.churn_frac,
            r.batch_ops,
            fmt_dur(r.delta),
            fmt_dur(r.recount),
            r.speedup,
            r.points_delta_maintained,
            r.points_recounted,
            r.cells_touched,
            r.resident_bytes,
            if r.consistent { "ok" } else { "MISMATCH" }
        ));
    }
    out
}

/// Machine-readable churn sweep (written to `BENCH_churn.json` by
/// `scripts/bench.sh`).  Key set is schema-stable; every non-timing
/// field is seed-deterministic (`rust/tests/churn_golden.rs`).
pub fn churn_rows_to_json(rows: &[ChurnRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("churn_frac", Json::Num(r.churn_frac)),
                    ("batch_ops", Json::Num(r.batch_ops as f64)),
                    ("link_inserts", Json::Num(r.link_inserts as f64)),
                    ("link_deletes", Json::Num(r.link_deletes as f64)),
                    ("entity_inserts", Json::Num(r.entity_inserts as f64)),
                    ("delta_s", Json::Num(r.delta.as_secs_f64())),
                    ("recount_s", Json::Num(r.recount.as_secs_f64())),
                    ("speedup", Json::Num(r.speedup)),
                    (
                        "points_delta_maintained",
                        Json::Num(r.points_delta_maintained as f64),
                    ),
                    ("points_recounted", Json::Num(r.points_recounted as f64)),
                    ("cells_touched", Json::Num(r.cells_touched as f64)),
                    ("resident_bytes", Json::Num(r.resident_bytes as f64)),
                    ("digest", Json::Str(r.digest.clone())),
                    ("consistent", Json::Bool(r.consistent)),
                    ("workers", Json::Num(r.workers as f64)),
                ])
            })
            .collect(),
    )
}

/// One generation's serving metrics from a `relcount serve` session:
/// request mix, latency, throughput and micro-batch queue depth, keyed
/// by the epoch the requests were answered from (`exp serve`,
/// `benches/serve_throughput.rs`, EXPERIMENTS.md §E12).
#[derive(Clone, Debug)]
pub struct ServeRow {
    pub database: String,
    /// Generation the requests in this row were served from.
    pub epoch: u64,
    pub requests: u64,
    pub count_requests: u64,
    pub score_requests: u64,
    /// In-protocol error responses (the session keeps going).
    pub errors: u64,
    /// Micro-batches dispatched against this generation.
    pub batches: u64,
    /// Largest micro-batch drained in one dispatch — the queue-depth
    /// proxy (capped by `--batch-max`).
    pub max_queue_depth: u64,
    /// Mean enqueue-to-response latency.
    pub mean_latency: Duration,
    pub max_latency: Duration,
    /// Requests per second over this generation's serving window.
    pub throughput_rps: f64,
    pub workers: usize,
    /// Nearest-rank latency percentiles over the (capped) sample set.
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Shard count of the topology the requests went through (0 =
    /// unsharded single-process serving).
    pub shards: usize,
    /// Client sessions that contributed to this row (1 for stdin mode).
    pub sessions: u64,
    /// Mean per-request wall time the router spent reconstructing and
    /// merging shard partials (0 when unsharded).
    pub merge_overhead_s: f64,
    /// Peak leader-minus-applied epoch gap observed on a follower
    /// while it replayed the run (0 without replication).
    pub follower_lag: f64,
}

/// Render a serve session's per-generation rows (`exp serve` and the
/// `serve_throughput` bench).
pub fn render_serve(rows: &[ServeRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>6} {:>6} {:>8} {:>8} {:>7} {:>7} {:>7} {:>8} {:>7} {:>9} {:>9} {:>9} {:>10} {:>9}\n",
        "database",
        "epoch",
        "shards",
        "sessions",
        "requests",
        "counts",
        "scores",
        "errors",
        "batches",
        "queue",
        "p50_ms",
        "p99_ms",
        "merge_ms",
        "req_per_s",
        "lag"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>6} {:>6} {:>8} {:>8} {:>7} {:>7} {:>7} {:>8} {:>7} {:>9.3} {:>9.3} {:>9.3} {:>10.1} {:>9.1}\n",
            r.database,
            r.epoch,
            r.shards,
            r.sessions,
            r.requests,
            r.count_requests,
            r.score_requests,
            r.errors,
            r.batches,
            r.max_queue_depth,
            r.p50_latency_s * 1e3,
            r.p99_latency_s * 1e3,
            r.merge_overhead_s * 1e3,
            r.throughput_rps,
            r.follower_lag
        ));
    }
    out
}

/// Machine-readable serve rows (written to `BENCH_serve.json` by
/// `scripts/bench.sh`).  Key set is schema-stable; the request mix is
/// seed-deterministic, the timing fields are not.
pub fn serve_rows_to_json(rows: &[ServeRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("epoch", Json::Num(r.epoch as f64)),
                    ("requests", Json::Num(r.requests as f64)),
                    ("count_requests", Json::Num(r.count_requests as f64)),
                    ("score_requests", Json::Num(r.score_requests as f64)),
                    ("errors", Json::Num(r.errors as f64)),
                    ("batches", Json::Num(r.batches as f64)),
                    ("max_queue_depth", Json::Num(r.max_queue_depth as f64)),
                    ("mean_latency_s", Json::Num(r.mean_latency.as_secs_f64())),
                    ("max_latency_s", Json::Num(r.max_latency.as_secs_f64())),
                    ("p50_latency_s", Json::Num(r.p50_latency_s)),
                    ("p99_latency_s", Json::Num(r.p99_latency_s)),
                    ("throughput_rps", Json::Num(r.throughput_rps)),
                    ("workers", Json::Num(r.workers as f64)),
                    ("shards", Json::Num(r.shards as f64)),
                    ("sessions", Json::Num(r.sessions as f64)),
                    ("merge_overhead_s", Json::Num(r.merge_overhead_s)),
                    ("follower_lag", Json::Num(r.follower_lag)),
                ])
            })
            .collect(),
    )
}

/// One preset's restart-latency comparison (`exp persist`,
/// EXPERIMENTS.md §E14): rebuilding the maintained-count state from
/// the base tables (a cold recount) versus saving a durable snapshot
/// and loading it back.  `digest_match` asserts all three states —
/// built, cold-rebuilt and snapshot-loaded — share one cache digest.
#[derive(Clone, Debug)]
pub struct PersistRow {
    pub database: String,
    /// Total tuples across all tables at snapshot time.
    pub rows: u64,
    /// Resident ct-cache bytes in the maintained state.
    pub resident_bytes: usize,
    /// On-disk bytes across every snapshot section file + manifest.
    pub snapshot_bytes: u64,
    /// Wall-clock of a from-scratch `MaintainedCounts::build`.
    pub cold_build: Duration,
    /// Wall-clock of `write_snapshot`.
    pub save: Duration,
    /// Wall-clock of `load_snapshot` + `into_maintained`.
    pub load: Duration,
    /// `cold_build / load` — the restart-latency win (E14 expects
    /// >= 5x on the largest preset).
    pub speedup: f64,
    pub digest_match: bool,
    pub workers: usize,
}

/// Render the restart-latency rows (`exp persist`).
pub fn render_persist(rows: &[PersistRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>9} {:>12} {:>12} {:>10} {:>10} {:>10} {:>8} {:>6}\n",
        "database",
        "rows",
        "resident_b",
        "snapshot_b",
        "cold_s",
        "save_s",
        "load_s",
        "speedup",
        "match"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>9} {:>12} {:>12} {:>10.4} {:>10.4} {:>10.4} {:>8.1} {:>6}\n",
            r.database,
            r.rows,
            r.resident_bytes,
            r.snapshot_bytes,
            r.cold_build.as_secs_f64(),
            r.save.as_secs_f64(),
            r.load.as_secs_f64(),
            r.speedup,
            r.digest_match
        ));
    }
    out
}

/// Machine-readable persist rows (written to `BENCH_persist.json` by
/// `scripts/bench.sh`).  Key set is schema-stable; `digest_match` is
/// deterministic, the timing fields are not.
pub fn persist_rows_to_json(rows: &[PersistRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("rows", Json::Num(r.rows as f64)),
                    ("resident_bytes", Json::Num(r.resident_bytes as f64)),
                    ("snapshot_bytes", Json::Num(r.snapshot_bytes as f64)),
                    ("cold_build_s", Json::Num(r.cold_build.as_secs_f64())),
                    ("save_s", Json::Num(r.save.as_secs_f64())),
                    ("load_s", Json::Num(r.load.as_secs_f64())),
                    ("speedup", Json::Num(r.speedup)),
                    ("digest_match", Json::Bool(r.digest_match)),
                    ("workers", Json::Num(r.workers as f64)),
                ])
            })
            .collect(),
    )
}

/// One (database, quality-mode) cell of the estimator quality lab
/// (`exp estimator`, EXPERIMENTS.md §E15): q-error distribution against
/// oracle counts plus plan-regret — see [`crate::estimate::quality`] for
/// the metric definitions.
#[derive(Clone, Debug)]
pub struct EstimatorRow {
    pub database: String,
    /// `"default"`, `"sampled"` or `"summary"`
    /// ([`crate::estimate::quality::QualityMode`]).
    pub mode: String,
    /// Lattice points evaluated.
    pub points: u64,
    pub q_p50: f64,
    pub q_p95: f64,
    pub q_max: f64,
    /// Fraction of points answered exactly.
    pub exact_frac: f64,
    /// Points answered by the O(1) summary tier.
    pub summary_hits: u64,
    /// Random walks consumed across all points.
    pub walks: u64,
    /// Fraction of the oracle plan's true admitted benefit forfeited.
    pub regret_saved_frac: f64,
    /// True bytes admitted beyond the budget, as a budget fraction;
    /// `None` for zero-budget sweeps, where the fraction is undefined.
    pub bytes_overrun_frac: Option<f64>,
}

/// Render the estimator quality lab (`exp estimator`).
pub fn render_estimator(rows: &[EstimatorRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:<8} {:>6} {:>8} {:>8} {:>8} {:>7} {:>8} {:>8} {:>8} {:>8}\n",
        "database",
        "mode",
        "points",
        "q_p50",
        "q_p95",
        "q_max",
        "exact",
        "sum_hit",
        "walks",
        "regret",
        "overrun"
    ));
    for r in rows {
        // a zero-budget sweep has no defined overrun fraction; say so
        // instead of printing a fabricated number
        let overrun = match r.bytes_overrun_frac {
            Some(v) => format!("{v:.3}"),
            None => "n/a".into(),
        };
        out.push_str(&format!(
            "{:<16} {:<8} {:>6} {:>8.3} {:>8.3} {:>8.3} {:>7.2} {:>8} {:>8} {:>8.3} {:>8}\n",
            r.database,
            r.mode,
            r.points,
            r.q_p50,
            r.q_p95,
            r.q_max,
            r.exact_frac,
            r.summary_hits,
            r.walks,
            r.regret_saved_frac,
            overrun
        ));
    }
    out
}

/// Machine-readable estimator-lab rows (written to
/// `BENCH_estimator.json` by `scripts/bench.sh` and gated in CI against
/// `scripts/estimator_gates.json`).  Key set is schema-stable; every
/// field is seed-deterministic.
pub fn estimator_rows_to_json(rows: &[EstimatorRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("mode", Json::Str(r.mode.clone())),
                    ("points", Json::Num(r.points as f64)),
                    ("q_p50", Json::Num(r.q_p50)),
                    ("q_p95", Json::Num(r.q_p95)),
                    ("q_max", Json::Num(r.q_max)),
                    ("exact_frac", Json::Num(r.exact_frac)),
                    ("summary_hits", Json::Num(r.summary_hits as f64)),
                    ("walks", Json::Num(r.walks as f64)),
                    ("regret_saved_frac", Json::Num(r.regret_saved_frac)),
                    (
                        "bytes_overrun_frac",
                        match r.bytes_overrun_frac {
                            Some(v) => Json::Num(v),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect(),
    )
}

/// One (database, lattice point) cell of the join-kernel experiment
/// (`exp wcoj`, EXPERIMENTS.md §E16): the binary chain kernel and the
/// worst-case optimal kernel count the same pattern; `identical` is the
/// differential gate ([`crate::db::wcoj`] docs) and must be `true` on
/// every row — the generator hard-errors otherwise, so the field exists
/// for the JSON schema, not as a soft signal.
#[derive(Clone, Debug)]
pub struct WcojRow {
    pub database: String,
    /// Relationship names of the lattice point, joined with `+`.
    pub point: String,
    /// [`crate::lattice::pattern::PatternClass`] name of the point.
    pub pattern: String,
    /// Relationships in the pattern.
    pub rels: usize,
    /// True join cardinality (`JoinStats::rows_enumerated`, identical
    /// across kernels by construction).
    pub rows_enumerated: u64,
    pub chain: Duration,
    pub wcoj: Duration,
    /// `chain / wcoj` wall-clock ratio (> 1 means the WCOJ kernel won).
    pub speedup: f64,
    /// Chain and WCOJ kernels (CSR and hash backends) agreed on the
    /// `CtTable` digest and on `JoinStats`.
    pub identical: bool,
}

/// Render the join-kernel differential experiment (`exp wcoj`).
pub fn render_wcoj(rows: &[WcojRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:<24} {:<10} {:>4} {:>10} {:>10} {:>10} {:>8} {:>6}\n",
        "database",
        "point",
        "pattern",
        "rels",
        "rows",
        "chain_s",
        "wcoj_s",
        "speedup",
        "ident"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:<24} {:<10} {:>4} {:>10} {:>10.4} {:>10.4} {:>8.1} {:>6}\n",
            r.database,
            r.point,
            r.pattern,
            r.rels,
            r.rows_enumerated,
            r.chain.as_secs_f64(),
            r.wcoj.as_secs_f64(),
            r.speedup,
            r.identical
        ));
    }
    out
}

/// Machine-readable WCOJ rows (written to `BENCH_wcoj.json` by
/// `scripts/bench.sh`).  Key set is schema-stable; `identical` and
/// `rows_enumerated` are deterministic, the timing fields are not.
pub fn wcoj_rows_to_json(rows: &[WcojRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("point", Json::Str(r.point.clone())),
                    ("pattern", Json::Str(r.pattern.clone())),
                    ("rels", Json::Num(r.rels as f64)),
                    ("rows_enumerated", Json::Num(r.rows_enumerated as f64)),
                    ("chain_s", Json::Num(r.chain.as_secs_f64())),
                    ("wcoj_s", Json::Num(r.wcoj.as_secs_f64())),
                    ("speedup", Json::Num(r.speedup)),
                    ("identical", Json::Bool(r.identical)),
                ])
            })
            .collect(),
    )
}

/// One database row of the index-compression experiment
/// (`exp compress`, EXPERIMENTS.md §E17): the three index backends
/// (csr, ccsr, hash) counted every multi-relationship lattice point
/// under both kernels and built the full caches at 1 and 4 workers;
/// `identical` is the differential gate and must be `true` on every
/// row — the generator hard-errors otherwise, so the field exists for
/// the JSON schema, not as a soft signal.
#[derive(Clone, Debug)]
pub struct CompressRow {
    pub database: String,
    /// Live link pairs across all relationship tables.
    pub pairs: u64,
    /// Resident bytes of all plain-CSR relationship indexes.
    pub csr_bytes: u64,
    /// Resident bytes of all compressed block-CSR indexes.
    pub ccsr_bytes: u64,
    pub bytes_per_pair_csr: f64,
    pub bytes_per_pair_ccsr: f64,
    /// `csr_bytes / ccsr_bytes` (> 1 means ccsr is smaller).
    pub bytes_ratio: f64,
    /// Multi-relationship lattice points differentially verified (the
    /// same set under each kernel).
    pub points: u64,
    /// Total positive-count time over those points on plain CSR.
    pub csr_time: Duration,
    /// Same workload on compressed block-CSR.
    pub ccsr_time: Duration,
    /// `csr_time / ccsr_time` intersection-throughput ratio (1.0 =
    /// parity; the CI gate requires >= 0.8 somewhere).
    pub throughput_vs_csr: f64,
    /// All three backends agreed on every count digest, JoinStats and
    /// cache digest at 1 and 4 workers.
    pub identical: bool,
    /// Highest worker count the cache digests were verified at.
    pub workers: usize,
}

/// Render the index-compression experiment (`exp compress`).
pub fn render_compress(rows: &[CompressRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>10} {:>12} {:>12} {:>7} {:>7} {:>6} {:>6} {:>8} {:>6}\n",
        "database",
        "pairs",
        "csr_bytes",
        "ccsr_bytes",
        "B/pair",
        "ratio",
        "points",
        "thru",
        "workers",
        "ident"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>10} {:>12} {:>12} {:>7.2} {:>6.2}x {:>6} {:>6.2} {:>8} {:>6}\n",
            r.database,
            r.pairs,
            r.csr_bytes,
            r.ccsr_bytes,
            r.bytes_per_pair_ccsr,
            r.bytes_ratio,
            r.points,
            r.throughput_vs_csr,
            r.workers,
            r.identical
        ));
    }
    out
}

/// Machine-readable compression rows (written to `BENCH_compress.json`
/// by `scripts/bench.sh`).  Key set is schema-stable; the byte and pair
/// fields are deterministic, the timing fields are not.
pub fn compress_rows_to_json(rows: &[CompressRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("database", Json::Str(r.database.clone())),
                    ("pairs", Json::Num(r.pairs as f64)),
                    ("csr_bytes", Json::Num(r.csr_bytes as f64)),
                    ("ccsr_bytes", Json::Num(r.ccsr_bytes as f64)),
                    ("bytes_per_pair_csr", Json::Num(r.bytes_per_pair_csr)),
                    ("bytes_per_pair_ccsr", Json::Num(r.bytes_per_pair_ccsr)),
                    ("bytes_ratio", Json::Num(r.bytes_ratio)),
                    ("points", Json::Num(r.points as f64)),
                    ("csr_s", Json::Num(r.csr_time.as_secs_f64())),
                    ("ccsr_s", Json::Num(r.ccsr_time.as_secs_f64())),
                    ("throughput_vs_csr", Json::Num(r.throughput_vs_csr)),
                    ("identical", Json::Bool(r.identical)),
                    ("workers", Json::Num(r.workers as f64)),
                ])
            })
            .collect(),
    )
}

/// Table-4-shaped rows.
#[derive(Clone, Debug)]
pub struct Table4Row {
    pub database: String,
    pub row_count: u64,
    pub n_relationships: usize,
    pub mean_parents_per_node: f64,
}

pub fn render_table4(rows: &[Table4Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>12} {:>16} {:>6}\n",
        "database", "row_count", "#relationships", "MP/N"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>12} {:>16} {:>6.1}\n",
            r.database, r.row_count, r.n_relationships, r.mean_parents_per_node
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> RunRow {
        RunRow {
            database: "uw".into(),
            strategy: "HYBRID".into(),
            metadata: Duration::from_millis(12),
            positive: Duration::from_millis(34),
            negative: Duration::from_millis(56),
            peak_ct_bytes: 2 * 1024 * 1024,
            ct_rows_generated: 1234,
            families_scored: 10,
            chain_queries: 7,
            timed_out: false,
        }
    }

    #[test]
    fn renders_all_tables() {
        let rows = vec![row()];
        let f3 = render_fig3(&rows);
        assert!(f3.contains("uw") && f3.contains("HYBRID") && f3.contains("0.056"));
        let f4 = render_fig4(&rows);
        assert!(f4.contains("2.000"));
        let t5 = render_table5(&[Table5Row {
            database: "uw".into(),
            ct_family_rows: 15318,
            ct_database_rows: 2828,
        }]);
        assert!(t5.contains("15318"));
        let t4 = render_table4(&[Table4Row {
            database: "uw".into(),
            row_count: 712,
            n_relationships: 2,
            mean_parents_per_node: 1.6,
        }]);
        assert!(t4.contains("1.6"));
    }

    #[test]
    fn renders_scaling() {
        let s = render_scaling(&[ScalingRow {
            database: "uw".into(),
            strategy: "HYBRID".into(),
            workers: 4,
            wall: Duration::from_millis(250),
            speedup: 3.2,
            cpu: Duration::from_millis(800),
            timed_out: false,
        }]);
        assert!(s.contains("3.20x"));
        assert!(s.contains("80%")); // 0.8 / (4 * 0.25)
    }

    #[test]
    fn total_sums_phases() {
        assert_eq!(row().total(), Duration::from_millis(102));
    }

    fn planner_row() -> PlannerRow {
        PlannerRow {
            database: "uw".into(),
            budget_bytes: Some(4096),
            pre_fraction: 0.375,
            planned_positive: 2,
            planned_complete: 1,
            lattice_points: 3,
            metadata: Duration::from_millis(2),
            positive: Duration::from_millis(10),
            negative: Duration::from_millis(5),
            peak_ct_bytes: 1024 * 1024,
            chain_queries: 4,
            ct_rows_generated: 99,
            estimator_walks: 256,
            workers: 1,
            timed_out: false,
        }
    }

    #[test]
    fn renders_planner() {
        let s = render_planner(&[planner_row()]);
        assert!(s.contains("uw") && s.contains("0.375") && s.contains("2/1"));
        let mut unlimited = planner_row();
        unlimited.budget_bytes = None;
        assert!(render_planner(&[unlimited]).contains("inf"));
    }

    #[test]
    fn planner_json_roundtrips() {
        let j = planner_rows_to_json(&[planner_row()]);
        let parsed = Json::parse(&j.dump()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("database").unwrap().as_str(), Some("uw"));
        assert_eq!(row.get("budget_bytes").unwrap().as_f64(), Some(4096.0));
        assert_eq!(row.get("planned_complete").unwrap().as_f64(), Some(1.0));
        // unlimited budget serializes as null
        let mut unlimited = planner_row();
        unlimited.budget_bytes = None;
        let j2 = planner_rows_to_json(&[unlimited]);
        assert!(j2.dump().contains("\"budget_bytes\":null"));
    }

    fn churn_row() -> ChurnRow {
        ChurnRow {
            database: "uw".into(),
            churn_frac: 0.05,
            batch_ops: 20,
            link_inserts: 9,
            link_deletes: 10,
            entity_inserts: 1,
            delta: Duration::from_millis(3),
            recount: Duration::from_millis(30),
            speedup: 10.0,
            points_delta_maintained: 3,
            points_recounted: 3,
            cells_touched: 120,
            resident_bytes: 4096,
            digest: "deadbeefdeadbeef".into(),
            consistent: true,
            workers: 1,
        }
    }

    #[test]
    fn renders_churn() {
        let s = render_churn(&[churn_row()]);
        assert!(s.contains("uw") && s.contains("10.00x") && s.contains("ok"));
        let mut bad = churn_row();
        bad.consistent = false;
        assert!(render_churn(&[bad]).contains("MISMATCH"));
    }

    #[test]
    fn churn_json_shapes() {
        let j = churn_rows_to_json(&[churn_row()]);
        let parsed = Json::parse(&j.dump()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("digest").unwrap().as_str(), Some("deadbeefdeadbeef"));
        assert_eq!(row.get("speedup").unwrap().as_f64(), Some(10.0));
        assert_eq!(row.get("consistent").unwrap(), &Json::Bool(true));
    }

    fn serve_row() -> ServeRow {
        ServeRow {
            database: "uw".into(),
            epoch: 2,
            requests: 40,
            count_requests: 30,
            score_requests: 10,
            errors: 0,
            batches: 5,
            max_queue_depth: 16,
            mean_latency: Duration::from_micros(250),
            max_latency: Duration::from_millis(2),
            throughput_rps: 1234.5,
            workers: 4,
            p50_latency_s: 0.000_25,
            p99_latency_s: 0.001_75,
            shards: 2,
            sessions: 3,
            merge_overhead_s: 0.000_125,
            follower_lag: 0.0,
        }
    }

    #[test]
    fn renders_serve() {
        let s = render_serve(&[serve_row()]);
        assert!(s.contains("uw"));
        assert!(s.contains("1234.5"));
        assert!(s.contains("0.250")); // p50 latency in ms
        assert!(s.contains("1.750")); // p99 latency in ms
        assert!(s.contains("shards") && s.contains("sessions"));
    }

    #[test]
    fn serve_json_shapes() {
        let j = serve_rows_to_json(&[serve_row()]);
        let parsed = Json::parse(&j.dump()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("epoch").unwrap().as_f64(), Some(2.0));
        assert_eq!(row.get("requests").unwrap().as_f64(), Some(40.0));
        assert_eq!(row.get("throughput_rps").unwrap().as_f64(), Some(1234.5));
        assert_eq!(row.get("workers").unwrap().as_f64(), Some(4.0));
        assert_eq!(row.get("shards").unwrap().as_f64(), Some(2.0));
        assert_eq!(row.get("sessions").unwrap().as_f64(), Some(3.0));
        assert_eq!(row.get("p50_latency_s").unwrap().as_f64(), Some(0.000_25));
        assert_eq!(row.get("merge_overhead_s").unwrap().as_f64(), Some(0.000_125));
        assert_eq!(row.get("follower_lag").unwrap().as_f64(), Some(0.0));
    }

    fn estimator_row() -> EstimatorRow {
        EstimatorRow {
            database: "uw".into(),
            mode: "sampled".into(),
            points: 3,
            q_p50: 1.25,
            q_p95: 2.5,
            q_max: 4.0,
            exact_frac: 0.0,
            summary_hits: 0,
            walks: 768,
            regret_saved_frac: 0.125,
            bytes_overrun_frac: Some(0.0),
        }
    }

    #[test]
    fn renders_estimator() {
        let s = render_estimator(&[estimator_row()]);
        assert!(s.contains("uw") && s.contains("sampled"));
        assert!(s.contains("1.250") && s.contains("4.000"));
        assert!(s.contains("0.125"));
    }

    #[test]
    fn undefined_overrun_renders_na_and_null() {
        let mut r = estimator_row();
        r.bytes_overrun_frac = None;
        let s = render_estimator(&[r.clone()]);
        assert!(s.contains("n/a"), "zero-budget rows must say n/a: {s}");
        let j = estimator_rows_to_json(&[r]).dump();
        assert!(j.contains("\"bytes_overrun_frac\":null"), "{j}");
    }

    #[test]
    fn estimator_json_shapes() {
        let j = estimator_rows_to_json(&[estimator_row()]);
        let parsed = Json::parse(&j.dump()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("mode").unwrap().as_str(), Some("sampled"));
        assert_eq!(row.get("q_p50").unwrap().as_f64(), Some(1.25));
        assert_eq!(row.get("q_max").unwrap().as_f64(), Some(4.0));
        assert_eq!(row.get("regret_saved_frac").unwrap().as_f64(), Some(0.125));
        assert_eq!(row.get("walks").unwrap().as_f64(), Some(768.0));
    }

    fn wcoj_row() -> WcojRow {
        WcojRow {
            database: "tri_skew".into(),
            point: "R0+R1+R2".into(),
            pattern: "triangle".into(),
            rels: 3,
            rows_enumerated: 70,
            chain: Duration::from_millis(40),
            wcoj: Duration::from_millis(5),
            speedup: 8.0,
            identical: true,
        }
    }

    #[test]
    fn renders_wcoj() {
        let s = render_wcoj(&[wcoj_row()]);
        assert!(s.contains("tri_skew") && s.contains("R0+R1+R2"));
        assert!(s.contains("triangle") && s.contains("8.0"));
        assert!(s.contains("true"));
    }

    #[test]
    fn wcoj_json_shapes() {
        let j = wcoj_rows_to_json(&[wcoj_row()]);
        let parsed = Json::parse(&j.dump()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("pattern").unwrap().as_str(), Some("triangle"));
        assert_eq!(row.get("rows_enumerated").unwrap().as_f64(), Some(70.0));
        assert_eq!(row.get("speedup").unwrap().as_f64(), Some(8.0));
        assert_eq!(row.get("identical").unwrap(), &Json::Bool(true));
    }

    fn compress_row() -> CompressRow {
        CompressRow {
            database: "tri_skew".into(),
            pairs: 12000,
            csr_bytes: 200_000,
            ccsr_bytes: 64_000,
            bytes_per_pair_csr: 16.67,
            bytes_per_pair_ccsr: 5.33,
            bytes_ratio: 3.125,
            points: 7,
            csr_time: Duration::from_millis(40),
            ccsr_time: Duration::from_millis(44),
            throughput_vs_csr: 0.91,
            identical: true,
            workers: 4,
        }
    }

    #[test]
    fn renders_compress() {
        let s = render_compress(&[compress_row()]);
        assert!(s.contains("tri_skew") && s.contains("64000"));
        assert!(s.contains("3.12x") && s.contains("true"));
    }

    #[test]
    fn compress_json_shapes() {
        let j = compress_rows_to_json(&[compress_row()]);
        let parsed = Json::parse(&j.dump()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("pairs").unwrap().as_f64(), Some(12000.0));
        assert_eq!(row.get("ccsr_bytes").unwrap().as_f64(), Some(64000.0));
        assert_eq!(row.get("bytes_per_pair_ccsr").unwrap().as_f64(), Some(5.33));
        assert_eq!(row.get("bytes_ratio").unwrap().as_f64(), Some(3.125));
        assert_eq!(row.get("throughput_vs_csr").unwrap().as_f64(), Some(0.91));
        assert_eq!(row.get("identical").unwrap(), &Json::Bool(true));
        assert_eq!(row.get("workers").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn scaling_json_shapes() {
        let j = scaling_rows_to_json(&[ScalingRow {
            database: "uw".into(),
            strategy: "ADAPTIVE".into(),
            workers: 2,
            wall: Duration::from_millis(100),
            speedup: 1.7,
            cpu: Duration::from_millis(150),
            timed_out: false,
        }]);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(
            parsed.as_arr().unwrap()[0].get("speedup").unwrap().as_f64(),
            Some(1.7)
        );
    }
}
