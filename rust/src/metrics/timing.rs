//! Phase timing: the paper breaks ct-table construction time into
//! MetaData, positive ct-table and negative ct-table components
//! (Figure 3).  [`PhaseTimer`] accumulates wall-clock per phase;
//! [`Deadline`] reproduces the 100-minute Slurm limit that ONDEMAND
//! exceeds on the large databases.

use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// The paper's three runtime components.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Schema/1rv extraction, lattice generation, metaquery planning.
    Metadata,
    /// Positive ct-tables: entity GROUP BYs, chain JOINs, projections.
    Positive,
    /// Negative ct-tables: the Möbius Join.
    Negative,
}

/// Accumulated wall-clock per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimer {
    pub metadata: Duration,
    pub positive: Duration,
    pub negative: Duration,
}

impl PhaseTimer {
    /// Run `f`, attributing its wall time to `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        match phase {
            Phase::Metadata => self.metadata += d,
            Phase::Positive => self.positive += d,
            Phase::Negative => self.negative += d,
        }
    }

    pub fn get(&self, phase: Phase) -> Duration {
        match phase {
            Phase::Metadata => self.metadata,
            Phase::Positive => self.positive,
            Phase::Negative => self.negative,
        }
    }

    pub fn total(&self) -> Duration {
        self.metadata + self.positive + self.negative
    }

    /// Merge another timer into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        self.metadata += other.metadata;
        self.positive += other.positive;
        self.negative += other.negative;
    }
}

/// Per-worker phase timers of a parallel counting run.
///
/// Each worker shard of the coordinator accumulates its own
/// [`PhaseTimer`]; this collection aggregates them two ways:
/// [`WorkerTimers::cpu_total`] (the summed CPU view, comparable to a
/// sequential run's timer) and [`WorkerTimers::critical_path`] (the
/// slowest worker per phase — the lower bound on parallel wall time).
#[derive(Clone, Debug, Default)]
pub struct WorkerTimers {
    pub workers: Vec<PhaseTimer>,
}

impl WorkerTimers {
    /// Timers for `n` workers, all zero.
    pub fn new(n: usize) -> Self {
        WorkerTimers { workers: vec![PhaseTimer::default(); n] }
    }

    /// Grow to at least `n` workers (keeps existing accumulations).
    pub fn ensure(&mut self, n: usize) {
        if self.workers.len() < n {
            self.workers.resize(n, PhaseTimer::default());
        }
    }

    /// Attribute `d` of `phase` to `worker`.
    pub fn add(&mut self, worker: usize, phase: Phase, d: Duration) {
        self.ensure(worker + 1);
        self.workers[worker].add(phase, d);
    }

    /// Summed CPU time per phase over all workers.
    pub fn cpu_total(&self) -> PhaseTimer {
        let mut t = PhaseTimer::default();
        for w in &self.workers {
            t.merge(w);
        }
        t
    }

    /// Per-phase maximum over workers: the busiest shard's time, i.e. the
    /// critical path of a perfectly overlapped parallel phase.
    pub fn critical_path(&self) -> PhaseTimer {
        let mut t = PhaseTimer::default();
        for w in &self.workers {
            t.metadata = t.metadata.max(w.metadata);
            t.positive = t.positive.max(w.positive);
            t.negative = t.negative.max(w.negative);
        }
        t
    }
}

/// A wall-clock budget.  `check` returns the paper-shaped timeout error
/// once exceeded.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    start: Instant,
    budget: Option<Duration>,
}

impl Deadline {
    pub fn new(budget: Option<Duration>) -> Self {
        Deadline { start: Instant::now(), budget }
    }

    pub fn unlimited() -> Self {
        Deadline::new(None)
    }

    pub fn check(&self, phase: &str) -> Result<()> {
        if let Some(b) = self.budget {
            let elapsed = self.start.elapsed();
            if elapsed > b {
                return Err(Error::Timeout {
                    phase: phase.to_string(),
                    elapsed_ms: elapsed.as_millis() as u64,
                });
            }
        }
        Ok(())
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let mut t = PhaseTimer::default();
        let x = t.time(Phase::Positive, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(x, 42);
        assert!(t.positive >= Duration::from_millis(5));
        assert_eq!(t.metadata, Duration::ZERO);
        assert_eq!(t.total(), t.positive);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::default();
        a.add(Phase::Metadata, Duration::from_millis(3));
        let mut b = PhaseTimer::default();
        b.add(Phase::Metadata, Duration::from_millis(4));
        b.add(Phase::Negative, Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.metadata, Duration::from_millis(7));
        assert_eq!(a.negative, Duration::from_millis(1));
    }

    #[test]
    fn worker_timers_aggregate() {
        let mut wt = WorkerTimers::new(2);
        wt.add(0, Phase::Positive, Duration::from_millis(10));
        wt.add(1, Phase::Positive, Duration::from_millis(4));
        wt.add(3, Phase::Negative, Duration::from_millis(6)); // auto-grow
        assert_eq!(wt.workers.len(), 4);
        let cpu = wt.cpu_total();
        assert_eq!(cpu.positive, Duration::from_millis(14));
        assert_eq!(cpu.negative, Duration::from_millis(6));
        let crit = wt.critical_path();
        assert_eq!(crit.positive, Duration::from_millis(10));
        assert_eq!(crit.negative, Duration::from_millis(6));
        assert_eq!(crit.metadata, Duration::ZERO);
    }

    #[test]
    fn deadline_fires() {
        let d = Deadline::new(Some(Duration::from_millis(1)));
        std::thread::sleep(Duration::from_millis(3));
        let e = d.check("positive").unwrap_err();
        assert!(e.is_timeout());
        assert!(Deadline::unlimited().check("x").is_ok());
    }
}
