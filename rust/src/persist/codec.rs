//! Length-prefixed little-endian binary codec for snapshot sections.
//!
//! Every section payload is built with [`ByteWriter`] and parsed back
//! with [`ByteReader`]; the reader carries the section name so every
//! decode failure surfaces as [`crate::error::Error::Persist`] naming
//! the on-disk artifact that broke.  The checksum is the crate's own
//! [`crate::util::fxhash::FxHasher`] over the payload bytes plus the
//! length — no external CRC dependency, and the same function the cache
//! digests use, so one hash implementation guards the whole pipeline.
//!
//! i128 ct-counts and f64 plan estimates are encoded via their exact
//! bit patterns (`to_le_bytes` / `to_bits`), never through JSON's f64
//! numbers, so round-trips are bit-identical at any magnitude.

use crate::error::{Error, Result};
use crate::util::fxhash::FxHasher;

/// Checksum over a byte string: FxHasher fed the bytes then the length
/// (the length term keeps a truncated-but-zero-padded payload from
/// colliding with the original).
pub fn checksum64(bytes: &[u8]) -> u64 {
    use std::hash::Hasher;
    let mut h = FxHasher::default();
    h.write(bytes);
    h.write_u64(bytes.len() as u64);
    h.finish()
}

/// Append-only little-endian byte buffer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i128(&mut self, v: i128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed u32 vector.
    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_u32(x);
        }
    }

    /// Length-prefixed u64 vector (ccsr packed words / bit offsets).
    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x);
        }
    }

    /// Length-prefixed u8 vector (ccsr per-block bit widths).
    pub fn put_u8s(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }
}

/// Cursor over a section payload; every failure names the section.
pub struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
    section: &'a str,
}

impl<'a> ByteReader<'a> {
    pub fn new(b: &'a [u8], section: &'a str) -> Self {
        ByteReader { b, i: 0, section }
    }

    pub fn err(&self, msg: impl Into<String>) -> Error {
        Error::Persist {
            section: self.section.to_string(),
            msg: format!("{} (at byte {})", msg.into(), self.i),
        }
    }

    /// All bytes consumed?  Trailing garbage in a section is corruption
    /// the checksum missed only if the checksum itself was forged, but
    /// we still reject it.
    pub fn finish(&self) -> Result<()> {
        if self.i != self.b.len() {
            return Err(self.err(format!(
                "{} trailing bytes after decode",
                self.b.len() - self.i
            )));
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(self.err(format!(
                "truncated: need {n} bytes, {} remain",
                self.b.len() - self.i
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn get_i128(&mut self) -> Result<i128> {
        Ok(i128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| self.err(format!("{v} overflows usize")))
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.err("invalid utf-8"))
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.get_u32()? as usize;
        // cap the preallocation: a corrupt length must not OOM before
        // the truncation check fires
        let mut v = Vec::with_capacity(n.min(self.b.len() / 4 + 1));
        for _ in 0..n {
            v.push(self.get_u32()?);
        }
        Ok(v)
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.get_usize()?;
        let mut v = Vec::with_capacity(n.min(self.b.len() / 8 + 1));
        for _ in 0..n {
            v.push(self.get_u64()?);
        }
        Ok(v)
    }

    pub fn get_u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.get_usize()?;
        Ok(self.take(n)?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_u128(u128::MAX / 3);
        w.put_i128(-(1i128 << 100));
        w.put_f64(-0.1);
        w.put_usize(42);
        w.put_str("café ✓");
        w.put_u32s(&[1, 2, 3]);
        w.put_u64s(&[u64::MAX, 0, 9]);
        w.put_u8s(&[4, 0, 32]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes, "test");
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_u128().unwrap(), u128::MAX / 3);
        assert_eq!(r.get_i128().unwrap(), -(1i128 << 100));
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.1f64).to_bits());
        assert_eq!(r.get_usize().unwrap(), 42);
        assert_eq!(r.get_str().unwrap(), "café ✓");
        assert_eq!(r.get_u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_u64s().unwrap(), vec![u64::MAX, 0, 9]);
        assert_eq!(r.get_u8s().unwrap(), vec![4, 0, 32]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_and_trailing_name_the_section() {
        let mut w = ByteWriter::new();
        w.put_u64(5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4], "caches");
        let e = r.get_u64().unwrap_err();
        assert_eq!(e.persist_section(), Some("caches"));
        assert!(e.to_string().contains("truncated"));

        let mut r = ByteReader::new(&bytes, "plan");
        r.get_u32().unwrap();
        let e = r.finish().unwrap_err();
        assert_eq!(e.persist_section(), Some("plan"));
    }

    #[test]
    fn checksum_sensitive_to_every_byte() {
        let data = b"0123456789abcdef".to_vec();
        let base = checksum64(&data);
        for i in 0..data.len() {
            let mut flipped = data.clone();
            flipped[i] ^= 0x01;
            assert_ne!(checksum64(&flipped), base, "byte {i}");
        }
        // length-extension: truncation changes the sum too
        assert_ne!(checksum64(&data[..15]), base);
    }
}
