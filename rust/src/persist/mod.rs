//! Durable snapshots + write-ahead log for the maintained-count state.
//!
//! PR 4/5 made mutation cheap (delta-maintained caches) and reads
//! concurrent (snapshot-isolated generations); both died with the
//! process.  This module adds the crash-consistency layer: a serving
//! data directory
//!
//! ```text
//! <data-dir>/
//!   wal.log            append-only DeltaBatch log (see [`wal`])
//!   snapshots/
//!     snap-<epoch:016x>/   manifest-addressed snapshot (see [`snapshot`])
//!     snap-<epoch:016x>/   ... the newest `RETAIN` epochs are kept
//! ```
//!
//! **Durability protocol** (the serving engine's write path):
//!
//! 1. apply the batch to a clone of the writer state (PR 4);
//! 2. append the batch to the WAL with the post-apply `cache_digest`
//!    and `fsync` — only then
//! 3. publish the new generation to readers;
//! 4. every N batches (and on graceful shutdown), write a full snapshot
//!    to a temp directory and `rename` it into place.
//!
//! A batch is therefore durable *before* any reader can observe it, and
//! a crash between (2) and (3) merely replays a batch the readers never
//! saw — convergent, since replay reproduces the exact writer state.
//!
//! **Recovery** ([`DataDir::recover`]) = newest snapshot that passes
//! full verification (per-section checksums + reloaded-cache digest) +
//! replay of the WAL records after its epoch.  Every replayed record
//! carries the digest the original writer observed, so recovery proves
//! bit-identity batch by batch — it can never silently diverge.  A
//! snapshot that fails verification is skipped (typed error recorded,
//! older snapshot tried); corrupt WAL records refuse recovery rather
//! than serve unproven counts.
//!
//! **WAL pruning**: after a successful snapshot save, records at or
//! below the **oldest retained** snapshot's epoch are dead — every
//! snapshot recovery could start from has already folded them in — so
//! the engine rewrites the log without them ([`WalWriter::prune_through`],
//! atomic temp + `rename`).  Pruning to the oldest (not newest) retained
//! epoch preserves the fallback invariant: even when the newest snapshot
//! is damaged, the oldest retained snapshot + the pruned log still
//! reaches the pre-crash epoch.  Replay itself still skips records at or
//! below its chosen snapshot's epoch, so a log that was never pruned
//! (or pruned less aggressively) recovers identically; see DESIGN.md §3e.

pub mod codec;
pub mod snapshot;
pub mod wal;

pub use snapshot::{
    load_snapshot, verify_snapshot, write_snapshot, SnapshotInfo, SnapshotState,
};
pub use wal::{prune_records, read_records, WalRecord, WalWriter};

use std::fs;
use std::path::{Path, PathBuf};

use crate::delta::maintain::MaintainedCounts;
use crate::error::{Error, Result};

/// Default number of snapshots kept per data directory (newest first);
/// older epochs are deleted after a successful save.  Override per
/// directory with [`DataDir::with_retain`] / [`DataDir::set_retain`]
/// (CLI `--snapshot-retain`).
pub const RETAIN: usize = 2;

const SNAP_PREFIX: &str = "snap-";

fn perr(section: &str, msg: impl Into<String>) -> Error {
    Error::Persist { section: section.into(), msg: msg.into() }
}

/// A serving data directory: WAL + snapshot retention + recovery.
pub struct DataDir {
    root: PathBuf,
    /// Snapshots kept by [`DataDir::prune_snapshots`]; the WAL prune
    /// cutoff follows the on-disk epochs, so it respects this too.
    retain: usize,
}

impl DataDir {
    /// Open (creating if needed) `root` and its `snapshots/` subdir,
    /// with the default [`RETAIN`] retention.
    pub fn open(root: &Path) -> Result<DataDir> {
        fs::create_dir_all(root.join("snapshots"))
            .map_err(|e| perr("datadir", format!("create {}: {e}", root.display())))?;
        Ok(DataDir { root: root.to_path_buf(), retain: RETAIN })
    }

    /// Open with an explicit retention count (must be >= 1: retaining
    /// zero snapshots would make every recovery impossible).
    pub fn with_retain(root: &Path, retain: usize) -> Result<DataDir> {
        if retain == 0 {
            return Err(perr("datadir", "snapshot retention must be >= 1"));
        }
        let mut dd = Self::open(root)?;
        dd.retain = retain;
        Ok(dd)
    }

    /// Snapshots kept after each successful save.
    pub fn retain(&self) -> usize {
        self.retain
    }

    /// Change the retention count (must be >= 1).  Takes effect at the
    /// next [`DataDir::save_snapshot`]; shrinking it does not delete
    /// anything until then.
    pub fn set_retain(&mut self, retain: usize) -> Result<()> {
        if retain == 0 {
            return Err(perr("datadir", "snapshot retention must be >= 1"));
        }
        self.retain = retain;
        Ok(())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn wal_path(&self) -> PathBuf {
        self.root.join("wal.log")
    }

    fn snapshots_root(&self) -> PathBuf {
        self.root.join("snapshots")
    }

    pub fn snapshot_dir(&self, epoch: u64) -> PathBuf {
        self.snapshots_root().join(format!("{SNAP_PREFIX}{epoch:016x}"))
    }

    /// Epochs with a `snap-<epoch>` directory, ascending.  Names that
    /// don't parse (temp dirs from an interrupted save) are ignored.
    pub fn snapshot_epochs(&self) -> Result<Vec<u64>> {
        let mut epochs = Vec::new();
        let dir = self.snapshots_root();
        let rd = fs::read_dir(&dir)
            .map_err(|e| perr("datadir", format!("list {}: {e}", dir.display())))?;
        for entry in rd {
            let entry =
                entry.map_err(|e| perr("datadir", format!("list entry: {e}")))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(hexpart) = name.strip_prefix(SNAP_PREFIX) {
                if let Ok(e) = u64::from_str_radix(hexpart, 16) {
                    epochs.push(e);
                }
            }
        }
        epochs.sort_unstable();
        Ok(epochs)
    }

    pub fn latest_snapshot_epoch(&self) -> Result<Option<u64>> {
        Ok(self.snapshot_epochs()?.last().copied())
    }

    pub fn has_snapshots(&self) -> Result<bool> {
        Ok(!self.snapshot_epochs()?.is_empty())
    }

    /// Write a snapshot of `m` at `epoch`: compact the indexes, write
    /// every section into a temp directory, then `rename` it to
    /// `snap-<epoch>` — the snapshot either exists completely or not at
    /// all.  Older snapshots beyond [`DataDir::retain`] are then
    /// deleted.
    pub fn save_snapshot(&self, m: &mut MaintainedCounts, epoch: u64) -> Result<PathBuf> {
        m.compact_indexes();
        let final_dir = self.snapshot_dir(epoch);
        if final_dir.exists() {
            // same epoch already durable (e.g. shutdown right after a
            // periodic snapshot): nothing to write
            return Ok(final_dir);
        }
        let tmp_dir = self.snapshots_root().join(format!(".tmp-{epoch:016x}"));
        if tmp_dir.exists() {
            fs::remove_dir_all(&tmp_dir)
                .map_err(|e| perr("datadir", format!("clear temp dir: {e}")))?;
        }
        fs::create_dir_all(&tmp_dir)
            .map_err(|e| perr("datadir", format!("create temp dir: {e}")))?;
        snapshot::write_snapshot(&tmp_dir, m, epoch)?;
        fs::rename(&tmp_dir, &final_dir).map_err(|e| {
            perr("datadir", format!("publish {}: {e}", final_dir.display()))
        })?;
        // best-effort: make the rename itself durable
        if let Ok(d) = fs::File::open(self.snapshots_root()) {
            let _ = d.sync_all();
        }
        self.prune_snapshots()?;
        Ok(final_dir)
    }

    fn prune_snapshots(&self) -> Result<()> {
        let epochs = self.snapshot_epochs()?;
        if epochs.len() <= self.retain {
            return Ok(());
        }
        for &old in &epochs[..epochs.len() - self.retain] {
            let dir = self.snapshot_dir(old);
            fs::remove_dir_all(&dir)
                .map_err(|e| perr("datadir", format!("prune {}: {e}", dir.display())))?;
        }
        Ok(())
    }

    /// The WAL-prune cutoff: the **oldest retained** snapshot's epoch.
    /// Records at or below it are folded into every snapshot recovery
    /// could start from, so dropping them can never break
    /// snapshot-plus-suffix replay — including the fallback past a
    /// damaged newer snapshot.  `None` when there is no snapshot yet
    /// (nothing is safely prunable).
    pub fn wal_prune_cutoff(&self) -> Result<Option<u64>> {
        Ok(self.snapshot_epochs()?.first().copied())
    }

    /// Prune WAL records already folded into every retained snapshot
    /// (`epoch <= cutoff`, normally [`DataDir::wal_prune_cutoff`]).
    /// Returns how many records were dropped.  A caller holding an open
    /// [`WalWriter`] must use [`WalWriter::prune_through`] instead — the
    /// rewrite replaces the file under any open append fd.
    pub fn prune_wal(&self, cutoff: u64) -> Result<usize> {
        wal::prune_records(&self.wal_path(), cutoff)
    }

    /// Recover the pre-crash writer state: load the newest snapshot
    /// that passes full verification (older ones are tried when a
    /// newer one is damaged — with the WAL intact no committed batch is
    /// lost, only replayed), then replay the WAL suffix, checking the
    /// recorded digest after **every** batch.  Returns the state and
    /// its epoch.  `workers` overrides the persisted worker count when
    /// non-zero.
    pub fn recover(&self, workers: usize) -> Result<(MaintainedCounts, u64)> {
        let epochs = self.snapshot_epochs()?;
        if epochs.is_empty() {
            return Err(perr("datadir", "no snapshots to recover from"));
        }
        let mut last_err: Option<Error> = None;
        for &epoch in epochs.iter().rev() {
            match snapshot::load_snapshot(&self.snapshot_dir(epoch)) {
                Ok(state) => {
                    let m = state.into_maintained(workers)?;
                    return self.replay_wal(m, epoch);
                }
                Err(e @ Error::Persist { .. }) => {
                    // damaged snapshot: remember why, fall back to the
                    // previous epoch
                    last_err = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(match last_err {
            Some(Error::Persist { section, msg }) => Error::Persist {
                section,
                msg: format!("no snapshot passed verification; last error: {msg}"),
            },
            _ => perr("datadir", "no snapshot passed verification"),
        })
    }

    /// Replay WAL records after `from_epoch` onto `m`, proving each
    /// step against the digest the original writer recorded.
    fn replay_wal(
        &self,
        mut m: MaintainedCounts,
        from_epoch: u64,
    ) -> Result<(MaintainedCounts, u64)> {
        let records = wal::read_records(&self.wal_path())?;
        let mut epoch = from_epoch;
        for rec in records {
            if rec.epoch <= from_epoch {
                continue; // already folded into the snapshot
            }
            if rec.epoch != epoch + 1 {
                return Err(perr(
                    "wal",
                    format!(
                        "gap: expected epoch {} next, found {}",
                        epoch + 1,
                        rec.epoch
                    ),
                ));
            }
            m.apply(&rec.batch)?;
            let got = m.digest();
            if got != rec.digest {
                return Err(perr(
                    "wal",
                    format!(
                        "replay diverged at epoch {}: digest {:016x}, writer recorded {:016x}",
                        rec.epoch, got, rec.digest
                    ),
                ));
            }
            epoch = rec.epoch;
        }
        Ok((m, epoch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::churn::churn_batch;
    use crate::db::fixtures::university_db;
    use crate::delta::maintain::MaintainConfig;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("relcount-datadir-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn save_recover_roundtrip_with_wal_suffix() {
        let root = tmp("roundtrip");
        let dd = DataDir::open(&root).unwrap();
        let mut m =
            MaintainedCounts::build(university_db(), MaintainConfig::default()).unwrap();
        dd.save_snapshot(&mut m, 0).unwrap();

        // three batches: snapshot after the first, WAL-only after
        let mut w = WalWriter::open(&dd.wal_path()).unwrap();
        for e in 1..=3u64 {
            let batch = churn_batch(m.db(), 0.05, 0xC0FFEE + e);
            m.apply(&batch).unwrap();
            w.append(e, m.digest(), &batch).unwrap();
            if e == 1 {
                dd.save_snapshot(&mut m, e).unwrap();
            }
        }
        drop(w);

        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 3);
        assert_eq!(r.digest(), m.digest());
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![0, 1]);
    }

    #[test]
    fn retention_prunes_oldest() {
        let root = tmp("retention");
        let dd = DataDir::open(&root).unwrap();
        assert_eq!(dd.retain(), RETAIN);
        let mut m =
            MaintainedCounts::build(university_db(), MaintainConfig::default()).unwrap();
        for e in [0, 5, 9] {
            dd.save_snapshot(&mut m, e).unwrap();
        }
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![5, 9]);
        assert_eq!(dd.latest_snapshot_epoch().unwrap(), Some(9));
    }

    #[test]
    fn configurable_retention_keeps_n_and_moves_wal_cutoff() {
        let root = tmp("retain-n");
        let dd = DataDir::with_retain(&root, 3).unwrap();
        assert_eq!(dd.retain(), 3);
        let mut m =
            MaintainedCounts::build(university_db(), MaintainConfig::default()).unwrap();
        for e in [0, 1, 2, 3, 4] {
            dd.save_snapshot(&mut m, e).unwrap();
        }
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![2, 3, 4]);
        // the WAL cutoff follows the oldest *retained* epoch, so wider
        // retention prunes less aggressively
        assert_eq!(dd.wal_prune_cutoff().unwrap(), Some(2));

        // a wider-retention reopen keeps more going forward
        let mut dd = DataDir::open(&root).unwrap();
        dd.set_retain(4).unwrap();
        dd.save_snapshot(&mut m, 5).unwrap();
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![2, 3, 4, 5]);

        // retention 0 is rejected everywhere
        assert!(DataDir::with_retain(&root, 0).is_err());
        assert!(dd.set_retain(0).is_err());
    }

    #[test]
    fn wal_prune_respects_oldest_retained_snapshot() {
        let root = tmp("wal-prune");
        let dd = DataDir::open(&root).unwrap();
        let mut m =
            MaintainedCounts::build(university_db(), MaintainConfig::default()).unwrap();
        dd.save_snapshot(&mut m, 0).unwrap();
        let mut w = WalWriter::open(&dd.wal_path()).unwrap();
        for e in 1..=4u64 {
            let batch = churn_batch(m.db(), 0.03, 0xBEEF + e);
            m.apply(&batch).unwrap();
            w.append(e, m.digest(), &batch).unwrap();
            if e == 2 || e == 3 {
                dd.save_snapshot(&mut m, e).unwrap();
                let cutoff = dd.wal_prune_cutoff().unwrap().unwrap();
                w = w.prune_through(cutoff).unwrap();
            }
        }
        drop(w);
        // snapshots 2 and 3 retained; the cutoff tracked the OLDEST one,
        // so epochs 1-2 are gone but 3-4 survive for the fallback path
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![2, 3]);
        assert_eq!(
            read_records(&dd.wal_path())
                .unwrap()
                .iter()
                .map(|r| r.epoch)
                .collect::<Vec<_>>(),
            vec![3, 4]
        );
        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 4);
        assert_eq!(r.digest(), m.digest());
    }

    #[test]
    fn recovery_falls_back_past_damaged_snapshot() {
        let root = tmp("fallback");
        let dd = DataDir::open(&root).unwrap();
        let mut m =
            MaintainedCounts::build(university_db(), MaintainConfig::default()).unwrap();
        dd.save_snapshot(&mut m, 0).unwrap();
        let mut w = WalWriter::open(&dd.wal_path()).unwrap();
        let batch = churn_batch(m.db(), 0.05, 7);
        m.apply(&batch).unwrap();
        w.append(1, m.digest(), &batch).unwrap();
        drop(w);
        dd.save_snapshot(&mut m, 1).unwrap();

        // damage the newest snapshot's caches section
        let caches = dd.snapshot_dir(1).join("caches.bin");
        let mut bytes = fs::read(&caches).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        fs::write(&caches, &bytes).unwrap();

        // epoch-1 snapshot fails verification; recovery uses epoch 0 +
        // WAL replay and still lands on the exact same state
        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(r.digest(), m.digest());

        // with the WAL also gone, recovery must refuse rather than
        // serve the unverified epoch-1 snapshot
        fs::remove_file(dd.wal_path()).unwrap();
        fs::remove_dir_all(dd.snapshot_dir(0)).unwrap();
        let e = dd.recover(0).unwrap_err();
        assert!(e.persist_section().is_some());
    }
}
