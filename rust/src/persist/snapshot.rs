//! The snapshot format: one manifest-addressed directory per epoch.
//!
//! ```text
//! snap-<epoch:016x>/
//!   MANIFEST.json   format, epoch, backend, config, cache_digest,
//!                   per-section {file, bytes, checksum}
//!   db.bin          schema JSON + columnar entity/relationship tables
//!   csr.bin         compacted CSR base arrays (CSR backend only)
//!   ccsr.bin        compacted packed block columns (CCSR backend only)
//!   plan.bin        the CountPlan, verbatim
//!   caches.bin      resident positive + complete ct-caches
//! ```
//!
//! Each `.bin` section is `[magic][payload len u64][payload][checksum
//! u64]`; the manifest records the same length and checksum, so a flip
//! in a section file *or* in the manifest's record of it is caught by
//! the cross-check.  The end-to-end integrity witness is the existing
//! `cache_digest` ([`crate::strategies::cache::digest_caches`]): it is
//! recomputed over the *reloaded* caches on every load and compared to
//! the manifest — a snapshot that cannot reproduce its own digest is
//! never served.
//!
//! What is persisted vs rebuilt:
//!
//! - the [`CountPlan`] is persisted **verbatim** — it was planned
//!   against the initial database and never re-planned on apply, so
//!   re-deriving it from the mutated tables would diverge from the
//!   pre-crash writer (and change which points are resident);
//! - the lattice is **rebuilt** — it is a pure function of (schema,
//!   max_chain_length);
//! - CSR indexes are persisted as base arrays and CCSR indexes as their
//!   packed block columns (the overlay is compacted first in both
//!   cases); the hash backend rebuilds its maps from the tables.
//!   Manifests written before the CCSR backend existed carry no
//!   `ccsr` section and load unchanged.

use std::fs::{self, File};
use std::io::Write as _;
use std::path::Path;

use crate::db::catalog::Database;
use crate::db::ccsr::{CcsrHalf, CcsrIndex};
use crate::db::csr::{CsrHalf, CsrIndex};
use crate::db::index::{Backend, RelIx};
use crate::db::schema::Schema;
use crate::db::table::{EntityTable, RelTable};
use crate::delta::maintain::{MaintainConfig, MaintainedCounts};
use crate::delta::policy::MaintenanceMode;
use crate::error::{Error, Result};
use crate::estimate::plan::{CountPlan, PlanLevel, PointEstimate};
use crate::estimate::sampler::EstimatorConfig;
use crate::meta::rvar::RVar;
use crate::persist::codec::{checksum64, ByteReader, ByteWriter};
use crate::strategies::cache::{digest_caches, CacheKey, CtCache};
use crate::util::json::Json;

/// Manifest `format` field; bump on any layout change.
pub const FORMAT: &str = "relcount-snapshot-v1";

const SECTION_MAGIC: &[u8; 8] = b"RCSNAP1\0";
pub const MANIFEST_FILE: &str = "MANIFEST.json";

fn perr(section: &str, msg: impl Into<String>) -> Error {
    Error::Persist { section: section.into(), msg: msg.into() }
}

/// Everything a snapshot holds, decoded and digest-verified.
pub struct SnapshotState {
    pub epoch: u64,
    /// Indexes installed (CSR from the persisted arrays, hash rebuilt).
    pub db: Database,
    /// The persisted maintenance config (workers as persisted; override
    /// at restore time if the new host differs).
    pub cfg: MaintainConfig,
    pub plan: CountPlan,
    pub positive: CtCache,
    pub complete: CtCache,
    /// The manifest digest, already verified against the loaded caches.
    pub cache_digest: u64,
}

impl SnapshotState {
    /// Restore a [`MaintainedCounts`] from this state, overriding the
    /// worker count when `workers > 0`.
    pub fn into_maintained(self, workers: usize) -> Result<MaintainedCounts> {
        let mut cfg = self.cfg;
        if workers > 0 {
            cfg.workers = workers;
        }
        MaintainedCounts::restore(
            self.db,
            cfg,
            self.plan,
            self.positive,
            self.complete,
        )
    }
}

/// Summary returned by [`verify_snapshot`] (a full load under the hood,
/// so "verified" means *loadable and digest-exact*, not just well-formed).
pub struct SnapshotInfo {
    pub epoch: u64,
    pub backend: Backend,
    pub cache_digest: u64,
    /// `(section, payload bytes)` in manifest order.
    pub sections: Vec<(String, u64)>,
}

// ---------------------------------------------------------------- sections

fn write_section(dir: &Path, name: &str, file: &str, payload: &[u8]) -> Result<(u64, u64)> {
    let crc = checksum64(payload);
    let mut out = Vec::with_capacity(24 + payload.len());
    out.extend_from_slice(SECTION_MAGIC);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc.to_le_bytes());
    let path = dir.join(file);
    let mut f = File::create(&path)
        .map_err(|e| perr(name, format!("create {}: {e}", path.display())))?;
    f.write_all(&out).map_err(|e| perr(name, format!("write: {e}")))?;
    f.sync_data().map_err(|e| perr(name, format!("fsync: {e}")))?;
    Ok((payload.len() as u64, crc))
}

fn read_section(
    dir: &Path,
    name: &str,
    file: &str,
    want_bytes: u64,
    want_crc: u64,
) -> Result<Vec<u8>> {
    let path = dir.join(file);
    let raw = fs::read(&path)
        .map_err(|e| perr(name, format!("read {}: {e}", path.display())))?;
    if raw.len() < 24 || &raw[..8] != SECTION_MAGIC {
        return Err(perr(name, "bad section magic"));
    }
    let len = u64::from_le_bytes(raw[8..16].try_into().unwrap());
    if raw.len() as u64 != 24 + len {
        return Err(perr(
            name,
            format!("file is {} bytes, header promises {}", raw.len(), 24 + len),
        ));
    }
    let payload = &raw[16..16 + len as usize];
    let stored = u64::from_le_bytes(raw[16 + len as usize..].try_into().unwrap());
    let crc = checksum64(payload);
    if crc != stored {
        return Err(perr(name, "section checksum mismatch"));
    }
    if len != want_bytes || crc != want_crc {
        return Err(perr(name, "manifest disagrees with section file"));
    }
    Ok(payload.to_vec())
}

// ------------------------------------------------------------------ db.bin

fn encode_db(db: &Database) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_str(&db.schema.to_json().dump());
    w.put_u32(db.entities.len() as u32);
    for t in &db.entities {
        w.put_u32(t.n);
        w.put_u32(t.cols.len() as u32);
        for c in &t.cols {
            w.put_u32s(c);
        }
    }
    w.put_u32(db.rels.len() as u32);
    for t in &db.rels {
        w.put_u32s(&t.from);
        w.put_u32s(&t.to);
        w.put_u32(t.cols.len() as u32);
        for c in &t.cols {
            w.put_u32s(c);
        }
    }
    w.into_bytes()
}

fn decode_db(payload: &[u8], backend: Backend) -> Result<Database> {
    let mut r = ByteReader::new(payload, "db");
    let schema_text = r.get_str()?;
    let schema_json = Json::parse(&schema_text)
        .map_err(|e| perr("db", format!("schema json: {e}")))?;
    let schema = Schema::from_json(&schema_json)
        .map_err(|e| perr("db", format!("schema: {e}")))?;

    let n_ent = r.get_u32()? as usize;
    let mut entities = Vec::with_capacity(n_ent);
    for i in 0..n_ent {
        let n = r.get_u32()?;
        let n_cols = r.get_u32()? as usize;
        let mut cols = Vec::with_capacity(n_cols);
        for _ in 0..n_cols {
            let c = r.get_u32s()?;
            if c.len() != n as usize {
                return Err(perr("db", format!("entity table {i}: ragged column")));
            }
            cols.push(c);
        }
        entities.push(EntityTable { n, cols });
    }
    let n_rel = r.get_u32()? as usize;
    let mut rels = Vec::with_capacity(n_rel);
    for i in 0..n_rel {
        let from = r.get_u32s()?;
        let to = r.get_u32s()?;
        if from.len() != to.len() {
            return Err(perr("db", format!("rel table {i}: from/to length skew")));
        }
        let n_cols = r.get_u32()? as usize;
        let mut cols = Vec::with_capacity(n_cols);
        for _ in 0..n_cols {
            let c = r.get_u32s()?;
            if c.len() != from.len() {
                return Err(perr("db", format!("rel table {i}: ragged column")));
            }
            cols.push(c);
        }
        rels.push(RelTable { from, to, cols });
    }
    r.finish()?;

    let mut db = Database::empty(schema);
    db.entities = entities;
    db.rels = rels;
    db.set_backend(backend)?; // no indexes yet: records the engine only
    db.validate().map_err(|e| perr("db", e.to_string()))?;
    Ok(db)
}

// ----------------------------------------------------------------- csr.bin

fn encode_half(w: &mut ByteWriter, h: &CsrHalf) {
    w.put_u32s(&h.offsets);
    w.put_u32s(&h.nbr);
    w.put_u32s(&h.tid);
}

fn decode_half(r: &mut ByteReader) -> Result<CsrHalf> {
    Ok(CsrHalf { offsets: r.get_u32s()?, nbr: r.get_u32s()?, tid: r.get_u32s()? })
}

fn encode_csr(db: &Database) -> Result<Vec<u8>> {
    let mut w = ByteWriter::new();
    w.put_u32(db.rels.len() as u32);
    for rel in 0..db.rels.len() {
        let ix = db.index(rel)?;
        let csr = ix.as_csr().ok_or_else(|| {
            perr("csr", format!("index {rel} is not CSR ({})", ix.backend().name()))
        })?;
        let (fwd, rev) = csr.halves().map_err(|e| perr("csr", e.to_string()))?;
        encode_half(&mut w, fwd);
        encode_half(&mut w, rev);
    }
    Ok(w.into_bytes())
}

/// Decode and install CSR indexes onto `db` (whose backend must be CSR).
fn decode_csr_into(payload: &[u8], db: &mut Database) -> Result<()> {
    let mut r = ByteReader::new(payload, "csr");
    let n = r.get_u32()? as usize;
    if n != db.rels.len() {
        return Err(perr(
            "csr",
            format!("{n} indexes for {} relationship tables", db.rels.len()),
        ));
    }
    let mut ixs = Vec::with_capacity(n);
    for rel in 0..n {
        let fwd = decode_half(&mut r)?;
        let rev = decode_half(&mut r)?;
        let ix = CsrIndex::from_halves(fwd, rev)
            .map_err(|e| perr("csr", format!("index {rel}: {e}")))?;
        ixs.push(RelIx::Csr(ix));
    }
    r.finish()?;
    db.install_indexes(ixs).map_err(|e| perr("csr", e.to_string()))
}

// ---------------------------------------------------------------- ccsr.bin

fn encode_ccsr_half(w: &mut ByteWriter, h: &CcsrHalf) {
    w.put_u32s(&h.offsets);
    w.put_u32s(&h.blk_offsets);
    w.put_u32s(&h.nbr_min);
    w.put_u32s(&h.nbr_max);
    w.put_u32s(&h.tid_min);
    w.put_u8s(&h.nbr_width);
    w.put_u8s(&h.tid_width);
    w.put_u64s(&h.data_off);
    w.put_u64s(&h.packed);
}

fn decode_ccsr_half(r: &mut ByteReader) -> Result<CcsrHalf> {
    Ok(CcsrHalf {
        offsets: r.get_u32s()?,
        blk_offsets: r.get_u32s()?,
        nbr_min: r.get_u32s()?,
        nbr_max: r.get_u32s()?,
        tid_min: r.get_u32s()?,
        nbr_width: r.get_u8s()?,
        tid_width: r.get_u8s()?,
        data_off: r.get_u64s()?,
        packed: r.get_u64s()?,
    })
}

fn encode_ccsr(db: &Database) -> Result<Vec<u8>> {
    let mut w = ByteWriter::new();
    w.put_u32(db.rels.len() as u32);
    for rel in 0..db.rels.len() {
        let ix = db.index(rel)?;
        let ccsr = ix.as_ccsr().ok_or_else(|| {
            perr("ccsr", format!("index {rel} is not CCSR ({})", ix.backend().name()))
        })?;
        let (fwd, rev) = ccsr.halves().map_err(|e| perr("ccsr", e.to_string()))?;
        encode_ccsr_half(&mut w, fwd);
        encode_ccsr_half(&mut w, rev);
    }
    Ok(w.into_bytes())
}

/// Decode and install CCSR indexes onto `db` (whose backend must be
/// CCSR).  [`CcsrIndex::from_halves`] re-validates the whole block
/// structure, so a corrupt-but-checksummed payload surfaces as a typed
/// error instead of a bad count.
fn decode_ccsr_into(payload: &[u8], db: &mut Database) -> Result<()> {
    let mut r = ByteReader::new(payload, "ccsr");
    let n = r.get_u32()? as usize;
    if n != db.rels.len() {
        return Err(perr(
            "ccsr",
            format!("{n} indexes for {} relationship tables", db.rels.len()),
        ));
    }
    let mut ixs = Vec::with_capacity(n);
    for rel in 0..n {
        let fwd = decode_ccsr_half(&mut r)?;
        let rev = decode_ccsr_half(&mut r)?;
        let ix = CcsrIndex::from_halves(fwd, rev)
            .map_err(|e| perr("ccsr", format!("index {rel}: {e}")))?;
        ixs.push(RelIx::Ccsr(ix));
    }
    r.finish()?;
    db.install_indexes(ixs).map_err(|e| perr("ccsr", e.to_string()))
}

// ---------------------------------------------------------------- plan.bin

fn encode_plan(p: &CountPlan) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u32(p.levels.len() as u32);
    for l in &p.levels {
        w.put_u8(match l {
            PlanLevel::OnDemand => 0,
            PlanLevel::Positive => 1,
            PlanLevel::Complete => 2,
        });
    }
    w.put_u8(p.marginals as u8);
    w.put_u64(p.marginal_bytes);
    match p.budget {
        Some(b) => {
            w.put_u8(1);
            w.put_u64(b);
        }
        None => {
            w.put_u8(0);
            w.put_u64(0);
        }
    }
    w.put_u64(p.est_spent_bytes);
    w.put_u64(p.est_all_positive_bytes);
    w.put_u64(p.est_all_complete_bytes);
    w.put_u64(p.walks);
    w.put_u32(p.estimates.len() as u32);
    for e in &p.estimates {
        w.put_usize(e.point);
        w.put_f64(e.est_join_rows);
        w.put_f64(e.est_positive_rows);
        w.put_u64(e.est_positive_bytes);
        w.put_f64(e.est_complete_rows);
        w.put_u64(e.est_complete_bytes);
        w.put_u64(e.reuse);
        w.put_u64(e.walks);
    }
    w.into_bytes()
}

fn decode_plan(payload: &[u8]) -> Result<CountPlan> {
    let mut r = ByteReader::new(payload, "plan");
    let n = r.get_u32()? as usize;
    let mut levels = Vec::with_capacity(n.min(payload.len()));
    for _ in 0..n {
        levels.push(match r.get_u8()? {
            0 => PlanLevel::OnDemand,
            1 => PlanLevel::Positive,
            2 => PlanLevel::Complete,
            x => return Err(r.err(format!("bad plan level {x}"))),
        });
    }
    let marginals = r.get_u8()? != 0;
    let marginal_bytes = r.get_u64()?;
    let budget = match (r.get_u8()?, r.get_u64()?) {
        (0, _) => None,
        (_, b) => Some(b),
    };
    let est_spent_bytes = r.get_u64()?;
    let est_all_positive_bytes = r.get_u64()?;
    let est_all_complete_bytes = r.get_u64()?;
    let walks = r.get_u64()?;
    let n_est = r.get_u32()? as usize;
    let mut estimates = Vec::with_capacity(n_est.min(payload.len()));
    for _ in 0..n_est {
        estimates.push(PointEstimate {
            point: r.get_usize()?,
            est_join_rows: r.get_f64()?,
            est_positive_rows: r.get_f64()?,
            est_positive_bytes: r.get_u64()?,
            est_complete_rows: r.get_f64()?,
            est_complete_bytes: r.get_u64()?,
            reuse: r.get_u64()?,
            walks: r.get_u64()?,
        });
    }
    r.finish()?;
    Ok(CountPlan {
        levels,
        marginals,
        estimates,
        marginal_bytes,
        budget,
        est_spent_bytes,
        est_all_positive_bytes,
        est_all_complete_bytes,
        walks,
    })
}

// -------------------------------------------------------------- caches.bin

fn encode_rvar(w: &mut ByteWriter, v: &RVar) {
    match *v {
        RVar::EntityAttr { et, attr } => {
            w.put_u8(0);
            w.put_usize(et);
            w.put_usize(attr);
        }
        RVar::RelAttr { rel, attr } => {
            w.put_u8(1);
            w.put_usize(rel);
            w.put_usize(attr);
        }
        RVar::RelInd { rel } => {
            w.put_u8(2);
            w.put_usize(rel);
            w.put_usize(0);
        }
    }
}

fn decode_rvar(r: &mut ByteReader) -> Result<RVar> {
    let tag = r.get_u8()?;
    let a = r.get_usize()?;
    let b = r.get_usize()?;
    Ok(match tag {
        0 => RVar::EntityAttr { et: a, attr: b },
        1 => RVar::RelAttr { rel: a, attr: b },
        2 => RVar::RelInd { rel: a },
        x => return Err(r.err(format!("bad rvar tag {x}"))),
    })
}

fn encode_cache(w: &mut ByteWriter, cache: &CtCache) {
    // sorted entry order (and sorted rows) so identical states always
    // serialize to identical bytes, whatever the hash-map iteration
    // order was — save→load→save is byte-stable.
    let mut entries: Vec<(&CacheKey, _)> = cache.iter().collect();
    entries.sort_by(|a, b| a.0.cmp(b.0));
    w.put_u64(entries.len() as u64);
    for (key, table) in entries {
        w.put_u32(key.0.len() as u32);
        for v in &key.0 {
            encode_rvar(w, v);
        }
        w.put_u32(key.1.len() as u32);
        for &c in &key.1 {
            w.put_usize(c);
        }
        w.put_u32(table.vars.len() as u32);
        for v in &table.vars {
            encode_rvar(w, v);
        }
        w.put_u32s(&table.dims);
        w.put_u64(table.n_rows() as u64);
        let mut rows: Vec<(u128, i128)> = table.iter_keys().collect();
        rows.sort_unstable();
        for (k, c) in rows {
            w.put_u128(k);
            w.put_i128(c);
        }
    }
}

fn decode_cache(r: &mut ByteReader) -> Result<CtCache> {
    use crate::ct::cttable::CtTable;
    let n = r.get_u64()?;
    let mut cache = CtCache::new();
    for _ in 0..n {
        let n_kv = r.get_u32()? as usize;
        let mut kvars = Vec::with_capacity(n_kv.min(1 << 16));
        for _ in 0..n_kv {
            kvars.push(decode_rvar(r)?);
        }
        let n_ctx = r.get_u32()? as usize;
        let mut ctx = Vec::with_capacity(n_ctx.min(1 << 16));
        for _ in 0..n_ctx {
            ctx.push(r.get_usize()?);
        }
        let n_tv = r.get_u32()? as usize;
        let mut tvars = Vec::with_capacity(n_tv.min(1 << 16));
        for _ in 0..n_tv {
            tvars.push(decode_rvar(r)?);
        }
        let dims = r.get_u32s()?;
        let mut table = CtTable::with_dims(tvars, dims)
            .map_err(|e| r.err(format!("ct table: {e}")))?;
        let n_rows = r.get_u64()?;
        for _ in 0..n_rows {
            let k = r.get_u128()?;
            let c = r.get_i128()?;
            table.add_key(k, c).map_err(|e| r.err(format!("ct row: {e}")))?;
        }
        cache.insert((kvars, ctx), table);
    }
    Ok(cache)
}

fn encode_caches(positive: &CtCache, complete: &CtCache) -> Vec<u8> {
    let mut w = ByteWriter::new();
    encode_cache(&mut w, positive);
    encode_cache(&mut w, complete);
    w.into_bytes()
}

fn decode_caches(payload: &[u8]) -> Result<(CtCache, CtCache)> {
    let mut r = ByteReader::new(payload, "caches");
    let positive = decode_cache(&mut r)?;
    let complete = decode_cache(&mut r)?;
    r.finish()?;
    Ok((positive, complete))
}

// ---------------------------------------------------------------- manifest

fn hex(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex(j: &Json, field: &str) -> Result<u64> {
    let s = j
        .req(field)
        .and_then(|x| {
            x.as_str().ok_or_else(|| Error::Manifest(format!("{field}: not a string")))
        })
        .map_err(|e| perr("manifest", e.to_string()))?;
    u64::from_str_radix(s, 16)
        .map_err(|_| perr("manifest", format!("{field}: bad hex {s:?}")))
}

fn config_json(cfg: &MaintainConfig) -> Json {
    Json::obj(vec![
        ("max_chain_length", Json::num(cfg.max_chain_length as f64)),
        (
            "mem_budget",
            match cfg.mem_budget {
                Some(b) => Json::num(b as f64),
                None => Json::Null,
            },
        ),
        (
            "estimator",
            Json::obj(vec![
                ("seed", Json::str(hex(cfg.estimator.seed))),
                ("walks", Json::num(cfg.estimator.walks as f64)),
                (
                    "exhaustive_limit",
                    Json::num(cfg.estimator.exhaustive_limit as f64),
                ),
                ("summary_bound", Json::num(cfg.estimator.summary_bound)),
            ]),
        ),
        ("workers", Json::num(cfg.workers as f64)),
        ("mode", Json::str(cfg.mode.name())),
        ("verify", Json::Bool(cfg.verify)),
    ])
}

fn config_from_json(j: &Json) -> Result<MaintainConfig> {
    let m = |e: Error| perr("manifest", e.to_string());
    let get_usize = |field: &str| -> Result<usize> {
        j.req(field)
            .and_then(|x| {
                x.as_usize()
                    .ok_or_else(|| Error::Manifest(format!("{field}: not an integer")))
            })
            .map_err(m)
    };
    let est = j.req("estimator").map_err(m)?;
    let mode_s = j
        .req("mode")
        .and_then(|x| {
            x.as_str().ok_or_else(|| Error::Manifest("mode: not a string".into()))
        })
        .map_err(m)?;
    let mode = MaintenanceMode::parse(mode_s)
        .ok_or_else(|| perr("manifest", format!("bad mode {mode_s:?}")))?;
    Ok(MaintainConfig {
        max_chain_length: get_usize("max_chain_length")?,
        mem_budget: match j.req("mem_budget").map_err(m)? {
            Json::Null => None,
            x => Some(x.as_f64().ok_or_else(|| {
                perr("manifest", "mem_budget: not a number")
            })? as u64),
        },
        estimator: EstimatorConfig {
            seed: parse_hex(est, "seed")?,
            walks: est
                .req("walks")
                .and_then(|x| {
                    x.as_usize()
                        .ok_or_else(|| Error::Manifest("walks: not an integer".into()))
                })
                .map_err(m)? as u32,
            exhaustive_limit: est
                .req("exhaustive_limit")
                .and_then(|x| {
                    x.as_usize().ok_or_else(|| {
                        Error::Manifest("exhaustive_limit: not an integer".into())
                    })
                })
                .map_err(m)? as u64,
            // absent in pre-summary-tier manifests: default to tier-off
            summary_bound: match est.get("summary_bound") {
                Some(x) => x.as_f64().ok_or_else(|| {
                    perr("manifest", "summary_bound: not a number")
                })?,
                None => 0.0,
            },
        },
        workers: get_usize("workers")?,
        mode,
        verify: matches!(j.req("verify").map_err(m)?, Json::Bool(true)),
    })
}

// ------------------------------------------------------------- save / load

/// Serialize `m` (compacted: [`MaintainedCounts::compact_indexes`] has
/// run) into `dir`, which must exist and be empty-ish (files are
/// overwritten).  The caller owns atomicity (write to a temp dir, then
/// rename) — see [`crate::persist::DataDir::save_snapshot`].
pub fn write_snapshot(dir: &Path, m: &MaintainedCounts, epoch: u64) -> Result<()> {
    let db = m.db();
    let backend = db.backend();
    let (positive, complete) = m.caches();

    let mut sections: Vec<(&str, &str, Vec<u8>)> = vec![
        ("db", "db.bin", encode_db(db)),
        ("plan", "plan.bin", encode_plan(m.plan())),
        ("caches", "caches.bin", encode_caches(positive, complete)),
    ];
    if backend == Backend::Csr {
        sections.insert(1, ("csr", "csr.bin", encode_csr(db)?));
    }
    if backend == Backend::Ccsr {
        sections.insert(1, ("ccsr", "ccsr.bin", encode_ccsr(db)?));
    }

    let mut section_json = Vec::new();
    for (name, file, payload) in &sections {
        let (bytes, crc) = write_section(dir, name, file, payload)?;
        section_json.push((
            *name,
            Json::obj(vec![
                ("file", Json::str(*file)),
                ("bytes", Json::num(bytes as f64)),
                ("checksum", Json::str(hex(crc))),
            ]),
        ));
    }

    let manifest = Json::obj(vec![
        ("format", Json::str(FORMAT)),
        ("epoch", Json::num(epoch as f64)),
        ("backend", Json::str(backend.name())),
        ("cache_digest", Json::str(hex(m.digest()))),
        ("config", config_json(m.config())),
        ("sections", Json::Obj(
            section_json.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )),
    ]);
    let path = dir.join(MANIFEST_FILE);
    let mut f = File::create(&path)
        .map_err(|e| perr("manifest", format!("create {}: {e}", path.display())))?;
    f.write_all(manifest.dump().as_bytes())
        .map_err(|e| perr("manifest", format!("write: {e}")))?;
    f.sync_data().map_err(|e| perr("manifest", format!("fsync: {e}")))?;
    Ok(())
}

struct Manifest {
    epoch: u64,
    backend: Backend,
    cache_digest: u64,
    cfg: MaintainConfig,
    /// `(section, file, bytes, checksum)`.
    sections: Vec<(String, String, u64, u64)>,
}

fn read_manifest(dir: &Path) -> Result<Manifest> {
    let path = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&path)
        .map_err(|e| perr("manifest", format!("read {}: {e}", path.display())))?;
    let j = Json::parse(&text).map_err(|e| perr("manifest", e.to_string()))?;
    let m = |e: Error| perr("manifest", e.to_string());
    let format = j
        .req("format")
        .and_then(|x| {
            x.as_str().ok_or_else(|| Error::Manifest("format: not a string".into()))
        })
        .map_err(m)?;
    if format != FORMAT {
        return Err(perr("manifest", format!("unsupported format {format:?}")));
    }
    let epoch = j
        .req("epoch")
        .and_then(|x| {
            x.as_usize().ok_or_else(|| Error::Manifest("epoch: not an integer".into()))
        })
        .map_err(m)? as u64;
    let backend_s = j
        .req("backend")
        .and_then(|x| {
            x.as_str().ok_or_else(|| Error::Manifest("backend: not a string".into()))
        })
        .map_err(m)?;
    let backend = Backend::parse(backend_s)
        .ok_or_else(|| perr("manifest", format!("bad backend {backend_s:?}")))?;
    let cache_digest = parse_hex(&j, "cache_digest")?;
    let cfg = config_from_json(j.req("config").map_err(m)?)?;
    let sec_obj = j
        .req("sections")
        .and_then(|x| {
            x.as_obj().ok_or_else(|| Error::Manifest("sections: not an object".into()))
        })
        .map_err(m)?;
    let mut sections = Vec::new();
    for (name, s) in sec_obj {
        let file = s
            .req("file")
            .and_then(|x| {
                x.as_str().ok_or_else(|| Error::Manifest("file: not a string".into()))
            })
            .map_err(m)?
            .to_string();
        let bytes = s
            .req("bytes")
            .and_then(|x| {
                x.as_usize()
                    .ok_or_else(|| Error::Manifest("bytes: not an integer".into()))
            })
            .map_err(m)? as u64;
        let crc = parse_hex(s, "checksum")?;
        sections.push((name.clone(), file, bytes, crc));
    }
    Ok(Manifest { epoch, backend, cache_digest, cfg, sections })
}

impl Manifest {
    fn section(&self, name: &str) -> Result<&(String, String, u64, u64)> {
        self.sections
            .iter()
            .find(|(n, ..)| n == name)
            .ok_or_else(|| perr("manifest", format!("missing section {name:?}")))
    }
}

/// Load and fully verify a snapshot directory: every section's length
/// and checksum (against both its own header and the manifest), then
/// the reloaded caches' digest against the manifest `cache_digest`.
pub fn load_snapshot(dir: &Path) -> Result<SnapshotState> {
    let man = read_manifest(dir)?;

    let (_, file, bytes, crc) = man.section("db")?;
    let db_payload = read_section(dir, "db", file, *bytes, *crc)?;
    let mut db = decode_db(&db_payload, man.backend)?;

    match man.backend {
        Backend::Csr => {
            let (_, file, bytes, crc) = man.section("csr")?;
            let payload = read_section(dir, "csr", file, *bytes, *crc)?;
            decode_csr_into(&payload, &mut db)?;
        }
        Backend::Ccsr => {
            let (_, file, bytes, crc) = man.section("ccsr")?;
            let payload = read_section(dir, "ccsr", file, *bytes, *crc)?;
            decode_ccsr_into(&payload, &mut db)?;
        }
        Backend::Hash => {
            db.build_indexes().map_err(|e| perr("db", e.to_string()))?;
        }
    }

    let (_, file, bytes, crc) = man.section("plan")?;
    let plan = decode_plan(&read_section(dir, "plan", file, *bytes, *crc)?)?;

    let (_, file, bytes, crc) = man.section("caches")?;
    let (positive, complete) =
        decode_caches(&read_section(dir, "caches", file, *bytes, *crc)?)?;

    let digest = digest_caches(&[(0u8, &positive), (1u8, &complete)]);
    if digest != man.cache_digest {
        return Err(perr(
            "digest",
            format!(
                "reloaded caches digest {:016x} != manifest cache_digest {:016x}",
                digest, man.cache_digest
            ),
        ));
    }

    Ok(SnapshotState {
        epoch: man.epoch,
        db,
        cfg: man.cfg,
        plan,
        positive,
        complete,
        cache_digest: digest,
    })
}

/// Verify by loading (so a "valid" snapshot is one that reproduces its
/// own digest), returning a summary instead of the state.
pub fn verify_snapshot(dir: &Path) -> Result<SnapshotInfo> {
    let man = read_manifest(dir)?;
    let state = load_snapshot(dir)?;
    Ok(SnapshotInfo {
        epoch: state.epoch,
        backend: state.db.backend(),
        cache_digest: state.cache_digest,
        sections: man.sections.iter().map(|(n, _, b, _)| (n.clone(), *b)).collect(),
    })
}
