//! The write-ahead log: durable [`DeltaBatch`]es between snapshots.
//!
//! One append-only file (`wal.log`) holding a magic header followed by
//! framed records.  The payload is the PR 3 JSON wire format
//! ([`DeltaBatch::to_json`]) — the same bytes `relcount apply --deltas`
//! reads — wrapped in a binary frame that makes every corruption mode
//! distinguishable:
//!
//! ```text
//! magic   8B   "RCWAL1\0\0"
//! record: len       u32   payload byte length
//!         epoch     u64   generation this batch produced
//!         digest    u64   writer cache_digest *after* applying the batch
//!         hcrc      u32   checksum of the 20 header bytes above
//!         payload   len   DeltaBatch JSON (UTF-8)
//!         crc       u64   checksum of epoch ‖ digest ‖ payload
//! ```
//!
//! The header checksum is what separates a **torn tail** (the process
//! died mid-append; fewer bytes than a full header, or a valid header
//! whose payload never finished) from **corruption** (a complete record
//! whose header or body fails its checksum).  Torn tails are silently
//! truncated on open-for-append — that is the expected shape of a crash
//! — while corruption anywhere is a typed [`Error::Persist`] naming the
//! record: recovery must never replay a batch it cannot prove intact.
//!
//! Appends are `fsync`ed ([`File::sync_data`]) before the engine
//! publishes the generation, so every published epoch is durable.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::delta::batch::DeltaBatch;
use crate::error::{Error, Result};
use crate::persist::codec::checksum64;

const MAGIC: &[u8; 8] = b"RCWAL1\0\0";
/// len + epoch + digest + hcrc.
const HEADER: usize = 4 + 8 + 8 + 4;
/// Trailing body checksum.
const TRAILER: usize = 8;

/// One decoded WAL record.
#[derive(Clone, Debug)]
pub struct WalRecord {
    /// The generation applying this batch produced.
    pub epoch: u64,
    /// The writer's `cache_digest` after the batch — recovery's witness
    /// that replay reproduced the pre-crash state bit-for-bit.
    pub digest: u64,
    pub batch: DeltaBatch,
}

fn wal_err(msg: impl Into<String>) -> Error {
    Error::Persist { section: "wal".into(), msg: msg.into() }
}

fn header_crc(len: u32, epoch: u64, digest: u64) -> u32 {
    let mut h = Vec::with_capacity(20);
    h.extend_from_slice(&len.to_le_bytes());
    h.extend_from_slice(&epoch.to_le_bytes());
    h.extend_from_slice(&digest.to_le_bytes());
    checksum64(&h) as u32
}

fn body_crc(epoch: u64, digest: u64, payload: &[u8]) -> u64 {
    let mut b = Vec::with_capacity(16 + payload.len());
    b.extend_from_slice(&epoch.to_le_bytes());
    b.extend_from_slice(&digest.to_le_bytes());
    b.extend_from_slice(payload);
    checksum64(&b)
}

fn encode_record(epoch: u64, digest: u64, batch: &DeltaBatch) -> Vec<u8> {
    let payload = batch.to_json().dump().into_bytes();
    let len = payload.len() as u32;
    let mut out = Vec::with_capacity(HEADER + payload.len() + TRAILER);
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&digest.to_le_bytes());
    out.extend_from_slice(&header_crc(len, epoch, digest).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&body_crc(epoch, digest, &payload).to_le_bytes());
    out
}

/// Result of scanning a WAL byte image.
struct Scan {
    records: Vec<WalRecord>,
    /// Byte length of the valid prefix (magic + complete records).
    valid_len: u64,
    /// A torn (incomplete) record follows the valid prefix.
    torn: bool,
}

fn scan(bytes: &[u8]) -> Result<Scan> {
    if bytes.is_empty() {
        // brand-new file before the magic is written
        return Ok(Scan { records: Vec::new(), valid_len: 0, torn: false });
    }
    if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
        return Err(wal_err("bad magic (not a relcount WAL)"));
    }
    let mut records = Vec::new();
    let mut o = MAGIC.len();
    loop {
        let remaining = bytes.len() - o;
        if remaining == 0 {
            return Ok(Scan { records, valid_len: o as u64, torn: false });
        }
        if remaining < HEADER {
            return Ok(Scan { records, valid_len: o as u64, torn: true });
        }
        let idx = records.len();
        let len = u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let epoch = u64::from_le_bytes(bytes[o + 4..o + 12].try_into().unwrap());
        let digest = u64::from_le_bytes(bytes[o + 12..o + 20].try_into().unwrap());
        let hcrc = u32::from_le_bytes(bytes[o + 20..o + 24].try_into().unwrap());
        if hcrc != header_crc(len, epoch, digest) {
            return Err(wal_err(format!("record {idx}: header checksum mismatch")));
        }
        if remaining < HEADER + len as usize + TRAILER {
            // header durable, payload not: the append was cut short
            return Ok(Scan { records, valid_len: o as u64, torn: true });
        }
        let p0 = o + HEADER;
        let payload = &bytes[p0..p0 + len as usize];
        let crc =
            u64::from_le_bytes(bytes[p0 + len as usize..p0 + len as usize + 8]
                .try_into()
                .unwrap());
        if crc != body_crc(epoch, digest, payload) {
            return Err(wal_err(format!("record {idx}: body checksum mismatch")));
        }
        let text = std::str::from_utf8(payload)
            .map_err(|_| wal_err(format!("record {idx}: payload is not UTF-8")))?;
        let batch = DeltaBatch::parse_json(text)
            .map_err(|e| wal_err(format!("record {idx}: {e}")))?;
        if let Some(prev) = records.last() {
            let prev: &WalRecord = prev;
            if epoch <= prev.epoch {
                return Err(wal_err(format!(
                    "record {idx}: epoch {epoch} not after previous {}",
                    prev.epoch
                )));
            }
        }
        records.push(WalRecord { epoch, digest, batch });
        o += HEADER + len as usize + TRAILER;
    }
}

/// Read every intact record, ignoring a torn tail (the read-only
/// recovery path; corruption of a *complete* record is an error).
pub fn read_records(path: &Path) -> Result<Vec<WalRecord>> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let bytes = std::fs::read(path)?;
    Ok(scan(&bytes)?.records)
}

/// Rewrite the WAL at `path` keeping only records with `epoch > cutoff`,
/// returning how many records were dropped.  The rewrite is atomic
/// (temp file + `rename`, both fsynced), so a crash leaves either the
/// old or the new log — never a partial one.  A torn tail is dropped
/// with the pruned prefix (open-for-append would truncate it anyway);
/// corruption of a complete record refuses the prune, exactly like
/// [`read_records`] — a log that cannot be proven intact is never
/// rewritten.  No-op (and no I/O) when nothing is prunable.
pub fn prune_records(path: &Path, cutoff: u64) -> Result<usize> {
    if !path.exists() {
        return Ok(0);
    }
    let bytes = std::fs::read(path)?;
    let s = scan(&bytes)?;
    let kept: Vec<&WalRecord> = s.records.iter().filter(|r| r.epoch > cutoff).collect();
    let dropped = s.records.len() - kept.len();
    if dropped == 0 && !s.torn {
        return Ok(0);
    }
    let mut out = Vec::with_capacity(bytes.len());
    out.extend_from_slice(MAGIC);
    for r in &kept {
        out.extend_from_slice(&encode_record(r.epoch, r.digest, &r.batch));
    }
    let tmp = path.with_extension("log.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(&out)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, path)?;
    // best-effort: make the rename itself durable
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(dropped)
}

/// The append handle the serving engine holds.
pub struct WalWriter {
    file: File,
    path: PathBuf,
    /// Epoch of the last durable record (0 = none yet).
    last_epoch: u64,
}

impl WalWriter {
    /// Open (creating if absent) for append.  A torn tail left by a
    /// crash mid-append is truncated away here; corruption of any
    /// complete record refuses the open instead.
    pub fn open(path: &Path) -> Result<WalWriter> {
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let s = scan(&bytes)?;
        if bytes.is_empty() {
            file.write_all(MAGIC)?;
            file.sync_data()?;
        } else if s.torn {
            file.set_len(s.valid_len)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::End(0))?;
        let last_epoch = s.records.last().map(|r| r.epoch).unwrap_or(0);
        Ok(WalWriter { file, path: path.to_path_buf(), last_epoch })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn last_epoch(&self) -> u64 {
        self.last_epoch
    }

    /// Append one batch and `fsync` before returning, so a generation is
    /// only published once its WAL record is durable.
    pub fn append(&mut self, epoch: u64, digest: u64, batch: &DeltaBatch) -> Result<()> {
        if self.last_epoch != 0 && epoch <= self.last_epoch {
            return Err(wal_err(format!(
                "append epoch {epoch} not after last durable epoch {}",
                self.last_epoch
            )));
        }
        self.file.write_all(&encode_record(epoch, digest, batch))?;
        self.file.sync_data()?;
        self.last_epoch = epoch;
        Ok(())
    }

    /// Prune records with `epoch <= cutoff` and reopen the handle on the
    /// rewritten log.  Consumes `self` because the rewrite replaces the
    /// file under the append fd ([`prune_records`]'s temp + `rename`) —
    /// the old handle would keep appending to the unlinked inode.
    pub fn prune_through(self, cutoff: u64) -> Result<WalWriter> {
        let path = self.path.clone();
        drop(self);
        prune_records(&path, cutoff)?;
        WalWriter::open(&path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::batch::DeltaOp;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("relcount-wal-{}-{name}.log", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn batch(i: u32) -> DeltaBatch {
        DeltaBatch::new(vec![DeltaOp::InsertLink {
            rel: 0,
            from: i,
            to: i + 1,
            values: vec![1],
        }])
    }

    #[test]
    fn append_then_read_roundtrips() {
        let p = tmp("roundtrip");
        let mut w = WalWriter::open(&p).unwrap();
        for e in 1..=3u64 {
            w.append(e, 100 + e, &batch(e as u32)).unwrap();
        }
        assert_eq!(w.last_epoch(), 3);
        drop(w);
        let recs = read_records(&p).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[2].epoch, 3);
        assert_eq!(recs[2].digest, 103);
        assert_eq!(recs[0].batch, batch(1));
        // reopen keeps appending after the existing records
        let mut w = WalWriter::open(&p).unwrap();
        assert_eq!(w.last_epoch(), 3);
        assert!(w.append(3, 0, &batch(9)).is_err()); // non-advancing epoch
        w.append(4, 104, &batch(4)).unwrap();
        assert_eq!(read_records(&p).unwrap().len(), 4);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_truncated_on_open_kept_on_read() {
        let p = tmp("torn");
        let mut w = WalWriter::open(&p).unwrap();
        w.append(1, 11, &batch(1)).unwrap();
        w.append(2, 22, &batch(2)).unwrap();
        drop(w);
        let full = std::fs::read(&p).unwrap();
        // cut the last record short, mid-payload
        std::fs::write(&p, &full[..full.len() - 12]).unwrap();
        // read-only recovery sees only the intact prefix
        let recs = read_records(&p).unwrap();
        assert_eq!(recs.len(), 1);
        // open-for-append truncates the tear away
        let w = WalWriter::open(&p).unwrap();
        assert_eq!(w.last_epoch(), 1);
        drop(w);
        assert!(std::fs::metadata(&p).unwrap().len() < full.len() as u64);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn prune_keeps_suffix_and_reopens_for_append() {
        let p = tmp("prune");
        let mut w = WalWriter::open(&p).unwrap();
        for e in 1..=4u64 {
            w.append(e, 100 + e, &batch(e as u32)).unwrap();
        }
        // epochs 1-2 folded into a snapshot: prune through 2
        let mut w = w.prune_through(2).unwrap();
        assert_eq!(w.last_epoch(), 4);
        let recs = read_records(&p).unwrap();
        assert_eq!(recs.iter().map(|r| r.epoch).collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(recs[0].digest, 103);
        assert_eq!(recs[0].batch, batch(3));
        // the reopened handle keeps appending where the log left off
        w.append(5, 105, &batch(5)).unwrap();
        assert_eq!(read_records(&p).unwrap().len(), 3);
        // pruning nothing is a no-op (same bytes, no rewrite)
        let before = std::fs::read(&p).unwrap();
        let w = w.prune_through(2).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), before);
        assert_eq!(w.last_epoch(), 5);
        // pruning everything leaves a valid empty log that accepts any
        // future epoch
        let mut w = w.prune_through(u64::MAX).unwrap();
        assert_eq!(read_records(&p).unwrap().len(), 0);
        assert_eq!(w.last_epoch(), 0);
        w.append(6, 106, &batch(6)).unwrap();
        assert_eq!(read_records(&p).unwrap().len(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn prune_refuses_corrupt_log() {
        let p = tmp("prune-corrupt");
        let mut w = WalWriter::open(&p).unwrap();
        w.append(1, 11, &batch(1)).unwrap();
        w.append(2, 22, &batch(2)).unwrap();
        drop(w);
        let full = std::fs::read(&p).unwrap();
        let mut bad = full.clone();
        bad[MAGIC.len() + HEADER + 2] ^= 0x40;
        std::fs::write(&p, &bad).unwrap();
        assert!(prune_records(&p, 1).is_err());
        // the corrupt log is left untouched for forensics
        assert_eq!(std::fs::read(&p).unwrap(), bad);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corruption_is_typed_not_truncated() {
        let p = tmp("corrupt");
        let mut w = WalWriter::open(&p).unwrap();
        w.append(1, 11, &batch(1)).unwrap();
        w.append(2, 22, &batch(2)).unwrap();
        drop(w);
        let full = std::fs::read(&p).unwrap();
        // flip one payload byte of the *first* record (inside its JSON)
        let mut bad = full.clone();
        bad[MAGIC.len() + HEADER + 2] ^= 0x40;
        std::fs::write(&p, &bad).unwrap();
        let e = read_records(&p).unwrap_err();
        assert_eq!(e.persist_section(), Some("wal"));
        assert!(e.to_string().contains("record 0"));
        assert!(WalWriter::open(&p).is_err());
        // flip one byte of the last record's length field: the header
        // checksum catches it — it is NOT mistaken for a torn tail
        let mut bad = full.clone();
        let last = full.len() - (HEADER + batch(2).to_json().dump().len() + TRAILER);
        bad[last] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        let e = read_records(&p).unwrap_err();
        assert!(e.to_string().contains("header checksum"));
        let _ = std::fs::remove_file(&p);
    }
}
