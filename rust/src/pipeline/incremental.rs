//! Incremental positive-count maintenance.
//!
//! During ingestion we keep the length-1 positive ct-tables (one per
//! relationship, over all its variables) and the entity marginals up to
//! date per fact.  After ingest these seed the HYBRID/PRECOUNT positive
//! cache for chain length 1 — the longer chains still need joins, but the
//! single-rel tables (often the bulk of Figure 3's positive component on
//! 1-relationship databases like MovieLens) come for free.
//!
//! This is the chain-length-1 special case of the delta maintenance
//! subsystem ([`crate::delta`]): link facts apply *signed*
//! ([`IncrementalCounts::retract`] subtracts the same row `apply`
//! adds), so an ingest stream may interleave retractions.  For resident
//! caches at all chain lengths — including complete (negative-count)
//! tables under deletes — hand the finished database to
//! [`crate::delta::MaintainedCounts`], which generalizes this mechanism
//! with per-tuple join-row deltas and the delta-Möbius.

use crate::ct::cttable::CtTable;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::meta::extract::{vars_for_chain, vars_for_entity};
use crate::meta::rvar::RVar;
use crate::pipeline::source::Fact;

/// Incrementally-maintained counts.
#[derive(Debug)]
pub struct IncrementalCounts {
    schema: Schema,
    /// Marginal ct per entity type (over all its attrs).
    pub entity_cts: Vec<CtTable>,
    /// Positive ct per relationship (over all chain-1 vars), maintained
    /// only while the entity attributes it references are append-only.
    pub rel_cts: Vec<CtTable>,
    /// Entity attribute rows kept for link-time lookups.
    entity_attrs: Vec<Vec<Vec<u32>>>,
}

impl IncrementalCounts {
    pub fn new(schema: Schema) -> Result<Self> {
        let mut entity_cts = Vec::new();
        for et in 0..schema.entities.len() {
            entity_cts.push(CtTable::new(&schema, vars_for_entity(&schema, et))?);
        }
        let mut rel_cts = Vec::new();
        for rel in 0..schema.relationships.len() {
            rel_cts.push(CtTable::new(&schema, vars_for_chain(&schema, &[rel]))?);
        }
        let entity_attrs = vec![Vec::new(); schema.entities.len()];
        Ok(IncrementalCounts { schema, entity_cts, rel_cts, entity_attrs })
    }

    /// Apply one fact (must mirror the shard builder's stream).
    pub fn apply(&mut self, fact: &Fact) -> Result<()> {
        self.apply_signed(fact, 1)
    }

    /// Retract a previously applied **link** fact: subtracts the exact
    /// row `apply` added (zero rows compact away, so apply-then-retract
    /// is a no-op).  Entity facts cannot be retracted — populations are
    /// stable dimensions here, as in [`crate::delta`].
    pub fn retract(&mut self, fact: &Fact) -> Result<()> {
        if matches!(fact, Fact::Entity { .. }) {
            return Err(Error::Pipeline(
                "entity facts cannot be retracted incrementally (rebuild)".into(),
            ));
        }
        self.apply_signed(fact, -1)
    }

    fn apply_signed(&mut self, fact: &Fact, sign: i128) -> Result<()> {
        match fact {
            Fact::Entity { et, values } => {
                self.entity_cts[*et].add(values, sign)?;
                self.entity_attrs[*et].push(values.clone());
            }
            Fact::Link { rel, from, to, values } => {
                let (fe, te) = self.schema.rel_endpoints(*rel);
                let fa = self
                    .entity_attrs
                    .get(fe)
                    .and_then(|v| v.get(*from as usize))
                    .ok_or_else(|| Error::Pipeline("link before entity".into()))?;
                let ta = self
                    .entity_attrs
                    .get(te)
                    .and_then(|v| v.get(*to as usize))
                    .ok_or_else(|| Error::Pipeline("link before entity".into()))?;
                // Row layout must match vars_for_chain's canonical order:
                // entity attrs (sorted by (et, attr)) then rel attrs.
                let ct = &mut self.rel_cts[*rel];
                let mut row = Vec::with_capacity(ct.vars.len());
                for v in ct.vars.clone() {
                    let code = match v {
                        RVar::EntityAttr { et, attr } => {
                            if et == fe {
                                fa[attr]
                            } else {
                                ta[attr]
                            }
                        }
                        RVar::RelAttr { attr, .. } => values[attr] + 1, // ct coords
                        RVar::RelInd { .. } => unreachable!(),
                    };
                    row.push(code);
                }
                ct.add(&row, sign)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::{university_db, university_schema};
    use crate::db::query::{groupby_entity, positive_chain_ct, JoinStats};
    use crate::pipeline::source::db_to_facts;

    #[test]
    fn incremental_equals_batch() {
        let db = university_db();
        let mut inc = IncrementalCounts::new(university_schema()).unwrap();
        for f in db_to_facts(&db) {
            inc.apply(&f).unwrap();
        }
        // entity marginals
        for et in 0..3 {
            let batch =
                groupby_entity(&db, et, &vars_for_entity(&db.schema, et)).unwrap();
            assert_eq!(inc.entity_cts[et].n_rows(), batch.n_rows());
            for (v, c) in batch.iter_rows() {
                assert_eq!(inc.entity_cts[et].get(&v).unwrap(), c, "et {et} {v:?}");
            }
        }
        // single-rel positives
        for rel in 0..2 {
            let vars = vars_for_chain(&db.schema, &[rel]);
            let mut stats = JoinStats::default();
            let batch = positive_chain_ct(&db, &[rel], &vars, &mut stats).unwrap();
            assert_eq!(inc.rel_cts[rel].n_rows(), batch.n_rows(), "rel {rel}");
            for (v, c) in batch.iter_rows() {
                assert_eq!(inc.rel_cts[rel].get(&v).unwrap(), c, "rel {rel} {v:?}");
            }
        }
    }

    #[test]
    fn link_before_entity_fails() {
        let mut inc = IncrementalCounts::new(university_schema()).unwrap();
        let f = Fact::Link { rel: 0, from: 0, to: 0, values: vec![0, 0] };
        assert!(inc.apply(&f).is_err());
    }

    #[test]
    fn apply_then_retract_is_noop() {
        let db = university_db();
        let mut inc = IncrementalCounts::new(university_schema()).unwrap();
        for f in db_to_facts(&db) {
            inc.apply(&f).unwrap();
        }
        let rows_before: Vec<usize> =
            inc.rel_cts.iter().map(|t| t.n_rows()).collect();
        let link = Fact::Link { rel: 1, from: 2, to: 3, values: vec![1] };
        inc.apply(&link).unwrap();
        inc.retract(&link).unwrap();
        let rows_after: Vec<usize> = inc.rel_cts.iter().map(|t| t.n_rows()).collect();
        assert_eq!(rows_before, rows_after);
        // entity retraction is rejected
        let e = Fact::Entity { et: 0, values: vec![0] };
        assert!(inc.retract(&e).is_err());
    }
}
