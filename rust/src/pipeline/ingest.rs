//! The ingestion orchestrator: producer thread -> bounded channel
//! (backpressure) -> router applying facts to shard builders and the
//! incremental counters.

use std::sync::mpsc;
use std::time::Instant;

use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::pipeline::incremental::IncrementalCounts;
use crate::pipeline::shard::ShardSet;
use crate::pipeline::source::Fact;

/// Ingestion tuning.
#[derive(Clone, Copy, Debug)]
pub struct IngestorConfig {
    /// Facts per batch message.
    pub batch_size: usize,
    /// Bounded channel capacity in batches — the backpressure knob: a
    /// slow consumer blocks the producer once this many batches queue up.
    pub channel_batches: usize,
    /// Maintain incremental counts during ingest.
    pub incremental_counts: bool,
}

impl Default for IngestorConfig {
    fn default() -> Self {
        IngestorConfig { batch_size: 1024, channel_batches: 8, incremental_counts: true }
    }
}

/// What came out of an ingestion run.
pub struct IngestReport {
    pub db: Database,
    pub incremental: Option<IncrementalCounts>,
    pub facts: u64,
    pub batches: u64,
    pub elapsed: std::time::Duration,
    /// Seconds the producer spent blocked on the full channel.
    pub producer_blocked: std::time::Duration,
}

/// Run the pipeline: `producer` yields facts on its own thread; the
/// calling thread routes them into shard builders (entities must precede
/// the links that reference them, as in [`crate::pipeline::source::db_to_facts`]).
pub fn ingest<I>(schema: Schema, producer: I, cfg: IngestorConfig) -> Result<IngestReport>
where
    I: IntoIterator<Item = Fact> + Send + 'static,
    I::IntoIter: Send,
{
    if cfg.batch_size == 0 || cfg.channel_batches == 0 {
        return Err(Error::Pipeline("batch_size/channel_batches must be > 0".into()));
    }
    let t0 = Instant::now();
    let (tx, rx) = mpsc::sync_channel::<Vec<Fact>>(cfg.channel_batches);
    let batch_size = cfg.batch_size;
    let producer_handle = std::thread::Builder::new()
        .name("relcount-ingest-producer".into())
        .spawn(move || -> std::time::Duration {
            let mut blocked = std::time::Duration::ZERO;
            let mut batch = Vec::with_capacity(batch_size);
            for fact in producer {
                batch.push(fact);
                if batch.len() == batch_size {
                    let full = std::mem::replace(&mut batch, Vec::with_capacity(batch_size));
                    match tx.try_send(full) {
                        Ok(()) => {}
                        Err(mpsc::TrySendError::Full(b)) => {
                            let w0 = Instant::now();
                            if tx.send(b).is_err() {
                                return blocked; // consumer died
                            }
                            blocked += w0.elapsed();
                        }
                        Err(mpsc::TrySendError::Disconnected(_)) => return blocked,
                    }
                }
            }
            if !batch.is_empty() {
                let _ = tx.send(batch);
            }
            blocked
        })
        .map_err(|e| Error::Pipeline(format!("spawn: {e}")))?;

    let mut shards = ShardSet::new(schema.clone());
    let mut inc = if cfg.incremental_counts {
        Some(IncrementalCounts::new(schema)?)
    } else {
        None
    };
    let mut batches = 0u64;
    for batch in rx {
        batches += 1;
        for fact in &batch {
            shards.apply(fact)?;
            if let Some(inc) = inc.as_mut() {
                inc.apply(fact)?;
            }
        }
    }
    let producer_blocked = producer_handle
        .join()
        .map_err(|_| Error::Pipeline("producer panicked".into()))?;
    let facts = shards.facts_applied;
    let db = shards.finish()?;
    Ok(IngestReport {
        db,
        incremental: inc,
        facts,
        batches,
        elapsed: t0.elapsed(),
        producer_blocked,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::{university_db, university_schema};
    use crate::pipeline::source::db_to_facts;

    #[test]
    fn end_to_end_rebuild() {
        let db = university_db();
        let facts = db_to_facts(&db);
        let n = facts.len() as u64;
        let rep = ingest(
            university_schema(),
            facts,
            IngestorConfig { batch_size: 7, channel_batches: 2, incremental_counts: true },
        )
        .unwrap();
        assert_eq!(rep.facts, n);
        assert!(rep.batches >= n / 7);
        assert_eq!(rep.db.total_rows(), db.total_rows());
        assert!(rep.incremental.is_some());
        assert!(rep.db.has_indexes());
    }

    #[test]
    fn zero_config_rejected() {
        let r = ingest(
            university_schema(),
            Vec::<Fact>::new(),
            IngestorConfig { batch_size: 0, channel_batches: 1, incremental_counts: false },
        );
        assert!(r.is_err());
    }

    #[test]
    fn empty_stream_yields_empty_db() {
        let rep = ingest(
            university_schema(),
            Vec::<Fact>::new(),
            IngestorConfig::default(),
        )
        .unwrap();
        assert_eq!(rep.facts, 0);
        assert_eq!(rep.db.total_rows(), 0);
    }
}
