//! Streaming ingestion pipeline — the L3 data-pipeline coordinator.
//!
//! Data facts arrive as a stream (from a generator, a file, or a test
//! vector), are batched by a producer, pushed through a *bounded* channel
//! (backpressure), routed by shard to per-table builders, and finalized
//! into a [`Database`](crate::db::Database).  Positive counts for
//! single-relationship chains and entity marginals are maintained
//! *incrementally* during ingestion ([`incremental`]), so a HYBRID
//! pre-count after ingest starts warm.

pub mod incremental;
pub mod ingest;
pub mod shard;
pub mod source;

pub use incremental::IncrementalCounts;
pub use ingest::{ingest, IngestReport, IngestorConfig};
pub use source::{db_to_facts, Fact};
