//! Per-table shard builders: accumulate routed facts into columnar
//! tables, validating against the schema as they go.

use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::db::table::{EntityTable, RelTable};
use crate::error::{Error, Result};
use crate::pipeline::source::Fact;

/// Accumulates facts for one database.
#[derive(Debug)]
pub struct ShardSet {
    schema: Schema,
    entities: Vec<EntityTable>,
    rels: Vec<RelTable>,
    pub facts_applied: u64,
}

impl ShardSet {
    pub fn new(schema: Schema) -> Self {
        let entities =
            schema.entities.iter().map(|e| EntityTable::new(e.attrs.len())).collect();
        let rels =
            schema.relationships.iter().map(|r| RelTable::new(r.attrs.len())).collect();
        ShardSet { schema, entities, rels, facts_applied: 0 }
    }

    /// Route and apply one fact.
    pub fn apply(&mut self, fact: &Fact) -> Result<()> {
        match fact {
            Fact::Entity { et, values } => {
                if *et >= self.entities.len() {
                    return Err(Error::Pipeline(format!("bad entity type {et}")));
                }
                self.entities[*et].push(values)?;
            }
            Fact::Link { rel, from, to, values } => {
                if *rel >= self.rels.len() {
                    return Err(Error::Pipeline(format!("bad relationship {rel}")));
                }
                let (fe, te) = self.schema.rel_endpoints(*rel);
                if *from >= self.entities[fe].len() || *to >= self.entities[te].len() {
                    return Err(Error::Pipeline(format!(
                        "link ({from},{to}) references missing entities (facts must \
                         arrive entities-first)"
                    )));
                }
                self.rels[*rel].push(*from, *to, values)?;
            }
        }
        self.facts_applied += 1;
        Ok(())
    }

    /// Finalize into a validated, indexed database.
    pub fn finish(self) -> Result<Database> {
        Database::new(self.schema, self.entities, self.rels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::{university_db, university_schema};
    use crate::pipeline::source::db_to_facts;

    #[test]
    fn rebuilds_database_from_facts() {
        let db = university_db();
        let mut s = ShardSet::new(university_schema());
        for f in db_to_facts(&db) {
            s.apply(&f).unwrap();
        }
        let back = s.finish().unwrap();
        assert_eq!(back.total_rows(), db.total_rows());
        assert_eq!(back.rels[0].from, db.rels[0].from);
        assert_eq!(back.entities[1].cols, db.entities[1].cols);
    }

    #[test]
    fn rejects_dangling_links() {
        let mut s = ShardSet::new(university_schema());
        let f = Fact::Link { rel: 0, from: 0, to: 0, values: vec![0, 0] };
        assert!(s.apply(&f).is_err());
    }

    #[test]
    fn rejects_bad_shard_ids() {
        let mut s = ShardSet::new(university_schema());
        assert!(s.apply(&Fact::Entity { et: 9, values: vec![] }).is_err());
        assert!(s
            .apply(&Fact::Link { rel: 9, from: 0, to: 0, values: vec![] })
            .is_err());
    }
}
