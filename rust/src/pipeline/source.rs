//! Fact streams: the unit of ingestion.

use crate::db::catalog::Database;
use crate::db::value::Code;

/// One data fact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fact {
    /// A new entity of type `et` (id assigned by arrival order).
    Entity { et: usize, values: Vec<Code> },
    /// A new relationship tuple.
    Link { rel: usize, from: u32, to: u32, values: Vec<Code> },
}

impl Fact {
    /// Shard key: entities and links route to their table's builder.
    pub fn shard(&self, n_entity_types: usize) -> usize {
        match self {
            Fact::Entity { et, .. } => *et,
            Fact::Link { rel, .. } => n_entity_types + *rel,
        }
    }
}

/// Flatten a database into a fact stream (entities first, so links always
/// reference existing ids) — used by tests and the replay example.
pub fn db_to_facts(db: &Database) -> Vec<Fact> {
    let mut out = Vec::new();
    for (et, t) in db.entities.iter().enumerate() {
        for i in 0..t.len() {
            out.push(Fact::Entity {
                et,
                values: (0..t.cols.len()).map(|a| t.value(a, i)).collect(),
            });
        }
    }
    for (rel, t) in db.rels.iter().enumerate() {
        for i in 0..t.len() {
            out.push(Fact::Link {
                rel,
                from: t.from[i as usize],
                to: t.to[i as usize],
                values: (0..t.cols.len()).map(|a| t.value(a, i)).collect(),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;

    #[test]
    fn fact_count_matches_rows() {
        let db = university_db();
        let facts = db_to_facts(&db);
        assert_eq!(facts.len() as u64, db.total_rows());
    }

    #[test]
    fn shards_are_stable() {
        let f1 = Fact::Entity { et: 2, values: vec![] };
        let f2 = Fact::Link { rel: 1, from: 0, to: 0, values: vec![] };
        assert_eq!(f1.shard(3), 2);
        assert_eq!(f2.shard(3), 4);
    }
}
