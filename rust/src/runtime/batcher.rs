//! The score micro-batcher — the L3 coordinator feature that amortizes
//! PJRT dispatch cost over many families.
//!
//! [`ScoreBatcher`] is the synchronous core: it packs up to `b_pad`
//! (q, r) count matrices into the `bdeu_batch` artifact's fixed batch
//! axis per dispatch.  [`ScoreService`] runs a batcher on a dedicated
//! thread behind an mpsc channel (the PJRT client is not `Send`), giving
//! the rest of the system a `Send + Clone` scoring handle with dynamic
//! batching: it drains whatever requests are queued (up to the batch
//! size) before dispatching.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::client::Runtime;

/// One family's padded-ready counts.
#[derive(Clone, Debug)]
pub struct FamilyCounts {
    /// Row-major `[q][r]` counts (true dims, unpadded).
    pub counts: Vec<f64>,
    pub q: usize,
    pub r: usize,
    /// BDeu equivalent sample size N'.
    pub n_prime: f64,
}

impl FamilyCounts {
    /// Reject degenerate shapes before any alpha math: a family with an
    /// empty parent-configuration space or a zero-arity child has no
    /// BDeu score, and dividing by `q` / `q*r` anyway would send
    /// NaN/inf silently into every downstream score.
    fn check_dims(&self) -> Result<()> {
        if self.q == 0 || self.r == 0 {
            return Err(Error::Runtime(format!(
                "degenerate family counts (q={}, r={}): no BDeu alphas exist",
                self.q, self.r
            )));
        }
        Ok(())
    }

    /// BDeu row pseudocount `N'/q`; errors on degenerate (q, r).
    pub fn alpha_row(&self) -> Result<f64> {
        self.check_dims()?;
        Ok(self.n_prime / self.q as f64)
    }

    /// BDeu cell pseudocount `N'/(q·r)`; errors on degenerate (q, r)
    /// and on a `q*r` too large to represent.
    pub fn alpha_cell(&self) -> Result<f64> {
        self.check_dims()?;
        let cells = self.q.checked_mul(self.r).ok_or_else(|| {
            Error::Runtime(format!(
                "family counts shape overflows: q={} * r={}",
                self.q, self.r
            ))
        })?;
        Ok(self.n_prime / cells as f64)
    }
}

/// Synchronous micro-batcher over a [`Runtime`].
pub struct ScoreBatcher<'r> {
    rt: &'r Runtime,
    b_pad: usize,
    q_pad: usize,
    r_pad: usize,
    /// Batches dispatched (perf accounting).
    pub dispatches: u64,
}

impl<'r> ScoreBatcher<'r> {
    pub fn new(rt: &'r Runtime) -> Result<Self> {
        let spec = rt.manifest.artifact("bdeu_batch")?;
        Ok(ScoreBatcher {
            rt,
            b_pad: spec.meta_dim("b_pad")?,
            q_pad: spec.meta_dim("q_pad")?,
            r_pad: spec.meta_dim("r_pad")?,
            dispatches: 0,
        })
    }

    /// Max families per dispatch.
    pub fn batch_size(&self) -> usize {
        self.b_pad
    }

    /// True if a family fits the artifact's padded dims.
    pub fn fits(&self, q: usize, r: usize) -> bool {
        q <= self.q_pad && r <= self.r_pad
    }

    /// Score many families; chunks into artifact batches, zero-padding
    /// the tail.  Every family must satisfy [`ScoreBatcher::fits`].
    pub fn score_all(&mut self, reqs: &[FamilyCounts]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(self.b_pad) {
            out.extend(self.score_chunk(chunk)?);
        }
        Ok(out)
    }

    fn score_chunk(&mut self, chunk: &[FamilyCounts]) -> Result<Vec<f64>> {
        debug_assert!(chunk.len() <= self.b_pad);
        let mut counts = vec![0.0; self.b_pad * self.q_pad * self.r_pad];
        // padding batches with alpha=1 avoids lgamma(0) while scoring 0
        let mut ar = vec![1.0; self.b_pad];
        let mut ac = vec![1.0; self.b_pad];
        for (b, req) in chunk.iter().enumerate() {
            if !self.fits(req.q, req.r) {
                return Err(Error::Runtime(format!(
                    "family (q={}, r={}) exceeds padded ({}, {})",
                    req.q, req.r, self.q_pad, self.r_pad
                )));
            }
            if req.counts.len() != req.q * req.r {
                return Err(Error::Runtime("counts length != q*r".into()));
            }
            let base = b * self.q_pad * self.r_pad;
            for j in 0..req.q {
                let src = j * req.r;
                let dst = base + j * self.r_pad;
                counts[dst..dst + req.r].copy_from_slice(&req.counts[src..src + req.r]);
            }
            ar[b] = req.alpha_row()?;
            ac[b] = req.alpha_cell()?;
        }
        self.dispatches += 1;
        let scores = self.rt.bdeu_batch(&counts, &ar, &ac)?;
        Ok(scores[..chunk.len()].to_vec())
    }
}

enum Msg {
    Score(FamilyCounts, mpsc::Sender<Result<f64>>),
    Shutdown,
}

/// A `Send + Clone` scoring handle backed by a dedicated runtime thread
/// with dynamic batching.
pub struct ScoreService {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl ScoreService {
    /// Spawn the service; the thread loads its own [`Runtime`] from
    /// `artifact_dir` (PJRT clients cannot cross threads).
    pub fn spawn(artifact_dir: PathBuf) -> Result<ScoreService> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("relcount-score".into())
            .spawn(move || {
                let rt = match Runtime::load(&artifact_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut batcher = match ScoreBatcher::new(&rt) {
                    Ok(b) => b,
                    Err(_) => return,
                };
                let mut pending: Vec<(FamilyCounts, mpsc::Sender<Result<f64>>)> =
                    Vec::new();
                loop {
                    // block for the first request
                    match rx.recv() {
                        Ok(Msg::Score(fc, reply)) => pending.push((fc, reply)),
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                    // dynamic batching: drain whatever else is queued
                    let mut shutdown = false;
                    while pending.len() < batcher.batch_size() {
                        match rx.try_recv() {
                            Ok(Msg::Score(fc, reply)) => pending.push((fc, reply)),
                            Ok(Msg::Shutdown) => {
                                shutdown = true;
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                    let reqs: Vec<FamilyCounts> =
                        pending.iter().map(|(fc, _)| fc.clone()).collect();
                    match batcher.score_all(&reqs) {
                        Ok(scores) => {
                            for ((_, reply), s) in pending.drain(..).zip(scores) {
                                let _ = reply.send(Ok(s));
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            for (_, reply) in pending.drain(..) {
                                let _ = reply.send(Err(Error::Runtime(msg.clone())));
                            }
                        }
                    }
                    if shutdown {
                        break;
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("score service died during startup".into()))??;
        Ok(ScoreService { tx, handle: Some(handle) })
    }

    /// Score one family (blocks until the batch containing it returns).
    pub fn score(&self, fc: FamilyCounts) -> Result<f64> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Score(fc, reply_tx))
            .map_err(|_| Error::Runtime("score service is down".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("score service dropped the request".into()))?
    }

    /// A cloneable sender for concurrent producers.
    pub fn sender(&self) -> ScoreSender {
        ScoreSender { tx: self.tx.clone() }
    }
}

/// Cloneable, `Send` handle for submitting score requests.
#[derive(Clone)]
pub struct ScoreSender {
    tx: mpsc::Sender<Msg>,
}

impl ScoreSender {
    pub fn score(&self, fc: FamilyCounts) -> Result<f64> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Score(fc, reply_tx))
            .map_err(|_| Error::Runtime("score service is down".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("score service dropped the request".into()))?
    }
}

impl Drop for ScoreService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alphas() {
        let fc = FamilyCounts { counts: vec![0.0; 6], q: 3, r: 2, n_prime: 6.0 };
        assert_eq!(fc.alpha_row().unwrap(), 2.0);
        assert_eq!(fc.alpha_cell().unwrap(), 1.0);
    }

    #[test]
    fn degenerate_shapes_error_instead_of_nan() {
        for (q, r) in [(0usize, 2usize), (3, 0), (0, 0)] {
            let fc = FamilyCounts { counts: vec![], q, r, n_prime: 1.0 };
            assert!(fc.alpha_row().is_err(), "q={q} r={r}");
            assert!(fc.alpha_cell().is_err(), "q={q} r={r}");
        }
        // q*r overflow is caught, not wrapped
        let big = FamilyCounts {
            counts: vec![],
            q: usize::MAX / 2,
            r: 3,
            n_prime: 1.0,
        };
        assert!(big.alpha_row().is_ok()); // q alone is representable
        assert!(big.alpha_cell().is_err());
    }

    #[test]
    fn service_startup_failure_is_reported() {
        let e = match ScoreService::spawn(PathBuf::from("/nonexistent/arts")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(e.to_string().contains("manifest"));
    }
}
