//! PJRT CPU client + compiled-artifact registry.
//!
//! HLO *text* is the interchange format (see `/opt/xla-example/README.md`
//! and `python/compile/aot.py`): jax >= 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.  Each artifact is compiled once at load; execution is a
//! buffer pack / dispatch / tuple unpack.

use std::path::{Path, PathBuf};

use crate::util::fxhash::FxHashMap;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactSpec, Dtype, Manifest};

/// Typed input buffer for artifact execution.
pub enum InputBuf<'a> {
    F64(&'a [f64]),
    I32(&'a [i32]),
}

impl InputBuf<'_> {
    fn len(&self) -> usize {
        match self {
            InputBuf::F64(s) => s.len(),
            InputBuf::I32(s) => s.len(),
        }
    }

    fn dtype(&self) -> Dtype {
        match self {
            InputBuf::F64(_) => Dtype::F64,
            InputBuf::I32(_) => Dtype::I32,
        }
    }
}

fn xe(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// A compiled artifact.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime (not `Send`: the client is `Rc`-based; use
/// [`crate::runtime::batcher::ScoreService`] for cross-thread scoring).
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    artifacts: FxHashMap<String, Artifact>,
    pub dir: PathBuf,
    /// Number of artifact executions (for perf accounting).
    pub dispatches: std::cell::Cell<u64>,
}

impl Runtime {
    /// Load the manifest and compile every artifact on the CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        let mut artifacts = FxHashMap::default();
        for (name, spec) in &manifest.artifacts {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )
            .map_err(xe)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(xe)?;
            artifacts.insert(name.clone(), Artifact { spec: spec.clone(), exe });
        }
        Ok(Runtime {
            client,
            manifest,
            artifacts,
            dir: dir.to_path_buf(),
            dispatches: std::cell::Cell::new(0),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("artifact {name:?} not loaded")))
    }

    /// Execute an artifact.  Inputs are validated against the manifest;
    /// outputs are returned as f64 vectors (all our artifact outputs are
    /// f64).
    pub fn exec(&self, name: &str, inputs: &[InputBuf]) -> Result<Vec<Vec<f64>>> {
        let art = self.artifact(name)?;
        let spec = &art.spec;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            )));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (buf, ispec) in inputs.iter().zip(&spec.inputs) {
            if buf.len() != ispec.len() {
                return Err(Error::Runtime(format!(
                    "{name}.{}: {} elements given, {} expected",
                    ispec.name,
                    buf.len(),
                    ispec.len()
                )));
            }
            if buf.dtype() != ispec.dtype {
                return Err(Error::Runtime(format!(
                    "{name}.{}: dtype mismatch ({} expected)",
                    ispec.name,
                    ispec.dtype.name()
                )));
            }
            let dims: Vec<i64> = ispec.shape.iter().map(|&d| d as i64).collect();
            let lit = match buf {
                InputBuf::F64(s) => xla::Literal::vec1(s),
                InputBuf::I32(s) => xla::Literal::vec1(s),
            };
            lits.push(lit.reshape(&dims).map_err(xe)?);
        }
        self.dispatches.set(self.dispatches.get() + 1);
        let result = art.exe.execute::<xla::Literal>(&lits).map_err(xe)?[0][0]
            .to_literal_sync()
            .map_err(xe)?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple().map_err(xe)?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} outputs returned, {} expected",
                parts.len(),
                spec.outputs.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
            let v = lit.to_vec::<f64>().map_err(xe)?;
            if v.len() != ospec.len() {
                return Err(Error::Runtime(format!(
                    "{name}.{}: output length {} != {}",
                    ospec.name,
                    v.len(),
                    ospec.len()
                )));
            }
            out.push(v);
        }
        Ok(out)
    }

    // ---- high-level entry points --------------------------------------

    /// Batched BDeu scores via the `bdeu_batch` artifact.
    pub fn bdeu_batch(
        &self,
        counts: &[f64],
        alpha_row: &[f64],
        alpha_cell: &[f64],
    ) -> Result<Vec<f64>> {
        let out = self.exec(
            "bdeu_batch",
            &[InputBuf::F64(counts), InputBuf::F64(alpha_row), InputBuf::F64(alpha_cell)],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Möbius Join over a dense padded family tensor via the `mobius`
    /// artifact.
    pub fn mobius(&self, g: &[f64]) -> Result<Vec<f64>> {
        let out = self.exec("mobius", &[InputBuf::F64(g)])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Fused Möbius + projection + BDeu for one family.
    pub fn family_score(
        &self,
        g: &[f64],
        seg: &[i32],
        alpha_row: f64,
        alpha_cell: f64,
    ) -> Result<(f64, Vec<f64>)> {
        let ar = [alpha_row];
        let ac = [alpha_cell];
        let mut out = self.exec(
            "family_score",
            &[InputBuf::F64(g), InputBuf::I32(seg), InputBuf::F64(&ar), InputBuf::F64(&ac)],
        )?;
        let complete = out.pop().unwrap();
        let score = out.pop().unwrap()[0];
        Ok((score, complete))
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need built artifacts live in
    // rust/tests/runtime_artifacts.rs (integration), since `cargo test`
    // may run before `make artifacts` in some workflows.  Here we only
    // test error paths that need no artifacts.
    use super::*;

    #[test]
    fn missing_dir_is_a_manifest_error() {
        let e = match Runtime::load(Path::new("/nonexistent/relcount-artifacts")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(matches!(e, Error::Manifest(_)), "{e}");
        assert!(e.to_string().contains("make artifacts"));
    }
}
